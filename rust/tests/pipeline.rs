//! Pipeline-level invariants of the plan/materialize refactor:
//!
//! * **Arena parity** — for every batching method, materializing into a
//!   dirty arena-reused buffer is bit-identical to materializing into a
//!   fresh `DenseBatch::zeros` buffer (catches stale-buffer-reset
//!   bugs), and release/acquire cycles never reallocate.
//! * **Ring determinism** — `run_prefetched` at depths 1, 2 and 4
//!   consumes the same items in the same order with a sane
//!   `overlap_ratio`, and hands every buffer back.
//! * **Zero steady-state allocations** — an epoch loop over the ring
//!   allocates exactly `depth` buffers, independent of epoch count.

use ibmb::baselines;
use ibmb::batching::{
    materialize, BatchArena, BatchCache, BatchGenerator, DenseBatch,
};
use ibmb::datasets::{sbm, DatasetSpec};
use ibmb::pipeline::run_prefetched;
use ibmb::util::Rng;

const METHODS: [&str; 8] = [
    "node-wise IBMB",
    "batch-wise IBMB",
    "fixed random",
    "neighbor sampling",
    "LADIES",
    "GraphSAINT-RW",
    "Cluster-GCN",
    "shaDow",
];

fn assert_dense_eq(a: &DenseBatch, b: &DenseBatch, ctx: &str) {
    assert_eq!(a.num_real, b.num_real, "{ctx}: num_real");
    assert_eq!(a.num_outputs, b.num_outputs, "{ctx}: num_outputs");
    assert_eq!(a.x, b.x, "{ctx}: x");
    assert_eq!(a.adj, b.adj, "{ctx}: adj");
    assert_eq!(a.labels, b.labels, "{ctx}: labels");
    assert_eq!(a.mask, b.mask, "{ctx}: mask");
}

/// Every generator's plans must materialize identically into a reused
/// arena buffer and a fresh zeroed buffer — the contract that makes
/// buffer pooling safe, including shaDow's duplicated-node plans.
#[test]
fn arena_reuse_matches_fresh_zeros_for_every_generator() {
    let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 400);
    for method in METHODS {
        let mut gen = baselines::by_name(method, 6, 4, 384).unwrap();
        let mut rng = Rng::new(0xA11C);
        let plans = gen.plan(&ds, &ds.splits.train, &mut rng);
        assert!(!plans.is_empty(), "{method}: no plans");
        let bucket = plans
            .iter()
            .map(|p| p.num_nodes())
            .max()
            .unwrap()
            .next_power_of_two()
            .max(16);
        let mut arena = BatchArena::new(ds.feat_dim);
        let mut reused = arena.acquire(bucket);
        for (i, p) in plans.iter().enumerate() {
            let mut fresh = DenseBatch::zeros(bucket, ds.feat_dim);
            materialize(&ds, p, &mut fresh);
            // `reused` still holds the previous plan's contents here
            materialize(&ds, p, &mut reused);
            assert_dense_eq(&fresh, &reused, &format!("{method} batch {i}"));
        }
        arena.release(reused);
        // further acquire/release cycles must hit the pool, not malloc
        for _ in 0..3 {
            let b = arena.acquire(bucket);
            arena.release(b);
        }
        assert_eq!(arena.allocations(), 1, "{method}: arena reallocated");
    }
}

/// The cache's arena-scan materialization obeys the same reuse parity
/// as the owned-plan path.
#[test]
fn cache_materialize_into_is_reuse_safe() {
    let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 401);
    let mut gen = baselines::by_name("node-wise IBMB", 8, 4, 256).unwrap();
    let mut rng = Rng::new(0xCAFE);
    let plans = gen.plan(&ds, &ds.splits.train, &mut rng);
    let cache = BatchCache::build(&plans);
    let bucket = cache.max_batch_nodes().next_power_of_two().max(16);
    let mut reused = DenseBatch::zeros(bucket, ds.feat_dim);
    // visit in an order that puts big batches before small ones too
    let mut order: Vec<usize> = (0..cache.len()).collect();
    order.reverse();
    for pass in 0..2 {
        for &i in &order {
            let mut fresh = DenseBatch::zeros(bucket, ds.feat_dim);
            cache.materialize_into(&ds, i, &mut fresh);
            cache.materialize_into(&ds, i, &mut reused);
            assert_dense_eq(&fresh, &reused, &format!("pass {pass} batch {i}"));
        }
    }
}

/// Depths 1 (serial), 2 (double buffering) and 4 must produce identical
/// consume orders and plausible overlap accounting.
#[test]
fn ring_depths_1_2_4_agree() {
    let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 402);
    let mut gen = baselines::by_name("node-wise IBMB", 8, 4, 256).unwrap();
    let mut rng = Rng::new(0xD00D);
    let cache = BatchCache::build(&gen.plan(&ds, &ds.splits.train, &mut rng));
    let bucket = cache.max_batch_nodes().next_power_of_two().max(16);
    let order: Vec<usize> = (0..cache.len()).collect();

    let mut consumed_orders = Vec::new();
    let mut checksums = Vec::new();
    for depth in [1usize, 2, 4] {
        let mut arena = BatchArena::new(ds.feat_dim);
        let ring = arena.acquire_many(bucket, depth);
        let mut seen = Vec::new();
        let mut sum = 0.0f64;
        let (stats, ring) = run_prefetched(
            &order,
            ring,
            |i, buf| cache.materialize_into(&ds, i, buf),
            |i, buf| {
                seen.push(i);
                sum += buf.x[..buf.num_real * buf.feat]
                    .iter()
                    .map(|&v| v as f64)
                    .sum::<f64>();
            },
        );
        arena.release_many(ring);
        assert_eq!(stats.items, cache.len(), "depth {depth}");
        assert_eq!(stats.depth, depth);
        assert_eq!(arena.pooled(), depth, "depth {depth}: buffers lost");
        let r = stats.overlap_ratio();
        assert!((0.0..=1.0).contains(&r), "depth {depth}: overlap {r}");
        consumed_orders.push(seen);
        checksums.push(sum);
    }
    assert_eq!(consumed_orders[0], order);
    assert!(consumed_orders.windows(2).all(|w| w[0] == w[1]));
    // same buffers, same plans => identical data at every depth
    assert!(
        checksums.windows(2).all(|w| (w[0] - w[1]).abs() < 1e-6),
        "checksums diverge: {checksums:?}"
    );
}

/// The epoch loop's allocation profile: exactly `depth` buffers total,
/// no matter how many epochs stream through the ring.
#[test]
fn steady_state_epoch_loop_allocates_only_the_ring() {
    let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 403);
    let mut gen = baselines::by_name("node-wise IBMB", 8, 4, 256).unwrap();
    let mut rng = Rng::new(0xFEED);
    let cache = BatchCache::build(&gen.plan(&ds, &ds.splits.train, &mut rng));
    let bucket = cache.max_batch_nodes().next_power_of_two().max(16);
    let order: Vec<usize> = (0..cache.len()).collect();
    let depth = 3usize;
    let mut arena = BatchArena::new(ds.feat_dim);
    for epoch in 0..6 {
        let ring = arena.acquire_many(bucket, depth);
        let (stats, ring) = run_prefetched(
            &order,
            ring,
            |i, buf| cache.materialize_into(&ds, i, buf),
            |_, _| {},
        );
        arena.release_many(ring);
        assert_eq!(stats.items, cache.len());
        assert_eq!(
            arena.allocations(),
            depth,
            "epoch {epoch}: steady state allocated"
        );
    }
}

/// A stochastic method re-planning per epoch still reuses the arena
/// ring (the plans change; the buffers do not).
#[test]
fn stochastic_replanning_reuses_buffers() {
    let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 404);
    let mut gen = baselines::by_name("neighbor sampling", 4, 4, 256).unwrap();
    assert!(!gen.is_fixed());
    let mut rng = Rng::new(0xB0B0);
    let bucket = 256usize;
    let depth = 2usize;
    let mut arena = BatchArena::new(ds.feat_dim);
    for _epoch in 0..4 {
        let cache = BatchCache::build(&gen.plan(&ds, &ds.splits.train, &mut rng));
        assert!(cache.max_batch_nodes() <= bucket);
        let order: Vec<usize> = (0..cache.len()).collect();
        let ring = arena.acquire_many(bucket, depth);
        let (_, ring) = run_prefetched(
            &order,
            ring,
            |i, buf| cache.materialize_into(&ds, i, buf),
            |_, _| {},
        );
        arena.release_many(ring);
    }
    assert_eq!(arena.allocations(), depth);
}
