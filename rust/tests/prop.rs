//! Property-style randomized tests over the coordinator's invariants
//! (proptest is unavailable offline — DESIGN.md §7 — so we drive a
//! seeded generator through many random configurations; every failure
//! message includes the case seed for replay).
//!
//! Invariants covered:
//! * every batching method partitions the output nodes exactly
//!   (disjoint cover), respects its node budget, and produces
//!   structurally valid batches;
//! * cache round-trips preserve batches bit-exactly;
//! * schedulers always emit permutations;
//! * the METIS partitioner covers all nodes within balance bounds;
//! * push/power PPR mass bounds hold on random graphs;
//! * JSON parser round-trips random documents.

use std::collections::HashSet;

use ibmb::baselines;
use ibmb::batching::BatchCache;
use ibmb::datasets::{sbm, DatasetSpec};
use ibmb::graph::{synth_delta_stream, DynamicGraph, GraphView};
use ibmb::partition::metis::{partition_graph, MetisConfig};
use ibmb::ppr::incremental::{push_ppr_state, refresh_ppr_state};
use ibmb::ppr::power::{batch_ppr, PowerConfig};
use ibmb::ppr::push::{push_ppr, PushConfig, PushWorkspace};
use ibmb::scheduler::{
    batch_distance_matrix, OptimalCycleScheduler, Scheduler, WeightedScheduler,
};
use ibmb::util::json::{parse, to_string, Json};
use ibmb::util::Rng;

fn random_dataset(rng: &mut Rng) -> ibmb::datasets::Dataset {
    let spec = DatasetSpec {
        nodes: 300 + rng.next_below(500),
        communities: 4 + rng.next_below(12),
        classes: 3 + rng.next_below(5),
        feat_dim: 8,
        avg_degree: 4.0 + rng.next_f64() * 8.0,
        p_intra: 0.5 + rng.next_f64() * 0.3,
        p_adjacent: 0.1,
        degree_tail: 2.0 + rng.next_f64(),
        noise: 1.0,
        train_frac: 0.2 + rng.next_f64() * 0.4,
        val_frac: 0.1,
        name: "prop",
    };
    sbm::generate(&spec, rng.next_u64())
}

const METHODS: [&str; 8] = [
    "node-wise IBMB",
    "batch-wise IBMB",
    "fixed random",
    "neighbor sampling",
    "LADIES",
    "GraphSAINT-RW",
    "Cluster-GCN",
    "shaDow",
];

#[test]
fn prop_all_methods_produce_valid_batches() {
    let mut master = Rng::new(0xFACE);
    for case in 0..8 {
        let seed = master.next_u64();
        let mut rng = Rng::new(seed);
        let ds = random_dataset(&mut rng);
        let budget = 256 + 256 * rng.next_below(3);
        let nb = 2 + rng.next_below(6);
        let aux = 2 + rng.next_below(12);
        for method in METHODS {
            let mut gen = baselines::by_name(method, aux, nb, budget).unwrap();
            let out = ds.splits.train.clone();
            let batches = gen.plan(&ds, &out, &mut rng);
            assert!(
                !batches.is_empty(),
                "case {case} seed {seed}: {method} produced no batches"
            );
            let mut seen: HashSet<u32> = HashSet::new();
            for b in &batches {
                b.validate().unwrap_or_else(|e| {
                    panic!("case {case} seed {seed}: {method}: {e}")
                });
                assert!(b.num_outputs > 0, "{method}: empty outputs");
                for &o in b.output_nodes() {
                    // GraphSAINT may sample an output in several
                    // batches (global sampler); all others must not
                    if method != "GraphSAINT-RW" {
                        assert!(
                            seen.insert(o),
                            "case {case} seed {seed}: {method}: output {o} twice"
                        );
                    }
                }
            }
            // exact cover for partition-based methods
            if !matches!(method, "GraphSAINT-RW") {
                assert_eq!(
                    seen.len(),
                    out.len(),
                    "case {case} seed {seed}: {method} covers {}/{}",
                    seen.len(),
                    out.len()
                );
            }
        }
    }
}

#[test]
fn prop_cache_roundtrip_is_exact() {
    let mut master = Rng::new(0xBEEF);
    for _ in 0..6 {
        let seed = master.next_u64();
        let mut rng = Rng::new(seed);
        let ds = random_dataset(&mut rng);
        let mut gen =
            baselines::by_name("node-wise IBMB", 6, 4, 512).unwrap();
        let batches = gen.plan(&ds, &ds.splits.train, &mut rng);
        let cache = BatchCache::build(&batches);
        assert_eq!(cache.len(), batches.len(), "seed {seed}");
        for (i, b) in batches.iter().enumerate() {
            let got = cache.to_plan(i);
            assert_eq!(got.nodes, b.nodes, "seed {seed} batch {i}");
            assert_eq!(got.edges, b.edges, "seed {seed} batch {i}");
            assert_eq!(got.weights, b.weights, "seed {seed} batch {i}");
            assert_eq!(got.num_outputs, b.num_outputs);
        }
    }
}

#[test]
fn prop_schedulers_always_emit_permutations() {
    let mut master = Rng::new(0xD1CE);
    for _ in 0..10 {
        let seed = master.next_u64();
        let mut rng = Rng::new(seed);
        let b = 1 + rng.next_below(24);
        let c = 2 + rng.next_below(6);
        let hists: Vec<Vec<f64>> = (0..b)
            .map(|_| (0..c).map(|_| rng.next_f64() * 10.0).collect())
            .collect();
        let dist = batch_distance_matrix(&hists);
        let mut scheds: Vec<Box<dyn Scheduler>> = vec![
            Box::new(OptimalCycleScheduler::new(&dist, &mut rng)),
            Box::new(WeightedScheduler::new(dist.clone())),
        ];
        for s in scheds.iter_mut() {
            for _ in 0..3 {
                let mut o = s.epoch_order(&mut rng);
                o.sort_unstable();
                assert_eq!(
                    o,
                    (0..b).collect::<Vec<_>>(),
                    "seed {seed} b={b} {}",
                    s.name()
                );
            }
        }
    }
}

#[test]
fn prop_metis_covers_and_balances() {
    let mut master = Rng::new(0xF00D);
    for _ in 0..6 {
        let seed = master.next_u64();
        let mut rng = Rng::new(seed);
        let ds = random_dataset(&mut rng);
        let k = 2 + rng.next_below(8);
        let part =
            partition_graph(&ds.graph, k, &MetisConfig::default(), &mut rng);
        assert_eq!(part.len(), ds.graph.num_nodes(), "seed {seed}");
        let mut sizes = vec![0usize; k];
        for &p in &part {
            assert!((p as usize) < k, "seed {seed}: part id out of range");
            sizes[p as usize] += 1;
        }
        let ideal = ds.graph.num_nodes() as f64 / k as f64;
        for (p, &s) in sizes.iter().enumerate() {
            assert!(
                (s as f64) <= ideal * 1.6 + 2.0,
                "seed {seed}: part {p} has {s} (ideal {ideal:.0})"
            );
        }
    }
}

#[test]
fn prop_ppr_mass_bounds() {
    let mut master = Rng::new(0xAB1E);
    for _ in 0..6 {
        let seed = master.next_u64();
        let mut rng = Rng::new(seed);
        let ds = random_dataset(&mut rng);
        let n = ds.graph.num_nodes();
        let mut ws = PushWorkspace::new(n);
        for _ in 0..5 {
            let root = rng.next_below(n) as u32;
            let ppr = push_ppr(&ds.graph, root, &PushConfig::default(), &mut ws);
            let mass = ppr.total_mass();
            assert!(
                (0.0..=1.0 + 1e-4).contains(&mass),
                "seed {seed} root {root}: push mass {mass}"
            );
            assert!(
                ppr.scores.iter().all(|s| *s >= 0.0),
                "seed {seed}: negative score"
            );
        }
        let roots: Vec<u32> = (0..5)
            .map(|_| rng.next_below(n) as u32)
            .collect();
        let (_, scores) = batch_ppr(&ds.graph, &roots, &PowerConfig::default());
        let mass: f32 = scores.iter().sum();
        assert!(
            mass > 0.5 && mass <= 1.0 + 1e-3,
            "seed {seed}: power mass {mass}"
        );
    }
}

#[test]
fn prop_incremental_ppr_matches_full_recompute() {
    // Invariant (DESIGN.md §10): repairing a stored push state with
    // the residual-correction rule and re-draining on the new graph
    // agrees with recomputing PPR from scratch on the new graph, up to
    // the push tolerance — for any graph and any random delta.
    let mut master = Rng::new(0x0E17A);
    let cfg = PushConfig {
        alpha: 0.25,
        epsilon: 1e-5,
        max_sweeps: 300,
    };
    for case in 0..4 {
        let seed = master.next_u64();
        let mut rng = Rng::new(seed);
        let ds = random_dataset(&mut rng);
        let n = ds.graph.num_nodes();
        let mut ws = PushWorkspace::new(n);
        let roots: Vec<u32> =
            (0..5).map(|_| rng.next_below(n) as u32).collect();
        let states: Vec<_> = roots
            .iter()
            .map(|&s| push_ppr_state(&ds.graph, s, &cfg, &mut ws))
            .collect();

        let mut dg = DynamicGraph::new(ds.graph.clone());
        let delta = synth_delta_stream(
            &ds.graph,
            &[],
            1,
            10 + rng.next_below(40),
            rng.next_below(3),
            0,
            ds.num_classes,
            seed ^ 0xD17A,
        )
        .pop()
        .unwrap();
        let applied = dg.apply(&delta).unwrap_or_else(|e| {
            panic!("case {case} seed {seed}: bad delta: {e}")
        });

        for st in &states {
            let (inc, l1) =
                refresh_ppr_state(&dg, st, &applied, &cfg, &mut ws);
            assert!(
                l1.is_finite() && l1 >= 0.0,
                "case {case} seed {seed}: l1 {l1}"
            );
            let full = push_ppr_state(&dg, st.root, &cfg, &mut ws);
            let mut inc_p = std::collections::HashMap::new();
            for (i, &v) in inc.nodes.iter().enumerate() {
                inc_p.insert(v, inc.p[i]);
            }
            let mut full_p = std::collections::HashMap::new();
            for (i, &v) in full.nodes.iter().enumerate() {
                full_p.insert(v, full.p[i]);
            }
            let keys: HashSet<u32> =
                inc_p.keys().chain(full_p.keys()).copied().collect();
            for v in keys {
                let a = inc_p.get(&v).copied().unwrap_or(0.0);
                let b = full_p.get(&v).copied().unwrap_or(0.0);
                // ACL-style bound: each estimate is within
                // eps * deg(v) of the true new-graph PPR, plus
                // float-accumulation slack
                let bound = 10.0 * cfg.epsilon * dg.degree(v) as f32 + 1e-3;
                assert!(
                    (a - b).abs() < bound,
                    "case {case} seed {seed} root {} node {v}: \
                     inc {a} vs full {b} (bound {bound})",
                    st.root
                );
            }
            // p + r mass is conserved by correction and pushes alike
            let mass = inc.total_mass() + inc.residual_mass();
            assert!(
                (mass - 1.0).abs() < 2e-3,
                "case {case} seed {seed} root {}: p+r mass {mass}",
                st.root
            );
        }
    }
}

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.next_below(4) } else { rng.next_below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.next_f64() < 0.5),
        2 => Json::Num((rng.next_f64() * 2000.0 - 1000.0 * 64.0).round() / 64.0),
        3 => {
            let len = rng.next_below(8);
            let s: String = (0..len)
                .map(|_| {
                    let opts = ['a', 'Z', '9', '"', '\\', 'é', '\n', '😀'];
                    opts[rng.next_below(opts.len())]
                })
                .collect();
            Json::Str(s)
        }
        4 => Json::Arr(
            (0..rng.next_below(4))
                .map(|_| random_json(rng, depth - 1))
                .collect(),
        ),
        _ => {
            let mut m = std::collections::BTreeMap::new();
            for i in 0..rng.next_below(4) {
                m.insert(format!("k{i}"), random_json(rng, depth - 1));
            }
            Json::Obj(m)
        }
    }
}

#[test]
fn prop_json_roundtrip() {
    let mut rng = Rng::new(0x15A5);
    for case in 0..200 {
        let doc = random_json(&mut rng, 3);
        let text = to_string(&doc);
        let back = parse(&text)
            .unwrap_or_else(|e| panic!("case {case}: {e} for {text}"));
        assert_eq!(doc, back, "case {case}: {text}");
    }
}

#[test]
fn prop_cow_patch_matches_full_rebuild_over_random_delta_sequences() {
    // Snapshot contract (DESIGN.md §11): after ANY sequence of deltas,
    // patching the previous cow store by the refresh report's changed
    // set must equal a from-scratch rebuild bucket-for-bucket, and
    // every unchanged bucket must be pointer-shared (the patch copies
    // only what the delta touched).
    use ibmb::batching::refresh::{DynamicPlanSet, RefreshConfig};

    let mut rng = Rng::new(0xC0575 ^ 0xBEEF);
    for case in 0..6 {
        let seed = rng.next_u64();
        let mut case_rng = Rng::new(seed);
        let ds = random_dataset(&mut case_rng);
        let eval = ds.splits.train.clone();
        let cfg = RefreshConfig {
            aux_per_output: 4 + case_rng.next_below(6),
            max_outputs_per_batch: 20 + case_rng.next_below(20),
            node_budget: 128 + case_rng.next_below(128),
            l1_tol: 0.01 + case_rng.next_f64() as f32 * 0.05,
            ..Default::default()
        };
        let mut set = DynamicPlanSet::plan_initial(
            &ds.graph,
            &eval,
            cfg,
            &mut Rng::new(seed ^ 1),
        );
        let mut dg = DynamicGraph::new(ds.graph.clone());
        let mut cow = set.cow_cache();
        let deltas = synth_delta_stream(
            &ds.graph,
            &eval,
            3,
            4 + case_rng.next_below(40),
            case_rng.next_below(3),
            case_rng.next_below(4),
            ds.num_classes,
            seed ^ 2,
        );
        for (step, delta) in deltas.iter().enumerate() {
            let applied = dg.apply(delta).unwrap_or_else(|e| {
                panic!("case {case} seed {seed} step {step}: {e}")
            });
            let report = set.apply_delta(&dg, &applied);
            let patched = set.patch_cow(&cow, &report.changed_plans);
            let full = set.cow_cache();
            assert_eq!(patched.len(), full.len());
            for i in 0..full.len() {
                assert_eq!(
                    patched.batch_nodes(i),
                    full.batch_nodes(i),
                    "case {case} seed {seed} step {step} plan {i} nodes"
                );
                assert_eq!(
                    patched.edge_src_of(i),
                    full.edge_src_of(i),
                    "case {case} seed {seed} step {step} plan {i} src"
                );
                assert_eq!(
                    patched.edge_dst_of(i),
                    full.edge_dst_of(i),
                    "case {case} seed {seed} step {step} plan {i} dst"
                );
                assert_eq!(
                    patched.edge_weights_of(i),
                    full.edge_weights_of(i),
                    "case {case} seed {seed} step {step} plan {i} weights"
                );
                assert_eq!(
                    patched.num_outputs(i),
                    full.num_outputs(i),
                    "case {case} seed {seed} step {step} plan {i} outputs"
                );
            }
            assert_eq!(
                patched.shared_with(&cow),
                full.len() - report.changed_plans.len(),
                "case {case} seed {seed} step {step}: sharing accounting"
            );
            cow = patched;
        }
    }
}
