//! Telemetry integration tests: the lossy sink under overflow and
//! concurrency, JSONL → call-tree round trips across real thread
//! interleaving, and an end-to-end traced serving run.

use std::io::{self, Write};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use ibmb::datasets::{sbm, DatasetSpec};
use ibmb::serve::{self, ServeConfig, Skew};
use ibmb::telemetry::span::{Stage, ADMIT_EXEC, NO_GROUP, NO_QUERY};
use ibmb::telemetry::{assemble, render_tree, TraceSink, Tracer};

/// `Write` target shared with the writer thread (tests trace into
/// memory instead of a file).
#[derive(Clone, Default)]
struct Shared(Arc<Mutex<Vec<u8>>>);

impl Shared {
    fn text(&self) -> String {
        String::from_utf8(self.0.lock().unwrap().clone()).unwrap()
    }
}

impl Write for Shared {
    fn write(&mut self, b: &[u8]) -> io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(b);
        Ok(b.len())
    }
    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }
}

#[test]
fn bounded_queue_overflow_drops_and_counts_without_blocking() {
    // nobody drains the channel: capacity 2 batches of 8 events each
    // can land, everything else must be dropped — and the push loop
    // must complete (try_send never blocks), which this test proves by
    // finishing at all
    let (sink, rx) = TraceSink::unconsumed(2);
    let mut buf = sink.buffer_with(8);
    const EVENTS: u64 = 1000;
    for i in 0..EVENTS {
        buf.instant(Stage::Admission, i, NO_GROUP, 0, ADMIT_EXEC);
    }
    buf.flush();
    let held: u64 = rx.try_iter().map(|b| b.len() as u64).sum();
    assert_eq!(held, 16, "2 batches × 8 events pass the bounded channel");
    assert_eq!(
        held + sink.dropped(),
        EVENTS,
        "every event is either delivered or counted dropped"
    );
    assert!(sink.dropped() > 0);
}

#[test]
fn multi_thread_jsonl_roundtrips_into_a_well_formed_tree() {
    let out = Shared::default();
    let (sink, writer) = TraceSink::with_writer(Box::new(out.clone()), 64);
    // control-thread view of query 7 riding group 3
    let mut control = sink.buffer();
    control.instant(Stage::Admission, 7, NO_GROUP, 0, ADMIT_EXEC);
    control.instant(Stage::Routing, 7, NO_GROUP, 0, 0);
    control.enter(Stage::QueueWait, 7, 3, 0);
    control.instant(Stage::Coalesce, NO_QUERY, 3, 0, 1);
    // two "shard threads" flush group-scoped spans concurrently — the
    // assembler must tolerate their batches landing out of order
    std::thread::scope(|scope| {
        for (gid, sh) in [(3u64, 0u32), (4u64, 1u32)] {
            let mut tb = sink.buffer();
            scope.spawn(move || {
                tb.enter(Stage::Fill, NO_QUERY, gid, sh);
                tb.exit(Stage::Fill, NO_QUERY, gid, sh);
                tb.enter(Stage::Forward, NO_QUERY, gid, sh);
                std::thread::sleep(Duration::from_millis(1));
                tb.exit(Stage::Forward, NO_QUERY, gid, sh);
                tb.instant(Stage::Memo, NO_QUERY, gid, sh, 128);
            });
        }
    });
    control.exit(Stage::QueueWait, 7, 3, 0);
    control.instant(Stage::Complete, 7, 3, 0, 1234);
    drop(control);
    drop(sink);
    let summary = writer.finish().unwrap();
    assert_eq!(summary.events_dropped, 0);
    assert_eq!(summary.events_written, 16);

    let rep = assemble(&out.text()).unwrap();
    assert!(rep.header_seen);
    assert_eq!(rep.events, 16);
    assert_eq!(rep.dropped, 0);
    assert_eq!(rep.queries.len(), 1, "group 4 has no rider query");
    let q = &rep.queries[0];
    assert_eq!(q.query, 7);
    assert_eq!(q.group, Some(3));
    assert_eq!(q.outcome, Some(ADMIT_EXEC));
    assert!(q.complete);
    // the rider inherits its own group's spans but not group 4's
    let fills = q.nodes.iter().filter(|n| n.stage == Stage::Fill).count();
    assert_eq!(fills, 1);
    assert!(q.nodes.iter().all(|n| n.shard != Some(1)));
    // both groups' forward spans aggregate across threads
    assert_eq!(rep.stages["forward"].spans, 2);
    assert_eq!(rep.stages["fill"].spans, 2);
    let rendered = render_tree(q);
    assert!(rendered.contains("query 7"), "{rendered}");
    assert!(rendered.contains("group 3"), "{rendered}");
}

#[test]
fn traced_serving_run_assembles_end_to_end() {
    let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 33);
    let cfg = ServeConfig {
        queries: 48,
        clients: 8,
        shards: 2,
        flush_window: Duration::from_micros(300),
        ..Default::default()
    };
    let eval = ds.splits.train.clone();
    let mut setup = serve::prepare(ds, &eval, &cfg);
    let out = Shared::default();
    let (sink, writer) = TraceSink::with_writer(Box::new(out.clone()), 256);
    setup.tracer = Tracer::attached(sink);
    let r = serve::serve_closed_loop(&mut setup, &eval, Skew::Zipf(1.2), &cfg)
        .unwrap();
    assert_eq!(r.executed_queries + r.cache_hits, 48);
    // detach so the writer sees the channel close
    setup.tracer = Tracer::disabled();
    let summary = writer.finish().unwrap();
    assert!(summary.events_written > 0);

    let rep = assemble(&out.text()).unwrap();
    assert!(rep.header_seen);
    assert_eq!(rep.dropped, summary.events_dropped);
    assert!(!rep.queries.is_empty());
    assert!(rep.complete_queries > 0, "executed queries trace to complete");
    // the serve path must emit every core stage at least once
    for stage in ["admission", "routing", "queue_wait", "coalesce", "fill", "forward", "memo", "complete"]
    {
        assert!(
            rep.stages.contains_key(stage),
            "stage {stage} missing from {:?}",
            rep.stages.keys().collect::<Vec<_>>()
        );
    }
    assert_eq!(rep.stages["admission"].count as usize, 48);
    // executed queries ride groups; their trees carry shard spans
    let executed = rep
        .queries
        .iter()
        .find(|q| q.group.is_some() && q.complete)
        .expect("at least one executed query tree");
    assert!(executed
        .nodes
        .iter()
        .any(|n| n.stage == Stage::Forward));
    assert!(!render_tree(executed).is_empty());
}
