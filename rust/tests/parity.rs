//! Cross-language parity: the AOT artifact (JAX/Pallas → HLO → PJRT)
//! and the independent Rust sparse forward pass must agree on the same
//! batch — this validates the entire compile path end to end, for all
//! three models.

use ibmb::batching::{BatchCache, BatchGenerator, DenseBatch, NodeWiseIbmb};
use ibmb::datasets::{sbm, DatasetSpec};
use ibmb::inference::fullgraph::{forward, SparseGraphRef};
use ibmb::runtime::{ModelState, Runtime};
use ibmb::util::Rng;

fn runtime() -> Option<Runtime> {
    for dir in ["artifacts", "../artifacts"] {
        if std::path::Path::new(dir).join("manifest.json").exists() {
            return Some(Runtime::load(dir).expect("runtime"));
        }
    }
    eprintln!("SKIP: artifacts not built (run `make artifacts`)");
    None
}

fn tiny_dataset() -> ibmb::datasets::Dataset {
    let spec = DatasetSpec {
        nodes: 800,
        feat_dim: 64,
        classes: 10,
        ..DatasetSpec::tiny_for_tests()
    };
    sbm::generate(&spec, 77)
}

/// For each model: run the infer artifact on one IBMB batch and compare
/// loss/accuracy against the host-side exact forward on that batch's
/// subgraph.
#[test]
fn artifact_matches_host_forward_all_models() {
    let Some(mut rt) = runtime() else { return };
    let ds = tiny_dataset();
    let mut gen = NodeWiseIbmb {
        aux_per_output: 8,
        max_outputs_per_batch: 48,
        node_budget: 256,
        ..Default::default()
    };
    let mut rng = Rng::new(1);
    let cache = BatchCache::build(&gen.plan(
        &ds,
        &ds.splits.val[..60.min(ds.splits.val.len())].to_vec(),
        &mut rng,
    ));
    assert!(!cache.is_empty());

    for model in ["gcn", "sage", "gat"] {
        let meta = rt
            .manifest
            .bucket_meta(model, "infer", cache.max_batch_nodes())
            .expect("bucket")
            .clone();
        let state = ModelState::init(&meta, 42);
        let mut dense = DenseBatch::zeros(meta.n_pad, meta.feat);

        for b in 0..cache.len().min(3) {
            cache.materialize_into(&ds, b, &mut dense);
            let metrics = rt.infer_step(&meta, &state, &dense).expect("infer");

            // host-side forward on the same subgraph
            let batch = cache.to_plan(b);
            let n = batch.num_nodes();
            let edge_src: Vec<u32> = batch.edges.iter().map(|e| e.0).collect();
            let edge_dst: Vec<u32> = batch.edges.iter().map(|e| e.1).collect();
            let g = SparseGraphRef {
                n,
                edge_src: &edge_dst, // aggregation into dst: artifact's
                edge_dst: &edge_src, // adj[d][s] sums over s — but host
                weights: &batch.weights, // spmm sums into edge_dst...
            };
            // NOTE: batch edges are symmetric (undirected + both slots),
            // so orientation does not matter here; kept explicit for
            // clarity.
            let x = &dense.x[..n * meta.feat];
            let logits = forward(&meta, &state, &g, x);
            // compare masked correct-count and mean loss
            let c = meta.classes;
            let mut correct = 0.0f32;
            let mut loss_sum = 0.0f32;
            let mut msum = 0.0f32;
            for i in 0..batch.num_outputs {
                let row = &logits[i * c..(i + 1) * c];
                let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let lse: f32 =
                    row.iter().map(|v| (v - max).exp()).sum::<f32>().ln() + max;
                let label = dense.labels[i] as usize;
                loss_sum += lse - row[label];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap()
                    .0;
                if pred == label {
                    correct += 1.0;
                }
                msum += 1.0;
            }
            let host_loss = loss_sum / msum.max(1.0);
            assert_eq!(
                metrics.mask_count, msum,
                "{model} batch {b}: mask count"
            );
            assert!(
                (metrics.correct - correct).abs() < 0.5,
                "{model} batch {b}: correct {} vs host {}",
                metrics.correct,
                correct
            );
            assert!(
                (metrics.loss - host_loss).abs() < 5e-3 * host_loss.abs().max(1.0),
                "{model} batch {b}: loss {} vs host {}",
                metrics.loss,
                host_loss
            );
        }
    }
}

/// The fused train step must reduce training loss on a realistic batch
/// set, for every model — end-to-end learning signal through Pallas
/// kernels, custom VJPs, and fused Adam.
#[test]
fn train_step_learns_all_models() {
    let Some(mut rt) = runtime() else { return };
    let ds = tiny_dataset();
    let mut gen = NodeWiseIbmb {
        aux_per_output: 8,
        max_outputs_per_batch: 64,
        node_budget: 256,
        ..Default::default()
    };
    let mut rng = Rng::new(2);
    let cache = BatchCache::build(&gen.plan(&ds, &ds.splits.train, &mut rng));
    for model in ["gcn", "sage", "gat"] {
        let meta = rt
            .manifest
            .bucket_meta(model, "train", cache.max_batch_nodes())
            .expect("bucket")
            .clone();
        let mut state = ModelState::init(&meta, 7);
        let mut dense = DenseBatch::zeros(meta.n_pad, meta.feat);
        let mut first = None;
        let mut last = 0.0;
        for epoch in 0..6 {
            let mut epoch_loss = 0.0;
            let mut count = 0.0;
            for b in 0..cache.len() {
                cache.materialize_into(&ds, b, &mut dense);
                let m = rt
                    .train_step(&meta, &mut state, &dense, 5e-3, epoch * 100 + b as i32)
                    .expect("train step");
                epoch_loss += m.loss as f64 * m.mask_count as f64;
                count += m.mask_count as f64;
            }
            let loss = epoch_loss / count;
            if first.is_none() {
                first = Some(loss);
            }
            last = loss;
        }
        let first = first.unwrap();
        assert!(
            last < first * 0.85,
            "{model}: loss {first:.3} -> {last:.3} did not improve"
        );
    }
}

/// Gradient-accumulation path: `grad` artifact + host Adam must also
/// learn, and grads must be finite.
#[test]
fn grad_step_and_host_adam_learn() {
    let Some(mut rt) = runtime() else { return };
    let ds = tiny_dataset();
    let mut gen = NodeWiseIbmb {
        aux_per_output: 8,
        max_outputs_per_batch: 64,
        node_budget: 256,
        ..Default::default()
    };
    let mut rng = Rng::new(3);
    let cache = BatchCache::build(&gen.plan(&ds, &ds.splits.train, &mut rng));
    let meta = rt
        .manifest
        .bucket_meta("gcn", "grad", cache.max_batch_nodes())
        .expect("grad bucket")
        .clone();
    let mut state = ModelState::init(&meta, 9);
    let mut dense = DenseBatch::zeros(meta.n_pad, meta.feat);
    let mut first = None;
    let mut last = 0.0;
    for epoch in 0..6i32 {
        let mut acc = vec![0.0f32; meta.param_count];
        let mut loss_sum = 0.0;
        let mut count = 0.0;
        for b in 0..cache.len() {
            cache.materialize_into(&ds, b, &mut dense);
            let m = rt
                .grad_step(&meta, &state, &dense, epoch * 31 + b as i32, &mut acc)
                .expect("grad step");
            assert!(acc.iter().all(|v| v.is_finite()));
            loss_sum += m.loss as f64 * m.mask_count as f64;
            count += m.mask_count as f64;
        }
        for v in acc.iter_mut() {
            *v /= cache.len() as f32;
        }
        ibmb::training::trainer::host_adam(&mut state, &acc, 1e-2);
        let loss = loss_sum / count;
        if first.is_none() {
            first = Some(loss);
        }
        last = loss;
    }
    assert!(last < first.unwrap(), "full-epoch accumulation not learning");
}
