//! Executor parity property tests (DESIGN.md §13): on random SBM
//! graphs, for every model family and every batch a real generator
//! plans, the blocked CPU backend must reproduce the scalar reference
//! logits.
//!
//! Bounds: the f32 blocked path must stay within 1e-4 max-abs of the
//! reference (in practice it is bit-identical — the counting sort is
//! stable, so every per-destination f32 sum runs in the reference's
//! order); the f16 path quantizes layer-0 features to IEEE half
//! (relative error ~2^-11 per value) and gets the documented looser
//! 0.05 bound on raw logits.

use ibmb::baselines;
use ibmb::batching::BatchCache;
use ibmb::datasets::{sbm, DatasetSpec};
use ibmb::exec::{ExecScratch, Executor, ExecutorKind, PlanView};
use ibmb::runtime::ModelState;
use ibmb::serve::reference_artifact;
use ibmb::util::Rng;

const F32_TOL: f32 = 1e-4;
const F16_TOL: f32 = 0.05;
const MODELS: [&str; 3] = ["gcn", "sage", "gat"];

fn random_dataset(rng: &mut Rng) -> ibmb::datasets::Dataset {
    let spec = DatasetSpec {
        nodes: 300 + rng.next_below(500),
        communities: 4 + rng.next_below(12),
        classes: 3 + rng.next_below(5),
        feat_dim: 8,
        avg_degree: 4.0 + rng.next_f64() * 8.0,
        p_intra: 0.5 + rng.next_f64() * 0.3,
        p_adjacent: 0.1,
        degree_tail: 2.0 + rng.next_f64(),
        noise: 1.0,
        train_frac: 0.2 + rng.next_f64() * 0.4,
        val_frac: 0.1,
        name: "prop",
    };
    sbm::generate(&spec, rng.next_u64())
}

/// Gather `nodes`' features into `x` (resized to fit exactly).
fn gather(ds: &ibmb::datasets::Dataset, nodes: &[u32], x: &mut Vec<f32>) {
    let d = ds.feat_dim;
    x.resize(nodes.len() * d, 0.0);
    for (j, &u) in nodes.iter().enumerate() {
        ds.node_features_into(u, &mut x[j * d..(j + 1) * d]);
    }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

#[test]
fn prop_blocked_executor_matches_reference() {
    let mut master = Rng::new(0xE8EC);
    for case in 0..4 {
        let seed = master.next_u64();
        let mut rng = Rng::new(seed);
        let ds = random_dataset(&mut rng);
        // a real generator supplies the batch shapes: variable node
        // counts, variable edge counts, outputs-first ordering
        let mut gen = baselines::by_name(
            "node-wise IBMB",
            4 + rng.next_below(8),
            8 + rng.next_below(24),
            128 + rng.next_below(256),
        )
        .unwrap();
        let cache = BatchCache::build(&gen.plan(&ds, &ds.splits.train, &mut rng));
        assert!(!cache.is_empty(), "case {case} seed {seed}: no batches");
        for model in MODELS {
            let meta = reference_artifact(
                model,
                ds.feat_dim,
                ds.num_classes,
                8,
                2,
                2,
                cache.max_batch_nodes(),
            );
            let state = ModelState::init(&meta, seed ^ 0x5EED);
            let reference = ExecutorKind::Reference.build().unwrap();
            let blocked = ExecutorKind::Blocked.build().unwrap();
            let f16 = ExecutorKind::BlockedF16.build().unwrap();
            let mut s_ref = ExecScratch::new();
            let mut s_blk = ExecScratch::new();
            let mut s_f16 = ExecScratch::new();
            let (mut o_ref, mut o_blk, mut o_f16) =
                (Vec::new(), Vec::new(), Vec::new());
            let mut x = Vec::new();
            for i in 0..cache.len() {
                let nodes = cache.batch_nodes(i);
                let n = nodes.len();
                gather(&ds, nodes, &mut x);
                let view = PlanView {
                    n,
                    edge_src: cache.edge_src_of(i),
                    edge_dst: cache.edge_dst_of(i),
                    weights: cache.edge_weights_of(i),
                };
                reference.forward(&meta, &state, &view, &x, &mut s_ref, &mut o_ref);
                blocked.forward(&meta, &state, &view, &x, &mut s_blk, &mut o_blk);
                f16.forward(&meta, &state, &view, &x, &mut s_f16, &mut o_f16);
                assert_eq!(
                    o_ref.len(),
                    n * meta.classes,
                    "case {case} seed {seed} {model} batch {i}"
                );
                let d32 = max_abs_diff(&o_ref, &o_blk);
                assert!(
                    d32 <= F32_TOL,
                    "case {case} seed {seed} {model} batch {i} (n={n}): \
                     blocked diverges from reference by {d32}"
                );
                let d16 = max_abs_diff(&o_ref, &o_f16);
                assert!(
                    d16 <= F16_TOL,
                    "case {case} seed {seed} {model} batch {i} (n={n}): \
                     blocked-f16 diverges from reference by {d16}"
                );
            }
        }
    }
}

#[test]
fn prop_blocked_matches_reference_on_full_graph_views() {
    // Degenerate "batch" = the whole graph (the fig2 full-batch row):
    // exercises the largest n and the densest CSR the executors see.
    let mut master = Rng::new(0xF0E8);
    for case in 0..2 {
        let seed = master.next_u64();
        let mut rng = Rng::new(seed);
        let ds = random_dataset(&mut rng);
        let n = ds.graph.num_nodes();
        let mut edge_src = Vec::new();
        let mut edge_dst = Vec::new();
        let mut weights = Vec::new();
        for u in 0..n as u32 {
            for &v in ds.graph.neighbors(u) {
                edge_src.push(v);
                edge_dst.push(u);
                weights.push(ds.graph.norm_weight(u, v));
            }
        }
        let view = PlanView {
            n,
            edge_src: &edge_src,
            edge_dst: &edge_dst,
            weights: &weights,
        };
        let nodes: Vec<u32> = (0..n as u32).collect();
        let mut x = Vec::new();
        gather(&ds, &nodes, &mut x);
        for model in MODELS {
            let meta =
                reference_artifact(model, ds.feat_dim, ds.num_classes, 8, 2, 2, n);
            let state = ModelState::init(&meta, seed ^ 0xF17);
            let reference = ExecutorKind::Reference.build().unwrap();
            let blocked = ExecutorKind::Blocked.build().unwrap();
            let (mut o_ref, mut o_blk) = (Vec::new(), Vec::new());
            let (mut s_ref, mut s_blk) = (ExecScratch::new(), ExecScratch::new());
            reference.forward(&meta, &state, &view, &x, &mut s_ref, &mut o_ref);
            blocked.forward(&meta, &state, &view, &x, &mut s_blk, &mut o_blk);
            let d = max_abs_diff(&o_ref, &o_blk);
            assert!(
                d <= F32_TOL,
                "case {case} seed {seed} {model} full graph (n={n}): \
                 blocked diverges by {d}"
            );
        }
    }
}
