//! Plan-store subsystem tests (DESIGN.md §14): the persistence
//! property that any random sequence of incremental saves, once
//! compacted, resolves to exactly the store a single fresh full save
//! of the final cache would produce — per-plan content hash, epoch
//! stamp, and bit-identical payload bytes — and the serving property
//! that a residency budget far too small for the corpus still answers
//! every query correctly (paged-out plans refault, they don't
//! mispredict).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use ibmb::batching::{BatchPlan, CowCache, PlanPayload};
use ibmb::datasets::{sbm, DatasetSpec};
use ibmb::serve::{self, ServeConfig, Skew};
use ibmb::store::PlanStore;
use ibmb::util::Rng;

fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("ibmb-store-test-{name}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    dir
}

/// Deterministic synthetic corpus; node ids are disjoint per plan so
/// every bucket starts with a distinct content hash.
fn synth_plans(n: usize, rng: &mut Rng) -> Vec<BatchPlan> {
    (0..n)
        .map(|i| {
            let n_nodes = 8 + rng.next_below(9);
            let nodes: Vec<u32> =
                (0..n_nodes).map(|k| (i * 32 + k) as u32).collect();
            let n_edges = n_nodes * 2;
            let edges: Vec<(u32, u32)> = (0..n_edges)
                .map(|_| {
                    (
                        rng.next_below(n_nodes) as u32,
                        rng.next_below(n_nodes) as u32,
                    )
                })
                .collect();
            let weights: Vec<f32> =
                (0..n_edges).map(|_| rng.uniform(0.01, 1.0)).collect();
            BatchPlan {
                nodes,
                num_outputs: 1 + rng.next_below(3.min(n_nodes)),
                edges,
                weights,
            }
        })
        .collect()
}

/// Property: save_full → random CoW patches, each saved incrementally
/// → compact → reopen ≡ one fresh save_full of the final cache. The
/// delta-log path and the monolithic path must resolve every plan id
/// to the same (hash, epoch) and the same payload bits.
#[test]
fn random_delta_sequences_compact_to_the_fresh_full_save() {
    for trial in 0..4u64 {
        let mut rng = Rng::new(0xBEEF ^ trial);
        let n = 32usize;
        let plans = synth_plans(n, &mut rng);
        let mut cur = CowCache::from_plans(&plans);
        let mut epochs = vec![0u64; n];
        let router: Vec<u64> = (0..n as u64).map(|p| p << 32).collect();

        let dir_a = scratch(&format!("delta-{trial}"));
        let dir_b = scratch(&format!("fresh-{trial}"));
        let store_a = PlanStore::open(&dir_a).unwrap();
        store_a.save_full(&cur, &epochs, 0, &router).unwrap();

        let steps = 3 + rng.next_below(5);
        let mut epoch = 0u64;
        for _ in 0..steps {
            epoch += 1;
            let k = 1 + rng.next_below(6);
            let mut repl: Vec<(u32, PlanPayload)> = Vec::new();
            for _ in 0..k {
                let pid = rng.next_below(n) as u32;
                // half the patches duplicate another bucket's exact
                // bytes: the blob must dedup, the manifest must not
                let payload = if rng.next_below(2) == 0 {
                    PlanPayload::from_plan(&cur.to_plan(rng.next_below(n)))
                } else {
                    let plan = synth_plans(1, &mut rng).pop().unwrap();
                    PlanPayload::from_plan(&plan)
                };
                repl.push((pid, payload));
            }
            let next = cur.with_patched(repl);
            for i in 0..n {
                if !Arc::ptr_eq(&cur.payload(i), &next.payload(i)) {
                    epochs[i] = epoch;
                }
            }
            store_a
                .save_incremental(&cur, &next, &epochs, epoch, &[])
                .unwrap();
            cur = next;
        }
        assert!(store_a.pending_delta_records() > 0);
        store_a.compact().unwrap();
        drop(store_a);

        // reopen A cold; build B with one full save of the final state
        let store_a = PlanStore::open(&dir_a).unwrap();
        let store_b = PlanStore::open(&dir_b).unwrap();
        store_b.save_full(&cur, &epochs, epoch, &router).unwrap();

        let (va, vb) = (store_a.view(), store_b.view());
        assert_eq!(va.delta_records, 0, "compaction must fold the log");
        assert_eq!(va.num_plans(), vb.num_plans(), "trial {trial}");
        assert_eq!(va.epoch, vb.epoch, "trial {trial}");
        assert_eq!(va.router, vb.router, "trial {trial}");
        for pid in 0..n {
            let (ea, eb) = (&va.entries[pid], &vb.entries[pid]);
            assert_eq!(ea.hash, eb.hash, "trial {trial} plan {pid} hash");
            assert_eq!(
                ea.plan_epoch, eb.plan_epoch,
                "trial {trial} plan {pid} epoch"
            );
            let (pa, _) = store_a.fault(pid).unwrap();
            let (pb, _) = store_b.fault(pid).unwrap();
            assert_eq!(pa.nodes, pb.nodes, "trial {trial} plan {pid}");
            assert_eq!(pa.num_outputs, pb.num_outputs);
            assert_eq!(pa.edge_src, pb.edge_src);
            assert_eq!(pa.edge_dst, pb.edge_dst);
            let bits =
                |p: &PlanPayload| -> Vec<u32> {
                    p.weights.iter().map(|w| w.to_bits()).collect()
                };
            assert_eq!(bits(&pa), bits(&pb), "trial {trial} plan {pid} bits");
        }
        drop(store_a);
        std::fs::remove_dir_all(&dir_a).ok();
        std::fs::remove_dir_all(&dir_b).ok();
    }
}

/// A residency budget of one byte (every plan pages out immediately)
/// must still answer every query with the same predictions as a
/// generous budget — only the fault counters may differ.
#[test]
fn paged_out_plans_refault_correctly_under_a_tiny_budget() {
    let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 101);
    let eval = ds.splits.train.clone();
    let base = ServeConfig {
        queries: 64,
        clients: 8,
        shards: 2,
        flush_window: Duration::from_micros(200),
        results_cache_bytes: 0,
        seed: 23,
        ..Default::default()
    };

    // populate the store from a warm preparation
    let dir = scratch("tiny-budget");
    let warm = serve::prepare(ds.clone(), &eval, &base);
    let warm_state = warm.state();
    let store = PlanStore::open(&dir).unwrap();
    store
        .save_full(
            &warm_state.cache,
            &warm_state.epochs,
            0,
            &warm_state.index.to_packed(),
        )
        .unwrap();
    let store = Arc::new(store);
    let plans = store.num_plans();
    assert!(plans > 1, "need a multi-plan corpus");

    let run = |budget: usize| {
        let cfg = ServeConfig {
            store_budget: budget,
            ..base.clone()
        };
        let mut setup =
            serve::prepare_from_store(ds.clone(), store.clone(), &cfg)
                .unwrap();
        let report =
            serve::serve_closed_loop(&mut setup, &eval, Skew::Uniform, &cfg)
                .unwrap();
        assert_eq!(
            report.executed_queries + report.cache_hits,
            base.queries as u64,
            "budget {budget}: dropped queries"
        );
        report
    };

    let generous = run(64 << 20);
    let tiny = run(1);
    assert_eq!(
        tiny.logit_hash, generous.logit_hash,
        "a paged-out plan refaulted to different predictions"
    );
    assert!((tiny.accuracy - generous.accuracy).abs() < 1e-12);
    assert!(
        tiny.store_faults > generous.store_faults,
        "a one-byte budget must refault ({} vs {})",
        tiny.store_faults,
        generous.store_faults
    );
    assert!(
        tiny.store_faults as usize > plans,
        "refaults should exceed the corpus size ({} faults, {plans} plans)",
        tiny.store_faults
    );
    // one plan is always kept resident per shard, so the footprint is
    // bounded by shards × the largest single payload, not the corpus
    let max_payload = (0..plans)
        .map(|i| store.fault(i).unwrap().0.memory_bytes() as u64)
        .max()
        .unwrap();
    assert!(
        tiny.resident_bytes <= base.shards as u64 * max_payload,
        "tiny-budget residency {} exceeds {} shards x {} B",
        tiny.resident_bytes,
        base.shards,
        max_payload
    );
    std::fs::remove_dir_all(&dir).ok();
}
