//! Zero-quiesce snapshot semantics (DESIGN.md §11): the property test
//! that loads racing a published swap never observe mixed-epoch state,
//! and end-to-end churn runs proving queries are never dropped while a
//! background applier publishes snapshots mid-traffic.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use ibmb::graph::synth_delta_stream;
use ibmb::serve::{
    serve_with_churn, Churn, DynamicServeSession, ResultsCache, ServeConfig,
    Skew, UpdateConfig,
};

fn session(seed: u64, results_cache_bytes: usize) -> DynamicServeSession {
    let ds = ibmb::datasets::sbm::generate(
        &ibmb::datasets::DatasetSpec::tiny_for_tests(),
        seed,
    );
    let cfg = ServeConfig {
        clients: 8,
        shards: 2,
        results_cache_bytes,
        flush_window: Duration::from_micros(200),
        seed,
        ..Default::default()
    };
    let eval = ds.splits.train.clone();
    DynamicServeSession::prepare(ds, &eval, &cfg, &UpdateConfig::default())
}

/// The mixed-epoch property: while an applier publishes a stream of
/// snapshots, concurrent readers loading from the cell must always
/// see a snapshot whose router index, plan cache buckets, plan
/// epochs, placement, and dataset sizes agree with each other —
/// `ServeState::validate` is exactly that cross-component contract —
/// and whose epoch never regresses. Seeded deltas drive the writer;
/// reader threads hammer `load()` the whole time.
#[test]
fn racing_loads_never_observe_mixed_epoch_state() {
    let mut s = session(42, 0);
    let ds = s.dataset();
    let eval = ds.splits.train.clone();
    let deltas = synth_delta_stream(
        &ds.graph,
        &eval,
        10,
        24,
        1, // node appends exercise index/placement extension races
        2,
        ds.num_classes,
        42,
    );
    drop(ds);
    let cell = s.applier.cell();
    let stop = Arc::new(AtomicBool::new(false));
    std::thread::scope(|scope| {
        let mut readers = Vec::new();
        for _ in 0..3 {
            let cell = cell.clone();
            let stop = stop.clone();
            readers.push(scope.spawn(move || {
                let mut last_epoch = 0u64;
                let mut loads = 0u64;
                while !stop.load(Ordering::Acquire) {
                    let state = cell.load();
                    assert!(
                        state.epoch >= last_epoch,
                        "epoch regressed {last_epoch} -> {}",
                        state.epoch
                    );
                    last_epoch = state.epoch;
                    // the full cross-component consistency contract:
                    // index ↔ cache ↔ epochs ↔ placement ↔ dataset
                    state.validate().unwrap_or_else(|e| {
                        panic!("mixed-epoch state at load {loads}: {e}")
                    });
                    // memo-epoch agreement: a cached key's freshness
                    // epoch is bounded by the snapshot epoch and
                    // matches the plan's entry in the same snapshot
                    for pid in 0..state.cache.len() as u32 {
                        let key = ibmb::serve::PlanKey::Cached(pid);
                        assert_eq!(
                            state.plan_epoch(&key),
                            state.epochs[pid as usize]
                        );
                    }
                    loads += 1;
                }
                loads
            }));
        }
        for d in &deltas {
            s.applier.apply(d).unwrap();
        }
        stop.store(true, Ordering::Release);
        let total_loads: u64 =
            readers.into_iter().map(|r| r.join().unwrap()).sum();
        assert!(total_loads > 0, "readers never ran");
    });
    assert_eq!(s.applier.epoch(), deltas.len() as u64);
    let last = s.state();
    assert_eq!(last.epoch, deltas.len() as u64);
    last.validate().unwrap();
}

/// Zero-quiesce end-to-end: a background applier publishes snapshots
/// while the closed loop serves — every query is answered, every fed
/// delta is applied, epochs stay monotone, and the memo's swap-time
/// sweep engages.
#[test]
fn background_churn_drops_no_queries_and_applies_every_delta() {
    let mut s = session(7, 1 << 20);
    let ds = s.dataset();
    let eval = ds.splits.train.clone();
    let deltas =
        synth_delta_stream(&ds.graph, &eval, 3, 40, 0, 0, ds.num_classes, 7);
    drop(ds);
    let queries = 120usize;
    let cfg = ServeConfig {
        queries,
        clients: 8,
        shards: 2,
        results_cache_bytes: 1 << 20,
        flush_window: Duration::from_micros(200),
        seed: 7,
        ..Default::default()
    };
    let triggers: Vec<(u64, _)> = deltas
        .into_iter()
        .enumerate()
        .map(|(i, d)| ((queries * (i + 1) / 4) as u64, d))
        .collect();
    let churn = Churn::Background {
        applier: &mut s.applier,
        deltas: triggers,
    };
    let (r, ups) = serve_with_churn(
        &mut s.setup,
        &eval,
        Skew::Zipf(1.2),
        &cfg,
        &mut s.memo,
        Some(churn),
    )
    .unwrap();
    assert_eq!(
        r.executed_queries + r.cache_hits,
        queries as u64,
        "zero-quiesce run dropped queries: {r:?}"
    );
    assert_eq!(ups.len(), 3, "every fed delta must be applied");
    assert_eq!(r.final_epoch, 3);
    // epochs the applier reported are strictly increasing
    for (i, up) in ups.iter().enumerate() {
        assert_eq!(up.epoch, i as u64 + 1);
    }
    assert_eq!(s.state().epoch, 3);
    s.state().validate().unwrap();
}

/// The quiesced baseline through the same loop: inline applies block
/// the control thread but still lose nothing and apply in order.
#[test]
fn inline_churn_applies_between_admissions() {
    let mut s = session(9, 0);
    let ds = s.dataset();
    let eval = ds.splits.train.clone();
    let deltas =
        synth_delta_stream(&ds.graph, &eval, 2, 30, 0, 0, ds.num_classes, 9);
    drop(ds);
    let queries = 60usize;
    let cfg = ServeConfig {
        queries,
        clients: 6,
        shards: 1,
        flush_window: Duration::from_micros(200),
        seed: 9,
        ..Default::default()
    };
    let triggers: Vec<(u64, _)> = deltas
        .into_iter()
        .enumerate()
        .map(|(i, d)| ((queries * (i + 1) / 3) as u64, d))
        .collect();
    let (r, ups) = serve_with_churn(
        &mut s.setup,
        &eval,
        Skew::Uniform,
        &cfg,
        &mut ResultsCache::new(0, None),
        Some(Churn::Inline {
            applier: &mut s.applier,
            deltas: triggers,
        }),
    )
    .unwrap();
    assert_eq!(r.executed_queries + r.cache_hits, queries as u64);
    assert_eq!(ups.len(), 2);
    assert_eq!(r.final_epoch, 2);
    assert_eq!(r.snapshot_swaps, 2, "loop must observe both swaps");
}
