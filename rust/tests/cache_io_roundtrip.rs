//! Save/load round-trip coverage for the versioned `IBMBCACH` format:
//! empty, single-batch, and multi-batch caches must round-trip
//! bit-exactly, and corrupted / truncated / wrong-version files must
//! be rejected with a clear error instead of misparsing.

use std::path::PathBuf;

use ibmb::batching::cache_io::{load, save, FORMAT_VERSION};
use ibmb::batching::{BatchCache, BatchGenerator, BatchPlan, NodeWiseIbmb};
use ibmb::datasets::{sbm, DatasetSpec};
use ibmb::util::crc::crc32;
use ibmb::util::Rng;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("ibmb_cache_roundtrip_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn assert_roundtrip(cache: &BatchCache, name: &str) {
    let path = tmp(name);
    save(cache, &path).unwrap();
    let loaded = load(&path).unwrap();
    assert_eq!(loaded.len(), cache.len(), "{name}: batch count");
    for i in 0..cache.len() {
        let a = cache.to_plan(i);
        let b = loaded.to_plan(i);
        assert_eq!(a.nodes, b.nodes, "{name}: batch {i} nodes");
        assert_eq!(a.num_outputs, b.num_outputs, "{name}: batch {i} outputs");
        assert_eq!(a.edges, b.edges, "{name}: batch {i} edges");
        assert_eq!(a.weights, b.weights, "{name}: batch {i} weights");
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn empty_cache_roundtrips() {
    assert_roundtrip(&BatchCache::build(&[]), "empty.bin");
}

#[test]
fn single_batch_cache_roundtrips() {
    let plan = BatchPlan {
        nodes: vec![4, 9, 2],
        num_outputs: 2,
        edges: vec![(0, 0), (0, 1), (2, 0)],
        weights: vec![0.5, 0.25, 0.125],
    };
    assert!(plan.validate().is_ok());
    assert_roundtrip(&BatchCache::build(&[plan]), "single.bin");
}

#[test]
fn multi_batch_cache_roundtrips() {
    let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 31);
    let mut gen = NodeWiseIbmb {
        aux_per_output: 6,
        max_outputs_per_batch: 30,
        node_budget: 200,
        ..Default::default()
    };
    let mut rng = Rng::new(8);
    let cache = BatchCache::build(&gen.plan(&ds, &ds.splits.train, &mut rng));
    assert!(cache.len() > 1, "want a multi-batch cache");
    assert_roundtrip(&cache, "multi.bin");
}

#[test]
fn rejects_corrupted_header() {
    // wrong magic
    let p = tmp("badmagic.bin");
    std::fs::write(&p, b"NOTACACHxxxxxxxxyyyyyyyyzzzzzzzz").unwrap();
    let err = format!("{:#}", load(&p).unwrap_err());
    assert!(err.contains("bad magic"), "{err}");
    std::fs::remove_file(p).ok();
}

#[test]
fn rejects_unknown_version() {
    // valid file with the version field bumped to an unknown value
    let cache = BatchCache::build(&[BatchPlan {
        nodes: vec![1, 2],
        num_outputs: 1,
        edges: vec![(0, 1)],
        weights: vec![1.0],
    }]);
    let p = tmp("badversion.bin");
    save(&cache, &p).unwrap();
    let mut bytes = std::fs::read(&p).unwrap();
    bytes[8..16].copy_from_slice(&99u64.to_le_bytes());
    std::fs::write(&p, &bytes).unwrap();
    let err = format!("{:#}", load(&p).unwrap_err());
    assert!(err.contains("version 99"), "{err}");
    assert!(err.contains(&FORMAT_VERSION.to_string()), "{err}");
    std::fs::remove_file(p).ok();
}

#[test]
fn rejects_version_1_style_file() {
    // a pre-version file: magic immediately followed by counts — the
    // old batches count lands in the version slot and is rejected
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"IBMBCACH");
    for v in [1u64, 2, 1] {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    let p = tmp("v1style.bin");
    std::fs::write(&p, &bytes).unwrap();
    let err = format!("{:#}", load(&p).unwrap_err());
    assert!(err.contains("unsupported IBMBCACH version"), "{err}");
    std::fs::remove_file(p).ok();
}

#[test]
fn rejects_truncated_file() {
    let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 32);
    let mut gen = NodeWiseIbmb {
        aux_per_output: 4,
        max_outputs_per_batch: 30,
        node_budget: 128,
        ..Default::default()
    };
    let mut rng = Rng::new(9);
    let cache = BatchCache::build(&gen.plan(&ds, &ds.splits.train, &mut rng));
    let p = tmp("trunc.bin");
    save(&cache, &p).unwrap();
    let bytes = std::fs::read(&p).unwrap();
    // cut at several depths: header reads fail with "truncated", and
    // payload cuts trip the section-length-vs-file-length cross-check
    for cut in [4usize, 12, 30, bytes.len() / 2, bytes.len() - 1] {
        let cut = cut.min(bytes.len() - 1);
        std::fs::write(&p, &bytes[..cut]).unwrap();
        let err = format!("{:#}", load(&p).unwrap_err());
        assert!(
            err.contains("truncated")
                || err.contains("bad magic")
                || err.contains("corrupt header")
                || err.contains("past end of file"),
            "cut {cut}: {err}"
        );
    }
    std::fs::remove_file(p).ok();
}

#[test]
fn rejects_corrupt_batch_count_without_allocating() {
    // a huge batch count must be a clean error (header/length check),
    // not a giant allocation attempt
    let cache = BatchCache::build(&[BatchPlan {
        nodes: vec![0, 1],
        num_outputs: 1,
        edges: vec![(0, 1)],
        weights: vec![1.0],
    }]);
    let p = tmp("hugecount.bin");
    save(&cache, &p).unwrap();
    let clean = std::fs::read(&p).unwrap();
    // v4 layout: magic(8) version(8) nsections(8) tag(8) len(8)
    // crc(8), then the plan section's batches count at offset 48.
    // Re-stamp the section checksum over the corrupted body so the
    // corruption reaches the parser's own count-vs-length guard.
    let mut bytes = clean.clone();
    bytes[48..56].copy_from_slice(&(1u64 << 48).to_le_bytes());
    let body_crc = crc32(&bytes[48..]) as u64;
    bytes[40..48].copy_from_slice(&body_crc.to_le_bytes());
    std::fs::write(&p, &bytes).unwrap();
    let err = format!("{:#}", load(&p).unwrap_err());
    assert!(err.contains("corrupt header"), "{err}");
    // without the re-stamp, the same corruption is caught one layer
    // earlier by the section checksum — and names the section
    let mut bytes = clean.clone();
    bytes[48..56].copy_from_slice(&(1u64 << 48).to_le_bytes());
    std::fs::write(&p, &bytes).unwrap();
    let err = format!("{:#}", load(&p).unwrap_err());
    assert!(err.contains("checksum mismatch"), "{err}");
    assert!(err.contains("plan section"), "{err}");
    // a section length pointing past end-of-file is caught before any
    // allocation as well
    let mut bytes = clean.clone();
    bytes[32..40].copy_from_slice(&(1u64 << 48).to_le_bytes());
    std::fs::write(&p, &bytes).unwrap();
    let err = format!("{:#}", load(&p).unwrap_err());
    assert!(err.contains("past end of file"), "{err}");
    std::fs::remove_file(p).ok();
}

#[test]
fn checksum_rejects_single_bit_flips_anywhere_in_payload() {
    let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 33);
    let mut gen = NodeWiseIbmb {
        aux_per_output: 5,
        max_outputs_per_batch: 30,
        node_budget: 160,
        ..Default::default()
    };
    let mut rng = Rng::new(11);
    let cache = BatchCache::build(&gen.plan(&ds, &ds.splits.train, &mut rng));
    let p = tmp("bitflip.bin");
    save(&cache, &p).unwrap();
    let clean = std::fs::read(&p).unwrap();
    let payload_start = 48; // file header 24 + section header 24
    // sample a spread of payload offsets; every flip must be caught,
    // and caught as *corruption*, not as some shape error
    let span = clean.len() - payload_start;
    for frac in [0, span / 3, span / 2, 2 * span / 3, span - 1] {
        let mut bytes = clean.clone();
        bytes[payload_start + frac] ^= 0x10;
        std::fs::write(&p, &bytes).unwrap();
        let err = format!("{:#}", load(&p).unwrap_err());
        assert!(
            err.contains("checksum mismatch") && err.contains("plan section"),
            "flip at payload byte {frac}: {err}"
        );
    }
    std::fs::write(&p, &clean).unwrap();
    load(&p).unwrap();
    std::fs::remove_file(p).ok();
}
