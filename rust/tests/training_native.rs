//! Native training backend parity (DESIGN.md §16): the sparse CSR
//! backends (reference scalar, blocked SIMD) must match the dense
//! padded oracle (`runtime::host`) within 1e-4 — the only tolerated
//! divergence is f32 summation order — and the fused-Adam fast path
//! must match grad_step + host Adam bitwise.

use ibmb::batching::{BatchCache, BatchGenerator, DenseBatch, NodeWiseIbmb};
use ibmb::datasets::{sbm, Dataset, DatasetSpec};
use ibmb::exec::train::train_artifact;
use ibmb::exec::{PlanView, TrainBatch, TrainExecutorKind, TrainScratch};
use ibmb::runtime::host::{host_grad_step, host_train_step};
use ibmb::runtime::ModelState;
use ibmb::training::{self, TrainConfig};
use ibmb::util::Rng;

const HIDDEN: usize = 8;
const LAYERS: usize = 2;
const HEADS: usize = 2;
const DROPOUT: f64 = 0.3;
const WD: f64 = 1e-4;

fn tiny_dataset() -> Dataset {
    let spec = DatasetSpec {
        nodes: 300,
        feat_dim: 12,
        classes: 5,
        ..DatasetSpec::tiny_for_tests()
    };
    sbm::generate(&spec, 99)
}

fn plan_cache(ds: &Dataset, seed: u64) -> BatchCache {
    let mut gen = NodeWiseIbmb {
        aux_per_output: 4,
        max_outputs_per_batch: 32,
        node_budget: 128,
        ..Default::default()
    };
    let mut rng = Rng::new(seed);
    let cache = BatchCache::build(&gen.plan(ds, &ds.splits.train, &mut rng));
    assert!(!cache.is_empty());
    cache
}

fn meta_for(model: &str, ds: &Dataset, cache: &BatchCache) -> ibmb::runtime::ArtifactMeta {
    train_artifact(
        model,
        ds.feat_dim,
        ds.num_classes,
        HIDDEN,
        LAYERS,
        HEADS,
        DROPOUT,
        WD,
        cache.max_batch_nodes(),
    )
}

/// Gathered sparse batch `i` (what the trainer's prefetch ring holds).
fn sparse_batch<'a>(
    ds: &Dataset,
    cache: &'a BatchCache,
    i: usize,
    x: &'a mut Vec<f32>,
    labels: &'a mut Vec<i32>,
) -> TrainBatch<'a> {
    let n = cache.gather_features_into(ds, i, x);
    cache.gather_labels_into(ds, i, labels);
    TrainBatch {
        view: PlanView {
            n,
            edge_src: cache.edge_src_of(i),
            edge_dst: cache.edge_dst_of(i),
            weights: cache.edge_weights_of(i),
        },
        x: &x[..n * ds.feat_dim],
        labels: &labels[..n],
        num_outputs: cache.num_outputs(i),
    }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max)
}

/// Both native backends, both models, three consecutive fused steps:
/// params/m/v track the dense padded oracle within 1e-4, and padding is
/// provably inert (the oracle runs at `n + 5` with extra zero rows).
#[test]
fn native_train_step_matches_dense_oracle() {
    let ds = tiny_dataset();
    let cache = plan_cache(&ds, 4);
    let mut x = Vec::new();
    let mut labels = Vec::new();
    for model in ["gcn", "sage"] {
        let meta = meta_for(model, &ds, &cache);
        for kind in [TrainExecutorKind::Reference, TrainExecutorKind::Blocked]
        {
            let exec = kind.build().expect("native backend");
            let mut state = ModelState::init(&meta, 21);
            let mut oracle = state.clone();
            let mut scratch = TrainScratch::new();
            for (step, b) in (0..cache.len().min(3)).enumerate() {
                let batch = sparse_batch(&ds, &cache, b, &mut x, &mut labels);
                let n = batch.view.n;
                // oracle at a DIFFERENT padding: extra zero rows must
                // not change anything
                let mut dense = DenseBatch::zeros(n + 5, meta.feat);
                cache.materialize_into(&ds, b, &mut dense);
                let seed = 1000 + step as i32;
                // tiny lr: Adam's step-1 update is ~lr·sign(g), so a
                // summation-order sign flip on a near-zero gradient
                // element costs at most 2·lr — keep that below the
                // parity tolerance instead of hoping no element lands
                // on zero
                let lr = 1e-5;
                let om = host_train_step(&meta, &mut oracle, &dense, lr, seed)
                    .expect("oracle step");
                let m = exec.train_step(
                    &meta, &mut state, &batch, lr, seed, &mut scratch,
                );
                assert!(
                    (m.loss - om.loss).abs() < 1e-3,
                    "{model}/{}: step {step} loss {} vs oracle {}",
                    exec.name(),
                    m.loss,
                    om.loss
                );
                assert_eq!(m.mask_count, om.mask_count);
                assert!(
                    (m.correct - om.correct).abs() <= 1.0,
                    "{model}/{}: step {step} correct {} vs oracle {}",
                    exec.name(),
                    m.correct,
                    om.correct
                );
                for (name, ours, theirs) in [
                    ("params", &state.params, &oracle.params),
                    ("m", &state.m, &oracle.m),
                    ("v", &state.v, &oracle.v),
                ] {
                    let d = max_abs_diff(ours, theirs);
                    assert!(
                        d < 1e-4,
                        "{model}/{}: step {step} {name} diverged by {d}",
                        exec.name()
                    );
                }
                assert_eq!(state.step, oracle.step);
            }
        }
    }
}

/// grad_step accumulates (`+=`): two identical calls yield exactly
/// twice one call (x + x is exact in f32), and the buffer is
/// caller-owned — no hidden zeroing.
#[test]
fn grad_step_accumulates_into_caller_buffer() {
    let ds = tiny_dataset();
    let cache = plan_cache(&ds, 5);
    let meta = meta_for("gcn", &ds, &cache);
    let state = ModelState::init(&meta, 3);
    let exec = TrainExecutorKind::Blocked.build().unwrap();
    let mut scratch = TrainScratch::new();
    let (mut x, mut labels) = (Vec::new(), Vec::new());
    let batch = sparse_batch(&ds, &cache, 0, &mut x, &mut labels);

    let mut once = vec![0.0f32; meta.param_count];
    exec.grad_step(&meta, &state, &batch, 7, &mut once, &mut scratch);
    assert!(once.iter().any(|&v| v != 0.0), "gradients all zero");
    let mut twice = vec![0.0f32; meta.param_count];
    exec.grad_step(&meta, &state, &batch, 7, &mut twice, &mut scratch);
    exec.grad_step(&meta, &state, &batch, 7, &mut twice, &mut scratch);
    for (i, (&a, &b)) in once.iter().zip(&twice).enumerate() {
        assert_eq!(2.0 * a, b, "param {i}: accumulation not exact");
    }
}

/// The blocked backward must match the dense oracle's gradients within
/// 1e-4 (lane-partial summation order is the only divergence), for both
/// models.
#[test]
fn grad_step_matches_dense_oracle() {
    let ds = tiny_dataset();
    let cache = plan_cache(&ds, 6);
    let (mut x, mut labels) = (Vec::new(), Vec::new());
    for model in ["gcn", "sage"] {
        let meta = meta_for(model, &ds, &cache);
        let state = ModelState::init(&meta, 13);
        let mut scratch = TrainScratch::new();
        for kind in [TrainExecutorKind::Reference, TrainExecutorKind::Blocked]
        {
            let exec = kind.build().unwrap();
            for b in 0..cache.len().min(2) {
                let batch = sparse_batch(&ds, &cache, b, &mut x, &mut labels);
                let mut dense =
                    DenseBatch::zeros(batch.view.n + 3, meta.feat);
                cache.materialize_into(&ds, b, &mut dense);
                let seed = 42 + b as i32;
                let mut ours = vec![0.0f32; meta.param_count];
                let mut oracle = vec![0.0f32; meta.param_count];
                exec.grad_step(
                    &meta, &state, &batch, seed, &mut ours, &mut scratch,
                );
                host_grad_step(&meta, &state, &dense, seed, &mut oracle)
                    .expect("oracle grads");
                let d = max_abs_diff(&ours, &oracle);
                assert!(
                    d < 1e-4,
                    "{model}/{}: batch {b} grads diverged by {d}",
                    exec.name()
                );
            }
        }
    }
}

/// Fused Adam (train_step) and the accumulation path (grad_step +
/// host_adam) are the same per-element expressions — the resulting
/// states must agree bitwise.
#[test]
fn fused_adam_matches_host_adam_bitwise() {
    let ds = tiny_dataset();
    let cache = plan_cache(&ds, 7);
    let meta = meta_for("sage", &ds, &cache);
    let exec = TrainExecutorKind::Blocked.build().unwrap();
    let mut scratch = TrainScratch::new();
    let (mut x, mut labels) = (Vec::new(), Vec::new());
    let mut fused = ModelState::init(&meta, 17);
    let mut accum = fused.clone();
    for b in 0..cache.len().min(3) {
        let batch = sparse_batch(&ds, &cache, b, &mut x, &mut labels);
        let seed = 9 + b as i32;
        exec.train_step(&meta, &mut fused, &batch, 5e-3, seed, &mut scratch);
        let mut grads = vec![0.0f32; meta.param_count];
        exec.grad_step(&meta, &accum, &batch, seed, &mut grads, &mut scratch);
        training::host_adam(&mut accum, &grads, 5e-3);
        assert_eq!(fused.params, accum.params, "batch {b}: params");
        assert_eq!(fused.m, accum.m, "batch {b}: m");
        assert_eq!(fused.v, accum.v, "batch {b}: v");
        assert_eq!(fused.step, accum.step);
    }
}

/// Determinism: the same pinned-seed step twice is bitwise identical,
/// and reference-vs-blocked stay within 1e-5 on this tiny model.
#[test]
fn backends_are_deterministic_and_close() {
    let ds = tiny_dataset();
    let cache = plan_cache(&ds, 8);
    let meta = meta_for("gcn", &ds, &cache);
    let (mut x, mut labels) = (Vec::new(), Vec::new());
    let batch = sparse_batch(&ds, &cache, 0, &mut x, &mut labels);

    let run = |kind: TrainExecutorKind| {
        let exec = kind.build().unwrap();
        let mut state = ModelState::init(&meta, 31);
        let mut scratch = TrainScratch::new();
        // tiny lr bounds a worst-case Adam sign flip (see the oracle
        // parity test) below the cross-backend tolerance
        let m = exec.train_step(&meta, &mut state, &batch, 1e-5, 55, &mut scratch);
        (state, m)
    };
    let (s1, m1) = run(TrainExecutorKind::Blocked);
    let (s2, m2) = run(TrainExecutorKind::Blocked);
    assert_eq!(s1.params, s2.params, "blocked step not deterministic");
    assert_eq!(m1.loss, m2.loss);
    let (sr, mr) = run(TrainExecutorKind::Reference);
    assert!(max_abs_diff(&s1.params, &sr.params) < 1e-4);
    assert!((m1.loss - mr.loss).abs() < 1e-5);
}

/// End-to-end `train_native` smoke: converges on the tiny SBM, runs the
/// requested epochs, and reports ring-bounded allocations.
#[test]
fn train_native_converges() {
    let ds = tiny_dataset();
    let mut gen = NodeWiseIbmb {
        aux_per_output: 4,
        max_outputs_per_batch: 32,
        node_budget: 128,
        ..Default::default()
    };
    let cfg = TrainConfig {
        model: "gcn".into(),
        epochs: 4,
        seed: 2,
        executor: TrainExecutorKind::Blocked,
        hidden: HIDDEN,
        layers: LAYERS,
        heads: HEADS,
        dropout: DROPOUT as f32,
        weight_decay: WD as f32,
        lr: 1e-2,
        ..Default::default()
    };
    let mut rng = Rng::new(2 ^ 0xE9E1);
    let tracer = ibmb::telemetry::Tracer::disabled();
    let res = training::train_native(&ds, &cfg, &mut gen, &mut rng, &tracer)
        .expect("train_native");
    assert_eq!(res.epochs_run, 4);
    assert!(!res.history.is_empty());
    let first = res.history.first().unwrap().train_loss;
    let last = res.history.last().unwrap().train_loss;
    assert!(
        last < first,
        "native training not learning: {first:.4} -> {last:.4}"
    );
    assert!(res.best_val_acc > 0.0);
    assert_eq!(res.arena_allocations, cfg.prefetch_depth.max(1));
}

/// GAT has no native attention VJP — the trainer must say so and point
/// at the runtime path.
#[test]
fn train_native_rejects_gat() {
    let ds = tiny_dataset();
    let mut gen = NodeWiseIbmb::default();
    let cfg = TrainConfig {
        model: "gat".into(),
        ..Default::default()
    };
    let mut rng = Rng::new(1);
    let tracer = ibmb::telemetry::Tracer::disabled();
    let err = training::train_native(&ds, &cfg, &mut gen, &mut rng, &tracer)
        .expect_err("gat must be rejected");
    assert!(err.to_string().contains("runtime"), "unhelpful error: {err}");
}
