//! End-to-end integration over the full stack: dataset → preprocessing
//! → cached batches → fused PJRT training → method-approximated
//! validation → batched inference. Also failure-injection cases for the
//! error paths (missing buckets, oversized batches, bad manifests).

use ibmb::baselines;
use ibmb::batching::{BatchArena, BatchCache, BatchGenerator, DenseBatch, NodeWiseIbmb};
use ibmb::datasets::{sbm, DatasetSpec};
use ibmb::inference::infer_with_batches;
use ibmb::runtime::{Manifest, ModelState, Runtime};
use ibmb::training::{train, trainer::SchedulerKind, TrainConfig};
use ibmb::util::Rng;

fn runtime() -> Option<Runtime> {
    for dir in ["artifacts", "../artifacts"] {
        if std::path::Path::new(dir).join("manifest.json").exists() {
            return Some(Runtime::load(dir).expect("runtime"));
        }
    }
    eprintln!("SKIP: artifacts not built (run `make artifacts`)");
    None
}

fn dataset(nodes: usize, seed: u64) -> ibmb::datasets::Dataset {
    let spec = DatasetSpec {
        nodes,
        feat_dim: 64,
        classes: 10,
        ..DatasetSpec::tiny_for_tests()
    };
    sbm::generate(&spec, seed)
}

#[test]
fn full_training_loop_learns_and_reports() {
    let Some(mut rt) = runtime() else { return };
    let ds = dataset(900, 1);
    let mut gen = NodeWiseIbmb {
        aux_per_output: 8,
        max_outputs_per_batch: 64,
        node_budget: 256,
        ..Default::default()
    };
    let cfg = TrainConfig {
        model: "gcn".into(),
        epochs: 8,
        lr: 3e-3,
        seed: 1,
        scheduler: SchedulerKind::Weighted,
        ..Default::default()
    };
    let mut rng = Rng::new(1);
    let res = train(&mut rt, &ds, &cfg, &mut gen, &mut rng).expect("train");
    assert_eq!(res.epochs_run, 8);
    assert!(!res.history.is_empty());
    let first = res.history.first().unwrap();
    let last = res.history.last().unwrap();
    assert!(
        last.train_loss < first.train_loss,
        "loss {} -> {}",
        first.train_loss,
        last.train_loss
    );
    // homophilic SBM with 10 classes: should beat chance comfortably
    assert!(
        res.best_val_acc > 0.2,
        "val acc {} barely above chance",
        res.best_val_acc
    );
    assert!(res.preprocess_s > 0.0);
    assert!(res.mean_epoch_s > 0.0);
    assert!(res.cache_bytes > 0);
}

#[test]
fn stochastic_method_trains_too() {
    let Some(mut rt) = runtime() else { return };
    let ds = dataset(700, 2);
    let mut gen = baselines::by_name("neighbor sampling", 4, 4, 256).unwrap();
    let cfg = TrainConfig {
        model: "gcn".into(),
        epochs: 4,
        seed: 2,
        ..Default::default()
    };
    let mut rng = Rng::new(2);
    let res = train(&mut rt, &ds, &cfg, gen.as_mut(), &mut rng).expect("train");
    assert!(res.history.last().unwrap().val_acc > 0.15);
}

#[test]
fn gradient_accumulation_path_trains() {
    let Some(mut rt) = runtime() else { return };
    let ds = dataset(700, 3);
    let mut gen = baselines::by_name("batch-wise IBMB", 8, 4, 256).unwrap();
    let cfg = TrainConfig {
        model: "gcn".into(),
        epochs: 5,
        seed: 3,
        grad_accum: 2,
        ..Default::default()
    };
    let mut rng = Rng::new(3);
    let res = train(&mut rt, &ds, &cfg, gen.as_mut(), &mut rng).expect("train");
    let first = res.history.first().unwrap().train_loss;
    let last = res.history.last().unwrap().train_loss;
    assert!(last < first, "accum path: {first} -> {last}");
}

#[test]
fn inference_accuracy_matches_training_signal() {
    let Some(mut rt) = runtime() else { return };
    let ds = dataset(900, 4);
    let mut gen = NodeWiseIbmb {
        aux_per_output: 8,
        max_outputs_per_batch: 64,
        node_budget: 256,
        ..Default::default()
    };
    let cfg = TrainConfig {
        model: "gcn".into(),
        epochs: 10,
        lr: 3e-3,
        seed: 4,
        ..Default::default()
    };
    let mut rng = Rng::new(4);
    let res = train(&mut rt, &ds, &cfg, &mut gen, &mut rng).expect("train");
    let cache = BatchCache::build(&gen.plan(&ds, &ds.splits.test, &mut rng));
    let mut arena = BatchArena::new(ds.feat_dim);
    let rep = infer_with_batches(
        &mut rt,
        &ds,
        "gcn",
        &res.state,
        &mut gen,
        Some(&cache),
        &ds.splits.test,
        &mut rng,
        &mut arena,
        2,
    )
    .expect("infer");
    assert!(rep.batches > 0);
    assert!(rep.pad_utilization > 0.05 && rep.pad_utilization <= 1.0);
    // test accuracy in the same ballpark as validation accuracy
    assert!(
        (rep.accuracy - res.best_val_acc).abs() < 0.25,
        "test {} vs val {}",
        rep.accuracy,
        res.best_val_acc
    );
}

#[test]
fn every_scheduler_kind_runs() {
    let Some(mut rt) = runtime() else { return };
    let ds = dataset(600, 5);
    for kind in [
        SchedulerKind::Sequential,
        SchedulerKind::Shuffle,
        SchedulerKind::OptimalCycle,
        SchedulerKind::Weighted,
    ] {
        let mut gen = baselines::by_name("batch-wise IBMB", 8, 3, 256).unwrap();
        let cfg = TrainConfig {
            model: "gcn".into(),
            epochs: 2,
            seed: 5,
            scheduler: kind,
            ..Default::default()
        };
        let mut rng = Rng::new(5);
        train(&mut rt, &ds, &cfg, gen.as_mut(), &mut rng)
            .unwrap_or_else(|e| panic!("{kind:?}: {e:#}"));
    }
}

// ---------------------------------------------------------------------
// failure injection
// ---------------------------------------------------------------------

#[test]
fn missing_bucket_is_a_clean_error() {
    let Some(mut rt) = runtime() else { return };
    let ds = dataset(600, 6);
    // a batch bigger than the largest bucket must fail with a clear
    // error, not a panic
    let mut gen = baselines::by_name("Cluster-GCN", 8, 1, usize::MAX).unwrap();
    let cfg = TrainConfig {
        model: "gcn".into(),
        epochs: 1,
        seed: 6,
        ..Default::default()
    };
    let mut rng = Rng::new(6);
    // 600-node dataset in ONE cluster batch exceeds n_pad=2048? No —
    // 600 < 2048 fits. Use a big dataset to exceed the bucket.
    let big = dataset(3000, 6);
    let err = train(&mut rt, &big, &cfg, gen.as_mut(), &mut rng);
    let _ = &ds;
    assert!(err.is_err(), "expected missing-bucket error");
    let msg = format!("{:#}", err.unwrap_err());
    assert!(msg.contains("bucket"), "unexpected error: {msg}");
}

#[test]
fn unknown_model_is_a_clean_error() {
    let Some(mut rt) = runtime() else { return };
    let ds = dataset(600, 7);
    let mut gen = NodeWiseIbmb {
        node_budget: 256,
        ..Default::default()
    };
    let cfg = TrainConfig {
        model: "transformer".into(),
        epochs: 1,
        seed: 7,
        ..Default::default()
    };
    let mut rng = Rng::new(7);
    assert!(train(&mut rt, &ds, &cfg, &mut gen, &mut rng).is_err());
}

#[test]
fn oversized_materialize_panics_with_context() {
    let ds = dataset(600, 8);
    let mut gen = NodeWiseIbmb {
        aux_per_output: 16,
        max_outputs_per_batch: 200,
        node_budget: 1024,
        ..Default::default()
    };
    let mut rng = Rng::new(8);
    let cache = BatchCache::build(&gen.plan(&ds, &ds.splits.train, &mut rng));
    let mut tiny = DenseBatch::zeros(8, ds.feat_dim);
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        cache.materialize_into(&ds, 0, &mut tiny);
    }));
    assert!(result.is_err());
}

#[test]
fn corrupt_manifest_is_rejected() {
    for bad in [
        "",                       // empty
        "{",                      // truncated
        r#"{"version": 9}"#,      // wrong version
        r#"{"version": 1}"#,      // missing artifacts
    ] {
        assert!(Manifest::parse(bad).is_err(), "accepted: {bad:?}");
    }
}

#[test]
fn model_state_rejects_nothing_but_stays_consistent() {
    let Some(rt) = runtime() else { return };
    // init for every artifact and check layout-derived lengths
    for meta in &rt.manifest.artifacts {
        let s = ModelState::init(meta, 1);
        assert_eq!(s.params.len(), meta.param_count, "{}", meta.id);
        assert!(s.params.iter().all(|v| v.is_finite()), "{}", meta.id);
    }
}
