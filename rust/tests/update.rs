//! End-to-end tests of the dynamic-update subsystem (DESIGN.md §10 /
//! §11): delta application on the overlay, the snapshot publish
//! cascade (plan buckets, router index, plan epochs, results memo),
//! and the mid-serve smoke the CI gate runs against a real delta
//! stream.

use std::time::Duration;

use ibmb::graph::{synth_delta_stream, GraphDelta};
use ibmb::serve::{
    DynamicServeSession, Route, ServeConfig, Skew, UpdateConfig,
};

fn session(results_cache_bytes: usize) -> DynamicServeSession {
    let ds = ibmb::datasets::sbm::generate(
        &ibmb::datasets::DatasetSpec::tiny_for_tests(),
        77,
    );
    let cfg = ServeConfig {
        clients: 8,
        shards: 2,
        results_cache_bytes,
        flush_window: Duration::from_micros(200),
        seed: 7,
        ..Default::default()
    };
    let eval = ds.splits.train.clone();
    DynamicServeSession::prepare(ds, &eval, &cfg, &UpdateConfig::default())
}

#[test]
fn fifty_edge_delta_mid_serve_keeps_answering() {
    // the CI smoke, as a deterministic in-process assertion
    let mut s = session(1 << 20);
    let ds = s.dataset();
    let eval = ds.splits.train.clone();
    let before = s.serve_segment(&eval, Skew::Zipf(1.2), 40).unwrap();
    assert_eq!(before.executed_queries + before.cache_hits, 40);

    let delta =
        synth_delta_stream(&ds.graph, &eval, 1, 50, 0, 0, ds.num_classes, 7)
            .pop()
            .unwrap();
    drop(ds);
    let up = s.apply(&delta).unwrap();
    assert!(up.stale_plans() > 0, "50 focused edges must stale plans");
    assert!(up.roots_refreshed > 0);

    let after = s.serve_segment(&eval, Skew::Zipf(1.2), 40).unwrap();
    assert_eq!(
        after.executed_queries + after.cache_hits,
        40,
        "queries lost across the update"
    );
    assert_eq!(after.final_epoch, 1);
    assert!((0.0..=1.0).contains(&after.accuracy));
}

#[test]
fn small_delta_repairs_a_strict_subset_of_plans() {
    // one edge between two outputs: the delta-local repair must leave
    // most of the precomputed state untouched
    let mut s = session(0);
    let eval = s.dataset().splits.train.clone();
    let plans = s.cache().len();
    assert!(plans > 1, "need several plans for a fraction to mean much");
    let before = s.cache();
    let up = s
        .apply(&GraphDelta {
            add_edges: vec![(eval[0], eval[1])],
            ..Default::default()
        })
        .unwrap();
    assert!(up.stale_plans() > 0);
    assert!(
        up.rebuilt_fraction() < 1.0,
        "a single edge rebuilt every plan: {up:?}"
    );
    assert!(
        up.stale_plans() < up.plans_total,
        "a single edge staled every plan: {up:?}"
    );
    assert!(up.roots_refreshed < eval.len());
    // the published snapshot shares every untouched plan bucket with
    // the pre-delta one — the patch copied only what changed
    let after = s.cache();
    assert_eq!(
        after.shared_with(&before),
        plans - up.stale_plans(),
        "structural sharing accounting"
    );
}

#[test]
fn router_index_stays_total_and_consistent_across_updates() {
    let mut s = session(0);
    let eval = s.dataset().splits.train.clone();

    // a cold node keeps a stable coalescing id across updates — its
    // *plan content* refreshes per epoch shard-side, so the id itself
    // never dangles
    let covered: std::collections::HashSet<u32> =
        eval.iter().copied().collect();
    let cold_node = (0..s.dataset().graph.num_nodes() as u32)
        .find(|u| !covered.contains(u))
        .expect("tiny split leaves cold nodes");
    let state0 = s.state();
    let old_cold_id = match s.setup.router.route(&state0.index, cold_node) {
        Route::Cold { id } => id,
        other => panic!("expected cold, got {other:?}"),
    };

    let delta = GraphDelta {
        add_edges: vec![(cold_node, eval[0]), (eval[1], eval[2])],
        ..Default::default()
    };
    let up = s.apply(&delta).unwrap();
    assert!(up.stale_plans() > 0, "{up:?}");

    let state1 = s.state();
    assert_eq!(state1.epoch, 1);
    // same id, different epoch: coalescing continuity without stale
    // plan content (the memo keys cold entries on the snapshot epoch)
    match s.setup.router.route(&state1.index, cold_node) {
        Route::Cold { id } => assert_eq!(id, old_cold_id),
        other => panic!("expected cold, got {other:?}"),
    }
    assert_ne!(
        state0.plan_epoch(&ibmb::serve::PlanKey::Cold(old_cold_id)),
        state1.plan_epoch(&ibmb::serve::PlanKey::Cold(old_cold_id)),
        "cold freshness epoch must move with the snapshot"
    );

    // warm routing stays total and consistent with the new snapshot
    let plans = state1.cache.len();
    for &u in &eval {
        match s.setup.router.route(&state1.index, u) {
            Route::Cached { plan, pos } => {
                assert!((plan as usize) < plans, "dangling plan id {plan}");
                assert_eq!(
                    state1.cache.output_nodes(plan as usize)[pos as usize],
                    u,
                    "output {u} routed to a plan that does not own it"
                );
            }
            Route::Cold { .. } => {
                panic!("output {u} lost warm routing after the update")
            }
        }
    }
}

#[test]
fn post_update_reads_never_serve_pre_delta_logits() {
    let mut s = session(1 << 20);
    let eval = s.dataset().splits.train.clone();
    // sequential repeats of one node: one execution, then memo hits
    let node = [eval[0]];
    let cfg_probe = |s: &mut DynamicServeSession| {
        s.serve_segment(&node, Skew::Uniform, 10).unwrap()
    };
    let warm = cfg_probe(&mut s);
    assert!(warm.cache_hits > 0, "memo never engaged: {warm:?}");

    // an edge incident to the queried node's plan outputs goes in;
    // the plan's epoch moves and its memo entry must die with it
    let delta = GraphDelta {
        add_edges: vec![(eval[0], eval[1])],
        ..Default::default()
    };
    let evictions_before = s.memo.epoch_evictions;
    let up = s.apply(&delta).unwrap();
    assert!(up.stale_plans() > 0);
    assert!(
        s.memo.epoch_evictions > evictions_before,
        "apply must eagerly sweep the stale memo entry: {up:?}"
    );

    let fresh = cfg_probe(&mut s);
    assert!(
        fresh.executions >= 1,
        "post-update segment was served entirely from the pre-delta \
         memo: {fresh:?}"
    );
}

#[test]
fn feature_update_invalidates_serving_state_without_topology_change() {
    let mut s = session(1 << 20);
    let eval = s.dataset().splits.train.clone();
    let edges_before = s.dataset().graph.num_edges();
    let target = eval[0];
    let mut probe = vec![0.0f32; s.dataset().feat_dim];
    s.dataset().node_features_into(target, &mut probe);

    let up = s
        .apply(&GraphDelta {
            feature_updates: vec![target],
            ..Default::default()
        })
        .unwrap();
    assert_eq!(
        s.dataset().graph.num_edges(),
        edges_before,
        "topology changed"
    );
    assert_eq!(up.plans_rebuilt, 0);
    assert!(up.plans_patched > 0, "feature epoch must stale its plans");
    assert_eq!(up.buckets_patched, 0, "feature-only: payloads shared");

    let mut after = vec![0.0f32; s.dataset().feat_dim];
    s.dataset().node_features_into(target, &mut after);
    assert_ne!(probe, after, "feature update did not change features");
    // other nodes are bit-identical
    let other = eval[1];
    let mut a = vec![0.0f32; s.dataset().feat_dim];
    let mut b = vec![0.0f32; s.dataset().feat_dim];
    s.dataset().node_features_into(other, &mut a);
    let up2 = s
        .apply(&GraphDelta {
            feature_updates: vec![target],
            ..Default::default()
        })
        .unwrap();
    assert_eq!(up2.epoch, 2);
    s.dataset().node_features_into(other, &mut b);
    assert_eq!(a, b, "unrelated node's features drifted");
}

#[test]
fn appended_nodes_become_serveable_via_cold_path() {
    let mut s = session(0);
    let eval = s.dataset().splits.train.clone();
    let n0 = s.dataset().graph.num_nodes();
    let up = s
        .apply(&GraphDelta {
            add_node_labels: vec![1, 2],
            add_edges: vec![(n0 as u32, eval[0]), (n0 as u32 + 1, eval[1])],
            ..Default::default()
        })
        .unwrap();
    assert_eq!(up.added_nodes, 2);
    assert_eq!(up.index_extended, 2);
    let ds = s.dataset();
    assert_eq!(ds.graph.num_nodes(), n0 + 2);
    assert_eq!(ds.labels.len(), n0 + 2);
    drop(ds);
    let pop = [n0 as u32, n0 as u32 + 1];
    let r = s.serve_segment(&pop, Skew::Uniform, 8).unwrap();
    assert_eq!(r.executed_queries + r.cache_hits, 8);
    assert!(r.cold_routes > 0, "new nodes must take the cold path");
}
