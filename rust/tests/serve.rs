//! Serving-subsystem integration tests: routing totality (every eval
//! node lands in exactly one plan, everything else takes the PPR cold
//! path) and end-to-end coalescing (K concurrent queries to one plan
//! cost exactly one materialize+execute).

use std::collections::HashSet;
use std::time::Duration;

use ibmb::batching::{BatchGenerator, CowCache, NodeWiseIbmb};
use ibmb::datasets::{sbm, Dataset, DatasetSpec};
use ibmb::serve::{self, QueryRouter, Route, RouterIndex, ServeConfig, Skew};
use ibmb::util::Rng;

fn setup() -> (Dataset, CowCache) {
    let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 101);
    let mut gen = NodeWiseIbmb {
        aux_per_output: 6,
        max_outputs_per_batch: 40,
        node_budget: 256,
        ..Default::default()
    };
    let mut rng = Rng::new(17);
    let eval = ds.splits.train.clone();
    let cache = CowCache::from_plans(&gen.plan(&ds, &eval, &mut rng));
    (ds, cache)
}

#[test]
fn every_node_routes_to_exactly_one_plan_or_cold_path() {
    let (ds, cache) = setup();
    let index = RouterIndex::build(ds.graph.num_nodes(), &cache);
    let mut router = QueryRouter::new();
    assert_eq!(index.duplicates, 0, "IBMB partition must be disjoint");
    let eval: HashSet<u32> = ds.splits.train.iter().copied().collect();
    assert_eq!(index.coverage(), eval.len());

    let mut routed_per_plan = vec![0usize; cache.len()];
    let mut cold_ids = HashSet::new();
    for u in 0..ds.graph.num_nodes() as u32 {
        match router.route(&index, u) {
            Route::Cached { plan, pos } => {
                assert!(
                    eval.contains(&u),
                    "non-eval node {u} routed to a cached plan"
                );
                assert_eq!(
                    cache.output_nodes(plan as usize)[pos as usize],
                    u,
                    "node {u} routed to a plan that does not output it"
                );
                routed_per_plan[plan as usize] += 1;
            }
            Route::Cold { id } => {
                assert!(!eval.contains(&u), "eval node {u} went cold");
                assert!(cold_ids.insert(id), "cold id {id} reused");
            }
        }
    }
    // cached routing is a bijection onto the plans' output lists
    for (pid, &n) in routed_per_plan.iter().enumerate() {
        assert_eq!(n, cache.num_outputs(pid), "plan {pid} output coverage");
    }
    assert_eq!(
        router.cold_built,
        ds.graph.num_nodes() - eval.len(),
        "one memoized cold id per uncovered node"
    );
}

#[test]
fn k_concurrent_queries_to_one_plan_materialize_once() {
    let (ds, _) = setup();
    let k = 12;
    let cfg = ServeConfig {
        queries: k,
        clients: k, // all K in flight at once
        shards: 1,
        // no size flush, generous deadline (admission takes µs), no
        // memo short-circuit: exactly one deadline-flushed group
        max_coalesce: k + 4,
        flush_window: Duration::from_millis(100),
        results_cache_bytes: 0,
        ..Default::default()
    };
    let eval = ds.splits.train.clone();
    // all K queries target the same node → same plan
    let population = [eval[0]];
    let mut setup = serve::prepare(ds, &eval, &cfg);
    let report =
        serve::serve_closed_loop(&mut setup, &population, Skew::Uniform, &cfg)
            .unwrap();
    assert_eq!(report.queries, k);
    assert_eq!(
        report.executions, 1,
        "K concurrent same-plan queries must coalesce into one execution"
    );
    assert_eq!(report.executed_queries, k as u64);
    assert!((report.coalescing_factor - k as f64).abs() < 1e-9);
    assert_eq!(report.cache_hits, 0);
}

#[test]
fn size_flush_bounds_group_size_end_to_end() {
    let (ds, _) = setup();
    let k = 9;
    let cfg = ServeConfig {
        queries: k,
        clients: k,
        shards: 1,
        max_coalesce: 3, // forces ceil(9/3) = 3 executions
        flush_window: Duration::from_millis(100),
        ..Default::default()
    };
    let eval = ds.splits.train.clone();
    let population = [eval[0]];
    let mut setup = serve::prepare(ds, &eval, &cfg);
    let report =
        serve::serve_closed_loop(&mut setup, &population, Skew::Uniform, &cfg)
            .unwrap();
    assert_eq!(report.executions, 3);
    assert!((report.coalescing_factor - 3.0).abs() < 1e-9);
}

#[test]
fn cold_queries_are_served_end_to_end() {
    let (ds, _) = setup();
    let cfg = ServeConfig {
        queries: 20,
        clients: 4,
        shards: 2,
        flush_window: Duration::from_micros(200),
        ..Default::default()
    };
    let eval = ds.splits.train.clone();
    // population drawn entirely from NON-eval nodes
    let covered: HashSet<u32> = eval.iter().copied().collect();
    let cold: Vec<u32> = (0..ds.graph.num_nodes() as u32)
        .filter(|u| !covered.contains(u))
        .take(5)
        .collect();
    assert!(!cold.is_empty());
    let mut setup = serve::prepare(ds, &eval, &cfg);
    let report =
        serve::serve_closed_loop(&mut setup, &cold, Skew::Uniform, &cfg)
            .unwrap();
    assert_eq!(report.cold_routes, 20, "every query took the cold path");
    assert!(report.cold_plans <= 5, "cold plans memoized per node");
    assert_eq!(report.executed_queries + report.cache_hits, 20);
}
