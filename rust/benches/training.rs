//! Training-backend throughput + convergence parity (DESIGN.md §16):
//! the same pinned-seed epoch schedule through three step
//! implementations —
//!
//! - `runtime`: the dense padded path the AOT artifacts execute
//!   (host-emulated exactly: bucket-padded `n_pad × n_pad` adjacency,
//!   dense SpMM, per-step gradient materialization),
//! - `reference`: the native sparse scalar backend,
//! - `blocked`: the native sparse `[f32; 8]`-lane backend,
//!
//! and writes `BENCH_training.json`. Gates (ci.sh greps the GATE
//! lines): blocked ≥ 3x steps/s over the runtime path, and final val
//! accuracy within 0.01 of it (same math, different summation order).

use std::collections::BTreeMap;

use ibmb::batching::{BatchCache, BatchGenerator, DenseBatch, NodeWiseIbmb};
use ibmb::bench_harness::Table;
use ibmb::datasets::{sbm, spec_by_name, Dataset};
use ibmb::exec::train::train_artifact;
use ibmb::exec::{ExecScratch, ExecutorKind, PlanView, TrainBatch, TrainExecutorKind, TrainScratch};
use ibmb::inference::infer_with_executor;
use ibmb::runtime::host::host_train_step;
use ibmb::runtime::{ArtifactMeta, ModelState};
use ibmb::serve::reference_artifact;
use ibmb::util::json::{to_string, Json};
use ibmb::util::{Rng, Timer};

const HIDDEN: usize = 32;
const LAYERS: usize = 2;
const HEADS: usize = 2;
const DROPOUT: f64 = 0.3;
const WD: f64 = 1e-4;
const LR: f32 = 1e-2;

struct ArmResult {
    executor: &'static str,
    steps_per_s: f64,
    epoch_s: f64,
    final_val_acc: f64,
}

/// Per-step dropout/loss seed — the formula `training::train_native`
/// uses, so bench arms and CLI runs draw identical masks.
fn step_seed(seed: u64, epoch: usize, step: usize) -> i32 {
    (seed as i32)
        .wrapping_mul(31)
        .wrapping_add((epoch * 10_007 + step) as i32)
}

/// Validation accuracy through the shared reference forward — the same
/// evaluator for every arm, so the parity gate sees only training
/// differences.
fn val_acc(
    meta_val: &ArtifactMeta,
    ds: &Dataset,
    state: &ModelState,
    val_cache: &BatchCache,
) -> anyhow::Result<f64> {
    let exec = ExecutorKind::Reference.build()?;
    let mut scratch = ExecScratch::new();
    let rep =
        infer_with_executor(exec.as_ref(), meta_val, ds, state, val_cache, &mut scratch)?;
    Ok(rep.accuracy)
}

fn main() -> anyhow::Result<()> {
    let args = ibmb::cli::Args::parse(
        std::env::args().skip(1).filter(|a| a != "--bench"),
    );
    let scale = args.get_f64("scale", 0.05);
    let seed = args.get_u64("seed", 11);
    let epochs = args.get_usize("epochs", 3);
    let model = args.get_or("model", "gcn").to_string();

    let spec = spec_by_name("synth-arxiv").unwrap().scaled(scale);
    let ds = sbm::generate(&spec, seed);
    println!(
        "dataset: {} nodes, {} edges, {} train / {} val",
        ds.graph.num_nodes(),
        ds.graph.num_edges(),
        ds.splits.train.len(),
        ds.splits.val.len()
    );

    // one plan set for every arm — identical batches, identical order
    let mut gen = NodeWiseIbmb {
        aux_per_output: 4,
        max_outputs_per_batch: 64,
        node_budget: 512,
        ..Default::default()
    };
    let mut rng = Rng::new(seed ^ 0xE9E1);
    let cache = BatchCache::build(&gen.plan(&ds, &ds.splits.train, &mut rng));
    let val_cache = BatchCache::build(&gen.plan(&ds, &ds.splits.val, &mut rng));
    anyhow::ensure!(!cache.is_empty() && !val_cache.is_empty());
    let max_nodes = cache.max_batch_nodes();
    // the AOT path pads every batch to its power-of-two bucket
    let bucket = max_nodes.next_power_of_two();
    println!(
        "{} train batches (max {} nodes, runtime bucket {}), {} val batches",
        cache.len(),
        max_nodes,
        bucket,
        val_cache.len()
    );

    let meta_native = train_artifact(
        &model, ds.feat_dim, ds.num_classes, HIDDEN, LAYERS, HEADS, DROPOUT,
        WD, max_nodes,
    );
    let meta_runtime = train_artifact(
        &model, ds.feat_dim, ds.num_classes, HIDDEN, LAYERS, HEADS, DROPOUT,
        WD, bucket,
    );
    let meta_val = reference_artifact(
        &model,
        ds.feat_dim,
        ds.num_classes,
        HIDDEN,
        LAYERS,
        HEADS,
        val_cache.max_batch_nodes(),
    );
    let steps_total = epochs * cache.len();
    let mut table =
        Table::new(&["executor", "steps/s", "epoch (s)", "final val acc"]);
    let mut results: Vec<ArmResult> = Vec::new();

    // ---- arm 1: the dense padded runtime path (host-emulated) ----
    {
        let mut state = ModelState::init(&meta_runtime, seed);
        let mut dense = DenseBatch::zeros(bucket, ds.feat_dim);
        cache.materialize_into(&ds, 0, &mut dense); // warm the buffer
        let t = Timer::start();
        for epoch in 0..epochs {
            for b in 0..cache.len() {
                cache.materialize_into(&ds, b, &mut dense);
                host_train_step(
                    &meta_runtime,
                    &mut state,
                    &dense,
                    LR,
                    step_seed(seed, epoch, b),
                )?;
            }
        }
        let elapsed = t.elapsed_s();
        let acc = val_acc(&meta_val, &ds, &state, &val_cache)?;
        results.push(ArmResult {
            executor: "runtime",
            steps_per_s: steps_total as f64 / elapsed,
            epoch_s: elapsed / epochs as f64,
            final_val_acc: acc,
        });
    }

    // ---- arms 2+3: native sparse backends ----
    for kind in [TrainExecutorKind::Reference, TrainExecutorKind::Blocked] {
        let exec = kind.build()?;
        let mut state = ModelState::init(&meta_native, seed);
        let mut scratch = TrainScratch::new();
        let mut x: Vec<f32> = Vec::new();
        let mut labels: Vec<i32> = Vec::new();
        let t = Timer::start();
        for epoch in 0..epochs {
            for b in 0..cache.len() {
                let n = cache.gather_features_into(&ds, b, &mut x);
                cache.gather_labels_into(&ds, b, &mut labels);
                let batch = TrainBatch {
                    view: PlanView {
                        n,
                        edge_src: cache.edge_src_of(b),
                        edge_dst: cache.edge_dst_of(b),
                        weights: cache.edge_weights_of(b),
                    },
                    x: &x[..n * ds.feat_dim],
                    labels: &labels[..n],
                    num_outputs: cache.num_outputs(b),
                };
                exec.train_step(
                    &meta_native,
                    &mut state,
                    &batch,
                    LR,
                    step_seed(seed, epoch, b),
                    &mut scratch,
                );
            }
        }
        let elapsed = t.elapsed_s();
        let acc = val_acc(&meta_val, &ds, &state, &val_cache)?;
        results.push(ArmResult {
            executor: exec.name(),
            steps_per_s: steps_total as f64 / elapsed,
            epoch_s: elapsed / epochs as f64,
            final_val_acc: acc,
        });
    }

    let runtime_sps = results[0].steps_per_s;
    let reference_sps = results[1].steps_per_s;
    for r in &results {
        table.row(&[
            r.executor.into(),
            format!("{:.1}", r.steps_per_s),
            format!("{:.3}", r.epoch_s),
            format!("{:.3}", r.final_val_acc),
        ]);
    }
    table.print("training — fused step backends");

    let blocked = &results[2];
    let speedup = blocked.steps_per_s / runtime_sps;
    let acc_delta = (blocked.final_val_acc - results[0].final_val_acc).abs();
    println!(
        "GATE training_speedup: blocked {speedup:.2}x vs runtime \
         (target >= 3.0) -> {}",
        if speedup >= 3.0 { "PASS" } else { "FAIL" }
    );
    println!(
        "GATE training_parity: |val_acc(blocked) - val_acc(runtime)| = \
         {acc_delta:.4} (target <= 0.01) -> {}",
        if acc_delta <= 0.01 { "PASS" } else { "FAIL" }
    );

    let json = Json::Obj(BTreeMap::from([
        ("bench".into(), Json::Str("training".into())),
        ("dataset".into(), Json::Str(ds.name.clone())),
        ("model".into(), Json::Str(model.clone())),
        ("epochs".into(), Json::Num(epochs as f64)),
        ("batches".into(), Json::Num(cache.len() as f64)),
        ("bucket".into(), Json::Num(bucket as f64)),
        ("seed".into(), Json::Num(seed as f64)),
        (
            "runs".into(),
            Json::Arr(
                results
                    .iter()
                    .map(|r| {
                        Json::Obj(BTreeMap::from([
                            ("executor".into(), Json::Str(r.executor.into())),
                            ("steps_per_s".into(), Json::Num(r.steps_per_s)),
                            ("epoch_s".into(), Json::Num(r.epoch_s)),
                            (
                                "speedup_vs_reference".into(),
                                Json::Num(r.steps_per_s / reference_sps),
                            ),
                            (
                                "speedup_vs_runtime".into(),
                                Json::Num(r.steps_per_s / runtime_sps),
                            ),
                            (
                                "final_val_acc".into(),
                                Json::Num(r.final_val_acc),
                            ),
                        ]))
                    })
                    .collect(),
            ),
        ),
    ]));
    let out_path = args.get_or("out", "BENCH_training.json").to_string();
    std::fs::write(&out_path, to_string(&json))?;
    println!("wrote {out_path}");
    Ok(())
}
