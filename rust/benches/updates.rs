//! Dynamic-update benchmark: incremental PPR refresh + staleness-
//! tracked replan vs. full replanning, as a function of delta size,
//! plus the **p99-under-churn** head-to-head — quiesced (deltas
//! applied inline on the serving control thread) vs. zero-quiesce
//! (background applier publishing epoch snapshots, DESIGN.md §11) vs.
//! a no-churn baseline. Emits `BENCH_updates.json` recording refresh
//! latency, the fraction of plans rebuilt, and the churn series — the
//! headline claims are that small deltas repair a small, delta-local
//! slice of the precomputed state, and that snapshot swaps keep tail
//! latency under churn near the no-churn baseline while inline
//! application spikes it.
//!
//! Run: `cargo bench --bench updates` (`--full` for the bigger graph;
//! `--sizes 8,32,128 --l1-tol F --seed N --churn-queries N
//! --churn-batches N --churn-edges N` to override).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use ibmb::batching::refresh::{DynamicPlanSet, RefreshConfig};
use ibmb::bench_harness::Table;
use ibmb::cli::Args;
use ibmb::config::preset_for;
use ibmb::datasets::{sbm, spec_by_name};
use ibmb::graph::{synth_delta_stream, DynamicGraph};
use ibmb::serve::{
    serve_with_churn, Churn, DynamicServeSession, ServeConfig, Skew,
    UpdateConfig,
};
use ibmb::util::json::{to_string, Json};
use ibmb::util::Rng;

struct RunRecord {
    delta_edges: usize,
    touched: usize,
    roots_refreshed: usize,
    plans_total: usize,
    plans_rebuilt: usize,
    plans_patched: usize,
    rebuilt_fraction: f64,
    max_root_l1: f64,
    refresh_ms: f64,
    replan_ms: f64,
    full_replan_ms: f64,
    speedup: f64,
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let factor = args.get_f64("scale", if args.flag("full") { 0.5 } else { 0.25 });
    let spec = spec_by_name("synth-arxiv").unwrap().scaled(factor);
    let ds = sbm::generate(&spec, 7);
    let eval = ds.splits.test.clone();
    let seed = args.get_u64("seed", 0);
    let l1_tol = args.get_f64("l1-tol", 0.05) as f32;
    let mut sizes: Vec<usize> = args
        .get("sizes")
        .map(|s| s.split(',').filter_map(|t| t.parse().ok()).collect())
        .unwrap_or_default();
    if sizes.is_empty() {
        sizes = vec![8, 32, 128, 512];
    }

    let p = preset_for(&ds.name);
    let rcfg = RefreshConfig {
        aux_per_output: p.aux_per_output,
        max_outputs_per_batch: p.outputs_per_batch,
        node_budget: p.node_budget,
        l1_tol,
        ..Default::default()
    };
    println!(
        "updates bench: {} nodes, {} outputs, l1_tol {}, deltas {:?}",
        ds.graph.num_nodes(),
        eval.len(),
        l1_tol,
        sizes
    );

    let t0 = Instant::now();
    let baseline =
        DynamicPlanSet::plan_initial(&ds.graph, &eval, rcfg.clone(), &mut Rng::new(seed ^ 0xCAFE));
    let initial_plan_s = t0.elapsed().as_secs_f64();
    println!(
        "initial plan: {} batches in {:.2}s",
        baseline.len(),
        initial_plan_s
    );
    drop(baseline);

    let mut records: Vec<RunRecord> = Vec::new();
    let mut table = Table::new(&[
        "delta edges",
        "touched",
        "roots",
        "rebuilt",
        "patched",
        "frac",
        "refresh (ms)",
        "full replan (ms)",
        "speedup",
    ]);
    for &edges in &sizes {
        // fresh state per size so runs are independent and comparable
        let mut set = DynamicPlanSet::plan_initial(
            &ds.graph,
            &eval,
            rcfg.clone(),
            &mut Rng::new(seed ^ 0xCAFE),
        );
        let mut dg = DynamicGraph::new(ds.graph.clone());
        let delta = synth_delta_stream(
            &ds.graph,
            &eval,
            1,
            edges,
            0,
            0,
            ds.num_classes,
            seed ^ edges as u64,
        )
        .pop()
        .unwrap();
        let applied = dg.apply(&delta).map_err(anyhow::Error::msg)?;
        let t = Instant::now();
        let r = set.apply_delta(&dg, &applied);
        let incremental_s = t.elapsed().as_secs_f64();

        // full-replan baseline on the post-delta graph
        let t = Instant::now();
        let full = DynamicPlanSet::plan_initial(
            &dg,
            &eval,
            rcfg.clone(),
            &mut Rng::new(seed ^ 0xCAFE),
        );
        let full_replan_s = t.elapsed().as_secs_f64();
        assert!(!full.is_empty());

        let rec = RunRecord {
            delta_edges: edges,
            touched: r.touched_nodes,
            roots_refreshed: r.roots_refreshed,
            plans_total: r.plans_total,
            plans_rebuilt: r.plans_rebuilt,
            plans_patched: r.plans_patched,
            rebuilt_fraction: r.rebuilt_fraction(),
            max_root_l1: r.max_root_l1 as f64,
            refresh_ms: r.refresh_s * 1e3,
            replan_ms: r.replan_s * 1e3,
            full_replan_ms: full_replan_s * 1e3,
            speedup: full_replan_s / incremental_s.max(1e-9),
        };
        table.row(&[
            format!("{edges}"),
            format!("{}", rec.touched),
            format!("{}", rec.roots_refreshed),
            format!("{}", rec.plans_rebuilt),
            format!("{}", rec.plans_patched),
            format!("{:.3}", rec.rebuilt_fraction),
            format!("{:.2}", rec.refresh_ms + rec.replan_ms),
            format!("{:.2}", rec.full_replan_ms),
            format!("{:.1}x", rec.speedup),
        ]);
        records.push(rec);
    }

    let smallest = &records[0];
    if smallest.rebuilt_fraction >= 1.0 {
        eprintln!(
            "WARNING: smallest delta ({} edges) rebuilt every plan \
             ({:.2}) — incremental maintenance is not paying off",
            smallest.delta_edges, smallest.rebuilt_fraction
        );
    }

    // ---- p99 under churn: quiesced vs zero-quiesce vs no churn ----
    struct ChurnRecord {
        mode: &'static str,
        qps: f64,
        p50_ms: f64,
        p99_ms: f64,
        max_ms: f64,
        updates_applied: usize,
        final_epoch: u64,
        snapshot_swaps: u64,
    }
    let churn_queries = args.get_usize("churn-queries", 600);
    let churn_batches = args.get_usize("churn-batches", 3);
    let churn_edges = args.get_usize("churn-edges", 64);
    let scfg = ServeConfig {
        shards: 2,
        clients: args.get_usize("churn-clients", 24),
        queries: churn_queries,
        flush_window: Duration::from_micros(args.get_u64("window-us", 500)),
        results_cache_bytes: 1 << 20,
        seed,
        ..Default::default()
    };
    let ucfg = UpdateConfig { l1_tol };
    let churn_deltas = synth_delta_stream(
        &ds.graph,
        &eval,
        churn_batches,
        churn_edges,
        0,
        0,
        ds.num_classes,
        seed ^ 0xC0,
    );
    // identical deltas fire at identical completed-count triggers in
    // both modes; only *where* the apply runs differs
    type Trigger = (u64, ibmb::graph::GraphDelta);
    let triggered = |deltas: &[ibmb::graph::GraphDelta]| -> Vec<Trigger> {
        deltas
            .iter()
            .enumerate()
            .map(|(i, d)| {
                (
                    (churn_queries * (i + 1) / (deltas.len() + 1)) as u64,
                    d.clone(),
                )
            })
            .collect()
    };
    let mut churn_records: Vec<ChurnRecord> = Vec::new();
    let mut churn_table = Table::new(&[
        "mode",
        "qps",
        "p50 (ms)",
        "p99 (ms)",
        "max (ms)",
        "updates",
        "epoch",
    ]);
    for mode in ["baseline", "quiesced", "zero_quiesce"] {
        let mut session =
            DynamicServeSession::prepare(ds.clone(), &eval, &scfg, &ucfg);
        let churn = match mode {
            "baseline" => None,
            "quiesced" => Some(Churn::Inline {
                applier: &mut session.applier,
                deltas: triggered(&churn_deltas),
            }),
            _ => Some(Churn::Background {
                applier: &mut session.applier,
                deltas: triggered(&churn_deltas),
            }),
        };
        let (r, ups) = serve_with_churn(
            &mut session.setup,
            &eval,
            Skew::Zipf(1.2),
            &scfg,
            &mut session.memo,
            churn,
        )?;
        assert_eq!(
            r.executed_queries + r.cache_hits,
            churn_queries as u64,
            "{mode}: dropped queries"
        );
        churn_table.row(&[
            mode.to_string(),
            format!("{:.0}", r.qps),
            format!("{:.2}", r.p50_ms),
            format!("{:.2}", r.p99_ms),
            format!("{:.2}", r.max_ms),
            format!("{}", ups.len()),
            format!("{}", r.final_epoch),
        ]);
        churn_records.push(ChurnRecord {
            mode,
            qps: r.qps,
            p50_ms: r.p50_ms,
            p99_ms: r.p99_ms,
            max_ms: r.max_ms,
            updates_applied: ups.len(),
            final_epoch: r.final_epoch,
            snapshot_swaps: r.snapshot_swaps,
        });
    }
    let p99_of = |mode: &str| {
        churn_records
            .iter()
            .find(|r| r.mode == mode)
            .map(|r| r.p99_ms)
            .unwrap_or(0.0)
    };
    let (base_p99, zero_p99, quiesced_p99) = (
        p99_of("baseline"),
        p99_of("zero_quiesce"),
        p99_of("quiesced"),
    );
    println!(
        "churn p99: baseline {base_p99:.2}ms, zero-quiesce {zero_p99:.2}ms \
         ({:.2}x), quiesced {quiesced_p99:.2}ms ({:.2}x)",
        zero_p99 / base_p99.max(1e-9),
        quiesced_p99 / base_p99.max(1e-9)
    );
    if zero_p99 > 2.0 * base_p99 {
        eprintln!(
            "WARNING: zero-quiesce p99 {zero_p99:.2}ms exceeds 2x the \
             no-churn baseline {base_p99:.2}ms"
        );
    }

    let json = Json::Obj(BTreeMap::from([
        ("bench".into(), Json::Str("updates".into())),
        ("dataset".into(), Json::Str(ds.name.clone())),
        ("nodes".into(), Json::Num(ds.graph.num_nodes() as f64)),
        ("outputs".into(), Json::Num(eval.len() as f64)),
        ("plans".into(), Json::Num(records[0].plans_total as f64)),
        ("l1_tol".into(), Json::Num(l1_tol as f64)),
        (
            "initial_plan_ms".into(),
            Json::Num(initial_plan_s * 1e3),
        ),
        (
            "runs".into(),
            Json::Arr(
                records
                    .iter()
                    .map(|r| {
                        Json::Obj(BTreeMap::from([
                            (
                                "delta_edges".into(),
                                Json::Num(r.delta_edges as f64),
                            ),
                            ("touched".into(), Json::Num(r.touched as f64)),
                            (
                                "roots_refreshed".into(),
                                Json::Num(r.roots_refreshed as f64),
                            ),
                            (
                                "plans_total".into(),
                                Json::Num(r.plans_total as f64),
                            ),
                            (
                                "plans_rebuilt".into(),
                                Json::Num(r.plans_rebuilt as f64),
                            ),
                            (
                                "plans_patched".into(),
                                Json::Num(r.plans_patched as f64),
                            ),
                            (
                                "rebuilt_fraction".into(),
                                Json::Num(r.rebuilt_fraction),
                            ),
                            ("max_root_l1".into(), Json::Num(r.max_root_l1)),
                            ("refresh_ms".into(), Json::Num(r.refresh_ms)),
                            ("replan_ms".into(), Json::Num(r.replan_ms)),
                            (
                                "full_replan_ms".into(),
                                Json::Num(r.full_replan_ms),
                            ),
                            ("speedup".into(), Json::Num(r.speedup)),
                        ]))
                    })
                    .collect(),
            ),
        ),
    ]));
    let json = match json {
        Json::Obj(mut m) => {
            m.insert(
                "churn".into(),
                Json::Arr(
                    churn_records
                        .iter()
                        .map(|r| {
                            Json::Obj(BTreeMap::from([
                                (
                                    "mode".into(),
                                    Json::Str(r.mode.to_string()),
                                ),
                                ("qps".into(), Json::Num(r.qps)),
                                ("p50_ms".into(), Json::Num(r.p50_ms)),
                                ("p99_ms".into(), Json::Num(r.p99_ms)),
                                ("max_ms".into(), Json::Num(r.max_ms)),
                                (
                                    "updates_applied".into(),
                                    Json::Num(r.updates_applied as f64),
                                ),
                                (
                                    "final_epoch".into(),
                                    Json::Num(r.final_epoch as f64),
                                ),
                                (
                                    "snapshot_swaps".into(),
                                    Json::Num(r.snapshot_swaps as f64),
                                ),
                            ]))
                        })
                        .collect(),
                ),
            );
            m.insert(
                "churn_queries".into(),
                Json::Num(churn_queries as f64),
            );
            m.insert(
                "churn_batches".into(),
                Json::Num(churn_batches as f64),
            );
            m.insert("churn_edges".into(), Json::Num(churn_edges as f64));
            Json::Obj(m)
        }
        other => other,
    };
    let out_path = args.get_or("out", "BENCH_updates.json").to_string();
    std::fs::write(&out_path, to_string(&json))?;
    println!("wrote {out_path}");
    table.print("updates — incremental refresh vs full replan by delta size");
    churn_table
        .print("updates — p99 under churn: quiesced vs zero-quiesce swap");
    Ok(())
}
