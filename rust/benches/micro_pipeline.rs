//! Micro-benchmarks of the L3 hot paths (the §Perf profile):
//! push-PPR throughput, batch-wise power-iteration PPR, METIS
//! partitioning, and the plan→materialize→consume ring pipeline at
//! configurable prefetch depth, plus a single fused train step per
//! bucket when artifacts are present.
//!
//! The pipeline section sweeps ring depths (default `1,2,4`; override
//! with `--depths 1,8`), reporting batches/sec, arena allocations, and
//! overlap ratio, and writes the machine-readable `BENCH_pipeline.json`
//! so the perf trajectory is recorded across PRs.

use std::collections::BTreeMap;

use ibmb::batching::{BatchArena, BatchCache, BatchGenerator, DenseBatch, NodeWiseIbmb};
use ibmb::bench_harness::{secs, time_it, Table};
use ibmb::config::preset_for;
use ibmb::datasets::{sbm, spec_by_name};
use ibmb::exec::{ExecScratch, Executor, ExecutorKind, PlanView};
use ibmb::serve::reference_artifact;
use ibmb::partition::metis::{partition_graph, MetisConfig};
use ibmb::pipeline::run_prefetched;
use ibmb::ppr::power::{batch_ppr, PowerConfig};
use ibmb::ppr::push::{push_ppr, PushConfig, PushWorkspace};
use ibmb::runtime::ModelState;
use ibmb::util::json::{to_string, Json};
use ibmb::util::{Rng, Timer};

/// One measured ring configuration.
struct DepthResult {
    depth: usize,
    batches_per_s: f64,
    overlap_ratio: f64,
    /// Total fresh buffer allocations over warmup + measured epochs.
    allocations: usize,
    /// Allocations during the measured (post-warmup) epochs — the
    /// steady-state zero-allocation invariant.
    steady_allocations: usize,
}

fn main() -> anyhow::Result<()> {
    let args = ibmb::cli::Args::parse(
        std::env::args().skip(1).filter(|a| a != "--bench"),
    );
    let mut depths: Vec<usize> = args
        .get("depths")
        .map(|s| s.split(',').filter_map(|t| t.parse().ok()).collect())
        .unwrap_or_else(|| vec![1, 2, 4]);
    if depths.is_empty() {
        eprintln!("--depths parsed to nothing; falling back to 1,2,4");
        depths = vec![1, 2, 4];
    }

    let spec = spec_by_name("synth-arxiv").unwrap().scaled(0.5);
    let ds = sbm::generate(&spec, 1);
    let n = ds.graph.num_nodes();
    println!("dataset: {} nodes, {} edges", n, ds.graph.num_edges());
    let mut table = Table::new(&["hot path", "mean (s)", "p95 (s)", "throughput"]);

    // push-PPR per root
    let mut ws = PushWorkspace::new(n);
    let mut root = 0u32;
    let s = time_it(20, 200, || {
        root = (root + 37) % n as u32;
        push_ppr(&ds.graph, root, &PushConfig::default(), &mut ws)
    });
    table.row(&[
        "push PPR / root".into(),
        secs(s.mean),
        secs(s.p95),
        format!("{:.0} roots/s", 1.0 / s.mean),
    ]);

    // batch-wise power PPR
    let roots: Vec<u32> = ds.splits.train[..128.min(ds.splits.train.len())].to_vec();
    let s = time_it(2, 10, || {
        batch_ppr(&ds.graph, &roots, &PowerConfig::default())
    });
    table.row(&[
        "power PPR / 128-root batch".into(),
        secs(s.mean),
        secs(s.p95),
        format!("{:.1} batches/s", 1.0 / s.mean),
    ]);

    // METIS partition
    let mut rng = Rng::new(2);
    let s = time_it(1, 5, || {
        partition_graph(&ds.graph, 16, &MetisConfig::default(), &mut rng)
    });
    table.row(&[
        "METIS 16-way".into(),
        secs(s.mean),
        secs(s.p95),
        format!("{:.2} Medges/s", ds.graph.num_edges() as f64 / s.mean / 1e6),
    ]);

    // ---- plan once, then stream through the ring at each depth ----
    let p = preset_for(&ds.name);
    let mut gen = NodeWiseIbmb {
        aux_per_output: p.aux_per_output,
        max_outputs_per_batch: p.outputs_per_batch,
        node_budget: p.node_budget,
        ..Default::default()
    };
    let cache = BatchCache::build(&gen.plan(&ds, &ds.splits.train, &mut rng));
    let bucket = cache
        .max_batch_nodes()
        .next_power_of_two()
        .clamp(256, 2048);
    let order: Vec<usize> = (0..cache.len()).collect();
    let epochs = 4usize;
    let mut depth_results: Vec<DepthResult> = Vec::new();
    for &depth in &depths {
        let depth = depth.max(1);
        let mut arena = BatchArena::new(ds.feat_dim);
        // consume = touch every materialized feature row (a stand-in
        // for the host->device copy the execute thread performs)
        let run_epoch = |arena: &mut BatchArena| {
            let ring = arena.acquire_many(bucket, depth);
            let (stats, ring) = run_prefetched(
                &order,
                ring,
                |i, buf| cache.materialize_into(&ds, i, buf),
                |_, buf| {
                    let sum: f32 =
                        buf.x[..buf.num_real * buf.feat].iter().sum();
                    std::hint::black_box(sum);
                },
            );
            arena.release_many(ring);
            stats
        };
        run_epoch(&mut arena); // warmup: populates the arena pools
        let warm_allocs = arena.allocations();
        let t = Timer::start();
        let mut overlap = 0.0;
        for _ in 0..epochs {
            overlap = run_epoch(&mut arena).overlap_ratio();
        }
        let elapsed = t.elapsed_s();
        let total_batches = epochs * cache.len();
        let result = DepthResult {
            depth,
            batches_per_s: total_batches as f64 / elapsed,
            overlap_ratio: overlap,
            allocations: arena.allocations(),
            steady_allocations: arena.allocations() - warm_allocs,
        };
        table.row(&[
            format!("ring depth {depth} (n{bucket})"),
            secs(elapsed / total_batches as f64),
            "-".into(),
            format!(
                "{:.0} batches/s, {} allocs ({} steady), overlap {:.2}",
                result.batches_per_s,
                result.allocations,
                result.steady_allocations,
                result.overlap_ratio
            ),
        ]);
        depth_results.push(result);
    }

    // ---- forward-stage throughput per execution backend ----
    // Features are pre-gathered outside the timed region, so the series
    // isolates exactly what `--executor` swaps: the per-batch forward.
    struct ForwardResult {
        executor: &'static str,
        batches_per_s: f64,
        speedup_vs_reference: f64,
    }
    let meta = reference_artifact("gcn", ds.feat_dim, ds.num_classes, 32, 2, 2, bucket);
    let state = ModelState::init(&meta, 7);
    let feats: Vec<Vec<f32>> = (0..cache.len())
        .map(|i| {
            let nodes = cache.batch_nodes(i);
            let mut x = vec![0.0f32; nodes.len() * ds.feat_dim];
            for (j, &u) in nodes.iter().enumerate() {
                ds.node_features_into(
                    u,
                    &mut x[j * ds.feat_dim..(j + 1) * ds.feat_dim],
                );
            }
            x
        })
        .collect();
    let mut fwd_results: Vec<ForwardResult> = Vec::new();
    let fwd_epochs = 3usize;
    for kind in [
        ExecutorKind::Reference,
        ExecutorKind::Blocked,
        ExecutorKind::BlockedF16,
    ] {
        let exec = kind.build()?;
        let mut scratch = ExecScratch::new();
        let mut logits = Vec::new();
        let epoch = |scratch: &mut ExecScratch, logits: &mut Vec<f32>| {
            for i in 0..cache.len() {
                let view = PlanView {
                    n: cache.batch_nodes(i).len(),
                    edge_src: cache.edge_src_of(i),
                    edge_dst: cache.edge_dst_of(i),
                    weights: cache.edge_weights_of(i),
                };
                exec.forward(&meta, &state, &view, &feats[i], scratch, logits);
                std::hint::black_box(logits.last().copied());
            }
        };
        epoch(&mut scratch, &mut logits); // warmup: scratch high-water
        let t = Timer::start();
        for _ in 0..fwd_epochs {
            epoch(&mut scratch, &mut logits);
        }
        let elapsed = t.elapsed_s();
        let batches_per_s = (fwd_epochs * cache.len()) as f64 / elapsed;
        let speedup_vs_reference = fwd_results
            .first()
            .map_or(1.0, |r| batches_per_s / r.batches_per_s);
        table.row(&[
            format!("forward ({})", kind.name()),
            secs(elapsed / (fwd_epochs * cache.len()) as f64),
            "-".into(),
            format!(
                "{batches_per_s:.0} batches/s ({speedup_vs_reference:.2}x vs reference)"
            ),
        ]);
        fwd_results.push(ForwardResult {
            executor: kind.name(),
            batches_per_s,
            speedup_vs_reference,
        });
    }

    // machine-readable record for the perf trajectory
    let json = Json::Obj(BTreeMap::from([
        ("bench".into(), Json::Str("micro_pipeline".into())),
        ("dataset".into(), Json::Str(ds.name.clone())),
        ("nodes".into(), Json::Num(n as f64)),
        ("batches".into(), Json::Num(cache.len() as f64)),
        ("bucket".into(), Json::Num(bucket as f64)),
        ("epochs".into(), Json::Num(epochs as f64)),
        (
            "depths".into(),
            Json::Arr(
                depth_results
                    .iter()
                    .map(|r| {
                        Json::Obj(BTreeMap::from([
                            ("depth".into(), Json::Num(r.depth as f64)),
                            (
                                "batches_per_s".into(),
                                Json::Num(r.batches_per_s),
                            ),
                            (
                                "overlap_ratio".into(),
                                Json::Num(r.overlap_ratio),
                            ),
                            (
                                "allocations".into(),
                                Json::Num(r.allocations as f64),
                            ),
                            (
                                "steady_allocations".into(),
                                Json::Num(r.steady_allocations as f64),
                            ),
                        ]))
                    })
                    .collect(),
            ),
        ),
        (
            "forward".into(),
            Json::Arr(
                fwd_results
                    .iter()
                    .map(|r| {
                        Json::Obj(BTreeMap::from([
                            (
                                "executor".into(),
                                Json::Str(r.executor.into()),
                            ),
                            (
                                "batches_per_s".into(),
                                Json::Num(r.batches_per_s),
                            ),
                            (
                                "speedup_vs_reference".into(),
                                Json::Num(r.speedup_vs_reference),
                            ),
                        ]))
                    })
                    .collect(),
            ),
        ),
    ]));
    let out_path = args.get_or("out", "BENCH_pipeline.json").to_string();
    std::fs::write(&out_path, to_string(&json))?;
    println!("wrote {out_path}");

    // fused train step per bucket (needs artifacts)
    match ibmb::experiments::runner::Env::load() {
        Ok(mut env) => {
            for bucket in env.rt.manifest.buckets("gcn", "train") {
                let meta = env
                    .rt
                    .manifest
                    .find("gcn", "train", bucket)
                    .unwrap()
                    .clone();
                env.rt.executable(&meta.id)?;
                let mut state = ModelState::init(&meta, 3);
                let mut dense = DenseBatch::zeros(meta.n_pad, meta.feat);
                // a bucket-sized batch (budget-matched generator)
                let mut bgen = NodeWiseIbmb {
                    aux_per_output: p.aux_per_output,
                    max_outputs_per_batch: bucket / 8,
                    node_budget: bucket,
                    ..Default::default()
                };
                let bcache = BatchCache::build(&bgen.plan(
                    &ds,
                    &ds.splits.train,
                    &mut rng,
                ));
                bcache.materialize_into(&ds, 0, &mut dense);
                let s = time_it(2, 10, || {
                    env.rt
                        .train_step(&meta, &mut state, &dense, 1e-3, 1)
                        .unwrap()
                });
                table.row(&[
                    format!("fused train step n{bucket}"),
                    secs(s.mean),
                    secs(s.p95),
                    format!("{:.1} steps/s", 1.0 / s.mean),
                ]);
            }
        }
        Err(e) => eprintln!("skipping PJRT micro-bench: {e:#}"),
    }

    table.print("micro_pipeline — L3 hot paths");
    Ok(())
}
