//! Micro-benchmarks of the L3 hot paths (the §Perf profile):
//! push-PPR throughput, batch-wise power-iteration PPR, METIS
//! partitioning, densification, the prefetch-overlap ratio, and a
//! single fused train step per bucket.

use ibmb::batching::{BatchCache, BatchGenerator, DenseBatch, NodeWiseIbmb};
use ibmb::bench_harness::{secs, time_it, Table};
use ibmb::config::preset_for;
use ibmb::datasets::{sbm, spec_by_name};
use ibmb::partition::metis::{partition_graph, MetisConfig};
use ibmb::ppr::power::{batch_ppr, PowerConfig};
use ibmb::ppr::push::{push_ppr, PushConfig, PushWorkspace};
use ibmb::runtime::ModelState;
use ibmb::util::Rng;

fn main() -> anyhow::Result<()> {
    let spec = spec_by_name("synth-arxiv").unwrap().scaled(0.5);
    let ds = sbm::generate(&spec, 1);
    let n = ds.graph.num_nodes();
    println!("dataset: {} nodes, {} edges", n, ds.graph.num_edges());
    let mut table = Table::new(&["hot path", "mean (s)", "p95 (s)", "throughput"]);

    // push-PPR per root
    let mut ws = PushWorkspace::new(n);
    let mut root = 0u32;
    let s = time_it(20, 200, || {
        root = (root + 37) % n as u32;
        push_ppr(&ds.graph, root, &PushConfig::default(), &mut ws)
    });
    table.row(&[
        "push PPR / root".into(),
        secs(s.mean),
        secs(s.p95),
        format!("{:.0} roots/s", 1.0 / s.mean),
    ]);

    // batch-wise power PPR
    let roots: Vec<u32> = ds.splits.train[..128.min(ds.splits.train.len())].to_vec();
    let s = time_it(2, 10, || {
        batch_ppr(&ds.graph, &roots, &PowerConfig::default())
    });
    table.row(&[
        "power PPR / 128-root batch".into(),
        secs(s.mean),
        secs(s.p95),
        format!("{:.1} batches/s", 1.0 / s.mean),
    ]);

    // METIS partition
    let mut rng = Rng::new(2);
    let s = time_it(1, 5, || {
        partition_graph(&ds.graph, 16, &MetisConfig::default(), &mut rng)
    });
    table.row(&[
        "METIS 16-way".into(),
        secs(s.mean),
        secs(s.p95),
        format!("{:.2} Medges/s", ds.graph.num_edges() as f64 / s.mean / 1e6),
    ]);

    // densification
    let p = preset_for(&ds.name);
    let mut gen = NodeWiseIbmb {
        aux_per_output: p.aux_per_output,
        max_outputs_per_batch: p.outputs_per_batch,
        node_budget: p.node_budget,
        ..Default::default()
    };
    let cache = BatchCache::build(&gen.generate(&ds, &ds.splits.train, &mut rng));
    let bucket = cache
        .max_batch_nodes()
        .next_power_of_two()
        .clamp(256, 2048);
    let mut dense = DenseBatch::zeros(bucket, ds.feat_dim);
    let mut i = 0;
    let s = time_it(5, 100, || {
        cache.densify_into(&ds, i % cache.len(), &mut dense);
        i += 1;
    });
    table.row(&[
        format!("densify into n{bucket}"),
        secs(s.mean),
        secs(s.p95),
        format!("{:.0} batches/s", 1.0 / s.mean),
    ]);

    // fused train step per bucket (needs artifacts)
    match ibmb::experiments::runner::Env::load() {
        Ok(mut env) => {
            for bucket in env.rt.manifest.buckets("gcn", "train") {
                let meta = env
                    .rt
                    .manifest
                    .find("gcn", "train", bucket)
                    .unwrap()
                    .clone();
                env.rt.executable(&meta.id)?;
                let mut state = ModelState::init(&meta, 3);
                let mut dense = DenseBatch::zeros(meta.n_pad, meta.feat);
                // a bucket-sized batch (budget-matched generator)
                let mut bgen = NodeWiseIbmb {
                    aux_per_output: p.aux_per_output,
                    max_outputs_per_batch: bucket / 8,
                    node_budget: bucket,
                    ..Default::default()
                };
                let bcache = BatchCache::build(&bgen.generate(
                    &ds,
                    &ds.splits.train,
                    &mut rng,
                ));
                bcache.densify_into(&ds, 0, &mut dense);
                let s = time_it(2, 10, || {
                    env.rt
                        .train_step(&meta, &mut state, &dense, 1e-3, 1)
                        .unwrap()
                });
                table.row(&[
                    format!("fused train step n{bucket}"),
                    secs(s.mean),
                    secs(s.p95),
                    format!("{:.1} steps/s", 1.0 / s.mean),
                ]);
            }
        }
        Err(e) => eprintln!("skipping PJRT micro-bench: {e:#}"),
    }

    table.print("micro_pipeline — L3 hot paths");
    Ok(())
}
