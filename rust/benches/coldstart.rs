//! Cold-start benchmark: time-to-first-answer from the
//! content-addressed plan store vs a monolithic container load
//! (DESIGN.md §14). The monolithic baseline deserializes every plan
//! before the first query can run; the store path opens the manifest
//! (O(plans) metadata) and faults exactly one blob. Also measures the
//! structural-sharing save: after a small CoW patch, an incremental
//! save appends only the changed buckets, and the byte ratio vs a full
//! save is reported per corpus size. Emits `BENCH_coldstart.json`.
//!
//! Run: `cargo bench --bench coldstart`
//! (`--sizes 1000,10000,100000 --budget BYTES --seed N` to override).

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Instant;

use ibmb::batching::{cache_io, BatchCache, BatchPlan, CowCache};
use ibmb::bench_harness::Table;
use ibmb::cli::Args;
use ibmb::store::{PlanResidency, PlanStore};
use ibmb::util::json::{to_string, Json};
use ibmb::util::Rng;

/// Synthetic plan corpus: shapes drawn from the same range the IBMB
/// planners produce for the synth datasets, node ids disjoint per plan
/// so every bucket has a distinct content hash.
fn synth_plans(n: usize, rng: &mut Rng) -> Vec<BatchPlan> {
    (0..n)
        .map(|i| {
            let n_nodes = 24 + rng.next_below(17);
            let nodes: Vec<u32> =
                (0..n_nodes).map(|k| (i * 64 + k) as u32).collect();
            let num_outputs = 1 + rng.next_below(4.min(n_nodes));
            let n_edges = n_nodes * 2;
            let edges: Vec<(u32, u32)> = (0..n_edges)
                .map(|_| {
                    (
                        rng.next_below(n_nodes) as u32,
                        rng.next_below(n_nodes) as u32,
                    )
                })
                .collect();
            let weights: Vec<f32> =
                (0..n_edges).map(|_| rng.uniform(0.01, 1.0)).collect();
            BatchPlan {
                nodes,
                num_outputs,
                edges,
                weights,
            }
        })
        .collect()
}

struct RunRecord {
    plans: usize,
    v3_load_s: f64,
    cas_ttfa_s: f64,
    speedup: f64,
    full_save_bytes: u64,
    incr_save_bytes: u64,
    incr_ratio: f64,
    resident_bytes: usize,
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let seed = args.get_u64("seed", 0);
    let budget = args.get_usize("budget", 32 << 10);
    let mut sizes: Vec<usize> = args
        .get("sizes")
        .map(|s| s.split(',').filter_map(|t| t.parse().ok()).collect())
        .unwrap_or_default();
    if sizes.is_empty() {
        sizes = vec![1_000, 10_000, 100_000];
    }
    let reps = args.get_usize("reps", 3);
    println!("coldstart bench: corpora {sizes:?}, residency budget {budget} B");

    let scratch = |name: String| -> PathBuf {
        std::env::temp_dir().join(format!("{}-{}", name, std::process::id()))
    };

    let mut records: Vec<RunRecord> = Vec::new();
    let mut table = Table::new(&[
        "plans",
        "mono load (ms)",
        "cas ttfa (ms)",
        "speedup",
        "full save (KiB)",
        "incr save (KiB)",
        "incr ratio",
        "resident (KiB)",
    ]);
    for &n in &sizes {
        let mut rng = Rng::new(seed ^ n as u64);
        let plans = synth_plans(n, &mut rng);
        let cow = CowCache::from_plans(&plans);

        // -- monolithic baseline: full-container load is the TTFA floor
        let mono_path = scratch(format!("ibmb-coldstart-mono-{n}.ibmb"));
        cache_io::save(&BatchCache::build(&plans), &mono_path)?;
        let mut v3_load_s = f64::INFINITY;
        for _ in 0..reps {
            let t = Instant::now();
            let loaded = cache_io::load(&mono_path)?;
            v3_load_s = v3_load_s.min(t.elapsed().as_secs_f64());
            assert_eq!(loaded.len(), n, "monolithic container dropped plans");
        }
        std::fs::remove_file(&mono_path).ok();

        // -- populate the store: full save, then a small CoW patch
        //    saved incrementally (the structural-sharing byte claim)
        let dir = scratch(format!("ibmb-coldstart-store-{n}"));
        std::fs::remove_dir_all(&dir).ok();
        let epochs = vec![0u64; n];
        let router: Vec<u64> = (0..n as u64).map(|p| p << 32).collect();
        let (full_stats, incr_stats) = {
            let store = PlanStore::open(&dir)?;
            let full = store.save_full(&cow, &epochs, 0, &router)?;
            let patched = max_patch(n).min(n);
            let mut fresh = Rng::new(seed ^ 0xD1FF ^ n as u64);
            let stride = (n / patched).max(1);
            let repl: Vec<(u32, ibmb::batching::PlanPayload)> = (0..patched)
                .map(|k| {
                    let plan = synth_plans(1, &mut fresh).pop().unwrap();
                    (
                        ((k * stride) % n) as u32,
                        ibmb::batching::PlanPayload::from_plan(&plan),
                    )
                })
                .collect();
            let next = cow.with_patched(repl);
            // patched buckets advance to epoch 1
            let mut epochs2 = epochs.clone();
            for i in 0..n {
                if !std::sync::Arc::ptr_eq(&cow.payload(i), &next.payload(i)) {
                    epochs2[i] = 1;
                }
            }
            let incr =
                store.save_incremental(&cow, &next, &epochs2, 1, &[])?;
            (full, incr)
        };

        // -- store cold start: open (manifest + delta fold only) and
        //    fault a single plan — that is the first answer's data path
        let mut cas_ttfa_s = f64::INFINITY;
        for rep in 0..reps {
            let t = Instant::now();
            let store = PlanStore::open(&dir)?;
            let (payload, bytes) = store.fault(rep % n)?;
            cas_ttfa_s = cas_ttfa_s.min(t.elapsed().as_secs_f64());
            assert!(bytes > 0, "fault read no bytes");
            assert!(!payload.nodes.is_empty(), "fault decoded empty plan");
        }

        // -- residency: a byte-budget LRU touring the corpus stays
        //    within budget no matter how many plans it faults
        let store = PlanStore::open(&dir)?;
        let mut res = PlanResidency::new(budget);
        for k in 0..256usize.min(n) {
            let pid = (k * 97) % n;
            res.get_or_fault(pid as u32, &store)?;
        }
        let resident_bytes = res.resident_bytes();
        assert!(
            resident_bytes <= budget,
            "residency {resident_bytes} B exceeds budget {budget} B"
        );
        drop(store);
        std::fs::remove_dir_all(&dir).ok();

        let rec = RunRecord {
            plans: n,
            v3_load_s,
            cas_ttfa_s,
            speedup: v3_load_s / cas_ttfa_s.max(1e-9),
            full_save_bytes: full_stats.bytes_written,
            incr_save_bytes: incr_stats.bytes_written,
            incr_ratio: incr_stats.bytes_written as f64
                / (full_stats.bytes_written as f64).max(1.0),
            resident_bytes,
        };
        table.row(&[
            format!("{n}"),
            format!("{:.2}", rec.v3_load_s * 1e3),
            format!("{:.3}", rec.cas_ttfa_s * 1e3),
            format!("{:.0}x", rec.speedup),
            format!("{}", rec.full_save_bytes / 1024),
            format!("{}", rec.incr_save_bytes / 1024),
            format!("{:.4}", rec.incr_ratio),
            format!("{}", rec.resident_bytes / 1024),
        ]);
        records.push(rec);
    }

    let largest = records.last().unwrap();
    if largest.speedup < 10.0 {
        eprintln!(
            "WARNING: cold-start speedup {:.1}x at {} plans is below the \
             10x target — faulted TTFA is not beating the monolithic load",
            largest.speedup, largest.plans
        );
    }
    if largest.incr_ratio >= 0.1 {
        eprintln!(
            "WARNING: incremental save wrote {:.1}% of the full-save bytes \
             — structural sharing is not paying off",
            largest.incr_ratio * 100.0
        );
    }

    let json = Json::Obj(BTreeMap::from([
        ("bench".into(), Json::Str("coldstart".into())),
        ("dataset".into(), Json::Str("synthetic".into())),
        ("lru_budget_bytes".into(), Json::Num(budget as f64)),
        (
            "runs".into(),
            Json::Arr(
                records
                    .iter()
                    .map(|r| {
                        Json::Obj(BTreeMap::from([
                            ("plans".into(), Json::Num(r.plans as f64)),
                            ("v3_load_s".into(), Json::Num(r.v3_load_s)),
                            ("cas_ttfa_s".into(), Json::Num(r.cas_ttfa_s)),
                            ("speedup".into(), Json::Num(r.speedup)),
                            (
                                "full_save_bytes".into(),
                                Json::Num(r.full_save_bytes as f64),
                            ),
                            (
                                "incr_save_bytes".into(),
                                Json::Num(r.incr_save_bytes as f64),
                            ),
                            ("incr_ratio".into(), Json::Num(r.incr_ratio)),
                            (
                                "resident_bytes".into(),
                                Json::Num(r.resident_bytes as f64),
                            ),
                        ]))
                    })
                    .collect(),
            ),
        ),
    ]));
    let out_path = args.get_or("out", "BENCH_coldstart.json").to_string();
    std::fs::write(&out_path, to_string(&json))?;
    println!("wrote {out_path}");
    table.print("coldstart — monolithic full load vs content-addressed fault");
    Ok(())
}

/// Patch size for the incremental-save measurement: 0.5% of the
/// corpus, at least one plan.
fn max_patch(n: usize) -> usize {
    (n / 200).max(1)
}
