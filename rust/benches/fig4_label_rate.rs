//! Bench target regenerating the paper's fig4 (see DESIGN.md §5).
//! Smoke scale by default; pass `--full` for the EXPERIMENTS.md scale.
fn main() -> anyhow::Result<()> {
    let args = ibmb::cli::Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let scale = ibmb::config::ExpScale::from_args(
        &args.flags.iter().map(|f| format!("--{f}")).collect::<Vec<_>>(),
    );
    ibmb::experiments::fig4::run(&scale, &args)
}
