//! Online serving benchmark: qps, tail latency, and coalescing factor
//! vs. shard count under uniform and zipf-skewed closed-loop load,
//! plus one memo-enabled run for the cache hit rate. Emits the
//! machine-readable `BENCH_serving.json` so the serving perf
//! trajectory is recorded across PRs (paper §5: inference is the
//! headline — precomputed influence batches are reusable at query
//! time; coalescing and memoization multiply that reuse).
//!
//! Run: `cargo bench --bench serving` (`--full` for the bigger graph;
//! `--shards 1,2,4 --queries N --clients N` to override).

use std::collections::BTreeMap;
use std::time::Duration;

use ibmb::bench_harness::Table;
use ibmb::cli::Args;
use ibmb::datasets::{sbm, spec_by_name};
use ibmb::serve::{self, ServeConfig, Skew};
use ibmb::util::json::{to_string, Json};

struct RunRecord {
    label: String,
    skew: String,
    shards: usize,
    memo_bytes: usize,
    qps: f64,
    p50_ms: f64,
    p99_ms: f64,
    coalescing: f64,
    hit_rate: f64,
    executions: u64,
    shard_balance: f64,
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let factor = args.get_f64("scale", if args.flag("full") { 0.5 } else { 0.25 });
    let spec = spec_by_name("synth-arxiv").unwrap().scaled(factor);
    let ds = sbm::generate(&spec, 7);
    let eval = ds.splits.test.clone();
    let queries = args.get_usize("queries", 1200);
    let clients = args.get_usize("clients", 48);
    let shard_counts: Vec<usize> = args
        .get("shards")
        .map(|s| s.split(',').filter_map(|t| t.parse().ok()).collect())
        .unwrap_or_else(|| vec![1, 2, 4]);
    let base = ServeConfig {
        queries,
        clients,
        flush_window: Duration::from_micros(args.get_u64("window-us", 800)),
        max_coalesce: args.get_usize("coalesce", 16),
        seed: args.get_u64("seed", 0),
        ..Default::default()
    };
    println!(
        "serving bench: {} nodes, {} eval nodes, {} queries, {} clients",
        ds.graph.num_nodes(),
        eval.len(),
        queries,
        clients
    );
    let mut setup = serve::prepare(ds.clone(), &eval, &base);
    let plans = setup.state().cache.len();
    println!("{} plans cached, bucket n{}", plans, setup.state().meta.n_pad);

    let mut records: Vec<RunRecord> = Vec::new();
    let mut table = Table::new(&[
        "config",
        "qps",
        "p50 (ms)",
        "p99 (ms)",
        "coalesce",
        "hit rate",
        "balance",
    ]);
    let skews = [Skew::Uniform, Skew::Zipf(args.get_f64("zipf-s", 1.2))];
    for skew in skews {
        for &shards in &shard_counts {
            let cfg = ServeConfig {
                shards,
                ..base.clone()
            };
            let r =
                serve::serve_closed_loop(&mut setup, &eval, skew, &cfg)?;
            let label = format!("{} s{}", skew.label(), shards);
            table.row(&[
                label.clone(),
                format!("{:.0}", r.qps),
                format!("{:.2}", r.p50_ms),
                format!("{:.2}", r.p99_ms),
                format!("{:.2}", r.coalescing_factor),
                format!("{:.2}", r.cache_hit_rate),
                format!("{:.2}", r.shard_balance),
            ]);
            records.push(RunRecord {
                label,
                skew: skew.label(),
                shards,
                memo_bytes: 0,
                qps: r.qps,
                p50_ms: r.p50_ms,
                p99_ms: r.p99_ms,
                coalescing: r.coalescing_factor,
                hit_rate: r.cache_hit_rate,
                executions: r.executions,
                shard_balance: r.shard_balance,
            });
        }
    }
    // one memo-enabled run: repeat traffic collapses to cache hits
    let memo_bytes = args.get_usize("results-cache-bytes", 4 << 20);
    let cfg = ServeConfig {
        shards: 2,
        results_cache_bytes: memo_bytes,
        results_ttl: Some(Duration::from_millis(
            args.get_u64("results-ttl-ms", 50),
        )),
        ..base.clone()
    };
    let skew = Skew::Zipf(args.get_f64("zipf-s", 1.2));
    let r = serve::serve_closed_loop(&mut setup, &eval, skew, &cfg)?;
    let label = format!("{} s2 +memo", skew.label());
    table.row(&[
        label.clone(),
        format!("{:.0}", r.qps),
        format!("{:.2}", r.p50_ms),
        format!("{:.2}", r.p99_ms),
        format!("{:.2}", r.coalescing_factor),
        format!("{:.2}", r.cache_hit_rate),
        format!("{:.2}", r.shard_balance),
    ]);
    records.push(RunRecord {
        label,
        skew: skew.label(),
        shards: 2,
        memo_bytes,
        qps: r.qps,
        p50_ms: r.p50_ms,
        p99_ms: r.p99_ms,
        coalescing: r.coalescing_factor,
        hit_rate: r.cache_hit_rate,
        executions: r.executions,
        shard_balance: r.shard_balance,
    });

    let zipf_coalesce = records
        .iter()
        .filter(|r| r.skew.starts_with("zipf") && r.memo_bytes == 0)
        .map(|r| r.coalescing)
        .fold(0.0f64, f64::max);
    if zipf_coalesce <= 1.0 {
        eprintln!(
            "WARNING: zipf coalescing factor {zipf_coalesce:.2} <= 1 — \
             raise --clients or --window-us"
        );
    }

    let json = Json::Obj(BTreeMap::from([
        ("bench".into(), Json::Str("serving".into())),
        ("dataset".into(), Json::Str(ds.name.clone())),
        ("nodes".into(), Json::Num(ds.graph.num_nodes() as f64)),
        ("eval_nodes".into(), Json::Num(eval.len() as f64)),
        ("plans".into(), Json::Num(plans as f64)),
        ("queries".into(), Json::Num(queries as f64)),
        ("clients".into(), Json::Num(clients as f64)),
        (
            "window_us".into(),
            Json::Num(base.flush_window.as_micros() as f64),
        ),
        (
            "runs".into(),
            Json::Arr(
                records
                    .iter()
                    .map(|r| {
                        Json::Obj(BTreeMap::from([
                            ("label".into(), Json::Str(r.label.clone())),
                            ("skew".into(), Json::Str(r.skew.clone())),
                            ("shards".into(), Json::Num(r.shards as f64)),
                            (
                                "memo_bytes".into(),
                                Json::Num(r.memo_bytes as f64),
                            ),
                            ("qps".into(), Json::Num(r.qps)),
                            ("p50_ms".into(), Json::Num(r.p50_ms)),
                            ("p99_ms".into(), Json::Num(r.p99_ms)),
                            (
                                "coalescing_factor".into(),
                                Json::Num(r.coalescing),
                            ),
                            ("hit_rate".into(), Json::Num(r.hit_rate)),
                            (
                                "executions".into(),
                                Json::Num(r.executions as f64),
                            ),
                            (
                                "shard_balance".into(),
                                Json::Num(r.shard_balance),
                            ),
                        ]))
                    })
                    .collect(),
            ),
        ),
    ]));
    let out_path = args.get_or("out", "BENCH_serving.json").to_string();
    std::fs::write(&out_path, to_string(&json))?;
    println!("wrote {out_path}");
    table.print("serving — qps / tail latency / coalescing vs shards");
    Ok(())
}
