//! Online serving benchmark: qps, tail latency, and coalescing factor
//! vs. shard count under uniform and zipf-skewed closed-loop load,
//! plus one memo-enabled run for the cache hit rate. Emits the
//! machine-readable `BENCH_serving.json` so the serving perf
//! trajectory is recorded across PRs (paper §5: inference is the
//! headline — precomputed influence batches are reusable at query
//! time; coalescing and memoization multiply that reuse).
//!
//! The second act is goodput under overload: the closed-loop sweep
//! calibrates capacity, then an open-loop series drives 1x–10x that
//! offered load with a deadline and records goodput, shed fraction,
//! and p99 *of admitted queries* per multiplier (uniform + zipf).
//! With the admission gate, goodput should plateau near capacity while
//! shedding absorbs the excess — without it the queue would grow
//! without bound and p99 with it.
//!
//! The third act is shard balance under skew (DESIGN.md §15): zipf-1.2
//! load over 1/2/4 shards with cooperative serving off vs on. Hot-plan
//! skew concentrates work on one shard's queue; stealing + replication
//! should pull the skewed p99 back toward the same configuration's
//! uniform-load p99 (the `p99_vs_uniform` column).
//!
//! Run: `cargo bench --bench serving` (`--full` for the bigger graph;
//! `--shards 1,2,4 --queries N --clients N --deadline-ms F` to
//! override).

use std::collections::BTreeMap;
use std::time::Duration;

use ibmb::bench_harness::Table;
use ibmb::cli::Args;
use ibmb::datasets::{sbm, spec_by_name};
use ibmb::exec::ExecutorKind;
use ibmb::serve::{self, ServeConfig, Skew};
use ibmb::util::json::{to_string, Json};

struct RunRecord {
    label: String,
    skew: String,
    shards: usize,
    memo_bytes: usize,
    qps: f64,
    p50_ms: f64,
    p99_ms: f64,
    coalescing: f64,
    hit_rate: f64,
    executions: u64,
    shard_balance: f64,
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let factor = args.get_f64("scale", if args.flag("full") { 0.5 } else { 0.25 });
    let spec = spec_by_name("synth-arxiv").unwrap().scaled(factor);
    let ds = sbm::generate(&spec, 7);
    let eval = ds.splits.test.clone();
    let queries = args.get_usize("queries", 1200);
    let clients = args.get_usize("clients", 48);
    let shard_counts: Vec<usize> = args
        .get("shards")
        .map(|s| s.split(',').filter_map(|t| t.parse().ok()).collect())
        .unwrap_or_else(|| vec![1, 2, 4]);
    let base = ServeConfig {
        queries,
        clients,
        flush_window: Duration::from_micros(args.get_u64("window-us", 800)),
        max_coalesce: args.get_usize("coalesce", 16),
        seed: args.get_u64("seed", 0),
        ..Default::default()
    };
    println!(
        "serving bench: {} nodes, {} eval nodes, {} queries, {} clients",
        ds.graph.num_nodes(),
        eval.len(),
        queries,
        clients
    );
    let mut setup = serve::prepare(ds.clone(), &eval, &base);
    let plans = setup.state().cache.len();
    println!("{} plans cached, bucket n{}", plans, setup.state().meta.n_pad);

    let mut records: Vec<RunRecord> = Vec::new();
    let mut table = Table::new(&[
        "config",
        "qps",
        "p50 (ms)",
        "p99 (ms)",
        "coalesce",
        "hit rate",
        "balance",
    ]);
    let skews = [Skew::Uniform, Skew::Zipf(args.get_f64("zipf-s", 1.2))];
    for skew in skews {
        for &shards in &shard_counts {
            let cfg = ServeConfig {
                shards,
                ..base.clone()
            };
            let r =
                serve::serve_closed_loop(&mut setup, &eval, skew, &cfg)?;
            let label = format!("{} s{}", skew.label(), shards);
            table.row(&[
                label.clone(),
                format!("{:.0}", r.qps),
                format!("{:.2}", r.p50_ms),
                format!("{:.2}", r.p99_ms),
                format!("{:.2}", r.coalescing_factor),
                format!("{:.2}", r.cache_hit_rate),
                format!("{:.2}", r.shard_balance),
            ]);
            records.push(RunRecord {
                label,
                skew: skew.label(),
                shards,
                memo_bytes: 0,
                qps: r.qps,
                p50_ms: r.p50_ms,
                p99_ms: r.p99_ms,
                coalescing: r.coalescing_factor,
                hit_rate: r.cache_hit_rate,
                executions: r.executions,
                shard_balance: r.shard_balance,
            });
        }
    }
    // one memo-enabled run: repeat traffic collapses to cache hits
    let memo_bytes = args.get_usize("results-cache-bytes", 4 << 20);
    let cfg = ServeConfig {
        shards: 2,
        results_cache_bytes: memo_bytes,
        results_ttl: Some(Duration::from_millis(
            args.get_u64("results-ttl-ms", 50),
        )),
        ..base.clone()
    };
    let skew = Skew::Zipf(args.get_f64("zipf-s", 1.2));
    let r = serve::serve_closed_loop(&mut setup, &eval, skew, &cfg)?;
    let label = format!("{} s2 +memo", skew.label());
    table.row(&[
        label.clone(),
        format!("{:.0}", r.qps),
        format!("{:.2}", r.p50_ms),
        format!("{:.2}", r.p99_ms),
        format!("{:.2}", r.coalescing_factor),
        format!("{:.2}", r.cache_hit_rate),
        format!("{:.2}", r.shard_balance),
    ]);
    records.push(RunRecord {
        label,
        skew: skew.label(),
        shards: 2,
        memo_bytes,
        qps: r.qps,
        p50_ms: r.p50_ms,
        p99_ms: r.p99_ms,
        coalescing: r.coalescing_factor,
        hit_rate: r.cache_hit_rate,
        executions: r.executions,
        shard_balance: r.shard_balance,
    });

    let zipf_coalesce = records
        .iter()
        .filter(|r| r.skew.starts_with("zipf") && r.memo_bytes == 0)
        .map(|r| r.coalescing)
        .fold(0.0f64, f64::max);
    if zipf_coalesce <= 1.0 {
        eprintln!(
            "WARNING: zipf coalescing factor {zipf_coalesce:.2} <= 1 — \
             raise --clients or --window-us"
        );
    }

    // ---- goodput under overload ------------------------------------
    // capacity = best memo-less closed-loop throughput observed above;
    // the open-loop series offers multiples of it under a deadline
    let capacity_qps = records
        .iter()
        .filter(|r| r.memo_bytes == 0)
        .map(|r| r.qps)
        .fold(0.0f64, f64::max)
        .max(1.0);
    let deadline_ms = args.get_f64("deadline-ms", 25.0);
    let overload_queries = args.get_usize("overload-queries", queries.min(800));
    println!(
        "overload series: capacity {capacity_qps:.0} qps, deadline \
         {deadline_ms:.1}ms, {overload_queries} queries per point"
    );
    let mut otable = Table::new(&[
        "config",
        "offered (qps)",
        "goodput (qps)",
        "shed frac",
        "p99 adm (ms)",
        "degraded",
    ]);
    struct OverloadRecord {
        skew: String,
        offered_x: f64,
        offered_qps: f64,
        goodput_qps: f64,
        shed_fraction: f64,
        p99_admitted_ms: f64,
        admitted: u64,
        shed: u64,
        shed_rate_limited: u64,
        degraded: u64,
    }
    let mut overload: Vec<OverloadRecord> = Vec::new();
    for skew in skews {
        for mult in [1.0, 2.0, 4.0, 6.0, 8.0, 10.0] {
            let cfg = ServeConfig {
                shards: 2,
                offered_qps: capacity_qps * mult,
                deadline: Some(Duration::from_secs_f64(deadline_ms * 1e-3)),
                tenants: 4,
                queries: overload_queries,
                results_cache_bytes: memo_bytes,
                results_ttl: Some(Duration::from_millis(
                    args.get_u64("results-ttl-ms", 50),
                )),
                ..base.clone()
            };
            let r = serve::serve_closed_loop(&mut setup, &eval, skew, &cfg)?;
            otable.row(&[
                format!("{} {mult:.0}x", skew.label()),
                format!("{:.0}", r.offered_qps),
                format!("{:.0}", r.goodput_qps),
                format!("{:.3}", r.shed_fraction),
                format!("{:.2}", r.p99_ms),
                format!("{}", r.degraded),
            ]);
            overload.push(OverloadRecord {
                skew: skew.label(),
                offered_x: mult,
                offered_qps: r.offered_qps,
                goodput_qps: r.goodput_qps,
                shed_fraction: r.shed_fraction,
                p99_admitted_ms: r.p99_ms,
                admitted: r.admitted,
                shed: r.shed,
                shed_rate_limited: r.shed_rate_limited,
                degraded: r.degraded,
            });
        }
    }
    let peak_goodput = overload
        .iter()
        .map(|o| o.goodput_qps)
        .fold(0.0f64, f64::max);
    if peak_goodput < capacity_qps * 0.5 {
        eprintln!(
            "WARNING: peak goodput {peak_goodput:.0} qps < half of \
             calibrated capacity {capacity_qps:.0} — deadline too tight?"
        );
    }

    // ---- executor before/after pair --------------------------------
    // One pinned configuration (2 shards, zipf, no memo) with only the
    // forward backend swapped: the serve-level latency win of the
    // blocked executor over the scalar reference.
    struct ExecRecord {
        executor: &'static str,
        qps: f64,
        p50_ms: f64,
        p99_ms: f64,
    }
    let mut exec_records: Vec<ExecRecord> = Vec::new();
    let mut etable = Table::new(&["executor", "qps", "p50 (ms)", "p99 (ms)"]);
    for kind in [ExecutorKind::Reference, ExecutorKind::Blocked] {
        let cfg = ServeConfig {
            shards: 2,
            executor: kind,
            ..base.clone()
        };
        let r = serve::serve_closed_loop(&mut setup, &eval, skew, &cfg)?;
        etable.row(&[
            kind.name().into(),
            format!("{:.0}", r.qps),
            format!("{:.2}", r.p50_ms),
            format!("{:.2}", r.p99_ms),
        ]);
        exec_records.push(ExecRecord {
            executor: kind.name(),
            qps: r.qps,
            p50_ms: r.p50_ms,
            p99_ms: r.p99_ms,
        });
    }

    // ---- shard balance under skew: cooperative off vs on -----------
    // per (shards, mode): one uniform run for the baseline p99, one
    // zipf run for the skewed p99 — cooperation should shrink the gap
    struct BalanceRecord {
        skew: String,
        shards: usize,
        cooperative: bool,
        qps: f64,
        p99_ms: f64,
        uniform_p99_ms: f64,
        p99_vs_uniform: f64,
        shard_balance: f64,
        steals: u64,
        replica_dispatches: u64,
        shared_row_bytes: u64,
    }
    let zipf_s = args.get_f64("zipf-s", 1.2);
    let steal_window = args.get_usize("steal-window", 2);
    let mut balance_records: Vec<BalanceRecord> = Vec::new();
    let mut btable = Table::new(&[
        "config",
        "qps",
        "p99 (ms)",
        "p99/unif",
        "balance",
        "steals",
        "replicas",
        "shared KiB",
    ]);
    for &shards in &shard_counts {
        for cooperative in [false, true] {
            let cfg = ServeConfig {
                shards,
                cooperative,
                steal_window,
                ..base.clone()
            };
            let u = serve::serve_closed_loop(
                &mut setup,
                &eval,
                Skew::Uniform,
                &cfg,
            )?;
            let r = serve::serve_closed_loop(
                &mut setup,
                &eval,
                Skew::Zipf(zipf_s),
                &cfg,
            )?;
            let ratio = r.p99_ms / u.p99_ms.max(1e-9);
            btable.row(&[
                format!(
                    "zipf({zipf_s:.1}) s{shards}{}",
                    if cooperative { " +coop" } else { "" }
                ),
                format!("{:.0}", r.qps),
                format!("{:.2}", r.p99_ms),
                format!("{ratio:.2}x"),
                format!("{:.2}", r.shard_balance),
                format!("{}", r.steals),
                format!("{}", r.replica_dispatches),
                format!("{}", r.shared_row_bytes / 1024),
            ]);
            balance_records.push(BalanceRecord {
                skew: format!("zipf({zipf_s:.2})"),
                shards,
                cooperative,
                qps: r.qps,
                p99_ms: r.p99_ms,
                uniform_p99_ms: u.p99_ms,
                p99_vs_uniform: ratio,
                shard_balance: r.shard_balance,
                steals: r.steals,
                replica_dispatches: r.replica_dispatches,
                shared_row_bytes: r.shared_row_bytes,
            });
        }
    }
    let best = balance_records
        .iter()
        .filter(|b| b.cooperative && b.shards > 1)
        .map(|b| b.p99_vs_uniform)
        .fold(f64::INFINITY, f64::min);
    if best.is_finite() && best > 1.5 {
        eprintln!(
            "WARNING: best cooperative zipf p99 is {best:.2}x the \
             uniform p99 (target ~1.5x) — skew still unbalanced"
        );
    }

    let json = Json::Obj(BTreeMap::from([
        ("bench".into(), Json::Str("serving".into())),
        ("dataset".into(), Json::Str(ds.name.clone())),
        ("nodes".into(), Json::Num(ds.graph.num_nodes() as f64)),
        ("eval_nodes".into(), Json::Num(eval.len() as f64)),
        ("plans".into(), Json::Num(plans as f64)),
        ("queries".into(), Json::Num(queries as f64)),
        ("clients".into(), Json::Num(clients as f64)),
        (
            "window_us".into(),
            Json::Num(base.flush_window.as_micros() as f64),
        ),
        ("capacity_qps".into(), Json::Num(capacity_qps)),
        ("deadline_ms".into(), Json::Num(deadline_ms)),
        (
            "executor_p99".into(),
            Json::Arr(
                exec_records
                    .iter()
                    .map(|r| {
                        Json::Obj(BTreeMap::from([
                            (
                                "executor".into(),
                                Json::Str(r.executor.into()),
                            ),
                            ("qps".into(), Json::Num(r.qps)),
                            ("p50_ms".into(), Json::Num(r.p50_ms)),
                            ("p99_ms".into(), Json::Num(r.p99_ms)),
                        ]))
                    })
                    .collect(),
            ),
        ),
        (
            "overload".into(),
            Json::Arr(
                overload
                    .iter()
                    .map(|o| {
                        Json::Obj(BTreeMap::from([
                            ("skew".into(), Json::Str(o.skew.clone())),
                            ("offered_x".into(), Json::Num(o.offered_x)),
                            ("offered_qps".into(), Json::Num(o.offered_qps)),
                            ("goodput_qps".into(), Json::Num(o.goodput_qps)),
                            (
                                "shed_fraction".into(),
                                Json::Num(o.shed_fraction),
                            ),
                            (
                                "p99_admitted_ms".into(),
                                Json::Num(o.p99_admitted_ms),
                            ),
                            (
                                "admitted".into(),
                                Json::Num(o.admitted as f64),
                            ),
                            ("shed".into(), Json::Num(o.shed as f64)),
                            (
                                "shed_rate_limited".into(),
                                Json::Num(o.shed_rate_limited as f64),
                            ),
                            (
                                "degraded".into(),
                                Json::Num(o.degraded as f64),
                            ),
                        ]))
                    })
                    .collect(),
            ),
        ),
        (
            "balance".into(),
            Json::Arr(
                balance_records
                    .iter()
                    .map(|b| {
                        Json::Obj(BTreeMap::from([
                            ("skew".into(), Json::Str(b.skew.clone())),
                            ("shards".into(), Json::Num(b.shards as f64)),
                            (
                                "cooperative".into(),
                                Json::Bool(b.cooperative),
                            ),
                            ("qps".into(), Json::Num(b.qps)),
                            ("p99_ms".into(), Json::Num(b.p99_ms)),
                            (
                                "uniform_p99_ms".into(),
                                Json::Num(b.uniform_p99_ms),
                            ),
                            (
                                "p99_vs_uniform".into(),
                                Json::Num(b.p99_vs_uniform),
                            ),
                            (
                                "shard_balance".into(),
                                Json::Num(b.shard_balance),
                            ),
                            ("steals".into(), Json::Num(b.steals as f64)),
                            (
                                "replica_dispatches".into(),
                                Json::Num(b.replica_dispatches as f64),
                            ),
                            (
                                "shared_row_bytes".into(),
                                Json::Num(b.shared_row_bytes as f64),
                            ),
                        ]))
                    })
                    .collect(),
            ),
        ),
        (
            "runs".into(),
            Json::Arr(
                records
                    .iter()
                    .map(|r| {
                        Json::Obj(BTreeMap::from([
                            ("label".into(), Json::Str(r.label.clone())),
                            ("skew".into(), Json::Str(r.skew.clone())),
                            ("shards".into(), Json::Num(r.shards as f64)),
                            (
                                "memo_bytes".into(),
                                Json::Num(r.memo_bytes as f64),
                            ),
                            ("qps".into(), Json::Num(r.qps)),
                            ("p50_ms".into(), Json::Num(r.p50_ms)),
                            ("p99_ms".into(), Json::Num(r.p99_ms)),
                            (
                                "coalescing_factor".into(),
                                Json::Num(r.coalescing),
                            ),
                            ("hit_rate".into(), Json::Num(r.hit_rate)),
                            (
                                "executions".into(),
                                Json::Num(r.executions as f64),
                            ),
                            (
                                "shard_balance".into(),
                                Json::Num(r.shard_balance),
                            ),
                        ]))
                    })
                    .collect(),
            ),
        ),
    ]));
    let out_path = args.get_or("out", "BENCH_serving.json").to_string();
    std::fs::write(&out_path, to_string(&json))?;
    println!("wrote {out_path}");
    table.print("serving — qps / tail latency / coalescing vs shards");
    otable.print("serving — goodput under overload (1x–10x capacity)");
    etable.print("serving — p99 by forward backend (pinned load)");
    btable.print("serving — shard balance under zipf, cooperative off/on");
    Ok(())
}
