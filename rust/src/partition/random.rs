//! Fixed random output-node batches — the ablation baseline of Fig. 6
//! ("Fixed random") and Fig. 2 ("IBMB, rand batch."): auxiliary nodes
//! are still selected by influence, but output nodes are grouped with
//! no locality, destroying the neighborhood-sharing synergy.

use super::Partition;
use crate::util::Rng;

/// Shuffle `out_nodes` and chop into `num_batches` nearly-equal batches.
pub fn random_partition(
    out_nodes: &[u32],
    num_batches: usize,
    rng: &mut Rng,
) -> Partition {
    let b = num_batches.clamp(1, out_nodes.len().max(1));
    let mut ids = out_nodes.to_vec();
    rng.shuffle(&mut ids);
    let mut out = Vec::with_capacity(b);
    let base = ids.len() / b;
    let extra = ids.len() % b;
    let mut pos = 0;
    for i in 0..b {
        let sz = base + usize::from(i < extra);
        if sz == 0 {
            continue;
        }
        out.push(ids[pos..pos + sz].to_vec());
        pos += sz;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::validate_partition;

    #[test]
    fn partitions_exactly() {
        let nodes: Vec<u32> = (0..103).collect();
        let mut rng = Rng::new(1);
        let p = random_partition(&nodes, 8, &mut rng);
        assert_eq!(p.len(), 8);
        assert!(validate_partition(&p, &nodes).is_ok());
        // sizes differ by at most one
        let sizes: Vec<usize> = p.iter().map(|b| b.len()).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn more_batches_than_nodes_degrades_gracefully() {
        let nodes: Vec<u32> = (0..3).collect();
        let mut rng = Rng::new(2);
        let p = random_partition(&nodes, 10, &mut rng);
        assert_eq!(p.len(), 3);
        assert!(validate_partition(&p, &nodes).is_ok());
    }

    #[test]
    fn deterministic_by_seed() {
        let nodes: Vec<u32> = (0..50).collect();
        let p1 = random_partition(&nodes, 5, &mut Rng::new(7));
        let p2 = random_partition(&nodes, 5, &mut Rng::new(7));
        assert_eq!(p1, p2);
    }
}
