//! Output-node partitioning (paper §3.2).
//!
//! Splits the output nodes (train/val/test ids) into batches so that
//! nodes sharing influential neighborhoods land together:
//!
//! * [`pprdist`] — greedy PPR-distance merging: scan PPR entries by
//!   descending magnitude, union the batches of the two endpoints while
//!   respecting the size cap (the paper's streaming-friendly variant).
//! * [`metis`] — a from-scratch multilevel k-way graph partitioner
//!   (heavy-edge-matching coarsening → greedy growth → boundary
//!   Kernighan–Lin refinement), standing in for libmetis. Used by
//!   batch-wise IBMB and the Cluster-GCN baseline.
//! * [`random`] — fixed random batches (the "Fixed random" ablation of
//!   Fig. 6 and the `IBMB, rand batch.` line of Fig. 2).

pub mod metis;
pub mod pprdist;
pub mod random;

/// A partition of output nodes into batches (global node ids).
pub type Partition = Vec<Vec<u32>>;

/// Balance = max batch size / ideal size (1.0 is perfect).
pub fn balance(p: &Partition) -> f64 {
    let total: usize = p.iter().map(|b| b.len()).sum();
    if p.is_empty() || total == 0 {
        return 1.0;
    }
    let max = p.iter().map(|b| b.len()).max().unwrap();
    max as f64 / (total as f64 / p.len() as f64)
}

/// Asserts structural sanity: disjoint, covering `expected` ids exactly.
pub fn validate_partition(p: &Partition, expected: &[u32]) -> Result<(), String> {
    let mut seen = std::collections::HashSet::new();
    for b in p {
        if b.is_empty() {
            return Err("empty batch".into());
        }
        for &u in b {
            if !seen.insert(u) {
                return Err(format!("node {u} in two batches"));
            }
        }
    }
    if seen.len() != expected.len() {
        return Err(format!(
            "covers {} of {} nodes",
            seen.len(),
            expected.len()
        ));
    }
    for &u in expected {
        if !seen.contains(&u) {
            return Err(format!("node {u} missing"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balance_of_even_partition_is_one() {
        let p: Partition = vec![vec![0, 1], vec![2, 3]];
        assert!((balance(&p) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn validate_catches_overlap_and_misses() {
        let ok: Partition = vec![vec![0, 1], vec![2]];
        assert!(validate_partition(&ok, &[0, 1, 2]).is_ok());
        let dup: Partition = vec![vec![0, 1], vec![1]];
        assert!(validate_partition(&dup, &[0, 1]).is_err());
        let missing: Partition = vec![vec![0]];
        assert!(validate_partition(&missing, &[0, 1]).is_err());
    }
}
