//! PPR-distance greedy output-node partitioning (paper §3.2,
//! "Distance-based partitioning").
//!
//! Start with every output node in its own batch; sort all PPR entries
//! between pairs of *output* nodes by descending magnitude; scan and
//! merge the two endpoints' batches whenever the union stays below the
//! size cap `B`; finally merge leftover small batches randomly. Because
//! auxiliary selection already computed node-wise PPR per output node,
//! the same sparse vectors feed this step for free.

use super::Partition;
use crate::ppr::push::SparsePpr;
use crate::util::Rng;

/// Union-find with size tracking.
struct Dsu {
    parent: Vec<u32>,
    size: Vec<u32>,
}

impl Dsu {
    fn new(n: usize) -> Dsu {
        Dsu {
            parent: (0..n as u32).collect(),
            size: vec![1; n],
        }
    }
    fn find(&mut self, mut x: u32) -> u32 {
        while self.parent[x as usize] != x {
            self.parent[x as usize] = self.parent[self.parent[x as usize] as usize];
            x = self.parent[x as usize];
        }
        x
    }
    /// Merge if the union stays within `cap`; returns success.
    fn union_capped(&mut self, a: u32, b: u32, cap: usize) -> bool {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra == rb {
            return true;
        }
        let total = self.size[ra as usize] + self.size[rb as usize];
        if total as usize > cap {
            return false;
        }
        let (big, small) = if self.size[ra as usize] >= self.size[rb as usize] {
            (ra, rb)
        } else {
            (rb, ra)
        };
        self.parent[small as usize] = big;
        self.size[big as usize] = total;
        true
    }
}

/// Greedy PPR-magnitude merging.
///
/// * `out_nodes` — the output nodes to partition (global ids).
/// * `pprs[i]` — sparse PPR vector rooted at `out_nodes[i]`.
/// * `max_batch` — size cap `B` per batch (output nodes per batch).
pub fn ppr_distance_partition(
    out_nodes: &[u32],
    pprs: &[SparsePpr],
    max_batch: usize,
    rng: &mut Rng,
) -> Partition {
    assert_eq!(out_nodes.len(), pprs.len());
    let n_out = out_nodes.len();
    if n_out == 0 {
        return Vec::new();
    }
    let cap = max_batch.max(1);

    // map global id -> output index
    let max_id = out_nodes.iter().copied().max().unwrap_or(0) as usize;
    let mut out_idx = vec![u32::MAX; max_id + 1];
    for (i, &u) in out_nodes.iter().enumerate() {
        out_idx[u as usize] = i as u32;
    }

    // collect (score, i, j) for PPR entries between output nodes
    let mut entries: Vec<(f32, u32, u32)> = Vec::new();
    for (i, ppr) in pprs.iter().enumerate() {
        for (v, s) in ppr.nodes.iter().zip(&ppr.scores) {
            let vi = *v as usize;
            if vi <= max_id {
                let j = out_idx[vi];
                if j != u32::MAX && j != i as u32 {
                    entries.push((*s, i as u32, j));
                }
            }
        }
    }
    // descending magnitude, deterministic tie-break
    entries.sort_by(|a, b| {
        b.0.partial_cmp(&a.0)
            .unwrap()
            .then(a.1.cmp(&b.1))
            .then(a.2.cmp(&b.2))
    });

    let mut dsu = Dsu::new(n_out);
    for &(_, i, j) in &entries {
        dsu.union_capped(i, j, cap);
    }

    // collect batches by root
    let mut by_root: std::collections::HashMap<u32, Vec<u32>> =
        std::collections::HashMap::new();
    for i in 0..n_out as u32 {
        let r = dsu.find(i);
        by_root.entry(r).or_default().push(i);
    }
    let mut batches: Vec<Vec<u32>> = by_root.into_values().collect();
    // deterministic order before random merging
    batches.sort_by_key(|b| b[0]);

    // randomly merge small leftovers while staying under the cap
    // (paper: "Afterwards we randomly merge any small leftover batches.")
    let mut order: Vec<usize> = (0..batches.len()).collect();
    rng.shuffle(&mut order);
    let mut merged: Vec<Vec<u32>> = Vec::new();
    for idx in order {
        let b = std::mem::take(&mut batches[idx]);
        if b.is_empty() {
            continue;
        }
        if let Some(last) = merged.last_mut() {
            if last.len() + b.len() <= cap && last.len() < cap / 2 {
                last.extend(b);
                continue;
            }
        }
        merged.push(b);
    }

    merged
        .into_iter()
        .map(|b| b.into_iter().map(|i| out_nodes[i as usize]).collect())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{sbm, DatasetSpec};
    use crate::partition::validate_partition;
    use crate::ppr::push::{push_ppr, PushConfig, PushWorkspace};

    fn pprs_for(
        g: &crate::graph::CsrGraph,
        out: &[u32],
    ) -> Vec<SparsePpr> {
        let mut ws = PushWorkspace::new(g.num_nodes());
        out.iter()
            .map(|&u| push_ppr(g, u, &PushConfig::default(), &mut ws))
            .collect()
    }

    #[test]
    fn produces_valid_partition_within_cap() {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 20);
        let out = ds.splits.train.clone();
        let pprs = pprs_for(&ds.graph, &out);
        let mut rng = Rng::new(0);
        let p = ppr_distance_partition(&out, &pprs, 40, &mut rng);
        assert!(validate_partition(&p, &out).is_ok());
        assert!(p.iter().all(|b| b.len() <= 40));
    }

    #[test]
    fn groups_community_members_together() {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 21);
        let out = ds.splits.train.clone();
        let pprs = pprs_for(&ds.graph, &out);
        let mut rng = Rng::new(1);
        let p = ppr_distance_partition(&out, &pprs, 60, &mut rng);
        // same-label fraction within batches beats the global rate
        let global: f64 = {
            let h = ds.label_histogram(&out);
            let tot: f64 = h.iter().sum();
            h.iter().map(|c| (c / tot) * (c / tot)).sum()
        };
        let mut same = 0.0;
        let mut tot = 0.0;
        for b in &p {
            if b.len() < 2 {
                continue;
            }
            let h = ds.label_histogram(b);
            let s: f64 = h.iter().sum();
            same += h.iter().map(|c| c * (c - 1.0)).sum::<f64>();
            tot += s * (s - 1.0);
        }
        let within = same / tot;
        // locality-based batching must concentrate labels vs the global
        // mixing rate (the margin is modest at this tiny scale — random
        // leftover merging dilutes it, as in the paper's algorithm)
        assert!(
            within > global * 1.08,
            "within {within:.3} vs global {global:.3}"
        );
    }

    #[test]
    fn cap_one_gives_singletons() {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 22);
        let out: Vec<u32> = ds.splits.val.clone();
        let pprs = pprs_for(&ds.graph, &out);
        let mut rng = Rng::new(2);
        let p = ppr_distance_partition(&out, &pprs, 1, &mut rng);
        assert_eq!(p.len(), out.len());
    }

    #[test]
    fn empty_input() {
        let mut rng = Rng::new(3);
        let p = ppr_distance_partition(&[], &[], 10, &mut rng);
        assert!(p.is_empty());
    }
}
