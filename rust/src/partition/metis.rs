//! From-scratch multilevel k-way graph partitioner (METIS stand-in).
//!
//! The paper uses METIS [Karypis & Kumar 1998] for graph-partition-based
//! output-node batching and for Cluster-GCN. libmetis is unavailable
//! offline, so we implement the same multilevel scheme:
//!
//! 1. **Coarsening** — repeated heavy-edge matching collapses matched
//!    pairs until the graph is small (`<= coarse_target` nodes).
//! 2. **Initial partition** — greedy BFS region growing on the coarsest
//!    graph into `k` balanced parts.
//! 3. **Uncoarsening + refinement** — project the partition back level
//!    by level, running boundary Kernighan–Lin style moves that reduce
//!    edge cut subject to a balance constraint.

use crate::graph::CsrGraph;
use crate::util::Rng;

/// Partitioner knobs.
#[derive(Debug, Clone, Copy)]
pub struct MetisConfig {
    /// Stop coarsening when at most this many (weighted) nodes remain,
    /// scaled by `k`.
    pub coarse_factor: usize,
    /// Refinement passes per level.
    pub refine_passes: usize,
    /// Allowed imbalance: max part weight <= (1 + slack) * ideal.
    pub balance_slack: f64,
}

impl Default for MetisConfig {
    fn default() -> Self {
        MetisConfig {
            coarse_factor: 30,
            refine_passes: 4,
            balance_slack: 0.10,
        }
    }
}

/// A coarsening level: weighted graph + mapping to the finer level.
struct Level {
    /// CSR adjacency with edge weights (parallel arrays).
    indptr: Vec<u32>,
    indices: Vec<u32>,
    eweights: Vec<u32>,
    /// Node weights (number of original nodes collapsed).
    nweights: Vec<u32>,
    /// For each finer-level node, its coarse node id (empty at level 0).
    fine_to_coarse: Vec<u32>,
}

impl Level {
    fn n(&self) -> usize {
        self.indptr.len() - 1
    }
    fn neighbors(&self, u: u32) -> impl Iterator<Item = (u32, u32)> + '_ {
        let s = self.indptr[u as usize] as usize;
        let e = self.indptr[u as usize + 1] as usize;
        self.indices[s..e]
            .iter()
            .copied()
            .zip(self.eweights[s..e].iter().copied())
    }
}

fn level_from_graph(g: &CsrGraph) -> Level {
    // drop self loops; unit edge/node weights
    let n = g.num_nodes();
    let mut indptr = vec![0u32; n + 1];
    let mut indices = Vec::with_capacity(g.num_edges());
    for u in 0..n as u32 {
        for &v in g.neighbors(u) {
            if v != u {
                indices.push(v);
            }
        }
        indptr[u as usize + 1] = indices.len() as u32;
    }
    let ew = vec![1u32; indices.len()];
    Level {
        indptr,
        indices,
        eweights: ew,
        nweights: vec![1; n],
        fine_to_coarse: Vec::new(),
    }
}

/// Heavy-edge matching: visit nodes in random order, match each
/// unmatched node to its unmatched neighbor with maximum edge weight.
fn heavy_edge_matching(level: &Level, rng: &mut Rng) -> (Vec<u32>, usize) {
    let n = level.n();
    let mut matched = vec![u32::MAX; n];
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    let mut coarse_n = 0usize;
    for &u in &order {
        if matched[u as usize] != u32::MAX {
            continue;
        }
        let mut best = u;
        let mut best_w = 0u32;
        for (v, w) in level.neighbors(u) {
            if matched[v as usize] == u32::MAX && v != u && w > best_w {
                best = v;
                best_w = w;
            }
        }
        matched[u as usize] = best;
        matched[best as usize] = u;
        coarse_n += 1;
    }
    // assign coarse ids in deterministic fine order
    let mut coarse_id = vec![u32::MAX; n];
    let mut next = 0u32;
    for u in 0..n as u32 {
        if coarse_id[u as usize] == u32::MAX {
            coarse_id[u as usize] = next;
            let m = matched[u as usize];
            if m != u && coarse_id[m as usize] == u32::MAX {
                coarse_id[m as usize] = next;
            }
            next += 1;
        }
    }
    (coarse_id, coarse_n.max(next as usize))
}

/// Contract a level along a matching.
fn contract(level: &Level, coarse_id: &[u32]) -> Level {
    let cn = coarse_id.iter().copied().max().map_or(0, |m| m + 1) as usize;
    let mut nweights = vec![0u32; cn];
    for u in 0..level.n() {
        nweights[coarse_id[u] as usize] += level.nweights[u];
    }
    // accumulate coarse edges via hashmap per row
    let mut rows: Vec<std::collections::HashMap<u32, u32>> =
        vec![std::collections::HashMap::new(); cn];
    for u in 0..level.n() as u32 {
        let cu = coarse_id[u as usize];
        for (v, w) in level.neighbors(u) {
            let cv = coarse_id[v as usize];
            if cu != cv {
                *rows[cu as usize].entry(cv).or_insert(0) += w;
            }
        }
    }
    let mut indptr = vec![0u32; cn + 1];
    let mut indices = Vec::new();
    let mut eweights = Vec::new();
    for (c, row) in rows.iter().enumerate() {
        let mut es: Vec<(u32, u32)> = row.iter().map(|(&v, &w)| (v, w)).collect();
        es.sort_unstable();
        for (v, w) in es {
            indices.push(v);
            eweights.push(w);
        }
        indptr[c + 1] = indices.len() as u32;
    }
    Level {
        indptr,
        indices,
        eweights,
        nweights,
        fine_to_coarse: coarse_id.to_vec(),
    }
}

/// Greedy BFS region growing into `k` parts on the coarsest level.
fn initial_partition(level: &Level, k: usize, rng: &mut Rng) -> Vec<u32> {
    let n = level.n();
    let total_w: u64 = level.nweights.iter().map(|&w| w as u64).sum();
    let ideal = (total_w as f64 / k as f64).ceil() as u64;
    let mut part = vec![u32::MAX; n];
    let mut weights = vec![0u64; k];
    let mut order: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut order);
    let mut oi = 0;
    for p in 0..k as u32 {
        // find an unassigned seed
        while oi < n && part[order[oi] as usize] != u32::MAX {
            oi += 1;
        }
        if oi >= n {
            break;
        }
        let seed = order[oi];
        let mut queue = std::collections::VecDeque::new();
        queue.push_back(seed);
        while let Some(u) = queue.pop_front() {
            if part[u as usize] != u32::MAX {
                continue;
            }
            if weights[p as usize] + level.nweights[u as usize] as u64
                > ideal + 1
            {
                break;
            }
            part[u as usize] = p;
            weights[p as usize] += level.nweights[u as usize] as u64;
            for (v, _) in level.neighbors(u) {
                if part[v as usize] == u32::MAX {
                    queue.push_back(v);
                }
            }
        }
    }
    // assign stragglers to the lightest part
    for u in 0..n {
        if part[u] == u32::MAX {
            let p = (0..k).min_by_key(|&p| weights[p]).unwrap();
            part[u] = p as u32;
            weights[p] += level.nweights[u] as u64;
        }
    }
    part
}

/// One boundary-refinement pass: move boundary nodes to the neighboring
/// part with maximal gain if balance permits. Returns moves made.
fn refine_pass(
    level: &Level,
    part: &mut [u32],
    k: usize,
    weights: &mut [u64],
    max_w: u64,
) -> usize {
    let n = level.n();
    let mut moves = 0;
    let mut conn = vec![0i64; k];
    for u in 0..n as u32 {
        let pu = part[u as usize];
        // connectivity of u to each part
        let mut touched: Vec<u32> = Vec::new();
        for (v, w) in level.neighbors(u) {
            let pv = part[v as usize];
            if conn[pv as usize] == 0 {
                touched.push(pv);
            }
            conn[pv as usize] += w as i64;
        }
        let mut best_p = pu;
        let mut best_gain = 0i64;
        for &p in &touched {
            if p == pu {
                continue;
            }
            let gain = conn[p as usize] - conn[pu as usize];
            let fits = weights[p as usize]
                + level.nweights[u as usize] as u64
                <= max_w;
            if gain > best_gain && fits {
                best_gain = gain;
                best_p = p;
            }
        }
        for &p in &touched {
            conn[p as usize] = 0;
        }
        if best_p != pu {
            weights[pu as usize] -= level.nweights[u as usize] as u64;
            weights[best_p as usize] += level.nweights[u as usize] as u64;
            part[u as usize] = best_p;
            moves += 1;
        }
    }
    moves
}

/// Edge cut of a node->part assignment on the original graph.
pub fn edge_cut(g: &CsrGraph, part: &[u32]) -> usize {
    let mut cut = 0;
    for u in 0..g.num_nodes() as u32 {
        for &v in g.neighbors(u) {
            if v != u && part[u as usize] != part[v as usize] {
                cut += 1;
            }
        }
    }
    cut / 2
}

/// Multilevel k-way partition of `g`; returns a part id per node.
pub fn partition_graph(
    g: &CsrGraph,
    k: usize,
    cfg: &MetisConfig,
    rng: &mut Rng,
) -> Vec<u32> {
    let k = k.max(1);
    if k == 1 {
        return vec![0; g.num_nodes()];
    }
    // 1. coarsen
    let mut levels = vec![level_from_graph(g)];
    let target = cfg.coarse_factor * k;
    loop {
        let last = levels.last().unwrap();
        if last.n() <= target {
            break;
        }
        let (coarse_id, _) = heavy_edge_matching(last, rng);
        let next = contract(last, &coarse_id);
        if next.n() as f64 > last.n() as f64 * 0.95 {
            // matching stalled (e.g. star graphs) — stop coarsening
            levels.push(next);
            break;
        }
        levels.push(next);
    }

    // 2. initial partition on coarsest
    let coarsest = levels.last().unwrap();
    let mut part = initial_partition(coarsest, k, rng);

    // 3. uncoarsen + refine
    let total_w: u64 = levels[0].nweights.iter().map(|&w| w as u64).sum();
    let max_w = ((total_w as f64 / k as f64) * (1.0 + cfg.balance_slack))
        .ceil() as u64;
    for li in (0..levels.len()).rev() {
        let level = &levels[li];
        let mut weights = vec![0u64; k];
        for u in 0..level.n() {
            weights[part[u] as usize] += level.nweights[u] as u64;
        }
        for _ in 0..cfg.refine_passes {
            if refine_pass(level, &mut part, k, &mut weights, max_w) == 0 {
                break;
            }
        }
        // project to finer level
        if li > 0 {
            let map = &level.fine_to_coarse;
            let finer_n = levels[li - 1].n();
            let mut fine_part = vec![0u32; finer_n];
            for u in 0..finer_n {
                fine_part[u] = part[map[u] as usize];
            }
            part = fine_part;
        }
    }
    part
}

/// Partition *output nodes* via a graph partition: partition the whole
/// graph into `num_batches` parts and group the output nodes by part —
/// exactly how the paper (and Cluster-GCN) derive output batches.
pub fn metis_output_partition(
    g: &CsrGraph,
    out_nodes: &[u32],
    num_batches: usize,
    cfg: &MetisConfig,
    rng: &mut Rng,
) -> super::Partition {
    let part = partition_graph(g, num_batches, cfg, rng);
    let mut batches: Vec<Vec<u32>> = vec![Vec::new(); num_batches];
    for &u in out_nodes {
        batches[part[u as usize] as usize].push(u);
    }
    batches.retain(|b| !b.is_empty());
    batches
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{sbm, DatasetSpec};
    use crate::graph::builder::from_edges;
    use crate::partition::validate_partition;

    #[test]
    fn partitions_are_complete_and_in_range() {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 30);
        let mut rng = Rng::new(0);
        let part = partition_graph(&ds.graph, 6, &MetisConfig::default(), &mut rng);
        assert_eq!(part.len(), ds.graph.num_nodes());
        assert!(part.iter().all(|&p| p < 6));
        let mut sizes = vec![0usize; 6];
        for &p in &part {
            sizes[p as usize] += 1;
        }
        assert!(sizes.iter().all(|&s| s > 0), "{sizes:?}");
    }

    #[test]
    fn balance_is_respected() {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 31);
        let mut rng = Rng::new(1);
        let k = 4;
        let part = partition_graph(&ds.graph, k, &MetisConfig::default(), &mut rng);
        let mut sizes = vec![0usize; k];
        for &p in &part {
            sizes[p as usize] += 1;
        }
        let ideal = ds.graph.num_nodes() as f64 / k as f64;
        for &s in &sizes {
            assert!(
                (s as f64) < ideal * 1.35,
                "part size {s} vs ideal {ideal}"
            );
        }
    }

    #[test]
    fn cut_beats_random_partition() {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 32);
        let g = &ds.graph;
        let mut rng = Rng::new(2);
        let k = 6;
        let part = partition_graph(g, k, &MetisConfig::default(), &mut rng);
        let random: Vec<u32> = (0..g.num_nodes())
            .map(|_| rng.next_below(k) as u32)
            .collect();
        let cut = edge_cut(g, &part);
        let rcut = edge_cut(g, &random);
        assert!(
            (cut as f64) < rcut as f64 * 0.6,
            "cut {cut} vs random {rcut}"
        );
    }

    #[test]
    fn two_cliques_are_separated() {
        // two K5s joined by one edge: the obvious bisection
        let mut edges = Vec::new();
        for a in 0..5u32 {
            for b in (a + 1)..5 {
                edges.push((a, b));
                edges.push((a + 5, b + 5));
            }
        }
        edges.push((0, 5));
        let g = from_edges(10, &edges);
        let mut rng = Rng::new(3);
        let part = partition_graph(&g, 2, &MetisConfig::default(), &mut rng);
        assert_eq!(edge_cut(&g, &part), 1);
    }

    #[test]
    fn output_partition_groups_by_part() {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 33);
        let mut rng = Rng::new(4);
        let out = ds.splits.train.clone();
        let p = metis_output_partition(
            &ds.graph,
            &out,
            5,
            &MetisConfig::default(),
            &mut rng,
        );
        assert!(validate_partition(&p, &out).is_ok());
        assert!(p.len() <= 5);
    }

    #[test]
    fn k_one_is_trivial() {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 34);
        let mut rng = Rng::new(5);
        let part = partition_graph(&ds.graph, 1, &MetisConfig::default(), &mut rng);
        assert!(part.iter().all(|&p| p == 0));
    }
}
