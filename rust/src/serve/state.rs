//! Immutable epoch-swapped serving snapshots (DESIGN.md §11).
//!
//! The zero-quiesce refactor's core type: a [`ServeState`] bundles
//! *everything* the query path reads — graph + dataset view, the
//! copy-on-write plan cache, the warm router index, per-plan epochs,
//! the shard placement, and the executor model — into one immutable,
//! `Arc`-shared snapshot. The control loop and every shard read a
//! snapshot; nothing on the query path ever takes a lock around
//! mutation, because there is no mutation: the background
//! [`super::update::UpdateApplier`] builds the *next* snapshot off to
//! the side (structural sharing keeps that cheap — only touched plan
//! payloads, index tails, and placement tails are new allocations) and
//! publishes it through the [`SwapCell`] with a single pointer swap.
//! In-flight microbatches finish against the snapshot they were
//! admitted under; the epoch-keyed results memo
//! ([`super::results::ResultsCache`]) expires their logits the moment
//! a newer epoch supersedes them.
//!
//! [`SwapCell`] is the `arc_swap`-style cell the crate implements
//! itself (the offline registry has no `arc-swap`): a mutex-guarded
//! `Arc` slot whose critical section is a pointer clone — readers
//! never wait on snapshot *construction*, only on a concurrent
//! pointer-width store, so the swap is effectively wait-free at
//! serving granularity.

use std::sync::{Arc, Mutex, MutexGuard};

use crate::batching::cache::CowCache;
use crate::datasets::Dataset;
use crate::runtime::{ArtifactMeta, ModelState};
use crate::store::PlanStore;

use super::router::{PlanKey, RouterIndex};
use super::shard::Placement;

/// Atomic `Arc<T>` slot: load clones the pointer, store swaps it.
///
/// The mutex only guards the pointer itself — the `T` behind it is
/// immutable by construction — so the critical section is a refcount
/// bump, never a data copy. A poisoned lock (a reader panicking while
/// holding the guard is impossible, but a panicking unwinder mid-store
/// is not) falls back to the inner value: the slot always holds a
/// fully-formed `Arc`, so poisoning cannot expose a torn snapshot.
#[derive(Debug)]
pub struct SwapCell<T> {
    slot: Mutex<Arc<T>>,
}

impl<T> SwapCell<T> {
    /// Cell initially publishing `value`.
    pub fn new(value: Arc<T>) -> SwapCell<T> {
        SwapCell {
            slot: Mutex::new(value),
        }
    }

    fn lock(&self) -> MutexGuard<'_, Arc<T>> {
        self.slot.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Current snapshot (pointer clone; the caller pins the epoch it
    /// loaded for as long as it holds the `Arc`).
    pub fn load(&self) -> Arc<T> {
        self.lock().clone()
    }

    /// Publish a new snapshot. Readers that already loaded keep the
    /// old one alive until they drop it.
    pub fn store(&self, value: Arc<T>) {
        *self.lock() = value;
    }

    /// Publish a new snapshot and return the one it replaced.
    pub fn swap(&self, value: Arc<T>) -> Arc<T> {
        std::mem::replace(&mut *self.lock(), value)
    }
}

/// One immutable serving snapshot: every piece of state the query path
/// reads, consistent at a single graph epoch.
#[derive(Debug)]
pub struct ServeState {
    /// Graph epoch this snapshot reflects (0 = initial deployment).
    pub epoch: u64,
    /// Dataset view: CSR graph, labels, feature epochs.
    pub ds: Arc<Dataset>,
    /// Copy-on-write plan cache (per-plan `Arc` payloads).
    pub cache: Arc<CowCache>,
    /// Warm output-node → (plan, pos) index.
    pub index: Arc<RouterIndex>,
    /// Per-plan epochs, parallel to `cache` (memo freshness keys).
    pub epochs: Arc<Vec<u64>>,
    /// Node/plan → partition-cell placement (shard locality).
    pub placement: Arc<Placement>,
    /// Executor artifact metadata (stable across epochs).
    pub meta: Arc<ArtifactMeta>,
    /// Executor model parameters (stable across epochs).
    pub model: Arc<ModelState>,
    /// Content-addressed plan store backing this deployment, if any.
    /// With an **empty** `cache` this is a *lazy* (store-backed)
    /// snapshot: shards fault payloads on demand through their
    /// residency LRU instead of reading `cache`. With a non-empty
    /// cache the store is a persistence mirror only (incremental
    /// saves), never a read path.
    pub store: Option<Arc<PlanStore>>,
}

impl ServeState {
    /// Store-backed lazy mode: plan payloads live on disk, not in
    /// `cache`, and shards resolve them by faulting.
    pub fn lazy(&self) -> bool {
        self.store.is_some() && self.cache.is_empty()
    }

    /// Number of plans this snapshot serves — cache entries, or in
    /// lazy mode the store manifest's plan count.
    pub fn num_plans(&self) -> usize {
        if self.lazy() {
            self.store.as_ref().map(|s| s.num_plans()).unwrap_or(0)
        } else {
            self.cache.len()
        }
    }

    /// The freshness epoch the results memo keys `key` on: a cached
    /// plan's own epoch (bumps only when *that plan* changed, so memo
    /// value survives unrelated deltas), the snapshot epoch for cold
    /// plans (synthesized from the snapshot graph, so any delta stales
    /// them).
    pub fn plan_epoch(&self, key: &PlanKey) -> u64 {
        match key {
            PlanKey::Cached(pid) => {
                self.epochs.get(*pid as usize).copied().unwrap_or(0)
            }
            PlanKey::Cold(_) => self.epoch,
        }
    }

    /// Cross-component consistency invariants — what "no mixed-epoch
    /// state" means concretely. Checked by the snapshot property test
    /// while swaps race loads, and by `debug_assert` at publish time.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.ds.graph.num_nodes();
        if self.ds.labels.len() != n {
            return Err(format!(
                "epoch {}: {} labels for {} nodes",
                self.epoch,
                self.ds.labels.len(),
                n
            ));
        }
        if self.ds.feat_epoch.len() != n {
            return Err(format!(
                "epoch {}: {} feature epochs for {} nodes",
                self.epoch,
                self.ds.feat_epoch.len(),
                n
            ));
        }
        if self.epochs.len() != self.num_plans() {
            return Err(format!(
                "epoch {}: {} plan epochs for {} plans",
                self.epoch,
                self.epochs.len(),
                self.num_plans()
            ));
        }
        if let Some(&e) = self.epochs.iter().find(|&&e| e > self.epoch) {
            return Err(format!(
                "plan epoch {e} ahead of snapshot epoch {}",
                self.epoch
            ));
        }
        if self.index.len() != n {
            return Err(format!(
                "epoch {}: index over {} nodes, graph has {n}",
                self.epoch,
                self.index.len()
            ));
        }
        if self.placement.num_nodes() != n
            || self.placement.num_plans() != self.num_plans()
        {
            return Err(format!(
                "epoch {}: placement covers {}/{} (nodes/plans), want {n}/{}",
                self.epoch,
                self.placement.num_nodes(),
                self.placement.num_plans(),
                self.num_plans()
            ));
        }
        if self.meta.feat != self.ds.feat_dim {
            return Err(format!(
                "artifact feat {} != dataset feat {}",
                self.meta.feat, self.ds.feat_dim
            ));
        }
        // every warm index entry resolves to a plan that owns the node.
        // In lazy mode payloads are on disk: validate against the
        // store manifest's shape metadata instead of resolving them.
        if self.lazy() {
            let store = self.store.as_ref().unwrap();
            let view = store.view();
            for u in 0..n as u32 {
                if let Some((pid, pos)) = self.index.lookup(u) {
                    let outputs = view
                        .entries
                        .get(pid as usize)
                        .map(|e| e.num_outputs as usize);
                    if outputs.is_none() || pos as usize >= outputs.unwrap() {
                        return Err(format!(
                            "epoch {}: node {u} routed to ({pid}, {pos}) \
                             outside the store manifest",
                            self.epoch
                        ));
                    }
                }
            }
            return Ok(());
        }
        for u in 0..n as u32 {
            if let Some((pid, pos)) = self.index.lookup(u) {
                let p = pid as usize;
                if p >= self.cache.len()
                    || pos as usize >= self.cache.num_outputs(p)
                    || self.cache.output_nodes(p)[pos as usize] != u
                {
                    return Err(format!(
                        "epoch {}: node {u} routed to ({pid}, {pos}) which \
                         does not own it",
                        self.epoch
                    ));
                }
            }
        }
        Ok(())
    }
}

/// The shared slot the serving loop loads and the applier publishes to.
pub type ServeStateCell = SwapCell<ServeState>;

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

    #[test]
    fn load_store_swap_roundtrip() {
        let cell = SwapCell::new(Arc::new(1u64));
        assert_eq!(*cell.load(), 1);
        cell.store(Arc::new(2));
        assert_eq!(*cell.load(), 2);
        let old = cell.swap(Arc::new(3));
        assert_eq!(*old, 2);
        assert_eq!(*cell.load(), 3);
    }

    #[test]
    fn loads_pin_the_snapshot_they_saw() {
        let cell = SwapCell::new(Arc::new(vec![1, 2, 3]));
        let pinned = cell.load();
        cell.store(Arc::new(vec![9]));
        // the in-flight reader still sees the old epoch, fully intact
        assert_eq!(*pinned, vec![1, 2, 3]);
        assert_eq!(*cell.load(), vec![9]);
    }

    /// Loom-style interleaving check (loom itself is unavailable
    /// offline): a writer publishes monotonically-versioned payloads
    /// whose fields must agree; readers hammer `load` concurrently and
    /// assert they never see a torn value or a version rollback. The
    /// schedule is whatever the OS provides — many iterations stand in
    /// for exhaustive interleavings.
    #[test]
    fn concurrent_loads_never_observe_torn_or_regressing_values() {
        struct Payload {
            version: u64,
            echo: [u64; 4],
        }
        let cell = Arc::new(SwapCell::new(Arc::new(Payload {
            version: 0,
            echo: [0; 4],
        })));
        let stop = Arc::new(AtomicBool::new(false));
        let max_seen = Arc::new(AtomicU64::new(0));
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let cell = cell.clone();
                let stop = stop.clone();
                let max_seen = max_seen.clone();
                scope.spawn(move || {
                    let mut last = 0u64;
                    while !stop.load(Ordering::Acquire) {
                        let s = cell.load();
                        assert!(
                            s.echo.iter().all(|&e| e == s.version),
                            "torn payload: v{} echo {:?}",
                            s.version,
                            s.echo
                        );
                        assert!(
                            s.version >= last,
                            "version regressed {last} -> {}",
                            s.version
                        );
                        last = s.version;
                        max_seen.fetch_max(last, Ordering::AcqRel);
                    }
                });
            }
            for v in 1..=10_000u64 {
                cell.store(Arc::new(Payload {
                    version: v,
                    echo: [v; 4],
                }));
            }
            stop.store(true, Ordering::Release);
        });
        assert!(
            max_seen.load(Ordering::Acquire) > 0,
            "readers never observed a published store"
        );
        assert_eq!(cell.load().version, 10_000);
    }
}
