//! Deadline-aware admission control for the serve loop.
//!
//! Past saturation a closed queue just grows: every admitted query
//! waits behind everything admitted before it, p99 explodes, and
//! goodput (completions that still meet their deadline) collapses even
//! though raw throughput looks fine. The gate keeps the system on the
//! goodput plateau instead: it tracks per-shard in-flight group depth
//! and an EWMA of group service time, predicts a new query's completion
//! as `(depth + 1) × ewma_service`, and rejects queries whose waited
//! time plus prediction exceeds their deadline — or *degrades* them to
//! a memo-only lookup when the results cache still holds their plan's
//! logits (a stale-tolerant answer beats no answer). A per-tenant
//! token bucket caps each tenant's admission rate ahead of the
//! deadline predicate, so one hot tenant cannot starve the rest.
//!
//! The gate is synchronous and clocked by caller-supplied [`Instant`]s
//! (like [`super::queue::MicrobatchQueue`]), so every decision path is
//! deterministic and unit-testable. The EWMA starts from a positive
//! prior ([`AdmissionConfig::service_prior_s`]) instead of zero:
//! before the first group completes, a zero estimate would predict
//! zero wait at any depth and admit an unbounded burst.

use std::time::{Duration, Instant};

/// Gate tuning. `Default` admits everything (no deadline, no rate
/// limit) — the closed-loop paths are untouched unless configured.
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Completion deadline; `None` disables the deadline predicate.
    pub deadline: Option<Duration>,
    /// EWMA smoothing for observed group service times.
    pub ewma_alpha: f64,
    /// Service-time estimate before any observation (seconds). Must
    /// be > 0 so cold-start bursts are still depth-limited.
    pub service_prior_s: f64,
    /// Per-tenant token refill rate (queries/s; 0 = unlimited).
    pub tenant_rate: f64,
    /// Per-tenant token-bucket burst capacity.
    pub tenant_burst: f64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            deadline: None,
            ewma_alpha: 0.2,
            service_prior_s: 5e-4,
            tenant_rate: 0.0,
            tenant_burst: 32.0,
        }
    }
}

/// Gate decision for one arriving query.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Predicted to complete in time: enqueue normally.
    Admit,
    /// Predicted to miss its deadline: answer from the results memo
    /// if possible (degraded), otherwise shed.
    OverDeadline,
    /// Tenant exhausted its token bucket: shed before any other work.
    RateLimited,
}

/// Per-tenant admission accounting (surfaced in `ServeReport`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TenantCounters {
    /// Queries answered at full fidelity (execution or fresh memo).
    pub admitted: u64,
    /// Over-deadline queries answered from the memo.
    pub degraded: u64,
    /// Queries shed by the deadline predicate (memo miss).
    pub shed_deadline: u64,
    /// Queries shed by the token bucket.
    pub shed_rate_limited: u64,
}

impl TenantCounters {
    /// All outcomes for this tenant (every offered query lands in
    /// exactly one counter).
    pub fn total(&self) -> u64 {
        self.admitted + self.degraded + self.shed_deadline + self.shed_rate_limited
    }
}

/// The admission gate: per-shard depth, service EWMA, tenant buckets.
#[derive(Debug)]
pub struct AdmissionGate {
    cfg: AdmissionConfig,
    /// Groups enqueued-or-executing per shard.
    depth: Vec<u64>,
    ewma_s: f64,
    observations: u64,
    tokens: Vec<f64>,
    refilled: Vec<Option<Instant>>,
    /// Per-tenant outcome counters (index = tenant id).
    pub tenants: Vec<TenantCounters>,
}

impl AdmissionGate {
    /// Gate for `shards` shards and `tenants` token buckets (at least
    /// one of each).
    pub fn new(shards: usize, tenants: usize, cfg: AdmissionConfig) -> Self {
        let tenants = tenants.max(1);
        AdmissionGate {
            tokens: vec![cfg.tenant_burst.max(1.0); tenants],
            refilled: vec![None; tenants],
            tenants: vec![TenantCounters::default(); tenants],
            depth: vec![0; shards.max(1)],
            ewma_s: 0.0,
            observations: 0,
            cfg,
        }
    }

    /// Current group service-time estimate (prior until observed).
    pub fn service_estimate_s(&self) -> f64 {
        if self.observations == 0 {
            self.cfg.service_prior_s.max(1e-9)
        } else {
            self.ewma_s
        }
    }

    /// Predicted completion wait for a query admitted to `shard` now:
    /// everything queued there, plus its own group, at the estimated
    /// per-group service time.
    pub fn predicted_wait_s(&self, shard: usize) -> f64 {
        (self.depth[shard] + 1) as f64 * self.service_estimate_s()
    }

    /// In-flight group depth of `shard`.
    pub fn depth(&self, shard: usize) -> u64 {
        self.depth[shard]
    }

    /// Decide one arrival: token bucket first, then the deadline
    /// predicate over `waited_s` (time already spent since the
    /// query's scheduled arrival) plus the predicted wait.
    pub fn assess(
        &mut self,
        tenant: u16,
        shard: usize,
        waited_s: f64,
        now: Instant,
    ) -> Verdict {
        if self.cfg.tenant_rate > 0.0 && !self.take_token(tenant, now) {
            return Verdict::RateLimited;
        }
        if let Some(deadline) = self.cfg.deadline {
            if waited_s + self.predicted_wait_s(shard)
                > deadline.as_secs_f64()
            {
                return Verdict::OverDeadline;
            }
        }
        Verdict::Admit
    }

    fn take_token(&mut self, tenant: u16, now: Instant) -> bool {
        let t = (tenant as usize).min(self.tokens.len() - 1);
        if let Some(last) = self.refilled[t] {
            let dt = now.saturating_duration_since(last).as_secs_f64();
            self.tokens[t] = (self.tokens[t] + dt * self.cfg.tenant_rate)
                .min(self.cfg.tenant_burst.max(1.0));
        }
        self.refilled[t] = Some(now);
        if self.tokens[t] >= 1.0 {
            self.tokens[t] -= 1.0;
            true
        } else {
            false
        }
    }

    /// A new group entered the queue for `shard`.
    pub fn group_enqueued(&mut self, shard: usize) {
        self.depth[shard] += 1;
    }

    /// Cooperative dispatch moved a group from `from` to `to` (replica
    /// routing or a steal, DESIGN.md §15): shift its depth so the
    /// deadline predicate sees where the work actually queues.
    pub fn group_moved(&mut self, from: usize, to: usize) {
        if from == to {
            return;
        }
        self.depth[from] = self.depth[from].saturating_sub(1);
        self.depth[to] += 1;
    }

    /// A group finished on `shard` after `service_s` seconds of
    /// execution: release its depth and fold the observation into the
    /// EWMA.
    pub fn group_done(&mut self, shard: usize, service_s: f64) {
        self.depth[shard] = self.depth[shard].saturating_sub(1);
        if service_s.is_finite() && service_s >= 0.0 {
            if self.observations == 0 {
                self.ewma_s = service_s;
            } else {
                let a = self.cfg.ewma_alpha.clamp(0.0, 1.0);
                self.ewma_s = a * service_s + (1.0 - a) * self.ewma_s;
            }
            self.observations += 1;
        }
    }

    fn tenant_mut(&mut self, tenant: u16) -> &mut TenantCounters {
        let t = (tenant as usize).min(self.tenants.len() - 1);
        &mut self.tenants[t]
    }

    /// Count a full-fidelity answer (execution or fresh memo hit).
    pub fn note_admitted(&mut self, tenant: u16) {
        self.tenant_mut(tenant).admitted += 1;
    }

    /// Count an over-deadline query answered from the memo.
    pub fn note_degraded(&mut self, tenant: u16) {
        self.tenant_mut(tenant).degraded += 1;
    }

    /// Count a query shed by the deadline predicate.
    pub fn note_shed_deadline(&mut self, tenant: u16) {
        self.tenant_mut(tenant).shed_deadline += 1;
    }

    /// Count a query shed by the tenant's token bucket.
    pub fn note_shed_rate(&mut self, tenant: u16) {
        self.tenant_mut(tenant).shed_rate_limited += 1;
    }

    /// Sum of all tenants' counters.
    pub fn totals(&self) -> TenantCounters {
        let mut out = TenantCounters::default();
        for t in &self.tenants {
            out.admitted += t.admitted;
            out.degraded += t.degraded;
            out.shed_deadline += t.shed_deadline;
            out.shed_rate_limited += t.shed_rate_limited;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gate(cfg: AdmissionConfig) -> AdmissionGate {
        AdmissionGate::new(2, 2, cfg)
    }

    #[test]
    fn admits_everything_by_default() {
        let mut g = gate(AdmissionConfig::default());
        let now = Instant::now();
        for i in 0..100 {
            g.group_enqueued(i % 2);
            assert_eq!(g.assess(0, i % 2, 0.0, now), Verdict::Admit);
        }
    }

    #[test]
    fn deadline_predicate_uses_depth_times_ewma() {
        let mut g = gate(AdmissionConfig {
            deadline: Some(Duration::from_millis(2)),
            service_prior_s: 5e-4,
            ..Default::default()
        });
        let now = Instant::now();
        // prior 500µs: (depth+1)*500µs exceeds 2ms once depth >= 4
        for _ in 0..3 {
            assert_eq!(g.assess(0, 0, 0.0, now), Verdict::Admit);
            g.group_enqueued(0);
        }
        assert_eq!(g.depth(0), 3);
        assert_eq!(g.assess(0, 0, 0.0, now), Verdict::Admit);
        g.group_enqueued(0);
        assert_eq!(g.assess(0, 0, 0.0, now), Verdict::OverDeadline);
        // the other shard is idle and still admits
        assert_eq!(g.assess(0, 1, 0.0, now), Verdict::Admit);
        // waited time counts against the budget too
        g.group_done(0, 5e-4);
        g.group_done(0, 5e-4);
        g.group_done(0, 5e-4);
        assert_eq!(g.assess(0, 0, 0.0, now), Verdict::Admit);
        assert_eq!(g.assess(0, 0, 1.9e-3, now), Verdict::OverDeadline);
    }

    #[test]
    fn ewma_tracks_observed_service_times() {
        let mut g = gate(AdmissionConfig {
            ewma_alpha: 0.5,
            service_prior_s: 1e-3,
            ..Default::default()
        });
        assert!((g.service_estimate_s() - 1e-3).abs() < 1e-12, "prior");
        g.group_enqueued(0);
        g.group_done(0, 4e-3);
        assert!((g.service_estimate_s() - 4e-3).abs() < 1e-12, "first obs");
        g.group_enqueued(0);
        g.group_done(0, 2e-3);
        assert!((g.service_estimate_s() - 3e-3).abs() < 1e-12, "ewma");
        assert_eq!(g.depth(0), 0);
        // depth never underflows
        g.group_done(0, 1e-3);
        assert_eq!(g.depth(0), 0);
    }

    #[test]
    fn group_moved_shifts_depth_between_shards() {
        let mut g = gate(AdmissionConfig::default());
        g.group_enqueued(0);
        g.group_enqueued(0);
        g.group_moved(0, 1);
        assert_eq!(g.depth(0), 1);
        assert_eq!(g.depth(1), 1);
        // self-moves and underflow are no-ops
        g.group_moved(1, 1);
        assert_eq!(g.depth(1), 1);
        g.group_done(1, 1e-4);
        g.group_moved(1, 0);
        assert_eq!(g.depth(1), 0);
        assert_eq!(g.depth(0), 2);
    }

    #[test]
    fn token_bucket_rate_limits_per_tenant() {
        let mut g = gate(AdmissionConfig {
            tenant_rate: 10.0,
            tenant_burst: 2.0,
            ..Default::default()
        });
        let t0 = Instant::now();
        // burst of 2, then dry
        assert_eq!(g.assess(0, 0, 0.0, t0), Verdict::Admit);
        assert_eq!(g.assess(0, 0, 0.0, t0), Verdict::Admit);
        assert_eq!(g.assess(0, 0, 0.0, t0), Verdict::RateLimited);
        // tenant 1 has its own bucket
        assert_eq!(g.assess(1, 0, 0.0, t0), Verdict::Admit);
        // 100ms at 10/s refills one token
        let t1 = t0 + Duration::from_millis(100);
        assert_eq!(g.assess(0, 0, 0.0, t1), Verdict::Admit);
        assert_eq!(g.assess(0, 0, 0.0, t1), Verdict::RateLimited);
        // refill clamps at the burst cap
        let t2 = t1 + Duration::from_secs(10);
        assert_eq!(g.assess(0, 0, 0.0, t2), Verdict::Admit);
        assert_eq!(g.assess(0, 0, 0.0, t2), Verdict::Admit);
        assert_eq!(g.assess(0, 0, 0.0, t2), Verdict::RateLimited);
    }

    #[test]
    fn tenant_counters_accumulate_and_total() {
        let mut g = gate(AdmissionConfig::default());
        g.note_admitted(0);
        g.note_admitted(1);
        g.note_degraded(1);
        g.note_shed_deadline(0);
        g.note_shed_rate(1);
        // out-of-range tenants clamp to the last bucket
        g.note_admitted(9);
        assert_eq!(g.tenants[0].admitted, 1);
        assert_eq!(g.tenants[1].admitted, 2);
        assert_eq!(g.tenants[1].degraded, 1);
        let t = g.totals();
        assert_eq!(t.admitted, 3);
        assert_eq!(t.total(), 6);
    }
}
