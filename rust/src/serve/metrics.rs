//! Serving metrics: log-bucketed latency histogram and the service
//! counters behind the bench's qps / p99 / coalescing-factor report.
//!
//! The histogram uses geometric buckets (1 µs × 1.25ᵏ, ~80 buckets up
//! to ~50 s) so memory is O(1) regardless of query count and quantiles
//! have bounded relative error (≤ the 25 % bucket growth) — the usual
//! HDR-style trade for long-running services.

/// Smallest resolvable latency (floor of bucket 0).
const LAT_MIN_S: f64 = 1e-6;
/// Geometric bucket growth factor.
const LAT_GROWTH: f64 = 1.25;
/// Bucket count: 1 µs × 1.25⁸⁰ ≈ 54 s covers any sane query.
const LAT_BUCKETS: usize = 80;

/// Fixed-size log-scale latency histogram.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    counts: Vec<u64>,
    count: u64,
    sum_s: f64,
    min_s: f64,
    max_s: f64,
}

impl LatencyHistogram {
    /// An empty histogram (all buckets zero).
    pub fn new() -> LatencyHistogram {
        LatencyHistogram {
            counts: vec![0; LAT_BUCKETS],
            count: 0,
            sum_s: 0.0,
            min_s: f64::INFINITY,
            max_s: 0.0,
        }
    }

    /// Record one latency sample (seconds).
    pub fn record(&mut self, s: f64) {
        let s = s.max(0.0);
        let b = if s <= LAT_MIN_S {
            0
        } else {
            (((s / LAT_MIN_S).ln() / LAT_GROWTH.ln()).floor() as usize)
                .min(LAT_BUCKETS - 1)
        };
        self.counts[b] += 1;
        self.count += 1;
        self.sum_s += s;
        self.min_s = self.min_s.min(s);
        self.max_s = self.max_s.max(s);
    }

    /// Quantile `q` in [0, 1], interpolated linearly *within* the
    /// containing log bucket by the target's rank among the bucket's
    /// samples (clamped to the observed min/max). Reporting the bucket
    /// upper edge instead would bias every quantile high by up to the
    /// 25 % bucket growth — at p99 over millisecond buckets that is
    /// hundreds of microseconds of phantom latency.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64)
            .max(1);
        let mut acc = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if acc + c >= target {
                let lo = if i == 0 {
                    0.0
                } else {
                    LAT_MIN_S * LAT_GROWTH.powi(i as i32)
                };
                let hi = LAT_MIN_S * LAT_GROWTH.powi(i as i32 + 1);
                let frac = (target - acc) as f64 / c as f64;
                return (lo + (hi - lo) * frac).clamp(self.min_s, self.max_s);
            }
            acc += c;
        }
        self.max_s
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Mean latency in seconds (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_s / self.count as f64
        }
    }

    /// Largest observed latency in seconds (0 when empty).
    pub fn max(&self) -> f64 {
        self.max_s
    }

    /// Smallest observed latency in seconds (0 when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min_s
        }
    }
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram::new()
    }
}

/// Counters accumulated by the service event loop.
#[derive(Debug)]
pub struct ServeMetrics {
    /// Completion-latency histogram over admitted queries.
    pub latency: LatencyHistogram,
    /// Materialize+execute runs dispatched to shards.
    pub executions: u64,
    /// Queries answered by those executions (≥ executions when
    /// coalescing works).
    pub executed_queries: u64,
    /// Queries answered straight from the results memo.
    pub cache_hit_queries: u64,
    /// Queries that needed a cold-path (synthesized) plan.
    pub cold_routes: u64,
    /// Queries answered (execution or memo hit).
    pub completed: u64,
    /// Completions whose prediction matched the dataset label.
    pub correct: u64,
    /// Groups *executed* per shard, tallied at result receipt — not at
    /// dispatch — so cooperative steals and replica dispatches show up
    /// on the shard that actually ran the work (DESIGN.md §15).
    pub shard_executions: Vec<u64>,
    /// Queries answered per shard by execution, tallied at result
    /// receipt like [`ServeMetrics::shard_executions`].
    pub shard_queries: Vec<u64>,
    /// Shard-side seconds spent in the model forward pass.
    pub exec_s: f64,
    /// Queries rejected by the deadline predicate (memo miss — never
    /// answered, never recorded in the latency histogram).
    pub shed_deadline: u64,
    /// Queries rejected by the per-tenant token bucket.
    pub shed_rate_limited: u64,
    /// Over-deadline queries answered from the memo (subset of
    /// `completed`).
    pub degraded: u64,
    /// Completions that met `deadline_s` (the goodput numerator; all
    /// completions when no deadline is set).
    pub within_deadline: u64,
    /// Deadline the goodput counter judges against (None = all good).
    pub deadline_s: Option<f64>,
}

impl ServeMetrics {
    /// Zeroed counters for a run over `shards` workers.
    pub fn new(shards: usize) -> ServeMetrics {
        ServeMetrics {
            latency: LatencyHistogram::new(),
            executions: 0,
            executed_queries: 0,
            cache_hit_queries: 0,
            cold_routes: 0,
            completed: 0,
            correct: 0,
            shard_executions: vec![0; shards.max(1)],
            shard_queries: vec![0; shards.max(1)],
            exec_s: 0.0,
            shed_deadline: 0,
            shed_rate_limited: 0,
            degraded: 0,
            within_deadline: 0,
            deadline_s: None,
        }
    }

    /// One group dispatched carrying `queries` queries. Per-shard
    /// attribution waits for [`ServeMetrics::record_group_executed`]:
    /// under cooperative serving the dispatch target is not always the
    /// executing shard.
    pub fn record_dispatch(&mut self, queries: u64) {
        self.executions += 1;
        self.executed_queries += queries;
    }

    /// One group's result arrived from `shard`: attribute the
    /// execution (and its `queries` riders) to the shard that actually
    /// ran it, so `shard_balance` sees steals and replica dispatches.
    pub fn record_group_executed(&mut self, shard: usize, queries: u64) {
        self.shard_executions[shard] += 1;
        self.shard_queries[shard] += queries;
    }

    /// One query finished (by execution or memo hit). Shed queries
    /// are *not* recorded here, so the histogram — and every quantile
    /// derived from it — covers admitted queries only.
    pub fn record_completion(&mut self, latency_s: f64, correct: bool) {
        self.latency.record(latency_s);
        self.completed += 1;
        if correct {
            self.correct += 1;
        }
        if self.deadline_s.map(|d| latency_s <= d).unwrap_or(true) {
            self.within_deadline += 1;
        }
    }

    /// Queries shed (deadline predicate + rate limit).
    pub fn shed(&self) -> u64 {
        self.shed_deadline + self.shed_rate_limited
    }

    /// Queries per execution (> 1 once coalescing pays off; 0 when no
    /// execution happened).
    pub fn coalescing_factor(&self) -> f64 {
        if self.executions == 0 {
            0.0
        } else {
            self.executed_queries as f64 / self.executions as f64
        }
    }

    /// Fraction of completed queries served from the results memo.
    pub fn hit_rate(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.cache_hit_queries as f64 / self.completed as f64
        }
    }

    /// Fraction of completions with a label-correct prediction.
    pub fn accuracy(&self) -> f64 {
        if self.completed == 0 {
            0.0
        } else {
            self.correct as f64 / self.completed as f64
        }
    }

    /// Max shard query share / ideal share (1.0 = perfectly balanced),
    /// mirroring [`crate::partition::balance`]. Computed over
    /// *executed* per-shard queries, so cooperative mode's steals and
    /// replica dispatches improve the reported balance.
    pub fn shard_balance(&self) -> f64 {
        let total: u64 = self.shard_queries.iter().sum();
        if total == 0 || self.shard_queries.is_empty() {
            return 1.0;
        }
        let max = *self.shard_queries.iter().max().unwrap();
        max as f64 / (total as f64 / self.shard_queries.len() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantiles_have_bounded_relative_error() {
        let mut h = LatencyHistogram::new();
        // 1000 samples spread uniformly over [1ms, 11ms]
        for i in 0..1000 {
            h.record(1e-3 + i as f64 * 1e-5);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        // true p50 = 6ms, true p99 = 10.9ms; bucket edge error <= 25%
        assert!((4.5e-3..7.5e-3).contains(&p50), "p50={p50}");
        assert!((8.5e-3..13.7e-3).contains(&p99), "p99={p99}");
        assert!(p50 <= p99);
        assert!(h.mean() > 5e-3 && h.mean() < 7e-3);
        assert!(h.max() <= 11e-3 + 1e-9);
    }

    #[test]
    fn quantiles_interpolate_within_the_bucket() {
        // constant samples: every quantile collapses to the exact
        // value (the min/max clamp pins it), where the old
        // upper-edge readout reported the bucket edge above it
        let mut h = LatencyHistogram::new();
        for _ in 0..100 {
            h.record(3e-3);
        }
        for q in [0.0, 0.5, 0.9, 0.99, 1.0] {
            assert!(
                (h.quantile(q) - 3e-3).abs() < 1e-12,
                "q={q}: {}",
                h.quantile(q)
            );
        }
        // uniform ramp over [1ms, 11ms): true p50 = 6ms. The bucket
        // containing it spans [5.87ms, 7.33ms) — upper-edge reporting
        // returns 7.33ms (+22%), interpolation lands on ~6.0ms.
        let mut h = LatencyHistogram::new();
        for i in 0..1000 {
            h.record(1e-3 + i as f64 * 1e-5);
        }
        let p50 = h.quantile(0.5);
        assert!((5.8e-3..6.4e-3).contains(&p50), "p50={p50}");
        // two-point distribution: p99 falls in the 10ms cluster and
        // clamps to the exact observed max
        let mut h = LatencyHistogram::new();
        for _ in 0..90 {
            h.record(1e-3);
        }
        for _ in 0..10 {
            h.record(10e-3);
        }
        assert!((h.quantile(0.5) - 1e-3).abs() < 1e-12);
        assert!((h.quantile(0.99) - 10e-3).abs() < 1e-12);
    }

    #[test]
    fn goodput_counts_completions_within_deadline() {
        let mut m = ServeMetrics::new(1);
        m.deadline_s = Some(2e-3);
        m.record_completion(1e-3, true);
        m.record_completion(2e-3, true); // exactly at the deadline: good
        m.record_completion(5e-3, false); // late: completed, not good
        m.shed_deadline = 2;
        m.shed_rate_limited = 1;
        assert_eq!(m.completed, 3);
        assert_eq!(m.within_deadline, 2);
        assert_eq!(m.shed(), 3);
        assert_eq!(m.latency.count(), 3, "shed queries never recorded");
        // without a deadline every completion is goodput
        let mut m = ServeMetrics::new(1);
        m.record_completion(10.0, true);
        assert_eq!(m.within_deadline, 1);
    }

    #[test]
    fn empty_and_extreme_samples_are_safe() {
        let mut h = LatencyHistogram::new();
        assert_eq!(h.quantile(0.99), 0.0);
        assert_eq!(h.mean(), 0.0);
        h.record(0.0);
        h.record(1e9); // clamps into the last bucket
        assert_eq!(h.count(), 2);
        assert!(h.quantile(0.0) > 0.0 || h.min() == 0.0);
        assert!(h.quantile(1.0) <= 1e9);
    }

    #[test]
    fn coalescing_and_balance_accounting() {
        let mut m = ServeMetrics::new(2);
        m.record_dispatch(4);
        m.record_dispatch(2);
        m.record_dispatch(6);
        assert_eq!(m.executions, 3);
        assert_eq!(m.executed_queries, 12);
        assert!((m.coalescing_factor() - 4.0).abs() < 1e-12);
        // balance is attributed at result receipt: a group dispatched
        // to shard 0 but stolen by shard 1 counts against shard 1
        assert_eq!(m.shard_queries, vec![0, 0], "nothing executed yet");
        assert!((m.shard_balance() - 1.0).abs() < 1e-12);
        m.record_group_executed(0, 4);
        m.record_group_executed(1, 2);
        m.record_group_executed(1, 6);
        assert_eq!(m.shard_executions, vec![1, 2]);
        assert_eq!(m.shard_queries, vec![4, 8]);
        assert!((m.shard_balance() - 8.0 / 6.0).abs() < 1e-12);
        m.record_completion(1e-3, true);
        m.record_completion(2e-3, false);
        m.cache_hit_queries = 1;
        assert!((m.accuracy() - 0.5).abs() < 1e-12);
        assert!((m.hit_rate() - 0.5).abs() < 1e-12);
    }
}
