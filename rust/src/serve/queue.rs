//! Admission / microbatch coalescing queue.
//!
//! Concurrent queries routed to the same plan are folded into one
//! pending group and executed with a *single* materialize+execute —
//! the serving-time analogue of the paper's "fixed batches are
//! reusable" argument, and the mechanism behind the coalescing factor
//! reported by `benches/serving.rs` (cf. "Cooperative Minibatching in
//! GNNs", arXiv 2310.12403: concurrent queries sharing neighborhoods
//! multiply the reuse win).
//!
//! Groups are keyed by **(plan, epoch)** and pin the snapshot they
//! were opened under (DESIGN.md §11): a query admitted after an epoch
//! swap whose plan changed opens a *new* group against the new
//! snapshot instead of riding a group whose pinned plan no longer owns
//! its output row — that separation is what makes "no query ever
//! observes mixed-epoch state" hold through the queue. Queries for a
//! plan the swap did *not* change keep coalescing into the old group
//! (its epoch, and therefore its content, is identical).
//!
//! Flush policy is the usual two-sided one: a group flushes when it
//! reaches `max_coalesce` queries (size flush, bounds per-query work)
//! or when its oldest query has waited `window` (deadline flush,
//! bounds added latency). The queue is purely synchronous and clocked
//! by caller-supplied [`Instant`]s, so its behavior is deterministic
//! and unit-testable without threads or sleeps; the snapshot payload
//! is generic (`S`), so tests drive it with `()` while the service
//! pins `Arc<ServeState>`.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use super::router::PlanKey;

/// One admitted query waiting for its plan to execute.
#[derive(Debug, Clone, Copy)]
pub struct QueryTicket {
    /// Caller-assigned query id (latency bookkeeping).
    pub id: u64,
    /// Queried node (global id).
    pub node: u32,
    /// The node's output-row position within the plan.
    pub pos: u32,
}

/// A coalesced group of queries for one (plan, epoch), ready to
/// execute against the snapshot it pinned at creation.
#[derive(Debug)]
pub struct PendingGroup<S> {
    /// Queue-assigned group id, unique within a run — the correlation
    /// id tying trace spans, dispatch accounting, and shard results
    /// back to one coalesced execution.
    pub gid: u64,
    /// The (cached or cold) plan every rider of this group shares.
    pub key: PlanKey,
    /// Freshness epoch of the group's plan at admission time.
    pub epoch: u64,
    /// Snapshot the group was opened under; execution and shard
    /// placement read this, never "the current" state.
    pub snap: S,
    /// Admission time of the group's first query (deadline anchor).
    pub created: Instant,
    /// The coalesced riders, in admission order.
    pub queries: Vec<QueryTicket>,
}

/// Deadline- and size-flushed per-(plan, epoch) coalescing queue.
pub struct MicrobatchQueue<S> {
    window: Duration,
    max_coalesce: usize,
    groups: HashMap<(PlanKey, u64), PendingGroup<S>>,
    /// Next group id (monotonic over the queue's lifetime).
    next_gid: u64,
}

impl<S: Clone> MicrobatchQueue<S> {
    /// `window` = max time a query waits for co-riders; `max_coalesce`
    /// = size flush threshold (≥ 1).
    pub fn new(window: Duration, max_coalesce: usize) -> MicrobatchQueue<S> {
        MicrobatchQueue {
            window,
            max_coalesce: max_coalesce.max(1),
            groups: HashMap::new(),
            next_gid: 0,
        }
    }

    /// Admit one query at time `now`, under plan-epoch `epoch` and
    /// snapshot `snap`. Returns the id of the group the query joined
    /// (or opened), plus the full group if this admission triggered a
    /// size flush.
    pub fn push(
        &mut self,
        key: PlanKey,
        epoch: u64,
        snap: &S,
        q: QueryTicket,
        now: Instant,
    ) -> (u64, Option<PendingGroup<S>>) {
        let next_gid = &mut self.next_gid;
        let g = self
            .groups
            .entry((key, epoch))
            .or_insert_with(|| {
                let gid = *next_gid;
                *next_gid += 1;
                PendingGroup {
                    gid,
                    key,
                    epoch,
                    snap: snap.clone(),
                    created: now,
                    queries: Vec::new(),
                }
            });
        g.queries.push(q);
        let gid = g.gid;
        if g.queries.len() >= self.max_coalesce {
            return (gid, self.groups.remove(&(key, epoch)));
        }
        (gid, None)
    }

    /// Whether a group is already open for (plan, epoch) — the
    /// admission gate's depth accounting increments only when a push
    /// *opens* a group (riders add no queue depth).
    pub fn contains(&self, key: PlanKey, epoch: u64) -> bool {
        self.groups.contains_key(&(key, epoch))
    }

    /// Iterate the open groups (snapshot-GC accounting reads the
    /// epochs their pinned snapshots hold alive).
    pub fn groups(&self) -> impl Iterator<Item = &PendingGroup<S>> {
        self.groups.values()
    }

    /// Remove and return every group whose deadline has passed.
    pub fn due(&mut self, now: Instant) -> Vec<PendingGroup<S>> {
        let keys: Vec<(PlanKey, u64)> = self
            .groups
            .iter()
            .filter(|(_, g)| now.duration_since(g.created) >= self.window)
            .map(|(&k, _)| k)
            .collect();
        keys.iter()
            .filter_map(|k| self.groups.remove(k))
            .collect()
    }

    /// Earliest pending deadline (None when the queue is empty) — the
    /// event loop's wake-up time.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.groups
            .values()
            .map(|g| g.created + self.window)
            .min()
    }

    /// Remove and return everything (shutdown).
    pub fn drain(&mut self) -> Vec<PendingGroup<S>> {
        self.groups.drain().map(|(_, g)| g).collect()
    }

    /// Open (not yet flushed) groups.
    pub fn pending_groups(&self) -> usize {
        self.groups.len()
    }

    /// Queries waiting across all open groups.
    pub fn pending_queries(&self) -> usize {
        self.groups.values().map(|g| g.queries.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ticket(id: u64) -> QueryTicket {
        QueryTicket {
            id,
            node: id as u32,
            pos: 0,
        }
    }

    fn queue(window: Duration, max: usize) -> MicrobatchQueue<()> {
        MicrobatchQueue::new(window, max)
    }

    #[test]
    fn coalesces_same_plan_until_deadline() {
        let mut q = queue(Duration::from_millis(10), 100);
        let t0 = Instant::now();
        for i in 0..5 {
            assert!(q
                .push(PlanKey::Cached(3), 0, &(), ticket(i), t0).1.is_none());
        }
        assert_eq!(q.pending_groups(), 1);
        assert_eq!(q.pending_queries(), 5);
        // not yet due
        assert!(q.due(t0 + Duration::from_millis(9)).is_empty());
        let due = q.due(t0 + Duration::from_millis(10));
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].queries.len(), 5);
        assert_eq!(q.pending_groups(), 0);
    }

    #[test]
    fn size_flush_returns_full_group() {
        let mut q = queue(Duration::from_secs(1), 3);
        let t0 = Instant::now();
        assert!(q.push(PlanKey::Cached(0), 0, &(), ticket(0), t0).1.is_none());
        assert!(q.push(PlanKey::Cached(0), 0, &(), ticket(1), t0).1.is_none());
        let g = q.push(PlanKey::Cached(0), 0, &(), ticket(2), t0).1.unwrap();
        assert_eq!(g.queries.len(), 3);
        assert_eq!(q.pending_groups(), 0);
        // a new query for the same plan starts a fresh group
        assert!(q.push(PlanKey::Cached(0), 0, &(), ticket(3), t0).1.is_none());
        assert_eq!(q.pending_queries(), 1);
    }

    #[test]
    fn distinct_plans_do_not_coalesce() {
        let mut q = queue(Duration::from_millis(5), 10);
        let t0 = Instant::now();
        assert!(q.push(PlanKey::Cached(1), 0, &(), ticket(0), t0).1.is_none());
        assert!(q.push(PlanKey::Cold(1), 0, &(), ticket(1), t0).1.is_none());
        assert!(q.push(PlanKey::Cached(2), 0, &(), ticket(2), t0).1.is_none());
        assert_eq!(q.pending_groups(), 3);
        let due = q.due(t0 + Duration::from_millis(5));
        assert_eq!(due.len(), 3);
        assert!(due.iter().all(|g| g.queries.len() == 1));
    }

    #[test]
    fn epochs_partition_groups_for_the_same_plan() {
        // the mixed-epoch guard: a post-swap query for a *changed*
        // plan must not ride a pre-swap group
        let mut q = queue(Duration::from_millis(50), 10);
        let t0 = Instant::now();
        assert!(q.push(PlanKey::Cached(7), 0, &(), ticket(0), t0).1.is_none());
        assert!(q.push(PlanKey::Cached(7), 1, &(), ticket(1), t0).1.is_none());
        assert_eq!(q.pending_groups(), 2, "epochs must not share a group");
        // same epoch still coalesces
        assert!(q.push(PlanKey::Cached(7), 0, &(), ticket(2), t0).1.is_none());
        let due = q.due(t0 + Duration::from_millis(50));
        let mut sizes: Vec<(u64, usize)> =
            due.iter().map(|g| (g.epoch, g.queries.len())).collect();
        sizes.sort_unstable();
        assert_eq!(sizes, vec![(0, 2), (1, 1)]);
    }

    #[test]
    fn group_pins_the_snapshot_it_was_opened_under() {
        let mut q: MicrobatchQueue<u64> =
            MicrobatchQueue::new(Duration::from_secs(1), 2);
        let t0 = Instant::now();
        assert!(q.push(PlanKey::Cached(0), 3, &30, ticket(0), t0).1.is_none());
        // the rider joins under a "newer" payload; the group keeps the
        // snapshot of its first query
        let g = q.push(PlanKey::Cached(0), 3, &99, ticket(1), t0).1.unwrap();
        assert_eq!(g.snap, 30);
        assert_eq!(g.epoch, 3);
    }

    #[test]
    fn next_deadline_is_earliest_group() {
        let mut q = queue(Duration::from_millis(10), 10);
        let t0 = Instant::now();
        let t1 = t0 + Duration::from_millis(4);
        assert!(q.push(PlanKey::Cached(1), 0, &(), ticket(0), t1).1.is_none());
        assert!(q.push(PlanKey::Cached(2), 0, &(), ticket(1), t0).1.is_none());
        assert_eq!(q.next_deadline(), Some(t0 + Duration::from_millis(10)));
        // staggered deadlines flush separately
        let due = q.due(t0 + Duration::from_millis(10));
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].key, PlanKey::Cached(2));
        assert_eq!(q.next_deadline(), Some(t1 + Duration::from_millis(10)));
    }

    #[test]
    fn drain_empties_everything() {
        let mut q = queue(Duration::from_secs(1), 10);
        let t0 = Instant::now();
        assert!(q.push(PlanKey::Cached(1), 0, &(), ticket(0), t0).1.is_none());
        assert!(q.push(PlanKey::Cold(0), 0, &(), ticket(1), t0).1.is_none());
        let all = q.drain();
        assert_eq!(all.len(), 2);
        assert_eq!(q.pending_groups(), 0);
        assert_eq!(q.next_deadline(), None);
    }

    #[test]
    fn group_ids_are_unique_and_riders_share_them() {
        let mut q = queue(Duration::from_secs(1), 2);
        let t0 = Instant::now();
        let (g0, none) = q.push(PlanKey::Cached(0), 0, &(), ticket(0), t0);
        assert!(none.is_none());
        // the rider joins the open group and reports the same id
        let (g0b, flushed) = q.push(PlanKey::Cached(0), 0, &(), ticket(1), t0);
        assert_eq!(g0, g0b);
        assert_eq!(flushed.unwrap().gid, g0);
        // a fresh group for the same plan gets a new id
        let (g1, _) = q.push(PlanKey::Cached(0), 0, &(), ticket(2), t0);
        assert_ne!(g0, g1);
        let (g2, _) = q.push(PlanKey::Cached(9), 0, &(), ticket(3), t0);
        assert!(g2 > g1);
        // deadline-flushed groups carry their ids out too
        let due = q.due(t0 + Duration::from_secs(1));
        let mut gids: Vec<u64> = due.iter().map(|g| g.gid).collect();
        gids.sort_unstable();
        assert_eq!(gids, vec![g1, g2]);
    }

    #[test]
    fn contains_and_groups_reflect_open_groups() {
        let mut q = queue(Duration::from_secs(1), 10);
        let t0 = Instant::now();
        assert!(!q.contains(PlanKey::Cached(1), 0));
        q.push(PlanKey::Cached(1), 0, &(), ticket(0), t0);
        q.push(PlanKey::Cached(1), 1, &(), ticket(1), t0);
        assert!(q.contains(PlanKey::Cached(1), 0));
        assert!(q.contains(PlanKey::Cached(1), 1));
        assert!(!q.contains(PlanKey::Cached(2), 0));
        assert_eq!(q.groups().count(), 2);
        assert!(q.groups().all(|g| g.key == PlanKey::Cached(1)));
        q.drain();
        assert!(!q.contains(PlanKey::Cached(1), 0));
        assert_eq!(q.groups().count(), 0);
    }
}
