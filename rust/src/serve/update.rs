//! Dynamic graph updates on the serve path (DESIGN.md §10).
//!
//! [`DynamicServeSession`] owns everything a long-lived deployment
//! mutates when the graph churns: the dataset (labels, feature
//! epochs, and the contiguous CSR swap), the [`DynamicGraph`] overlay
//! the deltas land on, the [`DynamicPlanSet`] keeping per-root
//! influence fresh, the [`ServeSetup`] (plan cache + router + plan
//! epochs), and one results memo that *survives across serving
//! segments* — which is what makes epoch-keyed freshness observable.
//!
//! One [`DynamicServeSession::apply`] runs the full invalidation
//! cascade:
//!
//! 1. the delta lands on the overlay (symmetrize, normalize, epoch++);
//! 2. dataset commit: labels/feature epochs extend, the overlay
//!    compacts into a fresh CSR the executor shards read;
//! 3. incremental PPR refresh repairs the touched roots, plans past
//!    the L1 tolerance are rebuilt, plans merely containing touched
//!    nodes are patched, their epochs bump;
//! 4. the plan cache is repacked and the router's entries for rebuilt
//!    plans are invalidated + re-indexed; cold-plan ids of touched
//!    nodes are dropped so shards lazily re-synthesize against the
//!    new graph;
//! 5. the results memo eagerly drops changed-plan and cold entries
//!    (the epoch check on the read path is the backstop — a pre-delta
//!    logit can never be served even if this sweep were skipped).
//!
//! Serving itself is segment-granular: queries in flight drain before
//! a delta applies, so shard threads always read a consistent
//! `(graph, cache, epochs)` triple without locks on the hot path.

use std::time::Instant;

use anyhow::Result;

use crate::batching::refresh::{DynamicPlanSet, RefreshConfig};
use crate::batching::BatchCache;
use crate::config::preset_for;
use crate::datasets::Dataset;
use crate::graph::delta::{DynamicGraph, GraphDelta};
use crate::graph::GraphView;
use crate::util::Rng;

use super::load::Skew;
use super::results::ResultsCache;
use super::router::PlanKey;
use super::service::{
    serve_closed_loop_with, setup_from_cache, ServeConfig, ServeReport,
    ServeSetup,
};

/// Dynamic-update knobs layered on a [`ServeConfig`].
#[derive(Debug, Clone, Copy)]
pub struct UpdateConfig {
    /// Rebuild a plan when its outputs' summed PPR L1 drift exceeds
    /// this (see [`RefreshConfig::l1_tol`]).
    pub l1_tol: f32,
}

impl Default for UpdateConfig {
    fn default() -> Self {
        UpdateConfig { l1_tol: 0.05 }
    }
}

/// What one applied delta did across the whole serve path.
#[derive(Debug, Clone, Default)]
pub struct UpdateReport {
    pub epoch: u64,
    pub touched_nodes: usize,
    pub added_nodes: usize,
    pub feature_updates: usize,
    pub roots_refreshed: usize,
    pub plans_total: usize,
    pub plans_rebuilt: usize,
    pub plans_patched: usize,
    pub max_root_l1: f32,
    /// Router warm-index entries retired + re-registered (rebuilt
    /// plans) and cold ids dropped (touched nodes).
    pub router_invalidated: usize,
    pub cold_ids_dropped: usize,
    /// Results-memo entries eagerly dropped (changed plans + all cold
    /// plans).
    pub memo_dropped: usize,
    /// Seconds in incremental PPR refresh.
    pub refresh_s: f64,
    /// Seconds in plan rebuild/patch.
    pub replan_s: f64,
    /// Seconds committing (CSR compaction + cache repack + router
    /// sync).
    pub commit_s: f64,
}

impl UpdateReport {
    pub fn stale_plans(&self) -> usize {
        self.plans_rebuilt + self.plans_patched
    }

    pub fn rebuilt_fraction(&self) -> f64 {
        if self.plans_total == 0 {
            0.0
        } else {
            self.plans_rebuilt as f64 / self.plans_total as f64
        }
    }
}

/// A serving deployment that admits graph deltas between serving
/// segments.
pub struct DynamicServeSession {
    pub ds: Dataset,
    pub setup: ServeSetup,
    pub graph: DynamicGraph,
    pub plans: DynamicPlanSet,
    /// Session-lifetime results memo (shared across segments).
    pub memo: ResultsCache,
    cfg: ServeConfig,
    /// Segments served so far — folded into each segment's load seed
    /// so successive segments draw fresh query streams instead of
    /// replaying segment 0's.
    segments: u64,
}

impl DynamicServeSession {
    /// Plan `eval_nodes` with the dataset preset (same planner inputs
    /// as [`super::service::prepare`], but retaining the per-root PPR
    /// states for incremental repair), synthesize the executor model,
    /// and build the router. The rebuild node budget is clamped to the
    /// artifact bucket so replanned batches keep fitting the arenas.
    pub fn prepare(
        ds: Dataset,
        eval_nodes: &[u32],
        cfg: &ServeConfig,
        ucfg: &UpdateConfig,
    ) -> DynamicServeSession {
        let p = preset_for(&ds.name);
        let rcfg = RefreshConfig {
            aux_per_output: p.aux_per_output,
            max_outputs_per_batch: p.outputs_per_batch,
            node_budget: p.node_budget,
            l1_tol: ucfg.l1_tol,
            ..Default::default()
        };
        let mut rng = Rng::new(cfg.seed ^ 0xCAFE);
        let mut plans =
            DynamicPlanSet::plan_initial(&ds.graph, eval_nodes, rcfg, &mut rng);
        let setup = setup_from_cache(&ds, plans.build_cache(), cfg);
        plans.clamp_node_budget(setup.meta.n_pad);
        let graph = DynamicGraph::new(ds.graph.clone());
        let memo = ResultsCache::new(cfg.results_cache_bytes, cfg.results_ttl);
        DynamicServeSession {
            ds,
            setup,
            graph,
            plans,
            memo,
            cfg: cfg.clone(),
            segments: 0,
        }
    }

    /// Apply one delta batch: overlay → dataset commit → incremental
    /// refresh → cache repack → router + memo invalidation.
    pub fn apply(&mut self, delta: &GraphDelta) -> Result<UpdateReport> {
        for &l in &delta.add_node_labels {
            anyhow::ensure!(
                (l as usize) < self.ds.num_classes,
                "new-node label {l} >= {} classes",
                self.ds.num_classes
            );
        }
        let applied = self
            .graph
            .apply(delta)
            .map_err(|e| anyhow::anyhow!("bad delta: {e}"))?;

        // dataset commit: labels + feature epochs + contiguous CSR
        let t_commit = Instant::now();
        self.ds
            .labels
            .extend(delta.add_node_labels.iter().copied());
        self.ds.feat_epoch.resize(self.ds.labels.len(), 0);
        for &u in &applied.feature_updates {
            self.ds.feat_epoch[u as usize] += 1;
        }
        // One CSR materialization per *structural* delta (the overlay
        // keeps its rows and only rebases, paying the extra clone,
        // once it has grown past a quarter of the node count).
        // Feature-only deltas change no adjacency, so they skip both
        // O(graph) commit costs and stay truly delta-local.
        let structural =
            !applied.touched.is_empty() || applied.added_nodes > 0;
        if structural {
            self.ds.graph = self.graph.snapshot();
            if self.graph.overlay_rows() * 4 > self.graph.num_nodes() {
                self.graph.rebase(self.ds.graph.clone());
            }
        }
        let commit_graph_s = t_commit.elapsed().as_secs_f64();

        // incremental influence refresh + staleness-tracked replan
        let refresh = self.plans.apply_delta(&self.ds.graph, &applied);

        // repack the cache only when some plan's content can actually
        // have changed (structural delta that rebuilt or patched at
        // least one plan), sync epochs, invalidate + re-index the
        // router entries of rebuilt plans, drop touched cold ids
        let t_sync = Instant::now();
        if structural && !refresh.changed_plans.is_empty() {
            self.setup.cache = self.plans.build_cache();
        }
        self.setup.epochs = self.plans.epochs().to_vec();
        let mut router_invalidated = 0usize;
        for &pid in &refresh.changed_plans {
            let outputs = self.setup.cache.output_nodes(pid as usize).to_vec();
            router_invalidated += self.setup.router.invalidate_outputs(&outputs);
            self.setup.router.index_plan(pid, &outputs);
        }
        let cold_ids_dropped =
            self.setup.router.invalidate_cold(&applied.touched);

        // eager memo sweep; the epoch check on reads is the backstop
        let changed: std::collections::HashSet<u32> =
            refresh.changed_plans.iter().copied().collect();
        let mut memo_dropped = self.memo.invalidate_where(|k| match k {
            PlanKey::Cached(pid) => changed.contains(pid),
            PlanKey::Cold(_) => true,
        });
        memo_dropped += self.memo.purge_expired(Instant::now());
        let commit_s = commit_graph_s + t_sync.elapsed().as_secs_f64();

        Ok(UpdateReport {
            epoch: applied.epoch,
            touched_nodes: applied.touched.len(),
            added_nodes: applied.added_nodes,
            feature_updates: applied.feature_updates.len(),
            roots_refreshed: refresh.roots_refreshed,
            plans_total: refresh.plans_total,
            plans_rebuilt: refresh.plans_rebuilt,
            plans_patched: refresh.plans_patched,
            max_root_l1: refresh.max_root_l1,
            router_invalidated,
            cold_ids_dropped,
            memo_dropped,
            refresh_s: refresh.refresh_s,
            replan_s: refresh.replan_s,
            commit_s,
        })
    }

    /// Serve one closed-loop segment against the current graph/plan
    /// epoch, reusing the session memo. `queries` overrides the config
    /// count (segmented streams split a total budget).
    pub fn serve_segment(
        &mut self,
        population: &[u32],
        skew: Skew,
        queries: usize,
    ) -> Result<ServeReport> {
        self.segments += 1;
        let cfg = ServeConfig {
            queries,
            // distinct load/shard RNG streams per segment — otherwise
            // every post-delta segment replays segment 0's queries and
            // the memo flatters the reported hit rate
            seed: self
                .cfg
                .seed
                .wrapping_add(self.segments.wrapping_mul(0x9E3779B97F4A7C15)),
            ..self.cfg.clone()
        };
        serve_closed_loop_with(
            &self.ds,
            &mut self.setup,
            population,
            skew,
            &cfg,
            &mut self.memo,
        )
    }

    /// The session's current plan cache (inspection/tests).
    pub fn cache(&self) -> &BatchCache {
        &self.setup.cache
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{sbm, DatasetSpec};
    use crate::serve::router::Route;
    use std::time::Duration;

    fn session() -> DynamicServeSession {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 33);
        let cfg = ServeConfig {
            queries: 48,
            clients: 8,
            shards: 2,
            results_cache_bytes: 1 << 20,
            flush_window: Duration::from_micros(200),
            ..Default::default()
        };
        let eval = ds.splits.train.clone();
        DynamicServeSession::prepare(ds, &eval, &cfg, &UpdateConfig::default())
    }

    #[test]
    fn prepare_matches_static_prepare_shape() {
        let s = session();
        assert!(!s.setup.cache.is_empty());
        assert_eq!(s.setup.epochs.len(), s.setup.cache.len());
        assert!(s.setup.epochs.iter().all(|&e| e == 0));
        assert_eq!(s.graph.epoch(), 0);
    }

    #[test]
    fn apply_then_serve_round_trips() {
        let mut s = session();
        let eval = s.ds.splits.train.clone();
        let before = s.serve_segment(&eval, Skew::Uniform, 32).unwrap();
        assert_eq!(before.queries, 32);

        let delta = GraphDelta {
            add_edges: vec![(eval[0], eval[1]), (eval[2], eval[3])],
            add_node_labels: vec![0],
            feature_updates: vec![eval[4]],
            ..Default::default()
        };
        let report = s.apply(&delta).unwrap();
        assert_eq!(report.epoch, 1);
        assert!(report.stale_plans() > 0);
        assert!(report.rebuilt_fraction() < 1.0);
        assert_eq!(s.ds.labels.len(), s.ds.graph.num_nodes());
        assert_eq!(s.ds.feat_epoch[eval[4] as usize], 1);

        let after = s.serve_segment(&eval, Skew::Uniform, 32).unwrap();
        assert_eq!(
            after.executed_queries + after.cache_hits,
            32,
            "updates must not lose queries"
        );
        // the appended node is serveable via the cold path
        let new_node = (s.ds.graph.num_nodes() - 1) as u32;
        let pop = [new_node];
        let cold = s.serve_segment(&pop, Skew::Uniform, 4).unwrap();
        assert_eq!(cold.executed_queries + cold.cache_hits, 4);
        assert!(cold.cold_routes > 0);
    }

    #[test]
    fn bad_deltas_are_rejected_atomically() {
        let mut s = session();
        let n = s.ds.graph.num_nodes() as u32;
        assert!(s
            .apply(&GraphDelta {
                add_edges: vec![(0, n + 5)],
                ..Default::default()
            })
            .is_err());
        assert!(s
            .apply(&GraphDelta {
                add_node_labels: vec![u16::MAX],
                ..Default::default()
            })
            .is_err());
        assert_eq!(s.graph.epoch(), 0);
        assert_eq!(s.setup.epochs.iter().max().copied().unwrap_or(0), 0);
    }

    #[test]
    fn router_survives_updates_totally() {
        let mut s = session();
        let eval = s.ds.splits.train.clone();
        let delta = GraphDelta {
            add_edges: vec![(eval[0], eval[5]), (eval[1], eval[6])],
            ..Default::default()
        };
        s.apply(&delta).unwrap();
        let plans = s.setup.cache.len();
        for &u in &eval {
            match s.setup.router.route(u) {
                Route::Cached { plan, pos } => {
                    assert!((plan as usize) < plans, "dangling plan id");
                    assert_eq!(
                        s.setup.cache.output_nodes(plan as usize)[pos as usize],
                        u,
                        "output {u} routed to a plan that does not own it"
                    );
                }
                Route::Cold { .. } => {
                    panic!("output {u} lost warm routing after update")
                }
            }
        }
    }
}
