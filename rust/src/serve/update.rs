//! Dynamic graph updates on the serve path: the snapshot builder
//! (DESIGN.md §10 for the repair math, §11 for the publish protocol).
//!
//! [`UpdateApplier`] owns everything a long-lived deployment *mutates*
//! when the graph churns — the dataset master copy, the
//! [`DynamicGraph`] overlay deltas land on, and the [`DynamicPlanSet`]
//! keeping per-root influence fresh — and turns each delta into a new
//! immutable [`super::state::ServeState`] published through the shared
//! cell. Serving never quiesces: the applier works on its own private
//! state, structurally sharing everything a delta did not touch with
//! the previous snapshot, and the publish is a single pointer swap.
//!
//! One [`UpdateApplier::apply`] runs the full build:
//!
//! 1. the delta lands on the overlay (symmetrize, normalize, epoch++);
//! 2. dataset commit on a copy-on-write master (labels/feature epochs
//!    extend, the overlay splices into a fresh CSR via the shared
//!    snapshot handle);
//! 3. incremental PPR refresh repairs the touched roots, plans past
//!    the L1 tolerance are rebuilt, plans merely containing touched
//!    nodes are patched, their epochs bump;
//! 4. the next snapshot is assembled by **patching** the previous one:
//!    only changed plan buckets get new payloads
//!    ([`DynamicPlanSet::patch_cow`]), the router index and placement
//!    only extend when nodes were appended (outputs never migrate
//!    between plans, so warm routing and plan homes are stable), and
//!    the epoch vector is refreshed;
//! 5. the swap publishes it. In-flight groups finish on the snapshot
//!    they pinned; the epoch-keyed results memo expires their logits
//!    on read, and the serving loop's swap-time
//!    [`super::results::ResultsCache::purge_stale`] sweep reclaims the
//!    bytes eagerly. Cold plans need no invalidation protocol at all:
//!    shards memoize them per (node, epoch), so a new epoch lazily
//!    re-synthesizes against the new graph.
//!
//! [`run_applier`] is the background-thread driver
//! ([`super::service::Churn::Background`] / `Stream`), and
//! [`DynamicServeSession`] the segment-granular harness: the same
//! applier used synchronously between serving segments — which is
//! exactly the quiesced baseline the zero-quiesce bench compares
//! against.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::batching::refresh::{DynamicPlanSet, RefreshConfig};
use crate::batching::CowCache;
use crate::config::preset_for;
use crate::datasets::Dataset;
use crate::graph::delta::{DynamicGraph, GraphDelta};
use crate::graph::GraphView;
use crate::runtime::{ArtifactMeta, ModelState};
use crate::store::PlanStore;
use crate::util::Rng;

use super::load::Skew;
use super::results::ResultsCache;
use super::router::QueryRouter;
use super::service::{
    build_initial_state, serve_closed_loop_with, ServeConfig, ServeReport,
    ServeSetup,
};
use super::state::{ServeState, ServeStateCell};

/// Fold the store's delta log into a fresh manifest generation once
/// this many delta records are pending. Compaction rewrites the
/// manifest off the serve path (readers keep their published view), so
/// the threshold only trades recovery-replay length against rewrite
/// frequency.
const COMPACT_AFTER_DELTAS: usize = 32;

/// Dynamic-update knobs layered on a [`ServeConfig`].
#[derive(Debug, Clone, Copy)]
pub struct UpdateConfig {
    /// Rebuild a plan when its outputs' summed PPR L1 drift exceeds
    /// this (see [`RefreshConfig::l1_tol`]).
    pub l1_tol: f32,
}

impl Default for UpdateConfig {
    fn default() -> Self {
        UpdateConfig { l1_tol: 0.05 }
    }
}

/// What one applied delta did across the whole serve path.
#[derive(Debug, Clone, Default)]
pub struct UpdateReport {
    /// Epoch the delta published as.
    pub epoch: u64,
    /// Existing nodes the delta touched (edges or features).
    pub touched_nodes: usize,
    /// Brand-new nodes the delta introduced.
    pub added_nodes: usize,
    /// Feature rows overwritten in place.
    pub feature_updates: usize,
    /// PPR roots re-solved by the incremental refresh.
    pub roots_refreshed: usize,
    /// Plans in the snapshot after the delta.
    pub plans_total: usize,
    /// Plans rebuilt from scratch (influence set drifted too far).
    pub plans_rebuilt: usize,
    /// Plans patched in place (drift within tolerance).
    pub plans_patched: usize,
    /// Worst per-root L1 drift the refresh observed.
    pub max_root_l1: f32,
    /// Plan buckets whose payload was re-packed into the new snapshot
    /// (0 when the delta was feature-only: epochs move, payloads are
    /// pointer-shared).
    pub buckets_patched: usize,
    /// Router-index slots appended for new nodes (warm entries are
    /// never rewritten — outputs do not migrate between plans).
    pub index_extended: usize,
    /// Seconds in incremental PPR refresh.
    pub refresh_s: f64,
    /// Seconds in plan rebuild/patch.
    pub replan_s: f64,
    /// Seconds committing (CSR splice + snapshot assembly + publish).
    pub commit_s: f64,
    /// Seconds persisting the delta to the attached plan store
    /// (0 when no store is attached).
    pub store_s: f64,
    /// Blobs actually appended to the store (content-new buckets);
    /// structurally shared buckets cost nothing.
    pub store_blobs_written: usize,
}

impl UpdateReport {
    /// Plans whose epoch moved (rebuilt or patched).
    pub fn stale_plans(&self) -> usize {
        self.plans_rebuilt + self.plans_patched
    }

    /// Fraction of the plan set rebuilt from scratch.
    pub fn rebuilt_fraction(&self) -> f64 {
        if self.plans_total == 0 {
            0.0
        } else {
            self.plans_rebuilt as f64 / self.plans_total as f64
        }
    }
}

/// The snapshot builder: private mutable state on one side, published
/// immutable [`ServeState`]s on the other. Runs synchronously (the
/// segmented [`DynamicServeSession`]) or on a background thread
/// ([`run_applier`]) — `apply` is the same either way; only *where the
/// stall lands* differs.
pub struct UpdateApplier {
    /// Master dataset; copy-on-write so each published snapshot owns
    /// an immutable view while the next delta mutates a fresh copy.
    ds: Arc<Dataset>,
    graph: DynamicGraph,
    plans: DynamicPlanSet,
    cell: Arc<ServeStateCell>,
    /// Executor identity (stable across epochs, shared by pointer).
    meta: Arc<ArtifactMeta>,
    model: Arc<ModelState>,
    /// Content-addressed store every published snapshot is mirrored
    /// into incrementally (only content-new buckets are written).
    store: Option<Arc<PlanStore>>,
}

impl UpdateApplier {
    /// The shared cell this applier publishes to.
    pub fn cell(&self) -> Arc<ServeStateCell> {
        self.cell.clone()
    }

    /// Attach a plan store: every subsequent [`UpdateApplier::apply`]
    /// mirrors the published snapshot into it via
    /// [`PlanStore::save_incremental`] — structural sharing means only
    /// buckets with new content hashes hit the disk — and folds the
    /// delta log once it exceeds [`COMPACT_AFTER_DELTAS`] records.
    pub fn attach_store(&mut self, store: Arc<PlanStore>) {
        self.store = Some(store);
    }

    /// The attached plan store, if any.
    pub fn store(&self) -> Option<Arc<PlanStore>> {
        self.store.clone()
    }

    /// Current graph epoch (== the last published snapshot's).
    pub fn epoch(&self) -> u64 {
        self.graph.epoch()
    }

    /// Apply one delta batch and publish the resulting snapshot:
    /// overlay → dataset commit → incremental refresh → structural
    /// patch → pointer swap.
    pub fn apply(&mut self, delta: &GraphDelta) -> Result<UpdateReport> {
        for &l in &delta.add_node_labels {
            anyhow::ensure!(
                (l as usize) < self.ds.num_classes,
                "new-node label {l} >= {} classes",
                self.ds.num_classes
            );
        }
        let applied = self
            .graph
            .apply(delta)
            .map_err(|e| anyhow::anyhow!("bad delta: {e}"))?;

        // dataset commit: the previous snapshot keeps its own Arc, so
        // the next dataset is built as a fresh value — sized vectors
        // are cloned once each, and the CSR is cloned exactly once per
        // delta (for a structural delta that one clone IS the new
        // splice, so the soon-to-be-replaced old adjacency is never
        // copied; one O(m) graph copy per delta is the floor while
        // `Dataset` owns its CSR by value).
        let t_commit = Instant::now();
        let structural =
            !applied.touched.is_empty() || applied.added_nodes > 0;
        {
            let prev_ds = &self.ds;
            let graph = if structural {
                (*self.graph.snapshot_shared()).clone()
            } else {
                prev_ds.graph.clone()
            };
            let mut labels = prev_ds.labels.clone();
            labels.extend(delta.add_node_labels.iter().copied());
            let mut feat_epoch = prev_ds.feat_epoch.clone();
            feat_epoch.resize(labels.len(), 0);
            for &u in &applied.feature_updates {
                feat_epoch[u as usize] += 1;
            }
            self.ds = Arc::new(Dataset {
                name: prev_ds.name.clone(),
                graph,
                labels,
                num_classes: prev_ds.num_classes,
                feat_dim: prev_ds.feat_dim,
                class_means: prev_ds.class_means.clone(),
                noise: prev_ds.noise,
                seed: prev_ds.seed,
                splits: prev_ds.splits.clone(),
                feat_epoch,
            });
        }
        if structural {
            // consume the memoized splice so it is not retained as a
            // third adjacency copy between deltas; holding the last
            // Arc lets the rebase MOVE the CSR instead of cloning it
            let snap = self.graph.take_snapshot();
            if self.graph.overlay_rows() * 4 > self.graph.num_nodes() {
                if let Some(snap) = snap {
                    let g = Arc::try_unwrap(snap)
                        .unwrap_or_else(|shared| (*shared).clone());
                    self.graph.rebase(g);
                }
            }
        }
        let commit_graph_s = t_commit.elapsed().as_secs_f64();

        // incremental influence refresh + staleness-tracked replan
        let refresh = self.plans.apply_delta(&self.ds.graph, &applied);

        // assemble the next snapshot by patching the previous one:
        // only touched buckets are new allocations
        let t_sync = Instant::now();
        let prev = self.cell.load();
        let cache = if structural && !refresh.changed_plans.is_empty() {
            Arc::new(self.plans.patch_cow(&prev.cache, &refresh.changed_plans))
        } else {
            // feature-only (or no-op) delta: payloads are identical,
            // share the whole store — epochs alone carry the staleness
            prev.cache.clone()
        };
        let buckets_patched = if structural {
            refresh.changed_plans.len()
        } else {
            0
        };
        let n = self.ds.graph.num_nodes();
        let index = if applied.added_nodes > 0 {
            Arc::new(prev.index.extended(n))
        } else {
            prev.index.clone()
        };
        let placement = if applied.added_nodes > 0 {
            Arc::new(prev.placement.extended(&self.ds.graph))
        } else {
            prev.placement.clone()
        };
        let next = Arc::new(ServeState {
            epoch: applied.epoch,
            ds: self.ds.clone(),
            cache,
            index,
            epochs: Arc::new(self.plans.epochs().to_vec()),
            placement,
            meta: self.meta.clone(),
            model: self.model.clone(),
            store: prev.store.clone(),
        });
        debug_assert!(next.validate().is_ok(), "{:?}", next.validate());
        self.cell.store(next.clone());
        let commit_s = commit_graph_s + t_sync.elapsed().as_secs_f64();

        // persistence mirror: append only content-new buckets + one
        // manifest delta record, off the publish path (readers already
        // have the new snapshot). A full delta log folds into a fresh
        // manifest generation without blocking serve.
        let mut store_s = 0.0;
        let mut store_blobs_written = 0usize;
        if let Some(store) = &self.store {
            let t_store = Instant::now();
            let packed = next.index.to_packed();
            let router_ext = &packed[prev.index.len().min(packed.len())..];
            let stats = store.save_incremental(
                &prev.cache,
                &next.cache,
                &next.epochs,
                applied.epoch,
                router_ext,
            )?;
            store_blobs_written = stats.blobs_written;
            if store.pending_delta_records() > COMPACT_AFTER_DELTAS {
                store.compact()?;
            }
            store_s = t_store.elapsed().as_secs_f64();
        }

        Ok(UpdateReport {
            epoch: applied.epoch,
            touched_nodes: applied.touched.len(),
            added_nodes: applied.added_nodes,
            feature_updates: applied.feature_updates.len(),
            roots_refreshed: refresh.roots_refreshed,
            plans_total: refresh.plans_total,
            plans_rebuilt: refresh.plans_rebuilt,
            plans_patched: refresh.plans_patched,
            max_root_l1: refresh.max_root_l1,
            buckets_patched,
            index_extended: applied.added_nodes,
            refresh_s: refresh.refresh_s,
            replan_s: refresh.replan_s,
            commit_s,
            store_s,
            store_blobs_written,
        })
    }
}

/// Background-thread driver: apply deltas as they arrive on `rx`,
/// publishing one snapshot each, until `stop` is set or the sender
/// hangs up. A closed channel drains its backlog before the thread
/// exits, so a caller that feeds N deltas and drops the sender gets N
/// snapshots; the stop flag is the early-exit path for external
/// streams that never close. A delta the graph rejects is logged and
/// skipped — serving must outlive a malformed update.
pub fn run_applier(
    applier: &mut UpdateApplier,
    rx: mpsc::Receiver<GraphDelta>,
    stop: &AtomicBool,
    reports: mpsc::Sender<UpdateReport>,
) {
    loop {
        // checked every iteration (not only on idle timeouts): a
        // stream that sends faster than the timeout must still stop
        if stop.load(Ordering::Acquire) {
            break;
        }
        match rx.recv_timeout(Duration::from_millis(20)) {
            Ok(delta) => match applier.apply(&delta) {
                Ok(report) => {
                    let _ = reports.send(report);
                }
                Err(e) => {
                    eprintln!("update applier: skipping bad delta: {e}");
                }
            },
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }
}

/// A serving deployment that admits graph deltas **between** serving
/// segments — the quiesced harness. It wires the same
/// [`UpdateApplier`] and snapshot cell the zero-quiesce path uses, so
/// `ibmb serve --update-stream` (segmented) and `--live-updates`
/// (mid-traffic) differ only in *when* `apply` runs relative to
/// queries.
pub struct DynamicServeSession {
    /// The snapshot builder segments feed deltas through.
    pub applier: UpdateApplier,
    /// The deployment handle segments serve against.
    pub setup: ServeSetup,
    /// Session-lifetime results memo (shared across segments).
    pub memo: ResultsCache,
    cfg: ServeConfig,
    /// Segments served so far — folded into each segment's load seed
    /// so successive segments draw fresh query streams instead of
    /// replaying segment 0's.
    segments: u64,
}

impl DynamicServeSession {
    /// Plan `eval_nodes` with the dataset preset (same planner inputs
    /// as [`super::service::prepare`], but retaining the per-root PPR
    /// states for incremental repair), synthesize the executor model,
    /// and publish the epoch-0 snapshot. The rebuild node budget is
    /// clamped to the artifact bucket so replanned batches keep
    /// fitting the arenas.
    pub fn prepare(
        ds: Dataset,
        eval_nodes: &[u32],
        cfg: &ServeConfig,
        ucfg: &UpdateConfig,
    ) -> DynamicServeSession {
        let p = preset_for(&ds.name);
        let rcfg = RefreshConfig {
            aux_per_output: p.aux_per_output,
            max_outputs_per_batch: p.outputs_per_batch,
            node_budget: p.node_budget,
            l1_tol: ucfg.l1_tol,
            ..Default::default()
        };
        let mut rng = Rng::new(cfg.seed ^ 0xCAFE);
        let mut plans =
            DynamicPlanSet::plan_initial(&ds.graph, eval_nodes, rcfg, &mut rng);
        let cow = plans.cow_cache();
        let ds = Arc::new(ds);
        let (cell, meta, model) =
            build_initial_state(ds.clone(), cow, cfg, None);
        plans.clamp_node_budget(meta.n_pad);
        let graph = DynamicGraph::new(ds.graph.clone());
        let applier = UpdateApplier {
            ds,
            graph,
            plans,
            cell: cell.clone(),
            meta,
            model,
            store: None,
        };
        let memo = ResultsCache::new(cfg.results_cache_bytes, cfg.results_ttl);
        DynamicServeSession {
            applier,
            setup: ServeSetup {
                cell,
                router: QueryRouter::new(),
                tracer: crate::telemetry::Tracer::disabled(),
            },
            memo,
            cfg: cfg.clone(),
            segments: 0,
        }
    }

    /// Apply one delta batch synchronously (between segments) and
    /// eagerly sweep the session memo against the new snapshot — in
    /// live mode the serving loop performs the same sweep when it
    /// observes the swap.
    pub fn apply(&mut self, delta: &GraphDelta) -> Result<UpdateReport> {
        let report = self.applier.apply(delta)?;
        let state = self.setup.cell.load();
        self.memo.purge_stale(move |k| state.plan_epoch(k));
        Ok(report)
    }

    /// Serve one closed-loop segment against the current snapshot,
    /// reusing the session memo. `queries` overrides the config count
    /// (segmented streams split a total budget).
    pub fn serve_segment(
        &mut self,
        population: &[u32],
        skew: Skew,
        queries: usize,
    ) -> Result<ServeReport> {
        self.segments += 1;
        let cfg = ServeConfig {
            queries,
            // distinct load/shard RNG streams per segment — otherwise
            // every post-delta segment replays segment 0's queries and
            // the memo flatters the reported hit rate
            seed: self
                .cfg
                .seed
                .wrapping_add(self.segments.wrapping_mul(0x9E3779B97F4A7C15)),
            ..self.cfg.clone()
        };
        serve_closed_loop_with(
            &mut self.setup,
            population,
            skew,
            &cfg,
            &mut self.memo,
        )
    }

    /// The currently published snapshot.
    pub fn state(&self) -> Arc<ServeState> {
        self.setup.cell.load()
    }

    /// The session's current plan cache (inspection/tests).
    pub fn cache(&self) -> Arc<CowCache> {
        self.state().cache.clone()
    }

    /// The session's current dataset view (inspection/tests).
    pub fn dataset(&self) -> Arc<Dataset> {
        self.state().ds.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::router::Route;

    fn session() -> DynamicServeSession {
        let ds = crate::datasets::sbm::generate(
            &crate::datasets::DatasetSpec::tiny_for_tests(),
            33,
        );
        let cfg = ServeConfig {
            queries: 48,
            clients: 8,
            shards: 2,
            results_cache_bytes: 1 << 20,
            flush_window: Duration::from_micros(200),
            ..Default::default()
        };
        let eval = ds.splits.train.clone();
        DynamicServeSession::prepare(ds, &eval, &cfg, &UpdateConfig::default())
    }

    #[test]
    fn prepare_publishes_a_valid_epoch0_snapshot() {
        let s = session();
        let state = s.state();
        assert!(!state.cache.is_empty());
        assert_eq!(state.epoch, 0);
        assert_eq!(state.epochs.len(), state.cache.len());
        assert!(state.epochs.iter().all(|&e| e == 0));
        assert_eq!(s.applier.epoch(), 0);
        state.validate().unwrap();
    }

    #[test]
    fn apply_publishes_a_patched_snapshot_with_structural_sharing() {
        let mut s = session();
        let before = s.state();
        let eval = s.dataset().splits.train.clone();
        let delta = GraphDelta {
            add_edges: vec![(eval[0], eval[1]), (eval[2], eval[3])],
            add_node_labels: vec![0],
            feature_updates: vec![eval[4]],
            ..Default::default()
        };
        let report = s.applier.apply(&delta).unwrap();
        assert_eq!(report.epoch, 1);
        assert!(report.stale_plans() > 0);
        assert!(report.rebuilt_fraction() < 1.0);
        assert_eq!(report.buckets_patched, report.stale_plans());
        assert_eq!(report.index_extended, 1);

        let after = s.state();
        assert_eq!(after.epoch, 1);
        after.validate().unwrap();
        // the old snapshot is untouched — in-flight readers are safe
        assert_eq!(before.epoch, 0);
        before.validate().unwrap();
        assert_eq!(
            before.ds.graph.num_nodes() + 1,
            after.ds.graph.num_nodes()
        );
        // untouched buckets are pointer-shared between the snapshots
        let shared = after.cache.shared_with(&before.cache);
        assert_eq!(shared.buckets, after.cache.len() - report.stale_plans());
        assert!(shared.bytes > 0, "shared buckets must carry bytes");
        assert!(shared.bytes < after.cache.memory_bytes());
        // changed plans carry the new epoch, unchanged keep the old
        for (pid, (&a, &b)) in
            after.epochs.iter().zip(before.epochs.iter()).enumerate()
        {
            assert!(a == b || a == 1, "plan {pid}: {b} -> {a}");
        }
        assert_eq!(after.ds.labels.len(), after.ds.graph.num_nodes());
        assert_eq!(after.ds.feat_epoch[eval[4] as usize], 1);
    }

    #[test]
    fn feature_only_delta_shares_every_bucket() {
        let mut s = session();
        let before = s.state();
        let eval = s.dataset().splits.train.clone();
        let report = s
            .apply(&GraphDelta {
                feature_updates: vec![eval[0]],
                ..Default::default()
            })
            .unwrap();
        assert_eq!(report.plans_rebuilt, 0);
        assert!(report.plans_patched > 0, "feature epoch must stale plans");
        assert_eq!(report.buckets_patched, 0, "payloads must be shared");
        let after = s.state();
        assert_eq!(
            after.cache.shared_with(&before.cache).buckets,
            after.cache.len(),
            "feature-only delta must share the whole plan store"
        );
        // ... while the epochs still record the staleness
        assert!(after
            .epochs
            .iter()
            .zip(before.epochs.iter())
            .any(|(&a, &b)| a > b));
    }

    #[test]
    fn apply_then_serve_round_trips() {
        let mut s = session();
        let eval = s.dataset().splits.train.clone();
        let before = s.serve_segment(&eval, Skew::Uniform, 32).unwrap();
        assert_eq!(before.queries, 32);

        let delta = GraphDelta {
            add_edges: vec![(eval[0], eval[1]), (eval[2], eval[3])],
            add_node_labels: vec![0],
            feature_updates: vec![eval[4]],
            ..Default::default()
        };
        let report = s.apply(&delta).unwrap();
        assert_eq!(report.epoch, 1);
        assert!(report.stale_plans() > 0);

        let after = s.serve_segment(&eval, Skew::Uniform, 32).unwrap();
        assert_eq!(
            after.executed_queries + after.cache_hits,
            32,
            "updates must not lose queries"
        );
        assert_eq!(after.final_epoch, 1);
        // the appended node is serveable via the cold path
        let new_node = (s.dataset().graph.num_nodes() - 1) as u32;
        let pop = [new_node];
        let cold = s.serve_segment(&pop, Skew::Uniform, 4).unwrap();
        assert_eq!(cold.executed_queries + cold.cache_hits, 4);
        assert!(cold.cold_routes > 0);
    }

    #[test]
    fn bad_deltas_are_rejected_atomically() {
        let mut s = session();
        let n = s.dataset().graph.num_nodes() as u32;
        assert!(s
            .apply(&GraphDelta {
                add_edges: vec![(0, n + 5)],
                ..Default::default()
            })
            .is_err());
        assert!(s
            .apply(&GraphDelta {
                add_node_labels: vec![u16::MAX],
                ..Default::default()
            })
            .is_err());
        assert_eq!(s.applier.epoch(), 0);
        let state = s.state();
        assert_eq!(state.epoch, 0, "no snapshot published on failure");
        assert_eq!(state.epochs.iter().max().copied().unwrap_or(0), 0);
    }

    #[test]
    fn warm_routing_stays_total_across_updates() {
        let mut s = session();
        let eval = s.dataset().splits.train.clone();
        let delta = GraphDelta {
            add_edges: vec![(eval[0], eval[5]), (eval[1], eval[6])],
            ..Default::default()
        };
        s.apply(&delta).unwrap();
        let state = s.state();
        let plans = state.cache.len();
        for &u in &eval {
            match s.setup.router.route(&state.index, u) {
                Route::Cached { plan, pos } => {
                    assert!((plan as usize) < plans, "dangling plan id");
                    assert_eq!(
                        state.cache.output_nodes(plan as usize)[pos as usize],
                        u,
                        "output {u} routed to a plan that does not own it"
                    );
                }
                Route::Cold { .. } => {
                    panic!("output {u} lost warm routing after update")
                }
            }
        }
    }

    #[test]
    fn background_applier_drains_queue_then_stops() {
        let mut s = session();
        let eval = s.dataset().splits.train.clone();
        let (tx, rx) = mpsc::channel::<GraphDelta>();
        let (rep_tx, rep_rx) = mpsc::channel::<UpdateReport>();
        let stop = AtomicBool::new(false);
        for i in 0..3u32 {
            tx.send(GraphDelta {
                add_edges: vec![(
                    eval[i as usize],
                    eval[(i + 7) as usize],
                )],
                ..Default::default()
            })
            .unwrap();
        }
        // a malformed delta must be skipped, not kill the applier
        tx.send(GraphDelta {
            add_edges: vec![(0, u32::MAX)],
            ..Default::default()
        })
        .unwrap();
        drop(tx);
        std::thread::scope(|scope| {
            let applier = &mut s.applier;
            let h = scope
                .spawn(move || run_applier(applier, rx, &stop, rep_tx));
            h.join().unwrap();
        });
        let reports: Vec<UpdateReport> = rep_rx.try_iter().collect();
        assert_eq!(reports.len(), 3, "3 good deltas, 1 skipped");
        assert_eq!(s.applier.epoch(), 3);
        assert_eq!(s.state().epoch, 3);
        s.state().validate().unwrap();
    }
}
