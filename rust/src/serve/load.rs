//! Closed-loop load generation with configurable arrival skew.
//!
//! Production inference traffic is rarely uniform: a minority of
//! entities absorbs most queries. The generator supports a zipf
//! popularity law over the query population (rank r drawn with
//! probability ∝ 1/rᶜ) next to a uniform baseline, which is exactly
//! the knob that separates "coalescing pays off" from "every query is
//! its own batch" in `benches/serving.rs`.

use crate::util::Rng;

/// Arrival skew over the query population.
#[derive(Debug, Clone, Copy)]
pub enum Skew {
    /// Every population node equally likely.
    Uniform,
    /// Zipf with the given exponent (> 0; ~1.0–1.5 is web-like).
    Zipf(f64),
}

impl Skew {
    /// Parse a CLI spelling: "uniform" or "zipf" (with `exponent`).
    /// Unknown names and non-positive / non-finite exponents are
    /// rejected so a typo doesn't silently benchmark the wrong
    /// arrival distribution.
    pub fn from_name(name: &str, exponent: f64) -> Option<Skew> {
        if name.eq_ignore_ascii_case("uniform") {
            Some(Skew::Uniform)
        } else if name.eq_ignore_ascii_case("zipf")
            && exponent.is_finite()
            && exponent > 0.0
        {
            Some(Skew::Zipf(exponent))
        } else {
            None
        }
    }

    /// Human label for reports and bench JSON (e.g. `zipf(1.20)`).
    pub fn label(&self) -> String {
        match self {
            Skew::Uniform => "uniform".to_string(),
            Skew::Zipf(s) => format!("zipf({s:.2})"),
        }
    }
}

/// One generated arrival: the queried node plus the tenant issuing
/// it (tenant ids feed the admission gate's per-tenant token buckets).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Arrival {
    /// Queried output node.
    pub node: u32,
    /// Issuing tenant (0-based, `< LoadGen::tenants`).
    pub tenant: u16,
}

/// Seeded query-node sampler. Zipf rank r (0-based) maps to
/// `nodes[r]`, so the head of the population list is the hot set.
pub struct LoadGen {
    nodes: Vec<u32>,
    /// Normalized CDF over ranks (empty for uniform).
    cdf: Vec<f64>,
    rng: Rng,
    tenants: u16,
}

impl LoadGen {
    /// Single-tenant sampler over `nodes` with the given skew.
    pub fn new(nodes: &[u32], skew: Skew, seed: u64) -> LoadGen {
        LoadGen::with_tenants(nodes, skew, 1, seed)
    }

    /// Like [`LoadGen::new`] with arrivals spread uniformly over
    /// `tenants` logical tenants. With a single tenant the rng draw
    /// for the tenant id is skipped entirely, so the node sequence is
    /// bit-identical to the tenant-less generator.
    pub fn with_tenants(
        nodes: &[u32],
        skew: Skew,
        tenants: usize,
        seed: u64,
    ) -> LoadGen {
        assert!(!nodes.is_empty(), "empty query population");
        let cdf = match skew {
            Skew::Uniform => Vec::new(),
            Skew::Zipf(s) => {
                let mut acc = 0.0;
                let mut cdf = Vec::with_capacity(nodes.len());
                for r in 0..nodes.len() {
                    acc += 1.0 / ((r + 1) as f64).powf(s);
                    cdf.push(acc);
                }
                for c in cdf.iter_mut() {
                    *c /= acc;
                }
                cdf
            }
        };
        LoadGen {
            nodes: nodes.to_vec(),
            cdf,
            rng: Rng::new(seed),
            tenants: tenants.clamp(1, u16::MAX as usize) as u16,
        }
    }

    /// Draw the next query node.
    pub fn next_node(&mut self) -> u32 {
        if self.cdf.is_empty() {
            return self.nodes[self.rng.next_below(self.nodes.len())];
        }
        let u = self.rng.next_f64();
        let r = self.cdf.partition_point(|&c| c < u);
        self.nodes[r.min(self.nodes.len() - 1)]
    }

    /// Draw the next arrival (node + issuing tenant).
    pub fn next_arrival(&mut self) -> Arrival {
        let node = self.next_node();
        let tenant = if self.tenants <= 1 {
            0
        } else {
            self.rng.next_below(self.tenants as usize) as u16
        };
        Arrival { node, tenant }
    }

    /// Number of distinct sampleable nodes.
    pub fn population(&self) -> usize {
        self.nodes.len()
    }

    /// Number of logical tenants arrivals are spread over.
    pub fn tenants(&self) -> usize {
        self.tenants as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_covers_population() {
        let nodes: Vec<u32> = (100..110).collect();
        let mut g = LoadGen::new(&nodes, Skew::Uniform, 1);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..1000 {
            let v = g.next_node();
            assert!(nodes.contains(&v));
            seen.insert(v);
        }
        assert_eq!(seen.len(), nodes.len());
    }

    #[test]
    fn zipf_concentrates_on_head_ranks() {
        let nodes: Vec<u32> = (0..100).collect();
        let mut g = LoadGen::new(&nodes, Skew::Zipf(1.3), 2);
        let mut counts = vec![0usize; 100];
        for _ in 0..20_000 {
            counts[g.next_node() as usize] += 1;
        }
        let head: usize = counts[..10].iter().sum();
        let tail: usize = counts[90..].iter().sum();
        assert!(
            head > 5 * tail.max(1),
            "head {head} should dominate tail {tail}"
        );
        assert!(counts[0] > counts[50], "{:?}", &counts[..5]);
    }

    #[test]
    fn tenants_cover_range_and_single_tenant_matches_tenantless() {
        let nodes: Vec<u32> = (0..20).collect();
        let mut g = LoadGen::with_tenants(&nodes, Skew::Uniform, 3, 7);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..500 {
            let a = g.next_arrival();
            assert!(a.tenant < 3);
            seen.insert(a.tenant);
        }
        assert_eq!(seen.len(), 3, "all tenants drawn");
        // tenants=1 must not perturb the node stream
        let mut plain = LoadGen::new(&nodes, Skew::Zipf(1.2), 9);
        let mut tagged = LoadGen::with_tenants(&nodes, Skew::Zipf(1.2), 1, 9);
        for _ in 0..200 {
            let a = tagged.next_arrival();
            assert_eq!(a.node, plain.next_node());
            assert_eq!(a.tenant, 0);
        }
        assert_eq!(tagged.tenants(), 1);
    }

    #[test]
    fn skew_parsing() {
        assert!(matches!(
            Skew::from_name("uniform", 1.1),
            Some(Skew::Uniform)
        ));
        assert!(matches!(
            Skew::from_name("Uniform", 1.1),
            Some(Skew::Uniform)
        ));
        match Skew::from_name("zipf", 1.4) {
            Some(Skew::Zipf(s)) => assert!((s - 1.4).abs() < 1e-12),
            other => panic!("{other:?}"),
        }
        assert!(Skew::from_name("unifrom", 1.1).is_none(), "typo rejected");
        assert!(Skew::from_name("zipf", 0.0).is_none(), "s=0 rejected");
        assert!(Skew::from_name("zipf", -1.2).is_none(), "s<0 rejected");
        assert!(Skew::from_name("zipf", f64::NAN).is_none());
        assert_eq!(Skew::Uniform.label(), "uniform");
        assert_eq!(Skew::Zipf(1.2).label(), "zipf(1.20)");
    }
}
