//! Influence-routed query router.
//!
//! Routing exploits the structural fact the paper's inference numbers
//! rest on: IBMB's output partition is *disjoint and covering*, so
//! every serveable node belongs to exactly one precomputed plan. The
//! router inverts that mapping once — node id → (plan id, position
//! among the plan's outputs) — into a flat array, making the hot-path
//! lookup one bounds-checked load.
//!
//! Nodes outside every plan (new nodes, non-eval splits) take the
//! **cold path**: the router assigns the node a stable cold-plan id so
//! concurrent and repeat cold queries coalesce exactly like warm ones,
//! and the node's home shard synthesizes (and memoizes) the actual
//! top-k-PPR plan off the control loop —
//! [`super::shard::synthesize_cold`]. Keeping synthesis off this
//! thread means a trickle of cold traffic cannot stall deadline
//! flushes for in-flight warm queries.

use std::collections::HashMap;

use crate::batching::BatchCache;
use crate::datasets::Dataset;

/// Identity of an executable plan: a precomputed cache entry or a
/// cold plan (keyed by router-assigned id). The coalescing queue and
/// the results memo key on this.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum PlanKey {
    /// Index into the [`BatchCache`].
    Cached(u32),
    /// Router-assigned id of a cold (shard-synthesized) plan.
    Cold(u32),
}

/// Routing decision for one query node.
#[derive(Debug, Clone, Copy)]
pub enum Route {
    /// Covered by precomputed plan `plan`; the node is output number
    /// `pos` of that plan (its logits row after execution).
    Cached { plan: u32, pos: u32 },
    /// Served by a cold plan (node = output 0), synthesized lazily on
    /// the node's home shard.
    Cold { id: u32 },
}

impl Route {
    pub fn key(&self) -> PlanKey {
        match self {
            Route::Cached { plan, .. } => PlanKey::Cached(*plan),
            Route::Cold { id } => PlanKey::Cold(*id),
        }
    }

    /// Output-row position of the query node within its plan.
    pub fn pos(&self) -> u32 {
        match self {
            Route::Cached { pos, .. } => *pos,
            Route::Cold { .. } => 0,
        }
    }
}

/// Packed `(plan << 32) | pos`; `u64::MAX` = not covered by any plan.
const ABSENT: u64 = u64::MAX;

/// Safety cap on the cold-id map: past this many distinct cold nodes
/// the map is reset. Ids keep incrementing, so a re-queried node gets
/// a fresh id and its stale memo entries simply age out of the
/// results cache; only coalescing continuity is briefly lost.
const MAX_COLD_IDS: usize = 1 << 20;

/// Output-node → plan inverted index plus stable cold-plan ids.
pub struct QueryRouter {
    index: Vec<u64>,
    cold: HashMap<u32, u32>,
    /// Output nodes that appeared in more than one plan while building
    /// the index (0 for a valid IBMB partition).
    pub duplicates: usize,
    /// Cold-plan ids handed out so far.
    pub cold_built: usize,
    /// Entries (warm slots + cold ids) dropped by graph-delta
    /// invalidation.
    pub invalidations: usize,
}

impl QueryRouter {
    /// Invert `cache`'s output lists over `ds`'s node id space.
    pub fn build(ds: &Dataset, cache: &BatchCache) -> QueryRouter {
        let n = ds.graph.num_nodes();
        let mut index = vec![ABSENT; n];
        let mut duplicates = 0usize;
        for pid in 0..cache.len() {
            for (pos, &u) in cache.output_nodes(pid).iter().enumerate() {
                let slot = &mut index[u as usize];
                if *slot != ABSENT {
                    duplicates += 1;
                    continue;
                }
                *slot = ((pid as u64) << 32) | pos as u64;
            }
        }
        QueryRouter {
            index,
            cold: HashMap::new(),
            duplicates,
            cold_built: 0,
            invalidations: 0,
        }
    }

    /// Drop the warm-index entries of `outputs` (a plan being retired
    /// or replanned). Until [`Self::index_plan`] re-registers them the
    /// nodes take the cold path — never a dangling plan id.
    pub fn invalidate_outputs(&mut self, outputs: &[u32]) -> usize {
        let mut dropped = 0;
        for &u in outputs {
            if let Some(slot) = self.index.get_mut(u as usize) {
                if *slot != ABSENT {
                    *slot = ABSENT;
                    dropped += 1;
                }
            }
        }
        self.invalidations += dropped;
        dropped
    }

    /// (Re-)register plan `pid`'s output nodes in the warm index,
    /// clearing any cold id the nodes may have picked up while
    /// unrouted. Slots already owned by another plan are counted as
    /// duplicates, as in [`Self::build`].
    pub fn index_plan(&mut self, pid: u32, outputs: &[u32]) {
        for (pos, &u) in outputs.iter().enumerate() {
            match self.index.get_mut(u as usize) {
                Some(slot) if *slot == ABSENT => {
                    *slot = ((pid as u64) << 32) | pos as u64;
                    self.cold.remove(&u);
                }
                Some(_) => self.duplicates += 1,
                None => self.duplicates += 1,
            }
        }
    }

    /// Forget the cold-plan ids of `nodes` (their synthesized
    /// neighborhoods went stale under a graph delta). The next query
    /// gets a *fresh* id, so shards re-synthesize against the new
    /// graph and memo entries for the old id become unreachable.
    pub fn invalidate_cold(&mut self, nodes: &[u32]) -> usize {
        let mut dropped = 0;
        for u in nodes {
            if self.cold.remove(u).is_some() {
                dropped += 1;
            }
        }
        self.invalidations += dropped;
        dropped
    }

    /// Number of nodes covered by a precomputed plan.
    pub fn coverage(&self) -> usize {
        self.index.iter().filter(|&&p| p != ABSENT).count()
    }

    /// Route a query node: cached-plan lookup, else a memoized cold id
    /// (assigning a fresh one is the only mutating case).
    pub fn route(&mut self, node: u32) -> Route {
        if let Some(&packed) = self.index.get(node as usize) {
            if packed != ABSENT {
                return Route::Cached {
                    plan: (packed >> 32) as u32,
                    pos: (packed & u32::MAX as u64) as u32,
                };
            }
        }
        if let Some(&id) = self.cold.get(&node) {
            return Route::Cold { id };
        }
        if self.cold.len() >= MAX_COLD_IDS {
            self.cold.clear();
        }
        let id = self.cold_built as u32;
        self.cold_built += 1;
        self.cold.insert(node, id);
        Route::Cold { id }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batching::{BatchGenerator, NodeWiseIbmb};
    use crate::datasets::{sbm, DatasetSpec};
    use crate::util::Rng;

    fn setup() -> (Dataset, BatchCache) {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 77);
        let mut g = NodeWiseIbmb {
            aux_per_output: 6,
            max_outputs_per_batch: 40,
            node_budget: 256,
            ..Default::default()
        };
        let mut rng = Rng::new(3);
        let out = ds.splits.train.clone();
        let cache = BatchCache::build(&g.plan(&ds, &out, &mut rng));
        (ds, cache)
    }

    #[test]
    fn every_output_node_routes_to_its_plan() {
        let (ds, cache) = setup();
        let mut router = QueryRouter::build(&ds, &cache);
        assert_eq!(router.duplicates, 0);
        assert_eq!(router.coverage(), ds.splits.train.len());
        for &u in &ds.splits.train {
            match router.route(u) {
                Route::Cached { plan, pos } => {
                    assert_eq!(
                        cache.output_nodes(plan as usize)[pos as usize],
                        u
                    );
                }
                Route::Cold { .. } => panic!("train node {u} went cold"),
            }
        }
        assert_eq!(router.cold_built, 0);
    }

    #[test]
    fn uncovered_nodes_get_stable_cold_ids() {
        let (ds, cache) = setup();
        let mut router = QueryRouter::build(&ds, &cache);
        let covered: std::collections::HashSet<u32> =
            ds.splits.train.iter().copied().collect();
        let mut cold_nodes = (0..ds.graph.num_nodes() as u32)
            .filter(|u| !covered.contains(u));
        let a = cold_nodes.next().expect("tiny split leaves cold nodes");
        let b = cold_nodes.next().expect("need two cold nodes");
        let ra = router.route(a);
        let rb = router.route(b);
        let ra2 = router.route(a);
        match (ra, rb, ra2) {
            (
                Route::Cold { id: ia },
                Route::Cold { id: ib },
                Route::Cold { id: ia2 },
            ) => {
                assert_eq!(ia, ia2, "cold id must be memoized per node");
                assert_ne!(ia, ib, "distinct nodes get distinct cold ids");
            }
            other => panic!("expected cold routes, got {other:?}"),
        }
        assert_eq!(router.cold_built, 2);
        assert_eq!(router.route(a).pos(), 0);
    }

    #[test]
    fn invalidation_retires_and_reindexes_entries() {
        let (ds, cache) = setup();
        let mut router = QueryRouter::build(&ds, &cache);
        let outputs = cache.output_nodes(0).to_vec();
        let dropped = router.invalidate_outputs(&outputs);
        assert_eq!(dropped, outputs.len());
        assert_eq!(router.invalidations, outputs.len());
        // unrouted outputs fall back to the cold path, never a stale id
        match router.route(outputs[0]) {
            Route::Cold { .. } => {}
            other => panic!("expected cold after invalidation, got {other:?}"),
        }
        // re-registering restores warm routing and clears the cold id
        router.index_plan(0, &outputs);
        match router.route(outputs[0]) {
            Route::Cached { plan, pos } => {
                assert_eq!(plan, 0);
                assert_eq!(cache.output_nodes(0)[pos as usize], outputs[0]);
            }
            other => panic!("expected warm after reindex, got {other:?}"),
        }
        assert_eq!(router.coverage(), ds.splits.train.len());
    }

    #[test]
    fn cold_invalidation_hands_out_fresh_ids() {
        let (ds, cache) = setup();
        let mut router = QueryRouter::build(&ds, &cache);
        let covered: std::collections::HashSet<u32> =
            ds.splits.train.iter().copied().collect();
        let node = (0..ds.graph.num_nodes() as u32)
            .find(|u| !covered.contains(u))
            .unwrap();
        let before = match router.route(node) {
            Route::Cold { id } => id,
            other => panic!("{other:?}"),
        };
        assert_eq!(router.invalidate_cold(&[node]), 1);
        assert_eq!(router.invalidate_cold(&[node]), 0, "already dropped");
        match router.route(node) {
            Route::Cold { id } => {
                assert_ne!(id, before, "stale cold plan must not be reused")
            }
            other => panic!("{other:?}"),
        }
    }
}
