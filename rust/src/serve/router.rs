//! Influence-routed query router.
//!
//! Routing exploits the structural fact the paper's inference numbers
//! rest on: IBMB's output partition is *disjoint and covering*, so
//! every serveable node belongs to exactly one precomputed plan. The
//! [`RouterIndex`] inverts that mapping once — node id → (plan id,
//! position among the plan's outputs) — into a flat array, making the
//! hot-path lookup one bounds-checked load. The index is **immutable**
//! and lives inside the serving snapshot
//! ([`super::state::ServeState`]): because outputs never migrate
//! between plans across graph deltas (DESIGN.md §10), the only patch a
//! delta ever needs is [`RouterIndex::extended`] for appended nodes —
//! a clone + tail fill, structurally cheap. The packed form round-trips
//! through the `IBMBCACH` container ([`crate::batching::cache_io`]) so
//! a cold-started server skips the build entirely.
//!
//! Nodes outside every plan (new nodes, non-eval splits) take the
//! **cold path**: the [`QueryRouter`] — the only mutable routing state,
//! owned by the single-threaded control loop — assigns the node a
//! stable cold-plan id so concurrent and repeat cold queries coalesce
//! exactly like warm ones, and the node's home shard synthesizes (and
//! memoizes per epoch) the actual top-k-PPR plan off the control loop —
//! [`super::shard::synthesize_cold`]. Cold ids are pure coalescing
//! identities: plan *content* is derived from whatever snapshot a
//! group was admitted under, so stale ids never need invalidating.

use std::collections::HashMap;

use crate::batching::CowCache;

/// Identity of an executable plan: a precomputed cache entry or a
/// cold plan (keyed by router-assigned id). The coalescing queue and
/// the results memo key on this (plus an epoch).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum PlanKey {
    /// Index into the snapshot's plan cache.
    Cached(u32),
    /// Router-assigned id of a cold (shard-synthesized) plan.
    Cold(u32),
}

/// Routing decision for one query node.
#[derive(Debug, Clone, Copy)]
pub enum Route {
    /// Covered by precomputed plan `plan`; the node is output number
    /// `pos` of that plan (its logits row after execution).
    Cached { plan: u32, pos: u32 },
    /// Served by a cold plan (node = output 0), synthesized lazily on
    /// the node's home shard.
    Cold { id: u32 },
}

impl Route {
    /// The plan key queries coalesce under.
    pub fn key(&self) -> PlanKey {
        match self {
            Route::Cached { plan, .. } => PlanKey::Cached(*plan),
            Route::Cold { id } => PlanKey::Cold(*id),
        }
    }

    /// Output-row position of the query node within its plan.
    pub fn pos(&self) -> u32 {
        match self {
            Route::Cached { pos, .. } => *pos,
            Route::Cold { .. } => 0,
        }
    }
}

/// Packed `(plan << 32) | pos`; `u64::MAX` = not covered by any plan.
const ABSENT: u64 = u64::MAX;

/// Safety cap on the cold-id map: past this many distinct cold nodes
/// the map is reset. Ids keep incrementing, so a re-queried node gets
/// a fresh id and its stale memo entries simply age out of the
/// results cache; only coalescing continuity is briefly lost.
const MAX_COLD_IDS: usize = 1 << 20;

/// Immutable warm index: output node → packed (plan, pos).
#[derive(Debug, Clone)]
pub struct RouterIndex {
    index: Vec<u64>,
    /// Output nodes that appeared in more than one plan while building
    /// the index (0 for a valid IBMB partition).
    pub duplicates: usize,
}

impl RouterIndex {
    /// Invert `cache`'s output lists over a `num_nodes`-wide id space.
    pub fn build(num_nodes: usize, cache: &CowCache) -> RouterIndex {
        let mut index = vec![ABSENT; num_nodes];
        let mut duplicates = 0usize;
        for pid in 0..cache.len() {
            for (pos, &u) in cache.output_nodes(pid).iter().enumerate() {
                match index.get_mut(u as usize) {
                    Some(slot) if *slot == ABSENT => {
                        *slot = ((pid as u64) << 32) | pos as u64;
                    }
                    _ => duplicates += 1,
                }
            }
        }
        RouterIndex { index, duplicates }
    }

    /// Warm lookup: `Some((plan, pos))` when a precomputed plan owns
    /// the node.
    #[inline]
    pub fn lookup(&self, node: u32) -> Option<(u32, u32)> {
        match self.index.get(node as usize) {
            Some(&packed) if packed != ABSENT => {
                Some(((packed >> 32) as u32, (packed & u32::MAX as u64) as u32))
            }
            _ => None,
        }
    }

    /// Node-id space the index covers.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    /// True for an index over an empty node-id space.
    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Number of nodes covered by a precomputed plan.
    pub fn coverage(&self) -> usize {
        self.index.iter().filter(|&&p| p != ABSENT).count()
    }

    /// The next snapshot's index after `num_nodes` grew (node
    /// appends): same warm entries, fresh `ABSENT` tail. The only
    /// index patch deltas ever need, because outputs never migrate.
    pub fn extended(&self, num_nodes: usize) -> RouterIndex {
        debug_assert!(num_nodes >= self.index.len());
        let mut index = self.index.clone();
        index.resize(num_nodes.max(index.len()), ABSENT);
        RouterIndex {
            index,
            duplicates: self.duplicates,
        }
    }

    /// Packed on-disk form (one u64 per node), for the `IBMBCACH`
    /// router-index section.
    pub fn to_packed(&self) -> Vec<u64> {
        self.index.clone()
    }

    /// Rebuild from the packed form, verifying every warm entry
    /// against `cache` so a mismatched cache/index pair is a clean
    /// load error instead of silent misrouting.
    pub fn from_packed(
        packed: Vec<u64>,
        cache: &CowCache,
    ) -> Result<RouterIndex, String> {
        for (u, &p) in packed.iter().enumerate() {
            if p == ABSENT {
                continue;
            }
            let (pid, pos) = ((p >> 32) as usize, (p & u32::MAX as u64) as usize);
            if pid >= cache.len() {
                return Err(format!(
                    "node {u}: plan {pid} out of range ({} plans)",
                    cache.len()
                ));
            }
            if pos >= cache.num_outputs(pid)
                || cache.output_nodes(pid)[pos] != u as u32
            {
                return Err(format!(
                    "node {u}: plan {pid} pos {pos} does not own it"
                ));
            }
        }
        Ok(RouterIndex {
            index: packed,
            duplicates: 0,
        })
    }

    /// Rebuild from the packed form against plan *metadata* only —
    /// the lazy (store-backed) cold-start path, where payloads live on
    /// disk and resolving each one to verify node ownership would
    /// defeat the point of faulting lazily. Every warm entry is
    /// checked to stay inside the manifest's declared shapes
    /// (`outputs_of(pid)` = the plan's output count); ownership itself
    /// is re-verified blob-by-blob at fault time via the content hash.
    pub fn from_packed_meta(
        packed: Vec<u64>,
        num_plans: usize,
        outputs_of: impl Fn(usize) -> usize,
    ) -> Result<RouterIndex, String> {
        for (u, &p) in packed.iter().enumerate() {
            if p == ABSENT {
                continue;
            }
            let (pid, pos) = ((p >> 32) as usize, (p & u32::MAX as u64) as usize);
            if pid >= num_plans {
                return Err(format!(
                    "node {u}: plan {pid} out of range ({num_plans} plans)"
                ));
            }
            if pos >= outputs_of(pid) {
                return Err(format!(
                    "node {u}: pos {pos} past plan {pid}'s {} outputs",
                    outputs_of(pid)
                ));
            }
        }
        Ok(RouterIndex {
            index: packed,
            duplicates: 0,
        })
    }
}

/// Mutable cold-routing state: node → stable cold-plan id. Owned by
/// the control loop (the only router writer); warm routing reads the
/// snapshot's [`RouterIndex`]. Survives snapshot swaps — a cold id is
/// a coalescing identity, not plan content.
#[derive(Debug, Default)]
pub struct QueryRouter {
    cold: HashMap<u32, u32>,
    /// Cold-plan ids handed out so far.
    pub cold_built: usize,
}

impl QueryRouter {
    /// Fresh router with an empty cold-id memo.
    pub fn new() -> QueryRouter {
        QueryRouter::default()
    }

    /// Distinct cold nodes currently holding an id.
    pub fn cold_ids(&self) -> usize {
        self.cold.len()
    }

    /// Route a query node against `index`: warm lookup, else a
    /// memoized cold id (assigning a fresh one is the only mutating
    /// case).
    pub fn route(&mut self, index: &RouterIndex, node: u32) -> Route {
        if let Some((plan, pos)) = index.lookup(node) {
            return Route::Cached { plan, pos };
        }
        if let Some(&id) = self.cold.get(&node) {
            return Route::Cold { id };
        }
        if self.cold.len() >= MAX_COLD_IDS {
            self.cold.clear();
        }
        let id = self.cold_built as u32;
        self.cold_built += 1;
        self.cold.insert(node, id);
        Route::Cold { id }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batching::{BatchGenerator, CowCache, NodeWiseIbmb};
    use crate::datasets::{sbm, Dataset, DatasetSpec};
    use crate::util::Rng;

    fn setup() -> (Dataset, CowCache) {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 77);
        let mut g = NodeWiseIbmb {
            aux_per_output: 6,
            max_outputs_per_batch: 40,
            node_budget: 256,
            ..Default::default()
        };
        let mut rng = Rng::new(3);
        let out = ds.splits.train.clone();
        let cache = CowCache::from_plans(&g.plan(&ds, &out, &mut rng));
        (ds, cache)
    }

    #[test]
    fn every_output_node_routes_to_its_plan() {
        let (ds, cache) = setup();
        let index = RouterIndex::build(ds.graph.num_nodes(), &cache);
        let mut router = QueryRouter::new();
        assert_eq!(index.duplicates, 0);
        assert_eq!(index.coverage(), ds.splits.train.len());
        for &u in &ds.splits.train {
            match router.route(&index, u) {
                Route::Cached { plan, pos } => {
                    assert_eq!(
                        cache.output_nodes(plan as usize)[pos as usize],
                        u
                    );
                }
                Route::Cold { .. } => panic!("train node {u} went cold"),
            }
        }
        assert_eq!(router.cold_built, 0);
    }

    #[test]
    fn uncovered_nodes_get_stable_cold_ids() {
        let (ds, cache) = setup();
        let index = RouterIndex::build(ds.graph.num_nodes(), &cache);
        let mut router = QueryRouter::new();
        let covered: std::collections::HashSet<u32> =
            ds.splits.train.iter().copied().collect();
        let mut cold_nodes = (0..ds.graph.num_nodes() as u32)
            .filter(|u| !covered.contains(u));
        let a = cold_nodes.next().expect("tiny split leaves cold nodes");
        let b = cold_nodes.next().expect("need two cold nodes");
        let ra = router.route(&index, a);
        let rb = router.route(&index, b);
        let ra2 = router.route(&index, a);
        match (ra, rb, ra2) {
            (
                Route::Cold { id: ia },
                Route::Cold { id: ib },
                Route::Cold { id: ia2 },
            ) => {
                assert_eq!(ia, ia2, "cold id must be memoized per node");
                assert_ne!(ia, ib, "distinct nodes get distinct cold ids");
            }
            other => panic!("expected cold routes, got {other:?}"),
        }
        assert_eq!(router.cold_built, 2);
        assert_eq!(router.cold_ids(), 2);
        assert_eq!(router.route(&index, a).pos(), 0);
    }

    #[test]
    fn extended_index_keeps_warm_entries_and_cold_tails() {
        let (ds, cache) = setup();
        let n = ds.graph.num_nodes();
        let index = RouterIndex::build(n, &cache);
        let grown = index.extended(n + 3);
        assert_eq!(grown.len(), n + 3);
        assert_eq!(grown.coverage(), index.coverage());
        for u in 0..n as u32 {
            assert_eq!(grown.lookup(u), index.lookup(u), "node {u}");
        }
        for u in n..n + 3 {
            assert_eq!(grown.lookup(u as u32), None, "appended node {u}");
        }
    }

    #[test]
    fn packed_roundtrip_validates_against_the_cache() {
        let (ds, cache) = setup();
        let n = ds.graph.num_nodes();
        let index = RouterIndex::build(n, &cache);
        let packed = index.to_packed();
        let back = RouterIndex::from_packed(packed.clone(), &cache).unwrap();
        assert_eq!(back.coverage(), index.coverage());
        for u in 0..n as u32 {
            assert_eq!(back.lookup(u), index.lookup(u));
        }
        // a corrupted entry is rejected, not trusted
        let mut bad = packed.clone();
        let victim = (0..n).find(|&u| bad[u] != super::ABSENT).unwrap();
        bad[victim] ^= 1; // flip pos
        assert!(RouterIndex::from_packed(bad, &cache).is_err());
        let mut oob = packed;
        let victim = (0..n).find(|&u| oob[u] != super::ABSENT).unwrap();
        oob[victim] = (cache.len() as u64) << 32; // plan out of range
        assert!(RouterIndex::from_packed(oob, &cache).is_err());
    }
}
