//! The serving event loop: closed-loop clients → router → coalescing
//! queue → sharded executors → results memo → latency metrics, all
//! reading immutable epoch snapshots (DESIGN.md §11).
//!
//! The loop is single-threaded on the control side (routing, queueing,
//! memoization, accounting) with N executor shard threads; queries
//! complete out of the shards' result channel. Every admission round
//! loads the current [`ServeState`] from the shared [`ServeStateCell`]
//! — one pointer clone — and routes, memo-probes, and enqueues against
//! that snapshot, so a query's (plan id, output row, memo epoch)
//! triple is internally consistent by construction even while the
//! background [`super::update::UpdateApplier`] publishes new epochs
//! mid-run. Nothing quiesces on a swap: in-flight groups execute
//! against the snapshot they pinned, and the only swap-time work on
//! the control thread is an eager memo sweep
//! ([`ResultsCache::purge_stale`]) reclaiming epoch-expired bytes.
//!
//! Clients are closed-loop: each of `clients` logical clients keeps
//! exactly one query in flight and issues its next the moment the
//! previous completes, which is what makes throughput self-limiting
//! and the coalescing factor an honest function of concurrency × skew
//! rather than of an open-loop arrival schedule.
//!
//! A query's life: admit (route + memo probe against the loaded
//! snapshot) → coalesce in the queue (size/deadline flush, grouped by
//! plan *and* epoch) → execute once per group on its plan's home shard
//! → complete every rider, memoize the plan's output logits at the
//! group's epoch.
//!
//! [`serve_with_churn`] is the same loop with a delta source attached:
//! `Inline` churn applies deltas on the control thread (the quiesced
//! baseline — the stall lands on every pending deadline) while
//! `Background`/`Stream` churn feeds a scoped applier thread that
//! builds and publishes snapshots off to the side — the zero-quiesce
//! path `benches/updates.rs` measures against it.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::batching::{BatchGenerator, CowCache, NodeWiseIbmb};
use crate::config::preset_for;
use crate::datasets::Dataset;
use crate::exec::ExecutorKind;
use crate::graph::GraphDelta;
use crate::runtime::{ArtifactMeta, ModelState};
use crate::util::Rng;

use super::admission::{AdmissionConfig, AdmissionGate, TenantCounters, Verdict};
use super::coop::{CoopDispatcher, HotTracker};
use super::load::{LoadGen, Skew};
use super::metrics::ServeMetrics;
use super::queue::{MicrobatchQueue, PendingGroup, QueryTicket};
use super::results::ResultsCache;
use super::router::{PlanKey, QueryRouter, Route, RouterIndex};
use super::shard::{
    argmax, reference_artifact, shard_worker, Placement, ShardCtx, ShardMsg,
    Work, WorkItem, PLACEMENT_CELLS,
};
use super::state::{ServeState, ServeStateCell};
use super::update::{run_applier, UpdateApplier, UpdateReport};
use crate::telemetry::span::{
    Stage, ADMIT_DEGRADED, ADMIT_EXEC, ADMIT_MEMO, NO_GROUP, NO_QUERY,
    NO_SHARD, SHED_DEADLINE, SHED_RATE,
};
use crate::telemetry::{TraceBuf, Tracer};

/// Serving configuration (CLI: `ibmb serve`).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Model family: "gcn" | "sage" | "gat".
    pub model: String,
    /// Executor worker shards.
    pub shards: usize,
    /// Closed-loop clients (max queries in flight).
    pub clients: usize,
    /// Total queries to serve.
    pub queries: usize,
    /// Microbatch deadline: max time a query waits for co-riders.
    pub flush_window: Duration,
    /// Size flush threshold (queries per group).
    pub max_coalesce: usize,
    /// Results-memo byte budget (0 disables).
    pub results_cache_bytes: usize,
    /// Results-memo freshness bound (None = until evicted).
    pub results_ttl: Option<Duration>,
    /// Top-k PPR budget for cold (uncovered) query nodes.
    pub cold_aux: usize,
    /// Per-shard prefetch ring depth.
    pub ring_depth: usize,
    /// Reference-model hidden width.
    pub hidden: usize,
    /// Reference-model layer count.
    pub layers: usize,
    /// Attention heads (GAT only).
    pub heads: usize,
    /// Seed for model init, placement, and the load generator.
    pub seed: u64,
    /// Open-loop offered load (queries/s). 0 keeps the classic
    /// closed-loop behavior; > 0 paces arrivals on a deterministic
    /// schedule regardless of completions, so the loop can be driven
    /// past capacity (the overload bench) and latency is measured from
    /// the *scheduled* arrival — coordinated-omission safe.
    pub offered_qps: f64,
    /// Per-query completion deadline for the admission gate and the
    /// goodput counter (None disables shedding).
    pub deadline: Option<Duration>,
    /// Logical tenants the load generator spreads arrivals over.
    pub tenants: usize,
    /// Per-tenant token-bucket refill rate (queries/s; 0 disables).
    pub tenant_rate: f64,
    /// Per-tenant token-bucket burst capacity.
    pub tenant_burst: f64,
    /// Forward backend every shard builds (`--executor`). Probe-built
    /// once before shards spawn so an unavailable backend (the PJRT
    /// stub) fails the run cleanly instead of panicking a worker.
    pub executor: ExecutorKind,
    /// Per-shard plan-residency byte budget for store-backed (lazy)
    /// deployments (`--store-budget`). Ignored when the snapshot
    /// carries its full cache in memory.
    pub store_budget: usize,
    /// Cooperative cross-shard serving (`--cooperative`, DESIGN.md
    /// §15): work-stealing between shard backlogs, hot-plan replica
    /// routing, and cross-query fetch sharing inside shard drains.
    /// Stealing/replication need ≥ 2 shards; fetch sharing applies at
    /// any shard count.
    pub cooperative: bool,
    /// Cooperative in-flight window: groups sent to a shard's channel
    /// before further dispatches backlog (and become stealable)
    /// (`--steal-window`).
    pub steal_window: usize,
    /// Hot plans the cooperative router replicates onto the
    /// least-loaded non-home shard at each re-rank (`--hot-replicas`).
    pub hot_replicas: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            model: "gcn".to_string(),
            shards: 1,
            clients: 16,
            queries: 500,
            flush_window: Duration::from_micros(500),
            max_coalesce: 16,
            results_cache_bytes: 0,
            results_ttl: None,
            cold_aux: 16,
            ring_depth: 2,
            hidden: 32,
            layers: 2,
            heads: 2,
            seed: 0,
            offered_qps: 0.0,
            deadline: None,
            tenants: 1,
            tenant_rate: 0.0,
            tenant_burst: 32.0,
            executor: ExecutorKind::default(),
            store_budget: 8 << 20,
            cooperative: false,
            steal_window: 4,
            hot_replicas: 4,
        }
    }
}

/// A serving deployment handle: the snapshot cell every run (and the
/// background applier) shares, plus the control loop's only mutable
/// routing state — the cold-id memo, which stays warm across repeated
/// runs (the bench's shard sweep reuses one setup).
pub struct ServeSetup {
    /// The published-snapshot cell shared with appliers and shards.
    pub cell: Arc<ServeStateCell>,
    /// Output-node router with its persistent cold-id memo.
    pub router: QueryRouter,
    /// Trace event sink attached to serving runs (disabled by
    /// default; `ibmb serve --trace` attaches a JSONL writer).
    pub tracer: Tracer,
}

impl ServeSetup {
    /// The snapshot currently published (inspection / tests).
    pub fn state(&self) -> Arc<ServeState> {
        self.cell.load()
    }
}

/// Build the initial (epoch 0) snapshot around an already-planned
/// cache: pick the artifact bucket, synthesize the reference executor
/// model, init its state, invert (or adopt) the router index, and
/// place plans on partition cells. Shared by the static [`prepare`],
/// the cold-start [`prepare_from_cache`], and the dynamic session
/// ([`super::update::DynamicServeSession::prepare`]) so the bucket
/// formula and seeds cannot drift between them.
pub(crate) fn build_initial_state(
    ds: Arc<Dataset>,
    cache: CowCache,
    cfg: &ServeConfig,
    index: Option<RouterIndex>,
) -> (Arc<ServeStateCell>, Arc<ArtifactMeta>, Arc<ModelState>) {
    let bucket = cache
        .max_batch_nodes()
        .max(cfg.cold_aux + 1)
        .next_power_of_two()
        .max(16);
    let meta = Arc::new(reference_artifact(
        &cfg.model,
        ds.feat_dim,
        ds.num_classes,
        cfg.hidden,
        cfg.layers,
        cfg.heads,
        bucket,
    ));
    let model = Arc::new(ModelState::init(&meta, cfg.seed ^ 0x51A7E));
    let index = Arc::new(index.unwrap_or_else(|| {
        RouterIndex::build(ds.graph.num_nodes(), &cache)
    }));
    let mut rng = Rng::new(cfg.seed ^ 0x5E21);
    let placement =
        Arc::new(Placement::build(&ds, &cache, PLACEMENT_CELLS, &mut rng));
    let epochs = Arc::new(vec![0u64; cache.len()]);
    let state = Arc::new(ServeState {
        epoch: 0,
        ds,
        cache: Arc::new(cache),
        index,
        epochs,
        placement,
        meta: meta.clone(),
        model: model.clone(),
        store: None,
    });
    debug_assert!(state.validate().is_ok(), "{:?}", state.validate());
    (Arc::new(ServeStateCell::new(state)), meta, model)
}

/// Plan the serveable node set with node-wise IBMB (dataset preset),
/// synthesize the reference executor model sized to the resulting
/// bucket, and publish the epoch-0 snapshot. Takes the dataset by
/// value: it becomes part of the immutable snapshot.
pub fn prepare(ds: Dataset, eval_nodes: &[u32], cfg: &ServeConfig) -> ServeSetup {
    let p = preset_for(&ds.name);
    let mut g = NodeWiseIbmb {
        aux_per_output: p.aux_per_output,
        max_outputs_per_batch: p.outputs_per_batch,
        node_budget: p.node_budget,
        ..Default::default()
    };
    let mut rng = Rng::new(cfg.seed ^ 0xCAFE);
    let cache = CowCache::from_plans(&g.plan(&ds, eval_nodes, &mut rng));
    let (cell, _, _) = build_initial_state(Arc::new(ds), cache, cfg, None);
    ServeSetup {
        cell,
        router: QueryRouter::new(),
        tracer: Tracer::disabled(),
    }
}

/// Cold-start [`prepare`]: adopt a plan cache (and optionally its
/// persisted router index — see [`crate::batching::cache_io`])
/// reloaded from disk instead of re-planning. The index, when given,
/// must already be validated against `cache`
/// ([`RouterIndex::from_packed`] does).
pub fn prepare_from_cache(
    ds: Dataset,
    cache: CowCache,
    index: Option<RouterIndex>,
    cfg: &ServeConfig,
) -> Result<ServeSetup> {
    anyhow::ensure!(!cache.is_empty(), "empty plan cache");
    if let Some(ix) = &index {
        anyhow::ensure!(
            ix.len() == ds.graph.num_nodes(),
            "router index covers {} nodes, dataset has {}",
            ix.len(),
            ds.graph.num_nodes()
        );
    }
    let (cell, _, _) = build_initial_state(Arc::new(ds), cache, cfg, index);
    Ok(ServeSetup {
        cell,
        router: QueryRouter::new(),
        tracer: Tracer::disabled(),
    })
}

/// Lazy cold start from a content-addressed [`PlanStore`]: the epoch-0
/// snapshot is assembled from the store *manifest alone* — plan
/// counts, shapes, epochs, and the packed router index — without
/// reading a single blob. Plan payloads stay on disk until a shard's
/// residency LRU faults them in ([`super::shard::shard_worker`]), so
/// time-to-first-answer is O(manifest + one blob) instead of O(corpus)
/// and resident bytes stay bounded by `cfg.store_budget` per shard.
///
/// The bucket is sized from the manifest's `max_plan_nodes` (the same
/// formula [`build_initial_state`] derives from a resident cache), the
/// router index is validated against manifest shape metadata
/// ([`RouterIndex::from_packed_meta`]), and placement is round-robin —
/// there are no payloads to majority-vote cells over.
pub fn prepare_from_store(
    ds: Dataset,
    store: Arc<crate::store::PlanStore>,
    cfg: &ServeConfig,
) -> Result<ServeSetup> {
    let view = store.view();
    anyhow::ensure!(view.num_plans() > 0, "store has no plans");
    let n = ds.graph.num_nodes();
    anyhow::ensure!(
        view.router.len() == n,
        "store router covers {} nodes, dataset has {n}",
        view.router.len()
    );
    let bucket = view
        .max_plan_nodes()
        .max(cfg.cold_aux + 1)
        .next_power_of_two()
        .max(16);
    let ds = Arc::new(ds);
    let meta = Arc::new(reference_artifact(
        &cfg.model,
        ds.feat_dim,
        ds.num_classes,
        cfg.hidden,
        cfg.layers,
        cfg.heads,
        bucket,
    ));
    let model = Arc::new(ModelState::init(&meta, cfg.seed ^ 0x51A7E));
    let entries = view.entries.clone();
    let index = RouterIndex::from_packed_meta(
        view.router.clone(),
        view.num_plans(),
        |pid| entries[pid].num_outputs as usize,
    )
    .map_err(|e| anyhow::anyhow!("store router index invalid: {e}"))?;
    let placement = Arc::new(Placement::round_robin(
        n,
        view.num_plans(),
        PLACEMENT_CELLS,
    ));
    let state = Arc::new(ServeState {
        epoch: view.epoch,
        ds,
        cache: Arc::new(CowCache::from_plans(&[])),
        index: Arc::new(index),
        epochs: Arc::new(view.epochs()),
        placement,
        meta,
        model,
        store: Some(store),
    });
    state
        .validate()
        .map_err(|e| anyhow::anyhow!("store snapshot invalid: {e}"))?;
    Ok(ServeSetup {
        cell: Arc::new(ServeStateCell::new(state)),
        router: QueryRouter::new(),
        tracer: Tracer::disabled(),
    })
}

/// Aggregate outcome of one closed-loop serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Queries offered to the run.
    pub queries: usize,
    /// Wall-clock seconds the run took.
    pub wall_s: f64,
    /// Offered queries per wall second.
    pub qps: f64,
    /// Median completion latency (admitted queries), ms.
    pub p50_ms: f64,
    /// 95th-percentile completion latency, ms.
    pub p95_ms: f64,
    /// 99th-percentile completion latency, ms.
    pub p99_ms: f64,
    /// Mean completion latency, ms.
    pub mean_ms: f64,
    /// Worst observed completion latency, ms.
    pub max_ms: f64,
    /// Materialize+execute runs performed.
    pub executions: u64,
    /// Queries answered by executions.
    pub executed_queries: u64,
    /// Queries per execution (> 1 = coalescing won).
    pub coalescing_factor: f64,
    /// Queries answered from the results memo.
    pub cache_hits: u64,
    /// Fraction of completions served from the memo.
    pub cache_hit_rate: f64,
    /// Queries answered via the cold (synthesized-plan) path — memo
    /// hits for previously executed cold plans are not counted.
    pub cold_routes: u64,
    /// Cold-plan ids assigned during this run (≈ distinct new cold
    /// nodes; shard-side FIFO eviction may resynthesize an id's plan).
    pub cold_plans: usize,
    /// Fraction of completions with a label-correct prediction.
    pub accuracy: f64,
    /// Queries *executed* per shard, attributed at result receipt —
    /// steals and replica dispatches count against the shard that ran
    /// the group, not the dispatch target.
    pub shard_queries: Vec<u64>,
    /// Max executed share / ideal share over `shard_queries`.
    pub shard_balance: f64,
    /// Precomputed plans available to the router (final snapshot).
    pub plans: usize,
    /// Shard-side seconds in the forward pass (summed over shards).
    pub exec_s: f64,
    /// Shard-side seconds stalled waiting on materialization.
    pub mat_wait_s: f64,
    /// Dense-buffer bytes pooled across all shard arenas.
    pub arena_bytes: usize,
    /// Fresh buffer allocations across all shard arenas (steady state:
    /// ring depth × shards).
    pub arena_allocations: usize,
    /// Bytes resident in the results memo at shutdown.
    pub results_cache_bytes: usize,
    /// Snapshot swaps the control loop observed mid-run (0 for a
    /// static deployment).
    pub snapshot_swaps: u64,
    /// Graph epoch of the last snapshot the loop served from.
    pub final_epoch: u64,
    /// Memo entries reclaimed eagerly by swap-time stale sweeps.
    pub memo_swept: u64,
    /// Offered load the run was driven at (0 = closed loop).
    pub offered_qps: f64,
    /// Admission deadline in ms (0 when no deadline was set).
    pub deadline_ms: f64,
    /// Queries admitted and answered (execution, memo, or degraded).
    pub admitted: u64,
    /// Queries shed by the deadline predicate (memo miss).
    pub shed: u64,
    /// Queries shed by the per-tenant token bucket.
    pub shed_rate_limited: u64,
    /// Over-deadline queries answered from the memo anyway.
    pub degraded: u64,
    /// (shed + rate-limited) / total offered.
    pub shed_fraction: f64,
    /// Completions within the deadline per wall second — the number
    /// the overload bench tracks against capacity.
    pub goodput_qps: f64,
    /// Per-tenant admitted/degraded/shed counters.
    pub tenant_stats: Vec<TenantCounters>,
    /// Peak bytes of old-epoch snapshot state held live by slow
    /// in-flight or queued groups, sampled at swap observations — the
    /// PR-5 "GC pressure" metric: how much memory zero-quiesce serving
    /// retains until stragglers finish.
    pub gc_retained_bytes_peak: usize,
    /// Cumulative count of old-epoch groups observed still holding a
    /// superseded snapshot at swap time.
    pub gc_retained_groups: u64,
    /// Order-independent hash over every answered query's
    /// (id, node, pred) triple — executions and memo hits alike. For a
    /// pinned seed this is invariant across shard interleavings and
    /// coalescing timing, so `ci.sh` compares it across executors:
    /// backends within logit tolerance produce identical predictions
    /// and therefore identical hashes.
    pub logit_hash: u64,
    /// Plan-store faults (blob reads) summed over shards — 0 unless
    /// the deployment is store-backed (lazy).
    pub store_faults: u64,
    /// Payload bytes resident in shard plan LRUs at shutdown, summed
    /// over shards — bounded by `shards × store_budget` (plus the
    /// one-plan floor).
    pub resident_bytes: u64,
    /// Cooperative mode (DESIGN.md §15): groups moved off their
    /// dispatch shard's backlog by an idle thief.
    pub steals: u64,
    /// Cooperative mode: groups dispatched to a hot plan's replica
    /// shard instead of its home.
    pub replica_dispatches: u64,
    /// Cooperative mode: feature bytes saved by cross-query fetch
    /// sharing, summed over shards.
    pub shared_row_bytes: u64,
}

/// Fold one answered query into the run's prediction hash. Wrapping
/// sum of per-query mixes: commutative, so completion order (which
/// varies with thread scheduling) cannot change the digest.
fn mix_outcome(hash: &mut u64, id: u64, node: u32, pred: u16) {
    let h = (id ^ ((node as u64) << 32) ^ ((pred as u64) << 17))
        .wrapping_mul(0x9E37_79B9_7F4A_7C15);
    *hash = hash.wrapping_add(h.rotate_left(23) ^ h);
}

/// A delta source attached to a serving run — the quiesced-vs-zero-
/// quiesce axis of `benches/updates.rs`.
pub enum Churn<'a> {
    /// Apply each delta **on the control thread** once `completed`
    /// reaches its trigger: the quiesced baseline. Admission and
    /// deadline flushes stall for the whole rebuild, which is exactly
    /// the latency cliff the snapshot refactor removes.
    Inline {
        applier: &'a mut UpdateApplier,
        deltas: Vec<(u64, GraphDelta)>,
    },
    /// Feed each delta to a **background applier thread** at the same
    /// completed-count triggers: zero-quiesce. The loop keeps serving;
    /// the new snapshot lands via the cell swap. The run drains all
    /// fed deltas before returning, so reports are complete.
    Background {
        applier: &'a mut UpdateApplier,
        deltas: Vec<(u64, GraphDelta)>,
    },
    /// Zero-quiesce with an external delta source (a file tailer):
    /// deltas arrive on `rx` on their own clock; whatever lands before
    /// the run finishes is applied.
    Stream {
        applier: &'a mut UpdateApplier,
        rx: mpsc::Receiver<GraphDelta>,
    },
}

/// The shard a query for `key`/`node` will execute on under `state` —
/// computable at admission time, which is what lets the gate judge
/// per-shard queue depth before the query ever enters the queue.
fn home_shard(
    state: &ServeState,
    key: &PlanKey,
    node: u32,
    shards: usize,
) -> usize {
    match key {
        PlanKey::Cached(pid) => state.placement.shard_of_plan(*pid, shards),
        PlanKey::Cold(_) => state.placement.shard_of_node(node, shards),
    }
}

#[allow(clippy::too_many_arguments)]
fn dispatch_group(
    g: PendingGroup<Arc<ServeState>>,
    shards: usize,
    txs: &[mpsc::Sender<WorkItem>],
    metrics: &mut ServeMetrics,
    inflight: &mut HashMap<u64, (u64, usize)>,
    tbuf: &mut TraceBuf,
    gate: &mut AdmissionGate,
    coop: &mut Option<CoopDispatcher<WorkItem>>,
    coop_placement: &Placement,
    replica_dispatches: &mut u64,
) -> Result<()> {
    let work = match g.key {
        PlanKey::Cached(pid) => Work::Cached(pid),
        // all riders of a cold group query the same node
        PlanKey::Cold(_) => Work::Cold(g.queries[0].node),
    };
    let home = home_shard(&g.snap, &g.key, g.queries[0].node, shards);
    let mut shard = home;
    // hot-plan replica routing (DESIGN.md §15): a replicated plan has
    // a second home; send the group to whichever copy has the
    // shallower instantaneous queue. Replicas fault the plan through
    // the ordinary residency path when store-backed.
    if coop.is_some() {
        if let PlanKey::Cached(pid) = g.key {
            if let Some(rs) =
                coop_placement.replica_shard_of_plan(pid, shards)
            {
                if rs != home && gate.depth(rs) < gate.depth(home) {
                    shard = rs;
                    *replica_dispatches += 1;
                    gate.group_moved(home, rs);
                }
            }
        }
    }
    metrics.record_dispatch(g.queries.len() as u64);
    tbuf.instant(
        Stage::Coalesce,
        NO_QUERY,
        g.gid,
        shard as u32,
        g.queries.len() as u64,
    );
    for q in &g.queries {
        tbuf.exit(Stage::QueueWait, q.id, g.gid, shard as u32);
    }
    // accounted until the group's ShardResult arrives: the bytes of
    // snapshot state the group pins (GC-pressure metric at swap time)
    inflight.insert(g.gid, (g.snap.epoch, g.snap.cache.memory_bytes()));
    let item = WorkItem {
        gid: g.gid,
        key: g.key,
        epoch: g.epoch,
        state: g.snap,
        work,
        queries: g.queries,
    };
    match coop {
        // cooperative: respect the in-flight window; overflow lands in
        // the control-loop backlog, where idle shards can steal it
        Some(c) => {
            if let Some((s, item)) = c.offer(shard, item) {
                txs[s]
                    .send(item)
                    .map_err(|_| anyhow::anyhow!("shard {s} hung up"))?;
            }
        }
        None => txs[shard]
            .send(item)
            .map_err(|_| anyhow::anyhow!("shard {shard} hung up"))?,
    }
    Ok(())
}

/// Refill every shard with spare cooperative window — own backlog
/// first, then steals from the deepest victim's tail — shifting gate
/// depth and emitting a [`Stage::Steal`] instant per stolen group.
fn coop_top_up(
    coop: &mut CoopDispatcher<WorkItem>,
    txs: &[mpsc::Sender<WorkItem>],
    gate: &mut AdmissionGate,
    tbuf: &mut TraceBuf,
) -> Result<()> {
    for d in coop.top_up() {
        if let Some(victim) = d.stolen_from {
            gate.group_moved(victim, d.shard);
            tbuf.instant(
                Stage::Steal,
                NO_QUERY,
                d.item.gid,
                d.shard as u32,
                victim as u64,
            );
        }
        let s = d.shard;
        txs[s]
            .send(d.item)
            .map_err(|_| anyhow::anyhow!("shard {s} hung up"))?;
    }
    Ok(())
}

/// Serve `cfg.queries` queries drawn from `population` with `skew`,
/// closed-loop, with a per-run results memo sized by
/// `cfg.results_cache_bytes`. `setup` is borrowed mutably for the
/// router's cold-id memo, which stays warm across repeated runs.
pub fn serve_closed_loop(
    setup: &mut ServeSetup,
    population: &[u32],
    skew: Skew,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    let mut results = ResultsCache::new(cfg.results_cache_bytes, cfg.results_ttl);
    serve_closed_loop_with(setup, population, skew, cfg, &mut results)
}

/// [`serve_closed_loop`] against a caller-owned results memo — the
/// dynamic-update session keeps one memo alive across serving
/// segments so post-delta epoch eviction is actually observable.
/// Memo lookups and inserts are keyed by the plan's epoch in the
/// snapshot the query was admitted under (cold plans key on the
/// snapshot epoch itself). Blocks until every query completes and all
/// shards have shut down; returns the aggregate report.
pub fn serve_closed_loop_with(
    setup: &mut ServeSetup,
    population: &[u32],
    skew: Skew,
    cfg: &ServeConfig,
    results: &mut ResultsCache,
) -> Result<ServeReport> {
    serve_with_churn(setup, population, skew, cfg, results, None)
        .map(|(report, _)| report)
}

/// The full loop: [`serve_closed_loop_with`] plus an optional [`Churn`]
/// source. Returns the serve report and the [`UpdateReport`]s of every
/// delta applied during the run (empty without churn).
pub fn serve_with_churn(
    setup: &mut ServeSetup,
    population: &[u32],
    skew: Skew,
    cfg: &ServeConfig,
    results: &mut ResultsCache,
    churn: Option<Churn<'_>>,
) -> Result<(ServeReport, Vec<UpdateReport>)> {
    let state0 = setup.cell.load();
    let tracer = setup.tracer.clone();
    let router = &mut setup.router;
    // ServeSetup persists across runs; report this run's delta
    let cold_ids_at_start = router.cold_built;
    anyhow::ensure!(!population.is_empty(), "empty query population");
    anyhow::ensure!(cfg.queries > 0, "need at least one query");
    anyhow::ensure!(
        state0.meta.feat == state0.ds.feat_dim,
        "artifact feat {} != dataset feat {}",
        state0.meta.feat,
        state0.ds.feat_dim
    );
    // fail an unavailable backend (e.g. the PJRT stub) here, before
    // any thread spawns or query is accepted
    drop(cfg.executor.build()?);
    let shards = cfg.shards.max(1);
    let total = cfg.queries as u64;
    let clients = cfg.clients.max(1).min(cfg.queries) as u64;
    let classes = state0.meta.classes;

    let mut queue: MicrobatchQueue<Arc<ServeState>> =
        MicrobatchQueue::new(cfg.flush_window, cfg.max_coalesce);
    let mut metrics = ServeMetrics::new(shards);
    metrics.deadline_s = cfg.deadline.map(|d| d.as_secs_f64());
    let mut load = LoadGen::with_tenants(
        population,
        skew,
        cfg.tenants.max(1),
        cfg.seed ^ 0x10AD,
    );
    let mut gate = AdmissionGate::new(
        shards,
        cfg.tenants.max(1),
        AdmissionConfig {
            deadline: cfg.deadline,
            tenant_rate: cfg.tenant_rate,
            tenant_burst: cfg.tenant_burst,
            ..Default::default()
        },
    );
    // open loop: arrivals follow a fixed schedule, not completions
    let open_loop = cfg.offered_qps > 0.0;
    let interarrival = if open_loop {
        Duration::from_secs_f64(1.0 / cfg.offered_qps)
    } else {
        Duration::ZERO
    };
    let mut tbuf = tracer.buffer();
    let cell = setup.cell.clone();

    // churn plumbing: triggers fire as `completed` crosses them
    let stop = Arc::new(AtomicBool::new(false));
    let (urep_tx, urep_rx) = mpsc::channel::<UpdateReport>();
    enum ChurnRt<'a> {
        None,
        Inline {
            applier: &'a mut UpdateApplier,
            pending: VecDeque<(u64, GraphDelta)>,
        },
        Background {
            tx: mpsc::Sender<GraphDelta>,
            pending: VecDeque<(u64, GraphDelta)>,
        },
        Stream,
    }

    std::thread::scope(|scope| -> Result<(ServeReport, Vec<UpdateReport>)> {
        let mut applier_handle = None;
        let mut churn_rt = match churn {
            None => ChurnRt::None,
            Some(Churn::Inline { applier, deltas }) => {
                let mut pending: VecDeque<_> = deltas.into_iter().collect();
                pending
                    .make_contiguous()
                    .sort_by_key(|(trigger, _)| *trigger);
                ChurnRt::Inline { applier, pending }
            }
            Some(Churn::Background { applier, deltas }) => {
                let mut pending: VecDeque<_> = deltas.into_iter().collect();
                pending
                    .make_contiguous()
                    .sort_by_key(|(trigger, _)| *trigger);
                let (tx, rx) = mpsc::channel::<GraphDelta>();
                let stop = stop.clone();
                let reports = urep_tx.clone();
                applier_handle = Some(
                    scope.spawn(move || run_applier(applier, rx, &stop, reports)),
                );
                ChurnRt::Background { tx, pending }
            }
            Some(Churn::Stream { applier, rx }) => {
                let stop = stop.clone();
                let reports = urep_tx.clone();
                applier_handle = Some(
                    scope.spawn(move || run_applier(applier, rx, &stop, reports)),
                );
                ChurnRt::Stream
            }
        };
        drop(urep_tx);

        let (res_tx, res_rx) = mpsc::channel::<ShardMsg>();
        let mut txs: Vec<mpsc::Sender<WorkItem>> = Vec::with_capacity(shards);
        for shard_id in 0..shards {
            let (tx, rx) = mpsc::channel::<WorkItem>();
            let ctx = ShardCtx {
                shard_id,
                feat_dim: state0.ds.feat_dim,
                bucket: state0.meta.n_pad,
                ring_depth: cfg.ring_depth,
                cold_aux: cfg.cold_aux,
                executor: cfg.executor,
                store_budget: cfg.store_budget,
                cooperative: cfg.cooperative,
            };
            let out = res_tx.clone();
            let strace = tracer.clone();
            scope.spawn(move || shard_worker(ctx, rx, out, strace));
            txs.push(tx);
        }
        drop(res_tx);

        let mut arrivals: HashMap<u64, Instant> = HashMap::new();
        let mut inline_reports: Vec<UpdateReport> = Vec::new();
        let mut issued = 0u64;
        let mut completed = 0u64;
        let mut seen_epoch = state0.epoch;
        let mut snapshot_swaps = 0u64;
        let mut memo_swept = 0u64;
        // dispatched-but-unfinished groups: gid → (snapshot epoch,
        // snapshot cache bytes) for the swap-time GC-pressure sample
        let mut inflight: HashMap<u64, (u64, usize)> = HashMap::new();
        let mut gc_retained_groups = 0u64;
        let mut gc_retained_bytes_peak = 0usize;
        let mut logit_hash = 0u64;
        // cooperative serving state (DESIGN.md §15): the dispatcher
        // owns per-shard in-flight windows + backlogs; the hot tracker
        // ranks plan demand; `coop_placement` is the control loop's
        // replica-augmented copy of the snapshot placement. Stealing
        // and replication need a second shard; with one shard only the
        // in-worker fetch sharing applies, so the dispatcher stays off.
        let mut coop: Option<CoopDispatcher<WorkItem>> =
            (cfg.cooperative && shards >= 2)
                .then(|| CoopDispatcher::new(shards, cfg.steal_window));
        let mut hot = HotTracker::new(0.5);
        let mut coop_placement: Placement = (*state0.placement).clone();
        let mut replica_dispatches = 0u64;
        let mut last_rebalance = 0u64;
        // how many executions between hot-plan re-ranks: long enough
        // to smooth noise, short enough to track a shifting working set
        const REBALANCE_EVERY: u64 = 32;
        drop(state0);
        let t0 = Instant::now();
        let mut next_arrival = t0;
        let wall_s = loop {
            // churn triggers keyed on progress
            match &mut churn_rt {
                ChurnRt::Inline { applier, pending } => {
                    while pending
                        .front()
                        .map(|(at, _)| *at <= completed)
                        .unwrap_or(false)
                    {
                        let (_, delta) = pending.pop_front().unwrap();
                        // quiesced baseline: the rebuild runs here, on
                        // the control thread — every pending deadline
                        // and admission waits it out
                        inline_reports.push(applier.apply(&delta)?);
                    }
                }
                ChurnRt::Background { tx, pending } => {
                    while pending
                        .front()
                        .map(|(at, _)| *at <= completed)
                        .unwrap_or(false)
                    {
                        let (_, delta) = pending.pop_front().unwrap();
                        let _ = tx.send(delta);
                    }
                }
                _ => {}
            }

            // one consistent snapshot per admission round
            let state = cell.load();
            if state.epoch != seen_epoch {
                anyhow::ensure!(
                    state.epoch > seen_epoch,
                    "snapshot epoch regressed: {} -> {}",
                    seen_epoch,
                    state.epoch
                );
                snapshot_swaps += 1;
                seen_epoch = state.epoch;
                // GC-pressure sample: every queued or in-flight group
                // still pinning an older snapshot keeps that whole
                // snapshot's plan store alive past the swap. Distinct
                // old epochs are counted once — groups sharing a
                // snapshot share the retained bytes.
                let mut old_epochs: HashMap<u64, usize> = HashMap::new();
                let mut stragglers = 0u64;
                for g in queue.groups() {
                    if g.snap.epoch < state.epoch {
                        stragglers += 1;
                        old_epochs
                            .insert(g.snap.epoch, g.snap.cache.memory_bytes());
                    }
                }
                for &(epoch, bytes) in inflight.values() {
                    if epoch < state.epoch {
                        stragglers += 1;
                        old_epochs.insert(epoch, bytes);
                    }
                }
                let retained: usize = old_epochs.values().sum();
                gc_retained_groups += stragglers;
                gc_retained_bytes_peak = gc_retained_bytes_peak.max(retained);
                tbuf.instant(
                    Stage::SnapshotSwap,
                    NO_QUERY,
                    NO_GROUP,
                    NO_SHARD,
                    state.epoch,
                );
                if retained > 0 {
                    tbuf.instant(
                        Stage::GcRetained,
                        NO_QUERY,
                        NO_GROUP,
                        NO_SHARD,
                        retained as u64,
                    );
                }
                // eager sweep: reclaim epoch-expired memo bytes now
                // instead of entry-by-entry on future reads
                let sweep_state = state.clone();
                memo_swept += results
                    .purge_stale(move |k| sweep_state.plan_epoch(k))
                    as u64;
                // adopt the new epoch's placement; replica choices for
                // surviving plan ids carry over until the next re-rank
                if coop.is_some() {
                    let mut fresh = (*state.placement).clone();
                    for (pid, cell) in coop_placement.replicas() {
                        if (pid as usize) < fresh.num_plans() {
                            fresh.set_replica(pid, cell);
                        }
                    }
                    coop_placement = fresh;
                }
            }

            // admission: closed loop tops up to `clients` in flight;
            // open loop drains every arrival whose scheduled time has
            // passed, regardless of completions (that backlog is what
            // the gate sheds against). Memo hits complete
            // synchronously and free their client slot immediately.
            loop {
                if issued >= total {
                    break;
                }
                let now = Instant::now();
                let arrived_at = if open_loop {
                    if now < next_arrival {
                        break;
                    }
                    let at = next_arrival;
                    next_arrival += interarrival;
                    at
                } else {
                    if issued - completed >= clients {
                        break;
                    }
                    now
                };
                let arr = load.next_arrival();
                let node = arr.node;
                let id = issued;
                issued += 1;
                let route = router.route(&state.index, node);
                let key = route.key();
                let pos = route.pos();
                let epoch = state.plan_epoch(&key);
                let shard = home_shard(&state, &key, node, shards);
                // time already burned waiting behind the arrival
                // schedule counts against the deadline budget
                let waited_s =
                    now.saturating_duration_since(arrived_at).as_secs_f64();
                let verdict = gate.assess(arr.tenant, shard, waited_s, now);
                if verdict == Verdict::RateLimited {
                    gate.note_shed_rate(arr.tenant);
                    metrics.shed_rate_limited += 1;
                    tbuf.instant(
                        Stage::Admission,
                        id,
                        NO_GROUP,
                        shard as u32,
                        SHED_RATE,
                    );
                    completed += 1;
                    continue;
                }
                if let Some(logits) = results.get(key, epoch, now) {
                    let start = pos as usize * classes;
                    let pred = argmax(&logits[start..start + classes]);
                    mix_outcome(&mut logit_hash, id, node, pred as u16);
                    metrics.cache_hit_queries += 1;
                    // an over-deadline query the memo can still answer
                    // is served degraded instead of shed
                    let code = if verdict == Verdict::OverDeadline {
                        gate.note_degraded(arr.tenant);
                        metrics.degraded += 1;
                        ADMIT_DEGRADED
                    } else {
                        gate.note_admitted(arr.tenant);
                        ADMIT_MEMO
                    };
                    tbuf.instant(
                        Stage::Admission,
                        id,
                        NO_GROUP,
                        shard as u32,
                        code,
                    );
                    let lat =
                        now.saturating_duration_since(arrived_at).as_secs_f64();
                    metrics.record_completion(
                        lat,
                        pred == state.ds.labels[node as usize] as usize,
                    );
                    tbuf.instant(
                        Stage::Complete,
                        id,
                        NO_GROUP,
                        shard as u32,
                        (lat * 1e6) as u64,
                    );
                    completed += 1;
                    continue;
                }
                if verdict == Verdict::OverDeadline {
                    gate.note_shed_deadline(arr.tenant);
                    metrics.shed_deadline += 1;
                    tbuf.instant(
                        Stage::Admission,
                        id,
                        NO_GROUP,
                        shard as u32,
                        SHED_DEADLINE,
                    );
                    completed += 1;
                    continue;
                }
                gate.note_admitted(arr.tenant);
                tbuf.instant(
                    Stage::Admission,
                    id,
                    NO_GROUP,
                    shard as u32,
                    ADMIT_EXEC,
                );
                // counted after the memo probe: memo-served repeats
                // never reach the synthesized-plan path
                let cold = matches!(route, Route::Cold { .. });
                if cold {
                    metrics.cold_routes += 1;
                }
                tbuf.instant(
                    Stage::Routing,
                    id,
                    NO_GROUP,
                    shard as u32,
                    cold as u64,
                );
                // demand signal for hot-plan replication: only queries
                // that will actually execute count (memo hits and shed
                // queries never load a shard)
                if coop.is_some() {
                    if let PlanKey::Cached(pid) = key {
                        hot.hit(pid);
                    }
                }
                arrivals.insert(id, arrived_at);
                let new_group = !queue.contains(key, epoch);
                let (gid, flushed) = queue.push(
                    key,
                    epoch,
                    &state,
                    QueryTicket { id, node, pos },
                    now,
                );
                if new_group {
                    gate.group_enqueued(shard);
                }
                tbuf.enter(Stage::QueueWait, id, gid, shard as u32);
                if let Some(group) = flushed {
                    dispatch_group(
                        group,
                        shards,
                        &txs,
                        &mut metrics,
                        &mut inflight,
                        &mut tbuf,
                        &mut gate,
                        &mut coop,
                        &coop_placement,
                        &mut replica_dispatches,
                    )?;
                }
            }
            if completed >= total {
                break t0.elapsed().as_secs_f64();
            }
            // periodic hot-plan re-rank (DESIGN.md §15): decay the
            // demand scores, then pin each surviving top-k plan's
            // replica to the least-loaded shard other than its home
            if coop.is_some()
                && metrics.executions >= last_rebalance + REBALANCE_EVERY
            {
                last_rebalance = metrics.executions;
                hot.decay();
                coop_placement.clear_replicas();
                for pid in hot.top_k(cfg.hot_replicas) {
                    if (pid as usize) >= coop_placement.num_plans() {
                        continue;
                    }
                    let home = coop_placement.shard_of_plan(pid, shards);
                    let mut best: Option<(usize, u64)> = None;
                    for s in 0..shards {
                        if s == home {
                            continue;
                        }
                        let d = gate.depth(s);
                        if best.map(|(_, bd)| d < bd).unwrap_or(true) {
                            best = Some((s, d));
                        }
                    }
                    if let Some((s, _)) = best {
                        coop_placement.set_replica(pid, s as u32);
                    }
                }
            }
            // deadline flushes
            let now = Instant::now();
            for group in queue.due(now) {
                dispatch_group(
                    group,
                    shards,
                    &txs,
                    &mut metrics,
                    &mut inflight,
                    &mut tbuf,
                    &mut gate,
                    &mut coop,
                    &coop_placement,
                    &mut replica_dispatches,
                )?;
            }
            // sleep until the next deadline, the next scheduled
            // arrival, or the next completion
            let mut timeout = queue
                .next_deadline()
                .map(|d| d.saturating_duration_since(Instant::now()))
                .unwrap_or(Duration::from_millis(10))
                .min(Duration::from_millis(10));
            if open_loop && issued < total {
                timeout = timeout
                    .min(next_arrival.saturating_duration_since(Instant::now()));
            }
            // keep idle shards fed before sleeping: a dispatch round
            // may have backlogged work while other windows sat open
            if let Some(c) = coop.as_mut() {
                coop_top_up(c, &txs, &mut gate, &mut tbuf)?;
            }
            match res_rx.recv_timeout(timeout) {
                Ok(ShardMsg::Result(r)) => {
                    let now = Instant::now();
                    inflight.remove(&r.gid);
                    gate.group_done(r.shard_id, r.exec_s);
                    // per-shard balance is attributed here, to the
                    // shard that actually executed (post-steal)
                    metrics
                        .record_group_executed(r.shard_id, r.outcomes.len() as u64);
                    if let Some(c) = coop.as_mut() {
                        c.complete(r.shard_id);
                        coop_top_up(c, &txs, &mut gate, &mut tbuf)?;
                    }
                    for o in &r.outcomes {
                        mix_outcome(&mut logit_hash, o.id, o.node, o.pred);
                        let lat = arrivals
                            .remove(&o.id)
                            .map(|a| {
                                now.saturating_duration_since(a).as_secs_f64()
                            })
                            .unwrap_or(0.0);
                        metrics.record_completion(lat, o.correct);
                        tbuf.instant(
                            Stage::Complete,
                            o.id,
                            r.gid,
                            r.shard_id as u32,
                            (lat * 1e6) as u64,
                        );
                        completed += 1;
                    }
                    metrics.exec_s += r.exec_s;
                    tbuf.instant(
                        Stage::Memo,
                        NO_QUERY,
                        r.gid,
                        r.shard_id as u32,
                        (r.out_logits.len() * 4) as u64,
                    );
                    results.insert(r.key, r.epoch, r.out_logits, now);
                }
                Ok(ShardMsg::Done(_)) => {
                    anyhow::bail!("shard exited early");
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("all shards disconnected");
                }
            }
        };

        // flush triggers the final completions skipped past (a memo
        // burst can advance `completed` across a trigger and straight
        // to `total` within one admission round) — every trigger-fed
        // delta is applied exactly once, deterministically
        match &mut churn_rt {
            ChurnRt::Inline { applier, pending } => {
                while let Some((_, delta)) = pending.pop_front() {
                    inline_reports.push(applier.apply(&delta)?);
                }
            }
            ChurnRt::Background { tx, pending } => {
                while let Some((_, delta)) = pending.pop_front() {
                    let _ = tx.send(delta);
                }
            }
            _ => {}
        }

        // retire the applier: Background closes its channel (the
        // applier drains every fed delta, then sees Disconnected);
        // Stream raises the stop flag (best effort — the tailer owns
        // the sender). Joining BEFORE draining the report channel
        // makes the collected reports complete: nothing can arrive
        // after the join, so no 30s-per-missing-report timeouts and no
        // silently dropped late reports.
        let mut update_reports = inline_reports;
        match churn_rt {
            ChurnRt::Background { tx, .. } => drop(tx),
            _ => stop.store(true, Ordering::Release),
        }
        if let Some(handle) = applier_handle {
            handle
                .join()
                .map_err(|_| anyhow::anyhow!("update applier panicked"))?;
            while let Ok(r) = urep_rx.try_recv() {
                update_reports.push(r);
            }
        }
        stop.store(true, Ordering::Release);

        // retire the cooperative dispatcher. The loop above exits only
        // once every query completed, so backlogs are empty — but
        // flush defensively so no group could ever be dropped.
        let steals = match coop.take() {
            Some(mut c) => {
                for (s, item) in c.drain_all() {
                    let _ = txs[s].send(item);
                }
                c.steals
            }
            None => 0,
        };

        // shut shards down and collect their final accounting
        drop(txs);
        let mut mat_wait_s = 0.0;
        let mut arena_bytes = 0usize;
        let mut arena_allocations = 0usize;
        let mut store_faults = 0u64;
        let mut resident_bytes = 0u64;
        let mut shared_row_bytes = 0u64;
        for msg in res_rx.iter() {
            if let ShardMsg::Done(d) = msg {
                mat_wait_s += d.wait_s;
                arena_bytes += d.arena_bytes;
                arena_allocations += d.arena_allocations;
                store_faults += d.store_faults;
                resident_bytes += d.resident_bytes;
                shared_row_bytes += d.shared_row_bytes;
            }
        }

        let final_state = cell.load();
        tbuf.flush();
        let shed_total = metrics.shed();
        let lat = &metrics.latency;
        let report = ServeReport {
            queries: cfg.queries,
            wall_s,
            qps: total as f64 / wall_s.max(1e-9),
            p50_ms: lat.quantile(0.50) * 1e3,
            p95_ms: lat.quantile(0.95) * 1e3,
            p99_ms: lat.quantile(0.99) * 1e3,
            mean_ms: lat.mean() * 1e3,
            max_ms: lat.max() * 1e3,
            executions: metrics.executions,
            executed_queries: metrics.executed_queries,
            coalescing_factor: metrics.coalescing_factor(),
            cache_hits: metrics.cache_hit_queries,
            cache_hit_rate: metrics.hit_rate(),
            cold_routes: metrics.cold_routes,
            cold_plans: router.cold_built - cold_ids_at_start,
            accuracy: metrics.accuracy(),
            shard_queries: metrics.shard_queries.clone(),
            shard_balance: metrics.shard_balance(),
            plans: final_state.num_plans(),
            exec_s: metrics.exec_s,
            mat_wait_s,
            arena_bytes,
            arena_allocations,
            results_cache_bytes: results.bytes(),
            snapshot_swaps,
            final_epoch: final_state.epoch,
            memo_swept,
            offered_qps: cfg.offered_qps,
            deadline_ms: cfg
                .deadline
                .map(|d| d.as_secs_f64() * 1e3)
                .unwrap_or(0.0),
            admitted: metrics.completed,
            shed: metrics.shed_deadline,
            shed_rate_limited: metrics.shed_rate_limited,
            degraded: metrics.degraded,
            shed_fraction: shed_total as f64 / total.max(1) as f64,
            goodput_qps: metrics.within_deadline as f64 / wall_s.max(1e-9),
            tenant_stats: gate.tenants.clone(),
            gc_retained_bytes_peak,
            gc_retained_groups,
            logit_hash,
            store_faults,
            resident_bytes,
            steals,
            replica_dispatches,
            shared_row_bytes,
        };
        Ok((report, update_reports))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{sbm, DatasetSpec};

    fn tiny() -> Dataset {
        sbm::generate(&DatasetSpec::tiny_for_tests(), 33)
    }

    #[test]
    fn serves_every_query_exactly_once() {
        let ds = tiny();
        let cfg = ServeConfig {
            queries: 64,
            clients: 8,
            shards: 2,
            flush_window: Duration::from_micros(300),
            ..Default::default()
        };
        let eval = ds.splits.train.clone();
        let mut setup = prepare(ds, &eval, &cfg);
        assert!(!setup.state().cache.is_empty());
        let report =
            serve_closed_loop(&mut setup, &eval, Skew::Zipf(1.2), &cfg)
                .unwrap();
        assert_eq!(report.queries, 64);
        assert_eq!(
            report.executed_queries + report.cache_hits,
            64,
            "every query answered by execution or memo"
        );
        assert!(report.executions <= report.executed_queries);
        assert!(report.qps > 0.0);
        assert!(report.wall_s > 0.0);
        assert!((0.0..=1.0).contains(&report.accuracy));
        assert_eq!(
            report.shard_queries.iter().sum::<u64>(),
            report.executed_queries
        );
        // closed loop with no warm memo must execute at least once
        assert!(report.executions >= 1);
        // static deployment: epoch 0 throughout, no swaps observed
        assert_eq!(report.snapshot_swaps, 0);
        assert_eq!(report.final_epoch, 0);
    }

    #[test]
    fn open_loop_overload_sheds_and_accounts_every_query() {
        let ds = tiny();
        let cfg = ServeConfig {
            queries: 300,
            shards: 1,
            // offered far past any plausible capacity with a deadline
            // the backlog cannot meet: the gate must shed
            offered_qps: 1e6,
            deadline: Some(Duration::from_millis(2)),
            tenants: 2,
            flush_window: Duration::from_micros(200),
            results_cache_bytes: 0, // memo off: over-deadline = shed
            ..Default::default()
        };
        let eval = ds.splits.train.clone();
        let mut setup = prepare(ds, &eval, &cfg);
        let r = serve_closed_loop(&mut setup, &eval, Skew::Uniform, &cfg)
            .unwrap();
        // every offered query is accounted exactly once
        assert_eq!(
            r.admitted + r.shed + r.shed_rate_limited,
            300,
            "admitted {} shed {} rate {}",
            r.admitted,
            r.shed,
            r.shed_rate_limited
        );
        assert_eq!(
            r.executed_queries + r.cache_hits,
            r.admitted,
            "every admitted query answered"
        );
        assert!(r.shed > 0, "1e6 qps at a 2ms deadline must shed");
        assert!(r.shed_fraction > 0.0 && r.shed_fraction <= 1.0);
        assert!(r.goodput_qps >= 0.0);
        assert!((r.deadline_ms - 2.0).abs() < 1e-9);
        let tenant_total: u64 =
            r.tenant_stats.iter().map(|t| t.total()).sum();
        assert_eq!(tenant_total, 300, "tenant counters cover the run");
    }

    #[test]
    fn tenant_rate_limit_sheds_excess() {
        let ds = tiny();
        let cfg = ServeConfig {
            queries: 50,
            clients: 4,
            shards: 1,
            tenants: 2,
            // ~zero refill with a burst of 2 per tenant: at most ~4
            // admissions can ever pass the buckets
            tenant_rate: 1e-3,
            tenant_burst: 2.0,
            ..Default::default()
        };
        let eval = ds.splits.train.clone();
        let mut setup = prepare(ds, &eval, &cfg);
        let r = serve_closed_loop(&mut setup, &eval, Skew::Uniform, &cfg)
            .unwrap();
        assert!(
            r.shed_rate_limited >= 40,
            "rate limiter passed {} of 50",
            50 - r.shed_rate_limited
        );
        assert_eq!(r.admitted + r.shed + r.shed_rate_limited, 50);
        assert_eq!(r.executed_queries + r.cache_hits, r.admitted);
    }

    #[test]
    fn memo_absorbs_repeat_queries() {
        let ds = tiny();
        let cfg = ServeConfig {
            queries: 40,
            clients: 1, // strictly sequential: every repeat is a hit
            shards: 1,
            results_cache_bytes: 1 << 20,
            flush_window: Duration::from_micros(100),
            ..Default::default()
        };
        let eval = ds.splits.train.clone();
        let node = [eval[0]];
        let mut setup = prepare(ds, &eval, &cfg);
        let report =
            serve_closed_loop(&mut setup, &node, Skew::Uniform, &cfg).unwrap();
        assert_eq!(report.executions, 1, "one execution, then memo hits");
        assert_eq!(report.cache_hits, 39);
        assert!(report.cache_hit_rate > 0.9);
    }

    #[test]
    fn executors_agree_on_predictions_and_hash() {
        let ds = tiny();
        let eval = ds.splits.train.clone();
        let base = ServeConfig {
            queries: 48,
            clients: 6,
            shards: 2,
            flush_window: Duration::from_micros(200),
            seed: 11,
            ..Default::default()
        };
        let mut runs = Vec::new();
        for kind in [ExecutorKind::Reference, ExecutorKind::Blocked] {
            let cfg = ServeConfig {
                executor: kind,
                ..base.clone()
            };
            let mut setup = prepare(ds.clone(), &eval, &cfg);
            let r = serve_closed_loop(&mut setup, &eval, Skew::Uniform, &cfg)
                .unwrap();
            assert_eq!(r.executed_queries + r.cache_hits, 48, "{kind:?}");
            runs.push(r);
        }
        assert!(runs[0].logit_hash != 0);
        assert_eq!(
            runs[0].logit_hash, runs[1].logit_hash,
            "reference and blocked disagree on predictions"
        );
        assert!((runs[0].accuracy - runs[1].accuracy).abs() < 1e-12);
    }

    #[test]
    fn pjrt_executor_fails_before_serving_starts() {
        let ds = tiny();
        let cfg = ServeConfig {
            queries: 8,
            executor: ExecutorKind::Pjrt,
            ..Default::default()
        };
        let eval = ds.splits.train.clone();
        let mut setup = prepare(ds, &eval, &cfg);
        let err = serve_closed_loop(&mut setup, &eval, Skew::Uniform, &cfg)
            .expect_err("stub backend must fail the run cleanly");
        assert!(err.to_string().contains("PJRT"), "{err}");
    }

    #[test]
    fn lazy_store_serving_matches_warm_predictions() {
        use crate::store::PlanStore;
        let dir = std::env::temp_dir().join(format!(
            "ibmb_lazy_serve_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let ds = tiny();
        let cfg = ServeConfig {
            queries: 48,
            clients: 6,
            shards: 2,
            flush_window: Duration::from_micros(200),
            seed: 17,
            ..Default::default()
        };
        let eval = ds.splits.train.clone();
        // warm run, then persist its deployment
        let mut warm = prepare(ds.clone(), &eval, &cfg);
        let warm_state = warm.state();
        let store = PlanStore::open(&dir).unwrap();
        store
            .save_full(
                &warm_state.cache,
                &warm_state.epochs,
                0,
                &warm_state.index.to_packed(),
            )
            .unwrap();
        let warm_report =
            serve_closed_loop(&mut warm, &eval, Skew::Uniform, &cfg).unwrap();
        assert_eq!(warm_report.store_faults, 0, "warm run must not fault");
        // lazy cold start: manifest only, payloads fault on demand
        let mut lazy =
            prepare_from_store(ds, Arc::new(store), &cfg).unwrap();
        let lazy_state = lazy.state();
        assert!(lazy_state.lazy());
        assert!(lazy_state.cache.is_empty(), "no payloads resident");
        assert_eq!(lazy_state.num_plans(), warm_state.cache.len());
        assert_eq!(lazy_state.meta.n_pad, warm_state.meta.n_pad);
        let lazy_report =
            serve_closed_loop(&mut lazy, &eval, Skew::Uniform, &cfg).unwrap();
        assert_eq!(
            lazy_report.executed_queries + lazy_report.cache_hits,
            48
        );
        assert!(lazy_report.store_faults > 0, "lazy run must fault");
        assert!(lazy_report.resident_bytes > 0);
        assert_eq!(
            lazy_report.logit_hash, warm_report.logit_hash,
            "store-backed serving changed predictions"
        );
        assert!((lazy_report.accuracy - warm_report.accuracy).abs() < 1e-12);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cold_start_from_cache_matches_planned_prepare() {
        let ds = tiny();
        let cfg = ServeConfig::default();
        let eval = ds.splits.train.clone();
        let planned = prepare(ds.clone(), &eval, &cfg);
        let planned_state = planned.state();
        // rebuild the deployment from the "persisted" cache + index
        let cache = CowCache::from_cache(
            &planned_state.cache.to_batch_cache(),
        );
        let index = RouterIndex::from_packed(
            planned_state.index.to_packed(),
            &cache,
        )
        .unwrap();
        let cold =
            prepare_from_cache(ds, cache, Some(index), &cfg).unwrap();
        let cold_state = cold.state();
        assert_eq!(cold_state.cache.len(), planned_state.cache.len());
        assert_eq!(
            cold_state.index.coverage(),
            planned_state.index.coverage()
        );
        for u in 0..cold_state.ds.graph.num_nodes() as u32 {
            assert_eq!(
                cold_state.index.lookup(u),
                planned_state.index.lookup(u),
                "node {u}"
            );
        }
        assert_eq!(cold_state.meta.n_pad, planned_state.meta.n_pad);
    }

    #[test]
    fn cooperative_matches_noncooperative_hash_across_seeds() {
        // stealing, replica routing, and shared fills move *where* a
        // group executes, never *what* it computes: the commutative
        // logit hash must be bit-identical with cooperation on or off
        let ds = tiny();
        let eval = ds.splits.train.clone();
        for seed in [11u64, 23, 47] {
            let base = ServeConfig {
                queries: 96,
                clients: 8,
                shards: 2,
                flush_window: Duration::from_micros(200),
                seed,
                ..Default::default()
            };
            let mut runs = Vec::new();
            for cooperative in [false, true] {
                let cfg = ServeConfig {
                    cooperative,
                    steal_window: 1, // tight window: force backlogging
                    ..base.clone()
                };
                let mut setup = prepare(ds.clone(), &eval, &cfg);
                let r =
                    serve_closed_loop(&mut setup, &eval, Skew::Zipf(1.2), &cfg)
                        .unwrap();
                assert_eq!(
                    r.executed_queries + r.cache_hits,
                    96,
                    "seed {seed} coop {cooperative}: every query answered"
                );
                runs.push(r);
            }
            assert!(runs[0].logit_hash != 0);
            assert_eq!(
                runs[0].logit_hash, runs[1].logit_hash,
                "seed {seed}: cooperative mode changed predictions"
            );
            assert!((runs[0].accuracy - runs[1].accuracy).abs() < 1e-12);
            // the baseline run must not report cooperative activity
            assert_eq!(runs[0].steals, 0);
            assert_eq!(runs[0].replica_dispatches, 0);
            assert_eq!(runs[0].shared_row_bytes, 0);
        }
    }

    #[test]
    fn cooperative_run_steals_and_accounts_every_group_once() {
        let ds = tiny();
        let cfg = ServeConfig {
            queries: 200,
            clients: 16,
            shards: 2,
            cooperative: true,
            steal_window: 1, // one group in flight per shard: skewed
            // load must backlog on the hot shard, and the idle shard
            // must either steal from it or absorb replica dispatches
            flush_window: Duration::from_micros(100),
            seed: 7,
            ..Default::default()
        };
        let eval = ds.splits.train.clone();
        let mut setup = prepare(ds.clone(), &eval, &cfg);
        let r = serve_closed_loop(&mut setup, &eval, Skew::Zipf(1.2), &cfg)
            .unwrap();
        assert_eq!(
            r.executed_queries + r.cache_hits,
            200,
            "every query answered exactly once"
        );
        // per-shard attribution at result receipt still covers every
        // executed query — no group double-executes or vanishes
        assert_eq!(
            r.shard_queries.iter().sum::<u64>(),
            r.executed_queries,
            "executed-query attribution drifted: {:?}",
            r.shard_queries
        );
        assert!(
            r.steals > 0 || r.replica_dispatches > 0,
            "zipf 1.2 over 2 shards with window 1 moved no work \
             (steals {} replicas {})",
            r.steals,
            r.replica_dispatches
        );
    }
}
