//! The serving event loop: closed-loop clients → router → coalescing
//! queue → sharded executors → results memo → latency metrics.
//!
//! The loop is single-threaded on the control side (routing, queueing,
//! memoization, accounting) with N executor shard threads; queries
//! complete out of the shards' result channel. Clients are closed-loop:
//! each of `clients` logical clients keeps exactly one query in flight
//! and issues its next the moment the previous completes, which is what
//! makes throughput self-limiting and the coalescing factor an honest
//! function of concurrency × skew rather than of an open-loop arrival
//! schedule.
//!
//! A query's life: admit (route + memo probe) → coalesce in the queue
//! (size/deadline flush) → execute once per *group* on its plan's home
//! shard → complete every rider, memoize the plan's output logits.

use std::collections::HashMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::batching::{BatchCache, BatchGenerator, NodeWiseIbmb};
use crate::config::preset_for;
use crate::datasets::Dataset;
use crate::runtime::{ArtifactMeta, ModelState};
use crate::util::Rng;

use super::load::{LoadGen, Skew};
use super::metrics::ServeMetrics;
use super::queue::{MicrobatchQueue, PendingGroup, QueryTicket};
use super::results::ResultsCache;
use super::router::{PlanKey, QueryRouter, Route};
use super::shard::{
    argmax, reference_artifact, shard_worker, ShardCtx, ShardMap, ShardMsg,
    Work, WorkItem,
};

/// Serving configuration (CLI: `ibmb serve`).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Model family: "gcn" | "sage" | "gat".
    pub model: String,
    /// Executor worker shards.
    pub shards: usize,
    /// Closed-loop clients (max queries in flight).
    pub clients: usize,
    /// Total queries to serve.
    pub queries: usize,
    /// Microbatch deadline: max time a query waits for co-riders.
    pub flush_window: Duration,
    /// Size flush threshold (queries per group).
    pub max_coalesce: usize,
    /// Results-memo byte budget (0 disables).
    pub results_cache_bytes: usize,
    /// Results-memo freshness bound (None = until evicted).
    pub results_ttl: Option<Duration>,
    /// Top-k PPR budget for cold (uncovered) query nodes.
    pub cold_aux: usize,
    /// Per-shard prefetch ring depth.
    pub ring_depth: usize,
    /// Reference-model hidden width.
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            model: "gcn".to_string(),
            shards: 1,
            clients: 16,
            queries: 500,
            flush_window: Duration::from_micros(500),
            max_coalesce: 16,
            results_cache_bytes: 0,
            results_ttl: None,
            cold_aux: 16,
            ring_depth: 2,
            hidden: 32,
            layers: 2,
            heads: 2,
            seed: 0,
        }
    }
}

/// Everything [`serve_closed_loop`] needs that is built once per
/// deployment: the precomputed plan cache, the executor model, and the
/// query router (whose cold-plan memo persists across runs). The
/// [`ShardMap`] is rebuilt per run because it depends on the run's
/// shard count.
pub struct ServeSetup {
    pub cache: BatchCache,
    pub meta: ArtifactMeta,
    pub state: ModelState,
    pub router: QueryRouter,
    /// Per-plan epochs, parallel to `cache`: the graph epoch each plan
    /// last reflected (all zero for a static deployment). The results
    /// memo keys freshness on these — see
    /// [`super::update::DynamicServeSession`].
    pub epochs: Vec<u64>,
}

/// Build a [`ServeSetup`] around an already-planned cache: pick the
/// artifact bucket, synthesize the reference executor model, init its
/// state, and invert the router index. Shared by the static
/// [`prepare`] and the dynamic session
/// ([`super::update::DynamicServeSession::prepare`]) so the bucket
/// formula and seeds cannot drift between the two.
pub(crate) fn setup_from_cache(
    ds: &Dataset,
    cache: BatchCache,
    cfg: &ServeConfig,
) -> ServeSetup {
    let bucket = cache
        .max_batch_nodes()
        .max(cfg.cold_aux + 1)
        .next_power_of_two()
        .max(16);
    let meta = reference_artifact(
        &cfg.model,
        ds.feat_dim,
        ds.num_classes,
        cfg.hidden,
        cfg.layers,
        cfg.heads,
        bucket,
    );
    let state = ModelState::init(&meta, cfg.seed ^ 0x51A7E);
    let router = QueryRouter::build(ds, &cache);
    let epochs = vec![0u64; cache.len()];
    ServeSetup {
        cache,
        meta,
        state,
        router,
        epochs,
    }
}

/// Plan the serveable node set with node-wise IBMB (dataset preset),
/// synthesize the reference executor model sized to the resulting
/// bucket, and build the query router over the plan set.
pub fn prepare(ds: &Dataset, eval_nodes: &[u32], cfg: &ServeConfig) -> ServeSetup {
    let p = preset_for(&ds.name);
    let mut g = NodeWiseIbmb {
        aux_per_output: p.aux_per_output,
        max_outputs_per_batch: p.outputs_per_batch,
        node_budget: p.node_budget,
        ..Default::default()
    };
    let mut rng = Rng::new(cfg.seed ^ 0xCAFE);
    let cache = BatchCache::build(&g.plan(ds, eval_nodes, &mut rng));
    setup_from_cache(ds, cache, cfg)
}

/// Aggregate outcome of one closed-loop serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub queries: usize,
    pub wall_s: f64,
    pub qps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub max_ms: f64,
    /// Materialize+execute runs performed.
    pub executions: u64,
    /// Queries answered by executions.
    pub executed_queries: u64,
    /// Queries per execution (> 1 = coalescing won).
    pub coalescing_factor: f64,
    /// Queries answered from the results memo.
    pub cache_hits: u64,
    pub cache_hit_rate: f64,
    /// Queries answered via the cold (synthesized-plan) path — memo
    /// hits for previously executed cold plans are not counted.
    pub cold_routes: u64,
    /// Cold-plan ids assigned during this run (≈ distinct new cold
    /// nodes; shard-side FIFO eviction may resynthesize an id's plan).
    pub cold_plans: usize,
    pub accuracy: f64,
    pub shard_queries: Vec<u64>,
    pub shard_balance: f64,
    /// Precomputed plans available to the router.
    pub plans: usize,
    /// Shard-side seconds in the forward pass (summed over shards).
    pub exec_s: f64,
    /// Shard-side seconds stalled waiting on materialization.
    pub mat_wait_s: f64,
    /// Dense-buffer bytes pooled across all shard arenas.
    pub arena_bytes: usize,
    /// Fresh buffer allocations across all shard arenas (steady state:
    /// ring depth × shards).
    pub arena_allocations: usize,
    /// Bytes resident in the results memo at shutdown.
    pub results_cache_bytes: usize,
}

fn dispatch_group(
    g: PendingGroup,
    work_of: &HashMap<PlanKey, Work>,
    map: &ShardMap,
    txs: &[mpsc::Sender<WorkItem>],
    metrics: &mut ServeMetrics,
) -> Result<()> {
    let work = *work_of
        .get(&g.key)
        .expect("dispatched group without registered work");
    let shard = match work {
        Work::Cached(pid) => map.shard_of_plan(pid),
        Work::Cold(node) => map.shard_of_node(node),
    };
    metrics.record_dispatch(shard, g.queries.len() as u64);
    txs[shard]
        .send(WorkItem {
            key: g.key,
            work,
            queries: g.queries,
        })
        .map_err(|_| anyhow::anyhow!("shard {shard} hung up"))?;
    Ok(())
}

/// Serve `cfg.queries` queries drawn from `population` with `skew`,
/// closed-loop, with a per-run results memo sized by
/// `cfg.results_cache_bytes`. `setup` is borrowed mutably for the
/// router's cold-plan memo, which stays warm across repeated runs
/// (the bench's shard sweep reuses one setup).
pub fn serve_closed_loop(
    ds: &Dataset,
    setup: &mut ServeSetup,
    population: &[u32],
    skew: Skew,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    let mut results = ResultsCache::new(cfg.results_cache_bytes, cfg.results_ttl);
    serve_closed_loop_with(ds, setup, population, skew, cfg, &mut results)
}

/// [`serve_closed_loop`] against a caller-owned results memo — the
/// dynamic-update session keeps one memo alive across serving
/// segments so post-delta epoch eviction is actually observable.
/// Memo lookups and inserts are keyed by the plan's current epoch
/// (`setup.epochs`); cold plans use epoch 0 (their router ids are
/// never reused across deltas). Blocks until every query completes
/// and all shards have shut down; returns the aggregate report.
pub fn serve_closed_loop_with(
    ds: &Dataset,
    setup: &mut ServeSetup,
    population: &[u32],
    skew: Skew,
    cfg: &ServeConfig,
    results: &mut ResultsCache,
) -> Result<ServeReport> {
    let cache = &setup.cache;
    let meta = &setup.meta;
    let state = &setup.state;
    let epochs = &setup.epochs;
    let router = &mut setup.router;
    // ServeSetup persists across runs; report this run's delta
    let cold_ids_at_start = router.cold_built;
    anyhow::ensure!(!population.is_empty(), "empty query population");
    anyhow::ensure!(cfg.queries > 0, "need at least one query");
    anyhow::ensure!(
        meta.feat == ds.feat_dim,
        "artifact feat {} != dataset feat {}",
        meta.feat,
        ds.feat_dim
    );
    let shards = cfg.shards.max(1);
    let total = cfg.queries as u64;
    let clients = cfg.clients.max(1).min(cfg.queries) as u64;
    let classes = meta.classes;

    let mut rng = Rng::new(cfg.seed ^ 0x5E21);
    let map = ShardMap::build(ds, cache, shards, &mut rng);
    let mut queue = MicrobatchQueue::new(cfg.flush_window, cfg.max_coalesce);
    let mut metrics = ServeMetrics::new(shards);
    let epoch_of = |key: &PlanKey| -> u64 {
        match key {
            PlanKey::Cached(pid) => {
                epochs.get(*pid as usize).copied().unwrap_or(0)
            }
            PlanKey::Cold(_) => 0,
        }
    };
    let mut load = LoadGen::new(population, skew, cfg.seed ^ 0x10AD);

    std::thread::scope(|scope| -> Result<ServeReport> {
        let (res_tx, res_rx) = mpsc::channel::<ShardMsg>();
        let mut txs: Vec<mpsc::Sender<WorkItem>> = Vec::with_capacity(shards);
        for shard_id in 0..shards {
            let (tx, rx) = mpsc::channel::<WorkItem>();
            let ctx = ShardCtx {
                shard_id,
                ds,
                cache,
                meta,
                state,
                bucket: meta.n_pad,
                ring_depth: cfg.ring_depth,
                cold_aux: cfg.cold_aux,
            };
            let out = res_tx.clone();
            scope.spawn(move || shard_worker(ctx, rx, out));
            txs.push(tx);
        }
        drop(res_tx);

        let mut work_of: HashMap<PlanKey, Work> = HashMap::new();
        let mut arrivals: HashMap<u64, Instant> = HashMap::new();
        let mut issued = 0u64;
        let mut completed = 0u64;
        let t0 = Instant::now();
        let wall_s = loop {
            // closed-loop admission: top up to `clients` in flight;
            // memo hits complete synchronously and free their client
            // slot immediately.
            while issued < total && issued - completed < clients {
                let node = load.next_node();
                let id = issued;
                issued += 1;
                let now = Instant::now();
                let route = router.route(node);
                let key = route.key();
                let pos = route.pos();
                if let Some(logits) = results.get(key, epoch_of(&key), now) {
                    let start = pos as usize * classes;
                    let pred = argmax(&logits[start..start + classes]);
                    metrics.cache_hit_queries += 1;
                    metrics.record_completion(
                        0.0,
                        pred == ds.labels[node as usize] as usize,
                    );
                    completed += 1;
                    continue;
                }
                // counted after the memo probe: memo-served repeats
                // never reach the synthesized-plan path
                if matches!(route, Route::Cold { .. }) {
                    metrics.cold_routes += 1;
                }
                let work = match route {
                    Route::Cached { plan, .. } => Work::Cached(plan),
                    // the node's home shard synthesizes + memoizes
                    Route::Cold { .. } => Work::Cold(node),
                };
                work_of.entry(key).or_insert(work);
                arrivals.insert(id, now);
                if let Some(group) =
                    queue.push(key, QueryTicket { id, node, pos }, now)
                {
                    dispatch_group(group, &work_of, &map, &txs, &mut metrics)?;
                }
            }
            if completed >= total {
                break t0.elapsed().as_secs_f64();
            }
            // deadline flushes
            let now = Instant::now();
            for group in queue.due(now) {
                dispatch_group(group, &work_of, &map, &txs, &mut metrics)?;
            }
            // sleep until the next deadline or the next completion
            let timeout = queue
                .next_deadline()
                .map(|d| d.saturating_duration_since(Instant::now()))
                .unwrap_or(Duration::from_millis(10))
                .min(Duration::from_millis(10));
            match res_rx.recv_timeout(timeout) {
                Ok(ShardMsg::Result(r)) => {
                    let now = Instant::now();
                    for o in &r.outcomes {
                        let lat = arrivals
                            .remove(&o.id)
                            .map(|a| now.duration_since(a).as_secs_f64())
                            .unwrap_or(0.0);
                        metrics.record_completion(lat, o.correct);
                        completed += 1;
                    }
                    metrics.exec_s += r.exec_s;
                    results.insert(r.key, epoch_of(&r.key), r.out_logits, now);
                }
                Ok(ShardMsg::Done(_)) => {
                    anyhow::bail!("shard exited early");
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("all shards disconnected");
                }
            }
        };

        // shut shards down and collect their final accounting
        drop(txs);
        let mut mat_wait_s = 0.0;
        let mut arena_bytes = 0usize;
        let mut arena_allocations = 0usize;
        for msg in res_rx.iter() {
            if let ShardMsg::Done(d) = msg {
                mat_wait_s += d.wait_s;
                arena_bytes += d.arena_bytes;
                arena_allocations += d.arena_allocations;
            }
        }

        let lat = &metrics.latency;
        Ok(ServeReport {
            queries: cfg.queries,
            wall_s,
            qps: total as f64 / wall_s.max(1e-9),
            p50_ms: lat.quantile(0.50) * 1e3,
            p95_ms: lat.quantile(0.95) * 1e3,
            p99_ms: lat.quantile(0.99) * 1e3,
            mean_ms: lat.mean() * 1e3,
            max_ms: lat.max() * 1e3,
            executions: metrics.executions,
            executed_queries: metrics.executed_queries,
            coalescing_factor: metrics.coalescing_factor(),
            cache_hits: metrics.cache_hit_queries,
            cache_hit_rate: metrics.hit_rate(),
            cold_routes: metrics.cold_routes,
            cold_plans: router.cold_built - cold_ids_at_start,
            accuracy: metrics.accuracy(),
            shard_queries: metrics.shard_queries.clone(),
            shard_balance: metrics.shard_balance(),
            plans: cache.len(),
            exec_s: metrics.exec_s,
            mat_wait_s,
            arena_bytes,
            arena_allocations,
            results_cache_bytes: results.bytes(),
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{sbm, DatasetSpec};

    fn tiny() -> Dataset {
        sbm::generate(&DatasetSpec::tiny_for_tests(), 33)
    }

    #[test]
    fn serves_every_query_exactly_once() {
        let ds = tiny();
        let cfg = ServeConfig {
            queries: 64,
            clients: 8,
            shards: 2,
            flush_window: Duration::from_micros(300),
            ..Default::default()
        };
        let eval = ds.splits.train.clone();
        let mut setup = prepare(&ds, &eval, &cfg);
        assert!(!setup.cache.is_empty());
        let report =
            serve_closed_loop(&ds, &mut setup, &eval, Skew::Zipf(1.2), &cfg)
                .unwrap();
        assert_eq!(report.queries, 64);
        assert_eq!(
            report.executed_queries + report.cache_hits,
            64,
            "every query answered by execution or memo"
        );
        assert!(report.executions <= report.executed_queries);
        assert!(report.qps > 0.0);
        assert!(report.wall_s > 0.0);
        assert!((0.0..=1.0).contains(&report.accuracy));
        assert_eq!(
            report.shard_queries.iter().sum::<u64>(),
            report.executed_queries
        );
        // closed loop with no warm memo must execute at least once
        assert!(report.executions >= 1);
    }

    #[test]
    fn memo_absorbs_repeat_queries() {
        let ds = tiny();
        let cfg = ServeConfig {
            queries: 40,
            clients: 1, // strictly sequential: every repeat is a hit
            shards: 1,
            results_cache_bytes: 1 << 20,
            flush_window: Duration::from_micros(100),
            ..Default::default()
        };
        let eval = ds.splits.train.clone();
        let mut setup = prepare(&ds, &eval, &cfg);
        let node = [eval[0]];
        let report =
            serve_closed_loop(&ds, &mut setup, &node, Skew::Uniform, &cfg)
                .unwrap();
        assert_eq!(report.executions, 1, "one execution, then memo hits");
        assert_eq!(report.cache_hits, 39);
        assert!(report.cache_hit_rate > 0.9);
    }
}
