//! The serving event loop: closed-loop clients → router → coalescing
//! queue → sharded executors → results memo → latency metrics, all
//! reading immutable epoch snapshots (DESIGN.md §11).
//!
//! The loop is single-threaded on the control side (routing, queueing,
//! memoization, accounting) with N executor shard threads; queries
//! complete out of the shards' result channel. Every admission round
//! loads the current [`ServeState`] from the shared [`ServeStateCell`]
//! — one pointer clone — and routes, memo-probes, and enqueues against
//! that snapshot, so a query's (plan id, output row, memo epoch)
//! triple is internally consistent by construction even while the
//! background [`super::update::UpdateApplier`] publishes new epochs
//! mid-run. Nothing quiesces on a swap: in-flight groups execute
//! against the snapshot they pinned, and the only swap-time work on
//! the control thread is an eager memo sweep
//! ([`ResultsCache::purge_stale`]) reclaiming epoch-expired bytes.
//!
//! Clients are closed-loop: each of `clients` logical clients keeps
//! exactly one query in flight and issues its next the moment the
//! previous completes, which is what makes throughput self-limiting
//! and the coalescing factor an honest function of concurrency × skew
//! rather than of an open-loop arrival schedule.
//!
//! A query's life: admit (route + memo probe against the loaded
//! snapshot) → coalesce in the queue (size/deadline flush, grouped by
//! plan *and* epoch) → execute once per group on its plan's home shard
//! → complete every rider, memoize the plan's output logits at the
//! group's epoch.
//!
//! [`serve_with_churn`] is the same loop with a delta source attached:
//! `Inline` churn applies deltas on the control thread (the quiesced
//! baseline — the stall lands on every pending deadline) while
//! `Background`/`Stream` churn feeds a scoped applier thread that
//! builds and publishes snapshots off to the side — the zero-quiesce
//! path `benches/updates.rs` measures against it.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::batching::{BatchGenerator, CowCache, NodeWiseIbmb};
use crate::config::preset_for;
use crate::datasets::Dataset;
use crate::graph::GraphDelta;
use crate::runtime::{ArtifactMeta, ModelState};
use crate::util::Rng;

use super::load::{LoadGen, Skew};
use super::metrics::ServeMetrics;
use super::queue::{MicrobatchQueue, PendingGroup, QueryTicket};
use super::results::ResultsCache;
use super::router::{PlanKey, QueryRouter, Route, RouterIndex};
use super::shard::{
    argmax, reference_artifact, shard_worker, Placement, ShardCtx, ShardMsg,
    Work, WorkItem, PLACEMENT_CELLS,
};
use super::state::{ServeState, ServeStateCell};
use super::update::{run_applier, UpdateApplier, UpdateReport};

/// Serving configuration (CLI: `ibmb serve`).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Model family: "gcn" | "sage" | "gat".
    pub model: String,
    /// Executor worker shards.
    pub shards: usize,
    /// Closed-loop clients (max queries in flight).
    pub clients: usize,
    /// Total queries to serve.
    pub queries: usize,
    /// Microbatch deadline: max time a query waits for co-riders.
    pub flush_window: Duration,
    /// Size flush threshold (queries per group).
    pub max_coalesce: usize,
    /// Results-memo byte budget (0 disables).
    pub results_cache_bytes: usize,
    /// Results-memo freshness bound (None = until evicted).
    pub results_ttl: Option<Duration>,
    /// Top-k PPR budget for cold (uncovered) query nodes.
    pub cold_aux: usize,
    /// Per-shard prefetch ring depth.
    pub ring_depth: usize,
    /// Reference-model hidden width.
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            model: "gcn".to_string(),
            shards: 1,
            clients: 16,
            queries: 500,
            flush_window: Duration::from_micros(500),
            max_coalesce: 16,
            results_cache_bytes: 0,
            results_ttl: None,
            cold_aux: 16,
            ring_depth: 2,
            hidden: 32,
            layers: 2,
            heads: 2,
            seed: 0,
        }
    }
}

/// A serving deployment handle: the snapshot cell every run (and the
/// background applier) shares, plus the control loop's only mutable
/// routing state — the cold-id memo, which stays warm across repeated
/// runs (the bench's shard sweep reuses one setup).
pub struct ServeSetup {
    pub cell: Arc<ServeStateCell>,
    pub router: QueryRouter,
}

impl ServeSetup {
    /// The snapshot currently published (inspection / tests).
    pub fn state(&self) -> Arc<ServeState> {
        self.cell.load()
    }
}

/// Build the initial (epoch 0) snapshot around an already-planned
/// cache: pick the artifact bucket, synthesize the reference executor
/// model, init its state, invert (or adopt) the router index, and
/// place plans on partition cells. Shared by the static [`prepare`],
/// the cold-start [`prepare_from_cache`], and the dynamic session
/// ([`super::update::DynamicServeSession::prepare`]) so the bucket
/// formula and seeds cannot drift between them.
pub(crate) fn build_initial_state(
    ds: Arc<Dataset>,
    cache: CowCache,
    cfg: &ServeConfig,
    index: Option<RouterIndex>,
) -> (Arc<ServeStateCell>, Arc<ArtifactMeta>, Arc<ModelState>) {
    let bucket = cache
        .max_batch_nodes()
        .max(cfg.cold_aux + 1)
        .next_power_of_two()
        .max(16);
    let meta = Arc::new(reference_artifact(
        &cfg.model,
        ds.feat_dim,
        ds.num_classes,
        cfg.hidden,
        cfg.layers,
        cfg.heads,
        bucket,
    ));
    let model = Arc::new(ModelState::init(&meta, cfg.seed ^ 0x51A7E));
    let index = Arc::new(index.unwrap_or_else(|| {
        RouterIndex::build(ds.graph.num_nodes(), &cache)
    }));
    let mut rng = Rng::new(cfg.seed ^ 0x5E21);
    let placement =
        Arc::new(Placement::build(&ds, &cache, PLACEMENT_CELLS, &mut rng));
    let epochs = Arc::new(vec![0u64; cache.len()]);
    let state = Arc::new(ServeState {
        epoch: 0,
        ds,
        cache: Arc::new(cache),
        index,
        epochs,
        placement,
        meta: meta.clone(),
        model: model.clone(),
    });
    debug_assert!(state.validate().is_ok(), "{:?}", state.validate());
    (Arc::new(ServeStateCell::new(state)), meta, model)
}

/// Plan the serveable node set with node-wise IBMB (dataset preset),
/// synthesize the reference executor model sized to the resulting
/// bucket, and publish the epoch-0 snapshot. Takes the dataset by
/// value: it becomes part of the immutable snapshot.
pub fn prepare(ds: Dataset, eval_nodes: &[u32], cfg: &ServeConfig) -> ServeSetup {
    let p = preset_for(&ds.name);
    let mut g = NodeWiseIbmb {
        aux_per_output: p.aux_per_output,
        max_outputs_per_batch: p.outputs_per_batch,
        node_budget: p.node_budget,
        ..Default::default()
    };
    let mut rng = Rng::new(cfg.seed ^ 0xCAFE);
    let cache = CowCache::from_plans(&g.plan(&ds, eval_nodes, &mut rng));
    let (cell, _, _) = build_initial_state(Arc::new(ds), cache, cfg, None);
    ServeSetup {
        cell,
        router: QueryRouter::new(),
    }
}

/// Cold-start [`prepare`]: adopt a plan cache (and optionally its
/// persisted router index — see [`crate::batching::cache_io`])
/// reloaded from disk instead of re-planning. The index, when given,
/// must already be validated against `cache`
/// ([`RouterIndex::from_packed`] does).
pub fn prepare_from_cache(
    ds: Dataset,
    cache: CowCache,
    index: Option<RouterIndex>,
    cfg: &ServeConfig,
) -> Result<ServeSetup> {
    anyhow::ensure!(!cache.is_empty(), "empty plan cache");
    if let Some(ix) = &index {
        anyhow::ensure!(
            ix.len() == ds.graph.num_nodes(),
            "router index covers {} nodes, dataset has {}",
            ix.len(),
            ds.graph.num_nodes()
        );
    }
    let (cell, _, _) = build_initial_state(Arc::new(ds), cache, cfg, index);
    Ok(ServeSetup {
        cell,
        router: QueryRouter::new(),
    })
}

/// Aggregate outcome of one closed-loop serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub queries: usize,
    pub wall_s: f64,
    pub qps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    pub max_ms: f64,
    /// Materialize+execute runs performed.
    pub executions: u64,
    /// Queries answered by executions.
    pub executed_queries: u64,
    /// Queries per execution (> 1 = coalescing won).
    pub coalescing_factor: f64,
    /// Queries answered from the results memo.
    pub cache_hits: u64,
    pub cache_hit_rate: f64,
    /// Queries answered via the cold (synthesized-plan) path — memo
    /// hits for previously executed cold plans are not counted.
    pub cold_routes: u64,
    /// Cold-plan ids assigned during this run (≈ distinct new cold
    /// nodes; shard-side FIFO eviction may resynthesize an id's plan).
    pub cold_plans: usize,
    pub accuracy: f64,
    pub shard_queries: Vec<u64>,
    pub shard_balance: f64,
    /// Precomputed plans available to the router (final snapshot).
    pub plans: usize,
    /// Shard-side seconds in the forward pass (summed over shards).
    pub exec_s: f64,
    /// Shard-side seconds stalled waiting on materialization.
    pub mat_wait_s: f64,
    /// Dense-buffer bytes pooled across all shard arenas.
    pub arena_bytes: usize,
    /// Fresh buffer allocations across all shard arenas (steady state:
    /// ring depth × shards).
    pub arena_allocations: usize,
    /// Bytes resident in the results memo at shutdown.
    pub results_cache_bytes: usize,
    /// Snapshot swaps the control loop observed mid-run (0 for a
    /// static deployment).
    pub snapshot_swaps: u64,
    /// Graph epoch of the last snapshot the loop served from.
    pub final_epoch: u64,
    /// Memo entries reclaimed eagerly by swap-time stale sweeps.
    pub memo_swept: u64,
}

/// A delta source attached to a serving run — the quiesced-vs-zero-
/// quiesce axis of `benches/updates.rs`.
pub enum Churn<'a> {
    /// Apply each delta **on the control thread** once `completed`
    /// reaches its trigger: the quiesced baseline. Admission and
    /// deadline flushes stall for the whole rebuild, which is exactly
    /// the latency cliff the snapshot refactor removes.
    Inline {
        applier: &'a mut UpdateApplier,
        deltas: Vec<(u64, GraphDelta)>,
    },
    /// Feed each delta to a **background applier thread** at the same
    /// completed-count triggers: zero-quiesce. The loop keeps serving;
    /// the new snapshot lands via the cell swap. The run drains all
    /// fed deltas before returning, so reports are complete.
    Background {
        applier: &'a mut UpdateApplier,
        deltas: Vec<(u64, GraphDelta)>,
    },
    /// Zero-quiesce with an external delta source (a file tailer):
    /// deltas arrive on `rx` on their own clock; whatever lands before
    /// the run finishes is applied.
    Stream {
        applier: &'a mut UpdateApplier,
        rx: mpsc::Receiver<GraphDelta>,
    },
}

fn dispatch_group(
    g: PendingGroup<Arc<ServeState>>,
    shards: usize,
    txs: &[mpsc::Sender<WorkItem>],
    metrics: &mut ServeMetrics,
) -> Result<()> {
    let work = match g.key {
        PlanKey::Cached(pid) => Work::Cached(pid),
        // all riders of a cold group query the same node
        PlanKey::Cold(_) => Work::Cold(g.queries[0].node),
    };
    let shard = match work {
        Work::Cached(pid) => g.snap.placement.shard_of_plan(pid, shards),
        Work::Cold(node) => g.snap.placement.shard_of_node(node, shards),
    };
    metrics.record_dispatch(shard, g.queries.len() as u64);
    txs[shard]
        .send(WorkItem {
            key: g.key,
            epoch: g.epoch,
            state: g.snap,
            work,
            queries: g.queries,
        })
        .map_err(|_| anyhow::anyhow!("shard {shard} hung up"))?;
    Ok(())
}

/// Serve `cfg.queries` queries drawn from `population` with `skew`,
/// closed-loop, with a per-run results memo sized by
/// `cfg.results_cache_bytes`. `setup` is borrowed mutably for the
/// router's cold-id memo, which stays warm across repeated runs.
pub fn serve_closed_loop(
    setup: &mut ServeSetup,
    population: &[u32],
    skew: Skew,
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    let mut results = ResultsCache::new(cfg.results_cache_bytes, cfg.results_ttl);
    serve_closed_loop_with(setup, population, skew, cfg, &mut results)
}

/// [`serve_closed_loop`] against a caller-owned results memo — the
/// dynamic-update session keeps one memo alive across serving
/// segments so post-delta epoch eviction is actually observable.
/// Memo lookups and inserts are keyed by the plan's epoch in the
/// snapshot the query was admitted under (cold plans key on the
/// snapshot epoch itself). Blocks until every query completes and all
/// shards have shut down; returns the aggregate report.
pub fn serve_closed_loop_with(
    setup: &mut ServeSetup,
    population: &[u32],
    skew: Skew,
    cfg: &ServeConfig,
    results: &mut ResultsCache,
) -> Result<ServeReport> {
    serve_with_churn(setup, population, skew, cfg, results, None)
        .map(|(report, _)| report)
}

/// The full loop: [`serve_closed_loop_with`] plus an optional [`Churn`]
/// source. Returns the serve report and the [`UpdateReport`]s of every
/// delta applied during the run (empty without churn).
pub fn serve_with_churn(
    setup: &mut ServeSetup,
    population: &[u32],
    skew: Skew,
    cfg: &ServeConfig,
    results: &mut ResultsCache,
    churn: Option<Churn<'_>>,
) -> Result<(ServeReport, Vec<UpdateReport>)> {
    let state0 = setup.cell.load();
    let router = &mut setup.router;
    // ServeSetup persists across runs; report this run's delta
    let cold_ids_at_start = router.cold_built;
    anyhow::ensure!(!population.is_empty(), "empty query population");
    anyhow::ensure!(cfg.queries > 0, "need at least one query");
    anyhow::ensure!(
        state0.meta.feat == state0.ds.feat_dim,
        "artifact feat {} != dataset feat {}",
        state0.meta.feat,
        state0.ds.feat_dim
    );
    let shards = cfg.shards.max(1);
    let total = cfg.queries as u64;
    let clients = cfg.clients.max(1).min(cfg.queries) as u64;
    let classes = state0.meta.classes;

    let mut queue: MicrobatchQueue<Arc<ServeState>> =
        MicrobatchQueue::new(cfg.flush_window, cfg.max_coalesce);
    let mut metrics = ServeMetrics::new(shards);
    let mut load = LoadGen::new(population, skew, cfg.seed ^ 0x10AD);
    let cell = setup.cell.clone();

    // churn plumbing: triggers fire as `completed` crosses them
    let stop = Arc::new(AtomicBool::new(false));
    let (urep_tx, urep_rx) = mpsc::channel::<UpdateReport>();
    enum ChurnRt<'a> {
        None,
        Inline {
            applier: &'a mut UpdateApplier,
            pending: VecDeque<(u64, GraphDelta)>,
        },
        Background {
            tx: mpsc::Sender<GraphDelta>,
            pending: VecDeque<(u64, GraphDelta)>,
        },
        Stream,
    }

    std::thread::scope(|scope| -> Result<(ServeReport, Vec<UpdateReport>)> {
        let mut applier_handle = None;
        let mut churn_rt = match churn {
            None => ChurnRt::None,
            Some(Churn::Inline { applier, deltas }) => {
                let mut pending: VecDeque<_> = deltas.into_iter().collect();
                pending
                    .make_contiguous()
                    .sort_by_key(|(trigger, _)| *trigger);
                ChurnRt::Inline { applier, pending }
            }
            Some(Churn::Background { applier, deltas }) => {
                let mut pending: VecDeque<_> = deltas.into_iter().collect();
                pending
                    .make_contiguous()
                    .sort_by_key(|(trigger, _)| *trigger);
                let (tx, rx) = mpsc::channel::<GraphDelta>();
                let stop = stop.clone();
                let reports = urep_tx.clone();
                applier_handle = Some(
                    scope.spawn(move || run_applier(applier, rx, &stop, reports)),
                );
                ChurnRt::Background { tx, pending }
            }
            Some(Churn::Stream { applier, rx }) => {
                let stop = stop.clone();
                let reports = urep_tx.clone();
                applier_handle = Some(
                    scope.spawn(move || run_applier(applier, rx, &stop, reports)),
                );
                ChurnRt::Stream
            }
        };
        drop(urep_tx);

        let (res_tx, res_rx) = mpsc::channel::<ShardMsg>();
        let mut txs: Vec<mpsc::Sender<WorkItem>> = Vec::with_capacity(shards);
        for shard_id in 0..shards {
            let (tx, rx) = mpsc::channel::<WorkItem>();
            let ctx = ShardCtx {
                shard_id,
                feat_dim: state0.ds.feat_dim,
                bucket: state0.meta.n_pad,
                ring_depth: cfg.ring_depth,
                cold_aux: cfg.cold_aux,
            };
            let out = res_tx.clone();
            scope.spawn(move || shard_worker(ctx, rx, out));
            txs.push(tx);
        }
        drop(res_tx);

        let mut arrivals: HashMap<u64, Instant> = HashMap::new();
        let mut inline_reports: Vec<UpdateReport> = Vec::new();
        let mut issued = 0u64;
        let mut completed = 0u64;
        let mut seen_epoch = state0.epoch;
        let mut snapshot_swaps = 0u64;
        let mut memo_swept = 0u64;
        drop(state0);
        let t0 = Instant::now();
        let wall_s = loop {
            // churn triggers keyed on progress
            match &mut churn_rt {
                ChurnRt::Inline { applier, pending } => {
                    while pending
                        .front()
                        .map(|(at, _)| *at <= completed)
                        .unwrap_or(false)
                    {
                        let (_, delta) = pending.pop_front().unwrap();
                        // quiesced baseline: the rebuild runs here, on
                        // the control thread — every pending deadline
                        // and admission waits it out
                        inline_reports.push(applier.apply(&delta)?);
                    }
                }
                ChurnRt::Background { tx, pending } => {
                    while pending
                        .front()
                        .map(|(at, _)| *at <= completed)
                        .unwrap_or(false)
                    {
                        let (_, delta) = pending.pop_front().unwrap();
                        let _ = tx.send(delta);
                    }
                }
                _ => {}
            }

            // one consistent snapshot per admission round
            let state = cell.load();
            if state.epoch != seen_epoch {
                anyhow::ensure!(
                    state.epoch > seen_epoch,
                    "snapshot epoch regressed: {} -> {}",
                    seen_epoch,
                    state.epoch
                );
                snapshot_swaps += 1;
                seen_epoch = state.epoch;
                // eager sweep: reclaim epoch-expired memo bytes now
                // instead of entry-by-entry on future reads
                let sweep_state = state.clone();
                memo_swept += results
                    .purge_stale(move |k| sweep_state.plan_epoch(k))
                    as u64;
            }

            // closed-loop admission: top up to `clients` in flight;
            // memo hits complete synchronously and free their client
            // slot immediately.
            while issued < total && issued - completed < clients {
                let node = load.next_node();
                let id = issued;
                issued += 1;
                let now = Instant::now();
                let route = router.route(&state.index, node);
                let key = route.key();
                let pos = route.pos();
                let epoch = state.plan_epoch(&key);
                if let Some(logits) = results.get(key, epoch, now) {
                    let start = pos as usize * classes;
                    let pred = argmax(&logits[start..start + classes]);
                    metrics.cache_hit_queries += 1;
                    metrics.record_completion(
                        0.0,
                        pred == state.ds.labels[node as usize] as usize,
                    );
                    completed += 1;
                    continue;
                }
                // counted after the memo probe: memo-served repeats
                // never reach the synthesized-plan path
                if matches!(route, Route::Cold { .. }) {
                    metrics.cold_routes += 1;
                }
                arrivals.insert(id, now);
                if let Some(group) = queue.push(
                    key,
                    epoch,
                    &state,
                    QueryTicket { id, node, pos },
                    now,
                ) {
                    dispatch_group(group, shards, &txs, &mut metrics)?;
                }
            }
            if completed >= total {
                break t0.elapsed().as_secs_f64();
            }
            // deadline flushes
            let now = Instant::now();
            for group in queue.due(now) {
                dispatch_group(group, shards, &txs, &mut metrics)?;
            }
            // sleep until the next deadline or the next completion
            let timeout = queue
                .next_deadline()
                .map(|d| d.saturating_duration_since(Instant::now()))
                .unwrap_or(Duration::from_millis(10))
                .min(Duration::from_millis(10));
            match res_rx.recv_timeout(timeout) {
                Ok(ShardMsg::Result(r)) => {
                    let now = Instant::now();
                    for o in &r.outcomes {
                        let lat = arrivals
                            .remove(&o.id)
                            .map(|a| now.duration_since(a).as_secs_f64())
                            .unwrap_or(0.0);
                        metrics.record_completion(lat, o.correct);
                        completed += 1;
                    }
                    metrics.exec_s += r.exec_s;
                    results.insert(r.key, r.epoch, r.out_logits, now);
                }
                Ok(ShardMsg::Done(_)) => {
                    anyhow::bail!("shard exited early");
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    anyhow::bail!("all shards disconnected");
                }
            }
        };

        // flush triggers the final completions skipped past (a memo
        // burst can advance `completed` across a trigger and straight
        // to `total` within one admission round) — every trigger-fed
        // delta is applied exactly once, deterministically
        match &mut churn_rt {
            ChurnRt::Inline { applier, pending } => {
                while let Some((_, delta)) = pending.pop_front() {
                    inline_reports.push(applier.apply(&delta)?);
                }
            }
            ChurnRt::Background { tx, pending } => {
                while let Some((_, delta)) = pending.pop_front() {
                    let _ = tx.send(delta);
                }
            }
            _ => {}
        }

        // retire the applier: Background closes its channel (the
        // applier drains every fed delta, then sees Disconnected);
        // Stream raises the stop flag (best effort — the tailer owns
        // the sender). Joining BEFORE draining the report channel
        // makes the collected reports complete: nothing can arrive
        // after the join, so no 30s-per-missing-report timeouts and no
        // silently dropped late reports.
        let mut update_reports = inline_reports;
        match churn_rt {
            ChurnRt::Background { tx, .. } => drop(tx),
            _ => stop.store(true, Ordering::Release),
        }
        if let Some(handle) = applier_handle {
            handle
                .join()
                .map_err(|_| anyhow::anyhow!("update applier panicked"))?;
            while let Ok(r) = urep_rx.try_recv() {
                update_reports.push(r);
            }
        }
        stop.store(true, Ordering::Release);

        // shut shards down and collect their final accounting
        drop(txs);
        let mut mat_wait_s = 0.0;
        let mut arena_bytes = 0usize;
        let mut arena_allocations = 0usize;
        for msg in res_rx.iter() {
            if let ShardMsg::Done(d) = msg {
                mat_wait_s += d.wait_s;
                arena_bytes += d.arena_bytes;
                arena_allocations += d.arena_allocations;
            }
        }

        let final_state = cell.load();
        let lat = &metrics.latency;
        let report = ServeReport {
            queries: cfg.queries,
            wall_s,
            qps: total as f64 / wall_s.max(1e-9),
            p50_ms: lat.quantile(0.50) * 1e3,
            p95_ms: lat.quantile(0.95) * 1e3,
            p99_ms: lat.quantile(0.99) * 1e3,
            mean_ms: lat.mean() * 1e3,
            max_ms: lat.max() * 1e3,
            executions: metrics.executions,
            executed_queries: metrics.executed_queries,
            coalescing_factor: metrics.coalescing_factor(),
            cache_hits: metrics.cache_hit_queries,
            cache_hit_rate: metrics.hit_rate(),
            cold_routes: metrics.cold_routes,
            cold_plans: router.cold_built - cold_ids_at_start,
            accuracy: metrics.accuracy(),
            shard_queries: metrics.shard_queries.clone(),
            shard_balance: metrics.shard_balance(),
            plans: final_state.cache.len(),
            exec_s: metrics.exec_s,
            mat_wait_s,
            arena_bytes,
            arena_allocations,
            results_cache_bytes: results.bytes(),
            snapshot_swaps,
            final_epoch: final_state.epoch,
            memo_swept,
        };
        Ok((report, update_reports))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{sbm, DatasetSpec};

    fn tiny() -> Dataset {
        sbm::generate(&DatasetSpec::tiny_for_tests(), 33)
    }

    #[test]
    fn serves_every_query_exactly_once() {
        let ds = tiny();
        let cfg = ServeConfig {
            queries: 64,
            clients: 8,
            shards: 2,
            flush_window: Duration::from_micros(300),
            ..Default::default()
        };
        let eval = ds.splits.train.clone();
        let mut setup = prepare(ds, &eval, &cfg);
        assert!(!setup.state().cache.is_empty());
        let report =
            serve_closed_loop(&mut setup, &eval, Skew::Zipf(1.2), &cfg)
                .unwrap();
        assert_eq!(report.queries, 64);
        assert_eq!(
            report.executed_queries + report.cache_hits,
            64,
            "every query answered by execution or memo"
        );
        assert!(report.executions <= report.executed_queries);
        assert!(report.qps > 0.0);
        assert!(report.wall_s > 0.0);
        assert!((0.0..=1.0).contains(&report.accuracy));
        assert_eq!(
            report.shard_queries.iter().sum::<u64>(),
            report.executed_queries
        );
        // closed loop with no warm memo must execute at least once
        assert!(report.executions >= 1);
        // static deployment: epoch 0 throughout, no swaps observed
        assert_eq!(report.snapshot_swaps, 0);
        assert_eq!(report.final_epoch, 0);
    }

    #[test]
    fn memo_absorbs_repeat_queries() {
        let ds = tiny();
        let cfg = ServeConfig {
            queries: 40,
            clients: 1, // strictly sequential: every repeat is a hit
            shards: 1,
            results_cache_bytes: 1 << 20,
            flush_window: Duration::from_micros(100),
            ..Default::default()
        };
        let eval = ds.splits.train.clone();
        let node = [eval[0]];
        let mut setup = prepare(ds, &eval, &cfg);
        let report =
            serve_closed_loop(&mut setup, &node, Skew::Uniform, &cfg).unwrap();
        assert_eq!(report.executions, 1, "one execution, then memo hits");
        assert_eq!(report.cache_hits, 39);
        assert!(report.cache_hit_rate > 0.9);
    }

    #[test]
    fn cold_start_from_cache_matches_planned_prepare() {
        let ds = tiny();
        let cfg = ServeConfig::default();
        let eval = ds.splits.train.clone();
        let planned = prepare(ds.clone(), &eval, &cfg);
        let planned_state = planned.state();
        // rebuild the deployment from the "persisted" cache + index
        let cache = CowCache::from_cache(
            &planned_state.cache.to_batch_cache(),
        );
        let index = RouterIndex::from_packed(
            planned_state.index.to_packed(),
            &cache,
        )
        .unwrap();
        let cold =
            prepare_from_cache(ds, cache, Some(index), &cfg).unwrap();
        let cold_state = cold.state();
        assert_eq!(cold_state.cache.len(), planned_state.cache.len());
        assert_eq!(
            cold_state.index.coverage(),
            planned_state.index.coverage()
        );
        for u in 0..cold_state.ds.graph.num_nodes() as u32 {
            assert_eq!(
                cold_state.index.lookup(u),
                planned_state.index.lookup(u),
                "node {u}"
            );
        }
        assert_eq!(cold_state.meta.n_pad, planned_state.meta.n_pad);
    }
}
