//! Byte-bounded LRU memo of executed plan outputs.
//!
//! A plan's logits are valid until the model, the features, *or the
//! plan itself* changes, so a popular plan need not re-execute at all
//! within a freshness window — the layer *above* coalescing: the queue
//! folds concurrent queries into one execution, the memo folds repeat
//! queries into zero. The budget is in bytes (not entries) because
//! plan output rows vary in size; an optional TTL models periodically
//! refreshed models, after which an entry counts as a miss and is
//! dropped.
//!
//! Freshness is enforced **on the read path**: every entry is stamped
//! with the plan epoch it was computed at, and [`ResultsCache::get`]
//! takes the plan's *current* epoch — a mismatch expires the entry on
//! the spot, so after a graph delta bumps a plan's epoch
//! (DESIGN.md §10) a read can never return logits computed from the
//! pre-delta plan, even before any proactive invalidation sweep runs.
//! TTL is likewise checked on read. The eager companions —
//! [`ResultsCache::invalidate_where`] (predicate over key *and* stored
//! epoch), [`ResultsCache::purge_stale`] (drop everything not at its
//! plan's current epoch), and [`ResultsCache::purge_expired`] (TTL
//! sweep) — reclaim the accounted bytes immediately, so capacity is
//! not held hostage by epoch-expired entries that nobody re-reads
//! (the serving loop runs `purge_stale` once per observed snapshot
//! swap, DESIGN.md §11).
//!
//! LRU is the standard lazy scheme: a monotone tick stamps each
//! access, a FIFO of `(key, tick)` pairs is popped on eviction and
//! entries whose stamp is stale are skipped — O(1) amortized, no
//! linked lists.

use std::collections::{HashMap, VecDeque};
use std::time::{Duration, Instant};

use super::router::PlanKey;

struct Entry {
    logits: Vec<f32>,
    stamp: u64,
    inserted: Instant,
    /// Plan epoch the logits were computed at.
    epoch: u64,
}

/// Per-entry bookkeeping overhead charged against the byte budget
/// (map + LRU queue slots), so the budget reflects real memory.
const ENTRY_OVERHEAD: usize = 64;

/// LRU memo: plan key → output-node logits of the last execution.
pub struct ResultsCache {
    budget: usize,
    ttl: Option<Duration>,
    map: HashMap<PlanKey, Entry>,
    lru: VecDeque<(PlanKey, u64)>,
    bytes: usize,
    tick: u64,
    /// Reads answered from the memo.
    pub hits: u64,
    /// Reads that missed (absent, stale, or expired entry).
    pub misses: u64,
    /// Entries dropped by the byte-budget LRU.
    pub evictions: u64,
    /// Entries dropped by TTL expiry.
    pub expirations: u64,
    /// Entries dropped because their plan epoch went stale (graph
    /// delta invalidation), on read or in an eager sweep.
    pub epoch_evictions: u64,
}

impl ResultsCache {
    /// `budget_bytes` = 0 disables the cache entirely (every lookup is
    /// a miss, inserts are dropped); `ttl` = None means entries stay
    /// fresh until evicted.
    pub fn new(budget_bytes: usize, ttl: Option<Duration>) -> ResultsCache {
        ResultsCache {
            budget: budget_bytes,
            ttl,
            map: HashMap::new(),
            lru: VecDeque::new(),
            bytes: 0,
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
            expirations: 0,
            epoch_evictions: 0,
        }
    }

    /// Charged against the budget by *capacity*, not length — a Vec
    /// truncated from a larger buffer still owns its full allocation.
    fn entry_bytes(capacity: usize) -> usize {
        capacity * 4 + ENTRY_OVERHEAD
    }

    /// Look up a plan's memoized logits at the plan's *current* epoch;
    /// counts a hit or miss and refreshes LRU order on hit. Entries
    /// whose stored epoch differs from `epoch` (the plan changed under
    /// a graph delta) or whose TTL lapsed are expired here, on the
    /// read path — staleness never survives a lookup.
    pub fn get(
        &mut self,
        key: PlanKey,
        epoch: u64,
        now: Instant,
    ) -> Option<&[f32]> {
        if self.budget == 0 {
            self.misses += 1;
            return None;
        }
        let (ttl_expired, epoch_stale) = match self.map.get(&key) {
            None => {
                self.misses += 1;
                return None;
            }
            Some(e) => (
                match self.ttl {
                    Some(t) => now.duration_since(e.inserted) >= t,
                    None => false,
                },
                e.epoch != epoch,
            ),
        };
        if ttl_expired || epoch_stale {
            if let Some(e) = self.map.remove(&key) {
                self.bytes -= Self::entry_bytes(e.logits.capacity());
            }
            if epoch_stale {
                self.epoch_evictions += 1;
            } else {
                self.expirations += 1;
            }
            self.misses += 1;
            return None;
        }
        self.tick += 1;
        let tick = self.tick;
        if let Some(e) = self.map.get_mut(&key) {
            e.stamp = tick;
        }
        self.lru.push_back((key, tick));
        // Hit traffic appends a stale record per access; eviction only
        // drains them under byte pressure, so compact once the queue
        // outgrows the live set (keeps steady-state memory O(entries)).
        if self.lru.len() > 2 * self.map.len() + 16 {
            let map = &self.map;
            self.lru.retain(|(k, s)| {
                map.get(k).map(|e| e.stamp == *s).unwrap_or(false)
            });
        }
        self.hits += 1;
        self.map.get(&key).map(|e| e.logits.as_slice())
    }

    /// Insert (or replace) a plan's logits computed at plan epoch
    /// `epoch`, evicting least-recently used entries until the byte
    /// budget holds. Entries larger than the whole budget are dropped
    /// on the floor. An insert at an *older* epoch than the stored
    /// entry's is dropped instead: a group pinned to a pre-swap
    /// snapshot can finish after a post-swap group for the same plan
    /// already memoized fresh logits, and clobbering those would force
    /// a redundant re-execution on the next read (epochs are monotone
    /// per key, so newer always wins).
    pub fn insert(
        &mut self,
        key: PlanKey,
        epoch: u64,
        mut logits: Vec<f32>,
        now: Instant,
    ) {
        if self.budget == 0 {
            return;
        }
        if let Some(e) = self.map.get(&key) {
            if e.epoch > epoch {
                return;
            }
        }
        // executors hand over Vecs truncated from larger buffers;
        // release the excess capacity the byte accounting would charge
        logits.shrink_to_fit();
        let nb = Self::entry_bytes(logits.capacity());
        if nb > self.budget {
            return;
        }
        if let Some(old) = self.map.remove(&key) {
            self.bytes -= Self::entry_bytes(old.logits.capacity());
        }
        self.tick += 1;
        let tick = self.tick;
        self.lru.push_back((key, tick));
        self.map.insert(
            key,
            Entry {
                logits,
                stamp: tick,
                inserted: now,
                epoch,
            },
        );
        self.bytes += nb;
        while self.bytes > self.budget {
            let (k, stamp) = match self.lru.pop_front() {
                Some(p) => p,
                None => break,
            };
            let live = self.map.get(&k).map(|e| e.stamp == stamp).unwrap_or(false);
            if !live {
                continue; // stale LRU record for a re-accessed entry
            }
            if let Some(e) = self.map.remove(&k) {
                self.bytes -= Self::entry_bytes(e.logits.capacity());
                self.evictions += 1;
            }
        }
    }

    /// Drop everything (model update invalidation).
    pub fn clear(&mut self) {
        self.map.clear();
        self.lru.clear();
        self.bytes = 0;
    }

    /// Remove `keys` outright: debit the byte accounting and compact
    /// the LRU queue down to live records. Shared by the eager
    /// invalidation sweeps; the matching counter is bumped by the
    /// caller.
    fn remove_keys(&mut self, keys: &[PlanKey]) -> usize {
        for k in keys {
            if let Some(e) = self.map.remove(k) {
                self.bytes -= Self::entry_bytes(e.logits.capacity());
            }
        }
        if !keys.is_empty() {
            let map = &self.map;
            self.lru.retain(|(k, s)| {
                map.get(k).map(|e| e.stamp == *s).unwrap_or(false)
            });
        }
        keys.len()
    }

    /// Eagerly drop every entry matching `stale(key, stored_epoch)`
    /// (graph-delta invalidation). The predicate sees the epoch the
    /// logits were computed at, so epoch-expired entries are
    /// reclaimable — bytes and all — without waiting for a read to
    /// stumble over them. Returns the number of entries dropped.
    pub fn invalidate_where(
        &mut self,
        stale: impl Fn(&PlanKey, u64) -> bool,
    ) -> usize {
        let keys: Vec<PlanKey> = self
            .map
            .iter()
            .filter(|(k, e)| stale(k, e.epoch))
            .map(|(&k, _)| k)
            .collect();
        let dropped = self.remove_keys(&keys);
        self.epoch_evictions += dropped as u64;
        dropped
    }

    /// Eagerly drop every entry whose stored epoch is not its plan's
    /// *current* epoch (`current_epoch_of`). This is the
    /// snapshot-swap sweep: the read path would expire these entries
    /// one by one, but their bytes would stay charged against the
    /// budget until each key happened to be re-queried — evicting
    /// still-fresh neighbors in the meantime. Returns the number
    /// dropped.
    pub fn purge_stale(
        &mut self,
        current_epoch_of: impl Fn(&PlanKey) -> u64,
    ) -> usize {
        self.invalidate_where(|k, e| e != current_epoch_of(k))
    }

    /// Eagerly drop every TTL-expired entry (read-path expiry only
    /// catches keys that get queried again). Returns the number
    /// dropped.
    pub fn purge_expired(&mut self, now: Instant) -> usize {
        let ttl = match self.ttl {
            Some(t) => t,
            None => return 0,
        };
        let keys: Vec<PlanKey> = self
            .map
            .iter()
            .filter(|(_, e)| now.duration_since(e.inserted) >= ttl)
            .map(|(&k, _)| k)
            .collect();
        let dropped = self.remove_keys(&keys);
        self.expirations += dropped as u64;
        dropped
    }

    /// Resident logit bytes (the LRU budget applies to this).
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Resident entries.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when no entries are resident.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Hits over all reads so far (0.0 before the first read).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    #[cfg(test)]
    fn lru_records(&self) -> usize {
        self.lru.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(i: u32) -> PlanKey {
        PlanKey::Cached(i)
    }

    #[test]
    fn hit_after_insert_miss_before() {
        let t0 = Instant::now();
        let mut c = ResultsCache::new(1 << 20, None);
        assert!(c.get(key(1), 0, t0).is_none());
        c.insert(key(1), 0, vec![1.0, 2.0], t0);
        assert_eq!(c.get(key(1), 0, t0).unwrap(), &[1.0, 2.0]);
        assert_eq!((c.hits, c.misses), (1, 1));
        assert!((c.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn evicts_least_recently_used_under_byte_pressure() {
        let t0 = Instant::now();
        // room for exactly two 8-float entries
        let per = 8 * 4 + ENTRY_OVERHEAD;
        let mut c = ResultsCache::new(2 * per, None);
        c.insert(key(1), 0, vec![0.0; 8], t0);
        c.insert(key(2), 0, vec![0.0; 8], t0);
        // touch 1 so 2 becomes LRU
        assert!(c.get(key(1), 0, t0).is_some());
        c.insert(key(3), 0, vec![0.0; 8], t0);
        assert_eq!(c.len(), 2);
        assert!(c.get(key(2), 0, t0).is_none(), "LRU entry must be evicted");
        assert!(c.get(key(1), 0, t0).is_some());
        assert!(c.get(key(3), 0, t0).is_some());
        assert_eq!(c.evictions, 1);
        assert!(c.bytes() <= 2 * per);
    }

    #[test]
    fn oversized_entry_is_dropped() {
        let t0 = Instant::now();
        let mut c = ResultsCache::new(32, None);
        c.insert(key(1), 0, vec![0.0; 1000], t0);
        assert!(c.is_empty());
        assert!(c.get(key(1), 0, t0).is_none());
    }

    #[test]
    fn ttl_expires_entries() {
        let t0 = Instant::now();
        let ttl = Duration::from_millis(50);
        let mut c = ResultsCache::new(1 << 20, Some(ttl));
        c.insert(key(1), 0, vec![1.0], t0);
        assert!(c.get(key(1), 0, t0 + Duration::from_millis(49)).is_some());
        assert!(c.get(key(1), 0, t0 + Duration::from_millis(50)).is_none());
        assert_eq!(c.expirations, 1);
        assert!(c.is_empty());
    }

    #[test]
    fn stale_epoch_insert_never_clobbers_a_fresher_entry() {
        let t0 = Instant::now();
        let mut c = ResultsCache::new(1 << 20, None);
        // a post-swap execution memoized epoch-1 logits...
        c.insert(key(1), 1, vec![2.0], t0);
        let bytes = c.bytes();
        // ...then a pre-swap group for the same plan finally finishes
        c.insert(key(1), 0, vec![1.0], t0);
        assert_eq!(
            c.get(key(1), 1, t0).unwrap(),
            &[2.0],
            "older-epoch insert must not clobber the fresher entry"
        );
        assert_eq!(c.bytes(), bytes, "dropped insert must not be charged");
        // newer epochs still replace
        c.insert(key(1), 2, vec![3.0], t0);
        assert_eq!(c.get(key(1), 2, t0).unwrap(), &[3.0]);
    }

    #[test]
    fn epoch_mismatch_expires_on_read() {
        let t0 = Instant::now();
        let mut c = ResultsCache::new(1 << 20, None);
        c.insert(key(1), 0, vec![1.0], t0);
        // the plan's epoch moved (graph delta): the pre-delta entry
        // must be unreadable and gone
        assert!(c.get(key(1), 1, t0).is_none());
        assert_eq!(c.epoch_evictions, 1);
        assert!(c.is_empty());
        assert_eq!(c.bytes(), 0);
        // re-inserted at the new epoch it serves again
        c.insert(key(1), 1, vec![2.0], t0);
        assert_eq!(c.get(key(1), 1, t0).unwrap(), &[2.0]);
    }

    #[test]
    fn invalidate_where_drops_matching_entries() {
        let t0 = Instant::now();
        let mut c = ResultsCache::new(1 << 20, None);
        c.insert(key(1), 0, vec![1.0], t0);
        c.insert(key(2), 0, vec![2.0], t0);
        c.insert(PlanKey::Cold(7), 0, vec![3.0], t0);
        let dropped = c.invalidate_where(|k, _| {
            matches!(k, PlanKey::Cold(_)) || *k == key(2)
        });
        assert_eq!(dropped, 2);
        assert_eq!(c.len(), 1);
        assert!(c.get(key(1), 0, t0).is_some());
        assert!(c.get(PlanKey::Cold(7), 0, t0).is_none());
    }

    #[test]
    fn purge_stale_reclaims_epoch_expired_bytes_eagerly() {
        let t0 = Instant::now();
        let mut c = ResultsCache::new(1 << 20, None);
        c.insert(key(1), 0, vec![0.0; 64], t0);
        c.insert(key(2), 3, vec![0.0; 64], t0);
        c.insert(PlanKey::Cold(9), 1, vec![0.0; 64], t0);
        let full = c.bytes();
        assert!(full > 0);
        // plan 1 moved to epoch 2, plan 2 is current at 3, snapshot
        // epoch for cold keys is now 2 — without any reads, the sweep
        // must reclaim the two stale entries' bytes immediately
        let dropped = c.purge_stale(|k| match k {
            PlanKey::Cached(1) => 2,
            PlanKey::Cached(_) => 3,
            PlanKey::Cold(_) => 2,
        });
        assert_eq!(dropped, 2);
        assert_eq!(c.len(), 1);
        assert_eq!(c.epoch_evictions, 2);
        assert!(
            c.bytes() < full / 2,
            "stale bytes still accounted: {} of {full}",
            c.bytes()
        );
        assert!(c.get(key(2), 3, t0).is_some(), "fresh entry survives");
        // idempotent: nothing left to reclaim
        assert_eq!(
            c.purge_stale(|k| match k {
                PlanKey::Cached(_) => 3,
                PlanKey::Cold(_) => 2,
            }),
            0
        );
    }

    #[test]
    fn purge_expired_sweeps_without_reads() {
        let t0 = Instant::now();
        let ttl = Duration::from_millis(10);
        let mut c = ResultsCache::new(1 << 20, Some(ttl));
        c.insert(key(1), 0, vec![1.0], t0);
        c.insert(key(2), 0, vec![2.0], t0 + Duration::from_millis(8));
        assert_eq!(c.purge_expired(t0 + Duration::from_millis(12)), 1);
        assert_eq!(c.len(), 1);
        assert!(c.get(key(2), 0, t0 + Duration::from_millis(12)).is_some());
        // no TTL configured → no-op
        let mut n = ResultsCache::new(1 << 20, None);
        n.insert(key(1), 0, vec![1.0], t0);
        assert_eq!(n.purge_expired(t0 + Duration::from_secs(60)), 0);
    }

    #[test]
    fn zero_budget_disables() {
        let t0 = Instant::now();
        let mut c = ResultsCache::new(0, None);
        c.insert(key(1), 0, vec![1.0], t0);
        assert!(c.get(key(1), 0, t0).is_none());
        assert_eq!(c.bytes(), 0);
    }

    #[test]
    fn hit_traffic_keeps_lru_queue_bounded() {
        let t0 = Instant::now();
        let mut c = ResultsCache::new(1 << 20, None);
        c.insert(key(1), 0, vec![0.0; 4], t0);
        c.insert(key(2), 0, vec![0.0; 4], t0);
        for _ in 0..10_000 {
            assert!(c.get(key(1), 0, t0).is_some());
        }
        assert_eq!(c.hits, 10_000);
        assert!(
            c.lru_records() <= 2 * c.len() + 17,
            "queue grew to {} records for {} entries",
            c.lru_records(),
            c.len()
        );
        // LRU semantics survive compaction: key(2) is still evictable
        let per = 4 * 4 + ENTRY_OVERHEAD;
        let mut tight = ResultsCache::new(2 * per, None);
        tight.insert(key(1), 0, vec![0.0; 4], t0);
        tight.insert(key(2), 0, vec![0.0; 4], t0);
        for _ in 0..1000 {
            assert!(tight.get(key(1), 0, t0).is_some());
        }
        tight.insert(key(3), 0, vec![0.0; 4], t0);
        assert!(tight.get(key(2), 0, t0).is_none(), "key(2) was LRU");
        assert!(tight.get(key(1), 0, t0).is_some());
    }

    #[test]
    fn replace_accounts_bytes_once() {
        let t0 = Instant::now();
        let mut c = ResultsCache::new(1 << 20, None);
        c.insert(key(1), 0, vec![0.0; 8], t0);
        let b1 = c.bytes();
        c.insert(key(1), 0, vec![0.0; 8], t0);
        assert_eq!(c.bytes(), b1);
        c.clear();
        assert_eq!((c.bytes(), c.len()), (0, 0));
    }
}
