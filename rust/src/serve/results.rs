//! Byte-bounded LRU memo of executed plan outputs.
//!
//! A plan's logits are valid until the model or features change, so a
//! popular plan need not re-execute at all within a freshness window —
//! the layer *above* coalescing: the queue folds concurrent queries
//! into one execution, the memo folds repeat queries into zero. The
//! budget is in bytes (not entries) because plan output rows vary in
//! size; an optional TTL models periodically refreshed models, after
//! which an entry counts as a miss and is dropped.
//!
//! LRU is the standard lazy scheme: a monotone tick stamps each
//! access, a FIFO of `(key, tick)` pairs is popped on eviction and
//! entries whose stamp is stale are skipped — O(1) amortized, no
//! linked lists.

use std::collections::{HashMap, VecDeque};
use std::time::{Duration, Instant};

use super::router::PlanKey;

struct Entry {
    logits: Vec<f32>,
    stamp: u64,
    inserted: Instant,
}

/// Per-entry bookkeeping overhead charged against the byte budget
/// (map + LRU queue slots), so the budget reflects real memory.
const ENTRY_OVERHEAD: usize = 64;

/// LRU memo: plan key → output-node logits of the last execution.
pub struct ResultsCache {
    budget: usize,
    ttl: Option<Duration>,
    map: HashMap<PlanKey, Entry>,
    lru: VecDeque<(PlanKey, u64)>,
    bytes: usize,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub expirations: u64,
}

impl ResultsCache {
    /// `budget_bytes` = 0 disables the cache entirely (every lookup is
    /// a miss, inserts are dropped); `ttl` = None means entries stay
    /// fresh until evicted.
    pub fn new(budget_bytes: usize, ttl: Option<Duration>) -> ResultsCache {
        ResultsCache {
            budget: budget_bytes,
            ttl,
            map: HashMap::new(),
            lru: VecDeque::new(),
            bytes: 0,
            tick: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
            expirations: 0,
        }
    }

    /// Charged against the budget by *capacity*, not length — a Vec
    /// truncated from a larger buffer still owns its full allocation.
    fn entry_bytes(capacity: usize) -> usize {
        capacity * 4 + ENTRY_OVERHEAD
    }

    /// Look up a plan's memoized logits; counts a hit or miss and
    /// refreshes LRU order on hit.
    pub fn get(&mut self, key: PlanKey, now: Instant) -> Option<&[f32]> {
        if self.budget == 0 {
            self.misses += 1;
            return None;
        }
        let expired = match self.map.get(&key) {
            None => {
                self.misses += 1;
                return None;
            }
            Some(e) => match self.ttl {
                Some(t) => now.duration_since(e.inserted) >= t,
                None => false,
            },
        };
        if expired {
            if let Some(e) = self.map.remove(&key) {
                self.bytes -= Self::entry_bytes(e.logits.capacity());
            }
            self.expirations += 1;
            self.misses += 1;
            return None;
        }
        self.tick += 1;
        let tick = self.tick;
        if let Some(e) = self.map.get_mut(&key) {
            e.stamp = tick;
        }
        self.lru.push_back((key, tick));
        // Hit traffic appends a stale record per access; eviction only
        // drains them under byte pressure, so compact once the queue
        // outgrows the live set (keeps steady-state memory O(entries)).
        if self.lru.len() > 2 * self.map.len() + 16 {
            let map = &self.map;
            self.lru.retain(|(k, s)| {
                map.get(k).map(|e| e.stamp == *s).unwrap_or(false)
            });
        }
        self.hits += 1;
        self.map.get(&key).map(|e| e.logits.as_slice())
    }

    /// Insert (or replace) a plan's logits, evicting least-recently
    /// used entries until the byte budget holds. Entries larger than
    /// the whole budget are dropped on the floor.
    pub fn insert(&mut self, key: PlanKey, mut logits: Vec<f32>, now: Instant) {
        if self.budget == 0 {
            return;
        }
        // executors hand over Vecs truncated from larger buffers;
        // release the excess capacity the byte accounting would charge
        logits.shrink_to_fit();
        let nb = Self::entry_bytes(logits.capacity());
        if nb > self.budget {
            return;
        }
        if let Some(old) = self.map.remove(&key) {
            self.bytes -= Self::entry_bytes(old.logits.capacity());
        }
        self.tick += 1;
        let tick = self.tick;
        self.lru.push_back((key, tick));
        self.map.insert(
            key,
            Entry {
                logits,
                stamp: tick,
                inserted: now,
            },
        );
        self.bytes += nb;
        while self.bytes > self.budget {
            let (k, stamp) = match self.lru.pop_front() {
                Some(p) => p,
                None => break,
            };
            let live = self.map.get(&k).map(|e| e.stamp == stamp).unwrap_or(false);
            if !live {
                continue; // stale LRU record for a re-accessed entry
            }
            if let Some(e) = self.map.remove(&k) {
                self.bytes -= Self::entry_bytes(e.logits.capacity());
                self.evictions += 1;
            }
        }
    }

    /// Drop everything (model update invalidation).
    pub fn clear(&mut self) {
        self.map.clear();
        self.lru.clear();
        self.bytes = 0;
    }

    pub fn bytes(&self) -> usize {
        self.bytes
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    #[cfg(test)]
    fn lru_records(&self) -> usize {
        self.lru.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(i: u32) -> PlanKey {
        PlanKey::Cached(i)
    }

    #[test]
    fn hit_after_insert_miss_before() {
        let t0 = Instant::now();
        let mut c = ResultsCache::new(1 << 20, None);
        assert!(c.get(key(1), t0).is_none());
        c.insert(key(1), vec![1.0, 2.0], t0);
        assert_eq!(c.get(key(1), t0).unwrap(), &[1.0, 2.0]);
        assert_eq!((c.hits, c.misses), (1, 1));
        assert!((c.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn evicts_least_recently_used_under_byte_pressure() {
        let t0 = Instant::now();
        // room for exactly two 8-float entries
        let per = 8 * 4 + ENTRY_OVERHEAD;
        let mut c = ResultsCache::new(2 * per, None);
        c.insert(key(1), vec![0.0; 8], t0);
        c.insert(key(2), vec![0.0; 8], t0);
        // touch 1 so 2 becomes LRU
        assert!(c.get(key(1), t0).is_some());
        c.insert(key(3), vec![0.0; 8], t0);
        assert_eq!(c.len(), 2);
        assert!(c.get(key(2), t0).is_none(), "LRU entry must be evicted");
        assert!(c.get(key(1), t0).is_some());
        assert!(c.get(key(3), t0).is_some());
        assert_eq!(c.evictions, 1);
        assert!(c.bytes() <= 2 * per);
    }

    #[test]
    fn oversized_entry_is_dropped() {
        let t0 = Instant::now();
        let mut c = ResultsCache::new(32, None);
        c.insert(key(1), vec![0.0; 1000], t0);
        assert!(c.is_empty());
        assert!(c.get(key(1), t0).is_none());
    }

    #[test]
    fn ttl_expires_entries() {
        let t0 = Instant::now();
        let ttl = Duration::from_millis(50);
        let mut c = ResultsCache::new(1 << 20, Some(ttl));
        c.insert(key(1), vec![1.0], t0);
        assert!(c.get(key(1), t0 + Duration::from_millis(49)).is_some());
        assert!(c.get(key(1), t0 + Duration::from_millis(50)).is_none());
        assert_eq!(c.expirations, 1);
        assert!(c.is_empty());
    }

    #[test]
    fn zero_budget_disables() {
        let t0 = Instant::now();
        let mut c = ResultsCache::new(0, None);
        c.insert(key(1), vec![1.0], t0);
        assert!(c.get(key(1), t0).is_none());
        assert_eq!(c.bytes(), 0);
    }

    #[test]
    fn hit_traffic_keeps_lru_queue_bounded() {
        let t0 = Instant::now();
        let mut c = ResultsCache::new(1 << 20, None);
        c.insert(key(1), vec![0.0; 4], t0);
        c.insert(key(2), vec![0.0; 4], t0);
        for _ in 0..10_000 {
            assert!(c.get(key(1), t0).is_some());
        }
        assert_eq!(c.hits, 10_000);
        assert!(
            c.lru_records() <= 2 * c.len() + 17,
            "queue grew to {} records for {} entries",
            c.lru_records(),
            c.len()
        );
        // LRU semantics survive compaction: key(2) is still evictable
        let per = 4 * 4 + ENTRY_OVERHEAD;
        let mut tight = ResultsCache::new(2 * per, None);
        tight.insert(key(1), vec![0.0; 4], t0);
        tight.insert(key(2), vec![0.0; 4], t0);
        for _ in 0..1000 {
            assert!(tight.get(key(1), t0).is_some());
        }
        tight.insert(key(3), vec![0.0; 4], t0);
        assert!(tight.get(key(2), t0).is_none(), "key(2) was LRU");
        assert!(tight.get(key(1), t0).is_some());
    }

    #[test]
    fn replace_accounts_bytes_once() {
        let t0 = Instant::now();
        let mut c = ResultsCache::new(1 << 20, None);
        c.insert(key(1), vec![0.0; 8], t0);
        let b1 = c.bytes();
        c.insert(key(1), vec![0.0; 8], t0);
        assert_eq!(c.bytes(), b1);
        c.clear();
        assert_eq!((c.bytes(), c.len()), (0, 0));
    }
}
