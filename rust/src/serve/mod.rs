//! Online inference serving (DESIGN.md §9).
//!
//! The paper's headline inference result — up to 130× faster than
//! sampling baselines at equal accuracy — comes from batches being
//! *fixed and reusable* at query time: all the expensive influence
//! computation happens once, offline. This module turns that property
//! into an online, concurrent service that answers "what is node v's
//! prediction?" requests:
//!
//! * [`router`] — inverted index from output node → precomputed plan id
//!   (built from a [`crate::batching::BatchCache`]), with a cold path
//!   for nodes no precomputed batch covers: the router assigns a
//!   stable cold-plan id (so cold queries coalesce too) and the node's
//!   home shard synthesizes + memoizes a personal top-k-PPR plan off
//!   the control loop.
//! * [`queue`] — admission/microbatch queue that coalesces concurrent
//!   queries routed to the same plan into one materialize+execute
//!   (deadline- and size-based flush), so a popular plan runs once per
//!   window instead of once per query (cf. "Cooperative Minibatching
//!   in GNNs", arXiv 2310.12403).
//! * [`shard`] — N executor worker shards, each owning its own
//!   [`crate::batching::BatchArena`] and prefetch ring; plans are
//!   assigned to shards by the METIS graph partition so each shard's
//!   working set stays memory-local.
//! * [`results`] — byte-bounded LRU memo of recently executed plan
//!   logits with hit/miss accounting (and an optional freshness TTL
//!   for periodically refreshed models).
//! * [`metrics`] — log-bucketed per-query latency histogram
//!   (p50/p95/p99), throughput, coalescing factor, cache hit rate.
//! * [`load`] — closed-loop load generator with configurable arrival
//!   skew (uniform or zipf over the query population).
//! * [`service`] — the event loop tying all of the above together
//!   behind the `ibmb serve` subcommand and `benches/serving.rs`.
//! * [`update`] — dynamic graph updates between serving segments
//!   (DESIGN.md §10): graph deltas land on a mutable overlay,
//!   incremental PPR refresh repairs per-root influence, stale plans
//!   rebuild past an L1 tolerance, and the router / results memo
//!   invalidate by plan epoch (`ibmb serve --update-stream`,
//!   `ibmb update`, `benches/updates.rs`).
//!
//! Execution uses the exact CPU reference forward pass
//! ([`crate::inference::fullgraph::forward`]) over each plan's induced
//! subgraph, so the service runs end-to-end even in the offline build
//! where the PJRT backend is stubbed; the artifact metadata it is
//! driven by ([`shard::reference_artifact`]) matches the AOT layout, so
//! swapping the executor for `Runtime::infer_step` is a local change.

pub mod load;
pub mod metrics;
pub mod queue;
pub mod results;
pub mod router;
pub mod service;
pub mod shard;
pub mod update;

pub use load::{LoadGen, Skew};
pub use metrics::{LatencyHistogram, ServeMetrics};
pub use queue::{MicrobatchQueue, PendingGroup, QueryTicket};
pub use results::ResultsCache;
pub use router::{PlanKey, QueryRouter, Route};
pub use service::{
    prepare, serve_closed_loop, serve_closed_loop_with, ServeConfig,
    ServeReport, ServeSetup,
};
pub use shard::{reference_artifact, synthesize_cold, ColdPlan, ShardMap};
pub use update::{DynamicServeSession, UpdateConfig, UpdateReport};
