//! Online inference serving (DESIGN.md §9, §11).
//!
//! The paper's headline inference result — up to 130× faster than
//! sampling baselines at equal accuracy — comes from batches being
//! *fixed and reusable* at query time: all the expensive influence
//! computation happens once, offline. This module turns that property
//! into an online, concurrent service that answers "what is node v's
//! prediction?" requests — and, since the zero-quiesce refactor, keeps
//! answering them *while the graph churns*:
//!
//! * [`state`] — the immutable [`state::ServeState`] snapshot (graph
//!   view + plan cache + router index + plan epochs + placement +
//!   model) and the [`state::SwapCell`] it is published through: the
//!   whole query path reads one consistent epoch per admission, and a
//!   delta lands as a single pointer swap (DESIGN.md §11).
//! * [`router`] — immutable inverted index from output node →
//!   precomputed plan id (lives in the snapshot), plus the control
//!   loop's cold-id memo for nodes no precomputed batch covers: cold
//!   queries coalesce under a stable id and the node's home shard
//!   synthesizes + memoizes a personal top-k-PPR plan per epoch, off
//!   the control loop.
//! * [`queue`] — admission/microbatch queue that coalesces concurrent
//!   queries routed to the same (plan, epoch) into one
//!   materialize+execute (deadline- and size-based flush), each group
//!   pinning the snapshot it opened under (cf. "Cooperative
//!   Minibatching in GNNs", arXiv 2310.12403).
//! * [`shard`] — N executor worker shards, each owning its own
//!   [`crate::batching::BatchArena`] and prefetch ring; work is placed
//!   by the [`shard::Placement`] partition-cell table (METIS cells
//!   folded onto the run's shard count) so each shard's working set
//!   stays memory-local.
//! * [`results`] — byte-bounded LRU memo of recently executed plan
//!   logits, epoch-keyed on read *and* eagerly swept on snapshot swaps
//!   so stale entries release their bytes immediately.
//! * [`metrics`] — log-bucketed per-query latency histogram
//!   (p50/p95/p99), throughput, coalescing factor, cache hit rate.
//! * [`load`] — closed-loop load generator with configurable arrival
//!   skew (uniform or zipf over the query population) and per-arrival
//!   tenant ids.
//! * [`admission`] — deadline-aware admission gate: per-shard depth ×
//!   service-time EWMA predicts completion, over-deadline queries are
//!   shed (or degraded to a memo-only answer), and per-tenant token
//!   buckets cap each tenant's admission rate (DESIGN.md §12).
//! * [`coop`] — cooperative cross-shard serving (`ibmb serve
//!   --cooperative`, DESIGN.md §15): a control-loop-owned dispatcher
//!   that bounds per-shard in-flight work and lets idle shards steal
//!   backlogged groups from the deepest victim's tail, plus the
//!   decayed-hit tracker behind hot-plan replication; shard workers
//!   additionally share materialized feature rows across co-drained
//!   groups. All of it moves *where* a group executes, never *what*
//!   it computes, so the order-independent logit hash is unchanged.
//! * [`service`] — the event loop tying all of the above together
//!   behind `ibmb serve` / `benches/serving.rs`, including the churn
//!   harness ([`service::Churn`]) that attaches a delta source to a
//!   run: inline (quiesced baseline) or background/stream
//!   (zero-quiesce, `ibmb serve --live-updates`).
//! * [`update`] — the snapshot builder: [`update::UpdateApplier`]
//!   turns graph deltas into new published snapshots (delta overlay →
//!   incremental PPR refresh → plan rebuild/patch → structural-sharing
//!   snapshot assembly → pointer swap), either on a background thread
//!   ([`update::run_applier`]) or synchronously between segments
//!   ([`update::DynamicServeSession`], `ibmb serve --update-stream`,
//!   `ibmb update`, `benches/updates.rs`).
//!
//! Store-backed deployments (`ibmb serve --store DIR`) cold-start from
//! the content-addressed plan store ([`crate::store`]): the epoch-0
//! snapshot is assembled from the manifest alone
//! ([`service::prepare_from_store`]) and shard workers fault payloads
//! on demand through per-shard byte-budget residency LRUs
//! ([`crate::store::PlanResidency`]), so time-to-first-answer scales
//! with the working set, not the corpus (DESIGN.md §14).
//!
//! Execution goes through the pluggable [`crate::exec::Executor`]
//! backends (`--executor reference|blocked|pjrt`): the exact CPU
//! reference and the SIMD-blocked CPU backend run end-to-end even in
//! the offline build where the PJRT backend is stubbed, and the
//! artifact metadata they are driven by
//! ([`shard::reference_artifact`]) matches the AOT layout, so a real
//! accelerator backend slots in without touching the serve loop.
//! Operational guidance — flags, report fields, tuning — lives in
//! `docs/OPERATIONS.md`.

#![warn(missing_docs)]

pub mod admission;
pub mod coop;
pub mod load;
pub mod metrics;
pub mod queue;
pub mod results;
pub mod router;
pub mod service;
pub mod shard;
pub mod state;
pub mod update;

pub use admission::{AdmissionConfig, AdmissionGate, TenantCounters, Verdict};
pub use load::{Arrival, LoadGen, Skew};
pub use metrics::{LatencyHistogram, ServeMetrics};
pub use queue::{MicrobatchQueue, PendingGroup, QueryTicket};
pub use results::ResultsCache;
pub use router::{PlanKey, QueryRouter, Route, RouterIndex};
pub use service::{
    prepare, prepare_from_cache, prepare_from_store, serve_closed_loop,
    serve_closed_loop_with, serve_with_churn, Churn, ServeConfig, ServeReport,
    ServeSetup,
};
pub use shard::{
    reference_artifact, synthesize_cold, ColdPlan, Placement, PLACEMENT_CELLS,
};
pub use state::{ServeState, ServeStateCell, SwapCell};
pub use update::{
    run_applier, DynamicServeSession, UpdateApplier, UpdateConfig,
    UpdateReport,
};
