//! Cooperative cross-shard dispatch: bounded in-flight windows,
//! depth-ranked work-stealing, and hot-plan tracking (DESIGN.md §15).
//!
//! Under zipf skew the METIS placement concentrates hot plans on one
//! shard: its queue grows while the rest idle. This module is the
//! control-loop side of the fix (cf. "Cooperative Minibatching in
//! GNNs", arXiv 2310.12403). The [`CoopDispatcher`] caps how many
//! groups are in flight per shard (the *window*); everything past the
//! window waits in a per-shard FIFO backlog owned by the control
//! thread. Whenever a shard has spare window, [`CoopDispatcher::top_up`]
//! refills it — from its own backlog first (locality preserved), and
//! when that is empty by **stealing from the tail of the deepest
//! backlog** (depth-ranked victim selection: the newest work of the
//! most overloaded shard has the least locality value and the most
//! queueing ahead of it, so it is the cheapest to move).
//!
//! Keeping the backlogs on the single-threaded control loop — instead
//! of a lock-striped deque per shard — means no item is ever owned by
//! two queues: a group is either in exactly one backlog or in exactly
//! one shard's channel, so the "stolen group executes exactly once"
//! invariant is structural, and the unit tests below pin it.
//!
//! [`HotTracker`] is the replication half: a per-plan hit EWMA
//! (decayed counters) whose top-k feeds
//! [`super::shard::Placement::set_replica`] — the serve loop re-ranks
//! it periodically and points each hot plan at the least-loaded
//! non-home shard, so dispatch can route a hot group to whichever copy
//! has the shallower queue. Prediction bit-identity is preserved by
//! construction: a plan's logits depend only on its (epoch-pinned)
//! content, the model, and deterministic features — never on which
//! shard runs it — and the run hash folds per-query outcomes
//! commutatively, so stealing and replication cannot change
//! `ServeReport::logit_hash`.

use std::collections::{HashMap, VecDeque};

/// One sendable unit produced by [`CoopDispatcher::top_up`]: dispatch
/// `item` to `shard`, noting the victim when the item was stolen.
#[derive(Debug)]
pub struct Dispatch<T> {
    /// Shard the item must now be sent to.
    pub shard: usize,
    /// The work item (moved out of the backlog exactly once).
    pub item: T,
    /// `Some(victim)` when the item was stolen from `victim`'s
    /// backlog tail; `None` for a shard draining its own backlog.
    pub stolen_from: Option<usize>,
}

/// Windowed per-shard dispatcher with depth-ranked tail stealing.
///
/// Generic over the item type so the steal/once invariants are
/// unit-testable with plain tokens; the serve loop instantiates it
/// with [`super::shard::WorkItem`].
#[derive(Debug)]
pub struct CoopDispatcher<T> {
    window: usize,
    /// Groups sent to each shard's channel and not yet completed.
    inflight: Vec<usize>,
    /// Control-loop-owned overflow queues, one per shard.
    backlog: Vec<VecDeque<T>>,
    /// Groups moved off their dispatch shard by stealing.
    pub steals: u64,
    /// Groups that could not be sent immediately and were backlogged.
    pub backlogged: u64,
}

impl<T> CoopDispatcher<T> {
    /// `window` = max groups in flight per shard before backlogging
    /// (≥ 1). A small window keeps queues shallow enough to steal
    /// from while still letting shards drain several groups per ring
    /// run (fetch sharing needs co-resident groups).
    pub fn new(shards: usize, window: usize) -> CoopDispatcher<T> {
        let shards = shards.max(1);
        CoopDispatcher {
            window: window.max(1),
            inflight: vec![0; shards],
            backlog: (0..shards).map(|_| VecDeque::new()).collect(),
            steals: 0,
            backlogged: 0,
        }
    }

    /// Offer an item for `shard`: returns it back for an immediate
    /// send when the shard has window, otherwise backlogs it (FIFO).
    pub fn offer(&mut self, shard: usize, item: T) -> Option<(usize, T)> {
        if self.inflight[shard] < self.window {
            self.inflight[shard] += 1;
            Some((shard, item))
        } else {
            self.backlog[shard].push_back(item);
            self.backlogged += 1;
            None
        }
    }

    /// A group completed on `shard`, freeing one window slot.
    pub fn complete(&mut self, shard: usize) {
        self.inflight[shard] = self.inflight[shard].saturating_sub(1);
    }

    /// Groups currently in `shard`'s channel (sent, not completed).
    pub fn inflight(&self, shard: usize) -> usize {
        self.inflight[shard]
    }

    /// Groups waiting in `shard`'s backlog.
    pub fn pending(&self, shard: usize) -> usize {
        self.backlog[shard].len()
    }

    /// Total backlogged groups across all shards.
    pub fn pending_total(&self) -> usize {
        self.backlog.iter().map(VecDeque::len).sum()
    }

    /// Deepest backlog eligible as a steal victim for `thief` (max
    /// depth, lowest index on ties), or `None` when every other
    /// backlog is empty.
    fn victim_for(&self, thief: usize) -> Option<usize> {
        let mut best: Option<usize> = None;
        for v in 0..self.backlog.len() {
            if v == thief || self.backlog[v].is_empty() {
                continue;
            }
            match best {
                Some(b) if self.backlog[v].len() <= self.backlog[b].len() => {}
                _ => best = Some(v),
            }
        }
        best
    }

    /// Refill every shard with spare window: own backlog first
    /// (FIFO front — oldest group, preserving its queue order), then
    /// steal from the **tail** of the deepest other backlog. Returns
    /// the dispatches to send; each backlogged item appears in at most
    /// one `top_up` result, exactly once.
    pub fn top_up(&mut self) -> Vec<Dispatch<T>> {
        let mut out = Vec::new();
        for s in 0..self.backlog.len() {
            while self.inflight[s] < self.window {
                if let Some(item) = self.backlog[s].pop_front() {
                    self.inflight[s] += 1;
                    out.push(Dispatch {
                        shard: s,
                        item,
                        stolen_from: None,
                    });
                } else if let Some(v) = self.victim_for(s) {
                    let item = self.backlog[v].pop_back().unwrap();
                    self.inflight[s] += 1;
                    self.steals += 1;
                    out.push(Dispatch {
                        shard: s,
                        item,
                        stolen_from: Some(v),
                    });
                } else {
                    break;
                }
            }
        }
        out
    }

    /// Drain every backlog to its own shard, ignoring windows —
    /// shutdown safety valve (a completed run has empty backlogs, but
    /// error paths must not strand work silently).
    pub fn drain_all(&mut self) -> Vec<(usize, T)> {
        let mut out = Vec::new();
        for (s, q) in self.backlog.iter_mut().enumerate() {
            while let Some(item) = q.pop_front() {
                out.push((s, item));
            }
        }
        out
    }
}

/// Per-plan hit-rate EWMA for hot-plan replication: decayed counters,
/// re-ranked periodically by the serve loop (DESIGN.md §15).
#[derive(Debug)]
pub struct HotTracker {
    alpha: f64,
    score: HashMap<u32, f64>,
}

impl HotTracker {
    /// `alpha` ∈ (0, 1]: the fraction of each plan's score retained
    /// per [`HotTracker::decay`] — lower forgets faster.
    pub fn new(alpha: f64) -> HotTracker {
        HotTracker {
            alpha: alpha.clamp(1e-3, 1.0),
            score: HashMap::new(),
        }
    }

    /// One query hit plan `pid`.
    pub fn hit(&mut self, pid: u32) {
        *self.score.entry(pid).or_insert(0.0) += 1.0;
    }

    /// Age every score by `alpha`, dropping plans that have cooled
    /// below noise so the map tracks the hot set, not history.
    pub fn decay(&mut self) {
        let a = self.alpha;
        self.score.retain(|_, s| {
            *s *= a;
            *s > 1e-3
        });
    }

    /// Plans currently tracked.
    pub fn len(&self) -> usize {
        self.score.len()
    }

    /// True when no plan has a live score.
    pub fn is_empty(&self) -> bool {
        self.score.is_empty()
    }

    /// The `k` hottest plans, descending by score (ties broken toward
    /// the lower plan id, so the ranking is deterministic).
    pub fn top_k(&self, k: usize) -> Vec<u32> {
        let mut ranked: Vec<(u32, f64)> =
            self.score.iter().map(|(&p, &s)| (p, s)).collect();
        ranked.sort_by(|a, b| {
            b.1.partial_cmp(&a.1)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.0.cmp(&b.0))
        });
        ranked.truncate(k);
        ranked.into_iter().map(|(p, _)| p).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Run offers + completion cycles until everything drained,
    /// recording each item's dispatch count.
    fn drain_cycle(
        d: &mut CoopDispatcher<u64>,
        sent: &mut HashMap<u64, (usize, u32)>,
        first: Vec<(usize, u64)>,
    ) {
        let mut live: Vec<(usize, u64)> = first;
        while !live.is_empty() {
            // complete everything currently in flight…
            for &(s, id) in &live {
                let e = sent.entry(id).or_insert((s, 0));
                e.0 = s;
                e.1 += 1;
                d.complete(s);
            }
            live.clear();
            // …then refill the freed windows
            for dis in d.top_up() {
                live.push((dis.shard, dis.item));
            }
        }
    }

    #[test]
    fn window_bounds_inflight_and_overflow_backlogs() {
        let mut d: CoopDispatcher<u64> = CoopDispatcher::new(2, 2);
        let mut direct = 0;
        for i in 0..5u64 {
            if d.offer(0, i).is_some() {
                direct += 1;
            }
        }
        assert_eq!(direct, 2, "window admits exactly `window` items");
        assert_eq!(d.inflight(0), 2);
        assert_eq!(d.pending(0), 3);
        assert_eq!(d.backlogged, 3);
        assert_eq!(d.pending_total(), 3);
    }

    #[test]
    fn idle_shard_steals_from_deepest_tail() {
        let mut d: CoopDispatcher<u64> = CoopDispatcher::new(3, 1);
        // fill shard 0's window, then backlog 10..13 behind it;
        // shard 2 gets a shallower backlog (20, 21)
        assert!(d.offer(0, 9).is_some());
        for i in [10u64, 11, 12, 13] {
            assert!(d.offer(0, i).is_none());
        }
        assert!(d.offer(2, 19).is_some());
        for i in [20u64, 21] {
            assert!(d.offer(2, i).is_none());
        }
        // shard 1 is idle: top_up must hand it the TAIL of the
        // deepest backlog (shard 0's newest item, 13)
        let out = d.top_up();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shard, 1);
        assert_eq!(out[0].item, 13);
        assert_eq!(out[0].stolen_from, Some(0));
        assert_eq!(d.steals, 1);
        assert_eq!(d.pending(0), 3, "only the tail left shard 0");
    }

    #[test]
    fn own_backlog_preferred_over_stealing() {
        let mut d: CoopDispatcher<u64> = CoopDispatcher::new(2, 1);
        assert!(d.offer(0, 1).is_some());
        assert!(d.offer(0, 2).is_none());
        assert!(d.offer(1, 3).is_some());
        assert!(d.offer(1, 4).is_none());
        d.complete(0);
        let out = d.top_up();
        // shard 0 refills from its OWN backlog (FIFO front), not by
        // stealing shard 1's
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shard, 0);
        assert_eq!(out[0].item, 2);
        assert_eq!(out[0].stolen_from, None);
        assert_eq!(d.steals, 0);
    }

    #[test]
    fn every_item_dispatches_exactly_once_under_stealing() {
        // the tentpole invariant: a stolen group is executed exactly
        // once — never double-sent, never dropped
        let mut d: CoopDispatcher<u64> = CoopDispatcher::new(4, 1);
        let mut first: Vec<(usize, u64)> = Vec::new();
        // 64 items, all offered to shard 0: three shards can only eat
        // via steals
        for i in 0..64u64 {
            if let Some((s, item)) = d.offer(0, i) {
                first.push((s, item));
            }
        }
        first.extend(d.top_up().into_iter().map(|x| (x.shard, x.item)));
        let mut sent: HashMap<u64, (usize, u32)> = HashMap::new();
        drain_cycle(&mut d, &mut sent, first);
        assert_eq!(sent.len(), 64, "no item dropped");
        assert!(sent.values().all(|&(_, n)| n == 1), "no item double-sent");
        assert!(d.steals > 0, "idle shards must have stolen");
        assert_eq!(d.pending_total(), 0);
        // work actually spread: thieves executed a real share
        let stolen_share = sent.values().filter(|&&(s, _)| s != 0).count();
        assert!(stolen_share > 16, "steals moved {stolen_share}/64");
    }

    #[test]
    fn drain_all_flushes_backlogs_to_home_shards() {
        let mut d: CoopDispatcher<u64> = CoopDispatcher::new(2, 1);
        assert!(d.offer(1, 7).is_some());
        assert!(d.offer(1, 8).is_none());
        assert!(d.offer(1, 9).is_none());
        let rest = d.drain_all();
        assert_eq!(rest, vec![(1, 8), (1, 9)]);
        assert_eq!(d.pending_total(), 0);
    }

    #[test]
    fn hot_tracker_ranks_and_decays() {
        let mut h = HotTracker::new(0.5);
        assert!(h.is_empty());
        for _ in 0..8 {
            h.hit(3);
        }
        for _ in 0..4 {
            h.hit(7);
        }
        h.hit(1);
        assert_eq!(h.top_k(2), vec![3, 7]);
        assert_eq!(h.top_k(10), vec![3, 7, 1]);
        assert_eq!(h.len(), 3);
        // ties break toward the lower plan id
        let mut t = HotTracker::new(0.5);
        t.hit(9);
        t.hit(2);
        assert_eq!(t.top_k(2), vec![2, 9]);
        // decay cools history; repeated decay evicts cold plans
        for _ in 0..16 {
            h.decay();
        }
        assert!(h.is_empty(), "fully decayed scores are dropped");
    }
}
