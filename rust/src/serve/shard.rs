//! Sharded executor workers.
//!
//! Each shard is one worker thread owning its own
//! [`BatchArena`] and prefetch ring — the serving analogue of the
//! training pipeline's materialize/execute overlap (DESIGN.md §7, cf.
//! "Accelerating Training and Inference of GNNs with Fast Sampling and
//! Pipelining", arXiv 2110.08450: keep executors saturated while batch
//! preparation overlaps). Plans are assigned to shards through the
//! METIS graph partition, so the plans a shard executes cover adjacent
//! regions of the graph and its arena + feature working set stays
//! memory-local; cold plans follow their root node's partition cell.
//!
//! Execution runs the exact CPU reference forward pass
//! ([`forward`]) over the plan's induced subgraph, reading
//! edge topology zero-copy from the [`BatchCache`] arena slices and
//! dense features from the arena-pooled [`DenseBatch`]. The artifact
//! metadata is synthesized by [`reference_artifact`] in the exact AOT
//! manifest layout, so swapping in `Runtime::infer_step` when PJRT
//! artifacts exist is a local change to [`shard_worker`]'s consume
//! closure.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{Receiver, Sender};
use std::time::Instant;

use crate::batching::{BatchArena, BatchCache, DenseBatch};
use crate::datasets::Dataset;
use crate::graph::induced_subgraph;
use crate::inference::fullgraph::{forward, SparseGraphRef};
use crate::partition::metis::{partition_graph, MetisConfig};
use crate::pipeline::run_prefetched;
use crate::ppr::push::{push_ppr, PushConfig, PushWorkspace};
use crate::ppr::topk::top_k_indices;
use crate::runtime::{ArtifactMeta, ModelState, ParamSpec};
use crate::util::Rng;

use super::queue::QueryTicket;
use super::router::PlanKey;

/// Max work items a shard drains from its channel per prefetch run.
const MAX_DRAIN: usize = 64;

/// Per-shard cold-plan memo cap (FIFO eviction). Cold plans are cheap
/// to resynthesize, so a simple bound keeps sustained cold traffic
/// from growing the memo without limit (each plan holds up to
/// `bucket` nodes plus its edge arrays).
const MAX_COLD_PLANS: usize = 1024;

/// Index of the largest logit (deterministic: first max wins).
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

/// Synthesize an `ArtifactMeta` in the AOT manifest's exact parameter
/// layout (`python/compile/model.py::init_params`) for the CPU
/// reference executor — lets serving run without on-disk artifacts
/// while staying drop-in compatible with the PJRT runtime.
pub fn reference_artifact(
    model: &str,
    feat: usize,
    classes: usize,
    hidden: usize,
    layers: usize,
    heads: usize,
    n_pad: usize,
) -> ArtifactMeta {
    assert!(layers >= 1, "need at least one layer");
    fn add(
        params: &mut Vec<ParamSpec>,
        off: &mut usize,
        name: String,
        shape: Vec<usize>,
    ) {
        let size = shape.iter().product::<usize>().max(1);
        params.push(ParamSpec {
            name,
            shape,
            offset: *off,
            size,
        });
        *off += size;
    }
    let mut params: Vec<ParamSpec> = Vec::new();
    let mut off = 0usize;
    let mut d_in = feat;
    for l in 0..layers {
        let last = l == layers - 1;
        let d_out = if last { classes } else { hidden };
        match model {
            "gcn" => {
                add(&mut params, &mut off, format!("l{l}.w"), vec![d_in, d_out])
            }
            "sage" => add(
                &mut params,
                &mut off,
                format!("l{l}.w"),
                vec![2 * d_in, d_out],
            ),
            "gat" => {
                let h = if last { 1 } else { heads.max(1) };
                assert!(
                    d_out % h == 0,
                    "gat: layer width {d_out} must divide heads {h}"
                );
                add(&mut params, &mut off, format!("l{l}.w"), vec![d_in, d_out]);
            }
            other => panic!("unknown model {other}"),
        }
        add(&mut params, &mut off, format!("l{l}.b"), vec![d_out]);
        if model == "gat" {
            let h = if last { 1 } else { heads.max(1) };
            add(
                &mut params,
                &mut off,
                format!("l{l}.a_src"),
                vec![h, d_out / h],
            );
            add(
                &mut params,
                &mut off,
                format!("l{l}.a_dst"),
                vec![h, d_out / h],
            );
        }
        if !last {
            add(&mut params, &mut off, format!("l{l}.ln_g"), vec![d_out]);
            add(&mut params, &mut off, format!("l{l}.ln_b"), vec![d_out]);
        }
        d_in = d_out;
    }
    ArtifactMeta {
        id: format!("serve_{model}_n{n_pad}"),
        model: model.to_string(),
        kind: "infer".to_string(),
        n_pad,
        feat,
        classes,
        hidden,
        layers,
        heads: heads.max(1),
        dropout: 0.0,
        weight_decay: 0.0,
        param_count: off,
        params,
        path: String::new(),
    }
}

/// Plan → shard and node → shard assignment derived from the METIS
/// graph partition (memory locality: a shard's plans cover adjacent
/// graph regions).
#[derive(Debug, Clone)]
pub struct ShardMap {
    pub num_shards: usize,
    node_part: Vec<u32>,
    plan_shard: Vec<u32>,
}

impl ShardMap {
    pub fn build(
        ds: &Dataset,
        cache: &BatchCache,
        num_shards: usize,
        rng: &mut Rng,
    ) -> ShardMap {
        let k = num_shards.max(1);
        let node_part = partition_graph(&ds.graph, k, &MetisConfig::default(), rng);
        let mut plan_shard = Vec::with_capacity(cache.len());
        for pid in 0..cache.len() {
            // majority vote of the plan's output nodes
            let mut votes = vec![0usize; k];
            for &u in cache.output_nodes(pid) {
                votes[node_part[u as usize] as usize] += 1;
            }
            let mut best = 0usize;
            for s in 1..k {
                if votes[s] > votes[best] {
                    best = s;
                }
            }
            plan_shard.push(best as u32);
        }
        ShardMap {
            num_shards: k,
            node_part,
            plan_shard,
        }
    }

    pub fn shard_of_plan(&self, pid: u32) -> usize {
        self.plan_shard[pid as usize] as usize
    }

    pub fn shard_of_node(&self, node: u32) -> usize {
        self.node_part[node as usize] as usize
    }
}

/// A synthesized single-output plan for a node absent from every
/// precomputed batch, memoized shard-locally. The query node is
/// always local id 0 / the single output. Edge endpoints are stored
/// *only* pre-split into parallel arrays (the tuple form a
/// `BatchPlan` carries would double the memo's edge bytes) so the
/// executor can build a [`SparseGraphRef`] without per-query work.
#[derive(Debug)]
pub struct ColdPlan {
    /// The query node.
    pub node: u32,
    /// Plan node list (global ids, query node first).
    pub nodes: Vec<u32>,
    pub edge_src: Vec<u32>,
    pub edge_dst: Vec<u32>,
    pub weights: Vec<f32>,
}

/// Cold path: single-output plan over the node's top-k PPR
/// neighborhood (paper §3.1 at batch size one), capped at `budget`
/// nodes. Runs on the node's home shard — never on the service
/// control loop — so synthesis cannot stall deadline flushes.
pub fn synthesize_cold(
    ds: &Dataset,
    node: u32,
    aux: usize,
    budget: usize,
    push: &PushConfig,
    ws: &mut PushWorkspace,
) -> ColdPlan {
    let ppr = push_ppr(&ds.graph, node, push, ws);
    let mut nodes = Vec::with_capacity(aux + 1);
    nodes.push(node);
    // +1 candidate slot because the root usually tops its own PPR
    for t in top_k_indices(&ppr.scores, aux + 1) {
        let v = ppr.nodes[t];
        if v != node && nodes.len() < aux + 1 {
            nodes.push(v);
        }
    }
    nodes.truncate(budget.max(1));
    let sg = induced_subgraph(&ds.graph, &nodes);
    let n = sg.nodes.len() as u32;
    debug_assert!(sg.edges.iter().all(|&(s, d)| s < n && d < n));
    debug_assert_eq!(sg.edges.len(), sg.weights.len());
    let (edge_src, edge_dst): (Vec<u32>, Vec<u32>) =
        sg.edges.iter().copied().unzip();
    ColdPlan {
        node,
        nodes: sg.nodes,
        edge_src,
        edge_dst,
        weights: sg.weights,
    }
}

/// What a shard executes: a cached plan id or a cold query node whose
/// plan the shard synthesizes (once) and memoizes locally.
#[derive(Debug, Clone, Copy)]
pub enum Work {
    Cached(u32),
    Cold(u32),
}

/// One coalesced group dispatched to a shard.
#[derive(Debug)]
pub struct WorkItem {
    pub key: PlanKey,
    pub work: Work,
    pub queries: Vec<QueryTicket>,
}

/// Per-query outcome of one execution.
#[derive(Debug, Clone, Copy)]
pub struct QueryOutcome {
    pub id: u64,
    pub node: u32,
    pub pred: u16,
    pub correct: bool,
}

/// One executed group's results.
#[derive(Debug)]
pub struct ShardResult {
    pub shard_id: usize,
    pub key: PlanKey,
    pub outcomes: Vec<QueryOutcome>,
    /// Logits of the plan's output nodes, row-major
    /// `[num_outputs * classes]` — feeds the results memo.
    pub out_logits: Vec<f32>,
    pub num_outputs: usize,
    pub batch_nodes: usize,
    /// Seconds spent in the forward pass for this group.
    pub exec_s: f64,
}

/// Final per-shard accounting, sent once when the shard shuts down.
#[derive(Debug, Clone, Copy)]
pub struct ShardDone {
    pub shard_id: usize,
    /// Seconds the execute side stalled waiting on materialization.
    pub wait_s: f64,
    /// Seconds spent in the consume (execute) closures.
    pub consume_s: f64,
    /// Prefetch-ring drains performed.
    pub drains: u64,
    pub arena_bytes: usize,
    pub arena_allocations: usize,
}

/// Everything flowing back from shards to the event loop.
#[derive(Debug)]
pub enum ShardMsg {
    Result(ShardResult),
    Done(ShardDone),
}

/// Borrowed execution context of one shard (all shared state is
/// immutable; the arena and cold-plan memo are shard-private).
#[derive(Clone, Copy)]
pub struct ShardCtx<'a> {
    pub shard_id: usize,
    pub ds: &'a Dataset,
    pub cache: &'a BatchCache,
    pub meta: &'a ArtifactMeta,
    pub state: &'a ModelState,
    /// Dense-buffer bucket (n_pad) every plan must fit — also the
    /// node cap for synthesized cold plans.
    pub bucket: usize,
    pub ring_depth: usize,
    /// Top-k PPR budget for cold-plan synthesis.
    pub cold_aux: usize,
}

/// Features-only fill for the CPU reference executor. The sparse
/// forward reads edge topology zero-copy from the plan and consumes
/// exactly `x[..n * feat]`, so the dense adjacency/labels/mask of a
/// full `materialize` would be dead work on the serving hot path
/// (O(n_pad²) zeroing per group). A PJRT executor swap would restore
/// full materialization here — that is the only change needed.
fn fill_features(
    ds: &Dataset,
    nodes: &[u32],
    num_outputs: usize,
    buf: &mut DenseBatch,
) {
    let n = nodes.len();
    assert!(
        n <= buf.n_pad,
        "batch of {n} nodes exceeds bucket {}",
        buf.n_pad
    );
    for (i, &u) in nodes.iter().enumerate() {
        ds.node_features_into(u, &mut buf.x[i * buf.feat..(i + 1) * buf.feat]);
    }
    buf.num_real = n;
    buf.num_outputs = num_outputs;
}

fn execute_one(
    ctx: &ShardCtx<'_>,
    item: &WorkItem,
    cold_plans: &HashMap<u32, ColdPlan>,
    buf: &DenseBatch,
) -> ShardResult {
    let t = Instant::now();
    let n = buf.num_real;
    let classes = ctx.meta.classes;
    let (edge_src, edge_dst, weights) = match &item.work {
        Work::Cached(pid) => {
            let p = *pid as usize;
            (
                ctx.cache.edge_src_of(p),
                ctx.cache.edge_dst_of(p),
                ctx.cache.edge_weights_of(p),
            )
        }
        Work::Cold(node) => {
            let cp = &cold_plans[node];
            (
                cp.edge_src.as_slice(),
                cp.edge_dst.as_slice(),
                cp.weights.as_slice(),
            )
        }
    };
    let g = SparseGraphRef {
        n,
        edge_src,
        edge_dst,
        weights,
    };
    let mut out_logits =
        forward(ctx.meta, ctx.state, &g, &buf.x[..n * ctx.meta.feat]);
    out_logits.truncate(buf.num_outputs * classes);
    let outcomes = item
        .queries
        .iter()
        .map(|q| {
            let start = q.pos as usize * classes;
            let pred = argmax(&out_logits[start..start + classes]);
            QueryOutcome {
                id: q.id,
                node: q.node,
                pred: pred as u16,
                correct: pred == ctx.ds.labels[q.node as usize] as usize,
            }
        })
        .collect();
    ShardResult {
        shard_id: ctx.shard_id,
        key: item.key,
        outcomes,
        out_logits,
        num_outputs: buf.num_outputs,
        batch_nodes: n,
        exec_s: t.elapsed().as_secs_f64(),
    }
}

/// Shard worker loop: drain up to [`MAX_DRAIN`] pending groups, stream
/// them through the prefetch ring (materialize overlapped with
/// execute), send one [`ShardResult`] per group, repeat until the work
/// channel closes; then report [`ShardDone`].
pub fn shard_worker(
    ctx: ShardCtx<'_>,
    rx: Receiver<WorkItem>,
    tx: Sender<ShardMsg>,
) {
    let mut arena = BatchArena::new(ctx.ds.feat_dim);
    let mut cold_plans: HashMap<u32, ColdPlan> = HashMap::new();
    let mut cold_order: VecDeque<u32> = VecDeque::new();
    let mut ws = PushWorkspace::new(ctx.ds.graph.num_nodes());
    let push_cfg = PushConfig::default();
    let mut wait_s = 0.0;
    let mut consume_s = 0.0;
    let mut drains = 0u64;
    loop {
        let first = match rx.recv() {
            Ok(w) => w,
            Err(_) => break,
        };
        let mut items = vec![first];
        while items.len() < MAX_DRAIN {
            match rx.try_recv() {
                Ok(w) => items.push(w),
                Err(_) => break,
            }
        }
        // synthesize any first-seen cold plans up front so the ring
        // closures below only read the memo
        for item in &items {
            if let Work::Cold(node) = item.work {
                if !cold_plans.contains_key(&node) {
                    let cp = synthesize_cold(
                        ctx.ds,
                        node,
                        ctx.cold_aux,
                        ctx.bucket,
                        &push_cfg,
                        &mut ws,
                    );
                    cold_plans.insert(node, cp);
                    cold_order.push_back(node);
                }
            }
        }
        let order: Vec<usize> = (0..items.len()).collect();
        let depth = ctx.ring_depth.max(1).min(items.len());
        let ring = arena.acquire_many(ctx.bucket, depth);
        let items_ref = &items;
        let cold_ref = &cold_plans;
        let (stats, ring) = run_prefetched(
            &order,
            ring,
            |i, buf| match &items_ref[i].work {
                Work::Cached(pid) => {
                    let p = *pid as usize;
                    fill_features(
                        ctx.ds,
                        ctx.cache.batch_nodes(p),
                        ctx.cache.num_outputs(p),
                        buf,
                    )
                }
                Work::Cold(node) => {
                    let cp = &cold_ref[node];
                    fill_features(ctx.ds, &cp.nodes, 1, buf)
                }
            },
            |i, buf| {
                let result = execute_one(&ctx, &items_ref[i], cold_ref, buf);
                let _ = tx.send(ShardMsg::Result(result));
            },
        );
        arena.release_many(ring);
        // FIFO-bound the cold memo AFTER the drain: evicting mid-drain
        // could drop a plan another item of this drain still reads.
        // The cap is exceeded by at most one drain's worth of plans.
        while cold_plans.len() > MAX_COLD_PLANS {
            match cold_order.pop_front() {
                Some(old) => {
                    cold_plans.remove(&old);
                }
                None => break,
            }
        }
        wait_s += stats.wait_s;
        consume_s += stats.consume_s;
        drains += 1;
    }
    let _ = tx.send(ShardMsg::Done(ShardDone {
        shard_id: ctx.shard_id,
        wait_s,
        consume_s,
        drains,
        arena_bytes: arena.memory_bytes(),
        arena_allocations: arena.allocations(),
    }));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batching::{BatchGenerator, NodeWiseIbmb};
    use crate::datasets::{sbm, DatasetSpec};

    fn setup() -> (Dataset, BatchCache) {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 21);
        let mut g = NodeWiseIbmb {
            aux_per_output: 6,
            max_outputs_per_batch: 40,
            node_budget: 256,
            ..Default::default()
        };
        let mut rng = Rng::new(9);
        let out = ds.splits.train.clone();
        let cache = BatchCache::build(&g.plan(&ds, &out, &mut rng));
        (ds, cache)
    }

    #[test]
    fn reference_artifact_layouts_parse_for_all_models() {
        for model in ["gcn", "sage", "gat"] {
            let meta = reference_artifact(model, 16, 4, 8, 2, 2, 64);
            assert_eq!(meta.kind, "infer");
            assert_eq!(meta.n_pad, 64);
            // contiguous offsets summing to param_count (the manifest
            // invariant Manifest::parse enforces)
            let mut off = 0usize;
            for p in &meta.params {
                assert_eq!(p.offset, off, "{model}: {}", p.name);
                assert_eq!(p.size, p.shape.iter().product::<usize>());
                off += p.size;
            }
            assert_eq!(off, meta.param_count, "{model}");
            // a state initialized from it drives the reference forward
            let state = ModelState::init(&meta, 5);
            assert_eq!(state.params.len(), meta.param_count);
            assert!(state.tensor(&meta, "l0.w").is_some());
            assert!(state.tensor(&meta, "l1.b").is_some());
        }
    }

    #[test]
    fn cold_plan_synthesis_respects_budget_and_root_first() {
        let (ds, _) = setup();
        let mut ws = PushWorkspace::new(ds.graph.num_nodes());
        let push = PushConfig::default();
        let cp = synthesize_cold(&ds, 5, 8, 64, &push, &mut ws);
        assert_eq!(cp.node, 5);
        assert_eq!(cp.nodes[0], 5, "query node is output 0");
        assert!(cp.nodes.len() <= 9, "aux budget respected");
        assert_eq!(cp.edge_src.len(), cp.weights.len());
        assert_eq!(cp.edge_dst.len(), cp.weights.len());
        let n = cp.nodes.len() as u32;
        assert!(cp.edge_src.iter().chain(&cp.edge_dst).all(|&v| v < n));
        // a tight node budget caps the plan below the aux budget
        let tight = synthesize_cold(&ds, 7, 32, 4, &push, &mut ws);
        assert!(tight.nodes.len() <= 4);
        assert_eq!(tight.nodes[0], 7);
    }

    #[test]
    fn shard_map_covers_all_plans_and_nodes() {
        let (ds, cache) = setup();
        let mut rng = Rng::new(4);
        for shards in [1usize, 2, 4] {
            let map = ShardMap::build(&ds, &cache, shards, &mut rng);
            assert_eq!(map.num_shards, shards);
            for pid in 0..cache.len() as u32 {
                assert!(map.shard_of_plan(pid) < shards);
            }
            for u in 0..ds.graph.num_nodes() as u32 {
                assert!(map.shard_of_node(u) < shards);
            }
        }
    }

    #[test]
    fn plan_shard_follows_output_majority() {
        let (ds, cache) = setup();
        let mut rng = Rng::new(4);
        let map = ShardMap::build(&ds, &cache, 2, &mut rng);
        for pid in 0..cache.len() {
            let shard = map.shard_of_plan(pid as u32);
            let on_shard = cache
                .output_nodes(pid)
                .iter()
                .filter(|&&u| map.shard_of_node(u) == shard)
                .count();
            assert!(
                2 * on_shard >= cache.num_outputs(pid),
                "plan {pid}: {} of {} outputs on shard {shard}",
                on_shard,
                cache.num_outputs(pid)
            );
        }
    }

    #[test]
    fn worker_executes_groups_and_reports_done() {
        use std::sync::mpsc;
        let (ds, cache) = setup();
        let meta = reference_artifact(
            "gcn",
            ds.feat_dim,
            ds.num_classes,
            8,
            2,
            2,
            cache.max_batch_nodes().next_power_of_two().max(16),
        );
        let state = ModelState::init(&meta, 1);
        let (work_tx, work_rx) = mpsc::channel::<WorkItem>();
        let (res_tx, res_rx) = mpsc::channel::<ShardMsg>();
        std::thread::scope(|scope| {
            let ctx = ShardCtx {
                shard_id: 0,
                ds: &ds,
                cache: &cache,
                meta: &meta,
                state: &state,
                bucket: meta.n_pad,
                ring_depth: 2,
                cold_aux: 8,
            };
            scope.spawn(move || shard_worker(ctx, work_rx, res_tx));
            // one group per cached plan, one query each (its first output)
            for pid in 0..cache.len() as u32 {
                let node = cache.output_nodes(pid as usize)[0];
                work_tx
                    .send(WorkItem {
                        key: PlanKey::Cached(pid),
                        work: Work::Cached(pid),
                        queries: vec![QueryTicket {
                            id: pid as u64,
                            node,
                            pos: 0,
                        }],
                    })
                    .unwrap();
            }
            drop(work_tx);
            let mut results = 0usize;
            let mut done = 0usize;
            for msg in res_rx.iter() {
                match msg {
                    ShardMsg::Result(r) => {
                        results += 1;
                        assert_eq!(r.outcomes.len(), 1);
                        assert_eq!(
                            r.out_logits.len(),
                            r.num_outputs * meta.classes
                        );
                        assert!(r.out_logits.iter().all(|v| v.is_finite()));
                        assert!((r.outcomes[0].pred as usize) < meta.classes);
                    }
                    ShardMsg::Done(d) => {
                        done += 1;
                        assert!(d.drains >= 1);
                        assert!(d.arena_allocations >= 1);
                        assert!(d.arena_bytes > 0);
                    }
                }
            }
            assert_eq!(results, cache.len());
            assert_eq!(done, 1);
        });
    }
}
