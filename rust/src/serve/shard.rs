//! Sharded executor workers over immutable snapshots.
//!
//! Each shard is one worker thread owning its own
//! [`BatchArena`] and prefetch ring — the serving analogue of the
//! training pipeline's materialize/execute overlap (DESIGN.md §7, cf.
//! "Accelerating Training and Inference of GNNs with Fast Sampling and
//! Pipelining", arXiv 2110.08450: keep executors saturated while batch
//! preparation overlaps). Every [`WorkItem`] carries the
//! `Arc<ServeState>` snapshot its group was admitted under
//! (DESIGN.md §11): the shard reads graph, plan payloads, features,
//! and labels from *that* snapshot, so an epoch swap mid-drain never
//! tears a group — items of different epochs simply read different
//! (immutable) states, and the shard needs no locks and no quiesce.
//!
//! Plans and nodes are placed on shards through the [`Placement`]
//! partition-cell table (a fixed-granularity METIS partition folded
//! onto the run's shard count), so the plans a shard executes cover
//! adjacent regions of the graph and its arena + feature working set
//! stays memory-local; cold plans follow their root node's cell.
//!
//! Execution goes through the pluggable [`Executor`] trait
//! (DESIGN.md §13): each worker builds its configured backend once at
//! startup ([`ShardCtx::executor`], default the SIMD-blocked CPU
//! kernels) together with one reusable [`ExecScratch`], and runs the
//! forward over the plan's induced subgraph — edge topology read
//! zero-copy from the snapshot's [`CowCache`] payloads as a
//! [`PlanView`], dense features from the arena-pooled [`DenseBatch`].
//! The artifact metadata is synthesized by [`reference_artifact`] in
//! the exact AOT manifest layout, so the PJRT executor can execute the
//! same groups once real bindings land.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use crate::batching::{BatchArena, CowCache, DenseBatch, PlanPayload};
use crate::datasets::Dataset;
use crate::exec::{ExecScratch, Executor, ExecutorKind, PlanView};
use crate::graph::{induced_subgraph, CsrGraph};
use crate::partition::metis::{partition_graph, MetisConfig};
use crate::pipeline::run_prefetched;
use crate::ppr::push::{push_ppr, PushConfig, PushWorkspace};
use crate::ppr::topk::top_k_indices;
use crate::runtime::{ArtifactMeta, ModelState, ParamSpec};
use crate::store::PlanResidency;
use crate::util::Rng;

use super::queue::QueryTicket;
use super::router::PlanKey;
use super::state::ServeState;
use crate::telemetry::span::{Stage, NO_QUERY};
use crate::telemetry::Tracer;

/// Max work items a shard drains from its channel per prefetch run.
const MAX_DRAIN: usize = 64;

/// Per-shard cold-plan memo cap (FIFO eviction). Cold plans are cheap
/// to resynthesize, so a simple bound keeps sustained cold traffic
/// from growing the memo without limit (each plan holds up to
/// `bucket` nodes plus its edge arrays).
const MAX_COLD_PLANS: usize = 1024;

/// Partition-cell granularity of [`Placement`]: fixed so the cell
/// table is shard-count independent (one table serves every run and
/// survives snapshot patches) yet fine enough that folding cells onto
/// 1–16 shards stays balanced.
pub const PLACEMENT_CELLS: usize = 32;

/// Index of the largest logit (deterministic: first max wins).
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

/// Synthesize an `ArtifactMeta` in the AOT manifest's exact parameter
/// layout (`python/compile/model.py::init_params`) for the CPU
/// reference executor — lets serving run without on-disk artifacts
/// while staying drop-in compatible with the PJRT runtime.
pub fn reference_artifact(
    model: &str,
    feat: usize,
    classes: usize,
    hidden: usize,
    layers: usize,
    heads: usize,
    n_pad: usize,
) -> ArtifactMeta {
    assert!(layers >= 1, "need at least one layer");
    fn add(
        params: &mut Vec<ParamSpec>,
        off: &mut usize,
        name: String,
        shape: Vec<usize>,
    ) {
        let size = shape.iter().product::<usize>().max(1);
        params.push(ParamSpec {
            name,
            shape,
            offset: *off,
            size,
        });
        *off += size;
    }
    let mut params: Vec<ParamSpec> = Vec::new();
    let mut off = 0usize;
    let mut d_in = feat;
    for l in 0..layers {
        let last = l == layers - 1;
        let d_out = if last { classes } else { hidden };
        match model {
            "gcn" => {
                add(&mut params, &mut off, format!("l{l}.w"), vec![d_in, d_out])
            }
            "sage" => add(
                &mut params,
                &mut off,
                format!("l{l}.w"),
                vec![2 * d_in, d_out],
            ),
            "gat" => {
                let h = if last { 1 } else { heads.max(1) };
                assert!(
                    d_out % h == 0,
                    "gat: layer width {d_out} must divide heads {h}"
                );
                add(&mut params, &mut off, format!("l{l}.w"), vec![d_in, d_out]);
            }
            other => panic!("unknown model {other}"),
        }
        add(&mut params, &mut off, format!("l{l}.b"), vec![d_out]);
        if model == "gat" {
            let h = if last { 1 } else { heads.max(1) };
            add(
                &mut params,
                &mut off,
                format!("l{l}.a_src"),
                vec![h, d_out / h],
            );
            add(
                &mut params,
                &mut off,
                format!("l{l}.a_dst"),
                vec![h, d_out / h],
            );
        }
        if !last {
            add(&mut params, &mut off, format!("l{l}.ln_g"), vec![d_out]);
            add(&mut params, &mut off, format!("l{l}.ln_b"), vec![d_out]);
        }
        d_in = d_out;
    }
    ArtifactMeta {
        id: format!("serve_{model}_n{n_pad}"),
        model: model.to_string(),
        kind: "infer".to_string(),
        n_pad,
        feat,
        classes,
        hidden,
        layers,
        heads: heads.max(1),
        dropout: 0.0,
        weight_decay: 0.0,
        param_count: off,
        params,
        path: String::new(),
    }
}

/// Node → partition cell and plan → home cell, derived from the METIS
/// graph partition at a fixed [`PLACEMENT_CELLS`] granularity (memory
/// locality: a shard's plans cover adjacent graph regions). Cells fold
/// onto the run's shard count with a modulus, so one immutable table
/// inside the snapshot serves any shard count, and graph deltas patch
/// it structurally: outputs never migrate between plans, so plan
/// homes are stable, and appended nodes only *extend* the node table
/// ([`Placement::extended`]).
#[derive(Debug, Clone)]
pub struct Placement {
    cells: usize,
    node_cell: Vec<u32>,
    plan_cell: Vec<u32>,
    /// Cooperative hot-plan replica routes (DESIGN.md §15): plan id →
    /// replica cell. Empty outside cooperative mode; maintained by the
    /// serve loop's control-side copy, never by snapshot builders.
    replicas: HashMap<u32, u32>,
}

impl Placement {
    /// METIS-place every node and majority-vote every cached plan into
    /// one of `cells` partition cells (DESIGN.md §11).
    pub fn build(
        ds: &Dataset,
        cache: &CowCache,
        cells: usize,
        rng: &mut Rng,
    ) -> Placement {
        let cells = cells.clamp(1, ds.graph.num_nodes().max(1));
        let node_cell =
            partition_graph(&ds.graph, cells, &MetisConfig::default(), rng);
        let plan_cell = (0..cache.len())
            .map(|pid| Self::majority_cell(cache.output_nodes(pid), &node_cell, cells))
            .collect();
        Placement {
            cells,
            node_cell,
            plan_cell,
            replicas: HashMap::new(),
        }
    }

    /// Metadata-only placement for store-backed cold starts: plan
    /// payloads are still on disk, so there are no output lists to
    /// majority-vote over and no reason to run METIS before the first
    /// query. Nodes and plans get round-robin cells — locality is
    /// deliberately traded for a zero-read start; a later re-`build`
    /// (once the working set is resident) restores METIS placement.
    pub fn round_robin(num_nodes: usize, num_plans: usize, cells: usize) -> Placement {
        let cells = cells.clamp(1, num_nodes.max(1));
        Placement {
            cells,
            node_cell: (0..num_nodes).map(|u| (u % cells) as u32).collect(),
            plan_cell: (0..num_plans).map(|p| (p % cells) as u32).collect(),
            replicas: HashMap::new(),
        }
    }

    fn majority_cell(outputs: &[u32], node_cell: &[u32], cells: usize) -> u32 {
        let mut votes = vec![0usize; cells];
        for &u in outputs {
            votes[node_cell[u as usize] as usize] += 1;
        }
        let mut best = 0usize;
        for c in 1..cells {
            if votes[c] > votes[best] {
                best = c;
            }
        }
        best as u32
    }

    /// The next snapshot's placement after node appends: existing
    /// cells are untouched (plan homes are majority votes over output
    /// nodes, which never change), appended nodes adopt the cell of
    /// their first already-placed neighbor — locality for nodes that
    /// arrived with edges — or fall back to a round-robin cell.
    pub fn extended(&self, graph: &CsrGraph) -> Placement {
        let n = graph.num_nodes();
        debug_assert!(n >= self.node_cell.len());
        let mut node_cell = self.node_cell.clone();
        for u in node_cell.len()..n {
            let inherited = graph
                .neighbors(u as u32)
                .iter()
                .find(|&&v| (v as usize) < node_cell.len() && v as usize != u)
                .map(|&v| node_cell[v as usize]);
            node_cell.push(
                inherited.unwrap_or((u % self.cells.max(1)) as u32),
            );
        }
        Placement {
            cells: self.cells,
            node_cell,
            plan_cell: self.plan_cell.clone(),
            replicas: self.replicas.clone(),
        }
    }

    /// Partition-cell granularity of the table.
    pub fn cells(&self) -> usize {
        self.cells
    }

    /// Nodes covered by the node→cell table.
    pub fn num_nodes(&self) -> usize {
        self.node_cell.len()
    }

    /// Cached plans covered by the plan→cell table.
    pub fn num_plans(&self) -> usize {
        self.plan_cell.len()
    }

    /// Point hot plan `pid` at a replica `cell` (cooperative serving,
    /// DESIGN.md §15). Dispatch then picks home vs replica by
    /// instantaneous queue depth; the replica shard faults the plan
    /// through the ordinary `PlanResidency` path if store-backed.
    pub fn set_replica(&mut self, pid: u32, cell: u32) {
        self.replicas.insert(pid, cell);
    }

    /// Drop every replica route (called before each re-rank of the
    /// hot set).
    pub fn clear_replicas(&mut self) {
        self.replicas.clear();
    }

    /// Replica routes currently installed.
    pub fn num_replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Iterate the installed replica routes as (plan, cell).
    pub fn replicas(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        self.replicas.iter().map(|(&p, &c)| (p, c))
    }

    /// Fold plan `pid`'s replica cell (if any) onto `shards` workers.
    pub fn replica_shard_of_plan(
        &self,
        pid: u32,
        shards: usize,
    ) -> Option<usize> {
        self.replicas
            .get(&pid)
            .map(|&c| c as usize % shards.max(1))
    }

    /// Fold plan `pid`'s home cell onto `shards` workers.
    pub fn shard_of_plan(&self, pid: u32, shards: usize) -> usize {
        self.plan_cell[pid as usize] as usize % shards.max(1)
    }

    /// Fold node `node`'s cell onto `shards` workers.
    pub fn shard_of_node(&self, node: u32, shards: usize) -> usize {
        self.node_cell[node as usize] as usize % shards.max(1)
    }
}

/// A synthesized single-output plan for a node absent from every
/// precomputed batch, memoized shard-locally per (node, epoch). The
/// query node is always local id 0 / the single output. Edge endpoints
/// are stored *only* pre-split into parallel arrays (the tuple form a
/// `BatchPlan` carries would double the memo's edge bytes) so the
/// executor can build a [`SparseGraphRef`] without per-query work.
#[derive(Debug)]
pub struct ColdPlan {
    /// The query node.
    pub node: u32,
    /// Plan node list (global ids, query node first).
    pub nodes: Vec<u32>,
    /// Induced-subgraph edge sources (local ids).
    pub edge_src: Vec<u32>,
    /// Induced-subgraph edge destinations (local ids).
    pub edge_dst: Vec<u32>,
    /// Per-edge normalized weights, parallel to the endpoint arrays.
    pub weights: Vec<f32>,
}

/// Cold path: single-output plan over the node's top-k PPR
/// neighborhood (paper §3.1 at batch size one), capped at `budget`
/// nodes. Runs on the node's home shard — never on the service
/// control loop — so synthesis cannot stall deadline flushes.
pub fn synthesize_cold(
    ds: &Dataset,
    node: u32,
    aux: usize,
    budget: usize,
    push: &PushConfig,
    ws: &mut PushWorkspace,
) -> ColdPlan {
    let ppr = push_ppr(&ds.graph, node, push, ws);
    let mut nodes = Vec::with_capacity(aux + 1);
    nodes.push(node);
    // +1 candidate slot because the root usually tops its own PPR
    for t in top_k_indices(&ppr.scores, aux + 1) {
        let v = ppr.nodes[t];
        if v != node && nodes.len() < aux + 1 {
            nodes.push(v);
        }
    }
    nodes.truncate(budget.max(1));
    let sg = induced_subgraph(&ds.graph, &nodes);
    let n = sg.nodes.len() as u32;
    debug_assert!(sg.edges.iter().all(|&(s, d)| s < n && d < n));
    debug_assert_eq!(sg.edges.len(), sg.weights.len());
    let (edge_src, edge_dst): (Vec<u32>, Vec<u32>) =
        sg.edges.iter().copied().unzip();
    ColdPlan {
        node,
        nodes: sg.nodes,
        edge_src,
        edge_dst,
        weights: sg.weights,
    }
}

/// What a shard executes: a cached plan id or a cold query node whose
/// plan the shard synthesizes (once per epoch) and memoizes locally.
#[derive(Debug, Clone, Copy)]
pub enum Work {
    /// Execute precomputed plan `pid` from the snapshot (or store).
    Cached(u32),
    /// Synthesize-and-execute a cold plan rooted at this query node.
    Cold(u32),
}

/// One coalesced group dispatched to a shard, pinned to the snapshot
/// it was admitted under.
#[derive(Debug)]
pub struct WorkItem {
    /// Queue-assigned group id (trace correlation + in-flight
    /// accounting on the control side).
    pub gid: u64,
    /// Router key the group coalesced under (memo key on completion).
    pub key: PlanKey,
    /// Freshness epoch of the group's plan (stamps the memo insert).
    pub epoch: u64,
    /// The snapshot this group executes against.
    pub state: Arc<ServeState>,
    /// What to execute: a cached plan id or a cold root node.
    pub work: Work,
    /// The coalesced rider queries answered by this execution.
    pub queries: Vec<QueryTicket>,
}

/// Per-query outcome of one execution.
#[derive(Debug, Clone, Copy)]
pub struct QueryOutcome {
    /// Caller-assigned query id.
    pub id: u64,
    /// The queried output node.
    pub node: u32,
    /// Predicted class (argmax of the node's logits).
    pub pred: u16,
    /// Whether the prediction matches the dataset label.
    pub correct: bool,
}

/// One executed group's results.
#[derive(Debug)]
pub struct ShardResult {
    /// Shard that executed the group (after any steal/replica move).
    pub shard_id: usize,
    /// Group id of the [`WorkItem`] this answers.
    pub gid: u64,
    /// Router key of the answered group (results-memo key).
    pub key: PlanKey,
    /// Plan epoch the logits were computed at (memo freshness stamp).
    pub epoch: u64,
    /// One outcome per rider query of the group.
    pub outcomes: Vec<QueryOutcome>,
    /// Logits of the plan's output nodes, row-major
    /// `[num_outputs * classes]` — feeds the results memo.
    pub out_logits: Vec<f32>,
    /// Output rows in `out_logits`.
    pub num_outputs: usize,
    /// Total nodes (outputs + auxiliaries) in the executed batch.
    pub batch_nodes: usize,
    /// Seconds spent in the forward pass for this group.
    pub exec_s: f64,
}

/// Final per-shard accounting, sent once when the shard shuts down.
#[derive(Debug, Clone, Copy)]
pub struct ShardDone {
    /// The reporting shard.
    pub shard_id: usize,
    /// Seconds the execute side stalled waiting on materialization.
    pub wait_s: f64,
    /// Seconds spent in the consume (execute) closures.
    pub consume_s: f64,
    /// Prefetch-ring drains performed.
    pub drains: u64,
    /// Bytes held by the shard's batch arena at shutdown.
    pub arena_bytes: usize,
    /// Dense-buffer allocations the arena performed over its lifetime.
    pub arena_allocations: usize,
    /// Plan-store faults (blob reads) this shard performed; 0 unless
    /// the deployment is store-backed.
    pub store_faults: u64,
    /// Payload bytes resident in this shard's plan LRU at shutdown.
    pub resident_bytes: u64,
    /// Feature bytes this shard did NOT re-materialize because a
    /// co-drained group already filled the same node's row (cooperative
    /// fetch sharing, DESIGN.md §15). 0 outside cooperative mode.
    pub shared_row_bytes: u64,
}

/// Everything flowing back from shards to the event loop.
#[derive(Debug)]
pub enum ShardMsg {
    /// One executed group's answers.
    Result(ShardResult),
    /// Final accounting, sent once as the worker exits.
    Done(ShardDone),
}

/// Plain-data execution context of one shard. Everything the shard
/// reads per group travels inside [`WorkItem::state`]; the context
/// only fixes run-wide constants (the arena bucket is pinned at
/// prepare time — rebuilt plans are budget-clamped to keep fitting
/// it).
#[derive(Debug, Clone, Copy)]
pub struct ShardCtx {
    /// This worker's shard index.
    pub shard_id: usize,
    /// Dataset feature width (arena pool key; stable across epochs).
    pub feat_dim: usize,
    /// Dense-buffer bucket (n_pad) every plan must fit — also the
    /// node cap for synthesized cold plans.
    pub bucket: usize,
    /// Prefetch-ring depth (dense buffers in flight per drain).
    pub ring_depth: usize,
    /// Top-k PPR budget for cold-plan synthesis.
    pub cold_aux: usize,
    /// Forward backend this shard builds at startup. The service
    /// probe-builds the kind before spawning workers, so construction
    /// here cannot fail for a validated config.
    pub executor: ExecutorKind,
    /// Byte budget of the shard's plan-residency LRU, used only when a
    /// snapshot is store-backed (lazy). 0 means "minimum": the LRU
    /// still keeps one plan so anything can execute.
    pub store_budget: usize,
    /// Cooperative serving (DESIGN.md §15): enables cross-query fetch
    /// sharing — feature rows of nodes appearing in several co-drained
    /// groups are materialized once and copied into the other fills.
    pub cooperative: bool,
}

/// Features-only fill for the CPU executors. The sparse forward reads
/// edge topology zero-copy from the plan and consumes exactly
/// `x[..n * feat]`, so the dense adjacency/labels/mask of a full
/// `materialize` would be dead work on the serving hot path
/// (O(n_pad²) zeroing per group). A PJRT executor swap would restore
/// full materialization here — that is the only change needed.
fn fill_features(
    ds: &Dataset,
    nodes: &[u32],
    num_outputs: usize,
    buf: &mut DenseBatch,
) {
    let n = nodes.len();
    assert!(
        n <= buf.n_pad,
        "batch of {n} nodes exceeds bucket {}",
        buf.n_pad
    );
    for (i, &u) in nodes.iter().enumerate() {
        ds.node_features_into(u, &mut buf.x[i * buf.feat..(i + 1) * buf.feat]);
    }
    buf.num_real = n;
    buf.num_outputs = num_outputs;
}

/// Fetch-sharing fill (cooperative mode, DESIGN.md §15): rows already
/// materialized by the drain's shared-row pass are copied instead of
/// re-read. Bit-identical to [`fill_features`] — a feature row is a
/// pure function of (snapshot, node), and `shared` is keyed by the
/// snapshot epoch, so groups pinned to different epochs never share.
fn fill_features_shared(
    ds: &Dataset,
    nodes: &[u32],
    num_outputs: usize,
    buf: &mut DenseBatch,
    shared: &HashMap<(u64, u32), Vec<f32>>,
    epoch: u64,
) {
    let n = nodes.len();
    assert!(
        n <= buf.n_pad,
        "batch of {n} nodes exceeds bucket {}",
        buf.n_pad
    );
    for (i, &u) in nodes.iter().enumerate() {
        let dst = &mut buf.x[i * buf.feat..(i + 1) * buf.feat];
        if let Some(row) = shared.get(&(epoch, u)) {
            dst.copy_from_slice(row);
        } else {
            ds.node_features_into(u, dst);
        }
    }
    buf.num_real = n;
    buf.num_outputs = num_outputs;
}

/// The node list a drained item will materialize — mirrors the fill
/// closure's source selection (faulted payload / CoW cache / cold
/// memo) so the shared-row pass counts exactly what the fills read.
fn item_nodes<'a>(
    item: &'a WorkItem,
    resolved: Option<&'a Arc<PlanPayload>>,
    cold_plans: &'a HashMap<(u32, u64), ColdPlan>,
) -> &'a [u32] {
    match &item.work {
        Work::Cached(_) if resolved.is_some() => &resolved.unwrap().nodes,
        Work::Cached(pid) => item.state.cache.batch_nodes(*pid as usize),
        Work::Cold(node) => &cold_plans[&(*node, item.epoch)].nodes,
    }
}

fn execute_one(
    ctx: &ShardCtx,
    item: &WorkItem,
    cold_plans: &HashMap<(u32, u64), ColdPlan>,
    resolved: Option<&PlanPayload>,
    buf: &DenseBatch,
    exec: &dyn Executor,
    scratch: &mut ExecScratch,
) -> ShardResult {
    let t = Instant::now();
    let state = &item.state;
    let n = buf.num_real;
    let classes = state.meta.classes;
    let (edge_src, edge_dst, weights) = match &item.work {
        // a store-backed (lazy) snapshot has the payload faulted into
        // `resolved`; a warm snapshot reads the CoW cache zero-copy
        Work::Cached(_) if resolved.is_some() => {
            let p = resolved.unwrap();
            (
                p.edge_src.as_slice(),
                p.edge_dst.as_slice(),
                p.weights.as_slice(),
            )
        }
        Work::Cached(pid) => {
            let p = *pid as usize;
            (
                state.cache.edge_src_of(p),
                state.cache.edge_dst_of(p),
                state.cache.edge_weights_of(p),
            )
        }
        Work::Cold(node) => {
            let cp = &cold_plans[&(*node, item.epoch)];
            (
                cp.edge_src.as_slice(),
                cp.edge_dst.as_slice(),
                cp.weights.as_slice(),
            )
        }
    };
    let view = PlanView {
        n,
        edge_src,
        edge_dst,
        weights,
    };
    let mut out_logits = Vec::new();
    exec.forward(
        &state.meta,
        &state.model,
        &view,
        &buf.x[..n * state.meta.feat],
        scratch,
        &mut out_logits,
    );
    out_logits.truncate(buf.num_outputs * classes);
    let outcomes = item
        .queries
        .iter()
        .map(|q| {
            let start = q.pos as usize * classes;
            let pred = argmax(&out_logits[start..start + classes]);
            QueryOutcome {
                id: q.id,
                node: q.node,
                pred: pred as u16,
                correct: pred == state.ds.labels[q.node as usize] as usize,
            }
        })
        .collect();
    ShardResult {
        shard_id: ctx.shard_id,
        gid: item.gid,
        key: item.key,
        epoch: item.epoch,
        outcomes,
        out_logits,
        num_outputs: buf.num_outputs,
        batch_nodes: n,
        exec_s: t.elapsed().as_secs_f64(),
    }
}

/// Shard worker loop: drain up to [`MAX_DRAIN`] pending groups, stream
/// them through the prefetch ring (materialize overlapped with
/// execute), send one [`ShardResult`] per group, repeat until the work
/// channel closes; then report [`ShardDone`]. Cold plans are memoized
/// per **(node, epoch)** — a delta that publishes a new snapshot makes
/// the next cold query for the node synthesize against the new graph,
/// while an in-flight old-epoch group still reads its own synthesis.
///
/// Tracing: the worker owns two event buffers — one on the execute
/// side (cold synthesis + forward spans) and one behind a mutex for
/// the fill closure, which [`run_prefetched`] runs on the materialize
/// thread. Both are group-scoped (`gid`), so the offline assembler
/// attaches their spans to every rider of the group.
pub fn shard_worker(
    ctx: ShardCtx,
    rx: Receiver<WorkItem>,
    tx: Sender<ShardMsg>,
    trace: Tracer,
) {
    let sh = ctx.shard_id as u32;
    let traced = trace.enabled();
    let mut tb = trace.buffer();
    let fill_tb = std::sync::Mutex::new(trace.buffer());
    // one backend + one forward scratch per shard, alive for the whole
    // worker: the steady-state forward allocates nothing
    let exec: Box<dyn Executor> = ctx
        .executor
        .build()
        .expect("executor kind validated before shard spawn");
    let mut scratch = ExecScratch::new();
    let mut scratch_sized = false;
    let mut arena = BatchArena::new(ctx.feat_dim);
    let mut cold_plans: HashMap<(u32, u64), ColdPlan> = HashMap::new();
    let mut cold_order: VecDeque<(u32, u64)> = VecDeque::new();
    let mut ws = PushWorkspace::new(0);
    let push_cfg = PushConfig::default();
    // plan-residency LRU, built on the first store-backed item so warm
    // deployments pay nothing for it
    let mut residency: Option<PlanResidency> = None;
    let mut wait_s = 0.0;
    let mut consume_s = 0.0;
    let mut drains = 0u64;
    let mut shared_row_bytes = 0u64;
    loop {
        let first = match rx.recv() {
            Ok(w) => w,
            Err(_) => break,
        };
        let mut items = vec![first];
        while items.len() < MAX_DRAIN {
            match rx.try_recv() {
                Ok(w) => items.push(w),
                Err(_) => break,
            }
        }
        // synthesize any first-seen (node, epoch) cold plans up front
        // so the ring closures below only read the memo
        for item in &items {
            if let Work::Cold(node) = item.work {
                let key = (node, item.epoch);
                if !cold_plans.contains_key(&key) {
                    tb.enter(Stage::ColdSynth, NO_QUERY, item.gid, sh);
                    let ds = &item.state.ds;
                    ws.ensure(ds.graph.num_nodes());
                    let cp = synthesize_cold(
                        ds,
                        node,
                        ctx.cold_aux,
                        ctx.bucket,
                        &push_cfg,
                        &mut ws,
                    );
                    cold_plans.insert(key, cp);
                    cold_order.push_back(key);
                    tb.exit(Stage::ColdSynth, NO_QUERY, item.gid, sh);
                }
            }
        }
        // fault store-backed payloads through the residency LRU before
        // the ring runs: the fill closure executes on the materialize
        // thread, which cannot borrow the LRU mutably. Resolved Arcs
        // pin evicted payloads for the rest of the drain.
        let mut resolved: Vec<Option<Arc<PlanPayload>>> = vec![None; items.len()];
        for (i, item) in items.iter().enumerate() {
            let Work::Cached(pid) = item.work else {
                continue;
            };
            if !item.state.lazy() {
                continue;
            }
            let store = item.state.store.as_ref().expect("lazy implies a store");
            let res = residency
                .get_or_insert_with(|| PlanResidency::new(ctx.store_budget.max(1)));
            let (payload, blob_bytes) = res
                .get_or_fault(pid, store)
                .expect("plan store fault failed (blob missing or corrupt)");
            if blob_bytes > 0 {
                tb.instant(Stage::StoreFault, NO_QUERY, item.gid, sh, blob_bytes);
            }
            resolved[i] = Some(payload);
        }
        if !scratch_sized {
            // size once from the bucket (the largest batch this shard
            // can see); edge-proportional buffers grow on demand and
            // stabilize within the first drains
            let st = &items[0].state;
            scratch =
                ExecScratch::for_meta(&st.meta, &st.model, ctx.bucket, 4 * ctx.bucket);
            scratch_sized = true;
        }
        // cooperative fetch sharing (DESIGN.md §15): count node
        // occurrences across the co-drained groups; any row needed by
        // ≥2 groups of the same snapshot epoch is materialized once
        // here and copied by their fills (features are a pure function
        // of (snapshot, node), so sharing is bit-identical)
        let mut shared: HashMap<(u64, u32), Vec<f32>> = HashMap::new();
        if ctx.cooperative && items.len() >= 2 {
            let feat = ctx.feat_dim;
            let mut seen: HashMap<(u64, u32), u32> = HashMap::new();
            let mut ds_of: HashMap<u64, &Dataset> = HashMap::new();
            for (i, item) in items.iter().enumerate() {
                ds_of
                    .entry(item.state.epoch)
                    .or_insert_with(|| item.state.ds.as_ref());
                for &u in item_nodes(item, resolved[i].as_ref(), &cold_plans) {
                    *seen.entry((item.state.epoch, u)).or_insert(0) += 1;
                }
            }
            for (&(ep, u), &c) in &seen {
                if c >= 2 {
                    let mut row = vec![0.0f32; feat];
                    ds_of[&ep].node_features_into(u, &mut row);
                    shared.insert((ep, u), row);
                    shared_row_bytes += (c as u64 - 1) * (feat as u64) * 4;
                }
            }
        }
        let shared_ref = &shared;
        let order: Vec<usize> = (0..items.len()).collect();
        let depth = ctx.ring_depth.max(1).min(items.len());
        let ring = arena.acquire_many(ctx.bucket, depth);
        let items_ref = &items;
        let cold_ref = &cold_plans;
        let resolved_ref = &resolved;
        let fill_tb_ref = &fill_tb;
        let (stats, ring) = run_prefetched(
            &order,
            ring,
            |i, buf| {
                let item = &items_ref[i];
                if traced {
                    if let Ok(mut t) = fill_tb_ref.lock() {
                        t.enter(Stage::Fill, NO_QUERY, item.gid, sh);
                    }
                }
                let (nodes, num_outputs): (&[u32], usize) = match &item.work {
                    Work::Cached(_) if resolved_ref[i].is_some() => {
                        let p = resolved_ref[i].as_ref().unwrap();
                        (&p.nodes, p.num_outputs)
                    }
                    Work::Cached(pid) => {
                        let p = *pid as usize;
                        (
                            item.state.cache.batch_nodes(p),
                            item.state.cache.num_outputs(p),
                        )
                    }
                    Work::Cold(node) => {
                        (&cold_ref[&(*node, item.epoch)].nodes, 1)
                    }
                };
                if shared_ref.is_empty() {
                    fill_features(&item.state.ds, nodes, num_outputs, buf);
                } else {
                    fill_features_shared(
                        &item.state.ds,
                        nodes,
                        num_outputs,
                        buf,
                        shared_ref,
                        item.state.epoch,
                    );
                }
                if traced {
                    if let Ok(mut t) = fill_tb_ref.lock() {
                        t.exit(Stage::Fill, NO_QUERY, item.gid, sh);
                    }
                }
            },
            |i, buf| {
                let item = &items_ref[i];
                tb.enter(Stage::Forward, NO_QUERY, item.gid, sh);
                let result = execute_one(
                    &ctx,
                    item,
                    cold_ref,
                    resolved_ref[i].as_deref(),
                    buf,
                    exec.as_ref(),
                    &mut scratch,
                );
                tb.exit(Stage::Forward, NO_QUERY, item.gid, sh);
                let _ = tx.send(ShardMsg::Result(result));
            },
        );
        arena.release_many(ring);
        // items drop here — releasing their pinned snapshots promptly
        drop(items);
        // FIFO-bound the cold memo AFTER the drain: evicting mid-drain
        // could drop a plan another item of this drain still reads.
        // The cap is exceeded by at most one drain's worth of plans.
        while cold_plans.len() > MAX_COLD_PLANS {
            match cold_order.pop_front() {
                Some(old) => {
                    cold_plans.remove(&old);
                }
                None => break,
            }
        }
        wait_s += stats.wait_s;
        consume_s += stats.consume_s;
        drains += 1;
    }
    let _ = tx.send(ShardMsg::Done(ShardDone {
        shard_id: ctx.shard_id,
        wait_s,
        consume_s,
        drains,
        arena_bytes: arena.memory_bytes(),
        arena_allocations: arena.allocations(),
        store_faults: residency.as_ref().map(|r| r.faults).unwrap_or(0),
        resident_bytes: residency
            .as_ref()
            .map(|r| r.resident_bytes() as u64)
            .unwrap_or(0),
        shared_row_bytes,
    }));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batching::{BatchGenerator, NodeWiseIbmb};
    use crate::datasets::{sbm, DatasetSpec};
    use crate::serve::service::build_initial_state;
    use crate::serve::ServeConfig;

    fn setup() -> (Dataset, CowCache) {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 21);
        let mut g = NodeWiseIbmb {
            aux_per_output: 6,
            max_outputs_per_batch: 40,
            node_budget: 256,
            ..Default::default()
        };
        let mut rng = Rng::new(9);
        let out = ds.splits.train.clone();
        let cache = CowCache::from_plans(&g.plan(&ds, &out, &mut rng));
        (ds, cache)
    }

    #[test]
    fn reference_artifact_layouts_parse_for_all_models() {
        for model in ["gcn", "sage", "gat"] {
            let meta = reference_artifact(model, 16, 4, 8, 2, 2, 64);
            assert_eq!(meta.kind, "infer");
            assert_eq!(meta.n_pad, 64);
            // contiguous offsets summing to param_count (the manifest
            // invariant Manifest::parse enforces)
            let mut off = 0usize;
            for p in &meta.params {
                assert_eq!(p.offset, off, "{model}: {}", p.name);
                assert_eq!(p.size, p.shape.iter().product::<usize>());
                off += p.size;
            }
            assert_eq!(off, meta.param_count, "{model}");
            // a state initialized from it drives the reference forward
            let state = ModelState::init(&meta, 5);
            assert_eq!(state.params.len(), meta.param_count);
            assert!(state.tensor(&meta, "l0.w").is_some());
            assert!(state.tensor(&meta, "l1.b").is_some());
        }
    }

    #[test]
    fn cold_plan_synthesis_respects_budget_and_root_first() {
        let (ds, _) = setup();
        let mut ws = PushWorkspace::new(ds.graph.num_nodes());
        let push = PushConfig::default();
        let cp = synthesize_cold(&ds, 5, 8, 64, &push, &mut ws);
        assert_eq!(cp.node, 5);
        assert_eq!(cp.nodes[0], 5, "query node is output 0");
        assert!(cp.nodes.len() <= 9, "aux budget respected");
        assert_eq!(cp.edge_src.len(), cp.weights.len());
        assert_eq!(cp.edge_dst.len(), cp.weights.len());
        let n = cp.nodes.len() as u32;
        assert!(cp.edge_src.iter().chain(&cp.edge_dst).all(|&v| v < n));
        // a tight node budget caps the plan below the aux budget
        let tight = synthesize_cold(&ds, 7, 32, 4, &push, &mut ws);
        assert!(tight.nodes.len() <= 4);
        assert_eq!(tight.nodes[0], 7);
    }

    #[test]
    fn placement_covers_all_plans_and_nodes_at_any_shard_count() {
        let (ds, cache) = setup();
        let mut rng = Rng::new(4);
        let p = Placement::build(&ds, &cache, PLACEMENT_CELLS, &mut rng);
        assert_eq!(p.num_nodes(), ds.graph.num_nodes());
        assert_eq!(p.num_plans(), cache.len());
        for shards in [1usize, 2, 4, 7] {
            for pid in 0..cache.len() as u32 {
                assert!(p.shard_of_plan(pid, shards) < shards);
            }
            for u in 0..ds.graph.num_nodes() as u32 {
                assert!(p.shard_of_node(u, shards) < shards);
            }
        }
        // the fold is consistent: same cell → same shard
        for pid in 0..cache.len() as u32 {
            assert_eq!(
                p.shard_of_plan(pid, 2),
                p.plan_cell[pid as usize] as usize % 2
            );
        }
    }

    #[test]
    fn extended_placement_inherits_neighbor_cells() {
        let (ds, cache) = setup();
        let mut rng = Rng::new(4);
        let p = Placement::build(&ds, &cache, PLACEMENT_CELLS, &mut rng);
        // append two nodes: one wired to node 0, one isolated
        let mut dg = crate::graph::DynamicGraph::new(ds.graph.clone());
        let n0 = ds.graph.num_nodes() as u32;
        dg.apply(&crate::graph::GraphDelta {
            add_node_labels: vec![0, 1],
            add_edges: vec![(n0, 0)],
            ..Default::default()
        })
        .unwrap();
        let grown = dg.snapshot();
        let q = p.extended(&grown);
        assert_eq!(q.num_nodes(), ds.graph.num_nodes() + 2);
        assert_eq!(q.num_plans(), p.num_plans());
        // wired node adopts its neighbor's cell; old nodes unchanged
        assert_eq!(q.node_cell[n0 as usize], p.node_cell[0]);
        assert_eq!(q.node_cell[..p.num_nodes()], p.node_cell[..]);
    }

    #[test]
    fn replica_routes_fold_like_cells_and_clear() {
        let (ds, cache) = setup();
        let mut rng = Rng::new(4);
        let mut p = Placement::build(&ds, &cache, PLACEMENT_CELLS, &mut rng);
        assert_eq!(p.num_replicas(), 0);
        assert_eq!(p.replica_shard_of_plan(0, 2), None);
        p.set_replica(0, 5);
        assert_eq!(p.replica_shard_of_plan(0, 2), Some(1));
        assert_eq!(p.replica_shard_of_plan(0, 4), Some(1));
        assert_eq!(p.num_replicas(), 1);
        assert_eq!(p.replicas().collect::<Vec<_>>(), vec![(0, 5)]);
        // cloning (the epoch-swap path) carries routes; clearing drops
        // them without touching the original
        let mut q = p.clone();
        assert_eq!(q.num_replicas(), 1);
        q.clear_replicas();
        assert_eq!(q.replica_shard_of_plan(0, 2), None);
        assert_eq!(p.num_replicas(), 1);
    }

    #[test]
    fn cooperative_fill_shares_rows_and_preserves_logits() {
        use std::sync::mpsc;
        let (ds, cache) = setup();
        let cfg = ServeConfig::default();
        let (cell, meta, _model) =
            build_initial_state(Arc::new(ds), cache, &cfg, None);
        let state = cell.load();
        // two groups over the same plan in one drain: every row of the
        // second fill can be shared
        let run = |cooperative: bool| -> (Vec<Vec<f32>>, u64) {
            let (work_tx, work_rx) = mpsc::channel::<WorkItem>();
            let (res_tx, res_rx) = mpsc::channel::<ShardMsg>();
            for gid in 0..2u64 {
                let node = state.cache.output_nodes(0)[0];
                work_tx
                    .send(WorkItem {
                        gid,
                        key: PlanKey::Cached(0),
                        epoch: 0,
                        state: state.clone(),
                        work: Work::Cached(0),
                        queries: vec![QueryTicket {
                            id: gid,
                            node,
                            pos: 0,
                        }],
                    })
                    .unwrap();
            }
            // close the channel first so the worker drains both items
            // in a single ring run and then exits
            drop(work_tx);
            let ctx = ShardCtx {
                shard_id: 0,
                feat_dim: state.ds.feat_dim,
                bucket: meta.n_pad,
                ring_depth: 2,
                cold_aux: 8,
                executor: ExecutorKind::Blocked,
                store_budget: 0,
                cooperative,
            };
            shard_worker(ctx, work_rx, res_tx, Tracer::disabled());
            let mut logits = Vec::new();
            let mut shared = 0u64;
            for msg in res_rx.iter() {
                match msg {
                    ShardMsg::Result(r) => logits.push(r.out_logits),
                    ShardMsg::Done(d) => shared = d.shared_row_bytes,
                }
            }
            (logits, shared)
        };
        let (base, s0) = run(false);
        let (coop, s1) = run(true);
        assert_eq!(s0, 0, "non-cooperative drains never share");
        assert!(s1 > 0, "identical co-drained groups must share rows");
        assert_eq!(base, coop, "fetch sharing is bit-identical");
    }

    #[test]
    fn worker_executes_groups_and_reports_done() {
        use std::sync::mpsc;
        let (ds, cache) = setup();
        let cfg = ServeConfig::default();
        let (cell, meta, _model) =
            build_initial_state(Arc::new(ds), cache, &cfg, None);
        let state = cell.load();
        let cache_len = state.cache.len();
        let (work_tx, work_rx) = mpsc::channel::<WorkItem>();
        let (res_tx, res_rx) = mpsc::channel::<ShardMsg>();
        std::thread::scope(|scope| {
            let ctx = ShardCtx {
                shard_id: 0,
                feat_dim: state.ds.feat_dim,
                bucket: meta.n_pad,
                ring_depth: 2,
                cold_aux: 8,
                executor: ExecutorKind::Blocked,
                store_budget: 0,
                cooperative: false,
            };
            scope.spawn(move || {
                shard_worker(ctx, work_rx, res_tx, Tracer::disabled())
            });
            // one group per cached plan, one query each (its first
            // output), plus one cold group for an uncovered node
            for pid in 0..cache_len as u32 {
                let node = state.cache.output_nodes(pid as usize)[0];
                work_tx
                    .send(WorkItem {
                        gid: pid as u64,
                        key: PlanKey::Cached(pid),
                        epoch: 0,
                        state: state.clone(),
                        work: Work::Cached(pid),
                        queries: vec![QueryTicket {
                            id: pid as u64,
                            node,
                            pos: 0,
                        }],
                    })
                    .unwrap();
            }
            let cold_node = (0..state.ds.graph.num_nodes() as u32)
                .find(|&u| state.index.lookup(u).is_none())
                .expect("tiny split leaves cold nodes");
            work_tx
                .send(WorkItem {
                    gid: 9999,
                    key: PlanKey::Cold(0),
                    epoch: 0,
                    state: state.clone(),
                    work: Work::Cold(cold_node),
                    queries: vec![QueryTicket {
                        id: 10_000,
                        node: cold_node,
                        pos: 0,
                    }],
                })
                .unwrap();
            drop(work_tx);
            let mut results = 0usize;
            let mut done = 0usize;
            for msg in res_rx.iter() {
                match msg {
                    ShardMsg::Result(r) => {
                        results += 1;
                        assert_eq!(r.outcomes.len(), 1);
                        assert_eq!(r.epoch, 0);
                        assert_eq!(
                            r.out_logits.len(),
                            r.num_outputs * state.meta.classes
                        );
                        assert!(r.out_logits.iter().all(|v| v.is_finite()));
                        assert!(
                            (r.outcomes[0].pred as usize) < state.meta.classes
                        );
                    }
                    ShardMsg::Done(d) => {
                        done += 1;
                        assert!(d.drains >= 1);
                        assert!(d.arena_allocations >= 1);
                        assert!(d.arena_bytes > 0);
                    }
                }
            }
            assert_eq!(results, cache_len + 1);
            assert_eq!(done, 1);
        });
    }
}
