//! Mini-batched inference driver: stream any batching method's plans
//! through the AOT infer executable with ring-prefetched
//! materialization into arena-reused buffers.

use anyhow::{anyhow, Result};

use crate::batching::{BatchArena, BatchCache, BatchGenerator};
use crate::datasets::Dataset;
use crate::exec::{ExecScratch, Executor, PlanView};
use crate::pipeline::run_prefetched;
use crate::runtime::{ArtifactMeta, ModelState, Runtime, StepMetrics};
use crate::util::{Rng, Timer};

/// Outcome of a batched inference pass.
#[derive(Debug, Clone, Copy)]
pub struct InferReport {
    pub accuracy: f64,
    pub mean_loss: f64,
    /// End-to-end seconds (plan sampling if stochastic + materialize +
    /// execute; preprocessing of fixed methods is NOT included,
    /// matching the paper's preprocess/inference column split).
    pub seconds: f64,
    /// Batches executed.
    pub batches: usize,
    /// Real nodes / padded slots (bucket efficiency).
    pub pad_utilization: f64,
    /// Cache bytes for the plan set used.
    pub cache_bytes: usize,
    /// Prefetch overlap for this pass (1.0 = materialization fully
    /// hidden behind execution).
    pub overlap_ratio: f64,
}

/// Run inference over `eval_nodes` with a trained `state`.
///
/// Fixed methods pass their prebuilt `cache`; stochastic methods pass
/// `None` and plan inside the timed region (their real cost). Dense
/// buffers are drawn from `arena` — shared with training when called
/// from the epoch loop — and `depth` sets the prefetch ring size, so
/// repeated passes perform zero tensor allocations after the first.
#[allow(clippy::too_many_arguments)]
pub fn infer_with_batches(
    rt: &mut Runtime,
    ds: &Dataset,
    model: &str,
    state: &ModelState,
    generator: &mut dyn BatchGenerator,
    cache: Option<&BatchCache>,
    eval_nodes: &[u32],
    rng: &mut Rng,
    arena: &mut BatchArena,
    depth: usize,
) -> Result<InferReport> {
    let t = Timer::start();
    let owned_cache;
    let cache = match cache {
        Some(c) => c,
        None => {
            owned_cache = BatchCache::build(&generator.plan(ds, eval_nodes, rng));
            &owned_cache
        }
    };
    anyhow::ensure!(!cache.is_empty(), "no batches for inference");
    let max_nodes = cache.max_batch_nodes();
    let meta = rt
        .manifest
        .bucket_meta(model, "infer", max_nodes)
        .ok_or_else(|| {
            anyhow!("no infer bucket for {model} fitting {max_nodes} nodes")
        })?
        .clone();
    anyhow::ensure!(
        arena.feat() == meta.feat,
        "arena feat {} != artifact feat {}",
        arena.feat(),
        meta.feat
    );
    // compile before the loop so the timing reflects steady state
    rt.executable(&meta.id)?;

    let order: Vec<usize> = (0..cache.len()).collect();
    let ring = arena.acquire_many(meta.n_pad, depth.max(1));
    let mut total = StepMetrics::default();
    let mut real_nodes = 0usize;
    let mut err: Option<anyhow::Error> = None;
    let (stats, ring) = run_prefetched(
        &order,
        ring,
        |i, buf| cache.materialize_into(ds, i, buf),
        |_, buf| {
            if err.is_some() {
                return;
            }
            match rt.infer_step(&meta, state, buf) {
                Ok(m) => {
                    total.merge(&m);
                    real_nodes += buf.num_real;
                }
                Err(e) => err = Some(e),
            }
        },
    );
    arena.release_many(ring);
    if let Some(e) = err {
        return Err(e);
    }
    Ok(InferReport {
        accuracy: total.accuracy(),
        mean_loss: total.mean_loss(),
        seconds: t.elapsed_s(),
        batches: cache.len(),
        pad_utilization: real_nodes as f64 / (cache.len() * meta.n_pad) as f64,
        cache_bytes: cache.memory_bytes(),
        overlap_ratio: stats.overlap_ratio(),
    })
}

/// Run inference over a prebuilt plan cache entirely on the host
/// through a pluggable [`Executor`] backend — no AOT artifact lookup,
/// no PJRT round-trip, no bucket padding (each batch executes at its
/// real node count, so `pad_utilization` is 1.0 and `overlap_ratio`
/// is 0.0: the forward is synchronous with feature gathering).
///
/// Loss and accuracy are computed on the host from the plan's output
/// rows — the executor contract puts a plan's output nodes in the
/// first `num_outputs` rows, exactly as the serve shards consume them.
pub fn infer_with_executor(
    exec: &dyn Executor,
    meta: &ArtifactMeta,
    ds: &Dataset,
    state: &ModelState,
    cache: &BatchCache,
    scratch: &mut ExecScratch,
) -> Result<InferReport> {
    anyhow::ensure!(!cache.is_empty(), "no batches for inference");
    anyhow::ensure!(
        meta.feat == ds.feat_dim && meta.classes == ds.num_classes,
        "artifact shape ({}, {}) != dataset shape ({}, {})",
        meta.feat,
        meta.classes,
        ds.feat_dim,
        ds.num_classes
    );
    let t = Timer::start();
    let mut x: Vec<f32> = Vec::new();
    let mut logits: Vec<f32> = Vec::new();
    let mut correct = 0usize;
    let mut loss_sum = 0f64;
    let mut outputs = 0usize;
    for i in 0..cache.len() {
        let n = cache.gather_features_into(ds, i, &mut x);
        let view = PlanView {
            n,
            edge_src: cache.edge_src_of(i),
            edge_dst: cache.edge_dst_of(i),
            weights: cache.edge_weights_of(i),
        };
        exec.forward(meta, state, &view, &x[..n * meta.feat], scratch, &mut logits);
        for (j, &u) in cache.output_nodes(i).iter().enumerate() {
            let row = &logits[j * meta.classes..(j + 1) * meta.classes];
            let label = ds.labels[u as usize] as usize;
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let lse: f32 = row.iter().map(|&v| (v - m).exp()).sum::<f32>().ln() + m;
            loss_sum += (lse - row[label]) as f64;
            let pred = row
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(c, _)| c)
                .unwrap();
            correct += usize::from(pred == label);
        }
        outputs += cache.num_outputs(i);
    }
    Ok(InferReport {
        accuracy: correct as f64 / outputs.max(1) as f64,
        mean_loss: loss_sum / outputs.max(1) as f64,
        seconds: t.elapsed_s(),
        batches: cache.len(),
        pad_utilization: 1.0,
        cache_bytes: cache.memory_bytes(),
        overlap_ratio: 0.0,
    })
}
