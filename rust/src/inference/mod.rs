//! Inference drivers.
//!
//! * [`driver`] — mini-batched inference through the AOT executables:
//!   any [`crate::batching::BatchGenerator`]'s batches, prefetched and
//!   padded, produce per-output-node predictions (the paper's Fig. 2 /
//!   Table 7 "Inference" and "Same method" columns).
//!   [`driver::infer_with_executor`] runs the same plan caches through
//!   a pluggable [`crate::exec::Executor`] backend on the host instead
//!   (no padding, no runtime round-trip).
//! * [`fullgraph`] — an exact sparse forward pass over the *whole*
//!   graph on the host, standing in for the paper's chunked full-batch
//!   GPU inference (Table 7 "Full-batch" column). Also serves as a
//!   numerical cross-check of the AOT artifacts: on a single batch the
//!   two paths must agree to f32 tolerance.

pub mod driver;
pub mod fullgraph;

pub use driver::{infer_with_batches, infer_with_executor, InferReport};
