//! Exact sparse full-graph forward pass on the host CPU.
//!
//! Reimplements the L2 models (GCN / GraphSAGE / GAT) over CSR edges in
//! plain Rust. Two roles:
//!
//! 1. The **"Full-batch"** baseline of Table 7 / Fig. 2 — exact
//!    inference over the entire graph, which is accurate but slow and
//!    memory-hungry, exactly the trade-off the paper reports.
//! 2. A **cross-language oracle**: on a single mini-batch subgraph this
//!    must match the AOT artifact's `infer_step` to f32 tolerance
//!    (integration test `rust/tests/parity.rs`), validating the whole
//!    Python→HLO→PJRT path end to end.

use crate::datasets::Dataset;
use crate::exec::{ExecScratch, Executor, PlanView};
use crate::runtime::{ArtifactMeta, ModelState};

/// Borrowed sparse graph view (full graph or batch subgraph).
#[derive(Debug, Clone, Copy)]
pub struct SparseGraphRef<'a> {
    pub n: usize,
    pub edge_src: &'a [u32],
    pub edge_dst: &'a [u32],
    pub weights: &'a [f32],
}

fn tensor<'a>(state: &'a ModelState, meta: &ArtifactMeta, name: &str) -> &'a [f32] {
    state
        .tensor(meta, name)
        .unwrap_or_else(|| panic!("missing param {name}"))
}

/// dst-accumulating sparse aggregation: `out[d] += w * h[s]`.
fn spmm(g: &SparseGraphRef, h: &[f32], dim: usize, out: &mut [f32]) {
    out.fill(0.0);
    for ((&s, &d), &w) in g
        .edge_src
        .iter()
        .zip(g.edge_dst)
        .zip(g.weights)
    {
        let (s, d) = (s as usize * dim, d as usize * dim);
        let (src, dst) = (&h[s..s + dim], &mut out[d..d + dim]);
        for (o, &x) in dst.iter_mut().zip(src) {
            *o += w * x;
        }
    }
}

/// Row-major dense `x [n, in] @ w [in, out] + b`.
fn linear(x: &[f32], n: usize, d_in: usize, w: &[f32], b: &[f32], d_out: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * d_out];
    for i in 0..n {
        let xi = &x[i * d_in..(i + 1) * d_in];
        let oi = &mut out[i * d_out..(i + 1) * d_out];
        oi.copy_from_slice(&b[..d_out]);
        for (k, &xv) in xi.iter().enumerate() {
            if xv != 0.0 {
                let wr = &w[k * d_out..(k + 1) * d_out];
                for (o, &wv) in oi.iter_mut().zip(wr) {
                    *o += xv * wv;
                }
            }
        }
    }
    out
}

fn layernorm_relu(x: &mut [f32], n: usize, dim: usize, g: &[f32], b: &[f32]) {
    const EPS: f32 = 1e-5;
    for i in 0..n {
        let row = &mut x[i * dim..(i + 1) * dim];
        let mean: f32 = row.iter().sum::<f32>() / dim as f32;
        let var: f32 =
            row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / dim as f32;
        let rstd = (var + EPS).sqrt().recip();
        for (j, v) in row.iter_mut().enumerate() {
            *v = ((*v - mean) * rstd * g[j] + b[j]).max(0.0);
        }
    }
}

fn gat_layer(
    meta: &ArtifactMeta,
    state: &ModelState,
    l: usize,
    g: &SparseGraphRef,
    h: &[f32],
    d_in: usize,
) -> (Vec<f32>, usize) {
    let last = l == meta.layers - 1;
    let heads = if last { 1 } else { meta.heads };
    let w = tensor(state, meta, &format!("l{l}.w"));
    let b = tensor(state, meta, &format!("l{l}.b"));
    let a_src = tensor(state, meta, &format!("l{l}.a_src"));
    let a_dst = tensor(state, meta, &format!("l{l}.a_dst"));
    let d_total = b.len();
    let dh = d_total / heads;
    let hw = linear(h, g.n, d_in, w, &vec![0.0; d_total], d_total);
    let mut out = vec![0.0f32; g.n * d_total];

    // per-destination softmax over incoming edges, per head
    for hd in 0..heads {
        // s_row[i] = hw_i . a_src[hd] (row/attending side = destination),
        // s_col[j] = hw_j . a_dst[hd] (column/source side) — matching the
        // dense kernel's scores = s_src + s_dst^T with row = dst.
        let mut s_row = vec![0.0f32; g.n];
        let mut s_col = vec![0.0f32; g.n];
        for i in 0..g.n {
            let v = &hw[i * d_total + hd * dh..i * d_total + (hd + 1) * dh];
            s_row[i] = v
                .iter()
                .zip(&a_src[hd * dh..(hd + 1) * dh])
                .map(|(a, b)| a * b)
                .sum();
            s_col[i] = v
                .iter()
                .zip(&a_dst[hd * dh..(hd + 1) * dh])
                .map(|(a, b)| a * b)
                .sum();
        }
        // two passes over edges grouped by destination: max, then expsum
        let mut row_max = vec![f32::NEG_INFINITY; g.n];
        for (&s, &d) in g.edge_src.iter().zip(g.edge_dst) {
            let raw = s_row[d as usize] + s_col[s as usize];
            let sc = if raw >= 0.0 { raw } else { 0.2 * raw };
            row_max[d as usize] = row_max[d as usize].max(sc);
        }
        let mut row_sum = vec![0.0f32; g.n];
        let mut edge_e = vec![0.0f32; g.edge_src.len()];
        for (e, (&s, &d)) in g.edge_src.iter().zip(g.edge_dst).enumerate() {
            let raw = s_row[d as usize] + s_col[s as usize];
            let sc = if raw >= 0.0 { raw } else { 0.2 * raw };
            let v = (sc - row_max[d as usize]).exp();
            edge_e[e] = v;
            row_sum[d as usize] += v;
        }
        for (e, (&s, &d)) in g.edge_src.iter().zip(g.edge_dst).enumerate() {
            let attn = edge_e[e] / row_sum[d as usize];
            let src =
                &hw[s as usize * d_total + hd * dh..s as usize * d_total + (hd + 1) * dh];
            let dst = &mut out
                [d as usize * d_total + hd * dh..d as usize * d_total + (hd + 1) * dh];
            for (o, &x) in dst.iter_mut().zip(src) {
                *o += attn * x;
            }
        }
    }
    for i in 0..g.n {
        for j in 0..d_total {
            out[i * d_total + j] += b[j];
        }
    }
    (out, d_total)
}

/// Exact forward pass; returns logits `[n * classes]`, row-major.
/// Mirrors `python/compile/model.py::forward` with `train=False`.
pub fn forward(
    meta: &ArtifactMeta,
    state: &ModelState,
    g: &SparseGraphRef,
    x: &[f32],
) -> Vec<f32> {
    assert_eq!(x.len(), g.n * meta.feat);
    let mut h = x.to_vec();
    let mut dim = meta.feat;
    let mut agg = vec![0.0f32; g.n * dim];
    for l in 0..meta.layers {
        let (mut next, d_out) = match meta.model.as_str() {
            "gcn" => {
                if agg.len() != g.n * dim {
                    agg = vec![0.0; g.n * dim];
                }
                spmm(g, &h, dim, &mut agg);
                let w = tensor(state, meta, &format!("l{l}.w"));
                let b = tensor(state, meta, &format!("l{l}.b"));
                let d_out = b.len();
                (linear(&agg, g.n, dim, w, b, d_out), d_out)
            }
            "sage" => {
                if agg.len() != g.n * dim {
                    agg = vec![0.0; g.n * dim];
                }
                spmm(g, &h, dim, &mut agg);
                // concat [h ‖ Âh]
                let mut cat = vec![0.0f32; g.n * dim * 2];
                for i in 0..g.n {
                    cat[i * 2 * dim..i * 2 * dim + dim]
                        .copy_from_slice(&h[i * dim..(i + 1) * dim]);
                    cat[i * 2 * dim + dim..(i + 1) * 2 * dim]
                        .copy_from_slice(&agg[i * dim..(i + 1) * dim]);
                }
                let w = tensor(state, meta, &format!("l{l}.w"));
                let b = tensor(state, meta, &format!("l{l}.b"));
                let d_out = b.len();
                (linear(&cat, g.n, 2 * dim, w, b, d_out), d_out)
            }
            "gat" => gat_layer(meta, state, l, g, &h, dim),
            other => panic!("unknown model {other}"),
        };
        if l != meta.layers - 1 {
            let gm = tensor(state, meta, &format!("l{l}.ln_g"));
            let bt = tensor(state, meta, &format!("l{l}.ln_b"));
            layernorm_relu(&mut next, g.n, d_out, gm, bt);
        }
        h = next;
        dim = d_out;
    }
    h
}

/// Report of a full-graph inference run.
#[derive(Debug, Clone, Copy)]
pub struct FullGraphReport {
    pub accuracy: f64,
    pub seconds: f64,
    /// Peak transient bytes (features + two activation buffers).
    pub bytes: usize,
}

/// Exact inference over the whole dataset graph; accuracy on `eval_nodes`.
pub fn full_graph_inference(
    meta: &ArtifactMeta,
    state: &ModelState,
    ds: &Dataset,
    eval_nodes: &[u32],
) -> FullGraphReport {
    full_graph_inference_with(
        &crate::exec::ReferenceExecutor,
        meta,
        state,
        ds,
        eval_nodes,
    )
}

/// Full-graph inference through a pluggable [`Executor`] backend: the
/// whole graph is one `PlanView`, so Fig. 2's "full-batch" row exercises
/// the same kernels the serve shards run (`ibmb fig2 --executor`).
pub fn full_graph_inference_with(
    exec: &dyn Executor,
    meta: &ArtifactMeta,
    state: &ModelState,
    ds: &Dataset,
    eval_nodes: &[u32],
) -> FullGraphReport {
    let t = crate::util::Timer::start();
    let n = ds.graph.num_nodes();
    // materialize features and edges (this is the memory cost the paper
    // attributes to full-batch inference)
    let mut x = vec![0.0f32; n * ds.feat_dim];
    for u in 0..n as u32 {
        ds.node_features_into(
            u,
            &mut x[u as usize * ds.feat_dim..(u as usize + 1) * ds.feat_dim],
        );
    }
    let m = ds.graph.num_edges();
    let mut edge_src = Vec::with_capacity(m);
    let mut edge_dst = Vec::with_capacity(m);
    let mut weights = Vec::with_capacity(m);
    for u in 0..n as u32 {
        for &v in ds.graph.neighbors(u) {
            // aggregation into u from v
            edge_src.push(v);
            edge_dst.push(u);
            weights.push(ds.graph.norm_weight(u, v));
        }
    }
    let view = PlanView {
        n,
        edge_src: &edge_src,
        edge_dst: &edge_dst,
        weights: &weights,
    };
    let mut scratch = ExecScratch::new();
    let mut logits = Vec::new();
    exec.forward(meta, state, &view, &x, &mut scratch, &mut logits);
    let c = meta.classes;
    let mut correct = 0usize;
    for &u in eval_nodes {
        let row = &logits[u as usize * c..(u as usize + 1) * c];
        let pred = row
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        if pred == ds.labels[u as usize] as usize {
            correct += 1;
        }
    }
    let bytes = x.len() * 4 + (edge_src.len() + edge_dst.len()) * 4
        + weights.len() * 4
        + 2 * n * meta.hidden.max(meta.feat) * 4;
    FullGraphReport {
        accuracy: correct as f64 / eval_nodes.len().max(1) as f64,
        seconds: t.elapsed_s(),
        bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    fn toy_meta(model: &str) -> ArtifactMeta {
        // layout for feat=4, hidden=4, classes=2, layers=2, heads=2
        let params = match model {
            "gcn" => vec![
                ("l0.w", vec![4, 4]),
                ("l0.b", vec![4]),
                ("l0.ln_g", vec![4]),
                ("l0.ln_b", vec![4]),
                ("l1.w", vec![4, 2]),
                ("l1.b", vec![2]),
            ],
            "sage" => vec![
                ("l0.w", vec![8, 4]),
                ("l0.b", vec![4]),
                ("l0.ln_g", vec![4]),
                ("l0.ln_b", vec![4]),
                ("l1.w", vec![8, 2]),
                ("l1.b", vec![2]),
            ],
            "gat" => vec![
                ("l0.w", vec![4, 4]),
                ("l0.b", vec![4]),
                ("l0.a_src", vec![2, 2]),
                ("l0.a_dst", vec![2, 2]),
                ("l0.ln_g", vec![4]),
                ("l0.ln_b", vec![4]),
                ("l1.w", vec![4, 2]),
                ("l1.b", vec![2]),
                ("l1.a_src", vec![1, 2]),
                ("l1.a_dst", vec![1, 2]),
            ],
            _ => unreachable!(),
        };
        let mut entries = String::new();
        let mut off = 0usize;
        for (i, (name, shape)) in params.iter().enumerate() {
            let size: usize = shape.iter().product();
            if i > 0 {
                entries.push(',');
            }
            entries.push_str(&format!(
                r#"{{"name": "{name}", "shape": {shape:?}, "offset": {off}, "size": {size}}}"#
            ));
            off += size;
        }
        let doc = format!(
            r#"{{"version": 1, "artifacts": [{{"id": "t", "model": "{model}",
             "kind": "infer", "n_pad": 16, "feat": 4, "classes": 2,
             "hidden": 4, "layers": 2, "heads": 2, "dropout": 0.0,
             "weight_decay": 0.0, "param_count": {off},
             "params": [{entries}], "path": "t.hlo.txt"}}]}}"#
        );
        Manifest::parse(&doc).unwrap().artifacts[0].clone()
    }

    fn ring_graph(n: usize) -> (Vec<u32>, Vec<u32>, Vec<f32>) {
        // ring with self loops, uniform weights (deg 3)
        let mut src = Vec::new();
        let mut dst = Vec::new();
        let mut w = Vec::new();
        for u in 0..n as u32 {
            for v in [
                u,
                (u + 1) % n as u32,
                (u + n as u32 - 1) % n as u32,
            ] {
                src.push(v);
                dst.push(u);
                w.push(1.0 / 3.0);
            }
        }
        (src, dst, w)
    }

    #[test]
    fn forward_shapes_and_finiteness_all_models() {
        for model in ["gcn", "sage", "gat"] {
            let meta = toy_meta(model);
            let state = ModelState::init(&meta, 3);
            let n = 12;
            let (src, dst, w) = ring_graph(n);
            let g = SparseGraphRef {
                n,
                edge_src: &src,
                edge_dst: &dst,
                weights: &w,
            };
            let x: Vec<f32> = (0..n * 4).map(|i| (i as f32 * 0.37).sin()).collect();
            let out = forward(&meta, &state, &g, &x);
            assert_eq!(out.len(), n * 2, "{model}");
            assert!(out.iter().all(|v| v.is_finite()), "{model}");
        }
    }

    #[test]
    fn gcn_aggregation_uses_weights() {
        let meta = toy_meta("gcn");
        let state = ModelState::init(&meta, 4);
        let n = 8;
        let (src, dst, w) = ring_graph(n);
        let g = SparseGraphRef {
            n,
            edge_src: &src,
            edge_dst: &dst,
            weights: &w,
        };
        let x: Vec<f32> = (0..n * 4).map(|i| (i % 7) as f32).collect();
        let a = forward(&meta, &state, &g, &x);
        let w2: Vec<f32> = w.iter().map(|v| v * 2.0).collect();
        let g2 = SparseGraphRef {
            weights: &w2,
            ..g
        };
        let b = forward(&meta, &state, &g2, &x);
        assert_ne!(a, b);
    }

    #[test]
    fn gat_attention_rows_are_convex() {
        // constant value vectors propagate unchanged through attention;
        // use W = I by setting params manually is overkill — instead
        // check permutation equivariance on a symmetric ring.
        let meta = toy_meta("gat");
        let state = ModelState::init(&meta, 5);
        let n = 10;
        let (src, dst, w) = ring_graph(n);
        let g = SparseGraphRef {
            n,
            edge_src: &src,
            edge_dst: &dst,
            weights: &w,
        };
        let x: Vec<f32> = (0..n * 4).map(|i| ((i * 13 % 11) as f32) * 0.1).collect();
        let out = forward(&meta, &state, &g, &x);
        // rotate node features by one ring position => output rotates
        let mut x_rot = vec![0.0; n * 4];
        for i in 0..n {
            x_rot[((i + 1) % n) * 4..((i + 1) % n) * 4 + 4]
                .copy_from_slice(&x[i * 4..i * 4 + 4]);
        }
        let out_rot = forward(&meta, &state, &g, &x_rot);
        for i in 0..n {
            let a = &out[i * 2..i * 2 + 2];
            let b = &out_rot[((i + 1) % n) * 2..((i + 1) % n) * 2 + 2];
            for (x, y) in a.iter().zip(b) {
                assert!((x - y).abs() < 1e-4, "node {i}: {x} vs {y}");
            }
        }
    }
}
