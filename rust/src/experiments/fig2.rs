//! Fig. 2 — inference accuracy vs time per method, sweeping the
//! computational budget (aux/sampled nodes) at a fixed bucket budget.
//! One pretrained model per setting is evaluated by every method, as in
//! the paper ("the same pretrained model and varying computational
//! budgets").

use anyhow::Result;

use super::runner::{self, Env};
use crate::bench_harness::{secs, Table};
use crate::cli::Args;
use crate::config::ExpScale;
use crate::exec::{Executor, ExecutorKind};
use crate::inference::fullgraph;
use crate::util::Rng;

pub const SWEEP_METHODS: [&str; 5] = [
    "node-wise IBMB",
    "batch-wise IBMB",
    "fixed random", // "IBMB, rand batch." in the paper's Fig. 2
    "neighbor sampling",
    "shaDow",
];

pub fn run(scale: &ExpScale, args: &Args) -> Result<()> {
    let mut env = Env::load()?;
    let ds_name = args.get_or("dataset", "synth-arxiv");
    let model = args.get_or("model", "gcn");
    let ds = runner::dataset(ds_name, scale, 1);
    eprintln!(
        "[fig2] {ds_name} ({} nodes), model {model}: pretraining…",
        ds.graph.num_nodes()
    );
    let trained =
        runner::train_once(&mut env, &ds, model, "node-wise IBMB", scale, 1)?;

    let budgets = [4usize, 8, 16, 32];
    let mut table = Table::new(&[
        "method",
        "aux budget",
        "test acc (%)",
        "time (s)",
        "batches",
    ]);
    for method in SWEEP_METHODS {
        for &b in &budgets {
            let rep = runner::infer_once(
                &mut env,
                &ds,
                model,
                &trained.state,
                method,
                Some(b),
                &ds.splits.test,
                7,
            )?;
            table.row(&[
                method.to_string(),
                b.to_string(),
                format!("{:.1}", rep.accuracy * 100.0),
                secs(rep.seconds),
                rep.batches.to_string(),
            ]);
        }
    }
    // full-batch (exact sparse host inference) reference row, through
    // the selected execution backend (whole graph = one PlanView)
    let kind = ExecutorKind::from_name(args.get_or("executor", "blocked"))
        .ok_or_else(|| {
            anyhow::anyhow!("unknown --executor (expected {})", ExecutorKind::ALL_NAMES)
        })?;
    let exec = kind.build()?;
    let meta = env
        .rt
        .manifest
        .bucket_meta(model, "infer", 1)
        .unwrap()
        .clone();
    let fb = fullgraph::full_graph_inference_with(
        exec.as_ref(),
        &meta,
        &trained.state,
        &ds,
        &ds.splits.test,
    );
    table.row(&[
        format!("full-batch ({})", exec.name()),
        "-".into(),
        format!("{:.1}", fb.accuracy * 100.0),
        secs(fb.seconds),
        "1".into(),
    ]);
    table.print(&format!(
        "Fig. 2 — inference accuracy vs time ({ds_name}, {model})"
    ));
    // Pareto check: IBMB should dominate the top-left corner
    let _ = Rng::new(0);
    Ok(())
}
