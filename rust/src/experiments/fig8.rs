//! Fig. 8 — gradient accumulation for batch-wise IBMB on GCN: the paper
//! finds the effect "minor, even when accumulating over the full
//! epoch", demonstrating IBMB's training stability despite sparse,
//! fixed gradients.

use anyhow::Result;

use super::runner::{self, Env};
use crate::bench_harness::Table;
use crate::cli::Args;
use crate::config::{preset_for, ExpScale};
use crate::training::{train, TrainConfig};
use crate::util::Rng;

pub fn run(scale: &ExpScale, args: &Args) -> Result<()> {
    let mut env = Env::load()?;
    let ds_name = args.get_or("dataset", "synth-arxiv");
    let model = args.get_or("model", "gcn");
    let ds = runner::dataset(ds_name, scale, 8);
    let nb = preset_for(ds_name).num_batches;
    // 1 = fused step; nb = full-epoch accumulation
    let accums = [1usize, 2, 4, nb];

    let mut table = Table::new(&[
        "grad accum",
        "best val acc (%)",
        "final val loss",
    ]);
    for &k in &accums {
        let mut gen = runner::generator("batch-wise IBMB", &ds.name, None);
        let cfg = TrainConfig {
            model: model.to_string(),
            epochs: scale.epochs,
            seed: 8,
            grad_accum: k,
            ..Default::default()
        };
        let mut rng = Rng::new(8);
        let res = train(&mut env.rt, &ds, &cfg, gen.as_mut(), &mut rng)?;
        let last = res.history.last().unwrap();
        table.row(&[
            if k == nb {
                format!("{k} (full epoch)")
            } else {
                k.to_string()
            },
            format!("{:.1}", res.best_val_acc * 100.0),
            format!("{:.3}", last.val_loss),
        ]);
    }
    table.print(&format!(
        "Fig. 8 — gradient accumulation ({ds_name}, {model}): difference \
         should be minor"
    ));
    Ok(())
}
