//! Table 7 — the paper's flagship end-to-end grid: per (dataset, model,
//! method) the preprocessing time, per-epoch time, inference time, and
//! test accuracy under (a) the same mini-batching method and (b) exact
//! full-batch inference.

use anyhow::Result;

use super::runner::{self, Env, MAIN_METHODS};
use crate::bench_harness::{pm, secs, Table};
use crate::cli::Args;
use crate::config::ExpScale;
use crate::inference::fullgraph;
use crate::util::stats::{mean, std_dev};

pub fn run(scale: &ExpScale, args: &Args) -> Result<()> {
    let mut env = Env::load()?;
    let default_settings = if args.flag("full") {
        "synth-arxiv:gcn,synth-arxiv:gat,synth-arxiv:sage,\
         synth-products:gcn,synth-reddit:gcn,synth-papers:gcn"
    } else {
        "synth-arxiv:gcn"
    };
    let settings: Vec<(String, String)> = args
        .get_or("settings", default_settings)
        .split(',')
        .filter(|s| !s.is_empty())
        .map(|s| {
            let (d, m) = s.trim().split_once(':').expect("dataset:model");
            (d.to_string(), m.to_string())
        })
        .collect();

    for (ds_name, model) in settings {
        let ds = runner::dataset(&ds_name, scale, 12);
        eprintln!(
            "[table7] {ds_name} ({} nodes, {} train), {model}",
            ds.graph.num_nodes(),
            ds.splits.train.len()
        );
        let mut table = Table::new(&[
            "method",
            "preprocess (s)",
            "per-epoch (s)",
            "inference (s)",
            "acc same (%)",
            "acc full-batch (%)",
        ]);
        // exact full-batch reference timing (once per setting)
        let mut fb_secs = 0.0;
        for method in MAIN_METHODS {
            let mut pre = Vec::new();
            let mut per_epoch = Vec::new();
            let mut inf = Vec::new();
            let mut acc_same = Vec::new();
            let mut acc_fb = Vec::new();
            for seed in 0..scale.seeds as u64 {
                let res = runner::train_once(
                    &mut env, &ds, &model, method, scale, seed,
                )?;
                pre.push(res.preprocess_s);
                per_epoch.push(res.mean_epoch_s);
                let rep = runner::infer_once(
                    &mut env,
                    &ds,
                    &model,
                    &res.state,
                    method,
                    None,
                    &ds.splits.test,
                    seed,
                )?;
                inf.push(rep.seconds);
                acc_same.push(rep.accuracy * 100.0);
                let fb = fullgraph::full_graph_inference(
                    &res.meta_train,
                    &res.state,
                    &ds,
                    &ds.splits.test,
                );
                fb_secs = fb.seconds;
                acc_fb.push(fb.accuracy * 100.0);
            }
            table.row(&[
                method.to_string(),
                secs(mean(&pre)),
                secs(mean(&per_epoch)),
                secs(mean(&inf)),
                pm(mean(&acc_same), std_dev(&acc_same)),
                pm(mean(&acc_fb), std_dev(&acc_fb)),
            ]);
        }
        table.row(&[
            "full-batch (exact)".into(),
            "-".into(),
            "-".into(),
            secs(fb_secs),
            "-".into(),
            "-".into(),
        ]);
        table.print(&format!("Table 7 — {ds_name}, {model}"));
    }
    Ok(())
}
