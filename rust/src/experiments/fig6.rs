//! Fig. 6 — output-node partitioning ablation: node-wise IBMB vs
//! batch-wise IBMB vs fixed random batches (same influence-based aux
//! selection everywhere). Both IBMB partitions should converge faster
//! and higher than random batching.

use anyhow::Result;

use super::runner::{self, Env};
use crate::bench_harness::{secs, Table};
use crate::cli::Args;
use crate::config::ExpScale;

const METHODS: [&str; 3] =
    ["node-wise IBMB", "batch-wise IBMB", "fixed random"];

pub fn run(scale: &ExpScale, args: &Args) -> Result<()> {
    let mut env = Env::load()?;
    let ds_name = args.get_or("dataset", "synth-arxiv");
    let model = args.get_or("model", "gcn");
    let ds = runner::dataset(ds_name, scale, 6);

    let mut table = Table::new(&[
        "partitioning",
        "best val acc (%)",
        "per-epoch (s)",
        "time to 60% (s)",
    ]);
    for method in METHODS {
        use crate::util::stats::{mean, std_dev};
        let mut accs = Vec::new();
        let mut t60 = Vec::new();
        let mut pe = Vec::new();
        for seed in 0..scale.seeds as u64 {
            let res =
                runner::train_once(&mut env, &ds, model, method, scale, seed)?;
            accs.push(res.best_val_acc * 100.0);
            pe.push(res.mean_epoch_s);
            if let Some(t) = runner::time_to_accuracy(&res, 0.60) {
                t60.push(t);
            }
        }
        table.row(&[
            method.to_string(),
            crate::bench_harness::pm(mean(&accs), std_dev(&accs)),
            secs(mean(&pe)),
            if t60.is_empty() {
                "-".into()
            } else {
                secs(mean(&t60))
            },
        ]);
    }
    table.print(&format!(
        "Fig. 6 — partitioning ablation ({ds_name}, {model})"
    ));
    Ok(())
}
