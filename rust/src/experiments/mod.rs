//! Experiment drivers — one module per paper table/figure (DESIGN.md §5
//! experiment index). Every module exposes `run(&ExpScale, &Args)` and
//! prints rows/series in the paper's shape; the `rust/benches/*`
//! targets are thin wrappers around these.

pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod runner;
pub mod table5;
pub mod table6;
pub mod table7;

pub use runner::Env;
