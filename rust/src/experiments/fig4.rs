//! Fig. 4 — label-rate sweep on synth-products: IBMB's convergence
//! scales with the number of training nodes, global methods
//! (Cluster-GCN, GraphSAINT-RW) with the whole graph, so the gap grows
//! as the training set shrinks.

use anyhow::Result;

use super::runner::{self, Env};
use crate::bench_harness::{secs, Table};
use crate::cli::Args;
use crate::config::ExpScale;
use crate::training::{train, TrainConfig};
use crate::util::Rng;

const METHODS: [&str; 3] = ["node-wise IBMB", "Cluster-GCN", "GraphSAINT-RW"];

pub fn run(scale: &ExpScale, args: &Args) -> Result<()> {
    let mut env = Env::load()?;
    let ds_name = args.get_or("dataset", "synth-products");
    let model = args.get_or("model", "gcn");
    let base = runner::dataset(ds_name, scale, 3);
    let fractions = [1.0, 0.25, 0.05];

    let mut table = Table::new(&[
        "train frac",
        "train nodes",
        "method",
        "per-epoch (s)",
        "best val acc (%)",
    ]);
    for &frac in &fractions {
        let mut ds = base.clone();
        let mut rng = Rng::new(42);
        ds.splits = ds.splits.with_train_fraction(frac, &mut rng);
        for method in METHODS {
            let mut gen = runner::generator(method, &ds.name, None);
            let cfg = TrainConfig {
                model: model.to_string(),
                epochs: scale.epochs,
                seed: 4,
                ..Default::default()
            };
            let mut trng = Rng::new(4);
            let res = train(&mut env.rt, &ds, &cfg, gen.as_mut(), &mut trng)?;
            table.row(&[
                format!("{frac:.2}"),
                ds.splits.train.len().to_string(),
                method.to_string(),
                secs(res.mean_epoch_s),
                format!("{:.1}", res.best_val_acc * 100.0),
            ]);
        }
    }
    table.print(&format!(
        "Fig. 4 — convergence vs label rate ({ds_name}, {model})"
    ));
    println!(
        "Expected shape: IBMB per-epoch time shrinks with the train set; \
         Cluster-GCN/GraphSAINT stay roughly constant (global methods)."
    );
    Ok(())
}
