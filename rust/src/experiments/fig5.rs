//! Fig. 5 — sensitivity to output nodes per batch (node-wise IBMB,
//! fixed aux-per-output): the paper finds the impact "rather minor",
//! especially above ~1000 outputs per batch.

use anyhow::Result;

use super::runner::Env;
use crate::batching::{BatchGenerator, NodeWiseIbmb};
use crate::bench_harness::Table;
use crate::cli::Args;
use crate::config::{preset_for, ExpScale};
use crate::training::{train, TrainConfig};
use crate::util::Rng;

pub fn run(scale: &ExpScale, args: &Args) -> Result<()> {
    let mut env = Env::load()?;
    let ds_name = args.get_or("dataset", "synth-arxiv");
    let model = args.get_or("model", "gcn");
    let ds = super::runner::dataset(ds_name, scale, 5);
    let p = preset_for(ds_name);
    let sweeps = [16usize, 48, 128, 384];

    let mut table = Table::new(&[
        "outputs/batch",
        "batches",
        "best val acc (%)",
        "per-epoch (s)",
    ]);
    for &opb in &sweeps {
        let mut gen = NodeWiseIbmb {
            aux_per_output: p.aux_per_output,
            max_outputs_per_batch: opb,
            node_budget: p.node_budget,
            ..Default::default()
        };
        let cfg = TrainConfig {
            model: model.to_string(),
            epochs: scale.epochs,
            seed: 5,
            ..Default::default()
        };
        let mut rng = Rng::new(5);
        let res = train(&mut env.rt, &ds, &cfg, &mut gen, &mut rng)?;
        // count batches by regenerating (cheap at this scale)
        let mut rng2 = Rng::new(5);
        let nb = {
            let mut g2 = gen.clone();
            <NodeWiseIbmb as BatchGenerator>::generate(
                &mut g2,
                &ds,
                &ds.splits.train,
                &mut rng2,
            )
            .len()
        };
        table.row(&[
            opb.to_string(),
            nb.to_string(),
            format!("{:.1}", res.best_val_acc * 100.0),
            crate::bench_harness::secs(res.mean_epoch_s),
        ]);
    }
    table.print(&format!(
        "Fig. 5 — output nodes per batch ({ds_name}, {model}): impact \
         should be minor"
    ));
    Ok(())
}
