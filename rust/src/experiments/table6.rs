//! Table 6 — main-memory usage per method: graph + batch cache + model
//! state + padded buffers. IBMB can use more memory (overlapping
//! batches) or less (ignores irrelevant graph regions) than baselines.

use anyhow::Result;

use super::runner::{self, Env, MAIN_METHODS};
use crate::batching::{BatchCache, DenseBatch};
use crate::bench_harness::Table;
use crate::cli::Args;
use crate::config::ExpScale;
use crate::runtime::ModelState;
use crate::util::Rng;

fn gib(bytes: usize) -> String {
    // MiB resolution: smoke-scale numbers round to zero in GiB
    format!("{:.2}", bytes as f64 / (1 << 20) as f64)
}

pub fn run(scale: &ExpScale, args: &Args) -> Result<()> {
    let env = Env::load()?;
    let ds_name = args.get_or("dataset", "synth-arxiv");
    let model = args.get_or("model", "gcn");
    let ds = runner::dataset(ds_name, scale, 11);

    let mut table = Table::new(&[
        "method",
        "dataset (MiB)",
        "batch cache (MiB)",
        "buffers+state (MiB)",
        "total (MiB)",
    ]);
    for method in MAIN_METHODS {
        let mut gen = runner::generator(method, &ds.name, None);
        let mut rng = Rng::new(11);
        let cache =
            BatchCache::build(&gen.plan(&ds, &ds.splits.train, &mut rng));
        let max_nodes = cache.max_batch_nodes();
        let meta = env
            .rt
            .manifest
            .bucket_meta(model, "train", max_nodes)
            .expect("bucket");
        let state = ModelState::init(meta, 11);
        // the prefetch ring holds `depth` arena buffers at steady state
        let buffers = env.prefetch_depth
            * DenseBatch::zeros(meta.n_pad, meta.feat).memory_bytes();
        // global methods keep the whole dataset resident; IBMB can drop
        // it after preprocessing (paper: "removes the dataset from
        // memory after preprocessing")
        let keeps_dataset = !gen.is_fixed()
            || matches!(method, "Cluster-GCN" | "GraphSAINT-RW" | "LADIES");
        let ds_bytes = if keeps_dataset { ds.memory_bytes() } else { 0 };
        let total =
            ds_bytes + cache.memory_bytes() + state.memory_bytes() + buffers;
        table.row(&[
            method.to_string(),
            gib(ds_bytes),
            gib(cache.memory_bytes()),
            gib(state.memory_bytes() + buffers),
            gib(total),
        ]);
    }
    table.print(&format!(
        "Table 6 — main-memory usage ({ds_name}, {model})"
    ));
    Ok(())
}
