//! Fig. 3 — training convergence: validation accuracy vs wall-clock
//! time per method. Prints each method's convergence curve (log-time
//! series) plus the time-to-target summary the paper's "up to 17x
//! faster convergence" claim is read from.

use anyhow::Result;

use super::runner::{self, Env, MAIN_METHODS};
use crate::bench_harness::{secs, Table};
use crate::cli::Args;
use crate::config::ExpScale;

pub fn run(scale: &ExpScale, args: &Args) -> Result<()> {
    let mut env = Env::load()?;
    let ds_name = args.get_or("dataset", "synth-arxiv");
    let model = args.get_or("model", "gcn");
    let ds = runner::dataset(ds_name, scale, 2);
    eprintln!(
        "[fig3] {ds_name} ({} nodes), model {model}, {} epochs",
        ds.graph.num_nodes(),
        scale.epochs
    );

    let mut results = Vec::new();
    for method in MAIN_METHODS {
        let mut accs = Vec::new();
        let mut t_to = Vec::new();
        let mut per_epoch = Vec::new();
        let mut curve: Vec<(f64, f64)> = Vec::new();
        for seed in 0..scale.seeds as u64 {
            let res =
                runner::train_once(&mut env, &ds, model, method, scale, seed)?;
            accs.push(res.best_val_acc * 100.0);
            per_epoch.push(res.mean_epoch_s);
            if seed == 0 {
                curve = res
                    .history
                    .iter()
                    .map(|r| (r.wall_s, r.val_acc * 100.0))
                    .collect();
            }
            if let Some(t) = runner::time_to_accuracy(&res, 0.60) {
                t_to.push(t);
            }
        }
        results.push((method, accs, t_to, per_epoch, curve));
    }

    let mut table = Table::new(&[
        "method",
        "best val acc (%)",
        "per-epoch (s)",
        "time to 60% (s)",
    ]);
    for (method, accs, t_to, per_epoch, curve) in &results {
        use crate::util::stats::{mean, std_dev};
        table.row(&[
            method.to_string(),
            crate::bench_harness::pm(mean(accs), std_dev(accs)),
            secs(mean(per_epoch)),
            if t_to.is_empty() {
                "-".into()
            } else {
                secs(mean(t_to))
            },
        ]);
        // convergence series (seed 0) for plotting
        let pts: Vec<String> = curve
            .iter()
            .map(|(t, a)| format!("({t:.2}s,{a:.1}%)"))
            .collect();
        eprintln!("[fig3] {method}: {}", pts.join(" "));
    }
    table.print(&format!(
        "Fig. 3 — training convergence ({ds_name}, {model})"
    ));
    Ok(())
}
