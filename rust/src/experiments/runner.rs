//! Shared experiment plumbing: runtime discovery, dataset construction,
//! method instantiation from presets, and the train/infer one-liners the
//! per-figure drivers compose.

use anyhow::{Context, Result};

use crate::baselines;
use crate::batching::{BatchArena, BatchCache, BatchGenerator};
use crate::config::{preset_for, ExpScale, DEFAULT_PREFETCH_DEPTH};
use crate::datasets::{sbm, spec_by_name, Dataset};
use crate::inference::{infer_with_batches, InferReport};
use crate::runtime::{ModelState, Runtime};
use crate::training::{train, TrainConfig, TrainResult};
use crate::util::Rng;

/// The methods of the paper's main comparison, in table order.
pub const MAIN_METHODS: [&str; 7] = [
    "neighbor sampling",
    "LADIES",
    "GraphSAINT-RW",
    "shaDow",
    "Cluster-GCN",
    "batch-wise IBMB",
    "node-wise IBMB",
];

/// Shared experiment environment.
pub struct Env {
    pub rt: Runtime,
    /// Prefetch ring depth used by the train/infer one-liners
    /// (`IBMB_PREFETCH_DEPTH` env override; `--prefetch-depth` in the
    /// CLI patches it after load).
    pub prefetch_depth: usize,
}

impl Env {
    /// Locate `artifacts/` (env `IBMB_ARTIFACTS` overrides) and start
    /// the PJRT runtime.
    pub fn load() -> Result<Env> {
        let dir = std::env::var("IBMB_ARTIFACTS").unwrap_or_else(|_| {
            // tolerate running from target subdirs
            for cand in ["artifacts", "../artifacts", "../../artifacts"] {
                if std::path::Path::new(cand).join("manifest.json").exists() {
                    return cand.to_string();
                }
            }
            "artifacts".to_string()
        });
        let rt = Runtime::load(&dir)
            .with_context(|| "run `make artifacts` first")?;
        let prefetch_depth = std::env::var("IBMB_PREFETCH_DEPTH")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(DEFAULT_PREFETCH_DEPTH);
        Ok(Env { rt, prefetch_depth })
    }
}

/// Build a dataset at the experiment scale.
pub fn dataset(name: &str, scale: &ExpScale, seed: u64) -> Dataset {
    let spec = spec_by_name(name)
        .unwrap_or_else(|| panic!("unknown dataset {name}"))
        .scaled(scale.dataset_factor);
    sbm::generate(&spec, seed)
}

/// Instantiate a method from the dataset preset. `aux_override`
/// replaces the preset aux budget (Fig. 2's sweep knob).
pub fn generator(
    method: &str,
    ds_name: &str,
    aux_override: Option<usize>,
) -> Box<dyn BatchGenerator> {
    let p = preset_for(ds_name);
    let aux = aux_override.unwrap_or(p.aux_per_output);
    baselines::by_name(method, aux, p.num_batches, p.node_budget)
        .unwrap_or_else(|| panic!("unknown method {method}"))
}

/// Train one (model, method) configuration.
pub fn train_once(
    env: &mut Env,
    ds: &Dataset,
    model: &str,
    method: &str,
    scale: &ExpScale,
    seed: u64,
) -> Result<TrainResult> {
    let mut gen = generator(method, &ds.name, None);
    let cfg = TrainConfig {
        model: model.to_string(),
        epochs: scale.epochs,
        seed,
        prefetch_depth: env.prefetch_depth,
        ..Default::default()
    };
    let mut rng = Rng::new(seed ^ 0xE9E1);
    train(&mut env.rt, ds, &cfg, gen.as_mut(), &mut rng)
}

/// Inference over the test split with a trained state.
#[allow(clippy::too_many_arguments)]
pub fn infer_once(
    env: &mut Env,
    ds: &Dataset,
    model: &str,
    state: &ModelState,
    method: &str,
    aux_override: Option<usize>,
    eval: &[u32],
    seed: u64,
) -> Result<InferReport> {
    let mut gen = generator(method, &ds.name, aux_override);
    let mut rng = Rng::new(seed ^ 0x1F3A);
    // fixed methods: preprocessing outside the timed region
    let cache = if gen.is_fixed() {
        Some(BatchCache::build(&gen.plan(ds, eval, &mut rng)))
    } else {
        None
    };
    let mut arena = BatchArena::new(ds.feat_dim);
    infer_with_batches(
        &mut env.rt,
        ds,
        model,
        state,
        gen.as_mut(),
        cache.as_ref(),
        eval,
        &mut rng,
        &mut arena,
        env.prefetch_depth,
    )
}

/// Seconds until the convergence curve first reaches `target_acc`
/// (None if never).
pub fn time_to_accuracy(res: &TrainResult, target_acc: f64) -> Option<f64> {
    res.history
        .iter()
        .find(|r| r.val_acc >= target_acc)
        .map(|r| r.wall_s)
}
