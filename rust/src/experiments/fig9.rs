//! Fig. 9 (appendix) — the pretraining method does not change the
//! Fig. 2 findings: models trained with GraphSAINT-RW instead of
//! node-wise IBMB produce the same method ranking at inference.

use anyhow::Result;

use super::runner::{self, Env};
use crate::bench_harness::{secs, Table};
use crate::cli::Args;
use crate::config::ExpScale;

pub fn run(scale: &ExpScale, args: &Args) -> Result<()> {
    let mut env = Env::load()?;
    let ds_name = args.get_or("dataset", "synth-arxiv");
    let model = args.get_or("model", "gcn");
    let ds = runner::dataset(ds_name, scale, 9);
    eprintln!("[fig9] pretraining with GraphSAINT-RW…");
    let trained =
        runner::train_once(&mut env, &ds, model, "GraphSAINT-RW", scale, 9)?;

    let mut table = Table::new(&[
        "inference method",
        "test acc (%)",
        "time (s)",
    ]);
    for method in super::fig2::SWEEP_METHODS {
        let rep = runner::infer_once(
            &mut env,
            &ds,
            model,
            &trained.state,
            method,
            None,
            &ds.splits.test,
            9,
        )?;
        table.row(&[
            method.to_string(),
            format!("{:.1}", rep.accuracy * 100.0),
            secs(rep.seconds),
        ]);
    }
    table.print(&format!(
        "Fig. 9 — inference ranking with GraphSAINT-pretrained model \
         ({ds_name}, {model})"
    ));
    Ok(())
}
