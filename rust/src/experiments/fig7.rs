//! Fig. 7 — batch scheduling ablation (GAT in the paper): sequential vs
//! shuffle vs SA-optimal cycle vs distance-weighted sampling. Optimal /
//! weighted scheduling should prevent the downward accuracy spikes and
//! raise final accuracy.

use anyhow::Result;

use super::runner::{self, Env};
use crate::bench_harness::Table;
use crate::cli::Args;
use crate::config::ExpScale;
use crate::training::{train, trainer::SchedulerKind, TrainConfig};
use crate::util::Rng;

const SCHEDULERS: [(&str, SchedulerKind); 4] = [
    ("sequential", SchedulerKind::Sequential),
    ("shuffle", SchedulerKind::Shuffle),
    ("optimal cycle (SA)", SchedulerKind::OptimalCycle),
    ("weighted sampling", SchedulerKind::Weighted),
];

pub fn run(scale: &ExpScale, args: &Args) -> Result<()> {
    let mut env = Env::load()?;
    let ds_name = args.get_or("dataset", "synth-arxiv");
    let model = args.get_or("model", "gat");
    let ds = runner::dataset(ds_name, scale, 7);

    let mut table = Table::new(&[
        "scheduler",
        "final val acc (%)",
        "worst dip (%)",
        "mean consec. KL dist",
    ]);
    for (name, kind) in SCHEDULERS {
        let mut gen = runner::generator("batch-wise IBMB", &ds.name, None);
        let cfg = TrainConfig {
            model: model.to_string(),
            epochs: scale.epochs,
            seed: 7,
            scheduler: kind,
            prefetch_depth: env.prefetch_depth,
            ..Default::default()
        };
        let mut rng = Rng::new(7);
        let res = train(&mut env.rt, &ds, &cfg, gen.as_mut(), &mut rng)?;
        // worst dip: largest drop below the running max of val acc
        let mut run_max = 0.0f64;
        let mut dip = 0.0f64;
        for r in &res.history {
            run_max = run_max.max(r.val_acc);
            dip = dip.max(run_max - r.val_acc);
        }
        // measure schedule quality on the actual batches
        let quality = {
            let mut g2 = runner::generator("batch-wise IBMB", &ds.name, None);
            let mut qrng = Rng::new(7);
            let batches = g2.plan(&ds, &ds.splits.train, &mut qrng);
            let hists: Vec<Vec<f64>> = batches
                .iter()
                .map(|b| ds.label_histogram(b.output_nodes()))
                .collect();
            let dist = crate::scheduler::batch_distance_matrix(&hists);
            let mut sched: Box<dyn crate::scheduler::Scheduler> = match kind {
                SchedulerKind::Sequential => {
                    Box::new(crate::scheduler::SequentialScheduler {
                        num_batches: batches.len(),
                    })
                }
                SchedulerKind::Shuffle => {
                    Box::new(crate::scheduler::ShuffleScheduler {
                        num_batches: batches.len(),
                    })
                }
                SchedulerKind::OptimalCycle => Box::new(
                    crate::scheduler::OptimalCycleScheduler::new(&dist, &mut qrng),
                ),
                SchedulerKind::Weighted => {
                    Box::new(crate::scheduler::WeightedScheduler::new(dist.clone()))
                }
            };
            crate::scheduler::order_quality(&dist, &sched.epoch_order(&mut qrng))
        };
        let final_acc = res
            .history
            .last()
            .map(|r| r.val_acc * 100.0)
            .unwrap_or(0.0);
        table.row(&[
            name.to_string(),
            format!("{final_acc:.1}"),
            format!("{:.1}", dip * 100.0),
            format!("{quality:.3}"),
        ]);
    }
    table.print(&format!(
        "Fig. 7 — batch scheduling ({ds_name}, {model})"
    ));
    Ok(())
}
