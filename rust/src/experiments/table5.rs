//! Table 5 — sensitivity to the local-clustering method and its
//! hyperparameters (batch-wise IBMB, GCN on products in the paper):
//! PPR with α ∈ {0.05..0.35} vs heat kernel with t ∈ {1..7}. IBMB
//! should be robust to this choice.

use anyhow::Result;

use super::runner::{self, Env};
use crate::batching::BatchWiseIbmb;
use crate::bench_harness::{secs, Table};
use crate::cli::Args;
use crate::config::{preset_for, ExpScale};
use crate::inference::fullgraph;
use crate::ppr::heat::HeatConfig;
use crate::ppr::power::PowerConfig;
use crate::training::{train, TrainConfig};
use crate::util::Rng;

pub fn run(scale: &ExpScale, args: &Args) -> Result<()> {
    let mut env = Env::load()?;
    let ds_name = args.get_or("dataset", "synth-products");
    let model = args.get_or("model", "gcn");
    let ds = runner::dataset(ds_name, scale, 10);
    let p = preset_for(ds_name);

    enum Sel {
        Ppr(f32),
        Heat(f32),
    }
    let variants: Vec<(String, Sel)> = vec![
        ("PPR a=0.05".into(), Sel::Ppr(0.05)),
        ("PPR a=0.15".into(), Sel::Ppr(0.15)),
        ("PPR a=0.25".into(), Sel::Ppr(0.25)),
        ("PPR a=0.35".into(), Sel::Ppr(0.35)),
        ("Heat t=1".into(), Sel::Heat(1.0)),
        ("Heat t=3".into(), Sel::Heat(3.0)),
        ("Heat t=5".into(), Sel::Heat(5.0)),
    ];

    let mut table = Table::new(&[
        "method",
        "per-epoch (s)",
        "IBMB-inference acc (%)",
        "full-batch acc (%)",
    ]);
    for (name, sel) in variants {
        let mut gen = BatchWiseIbmb {
            num_batches: p.num_batches,
            node_budget: p.node_budget,
            power: match sel {
                Sel::Ppr(a) => PowerConfig {
                    alpha: a,
                    ..Default::default()
                },
                Sel::Heat(_) => PowerConfig::default(),
            },
            heat: match sel {
                Sel::Heat(t) => Some(HeatConfig {
                    t,
                    ..Default::default()
                }),
                Sel::Ppr(_) => None,
            },
            ..Default::default()
        };
        let cfg = TrainConfig {
            model: model.to_string(),
            epochs: scale.epochs,
            seed: 10,
            ..Default::default()
        };
        let mut rng = Rng::new(10);
        let res = train(&mut env.rt, &ds, &cfg, &mut gen, &mut rng)?;
        let same = runner::infer_once(
            &mut env,
            &ds,
            model,
            &res.state,
            "batch-wise IBMB",
            None,
            &ds.splits.test,
            10,
        )?;
        let fb = fullgraph::full_graph_inference(
            &res.meta_train,
            &res.state,
            &ds,
            &ds.splits.test,
        );
        table.row(&[
            name,
            secs(res.mean_epoch_s),
            format!("{:.1}", same.accuracy * 100.0),
            format!("{:.1}", fb.accuracy * 100.0),
        ]);
    }
    table.print(&format!(
        "Table 5 — aux-selection sensitivity ({ds_name}, {model}): IBMB \
         should be robust"
    ));
    Ok(())
}
