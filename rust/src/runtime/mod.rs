//! PJRT runtime: loads the AOT artifacts and executes them on the hot
//! path. Python never runs here — `artifacts/*.hlo.txt` + manifest are
//! the entire interface (DESIGN.md §6).
//!
//! HLO **text** is the interchange format: jax ≥ 0.5 serializes protos
//! with 64-bit instruction ids which xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md).

pub mod host;
pub mod manifest;
pub mod state;

pub use manifest::{ArtifactMeta, Manifest, ParamSpec};
pub use state::ModelState;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::batching::DenseBatch;

/// Metrics returned by a train or infer step.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepMetrics {
    pub loss: f32,
    pub correct: f32,
    pub mask_count: f32,
}

impl StepMetrics {
    pub fn accuracy(&self) -> f64 {
        if self.mask_count > 0.0 {
            self.correct as f64 / self.mask_count as f64
        } else {
            0.0
        }
    }
    pub fn merge(&mut self, other: &StepMetrics) {
        self.loss += other.loss * other.mask_count;
        self.correct += other.correct;
        self.mask_count += other.mask_count;
    }
    pub fn mean_loss(&self) -> f64 {
        if self.mask_count > 0.0 {
            (self.loss / self.mask_count) as f64
        } else {
            0.0
        }
    }
}

/// PJRT CPU runtime with lazily compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    compiled: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Device-fetch staging for [`Self::grad_step`] — reused across
    /// batches so gradient accumulation performs zero steady-state
    /// allocations (the caller owns the accumulator, we own the
    /// transfer buffer).
    grad_scratch: Vec<f32>,
}

impl Runtime {
    /// Load the manifest and create the PJRT CPU client.
    pub fn load(artifacts_dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {dir:?}"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        Ok(Runtime {
            client,
            dir,
            manifest,
            compiled: HashMap::new(),
            grad_scratch: Vec::new(),
        })
    }

    /// Number of executables compiled so far (perf accounting).
    pub fn compiled_count(&self) -> usize {
        self.compiled.len()
    }

    /// Compile (once) and fetch the executable for an artifact id.
    pub fn executable(&mut self, id: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.compiled.contains_key(id) {
            let meta = self
                .manifest
                .by_id(id)
                .ok_or_else(|| anyhow!("unknown artifact {id}"))?;
            let path = self.dir.join(&meta.path);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parse {path:?}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {id}: {e}"))?;
            self.compiled.insert(id.to_string(), exe);
        }
        Ok(&self.compiled[id])
    }

    /// Host-to-device transfer without the Literal intermediate.
    ///
    /// NOTE: `PjRtLoadedExecutable::execute` (literal variant) in xla
    /// 0.1.6 leaks every input buffer (`buffer.release()` in the C
    /// wrapper's `execute`, never freed — ~10 MB/step at n_pad=2048).
    /// We therefore create input buffers ourselves and run `execute_b`,
    /// so Drop reclaims them. This also saves one host-side copy per
    /// input (EXPERIMENTS.md §Perf L3 iteration log).
    fn buf<T: xla::ArrayElement>(
        &self,
        data: &[T],
        dims: &[usize],
    ) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("host->device: {e}"))
    }

    fn batch_buffers(
        &self,
        dense: &DenseBatch,
        meta: &ArtifactMeta,
    ) -> Result<[xla::PjRtBuffer; 4]> {
        let n = meta.n_pad;
        let f = meta.feat;
        Ok([
            self.buf(&dense.x, &[n, f])?,
            self.buf(&dense.adj, &[n, n])?,
            self.buf(&dense.labels, &[n])?,
            self.buf(&dense.mask, &[n])?,
        ])
    }

    /// Run one fused train step (fwd + bwd + Adam), updating `state`
    /// in place and returning the batch metrics.
    pub fn train_step(
        &mut self,
        meta: &ArtifactMeta,
        state: &mut ModelState,
        dense: &DenseBatch,
        lr: f32,
        seed: i32,
    ) -> Result<StepMetrics> {
        debug_assert_eq!(meta.kind, "train");
        debug_assert_eq!(dense.n_pad, meta.n_pad);
        state.step += 1;
        let p = meta.param_count;
        let [x, adj, labels, mask] = self.batch_buffers(dense, meta)?;
        let inputs = [
            self.buf(&state.params, &[p])?,
            self.buf(&state.m, &[p])?,
            self.buf(&state.v, &[p])?,
            self.buf(&[state.step as f32], &[])?,
            self.buf(&[lr], &[])?,
            self.buf(&[seed], &[])?,
            x,
            adj,
            labels,
            mask,
        ];
        let exe = self.executable(&meta.id)?;
        let result = exe
            .execute_b::<xla::PjRtBuffer>(&inputs)
            .map_err(|e| anyhow!("execute {}: {e}", meta.id))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e}"))?;
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow!("tuple: {e}"))?;
        anyhow::ensure!(parts.len() == 6, "expected 6 outputs");
        let mut it = parts.into_iter();
        it.next().unwrap().copy_raw_to(&mut state.params).map_err(|e| anyhow!("{e}"))?;
        it.next().unwrap().copy_raw_to(&mut state.m).map_err(|e| anyhow!("{e}"))?;
        it.next().unwrap().copy_raw_to(&mut state.v).map_err(|e| anyhow!("{e}"))?;
        let loss: f32 = it.next().unwrap().get_first_element().map_err(|e| anyhow!("{e}"))?;
        let correct: f32 = it.next().unwrap().get_first_element().map_err(|e| anyhow!("{e}"))?;
        let mask_count: f32 = it.next().unwrap().get_first_element().map_err(|e| anyhow!("{e}"))?;
        Ok(StepMetrics {
            loss,
            correct,
            mask_count,
        })
    }

    /// Run one forward+backward step WITHOUT the optimizer,
    /// **accumulating** (`+=`) the gradients into the caller-owned
    /// `grads` buffer (gradient-accumulation mode, paper Fig. 8).
    /// The device fetch lands in an internal staging buffer reused
    /// across batches, so steady-state accumulation allocates nothing.
    pub fn grad_step(
        &mut self,
        meta: &ArtifactMeta,
        state: &ModelState,
        dense: &DenseBatch,
        seed: i32,
        grads: &mut [f32],
    ) -> Result<StepMetrics> {
        debug_assert_eq!(meta.kind, "grad");
        let p = meta.param_count;
        anyhow::ensure!(
            grads.len() == p,
            "grad buffer {} != param_count {p}",
            grads.len()
        );
        let [x, adj, labels, mask] = self.batch_buffers(dense, meta)?;
        let inputs = [
            self.buf(&state.params, &[p])?,
            self.buf(&[seed], &[])?,
            x,
            adj,
            labels,
            mask,
        ];
        let exe = self.executable(&meta.id)?;
        let result = exe
            .execute_b::<xla::PjRtBuffer>(&inputs)
            .map_err(|e| anyhow!("execute {}: {e}", meta.id))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e}"))?;
        let (g, l, c, mc) = result
            .to_tuple4()
            .map_err(|e| anyhow!("tuple4: {e}"))?;
        if self.grad_scratch.len() < p {
            self.grad_scratch.resize(p, 0.0);
        }
        g.copy_raw_to(&mut self.grad_scratch[..p])
            .map_err(|e| anyhow!("{e}"))?;
        for (a, &b) in grads.iter_mut().zip(&self.grad_scratch[..p]) {
            *a += b;
        }
        Ok(StepMetrics {
            loss: l.get_first_element().map_err(|e| anyhow!("{e}"))?,
            correct: c.get_first_element().map_err(|e| anyhow!("{e}"))?,
            mask_count: mc.get_first_element().map_err(|e| anyhow!("{e}"))?,
        })
    }

    /// Run one inference step (no dropout, no state mutation).
    pub fn infer_step(
        &mut self,
        meta: &ArtifactMeta,
        state: &ModelState,
        dense: &DenseBatch,
    ) -> Result<StepMetrics> {
        debug_assert_eq!(meta.kind, "infer");
        debug_assert_eq!(dense.n_pad, meta.n_pad);
        let [x, adj, labels, mask] = self.batch_buffers(dense, meta)?;
        let inputs = [
            self.buf(&state.params, &[meta.param_count])?,
            x,
            adj,
            labels,
            mask,
        ];
        let exe = self.executable(&meta.id)?;
        let result = exe
            .execute_b::<xla::PjRtBuffer>(&inputs)
            .map_err(|e| anyhow!("execute {}: {e}", meta.id))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e}"))?;
        let (l, c, mc) = result
            .to_tuple3()
            .map_err(|e| anyhow!("tuple3: {e}"))?;
        Ok(StepMetrics {
            loss: l.get_first_element().map_err(|e| anyhow!("{e}"))?,
            correct: c.get_first_element().map_err(|e| anyhow!("{e}"))?,
            mask_count: mc.get_first_element().map_err(|e| anyhow!("{e}"))?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn step_metrics_accumulate() {
        let mut a = StepMetrics::default();
        a.merge(&StepMetrics {
            loss: 2.0,
            correct: 3.0,
            mask_count: 4.0,
        });
        a.merge(&StepMetrics {
            loss: 1.0,
            correct: 5.0,
            mask_count: 6.0,
        });
        assert!((a.accuracy() - 0.8).abs() < 1e-9);
        assert!((a.mean_loss() - 1.4).abs() < 1e-6);
    }
}
