//! Host-side dense-padded training oracle.
//!
//! A scalar re-implementation of the AOT train/grad artifacts
//! (`python/compile/model.py`) over [`DenseBatch`] buffers: masked
//! softmax-CE over the padded `n_pad × n_pad` adjacency, reverse-mode
//! gradients, weight decay on the full flat vector, and a plain Adam
//! step. It exists for two reasons:
//!
//! 1. **Parity oracle.** The vendored xla stub cannot execute, so this
//!    is the executable ground truth the sparse [`crate::exec::train`]
//!    backends are tested against. Padded rows have all-zero adjacency
//!    rows/columns, zero features and zero mask, so every padded
//!    contribution to every gradient is an exact f32 zero — the dense
//!    and sparse steps agree up to summation order (documented
//!    bit-tolerance: 1e-4 in the parity tests).
//! 2. **Runtime-path emulation.** `benches/training.rs` uses it as the
//!    honest stand-in for the dense runtime/xla step when measuring the
//!    native backends' speedup, since it performs the same O(n_pad²·d)
//!    work the padded artifact does.
//!
//! Allocation discipline deliberately does NOT apply here: the oracle
//! allocates its tape per call. Only the native backends are hot.

use anyhow::{anyhow, bail, ensure, Result};

use super::manifest::ArtifactMeta;
use super::state::ModelState;
use super::StepMetrics;
use crate::batching::DenseBatch;
use crate::exec::train::{dropout_scale, ADAM_B1, ADAM_B2, ADAM_EPS, LN_EPS};

fn tensor<'a>(
    state: &'a ModelState,
    meta: &ArtifactMeta,
    name: &str,
) -> Result<&'a [f32]> {
    state
        .tensor(meta, name)
        .ok_or_else(|| anyhow!("{}: missing param {name}", meta.id))
}

fn spec(meta: &ArtifactMeta, name: &str) -> Result<(usize, usize)> {
    meta.params
        .iter()
        .find(|p| p.name == name)
        .map(|p| (p.offset, p.size))
        .ok_or_else(|| anyhow!("{}: missing param {name}", meta.id))
}

/// Per-layer (d_in, d_out) pairs derived from the manifest layout.
fn layer_dims(meta: &ArtifactMeta) -> Result<Vec<(usize, usize)>> {
    let mut dims = Vec::with_capacity(meta.layers);
    let mut d_in = meta.feat;
    for l in 0..meta.layers {
        let (_, d_out) = spec(meta, &format!("l{l}.b"))?;
        dims.push((d_in, d_out));
        d_in = d_out;
    }
    Ok(dims)
}

/// Forward tape: everything the backward pass re-reads.
struct Tape {
    /// Linear input per layer (`agg` for gcn, `[h ‖ agg]` for sage).
    a: Vec<Vec<f32>>,
    /// Pre-layernorm linear output per layer (last = logits).
    z: Vec<Vec<f32>>,
    mean: Vec<Vec<f32>>,
    rstd: Vec<Vec<f32>>,
}

/// `agg[d, :] = Σ_s adj[d, s] · h[s, :]` over the dense padded matrix.
fn dense_spmm(adj: &[f32], h: &[f32], n: usize, dim: usize, out: &mut [f32]) {
    for d in 0..n {
        let row = &mut out[d * dim..(d + 1) * dim];
        row.fill(0.0);
        for s in 0..n {
            let w = adj[d * n + s];
            if w == 0.0 {
                continue;
            }
            let hs = &h[s * dim..(s + 1) * dim];
            for (o, &v) in row.iter_mut().zip(hs) {
                *o += w * v;
            }
        }
    }
}

/// `z = a @ w + b` (w row-major `[d_in, d_out]`).
fn dense_linear(
    a: &[f32],
    n: usize,
    d_in: usize,
    w: &[f32],
    b: &[f32],
    d_out: usize,
    out: &mut [f32],
) {
    for i in 0..n {
        let row = &mut out[i * d_out..(i + 1) * d_out];
        row.copy_from_slice(b);
        let ai = &a[i * d_in..(i + 1) * d_in];
        for (k, &av) in ai.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let wk = &w[k * d_out..(k + 1) * d_out];
            for (o, &wv) in row.iter_mut().zip(wk) {
                *o += av * wv;
            }
        }
    }
}

fn forward(
    meta: &ArtifactMeta,
    state: &ModelState,
    dense: &DenseBatch,
    seed: i32,
    dims: &[(usize, usize)],
) -> Result<Tape> {
    let n = dense.n_pad;
    let rate = meta.dropout as f32;
    let mut tape = Tape {
        a: Vec::with_capacity(meta.layers),
        z: Vec::with_capacity(meta.layers),
        mean: Vec::with_capacity(meta.layers),
        rstd: Vec::with_capacity(meta.layers),
    };
    let mut h = dense.x.clone();
    for (l, &(d_in, d_out)) in dims.iter().enumerate() {
        let w = tensor(state, meta, &format!("l{l}.w"))?;
        let b = tensor(state, meta, &format!("l{l}.b"))?;
        let a = match meta.model.as_str() {
            "gcn" => {
                let mut agg = vec![0.0f32; n * d_in];
                dense_spmm(&dense.adj, &h, n, d_in, &mut agg);
                agg
            }
            "sage" => {
                let mut agg = vec![0.0f32; n * d_in];
                dense_spmm(&dense.adj, &h, n, d_in, &mut agg);
                let mut cat = vec![0.0f32; n * 2 * d_in];
                for i in 0..n {
                    cat[i * 2 * d_in..i * 2 * d_in + d_in]
                        .copy_from_slice(&h[i * d_in..(i + 1) * d_in]);
                    cat[i * 2 * d_in + d_in..(i + 1) * 2 * d_in]
                        .copy_from_slice(&agg[i * d_in..(i + 1) * d_in]);
                }
                cat
            }
            other => bail!("host oracle: unsupported model {other:?}"),
        };
        let a_dim = a.len() / n;
        let mut z = vec![0.0f32; n * d_out];
        dense_linear(&a, n, a_dim, w, b, d_out, &mut z);
        let last = l + 1 == meta.layers;
        if !last {
            let g = tensor(state, meta, &format!("l{l}.ln_g"))?;
            let bl = tensor(state, meta, &format!("l{l}.ln_b"))?;
            let mut mean = vec![0.0f32; n];
            let mut rstd = vec![0.0f32; n];
            h.resize(n * d_out, 0.0);
            for i in 0..n {
                let zi = &z[i * d_out..(i + 1) * d_out];
                let mu = zi.iter().sum::<f32>() / d_out as f32;
                let var = zi.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>()
                    / d_out as f32;
                let rs = 1.0 / (var + LN_EPS).sqrt();
                mean[i] = mu;
                rstd[i] = rs;
                for j in 0..d_out {
                    let y = (zi[j] - mu) * rs * g[j] + bl[j];
                    let mut v = y.max(0.0);
                    if rate > 0.0 {
                        v *= dropout_scale(seed, l as u32, i * d_out + j, rate);
                    }
                    h[i * d_out + j] = v;
                }
            }
            tape.mean.push(mean);
            tape.rstd.push(rstd);
        } else {
            tape.mean.push(Vec::new());
            tape.rstd.push(Vec::new());
        }
        tape.a.push(a);
        tape.z.push(z);
    }
    Ok(tape)
}

/// One forward+backward over the padded dense batch, **accumulating**
/// (`+=`) the weight-decayed gradients into the caller-owned `grads`
/// buffer (same contract as the native backends and the reworked
/// [`super::Runtime::grad_step`]).
pub fn host_grad_step(
    meta: &ArtifactMeta,
    state: &ModelState,
    dense: &DenseBatch,
    seed: i32,
    grads: &mut [f32],
) -> Result<StepMetrics> {
    ensure!(
        grads.len() == meta.param_count,
        "grad buffer {} != param_count {}",
        grads.len(),
        meta.param_count
    );
    let n = dense.n_pad;
    let classes = meta.classes;
    let rate = meta.dropout as f32;
    let dims = layer_dims(meta)?;
    let tape = forward(meta, state, dense, seed, &dims)?;

    // ---- masked softmax-CE loss/grad on the logits ----
    let logits = &tape.z[meta.layers - 1];
    let msum: f32 = dense.mask.iter().sum();
    let inv = 1.0 / msum.max(1.0);
    let mut loss_sum = 0.0f32;
    let mut correct = 0.0f32;
    let mut dz = vec![0.0f32; n * classes];
    for i in 0..n {
        if dense.mask[i] == 0.0 {
            continue;
        }
        let row = &logits[i * classes..(i + 1) * classes];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse =
            row.iter().map(|&v| (v - mx).exp()).sum::<f32>().ln() + mx;
        let label = dense.labels[i] as usize;
        loss_sum += lse - row[label];
        let mut pred = 0usize;
        let mut best = row[0];
        for (c, &v) in row.iter().enumerate().skip(1) {
            if v > best {
                best = v;
                pred = c;
            }
        }
        if pred == label {
            correct += 1.0;
        }
        let dr = &mut dz[i * classes..(i + 1) * classes];
        for (c, d) in dr.iter_mut().enumerate() {
            let p = (row[c] - lse).exp();
            *d = (p - f32::from(c == label)) * inv;
        }
    }

    // ---- reverse pass ----
    let mut dz = dz; // current dL/dz[l] (pre-post-op of layer l)
    for l in (0..meta.layers).rev() {
        let (d_in, d_out) = dims[l];
        let a = &tape.a[l];
        let a_dim = a.len() / n;
        let w = tensor(state, meta, &format!("l{l}.w"))?;
        let (w_off, w_len) = spec(meta, &format!("l{l}.w"))?;
        let (b_off, b_len) = spec(meta, &format!("l{l}.b"))?;
        // dW[k, j] += Σ_i a[i, k]·dz[i, j];  db[j] += Σ_i dz[i, j]
        for i in 0..n {
            let dzi = &dz[i * d_out..(i + 1) * d_out];
            for (j, &dv) in dzi.iter().enumerate() {
                grads[b_off + j] += dv;
            }
            let ai = &a[i * a_dim..(i + 1) * a_dim];
            for (k, &av) in ai.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                for (j, &dv) in dzi.iter().enumerate() {
                    grads[w_off + k * d_out + j] += av * dv;
                }
            }
        }
        debug_assert_eq!(w_len, a_dim * d_out);
        debug_assert_eq!(b_len, d_out);
        // da[i, k] = dz[i, :] · w[k, :]
        let mut da = vec![0.0f32; n * a_dim];
        for i in 0..n {
            let dzi = &dz[i * d_out..(i + 1) * d_out];
            for k in 0..a_dim {
                let wk = &w[k * d_out..(k + 1) * d_out];
                da[i * a_dim + k] =
                    dzi.iter().zip(wk).map(|(&x, &y)| x * y).sum();
            }
        }
        // dh = Âᵀ·dagg (+ the direct half for sage)
        let mut dh = vec![0.0f32; n * d_in];
        let dagg_col = if meta.model == "sage" { d_in } else { 0 };
        if meta.model == "sage" {
            for i in 0..n {
                dh[i * d_in..(i + 1) * d_in]
                    .copy_from_slice(&da[i * a_dim..i * a_dim + d_in]);
            }
        }
        for d in 0..n {
            let dd = &da[d * a_dim + dagg_col..d * a_dim + dagg_col + d_in];
            for s in 0..n {
                let wgt = dense.adj[d * n + s];
                if wgt == 0.0 {
                    continue;
                }
                let out = &mut dh[s * d_in..(s + 1) * d_in];
                for (o, &v) in out.iter_mut().zip(dd) {
                    *o += wgt * v;
                }
            }
        }
        if l == 0 {
            break;
        }
        // back through layer l-1's layernorm → relu → dropout
        let pd = d_in; // == dims[l-1].1
        let pl = l - 1;
        let z = &tape.z[pl];
        let mean = &tape.mean[pl];
        let rstd = &tape.rstd[pl];
        let g = tensor(state, meta, &format!("l{pl}.ln_g"))?;
        let bl = tensor(state, meta, &format!("l{pl}.ln_b"))?;
        let (g_off, _) = spec(meta, &format!("l{pl}.ln_g"))?;
        let (bl_off, _) = spec(meta, &format!("l{pl}.ln_b"))?;
        let mut next_dz = vec![0.0f32; n * pd];
        for i in 0..n {
            let zi = &z[i * pd..(i + 1) * pd];
            let mut gx_mean = 0.0f32;
            let mut gxxh_mean = 0.0f32;
            let row = &mut next_dz[i * pd..(i + 1) * pd];
            for j in 0..pd {
                let xhat = (zi[j] - mean[i]) * rstd[i];
                let y = xhat * g[j] + bl[j];
                let mut gr = dh[i * pd + j];
                if rate > 0.0 {
                    gr *= dropout_scale(seed, pl as u32, i * pd + j, rate);
                }
                if y <= 0.0 {
                    gr = 0.0;
                }
                grads[g_off + j] += gr * xhat;
                grads[bl_off + j] += gr;
                let gx = gr * g[j];
                gx_mean += gx;
                gxxh_mean += gx * xhat;
                row[j] = gx; // stash gx; finish after the means
            }
            gx_mean /= pd as f32;
            gxxh_mean /= pd as f32;
            for j in 0..pd {
                let xhat = (zi[j] - mean[i]) * rstd[i];
                row[j] = rstd[i] * (row[j] - gx_mean - xhat * gxxh_mean);
            }
        }
        dz = next_dz;
    }

    // weight decay on the whole flat vector (model.py applies it after
    // autodiff, to every parameter including biases and LN)
    let wd = meta.weight_decay as f32;
    if wd > 0.0 {
        for (gv, &p) in grads.iter_mut().zip(&state.params) {
            *gv += wd * p;
        }
    }
    Ok(StepMetrics {
        loss: loss_sum * inv,
        correct,
        mask_count: msum,
    })
}

/// One fused oracle step: gradients + in-place Adam on `state`.
///
/// The Adam expressions are written out independently of
/// [`crate::exec::train::fused_adam`] so the oracle stays a genuinely
/// separate implementation; both follow `model.py` exactly (1-based
/// step, `powf` bias correction) and the parity tests pin them
/// together.
pub fn host_train_step(
    meta: &ArtifactMeta,
    state: &mut ModelState,
    dense: &DenseBatch,
    lr: f32,
    seed: i32,
) -> Result<StepMetrics> {
    let mut grads = vec![0.0f32; meta.param_count];
    let metrics = host_grad_step(meta, state, dense, seed, &mut grads)?;
    state.step += 1;
    let t = state.step as f32;
    let bc1 = 1.0 - ADAM_B1.powf(t);
    let bc2 = 1.0 - ADAM_B2.powf(t);
    for i in 0..state.params.len() {
        let g = grads[i];
        state.m[i] = ADAM_B1 * state.m[i] + (1.0 - ADAM_B1) * g;
        state.v[i] = ADAM_B2 * state.v[i] + (1.0 - ADAM_B2) * g * g;
        let m_hat = state.m[i] / bc1;
        let v_hat = state.v[i] / bc2;
        state.params[i] -= lr * m_hat / (v_hat.sqrt() + ADAM_EPS);
    }
    Ok(metrics)
}
