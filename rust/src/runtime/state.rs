//! Host-side model state: the flat parameter vector plus Adam moments.
//!
//! Initialization reproduces `python/compile/model.py::init_params`'s
//! *layout* (Glorot-uniform weights, ones for LayerNorm gains, zeros
//! elsewhere) with the Rust PRNG — the artifacts only fix the layout,
//! not the init values, so cross-language bit-parity is not required.

use super::manifest::ArtifactMeta;
use crate::util::Rng;

/// Trainable state threaded through the fused train step.
#[derive(Debug, Clone)]
pub struct ModelState {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    /// 1-based Adam step counter (fed as f32 for bias correction).
    pub step: u64,
}

impl ModelState {
    /// Glorot-style init matching the manifest's parameter layout.
    pub fn init(meta: &ArtifactMeta, seed: u64) -> ModelState {
        let mut rng = Rng::new(seed ^ 0x9D06_57A1);
        let mut params = vec![0.0f32; meta.param_count];
        for spec in &meta.params {
            let slice = &mut params[spec.offset..spec.offset + spec.size];
            if spec.name.ends_with(".w") {
                let (fan_in, fan_out) = (spec.shape[0], spec.shape[1]);
                let limit = (6.0 / (fan_in + fan_out) as f32).sqrt();
                for x in slice.iter_mut() {
                    *x = rng.uniform(-limit, limit);
                }
            } else if spec.name.ends_with(".a_src")
                || spec.name.ends_with(".a_dst")
            {
                let limit = (6.0 / (spec.size + 1) as f32).sqrt();
                for x in slice.iter_mut() {
                    *x = rng.uniform(-limit, limit);
                }
            } else if spec.name.ends_with(".ln_g") {
                slice.fill(1.0);
            } // biases and ln_b stay zero
        }
        ModelState {
            params,
            m: vec![0.0; meta.param_count],
            v: vec![0.0; meta.param_count],
            step: 0,
        }
    }

    /// View of one named parameter tensor.
    pub fn tensor<'a>(&'a self, meta: &ArtifactMeta, name: &str) -> Option<&'a [f32]> {
        meta.params
            .iter()
            .find(|p| p.name == name)
            .map(|p| &self.params[p.offset..p.offset + p.size])
    }

    pub fn memory_bytes(&self) -> usize {
        (self.params.len() + self.m.len() + self.v.len()) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;

    const SAMPLE: &str = r#"{"version": 1, "artifacts": [
      {"id": "t", "model": "gcn", "kind": "train", "n_pad": 64,
       "feat": 8, "classes": 4, "hidden": 8, "layers": 2, "heads": 4,
       "dropout": 0.0, "weight_decay": 0.0, "param_count": 92,
       "inputs": [], "outputs": [],
       "params": [
         {"name": "l0.w", "shape": [8, 8], "offset": 0, "size": 64},
         {"name": "l0.b", "shape": [8], "offset": 64, "size": 8},
         {"name": "l0.ln_g", "shape": [8], "offset": 72, "size": 8},
         {"name": "l0.ln_b", "shape": [8], "offset": 80, "size": 8},
         {"name": "l1.a_src", "shape": [1, 4], "offset": 88, "size": 4}],
       "path": "t.hlo.txt"}]}"#;

    fn meta() -> ArtifactMeta {
        Manifest::parse(SAMPLE).unwrap().artifacts[0].clone()
    }

    #[test]
    fn init_respects_layout() {
        let m = meta();
        let s = ModelState::init(&m, 1);
        assert_eq!(s.params.len(), 92);
        // weights non-zero, bounded by glorot limit
        let limit = (6.0f32 / 16.0).sqrt();
        let w = s.tensor(&m, "l0.w").unwrap();
        assert!(w.iter().any(|&x| x != 0.0));
        assert!(w.iter().all(|&x| x.abs() <= limit));
        // bias zero, ln_g one, ln_b zero
        assert!(s.tensor(&m, "l0.b").unwrap().iter().all(|&x| x == 0.0));
        assert!(s.tensor(&m, "l0.ln_g").unwrap().iter().all(|&x| x == 1.0));
        assert!(s.tensor(&m, "l0.ln_b").unwrap().iter().all(|&x| x == 0.0));
        // attention vectors initialized
        assert!(s
            .tensor(&m, "l1.a_src")
            .unwrap()
            .iter()
            .any(|&x| x != 0.0));
        // adam state zeroed
        assert!(s.m.iter().all(|&x| x == 0.0));
        assert_eq!(s.step, 0);
    }

    #[test]
    fn init_is_seeded() {
        let m = meta();
        let a = ModelState::init(&m, 1);
        let b = ModelState::init(&m, 1);
        let c = ModelState::init(&m, 2);
        assert_eq!(a.params, b.params);
        assert_ne!(a.params, c.params);
    }
}
