//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime. Parsed with the in-house JSON reader (no serde
//! offline).

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::{self, Json};

/// One tensor slot in the flat parameter vector.
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

/// One AOT-lowered executable and its static hyperparameters.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub id: String,
    pub model: String,
    pub kind: String, // "train" | "infer"
    pub n_pad: usize,
    pub feat: usize,
    pub classes: usize,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub dropout: f64,
    pub weight_decay: f64,
    pub param_count: usize,
    pub params: Vec<ParamSpec>,
    pub path: String,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactMeta>,
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("missing numeric field {key}"))
}

fn req_f64(j: &Json, key: &str) -> Result<f64> {
    j.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| anyhow!("missing numeric field {key}"))
}

fn req_str(j: &Json, key: &str) -> Result<String> {
    j.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| anyhow!("missing string field {key}"))
}

impl Manifest {
    /// Parse `manifest.json`.
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {path:?}"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let doc = json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let version = req_usize(&doc, "version")?;
        anyhow::ensure!(version == 1, "unsupported manifest version {version}");
        let arts = doc
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("missing artifacts array"))?;
        let mut artifacts = Vec::with_capacity(arts.len());
        for a in arts {
            let mut params = Vec::new();
            for p in a
                .get("params")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing params"))?
            {
                params.push(ParamSpec {
                    name: req_str(p, "name")?,
                    shape: p
                        .get("shape")
                        .and_then(Json::as_arr)
                        .ok_or_else(|| anyhow!("missing shape"))?
                        .iter()
                        .map(|d| d.as_usize().unwrap_or(0))
                        .collect(),
                    offset: req_usize(p, "offset")?,
                    size: req_usize(p, "size")?,
                });
            }
            let meta = ArtifactMeta {
                id: req_str(a, "id")?,
                model: req_str(a, "model")?,
                kind: req_str(a, "kind")?,
                n_pad: req_usize(a, "n_pad")?,
                feat: req_usize(a, "feat")?,
                classes: req_usize(a, "classes")?,
                hidden: req_usize(a, "hidden")?,
                layers: req_usize(a, "layers")?,
                heads: req_usize(a, "heads")?,
                dropout: req_f64(a, "dropout")?,
                weight_decay: req_f64(a, "weight_decay")?,
                param_count: req_usize(a, "param_count")?,
                params,
                path: req_str(a, "path")?,
            };
            // structural invariants
            let mut off = 0usize;
            for p in &meta.params {
                anyhow::ensure!(
                    p.offset == off,
                    "{}: param {} offset {} != {off}",
                    meta.id,
                    p.name,
                    p.offset
                );
                anyhow::ensure!(
                    p.size == p.shape.iter().product::<usize>().max(1),
                    "{}: param {} size mismatch",
                    meta.id,
                    p.name
                );
                off += p.size;
            }
            anyhow::ensure!(
                off == meta.param_count,
                "{}: param_count {} != layout {off}",
                meta.id,
                meta.param_count
            );
            artifacts.push(meta);
        }
        Ok(Manifest { artifacts })
    }

    /// Find an artifact by exact id.
    pub fn by_id(&self, id: &str) -> Option<&ArtifactMeta> {
        self.artifacts.iter().find(|a| a.id == id)
    }

    /// Find the artifact for (model, kind, bucket).
    pub fn find(&self, model: &str, kind: &str, n_pad: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.model == model && a.kind == kind && a.n_pad == n_pad)
    }

    /// Available buckets for a model/kind, ascending.
    pub fn buckets(&self, model: &str, kind: &str) -> Vec<usize> {
        let mut b: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.model == model && a.kind == kind)
            .map(|a| a.n_pad)
            .collect();
        b.sort_unstable();
        b
    }

    /// Smallest bucket that fits `n` nodes for (model, kind).
    pub fn bucket_meta(&self, model: &str, kind: &str, n: usize) -> Option<&ArtifactMeta> {
        self.buckets(model, kind)
            .into_iter()
            .find(|&b| b >= n)
            .and_then(|b| self.find(model, kind, b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{"version": 1, "artifacts": [
      {"id": "gcn_train_n256", "model": "gcn", "kind": "train",
       "n_pad": 256, "feat": 64, "classes": 10, "hidden": 64,
       "layers": 3, "heads": 4, "dropout": 0.3, "weight_decay": 0.0001,
       "param_count": 10,
       "inputs": [], "outputs": [],
       "params": [{"name": "l0.w", "shape": [2, 3], "offset": 0, "size": 6},
                   {"name": "l0.b", "shape": [4], "offset": 6, "size": 4}],
       "path": "gcn_train_n256.hlo.txt"},
      {"id": "gcn_train_n512", "model": "gcn", "kind": "train",
       "n_pad": 512, "feat": 64, "classes": 10, "hidden": 64,
       "layers": 3, "heads": 4, "dropout": 0.3, "weight_decay": 0.0001,
       "param_count": 0, "inputs": [], "outputs": [], "params": [],
       "path": "gcn_train_n512.hlo.txt"}
    ]}"#;

    #[test]
    fn parses_and_indexes() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        assert!(m.by_id("gcn_train_n256").is_some());
        assert!(m.find("gcn", "train", 512).is_some());
        assert!(m.find("gat", "train", 512).is_none());
        assert_eq!(m.buckets("gcn", "train"), vec![256, 512]);
        assert_eq!(m.bucket_meta("gcn", "train", 300).unwrap().n_pad, 512);
        assert!(m.bucket_meta("gcn", "train", 4096).is_none());
    }

    #[test]
    fn rejects_bad_layout() {
        let bad = SAMPLE.replace("\"offset\": 6", "\"offset\": 7");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_wrong_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 2");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn parses_shipped_manifest_if_built() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json");
        if path.exists() {
            let m = Manifest::load(&path).unwrap();
            assert!(m.artifacts.len() >= 2);
            for a in &m.artifacts {
                assert!(path.parent().unwrap().join(&a.path).exists());
            }
        }
    }
}
