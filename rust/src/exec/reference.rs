//! Scalar oracle backend: delegates to `inference::fullgraph::forward`
//! unchanged. Every other executor is property-tested against this one
//! (`rust/tests/exec.rs`), so its output defines correctness.

use crate::exec::{ExecScratch, Executor, PlanView};
use crate::inference::fullgraph::{self, SparseGraphRef};
use crate::runtime::{ArtifactMeta, ModelState};

pub struct ReferenceExecutor;

impl Executor for ReferenceExecutor {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn forward(
        &self,
        meta: &ArtifactMeta,
        state: &ModelState,
        view: &PlanView,
        x: &[f32],
        _scratch: &mut ExecScratch,
        out: &mut Vec<f32>,
    ) {
        let g = SparseGraphRef {
            n: view.n,
            edge_src: view.edge_src,
            edge_dst: view.edge_dst,
            weights: view.weights,
        };
        let logits = fullgraph::forward(meta, state, &g, x);
        out.clear();
        out.extend_from_slice(&logits);
    }
}
