//! Scalar native training backend: straightforward loops over the same
//! dst-major CSR the blocked backend uses. This is the readable
//! baseline the blocked kernels are measured against (and the parity
//! anchor: both backends share every non-kernel line of the step via
//! [`super::train::forward_backward`], so any divergence is isolated
//! to loop blocking).

use super::train::{
    forward_backward, train_step_impl, TrainBatch, TrainExecutor,
    TrainKernels, TrainScratch,
};
use crate::runtime::{ArtifactMeta, ModelState, StepMetrics};

pub(crate) struct RefKernels;

impl TrainKernels for RefKernels {
    fn spmm(
        &self,
        off: &[u32],
        src: &[u32],
        w: &[f32],
        h: &[f32],
        n: usize,
        dim: usize,
        out: &mut [f32],
    ) {
        for d in 0..n {
            let (lo, hi) = (off[d] as usize, off[d + 1] as usize);
            let row = &mut out[d * dim..(d + 1) * dim];
            row.fill(0.0);
            for e in lo..hi {
                let s = src[e] as usize;
                let we = w[e];
                let hs = &h[s * dim..(s + 1) * dim];
                for j in 0..dim {
                    row[j] += we * hs[j];
                }
            }
        }
    }

    fn spmm_t(
        &self,
        off: &[u32],
        src: &[u32],
        w: &[f32],
        dagg: &[f32],
        n: usize,
        dim: usize,
        dh: &mut [f32],
    ) {
        for d in 0..n {
            let (lo, hi) = (off[d] as usize, off[d + 1] as usize);
            let dd = &dagg[d * dim..(d + 1) * dim];
            for e in lo..hi {
                let s = src[e] as usize;
                let we = w[e];
                let out = &mut dh[s * dim..(s + 1) * dim];
                for j in 0..dim {
                    out[j] += we * dd[j];
                }
            }
        }
    }

    fn linear(
        &self,
        x: &[f32],
        n: usize,
        d_in: usize,
        w: &[f32],
        b: &[f32],
        d_out: usize,
        out: &mut [f32],
    ) {
        for i in 0..n {
            let row = &mut out[i * d_out..(i + 1) * d_out];
            row.copy_from_slice(b);
            let xi = &x[i * d_in..(i + 1) * d_in];
            for (k, &xv) in xi.iter().enumerate() {
                let wk = &w[k * d_out..(k + 1) * d_out];
                for j in 0..d_out {
                    row[j] += xv * wk[j];
                }
            }
        }
    }

    fn linear_wgrad(
        &self,
        a: &[f32],
        dz: &[f32],
        n: usize,
        d_a: usize,
        d_out: usize,
        dw: &mut [f32],
        db: &mut [f32],
    ) {
        for i in 0..n {
            let dzi = &dz[i * d_out..(i + 1) * d_out];
            for j in 0..d_out {
                db[j] += dzi[j];
            }
            let ai = &a[i * d_a..(i + 1) * d_a];
            for (k, &av) in ai.iter().enumerate() {
                let dwk = &mut dw[k * d_out..(k + 1) * d_out];
                for j in 0..d_out {
                    dwk[j] += av * dzi[j];
                }
            }
        }
    }

    fn linear_igrad(
        &self,
        dz: &[f32],
        w: &[f32],
        n: usize,
        d_a: usize,
        d_out: usize,
        da: &mut [f32],
    ) {
        for i in 0..n {
            let dzi = &dz[i * d_out..(i + 1) * d_out];
            for k in 0..d_a {
                let wk = &w[k * d_out..(k + 1) * d_out];
                let mut s = 0.0f32;
                for j in 0..d_out {
                    s += dzi[j] * wk[j];
                }
                da[i * d_a + k] = s;
            }
        }
    }
}

/// The scalar training backend.
pub struct ReferenceTrainExecutor;

impl TrainExecutor for ReferenceTrainExecutor {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn train_step(
        &self,
        meta: &ArtifactMeta,
        state: &mut ModelState,
        batch: &TrainBatch,
        lr: f32,
        seed: i32,
        scratch: &mut TrainScratch,
    ) -> StepMetrics {
        train_step_impl(&RefKernels, meta, state, batch, lr, seed, scratch)
    }

    fn grad_step(
        &self,
        meta: &ArtifactMeta,
        state: &ModelState,
        batch: &TrainBatch,
        seed: i32,
        grads: &mut [f32],
        scratch: &mut TrainScratch,
    ) -> StepMetrics {
        forward_backward(&RefKernels, meta, state, batch, seed, scratch, grads)
    }
}
