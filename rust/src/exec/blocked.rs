//! SIMD-blocked CPU forward: the default serving backend.
//!
//! What it changes vs the scalar reference (and why it's faster):
//!
//! * **CSR conversion per batch.** The COO edge slices a plan carries
//!   are counting-sorted by destination into `ExecScratch` (O(E), one
//!   pass, stable — per-destination edge order matches the reference's
//!   global scan, keeping f32 sums identical). Aggregation then becomes
//!   a sequential dst-major sweep: each output row is produced once,
//!   from a contiguous run of (src, weight) pairs — the consecutive-
//!   access layout IBMB's precomputed batches exist to enable
//!   (PAPER.md §1, §5).
//! * **No zero-fill.** Because the sweep writes each destination row
//!   exactly once from register accumulators, the old
//!   `out.fill(0.0)`-then-scatter `spmm` disappears: rows outside the
//!   batch's live set are never touched.
//! * **8-lane blocks.** Inner loops run over `LANES = 8` column chunks
//!   with `[f32; 8]` stack accumulators — fixed-width slices the
//!   autovectorizer keeps in vector registers, the same output-block
//!   accumulator shape as the Pallas tiled matmul in
//!   `python/compile/kernels/spmm.py`.
//! * **Fused normalize+aggregate.** Degree-normalized edge weights are
//!   folded into the CSR payload at build time, so the sweep does one
//!   fused multiply-add per (edge, lane) — the `index_add` scatter
//!   idiom from SNIPPETS.md, turned inside out into a gather.
//! * **Zero steady-state allocations.** `linear` writes into scratch
//!   instead of returning a fresh `Vec` per layer; GAT's per-head
//!   score/softmax temporaries live in scratch too.
//! * **Optional f16 features.** `blocked-f16` round-trips the feature
//!   block through IEEE half precision before layer 0 — halves feature
//!   staging bandwidth when a real f16 feature store lands, at a
//!   documented looser parity bound (DESIGN.md §13).

use crate::exec::{ExecScratch, Executor, PlanView};
use crate::runtime::{ArtifactMeta, ModelState};

/// Fixed SIMD block width: 8 f32 lanes (one AVX2 register).
pub const LANES: usize = 8;

pub struct BlockedCpuExecutor {
    quantize_f16: bool,
}

impl BlockedCpuExecutor {
    pub fn new(quantize_f16: bool) -> BlockedCpuExecutor {
        BlockedCpuExecutor { quantize_f16 }
    }

    pub fn quantizes(&self) -> bool {
        self.quantize_f16
    }
}

fn tensor<'a>(state: &'a ModelState, meta: &ArtifactMeta, name: &str) -> &'a [f32] {
    state
        .tensor(meta, name)
        .unwrap_or_else(|| panic!("missing param {name}"))
}

/// Counting-sort the batch's COO edges into dst-major CSR form.
/// `off` must be `n + 1` long; `csr_src`/`csr_w` at least `E` long.
/// Stable: edges sharing a destination keep their COO order, so
/// accumulation order (and thus f32 results) match the reference scan.
pub(crate) fn build_csr(
    view: &PlanView,
    off: &mut [u32],
    csr_src: &mut [u32],
    csr_w: &mut [f32],
) {
    let n = view.n;
    debug_assert_eq!(off.len(), n + 1);
    off.fill(0);
    for &d in view.edge_dst {
        off[d as usize + 1] += 1;
    }
    for i in 0..n {
        off[i + 1] += off[i];
    }
    for ((&s, &d), &w) in view.edge_src.iter().zip(view.edge_dst).zip(view.weights) {
        let pos = off[d as usize] as usize;
        csr_src[pos] = s;
        csr_w[pos] = w;
        off[d as usize] += 1;
    }
    // the fill pass advanced each row's start to its end; shift back
    for d in (1..=n).rev() {
        off[d] = off[d - 1];
    }
    off[0] = 0;
}

/// Dst-major blocked SpMM: `out[d] = Σ_e w_e * h[src_e]` over row `d`'s
/// CSR range. Each row is written exactly once (no prior zero-fill);
/// the per-edge weight multiply is fused into the accumulate.
pub(crate) fn spmm_blocked(
    off: &[u32],
    csr_src: &[u32],
    csr_w: &[f32],
    h: &[f32],
    n: usize,
    dim: usize,
    out: &mut [f32],
) {
    let blocks = dim / LANES;
    let rem = dim % LANES;
    for d in 0..n {
        let (lo, hi) = (off[d] as usize, off[d + 1] as usize);
        let row = &mut out[d * dim..(d + 1) * dim];
        for b in 0..blocks {
            let j0 = b * LANES;
            let mut acc = [0.0f32; LANES];
            for e in lo..hi {
                let src = &h[csr_src[e] as usize * dim + j0..][..LANES];
                let w = csr_w[e];
                for j in 0..LANES {
                    acc[j] += w * src[j];
                }
            }
            row[j0..j0 + LANES].copy_from_slice(&acc);
        }
        if rem != 0 {
            let j0 = blocks * LANES;
            let mut acc = [0.0f32; LANES];
            for e in lo..hi {
                let sbase = csr_src[e] as usize * dim + j0;
                let w = csr_w[e];
                for (j, a) in acc[..rem].iter_mut().enumerate() {
                    *a += w * h[sbase + j];
                }
            }
            row[j0..].copy_from_slice(&acc[..rem]);
        }
    }
}

/// Tiled row-major `x [n, d_in] @ w [d_in, d_out] (+ b)` into `out`.
/// Output-block accumulators ([f32; 8] per j-block, k innermost) keep
/// the hot values in registers and drop both the per-row `Vec`
/// allocation and the `xv != 0` branch of the reference kernel.
pub(crate) fn linear_blocked(
    x: &[f32],
    n: usize,
    d_in: usize,
    w: &[f32],
    b: Option<&[f32]>,
    d_out: usize,
    out: &mut [f32],
) {
    let blocks = d_out / LANES;
    let rem = d_out % LANES;
    for i in 0..n {
        let xi = &x[i * d_in..(i + 1) * d_in];
        let oi = &mut out[i * d_out..(i + 1) * d_out];
        for bl in 0..blocks {
            let j0 = bl * LANES;
            let mut acc = [0.0f32; LANES];
            if let Some(b) = b {
                acc.copy_from_slice(&b[j0..j0 + LANES]);
            }
            for (k, &xv) in xi.iter().enumerate() {
                let wr = &w[k * d_out + j0..][..LANES];
                for j in 0..LANES {
                    acc[j] += xv * wr[j];
                }
            }
            oi[j0..j0 + LANES].copy_from_slice(&acc);
        }
        if rem != 0 {
            let j0 = blocks * LANES;
            let mut acc = [0.0f32; LANES];
            if let Some(b) = b {
                acc[..rem].copy_from_slice(&b[j0..]);
            }
            for (k, &xv) in xi.iter().enumerate() {
                let wbase = k * d_out + j0;
                for (j, a) in acc[..rem].iter_mut().enumerate() {
                    *a += xv * w[wbase + j];
                }
            }
            oi[j0..].copy_from_slice(&acc[..rem]);
        }
    }
}

/// In-place LayerNorm + ReLU over the first `n` rows. Same summation
/// order as the reference (bit-identical output).
fn layernorm_relu(x: &mut [f32], n: usize, dim: usize, g: &[f32], b: &[f32]) {
    const EPS: f32 = 1e-5;
    for i in 0..n {
        let row = &mut x[i * dim..(i + 1) * dim];
        let mean: f32 = row.iter().sum::<f32>() / dim as f32;
        let var: f32 =
            row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / dim as f32;
        let rstd = (var + EPS).sqrt().recip();
        for (j, v) in row.iter_mut().enumerate() {
            *v = ((*v - mean) * rstd * g[j] + b[j]).max(0.0);
        }
    }
}

/// One GAT layer over the CSR view. The three reference edge scans
/// (max, exp-sum, accumulate) fuse into a single per-destination pass:
/// each row's incoming edges are contiguous, so scores stay in `edge_e`
/// segments and the softmax never leaves cache. Per-destination edge
/// order matches the reference scan, so sums are bit-identical.
#[allow(clippy::too_many_arguments)]
fn gat_layer_blocked(
    meta: &ArtifactMeta,
    state: &ModelState,
    l: usize,
    n: usize,
    off: &[u32],
    csr_src: &[u32],
    h: &[f32],
    d_in: usize,
    hw: &mut [f32],
    s_row: &mut [f32],
    s_col: &mut [f32],
    edge_e: &mut [f32],
    out: &mut [f32],
) -> usize {
    let last = l == meta.layers - 1;
    let heads = if last { 1 } else { meta.heads };
    let w = tensor(state, meta, &format!("l{l}.w"));
    let b = tensor(state, meta, &format!("l{l}.b"));
    let a_src = tensor(state, meta, &format!("l{l}.a_src"));
    let a_dst = tensor(state, meta, &format!("l{l}.a_dst"));
    let d_total = b.len();
    let dh = d_total / heads;
    linear_blocked(h, n, d_in, w, None, d_total, hw);

    for hd in 0..heads {
        let ah_src = &a_src[hd * dh..(hd + 1) * dh];
        let ah_dst = &a_dst[hd * dh..(hd + 1) * dh];
        for i in 0..n {
            let v = &hw[i * d_total + hd * dh..i * d_total + (hd + 1) * dh];
            s_row[i] = v.iter().zip(ah_src).map(|(a, b)| a * b).sum();
            s_col[i] = v.iter().zip(ah_dst).map(|(a, b)| a * b).sum();
        }
        for d in 0..n {
            let (lo, hi) = (off[d] as usize, off[d + 1] as usize);
            // LeakyReLU scores + running max for the stable softmax
            let mut mx = f32::NEG_INFINITY;
            for e in lo..hi {
                let raw = s_row[d] + s_col[csr_src[e] as usize];
                let sc = if raw >= 0.0 { raw } else { 0.2 * raw };
                edge_e[e] = sc;
                mx = mx.max(sc);
            }
            let mut sum = 0.0f32;
            for e in lo..hi {
                let v = (edge_e[e] - mx).exp();
                edge_e[e] = v;
                sum += v;
            }
            let ob = &mut out[d * d_total + hd * dh..d * d_total + (hd + 1) * dh];
            ob.fill(0.0); // this row+head block only — written once per batch
            for e in lo..hi {
                let attn = edge_e[e] / sum;
                let src =
                    &hw[csr_src[e] as usize * d_total + hd * dh..][..dh];
                for (o, &x) in ob.iter_mut().zip(src) {
                    *o += attn * x;
                }
            }
        }
    }
    for i in 0..n {
        let row = &mut out[i * d_total..(i + 1) * d_total];
        for (o, &bv) in row.iter_mut().zip(b) {
            *o += bv;
        }
    }
    d_total
}

// ---- f16 feature quantization ---------------------------------------
//
// Manual IEEE 754 binary16 conversion (no `half` crate in the offline
// build). Round-to-nearest on the mantissa; values below the half
// min-normal collapse to scaled subnormals; |v| >= 65520 saturates to
// infinity. Relative round-trip error is <= 2^-11 for normal values —
// the documented f16 parity bound in rust/tests/exec.rs derives from
// this.

pub(crate) fn f32_to_f16_bits(v: f32) -> u16 {
    let bits = v.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let av = f32::from_bits(bits & 0x7fff_ffff);
    if av.is_nan() {
        return sign | 0x7e00;
    }
    if av >= 65520.0 {
        return sign | 0x7c00; // rounds to +/- inf
    }
    if av < f32::from_bits(0x3880_0000) {
        // below the f16 min normal (2^-14): magnitude in units of the
        // subnormal ulp 2^-24. q == 1024 correctly carries into the
        // min-normal encoding (0x400).
        let q = (av * 16_777_216.0).round() as u32;
        return sign | q as u16;
    }
    let e = (bits >> 23) & 0xff;
    let m = bits & 0x7f_ffff;
    let mut out = (((e - 112) << 10) | (m >> 13)) as u32;
    if m & 0x1000 != 0 {
        out += 1; // round up; mantissa carry correctly bumps the exponent
    }
    sign | out as u16
}

pub(crate) fn f16_bits_to_f32(bits: u16) -> f32 {
    let sign = ((bits & 0x8000) as u32) << 16;
    let exp = ((bits >> 10) & 0x1f) as u32;
    let mant = (bits & 0x3ff) as u32;
    let out = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // subnormal: renormalize into the f32 exponent range
            let mut e = 113u32;
            let mut m = mant;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | (e << 23) | ((m & 0x3ff) << 13)
        }
    } else if exp == 31 {
        sign | 0x7f80_0000 | (mant << 13)
    } else {
        sign | ((exp + 112) << 23) | (mant << 13)
    };
    f32::from_bits(out)
}

impl Executor for BlockedCpuExecutor {
    fn name(&self) -> &'static str {
        if self.quantize_f16 {
            "blocked-f16"
        } else {
            "blocked"
        }
    }

    fn forward(
        &self,
        meta: &ArtifactMeta,
        state: &ModelState,
        view: &PlanView,
        x: &[f32],
        scratch: &mut ExecScratch,
        out: &mut Vec<f32>,
    ) {
        let n = view.n;
        let e = view.num_edges();
        assert_eq!(x.len(), n * meta.feat);
        scratch.ensure(meta, state, n, e);
        build_csr(
            view,
            &mut scratch.csr_off[..n + 1],
            &mut scratch.csr_src[..e],
            &mut scratch.csr_w[..e],
        );

        // layer 0 input: features, optionally round-tripped through f16
        if self.quantize_f16 {
            let q = &mut scratch.q16[..n * meta.feat];
            for (qi, &v) in q.iter_mut().zip(x) {
                *qi = f32_to_f16_bits(v);
            }
            for (hi, &qi) in scratch.h[..n * meta.feat].iter_mut().zip(q.iter()) {
                *hi = f16_bits_to_f32(qi);
            }
        } else {
            scratch.h[..n * meta.feat].copy_from_slice(x);
        }

        let off = &scratch.csr_off[..n + 1];
        let csr_src = &scratch.csr_src[..e];
        let csr_w = &scratch.csr_w[..e];
        let mut dim = meta.feat;
        for l in 0..meta.layers {
            let d_out = match meta.model.as_str() {
                "gcn" => {
                    spmm_blocked(off, csr_src, csr_w, &scratch.h, n, dim, &mut scratch.agg);
                    let w = tensor(state, meta, &format!("l{l}.w"));
                    let b = tensor(state, meta, &format!("l{l}.b"));
                    let d_out = b.len();
                    linear_blocked(&scratch.agg, n, dim, w, Some(b), d_out, &mut scratch.h2);
                    d_out
                }
                "sage" => {
                    spmm_blocked(off, csr_src, csr_w, &scratch.h, n, dim, &mut scratch.agg);
                    // concat [h ‖ Âh], interleaved per row
                    for i in 0..n {
                        scratch.cat[i * 2 * dim..i * 2 * dim + dim]
                            .copy_from_slice(&scratch.h[i * dim..(i + 1) * dim]);
                        scratch.cat[i * 2 * dim + dim..(i + 1) * 2 * dim]
                            .copy_from_slice(&scratch.agg[i * dim..(i + 1) * dim]);
                    }
                    let w = tensor(state, meta, &format!("l{l}.w"));
                    let b = tensor(state, meta, &format!("l{l}.b"));
                    let d_out = b.len();
                    linear_blocked(
                        &scratch.cat,
                        n,
                        2 * dim,
                        w,
                        Some(b),
                        d_out,
                        &mut scratch.h2,
                    );
                    d_out
                }
                "gat" => gat_layer_blocked(
                    meta,
                    state,
                    l,
                    n,
                    off,
                    csr_src,
                    &scratch.h,
                    dim,
                    &mut scratch.hw,
                    &mut scratch.s_row,
                    &mut scratch.s_col,
                    &mut scratch.edge_e,
                    &mut scratch.h2,
                ),
                other => panic!("unknown model {other}"),
            };
            if l != meta.layers - 1 {
                let gm = tensor(state, meta, &format!("l{l}.ln_g"));
                let bt = tensor(state, meta, &format!("l{l}.ln_b"));
                layernorm_relu(&mut scratch.h2, n, d_out, gm, bt);
            }
            std::mem::swap(&mut scratch.h, &mut scratch.h2);
            dim = d_out;
        }
        debug_assert_eq!(dim, meta.classes);
        out.clear();
        out.extend_from_slice(&scratch.h[..n * meta.classes]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::testutil::{ring_graph, toy_meta};
    use crate::exec::ReferenceExecutor;

    fn run(
        exec: &dyn Executor,
        model: &str,
        seed: u64,
        n: usize,
        scratch: &mut ExecScratch,
    ) -> Vec<f32> {
        let meta = toy_meta(model);
        let state = ModelState::init(&meta, seed);
        let (src, dst, w) = ring_graph(n);
        let view = PlanView {
            n,
            edge_src: &src,
            edge_dst: &dst,
            weights: &w,
        };
        let x: Vec<f32> = (0..n * 4).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut out = Vec::new();
        exec.forward(&meta, &state, &view, &x, scratch, &mut out);
        out
    }

    #[test]
    fn blocked_matches_reference_on_ring() {
        for model in ["gcn", "sage", "gat"] {
            let mut s1 = ExecScratch::new();
            let mut s2 = ExecScratch::new();
            let want = run(&ReferenceExecutor, model, 3, 12, &mut s1);
            let got = run(&BlockedCpuExecutor::new(false), model, 3, 12, &mut s2);
            assert_eq!(want.len(), got.len(), "{model}");
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                assert!((a - b).abs() <= 1e-5, "{model} [{i}]: {a} vs {b}");
            }
        }
    }

    #[test]
    fn scratch_reuse_across_shrinking_batches_is_clean() {
        // run a big batch, then a smaller one in the SAME scratch; the
        // small batch must match a fresh-scratch run exactly (no stale
        // rows leak through the no-zero-fill kernels)
        for model in ["gcn", "sage", "gat"] {
            let exec = BlockedCpuExecutor::new(false);
            let mut reused = ExecScratch::new();
            let _big = run(&exec, model, 9, 24, &mut reused);
            let got = run(&exec, model, 5, 8, &mut reused);
            let mut fresh = ExecScratch::new();
            let want = run(&exec, model, 5, 8, &mut fresh);
            assert_eq!(want, got, "{model}: stale scratch state leaked");
        }
    }

    #[test]
    fn f16_round_trip_error_is_bounded() {
        for i in 0..4096 {
            let v = ((i as f32) * 0.731 - 1500.0) * 1.7;
            let r = f16_bits_to_f32(f32_to_f16_bits(v));
            let tol = v.abs().max(6.2e-5) * 1.0e-3;
            assert!((v - r).abs() <= tol, "{v} -> {r}");
        }
        // specials
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(0.0)), 0.0);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1.0)), 1.0);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(-2.5)), -2.5);
        assert!(f16_bits_to_f32(f32_to_f16_bits(1e9)).is_infinite());
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1.0e-9)), 0.0);
    }

    #[test]
    fn f16_executor_stays_near_reference() {
        for model in ["gcn", "sage", "gat"] {
            let mut s1 = ExecScratch::new();
            let mut s2 = ExecScratch::new();
            let want = run(&ReferenceExecutor, model, 7, 16, &mut s1);
            let got = run(&BlockedCpuExecutor::new(true), model, 7, 16, &mut s2);
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                assert!((a - b).abs() <= 0.05, "{model} [{i}]: {a} vs {b}");
            }
        }
    }

    #[test]
    fn csr_build_is_stable_and_complete() {
        // duplicate destinations keep COO order; offsets tile the edges
        let src = [3u32, 1, 0, 2, 1];
        let dst = [1u32, 0, 1, 3, 1];
        let w = [0.1f32, 0.2, 0.3, 0.4, 0.5];
        let view = PlanView {
            n: 4,
            edge_src: &src,
            edge_dst: &dst,
            weights: &w,
        };
        let mut off = vec![0u32; 5];
        let mut cs = vec![0u32; 5];
        let mut cw = vec![0f32; 5];
        build_csr(&view, &mut off, &mut cs, &mut cw);
        assert_eq!(off, vec![0, 1, 4, 4, 5]);
        assert_eq!(cs, vec![1, 3, 0, 1, 2]);
        assert_eq!(cw, vec![0.2, 0.1, 0.3, 0.5, 0.4]);
    }
}
