//! Training-capable executors: fused forward + backward + softmax-CE +
//! Adam over the **sparse CSR batch representation** (DESIGN.md §16).
//!
//! The runtime/xla path steps through a dense padded `n_pad × n_pad`
//! adjacency and round-trips params/m/v through device literals every
//! step. A [`TrainExecutor`] does the same math directly on the plan's
//! edge list: no padding, no dense adjacency, no state copies — Adam
//! updates [`ModelState`] in place and all intermediates live on a
//! grow-never-shrink [`TrainScratch`] (zero steady-state allocations).
//!
//! Both native backends share one orchestration ([`forward_backward`]):
//! a [`TrainKernels`] impl supplies the five dense/sparse primitives
//! (forward SpMM, transpose-CSR scatter SpMM, forward linear, weight
//! grad, input grad) while the layernorm/relu/dropout algebra, the
//! masked softmax-CE head and the Adam sweep are common code. The
//! scalar reference backend and the `[f32; 8]`-lane blocked backend
//! therefore differ only in loop blocking — they traverse the same
//! stable dst-major CSR in the same order, so their results differ only
//! by lane-partial summation order (≤ a few ulps; the parity contract
//! against the dense oracle in `runtime/host.rs` is 1e-4).
//!
//! GAT is out of scope for the native path (its attention VJP is not
//! implemented); [`TrainExecutorKind::build`] and the trainer both
//! direct it to the runtime path.

use anyhow::{bail, Result};

use super::blocked::build_csr;
use super::PlanView;
use crate::runtime::{ArtifactMeta, ModelState, StepMetrics};

/// Adam β₁ (matches `python/compile/model.py`).
pub const ADAM_B1: f32 = 0.9;
/// Adam β₂.
pub const ADAM_B2: f32 = 0.999;
/// Adam ε.
pub const ADAM_EPS: f32 = 1e-8;
/// LayerNorm variance epsilon (`python/compile/kernels/layernorm.py`).
pub const LN_EPS: f32 = 1e-5;

/// One sparse training batch: the plan's edge view plus gathered
/// features and labels. `x` is row-major `[n, feat]` over the plan's
/// node order (outputs first); `labels[i]` is the class of node `i`
/// (only the first `num_outputs` rows enter the loss).
pub struct TrainBatch<'a> {
    pub view: PlanView<'a>,
    pub x: &'a [f32],
    pub labels: &'a [i32],
    pub num_outputs: usize,
}

/// A backend that runs fused optimizer steps on the host.
pub trait TrainExecutor: Send + Sync {
    /// Backend name (stable; used in CLI flags and bench JSON).
    fn name(&self) -> &'static str;

    /// One fused step: forward + backward + weight decay + Adam,
    /// updating `state` (params, moments, step counter) in place.
    fn train_step(
        &self,
        meta: &ArtifactMeta,
        state: &mut ModelState,
        batch: &TrainBatch,
        lr: f32,
        seed: i32,
        scratch: &mut TrainScratch,
    ) -> StepMetrics;

    /// Forward + backward only, **accumulating** (`+=`) the
    /// weight-decayed gradients into the caller-owned `grads` buffer
    /// (gradient-accumulation mode; `grads.len() == meta.param_count`).
    fn grad_step(
        &self,
        meta: &ArtifactMeta,
        state: &ModelState,
        batch: &TrainBatch,
        seed: i32,
        grads: &mut [f32],
        scratch: &mut TrainScratch,
    ) -> StepMetrics;
}

/// Which training backend `ibmb train --executor` selects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrainExecutorKind {
    /// Scalar native backend (parity baseline for the blocked one).
    Reference,
    /// `[f32; 8]`-lane blocked native backend (the fast path).
    Blocked,
    /// The AOT artifact path through [`crate::runtime::Runtime`] —
    /// not buildable here; the trainer routes it to `training::train`.
    Runtime,
}

impl TrainExecutorKind {
    /// Accepted `--executor` values.
    pub const ALL_NAMES: &'static str = "reference|blocked|runtime";

    pub fn from_name(name: &str) -> Option<TrainExecutorKind> {
        Some(match name {
            "reference" => TrainExecutorKind::Reference,
            "blocked" => TrainExecutorKind::Blocked,
            "runtime" => TrainExecutorKind::Runtime,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TrainExecutorKind::Reference => "reference",
            TrainExecutorKind::Blocked => "blocked",
            TrainExecutorKind::Runtime => "runtime",
        }
    }

    /// Instantiate the native backend.
    pub fn build(&self) -> Result<Box<dyn TrainExecutor>> {
        match self {
            TrainExecutorKind::Reference => {
                Ok(Box::new(super::train_reference::ReferenceTrainExecutor))
            }
            TrainExecutorKind::Blocked => {
                Ok(Box::new(super::train_blocked::BlockedTrainExecutor))
            }
            TrainExecutorKind::Runtime => bail!(
                "the runtime executor steps through AOT artifacts \
                 (training::train), not the native path"
            ),
        }
    }
}

impl Default for TrainExecutorKind {
    fn default() -> Self {
        TrainExecutorKind::Blocked
    }
}

/// Synthesize a train-kind [`ArtifactMeta`] for the native path — the
/// same parameter layout the serve shards use, with the training
/// hyperparameters (dropout, weight decay) filled in. No `.hlo.txt`
/// backs it; only the layout and hyperparameters are consumed.
#[allow(clippy::too_many_arguments)]
pub fn train_artifact(
    model: &str,
    feat: usize,
    classes: usize,
    hidden: usize,
    layers: usize,
    heads: usize,
    dropout: f64,
    weight_decay: f64,
    n_pad: usize,
) -> ArtifactMeta {
    let mut meta = crate::serve::reference_artifact(
        model, feat, classes, hidden, layers, heads, n_pad,
    );
    meta.id = format!("native_train_{model}_n{n_pad}");
    meta.kind = "train".into();
    meta.dropout = dropout;
    meta.weight_decay = weight_decay;
    meta
}

/// Deterministic counter-based dropout: a splitmix64 finalizer keyed on
/// `(seed, layer, element)` decides keep/drop per activation, so every
/// backend — and the dense oracle — draws the *same* mask for the same
/// step seed without materializing it. Returns the inverted-dropout
/// scale (`1/keep` or `0`).
pub fn dropout_scale(seed: i32, layer: u32, elem: usize, rate: f32) -> f32 {
    if rate <= 0.0 {
        return 1.0;
    }
    let keep = 1.0 - rate;
    let mut z = (seed as u32 as u64)
        ^ ((layer as u64) << 32)
        ^ (elem as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z ^= z >> 30;
    z = z.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z ^= z >> 27;
    z = z.wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    let u = ((z >> 40) as f32) / (1u64 << 24) as f32;
    if u < keep {
        1.0 / keep
    } else {
        0.0
    }
}

fn grow_f32(v: &mut Vec<f32>, len: usize) {
    if v.len() < len {
        v.resize(len, 0.0);
    }
}

fn grow_u32(v: &mut Vec<u32>, len: usize) {
    if v.len() < len {
        v.resize(len, 0);
    }
}

/// Grow-never-shrink workspace for one training stream. All buffers
/// ratchet up to the epoch's high-water batch shape and are then reused
/// allocation-free; the forward tape (`aggc`, `z`, `mean`, `rstd`) is
/// kept per layer because the backward pass re-reads it.
#[derive(Default)]
pub struct TrainScratch {
    // shared CSR of the current batch (dst-major, stable order)
    csr_off: Vec<u32>,
    csr_src: Vec<u32>,
    csr_w: Vec<f32>,
    // rolling activation + per-layer tape
    h: Vec<f32>,
    agg: Vec<f32>,
    aggc: Vec<Vec<f32>>,
    z: Vec<Vec<f32>>,
    mean: Vec<Vec<f32>>,
    rstd: Vec<Vec<f32>>,
    // backward rolling buffers
    dz: Vec<f32>,
    dh: Vec<f32>,
    dcat: Vec<f32>,
    // fused-step gradient buffer (train_step only)
    grads: Vec<f32>,
    d_max: usize,
}

impl TrainScratch {
    pub fn new() -> TrainScratch {
        TrainScratch::default()
    }

    /// Widest layer dimension (from the manifest's bias sizes).
    fn compute_d_max(meta: &ArtifactMeta) -> usize {
        let mut d = meta.feat.max(meta.classes);
        for p in &meta.params {
            if p.name.ends_with(".b") {
                d = d.max(p.size);
            }
        }
        d
    }

    /// Ensure capacity for a batch of `n` nodes and `e` edges.
    pub fn ensure(&mut self, meta: &ArtifactMeta, n: usize, e: usize) {
        let d = Self::compute_d_max(meta);
        self.d_max = self.d_max.max(d);
        let d = self.d_max;
        grow_u32(&mut self.csr_off, n + 1);
        grow_u32(&mut self.csr_src, e);
        grow_f32(&mut self.csr_w, e);
        grow_f32(&mut self.h, n * d);
        grow_f32(&mut self.agg, n * d);
        grow_f32(&mut self.dz, n * d);
        grow_f32(&mut self.dh, n * d);
        grow_f32(&mut self.dcat, n * 2 * d);
        if self.aggc.len() < meta.layers {
            self.aggc.resize_with(meta.layers, Vec::new);
            self.z.resize_with(meta.layers, Vec::new);
            self.mean.resize_with(meta.layers, Vec::new);
            self.rstd.resize_with(meta.layers, Vec::new);
        }
        for l in 0..meta.layers {
            grow_f32(&mut self.aggc[l], n * 2 * d);
            grow_f32(&mut self.z[l], n * d);
            grow_f32(&mut self.mean[l], n);
            grow_f32(&mut self.rstd[l], n);
        }
    }

    /// Resident bytes (perf accounting).
    pub fn memory_bytes(&self) -> usize {
        let nested: usize = self
            .aggc
            .iter()
            .chain(&self.z)
            .chain(&self.mean)
            .chain(&self.rstd)
            .map(|v| v.len() * 4)
            .sum();
        (self.csr_off.len()
            + self.csr_src.len()
            + self.csr_w.len()
            + self.h.len()
            + self.agg.len()
            + self.dz.len()
            + self.dh.len()
            + self.dcat.len()
            + self.grads.len())
            * 4
            + nested
    }
}

/// The five shape-blocked primitives a backend supplies. Everything
/// else in the step (CSR build, layernorm/relu/dropout algebra, the
/// loss head, weight decay, Adam) is shared scalar code in this module.
pub(crate) trait TrainKernels {
    /// `out[d, :] = Σ_{e: dst=d} w_e · h[src_e, :]` (dst-major CSR;
    /// writes every row exactly once — no zero-fill required).
    fn spmm(
        &self,
        off: &[u32],
        src: &[u32],
        w: &[f32],
        h: &[f32],
        n: usize,
        dim: usize,
        out: &mut [f32],
    );
    /// Transpose scatter: `dh[src_e, :] += w_e · dagg[d, :]` for every
    /// edge, walked dst-major over the same CSR (caller zero-fills or
    /// pre-loads `dh`).
    fn spmm_t(
        &self,
        off: &[u32],
        src: &[u32],
        w: &[f32],
        dagg: &[f32],
        n: usize,
        dim: usize,
        dh: &mut [f32],
    );
    /// `out = x @ w + b` (w row-major `[d_in, d_out]`).
    fn linear(
        &self,
        x: &[f32],
        n: usize,
        d_in: usize,
        w: &[f32],
        b: &[f32],
        d_out: usize,
        out: &mut [f32],
    );
    /// `dw[k, j] += Σ_i a[i, k]·dz[i, j]`; `db[j] += Σ_i dz[i, j]`.
    fn linear_wgrad(
        &self,
        a: &[f32],
        dz: &[f32],
        n: usize,
        d_a: usize,
        d_out: usize,
        dw: &mut [f32],
        db: &mut [f32],
    );
    /// `da[i, k] = dz[i, :] · w[k, :]` (overwrites `da`).
    fn linear_igrad(
        &self,
        dz: &[f32],
        w: &[f32],
        n: usize,
        d_a: usize,
        d_out: usize,
        da: &mut [f32],
    );
}

fn tensor<'a>(
    state: &'a ModelState,
    meta: &ArtifactMeta,
    name: &str,
) -> &'a [f32] {
    state
        .tensor(meta, name)
        .unwrap_or_else(|| panic!("{}: missing param {name}", meta.id))
}

fn spec(meta: &ArtifactMeta, name: &str) -> (usize, usize) {
    meta.params
        .iter()
        .find(|p| p.name == name)
        .map(|p| (p.offset, p.size))
        .unwrap_or_else(|| panic!("{}: missing param {name}", meta.id))
}

/// Two non-overlapping mutable windows of one flat gradient vector.
fn disjoint_mut(
    v: &mut [f32],
    a: (usize, usize),
    b: (usize, usize),
) -> (&mut [f32], &mut [f32]) {
    if a.0 + a.1 <= b.0 {
        let (lo, hi) = v.split_at_mut(b.0);
        (&mut lo[a.0..a.0 + a.1], &mut hi[..b.1])
    } else {
        assert!(b.0 + b.1 <= a.0, "overlapping param ranges");
        let (lo, hi) = v.split_at_mut(a.0);
        let (bs, asl) = (&mut lo[b.0..b.0 + b.1], &mut hi[..a.1]);
        (asl, bs)
    }
}

/// Per-layer (d_in, d_out) from the manifest layout.
pub(crate) fn layer_dims(meta: &ArtifactMeta) -> Vec<(usize, usize)> {
    let mut dims = Vec::with_capacity(meta.layers);
    let mut d_in = meta.feat;
    for l in 0..meta.layers {
        let (_, d_out) = spec(meta, &format!("l{l}.b"));
        dims.push((d_in, d_out));
        d_in = d_out;
    }
    dims
}

/// Masked softmax cross-entropy head: loss/accuracy over the first
/// `num_outputs` rows and `dz = (softmax − onehot) / max(outputs, 1)`
/// (zero for aux rows). Expressions mirror `model.py` (max-shifted
/// log-sum-exp, first-max argmax like `jnp.argmax`).
fn softmax_ce_backward(
    logits: &[f32],
    labels: &[i32],
    n: usize,
    classes: usize,
    num_outputs: usize,
    dz: &mut [f32],
) -> StepMetrics {
    let inv = 1.0 / (num_outputs as f32).max(1.0);
    dz[..n * classes].fill(0.0);
    let mut loss_sum = 0.0f32;
    let mut correct = 0.0f32;
    for i in 0..num_outputs {
        let row = &logits[i * classes..(i + 1) * classes];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse =
            row.iter().map(|&v| (v - mx).exp()).sum::<f32>().ln() + mx;
        let label = labels[i] as usize;
        loss_sum += lse - row[label];
        let mut pred = 0usize;
        let mut best = row[0];
        for (c, &v) in row.iter().enumerate().skip(1) {
            if v > best {
                best = v;
                pred = c;
            }
        }
        if pred == label {
            correct += 1.0;
        }
        let dr = &mut dz[i * classes..(i + 1) * classes];
        for (c, d) in dr.iter_mut().enumerate() {
            let p = (row[c] - lse).exp();
            *d = (p - f32::from(c == label)) * inv;
        }
    }
    StepMetrics {
        loss: loss_sum * inv,
        correct,
        mask_count: num_outputs as f32,
    }
}

/// Fused layernorm → relu → inverted dropout, saving (mean, rstd) for
/// the backward pass. Summation order matches the blocked forward
/// (`exec::blocked::layernorm_relu`) and the dense oracle.
#[allow(clippy::too_many_arguments)]
fn ln_relu_dropout_fwd(
    z: &[f32],
    n: usize,
    d: usize,
    g: &[f32],
    b: &[f32],
    rate: f32,
    seed: i32,
    layer: u32,
    mean: &mut [f32],
    rstd: &mut [f32],
    h: &mut [f32],
) {
    for i in 0..n {
        let zi = &z[i * d..(i + 1) * d];
        let mu = zi.iter().sum::<f32>() / d as f32;
        let var =
            zi.iter().map(|&v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let rs = 1.0 / (var + LN_EPS).sqrt();
        mean[i] = mu;
        rstd[i] = rs;
        let hr = &mut h[i * d..(i + 1) * d];
        for j in 0..d {
            let y = (zi[j] - mu) * rs * g[j] + b[j];
            let mut v = y.max(0.0);
            if rate > 0.0 {
                v *= dropout_scale(seed, layer, i * d + j, rate);
            }
            hr[j] = v;
        }
    }
}

/// Backward through dropout → relu → layernorm: reads the upstream
/// grad `dh`, writes the downstream grad `dz_out`, and accumulates
/// `dγ = Σ g·x̂`, `dβ = Σ g` (relu-gated, strict `y > 0` — grad is 0
/// at exactly 0, like the python VJP).
#[allow(clippy::too_many_arguments)]
fn ln_relu_dropout_bwd(
    z: &[f32],
    mean: &[f32],
    rstd: &[f32],
    g: &[f32],
    b: &[f32],
    rate: f32,
    seed: i32,
    layer: u32,
    dh: &[f32],
    dz_out: &mut [f32],
    dg: &mut [f32],
    db: &mut [f32],
    n: usize,
    d: usize,
) {
    for i in 0..n {
        let zi = &z[i * d..(i + 1) * d];
        let row = &mut dz_out[i * d..(i + 1) * d];
        let mut gx_mean = 0.0f32;
        let mut gxxh_mean = 0.0f32;
        for j in 0..d {
            let xhat = (zi[j] - mean[i]) * rstd[i];
            let y = xhat * g[j] + b[j];
            let mut gr = dh[i * d + j];
            if rate > 0.0 {
                gr *= dropout_scale(seed, layer, i * d + j, rate);
            }
            if y <= 0.0 {
                gr = 0.0;
            }
            dg[j] += gr * xhat;
            db[j] += gr;
            let gx = gr * g[j];
            gx_mean += gx;
            gxxh_mean += gx * xhat;
            row[j] = gx; // stash gx; finished after the row means
        }
        gx_mean /= d as f32;
        gxxh_mean /= d as f32;
        for j in 0..d {
            let xhat = (zi[j] - mean[i]) * rstd[i];
            row[j] = rstd[i] * (row[j] - gx_mean - xhat * gxxh_mean);
        }
    }
}

/// The shared fused step body: CSR build → forward (with tape) →
/// loss head → reverse sweep → weight decay, accumulating gradients
/// into `grads`. Panics on GAT metas — callers gate on the model name.
pub(crate) fn forward_backward<K: TrainKernels>(
    kern: &K,
    meta: &ArtifactMeta,
    state: &ModelState,
    batch: &TrainBatch,
    seed: i32,
    scratch: &mut TrainScratch,
    grads: &mut [f32],
) -> StepMetrics {
    let n = batch.view.n;
    let e = batch.view.num_edges();
    let sage = match meta.model.as_str() {
        "gcn" => false,
        "sage" => true,
        other => panic!("native training: unsupported model {other:?}"),
    };
    debug_assert_eq!(batch.x.len(), n * meta.feat);
    debug_assert!(batch.labels.len() >= batch.num_outputs);
    debug_assert_eq!(grads.len(), meta.param_count);
    scratch.ensure(meta, n, e);
    build_csr(
        &batch.view,
        &mut scratch.csr_off[..n + 1],
        &mut scratch.csr_src[..e],
        &mut scratch.csr_w[..e],
    );
    let dims = layer_dims(meta);
    let rate = meta.dropout as f32;

    // ---- forward, taping linear inputs, pre-LN outputs, (μ, rstd) ----
    scratch.h[..n * meta.feat].copy_from_slice(batch.x);
    for (l, &(d_in, d_out)) in dims.iter().enumerate() {
        let w = tensor(state, meta, &format!("l{l}.w"));
        let b = tensor(state, meta, &format!("l{l}.b"));
        let a_dim = if sage { 2 * d_in } else { d_in };
        if sage {
            kern.spmm(
                &scratch.csr_off[..n + 1],
                &scratch.csr_src[..e],
                &scratch.csr_w[..e],
                &scratch.h,
                n,
                d_in,
                &mut scratch.agg[..n * d_in],
            );
            let cat = &mut scratch.aggc[l];
            for i in 0..n {
                cat[i * a_dim..i * a_dim + d_in]
                    .copy_from_slice(&scratch.h[i * d_in..(i + 1) * d_in]);
                cat[i * a_dim + d_in..(i + 1) * a_dim].copy_from_slice(
                    &scratch.agg[i * d_in..(i + 1) * d_in],
                );
            }
        } else {
            kern.spmm(
                &scratch.csr_off[..n + 1],
                &scratch.csr_src[..e],
                &scratch.csr_w[..e],
                &scratch.h,
                n,
                d_in,
                &mut scratch.aggc[l][..n * d_in],
            );
        }
        kern.linear(
            &scratch.aggc[l][..n * a_dim],
            n,
            a_dim,
            w,
            b,
            d_out,
            &mut scratch.z[l][..n * d_out],
        );
        if l + 1 != meta.layers {
            let g = tensor(state, meta, &format!("l{l}.ln_g"));
            let bl = tensor(state, meta, &format!("l{l}.ln_b"));
            ln_relu_dropout_fwd(
                &scratch.z[l][..n * d_out],
                n,
                d_out,
                g,
                bl,
                rate,
                seed,
                l as u32,
                &mut scratch.mean[l][..n],
                &mut scratch.rstd[l][..n],
                &mut scratch.h[..n * d_out],
            );
        }
    }

    // ---- loss head ----
    let classes = meta.classes;
    let metrics = softmax_ce_backward(
        &scratch.z[meta.layers - 1][..n * classes],
        batch.labels,
        n,
        classes,
        batch.num_outputs,
        &mut scratch.dz,
    );

    // ---- reverse sweep ----
    for l in (0..dims.len()).rev() {
        let (d_in, d_out) = dims[l];
        let a_dim = if sage { 2 * d_in } else { d_in };
        let w = tensor(state, meta, &format!("l{l}.w"));
        let (dw, db) = disjoint_mut(
            grads,
            spec(meta, &format!("l{l}.w")),
            spec(meta, &format!("l{l}.b")),
        );
        kern.linear_wgrad(
            &scratch.aggc[l][..n * a_dim],
            &scratch.dz[..n * d_out],
            n,
            a_dim,
            d_out,
            dw,
            db,
        );
        kern.linear_igrad(
            &scratch.dz[..n * d_out],
            w,
            n,
            a_dim,
            d_out,
            &mut scratch.dcat[..n * a_dim],
        );
        if sage {
            // direct half: dh = dcat[:, :d_in]; agg half scatters below
            for i in 0..n {
                scratch.dh[i * d_in..(i + 1) * d_in].copy_from_slice(
                    &scratch.dcat[i * a_dim..i * a_dim + d_in],
                );
                scratch.agg[i * d_in..(i + 1) * d_in].copy_from_slice(
                    &scratch.dcat[i * a_dim + d_in..(i + 1) * a_dim],
                );
            }
            kern.spmm_t(
                &scratch.csr_off[..n + 1],
                &scratch.csr_src[..e],
                &scratch.csr_w[..e],
                &scratch.agg[..n * d_in],
                n,
                d_in,
                &mut scratch.dh[..n * d_in],
            );
        } else {
            scratch.dh[..n * d_in].fill(0.0);
            kern.spmm_t(
                &scratch.csr_off[..n + 1],
                &scratch.csr_src[..e],
                &scratch.csr_w[..e],
                &scratch.dcat[..n * d_in],
                n,
                d_in,
                &mut scratch.dh[..n * d_in],
            );
        }
        if l == 0 {
            break;
        }
        let pl = l - 1;
        let pd = d_in; // == dims[pl].1
        let g = tensor(state, meta, &format!("l{pl}.ln_g"));
        let bl = tensor(state, meta, &format!("l{pl}.ln_b"));
        let (dg, dbl) = disjoint_mut(
            grads,
            spec(meta, &format!("l{pl}.ln_g")),
            spec(meta, &format!("l{pl}.ln_b")),
        );
        ln_relu_dropout_bwd(
            &scratch.z[pl][..n * pd],
            &scratch.mean[pl][..n],
            &scratch.rstd[pl][..n],
            g,
            bl,
            rate,
            seed,
            pl as u32,
            &scratch.dh[..n * pd],
            &mut scratch.dz[..n * pd],
            dg,
            dbl,
            n,
            pd,
        );
    }

    // weight decay on the whole flat vector (model.py: after autodiff)
    let wd = meta.weight_decay as f32;
    if wd > 0.0 {
        for (gv, &p) in grads.iter_mut().zip(&state.params) {
            *gv += wd * p;
        }
    }
    metrics
}

/// Fused Adam sweep: one pass over (params, m, v, grads), in place —
/// no literal round-trips, no state clones. Per-element expressions
/// are identical to [`crate::training::host_adam`] (the accumulation
/// path), which the parity tests pin bitwise.
pub fn fused_adam(state: &mut ModelState, grads: &[f32], lr: f32) {
    debug_assert_eq!(grads.len(), state.params.len());
    state.step += 1;
    let t = state.step as f32;
    let bc1 = 1.0 - ADAM_B1.powf(t);
    let bc2 = 1.0 - ADAM_B2.powf(t);
    for i in 0..state.params.len() {
        let g = grads[i];
        state.m[i] = ADAM_B1 * state.m[i] + (1.0 - ADAM_B1) * g;
        state.v[i] = ADAM_B2 * state.v[i] + (1.0 - ADAM_B2) * g * g;
        let m_hat = state.m[i] / bc1;
        let v_hat = state.v[i] / bc2;
        state.params[i] -= lr * m_hat / (v_hat.sqrt() + ADAM_EPS);
    }
}

/// Shared fused-step body for both backends: zero the scratch gradient
/// buffer, run [`forward_backward`], apply [`fused_adam`].
pub(crate) fn train_step_impl<K: TrainKernels>(
    kern: &K,
    meta: &ArtifactMeta,
    state: &mut ModelState,
    batch: &TrainBatch,
    lr: f32,
    seed: i32,
    scratch: &mut TrainScratch,
) -> StepMetrics {
    let mut g = std::mem::take(&mut scratch.grads);
    grow_f32(&mut g, meta.param_count);
    g[..meta.param_count].fill(0.0);
    let metrics = forward_backward(
        kern,
        meta,
        state,
        batch,
        seed,
        scratch,
        &mut g[..meta.param_count],
    );
    fused_adam(state, &g[..meta.param_count], lr);
    scratch.grads = g;
    metrics
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_names_roundtrip() {
        for k in [
            TrainExecutorKind::Reference,
            TrainExecutorKind::Blocked,
            TrainExecutorKind::Runtime,
        ] {
            assert_eq!(TrainExecutorKind::from_name(k.name()), Some(k));
        }
        assert_eq!(TrainExecutorKind::from_name("nope"), None);
        assert!(TrainExecutorKind::Reference.build().is_ok());
        assert!(TrainExecutorKind::Blocked.build().is_ok());
        assert!(TrainExecutorKind::Runtime.build().is_err());
    }

    #[test]
    fn dropout_scale_is_deterministic_and_unbiased() {
        let rate = 0.3f32;
        let a = dropout_scale(42, 1, 123, rate);
        let b = dropout_scale(42, 1, 123, rate);
        assert_eq!(a, b);
        // different coordinates decorrelate
        let mut kept = 0usize;
        let trials = 20_000usize;
        for i in 0..trials {
            if dropout_scale(7, 0, i, rate) > 0.0 {
                kept += 1;
            }
        }
        let frac = kept as f64 / trials as f64;
        assert!(
            (frac - 0.7).abs() < 0.02,
            "keep fraction {frac} far from 0.7"
        );
        // rate 0 is the identity
        assert_eq!(dropout_scale(7, 0, 5, 0.0), 1.0);
    }

    #[test]
    fn disjoint_mut_splits_both_orders() {
        let mut v: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let (a, b) = disjoint_mut(&mut v, (1, 2), (6, 3));
        assert_eq!(a, &[1.0, 2.0]);
        assert_eq!(b, &[6.0, 7.0, 8.0]);
        let (a, b) = disjoint_mut(&mut v, (6, 3), (1, 2));
        assert_eq!(a, &[6.0, 7.0, 8.0]);
        assert_eq!(b, &[1.0, 2.0]);
    }
}
