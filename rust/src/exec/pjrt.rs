//! PJRT-backed executor over the vendored `xla` bindings.
//!
//! With the offline stub (`vendor/xla`) the client constructor returns
//! a clean error, so `ExecutorKind::Pjrt.build()` fails before any
//! query is accepted — the CLI surfaces the stub message instead of a
//! mid-stream panic. Swapping real bindings back in (rust/DESIGN.md §6)
//! turns this file into the only integration point: compile the
//! artifact once, then stage each batch through host buffers exactly
//! like `Runtime::infer_step` does.

use anyhow::{anyhow, Result};

use crate::exec::{ExecScratch, Executor, PlanView};
use crate::runtime::{ArtifactMeta, ModelState};

pub struct PjrtExecutor {
    client: xla::PjRtClient,
}

impl PjrtExecutor {
    /// Create the PJRT CPU client. Errors on the vendored stub.
    pub fn new() -> Result<PjrtExecutor> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT executor unavailable: {e}"))?;
        Ok(PjrtExecutor { client })
    }
}

impl Executor for PjrtExecutor {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn forward(
        &self,
        meta: &ArtifactMeta,
        state: &ModelState,
        view: &PlanView,
        x: &[f32],
        _scratch: &mut ExecScratch,
        out: &mut Vec<f32>,
    ) {
        // Host->device staging mirrors Runtime::batch_buffers; real
        // bindings would then execute the compiled `meta.id` artifact
        // and read logits back into `out`. With the stub, new() fails,
        // so this body is unreachable; any staging error still panics
        // with the descriptive stub message rather than silently
        // returning garbage logits.
        let staged = self
            .client
            .buffer_from_host_buffer(x, &[view.n, meta.feat], None)
            .and_then(|_| {
                self.client
                    .buffer_from_host_buffer(&state.params, &[meta.param_count], None)
            });
        if let Err(e) = staged {
            panic!("pjrt forward ({} nodes): {e}", view.n);
        }
        out.resize(view.n * meta.classes, 0.0);
        unimplemented!("pjrt forward: execute path lands with real bindings");
    }
}
