//! Pluggable execution backends for the per-batch forward pass.
//!
//! Everything upstream of the math — plan construction, arena
//! materialization, snapshot swaps, coalescing — got fast across PRs
//! 2–6 while the forward itself stayed the scalar reference in
//! `inference::fullgraph`. The [`Executor`] trait makes the forward a
//! swappable component (DESIGN.md §13):
//!
//! * [`ReferenceExecutor`] — wraps `fullgraph::forward` unchanged; the
//!   numerical oracle every other backend is tested against.
//! * [`BlockedCpuExecutor`] — CSR-converted, dst-major, 8-lane-blocked
//!   CPU kernels with zero steady-state allocations via [`ExecScratch`]
//!   and optional f16 feature quantization.
//! * [`PjrtExecutor`] — stages batches through the vendored `xla` PJRT
//!   bindings; with the offline stub it fails cleanly at construction,
//!   so swapping in real bindings stays a local change.
//!
//! The contract is deliberately narrow: a forward consumes a borrowed
//! [`PlanView`] (the COO slices a materialized plan already holds), a
//! dense feature block, and the model state, and writes logits. All
//! intermediate storage lives in the caller-owned [`ExecScratch`],
//! sized once per shard from the largest bucket and reused for every
//! batch thereafter.

pub mod blocked;
pub mod pjrt;
pub mod reference;
pub mod train;
pub mod train_blocked;
pub mod train_reference;

pub use blocked::BlockedCpuExecutor;
pub use pjrt::PjrtExecutor;
pub use reference::ReferenceExecutor;
pub use train::{
    TrainBatch, TrainExecutor, TrainExecutorKind, TrainScratch,
};
pub use train_blocked::BlockedTrainExecutor;
pub use train_reference::ReferenceTrainExecutor;

use anyhow::Result;

use crate::runtime::{ArtifactMeta, ModelState};

/// Borrowed per-batch graph view: COO edge slices over batch-local
/// node ids `0..n`, exactly as a materialized plan stores them (edge
/// `e` aggregates `src[e]` into `dst[e]` with weight `weights[e]`).
#[derive(Debug, Clone, Copy)]
pub struct PlanView<'a> {
    pub n: usize,
    pub edge_src: &'a [u32],
    pub edge_dst: &'a [u32],
    pub weights: &'a [f32],
}

impl<'a> PlanView<'a> {
    pub fn num_edges(&self) -> usize {
        self.edge_src.len()
    }
}

/// A forward-pass backend. Implementations must be deterministic for a
/// fixed (meta, state, view, x): serving compares executors by replaying
/// pinned seeds (ci.sh executor smoke).
pub trait Executor: Send {
    /// Human-readable backend name (CLI + bench labels).
    fn name(&self) -> &'static str;

    /// Compute logits for one batch: `out` is resized to
    /// `view.n * meta.classes`, row-major. `x` holds `view.n * meta.feat`
    /// dense features. Must not retain references into `scratch`.
    fn forward(
        &self,
        meta: &ArtifactMeta,
        state: &ModelState,
        view: &PlanView,
        x: &[f32],
        scratch: &mut ExecScratch,
        out: &mut Vec<f32>,
    );
}

/// Executor selector: parsed from `--executor`, carried by value into
/// shard workers (the boxed executor itself is built thread-local).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecutorKind {
    /// Scalar oracle (`fullgraph::forward`).
    Reference,
    /// SIMD-blocked CSR CPU backend (default).
    Blocked,
    /// Blocked backend + f16 feature quantization (looser parity bound).
    BlockedF16,
    /// Vendored PJRT bindings; errors at build on the offline stub.
    Pjrt,
}

impl Default for ExecutorKind {
    fn default() -> Self {
        ExecutorKind::Blocked
    }
}

impl ExecutorKind {
    pub fn name(self) -> &'static str {
        match self {
            ExecutorKind::Reference => "reference",
            ExecutorKind::Blocked => "blocked",
            ExecutorKind::BlockedF16 => "blocked-f16",
            ExecutorKind::Pjrt => "pjrt",
        }
    }

    /// Parse a `--executor` value. `None` for unknown names — the CLI
    /// reports the accepted set.
    pub fn from_name(s: &str) -> Option<ExecutorKind> {
        match s {
            "reference" => Some(ExecutorKind::Reference),
            "blocked" => Some(ExecutorKind::Blocked),
            "blocked-f16" => Some(ExecutorKind::BlockedF16),
            "pjrt" => Some(ExecutorKind::Pjrt),
            _ => None,
        }
    }

    pub const ALL_NAMES: &'static str = "reference|blocked|blocked-f16|pjrt";

    /// Construct the backend. Fallibility lives here (not in
    /// `Executor::forward`) so a backend whose runtime is unavailable —
    /// the PJRT stub — fails once, loudly, before any query is accepted.
    pub fn build(self) -> Result<Box<dyn Executor>> {
        match self {
            ExecutorKind::Reference => Ok(Box::new(ReferenceExecutor)),
            ExecutorKind::Blocked => Ok(Box::new(BlockedCpuExecutor::new(false))),
            ExecutorKind::BlockedF16 => Ok(Box::new(BlockedCpuExecutor::new(true))),
            ExecutorKind::Pjrt => Ok(Box::new(PjrtExecutor::new()?)),
        }
    }
}

/// Reusable per-worker forward scratch. One instance per shard worker,
/// grown to the high-water batch shape on first use (the shard sizes it
/// from its bucket up front) and never shrunk — the steady-state
/// forward performs zero heap allocations.
///
/// Buffers are grown, not re-zeroed: every kernel writes each row it
/// owns exactly once, so rows beyond the current batch's `n` are simply
/// never read. That retires the old `spmm` full-buffer `fill(0.0)` —
/// only live rows are ever touched.
#[derive(Debug, Default)]
pub struct ExecScratch {
    /// Ping-pong activation buffers (`n * d_max`).
    pub(crate) h: Vec<f32>,
    pub(crate) h2: Vec<f32>,
    /// Aggregation target (`n * d_max`).
    pub(crate) agg: Vec<f32>,
    /// SAGE concat input (`n * 2 * d_max`).
    pub(crate) cat: Vec<f32>,
    /// GAT projected features (`n * d_max`).
    pub(crate) hw: Vec<f32>,
    /// CSR row offsets (`n + 1`), counting-sorted per batch.
    pub(crate) csr_off: Vec<u32>,
    /// CSR column (source) ids, dst-major (`e`).
    pub(crate) csr_src: Vec<u32>,
    /// CSR edge weights, aligned with `csr_src` (`e`).
    pub(crate) csr_w: Vec<f32>,
    /// GAT per-node attention scores (`n` each).
    pub(crate) s_row: Vec<f32>,
    pub(crate) s_col: Vec<f32>,
    /// GAT per-edge exponentials (`e`), segmented by CSR row.
    pub(crate) edge_e: Vec<f32>,
    /// Quantized feature staging for the f16 path (`n * feat`).
    pub(crate) q16: Vec<u16>,
    /// Cached max layer width for the meta this scratch serves.
    d_max: usize,
}

impl ExecScratch {
    pub fn new() -> ExecScratch {
        ExecScratch::default()
    }

    /// Pre-size for up to `max_nodes` batch nodes and `max_edges` batch
    /// edges under `meta`'s layer widths. Shards call this once with
    /// their bucket capacity so the serve path never grows mid-stream.
    pub fn for_meta(meta: &ArtifactMeta, state: &ModelState, max_nodes: usize, max_edges: usize) -> ExecScratch {
        let mut s = ExecScratch::new();
        s.ensure(meta, state, max_nodes, max_edges);
        s
    }

    /// Widest activation any layer produces (bias length), floored by
    /// the input feature width. Computed once per scratch lifetime.
    fn compute_d_max(meta: &ArtifactMeta, state: &ModelState) -> usize {
        let mut d = meta.feat.max(meta.classes);
        for l in 0..meta.layers {
            if let Some(b) = state.tensor(meta, &format!("l{l}.b")) {
                d = d.max(b.len());
            }
        }
        d
    }

    /// Grow (never shrink) every buffer to fit an `n`-node, `e`-edge
    /// batch. No-op (and allocation-free) once high-water sized.
    pub(crate) fn ensure(
        &mut self,
        meta: &ArtifactMeta,
        state: &ModelState,
        n: usize,
        e: usize,
    ) {
        if self.d_max == 0 {
            self.d_max = ExecScratch::compute_d_max(meta, state);
        }
        let d = self.d_max;
        grow(&mut self.h, n * d);
        grow(&mut self.h2, n * d);
        grow(&mut self.agg, n * d);
        grow(&mut self.cat, n * 2 * d);
        grow(&mut self.hw, n * d);
        grow_u32(&mut self.csr_off, n + 1);
        grow_u32(&mut self.csr_src, e);
        grow(&mut self.csr_w, e);
        grow(&mut self.s_row, n);
        grow(&mut self.s_col, n);
        grow(&mut self.edge_e, e);
        if self.q16.len() < n * meta.feat {
            self.q16.resize(n * meta.feat, 0);
        }
    }

    /// Resident bytes across all buffers (shard memory accounting).
    pub fn bytes(&self) -> usize {
        (self.h.capacity()
            + self.h2.capacity()
            + self.agg.capacity()
            + self.cat.capacity()
            + self.hw.capacity()
            + self.csr_w.capacity()
            + self.s_row.capacity()
            + self.s_col.capacity()
            + self.edge_e.capacity())
            * 4
            + (self.csr_off.capacity() + self.csr_src.capacity()) * 4
            + self.q16.capacity() * 2
    }
}

fn grow(v: &mut Vec<f32>, len: usize) {
    if v.len() < len {
        v.resize(len, 0.0);
    }
}

fn grow_u32(v: &mut Vec<u32>, len: usize) {
    if v.len() < len {
        v.resize(len, 0);
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use crate::runtime::manifest::Manifest;
    use crate::runtime::ArtifactMeta;

    /// Tiny manifest-backed meta (feat=4, hidden=4, classes=2,
    /// layers=2, heads=2) mirroring `fullgraph`'s test fixture.
    pub fn toy_meta(model: &str) -> ArtifactMeta {
        let params: Vec<(&str, Vec<usize>)> = match model {
            "gcn" => vec![
                ("l0.w", vec![4, 4]),
                ("l0.b", vec![4]),
                ("l0.ln_g", vec![4]),
                ("l0.ln_b", vec![4]),
                ("l1.w", vec![4, 2]),
                ("l1.b", vec![2]),
            ],
            "sage" => vec![
                ("l0.w", vec![8, 4]),
                ("l0.b", vec![4]),
                ("l0.ln_g", vec![4]),
                ("l0.ln_b", vec![4]),
                ("l1.w", vec![8, 2]),
                ("l1.b", vec![2]),
            ],
            "gat" => vec![
                ("l0.w", vec![4, 4]),
                ("l0.b", vec![4]),
                ("l0.a_src", vec![2, 2]),
                ("l0.a_dst", vec![2, 2]),
                ("l0.ln_g", vec![4]),
                ("l0.ln_b", vec![4]),
                ("l1.w", vec![4, 2]),
                ("l1.b", vec![2]),
                ("l1.a_src", vec![1, 2]),
                ("l1.a_dst", vec![1, 2]),
            ],
            _ => unreachable!(),
        };
        let mut entries = String::new();
        let mut off = 0usize;
        for (i, (name, shape)) in params.iter().enumerate() {
            let size: usize = shape.iter().product();
            if i > 0 {
                entries.push(',');
            }
            entries.push_str(&format!(
                r#"{{"name": "{name}", "shape": {shape:?}, "offset": {off}, "size": {size}}}"#
            ));
            off += size;
        }
        let doc = format!(
            r#"{{"version": 1, "artifacts": [{{"id": "t", "model": "{model}",
             "kind": "infer", "n_pad": 16, "feat": 4, "classes": 2,
             "hidden": 4, "layers": 2, "heads": 2, "dropout": 0.0,
             "weight_decay": 0.0, "param_count": {off},
             "params": [{entries}], "path": "t.hlo.txt"}}]}}"#
        );
        Manifest::parse(&doc).unwrap().artifacts[0].clone()
    }

    /// Ring with self loops, uniform 1/3 weights, edges (v -> u).
    pub fn ring_graph(n: usize) -> (Vec<u32>, Vec<u32>, Vec<f32>) {
        let mut src = Vec::new();
        let mut dst = Vec::new();
        let mut w = Vec::new();
        for u in 0..n as u32 {
            for v in [u, (u + 1) % n as u32, (u + n as u32 - 1) % n as u32] {
                src.push(v);
                dst.push(u);
                w.push(1.0 / 3.0);
            }
        }
        (src, dst, w)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_roundtrips_names() {
        for kind in [
            ExecutorKind::Reference,
            ExecutorKind::Blocked,
            ExecutorKind::BlockedF16,
            ExecutorKind::Pjrt,
        ] {
            assert_eq!(ExecutorKind::from_name(kind.name()), Some(kind));
        }
        assert_eq!(ExecutorKind::from_name("cuda"), None);
        assert_eq!(ExecutorKind::default(), ExecutorKind::Blocked);
    }

    #[test]
    fn pjrt_build_fails_cleanly_on_stub() {
        let err = ExecutorKind::Pjrt.build().expect_err("stub must not build");
        assert!(err.to_string().contains("PJRT"), "{err}");
    }

    #[test]
    fn scratch_grows_once_then_stays() {
        let meta = testutil::toy_meta("sage");
        let state = ModelState::init(&meta, 1);
        let mut s = ExecScratch::for_meta(&meta, &state, 64, 512);
        let bytes = s.bytes();
        assert!(bytes > 0);
        // smaller batches never reallocate
        s.ensure(&meta, &state, 16, 100);
        assert_eq!(s.bytes(), bytes);
    }
}
