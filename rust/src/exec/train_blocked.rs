//! `[f32; 8]`-lane blocked native training backend.
//!
//! Forward SpMM and forward linear come straight from the inference
//! executor (`exec::blocked`, PR 7). This module adds the backward
//! kernels:
//!
//! - **Transpose-CSR scatter SpMM** (`dh[src] += w · dagg[dst]`): the
//!   backward of aggregation is a scatter along the same edges. We walk
//!   the *same* dst-major CSR the forward built (no second index): for
//!   each destination row, its upstream gradient row is broadcast-axpy'd
//!   into every source row. The inner axpy has no loop-carried
//!   dependency, so it vectorizes cleanly; `dagg[d]` stays hot across
//!   the row's whole edge range.
//! - **Weight grad** (`dw[k, :] += a[i, k] · dz[i, :]`): i-outer
//!   rank-one updates — `dz[i]` is read once per row and each `dw[k]`
//!   update is a contiguous axpy.
//! - **Input grad** (`da[i, k] = dz[i, :] · w[k, :]`): both operands
//!   contiguous; accumulated in 8 independent lane partials to break
//!   the add dependency chain, then horizontally reduced. This is the
//!   one kernel whose summation order differs from the scalar
//!   reference (lane partials vs strict left-to-right), which is why
//!   the backend parity contract is tolerance-based (1e-4), not
//!   bitwise.

use super::blocked::{linear_blocked, spmm_blocked, LANES};
use super::train::{
    forward_backward, train_step_impl, TrainBatch, TrainExecutor,
    TrainKernels, TrainScratch,
};
use crate::runtime::{ArtifactMeta, ModelState, StepMetrics};

pub(crate) struct BlockedKernels;

impl TrainKernels for BlockedKernels {
    fn spmm(
        &self,
        off: &[u32],
        src: &[u32],
        w: &[f32],
        h: &[f32],
        n: usize,
        dim: usize,
        out: &mut [f32],
    ) {
        spmm_blocked(off, src, w, h, n, dim, out);
    }

    fn spmm_t(
        &self,
        off: &[u32],
        src: &[u32],
        w: &[f32],
        dagg: &[f32],
        n: usize,
        dim: usize,
        dh: &mut [f32],
    ) {
        for d in 0..n {
            let (lo, hi) = (off[d] as usize, off[d + 1] as usize);
            let dd = &dagg[d * dim..(d + 1) * dim];
            for e in lo..hi {
                let s = src[e] as usize;
                let we = w[e];
                let out = &mut dh[s * dim..(s + 1) * dim];
                for (o, &v) in out.iter_mut().zip(dd) {
                    *o += we * v;
                }
            }
        }
    }

    fn linear(
        &self,
        x: &[f32],
        n: usize,
        d_in: usize,
        w: &[f32],
        b: &[f32],
        d_out: usize,
        out: &mut [f32],
    ) {
        linear_blocked(x, n, d_in, w, Some(b), d_out, out);
    }

    fn linear_wgrad(
        &self,
        a: &[f32],
        dz: &[f32],
        n: usize,
        d_a: usize,
        d_out: usize,
        dw: &mut [f32],
        db: &mut [f32],
    ) {
        for i in 0..n {
            let dzi = &dz[i * d_out..(i + 1) * d_out];
            for (o, &v) in db.iter_mut().zip(dzi) {
                *o += v;
            }
            let ai = &a[i * d_a..(i + 1) * d_a];
            for (k, &av) in ai.iter().enumerate() {
                if av == 0.0 {
                    continue; // dropout/relu zeros skip whole axpys
                }
                let dwk = &mut dw[k * d_out..(k + 1) * d_out];
                for (o, &v) in dwk.iter_mut().zip(dzi) {
                    *o += av * v;
                }
            }
        }
    }

    fn linear_igrad(
        &self,
        dz: &[f32],
        w: &[f32],
        n: usize,
        d_a: usize,
        d_out: usize,
        da: &mut [f32],
    ) {
        let blocks = d_out / LANES;
        for i in 0..n {
            let dzi = &dz[i * d_out..(i + 1) * d_out];
            let dai = &mut da[i * d_a..(i + 1) * d_a];
            for (k, dv) in dai.iter_mut().enumerate() {
                let wk = &w[k * d_out..(k + 1) * d_out];
                let mut acc = [0.0f32; LANES];
                for bk in 0..blocks {
                    let j0 = bk * LANES;
                    for j in 0..LANES {
                        acc[j] += dzi[j0 + j] * wk[j0 + j];
                    }
                }
                let mut s: f32 = acc.iter().sum();
                for j in blocks * LANES..d_out {
                    s += dzi[j] * wk[j];
                }
                *dv = s;
            }
        }
    }
}

/// The `[f32; 8]`-lane blocked training backend (the fast path).
pub struct BlockedTrainExecutor;

impl TrainExecutor for BlockedTrainExecutor {
    fn name(&self) -> &'static str {
        "blocked"
    }

    fn train_step(
        &self,
        meta: &ArtifactMeta,
        state: &mut ModelState,
        batch: &TrainBatch,
        lr: f32,
        seed: i32,
        scratch: &mut TrainScratch,
    ) -> StepMetrics {
        train_step_impl(&BlockedKernels, meta, state, batch, lr, seed, scratch)
    }

    fn grad_step(
        &self,
        meta: &ArtifactMeta,
        state: &ModelState,
        batch: &TrainBatch,
        seed: i32,
        grads: &mut [f32],
        scratch: &mut TrainScratch,
    ) -> StepMetrics {
        forward_backward(
            &BlockedKernels,
            meta,
            state,
            batch,
            seed,
            scratch,
            grads,
        )
    }
}
