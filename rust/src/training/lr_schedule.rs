//! ReduceLROnPlateau — the paper's LR schedule (App. B): decay 0.33,
//! patience 30, min LR 1e-4, cooldown 10, driven by validation loss.

/// Plateau-based learning-rate decay.
#[derive(Debug, Clone)]
pub struct ReduceLROnPlateau {
    pub lr: f32,
    pub factor: f32,
    pub patience: usize,
    pub min_lr: f32,
    pub cooldown: usize,
    best: f64,
    bad_epochs: usize,
    cooldown_left: usize,
}

impl ReduceLROnPlateau {
    /// Paper defaults with the given starting LR.
    pub fn paper_defaults(lr: f32) -> Self {
        ReduceLROnPlateau::new(lr, 0.33, 30, 1e-4, 10)
    }

    pub fn new(
        lr: f32,
        factor: f32,
        patience: usize,
        min_lr: f32,
        cooldown: usize,
    ) -> Self {
        ReduceLROnPlateau {
            lr,
            factor,
            patience,
            min_lr,
            cooldown,
            best: f64::INFINITY,
            bad_epochs: 0,
            cooldown_left: 0,
        }
    }

    /// Record an epoch's validation loss; returns the (possibly
    /// reduced) learning rate to use next.
    pub fn step(&mut self, val_loss: f64) -> f32 {
        if val_loss < self.best - 1e-12 {
            self.best = val_loss;
            self.bad_epochs = 0;
        } else if self.cooldown_left > 0 {
            self.cooldown_left -= 1;
        } else {
            self.bad_epochs += 1;
            if self.bad_epochs > self.patience {
                self.lr = (self.lr * self.factor).max(self.min_lr);
                self.bad_epochs = 0;
                self.cooldown_left = self.cooldown;
            }
        }
        self.lr
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn improving_loss_keeps_lr() {
        let mut s = ReduceLROnPlateau::new(0.1, 0.5, 2, 0.001, 0);
        for i in 0..10 {
            assert_eq!(s.step(1.0 / (i + 1) as f64), 0.1);
        }
    }

    #[test]
    fn plateau_triggers_decay_after_patience() {
        let mut s = ReduceLROnPlateau::new(0.1, 0.5, 2, 0.001, 0);
        s.step(1.0);
        assert_eq!(s.step(1.0), 0.1); // bad 1
        assert_eq!(s.step(1.0), 0.1); // bad 2
        assert!((s.step(1.0) - 0.05).abs() < 1e-9); // bad 3 > patience
    }

    #[test]
    fn respects_min_lr() {
        let mut s = ReduceLROnPlateau::new(0.01, 0.1, 0, 0.005, 0);
        s.step(1.0);
        for _ in 0..5 {
            s.step(1.0);
        }
        assert!((s.lr - 0.005).abs() < 1e-9);
    }

    #[test]
    fn cooldown_suppresses_counting() {
        let mut s = ReduceLROnPlateau::new(0.1, 0.5, 1, 0.001, 3);
        s.step(1.0);
        s.step(1.0); // bad 1
        s.step(1.0); // bad 2 -> decay, cooldown 3
        assert!((s.lr - 0.05).abs() < 1e-9);
        s.step(1.0); // cooldown 2
        s.step(1.0); // cooldown 1
        s.step(1.0); // cooldown 0
        assert!((s.lr - 0.05).abs() < 1e-9, "decayed during cooldown");
        s.step(1.0); // bad 1
        s.step(1.0); // bad 2 -> decay
        assert!((s.lr - 0.025).abs() < 1e-9);
    }
}
