//! Training orchestration (paper §4): fused-Adam steps through the AOT
//! train executable, plateau LR scheduling, early stopping, batch
//! scheduling, gradient accumulation, and per-epoch approximate
//! validation using the training method's own batches (the paper's
//! protocol: "we use the mini-batching method used for training to also
//! approximate inference during training").

pub mod lr_schedule;
pub mod metrics;
pub mod trainer;

pub use lr_schedule::ReduceLROnPlateau;
pub use trainer::{
    host_adam, train, train_native, EpochRecord, SchedulerKind,
    TrainConfig, TrainResult,
};
