//! The training loop: preprocessing → cached plans → ring-prefetched
//! fused-Adam steps on arena-reused buffers → per-epoch approximate
//! validation → plateau LR + early stopping. Reproduces the paper's
//! protocol (App. B) on top of the plan/materialize pipeline
//! (DESIGN.md §4, §7).

use anyhow::{anyhow, Result};

use crate::batching::{BatchArena, BatchCache, BatchGenerator};
use crate::datasets::Dataset;
use crate::exec::{ExecScratch, Executor, ExecutorKind};
use crate::pipeline::run_prefetched;
use crate::runtime::{ArtifactMeta, ModelState, Runtime, StepMetrics};
use crate::scheduler::{
    batch_distance_matrix, OptimalCycleScheduler, Scheduler,
    SequentialScheduler, ShuffleScheduler, WeightedScheduler,
};
use crate::util::{Rng, Timer};

/// Which batch-order policy to use (paper §4 "Batch scheduling").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    Sequential,
    Shuffle,
    OptimalCycle,
    Weighted,
}

/// Training configuration (paper App. B defaults).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub model: String,
    pub epochs: usize,
    pub lr: f32,
    /// Early-stop patience in epochs on val loss (paper: 100; 0 = off).
    pub early_stop: usize,
    pub seed: u64,
    pub scheduler: SchedulerKind,
    /// Gradient accumulation: apply Adam every `grad_accum` batches
    /// via the `grad` artifact + host Adam (1 = fused fast path).
    pub grad_accum: usize,
    /// Evaluate validation every this many epochs.
    pub eval_every: usize,
    /// Prefetch ring depth: number of arena buffers rotating between
    /// the materialize worker and the execute thread (2 = double
    /// buffering; see `--prefetch-depth`).
    pub prefetch_depth: usize,
    /// When set (and the generator is fixed, so a reusable validation
    /// cache exists), the per-epoch validation pass runs through this
    /// host [`Executor`] backend instead of the AOT infer artifact —
    /// no bucket padding, no runtime round-trip (`--val-executor`).
    pub val_executor: Option<ExecutorKind>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "gcn".into(),
            epochs: 100,
            lr: 1e-3,
            early_stop: 100,
            seed: 0,
            scheduler: SchedulerKind::Weighted,
            grad_accum: 1,
            eval_every: 1,
            prefetch_depth: crate::config::DEFAULT_PREFETCH_DEPTH,
            val_executor: None,
        }
    }
}

/// One point of the convergence curve.
#[derive(Debug, Clone, Copy)]
pub struct EpochRecord {
    pub epoch: usize,
    /// Wall-clock seconds since training start (excl. preprocessing).
    pub wall_s: f64,
    pub train_loss: f64,
    pub val_loss: f64,
    pub val_acc: f64,
    pub lr: f32,
}

/// Everything the experiment drivers need.
#[derive(Debug)]
pub struct TrainResult {
    pub history: Vec<EpochRecord>,
    pub preprocess_s: f64,
    pub mean_epoch_s: f64,
    pub state: ModelState,
    pub meta_train: ArtifactMeta,
    pub best_val_acc: f64,
    pub epochs_run: usize,
    pub cache_bytes: usize,
    /// Prefetch overlap ratio across training (§Perf target > 0.95).
    pub overlap_ratio: f64,
    /// Fresh `DenseBatch` allocations over the whole run — with arena
    /// reuse this equals the high-water ring size (train + validation
    /// buckets), NOT epochs × batches.
    pub arena_allocations: usize,
}

/// Host-side Adam (used only on the gradient-accumulation path; the
/// fast path fuses Adam into the train artifact).
pub fn host_adam(
    state: &mut ModelState,
    grads: &[f32],
    lr: f32,
) {
    const B1: f32 = 0.9;
    const B2: f32 = 0.999;
    const EPS: f32 = 1e-8;
    state.step += 1;
    let t = state.step as f32;
    let bc1 = 1.0 - B1.powf(t);
    let bc2 = 1.0 - B2.powf(t);
    for i in 0..state.params.len() {
        let g = grads[i];
        state.m[i] = B1 * state.m[i] + (1.0 - B1) * g;
        state.v[i] = B2 * state.v[i] + (1.0 - B2) * g * g;
        let m_hat = state.m[i] / bc1;
        let v_hat = state.v[i] / bc2;
        state.params[i] -= lr * m_hat / (v_hat.sqrt() + EPS);
    }
}

fn make_scheduler(
    kind: SchedulerKind,
    ds: &Dataset,
    cache: &BatchCache,
    rng: &mut Rng,
) -> Box<dyn Scheduler> {
    match kind {
        SchedulerKind::Sequential => Box::new(SequentialScheduler {
            num_batches: cache.len(),
        }),
        SchedulerKind::Shuffle => Box::new(ShuffleScheduler {
            num_batches: cache.len(),
        }),
        SchedulerKind::OptimalCycle | SchedulerKind::Weighted => {
            let hists: Vec<Vec<f64>> = (0..cache.len())
                .map(|i| ds.label_histogram(cache.output_nodes(i)))
                .collect();
            let dist = batch_distance_matrix(&hists);
            if kind == SchedulerKind::OptimalCycle {
                Box::new(OptimalCycleScheduler::new(&dist, rng))
            } else {
                Box::new(WeightedScheduler::new(dist))
            }
        }
    }
}

/// Train `cfg.model` with `generator`'s plans.
pub fn train(
    rt: &mut Runtime,
    ds: &Dataset,
    cfg: &TrainConfig,
    generator: &mut dyn BatchGenerator,
    rng: &mut Rng,
) -> Result<TrainResult> {
    let train_nodes = &ds.splits.train;
    let val_nodes = &ds.splits.val;
    anyhow::ensure!(!train_nodes.is_empty(), "empty training set");

    // ---- preprocessing (timed separately, like the paper's tables) ----
    let t_pre = Timer::start();
    let mut cache = BatchCache::build(&generator.plan(ds, train_nodes, rng));
    let val_cache = if generator.is_fixed() && !val_nodes.is_empty() {
        Some(BatchCache::build(&generator.plan(ds, val_nodes, rng)))
    } else {
        None
    };
    let preprocess_s = t_pre.elapsed_s();
    anyhow::ensure!(!cache.is_empty(), "generator produced no batches");

    let max_train = cache.max_batch_nodes();
    let train_kind = if cfg.grad_accum > 1 { "grad" } else { "train" };
    let meta_train = rt
        .manifest
        .bucket_meta(&cfg.model, train_kind, max_train)
        .ok_or_else(|| {
            anyhow!(
                "no {train_kind} bucket for {} fitting {max_train}",
                cfg.model
            )
        })?
        .clone();
    rt.executable(&meta_train.id)?; // compile outside the timed epochs
    anyhow::ensure!(
        ds.feat_dim == meta_train.feat,
        "dataset feat {} != artifact feat {}",
        ds.feat_dim,
        meta_train.feat
    );

    let mut state = ModelState::init(&meta_train, cfg.seed);

    // Executor-backed validation: built once, reused every eval epoch.
    // Scratch grows to the val high-water shape on the first pass and
    // stays allocation-free thereafter.
    let mut val_exec: Option<(Box<dyn Executor>, ArtifactMeta, ExecScratch)> =
        match (cfg.val_executor, val_cache.as_ref()) {
            (Some(kind), Some(vc)) => {
                let max_val = vc.max_batch_nodes();
                let meta_val = rt
                    .manifest
                    .bucket_meta(&cfg.model, "infer", max_val)
                    .ok_or_else(|| {
                        anyhow!(
                            "no infer bucket for {} fitting {max_val} nodes",
                            cfg.model
                        )
                    })?
                    .clone();
                Some((kind.build()?, meta_val, ExecScratch::new()))
            }
            _ => None,
        };

    let mut sched = make_scheduler(cfg.scheduler, ds, &cache, rng);
    let mut plateau =
        super::lr_schedule::ReduceLROnPlateau::paper_defaults(cfg.lr);

    // One arena serves the whole run: the train ring, and (through
    // `infer_with_batches`) the validation ring. After the first epoch
    // every buffer comes back from the pools.
    let mut arena = BatchArena::new(ds.feat_dim);
    let depth = cfg.prefetch_depth.max(1);

    let mut history = Vec::new();
    let mut best_val_loss = f64::INFINITY;
    let mut best_val_acc = 0.0f64;
    let mut bad_epochs = 0usize;
    let mut lr = cfg.lr;
    let mut epoch_times = Vec::new();
    let mut wait_total = 0.0;
    let mut consume_total = 0.0;
    let t_train = Timer::start();
    let cache_bytes = cache.memory_bytes()
        + val_cache.as_ref().map_or(0, |c| c.memory_bytes());

    let mut grad_buf = vec![0.0f32; meta_train.param_count];
    let mut epochs_run = 0;
    for epoch in 0..cfg.epochs {
        let t_epoch = Timer::start();
        // stochastic methods re-plan every epoch (their real cost) but
        // keep materializing into the same arena buffers
        if !generator.is_fixed() {
            cache = BatchCache::build(&generator.plan(ds, train_nodes, rng));
            if cache.is_empty() {
                continue;
            }
            sched = Box::new(ShuffleScheduler {
                num_batches: cache.len(),
            });
            let max_now = cache.max_batch_nodes();
            anyhow::ensure!(
                max_now <= meta_train.n_pad,
                "epoch {epoch}: batch of {max_now} exceeds bucket {}",
                meta_train.n_pad
            );
        }
        let order = sched.epoch_order(rng);
        let ring = arena.acquire_many(meta_train.n_pad, depth);
        let mut train_metrics = StepMetrics::default();
        let mut err: Option<anyhow::Error> = None;
        let mut accum_count = 0usize;
        let mut step_idx = 0usize;
        let cache_ref = &cache;
        let (stats, ring) = run_prefetched(
            &order,
            ring,
            |i, buf| cache_ref.materialize_into(ds, i, buf),
            |_, buf| {
                if err.is_some() {
                    return;
                }
                let seed = (cfg.seed as i32)
                    .wrapping_mul(31)
                    .wrapping_add((epoch * 10_007 + step_idx) as i32);
                step_idx += 1;
                let res = if cfg.grad_accum > 1 {
                    rt.grad_step(&meta_train, &state, buf, seed).map(|(g, m)| {
                        for (a, b) in grad_buf.iter_mut().zip(&g) {
                            *a += b;
                        }
                        accum_count += 1;
                        if accum_count == cfg.grad_accum {
                            for v in grad_buf.iter_mut() {
                                *v /= accum_count as f32;
                            }
                            host_adam(&mut state, &grad_buf, lr);
                            grad_buf.fill(0.0);
                            accum_count = 0;
                        }
                        m
                    })
                } else {
                    rt.train_step(&meta_train, &mut state, buf, lr, seed)
                };
                match res {
                    Ok(m) => train_metrics.merge(&m),
                    Err(e) => err = Some(e),
                }
            },
        );
        arena.release_many(ring);
        if let Some(e) = err {
            return Err(e);
        }
        // flush a trailing partial accumulation group
        if cfg.grad_accum > 1 && accum_count > 0 {
            for v in grad_buf.iter_mut() {
                *v /= accum_count as f32;
            }
            host_adam(&mut state, &grad_buf, lr);
            grad_buf.fill(0.0);
        }
        wait_total += stats.wait_s;
        consume_total += stats.consume_s;
        epoch_times.push(t_epoch.elapsed_s());
        epochs_run = epoch + 1;

        // ---- validation (method-approximated, like the paper) ----
        if epoch % cfg.eval_every != 0 && epoch + 1 != cfg.epochs {
            continue;
        }
        let (val_loss, val_acc) = if val_nodes.is_empty() {
            (train_metrics.mean_loss(), train_metrics.accuracy())
        } else if let Some((exec, meta_val, scratch)) = val_exec.as_mut() {
            let report = crate::inference::infer_with_executor(
                exec.as_ref(),
                meta_val,
                ds,
                &state,
                val_cache.as_ref().expect("val_exec implies val_cache"),
                scratch,
            )?;
            (report.mean_loss, report.accuracy)
        } else {
            let report = crate::inference::infer_with_batches(
                rt,
                ds,
                &cfg.model,
                &state,
                generator,
                val_cache.as_ref(),
                val_nodes,
                rng,
                &mut arena,
                depth,
            )?;
            (report.mean_loss, report.accuracy)
        };
        history.push(EpochRecord {
            epoch,
            wall_s: t_train.elapsed_s(),
            train_loss: train_metrics.mean_loss(),
            val_loss,
            val_acc,
            lr,
        });
        best_val_acc = best_val_acc.max(val_acc);
        lr = plateau.step(val_loss);
        if val_loss < best_val_loss - 1e-9 {
            best_val_loss = val_loss;
            bad_epochs = 0;
        } else {
            bad_epochs += 1;
            if cfg.early_stop > 0 && bad_epochs >= cfg.early_stop {
                break;
            }
        }
    }

    let mean_epoch_s = if epoch_times.is_empty() {
        0.0
    } else {
        epoch_times.iter().sum::<f64>() / epoch_times.len() as f64
    };
    let overlap_ratio = if wait_total + consume_total > 0.0 {
        consume_total / (wait_total + consume_total)
    } else {
        1.0
    };
    Ok(TrainResult {
        history,
        preprocess_s,
        mean_epoch_s,
        state,
        meta_train,
        best_val_acc,
        epochs_run,
        cache_bytes,
        overlap_ratio,
        arena_allocations: arena.allocations(),
    })
}
