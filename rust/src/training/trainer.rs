//! The training loop: preprocessing → cached plans → ring-prefetched
//! fused-Adam steps on arena-reused buffers → per-epoch approximate
//! validation → plateau LR + early stopping. Reproduces the paper's
//! protocol (App. B) on top of the plan/materialize pipeline
//! (DESIGN.md §4, §7).

use anyhow::{anyhow, bail, Result};

use crate::batching::{BatchArena, BatchCache, BatchGenerator};
use crate::datasets::Dataset;
use crate::exec::train::{
    train_artifact, TrainBatch, TrainExecutorKind, TrainScratch,
};
use crate::exec::{ExecScratch, Executor, ExecutorKind, PlanView};
use crate::pipeline::run_prefetched;
use crate::runtime::{ArtifactMeta, ModelState, Runtime, StepMetrics};
use crate::telemetry::span::{NO_QUERY, NO_SHARD};
use crate::telemetry::{Stage, Tracer};
use crate::scheduler::{
    batch_distance_matrix, OptimalCycleScheduler, Scheduler,
    SequentialScheduler, ShuffleScheduler, WeightedScheduler,
};
use crate::util::{Rng, Timer};

/// Which batch-order policy to use (paper §4 "Batch scheduling").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulerKind {
    Sequential,
    Shuffle,
    OptimalCycle,
    Weighted,
}

/// Training configuration (paper App. B defaults).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub model: String,
    pub epochs: usize,
    pub lr: f32,
    /// Early-stop patience in epochs on val loss (paper: 100; 0 = off).
    pub early_stop: usize,
    pub seed: u64,
    pub scheduler: SchedulerKind,
    /// Gradient accumulation: apply Adam every `grad_accum` batches
    /// via the `grad` artifact + host Adam (1 = fused fast path).
    pub grad_accum: usize,
    /// Evaluate validation every this many epochs.
    pub eval_every: usize,
    /// Prefetch ring depth: number of arena buffers rotating between
    /// the materialize worker and the execute thread (2 = double
    /// buffering; see `--prefetch-depth`).
    pub prefetch_depth: usize,
    /// When set (and the generator is fixed, so a reusable validation
    /// cache exists), the per-epoch validation pass runs through this
    /// host [`Executor`] backend instead of the AOT infer artifact —
    /// no bucket padding, no runtime round-trip (`--val-executor`).
    pub val_executor: Option<ExecutorKind>,
    /// Native training backend for [`train_native`] (`--executor`).
    /// Ignored by [`train`], which always steps through the runtime.
    pub executor: TrainExecutorKind,
    /// Model hyperparameters for the native path, which synthesizes
    /// its artifact meta instead of loading one (paper App. B
    /// defaults). [`train`] takes these from the AOT manifest instead.
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub dropout: f32,
    pub weight_decay: f32,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "gcn".into(),
            epochs: 100,
            lr: 1e-3,
            early_stop: 100,
            seed: 0,
            scheduler: SchedulerKind::Weighted,
            grad_accum: 1,
            eval_every: 1,
            prefetch_depth: crate::config::DEFAULT_PREFETCH_DEPTH,
            val_executor: None,
            executor: TrainExecutorKind::Blocked,
            hidden: 64,
            layers: 3,
            heads: 4,
            dropout: 0.3,
            weight_decay: 1e-4,
        }
    }
}

/// One point of the convergence curve.
#[derive(Debug, Clone, Copy)]
pub struct EpochRecord {
    pub epoch: usize,
    /// Wall-clock seconds since training start (excl. preprocessing).
    pub wall_s: f64,
    pub train_loss: f64,
    pub val_loss: f64,
    pub val_acc: f64,
    pub lr: f32,
}

/// Everything the experiment drivers need.
#[derive(Debug)]
pub struct TrainResult {
    pub history: Vec<EpochRecord>,
    pub preprocess_s: f64,
    pub mean_epoch_s: f64,
    pub state: ModelState,
    pub meta_train: ArtifactMeta,
    pub best_val_acc: f64,
    pub epochs_run: usize,
    pub cache_bytes: usize,
    /// Prefetch overlap ratio across training (§Perf target > 0.95).
    pub overlap_ratio: f64,
    /// Fresh `DenseBatch` allocations over the whole run — with arena
    /// reuse this equals the high-water ring size (train + validation
    /// buckets), NOT epochs × batches.
    pub arena_allocations: usize,
}

/// Host-side Adam (used only on the gradient-accumulation path; the
/// fast path fuses Adam into the train artifact).
pub fn host_adam(
    state: &mut ModelState,
    grads: &[f32],
    lr: f32,
) {
    const B1: f32 = 0.9;
    const B2: f32 = 0.999;
    const EPS: f32 = 1e-8;
    state.step += 1;
    let t = state.step as f32;
    let bc1 = 1.0 - B1.powf(t);
    let bc2 = 1.0 - B2.powf(t);
    for i in 0..state.params.len() {
        let g = grads[i];
        state.m[i] = B1 * state.m[i] + (1.0 - B1) * g;
        state.v[i] = B2 * state.v[i] + (1.0 - B2) * g * g;
        let m_hat = state.m[i] / bc1;
        let v_hat = state.v[i] / bc2;
        state.params[i] -= lr * m_hat / (v_hat.sqrt() + EPS);
    }
}

fn make_scheduler(
    kind: SchedulerKind,
    ds: &Dataset,
    cache: &BatchCache,
    rng: &mut Rng,
) -> Box<dyn Scheduler> {
    match kind {
        SchedulerKind::Sequential => Box::new(SequentialScheduler {
            num_batches: cache.len(),
        }),
        SchedulerKind::Shuffle => Box::new(ShuffleScheduler {
            num_batches: cache.len(),
        }),
        SchedulerKind::OptimalCycle | SchedulerKind::Weighted => {
            let hists: Vec<Vec<f64>> = (0..cache.len())
                .map(|i| ds.label_histogram(cache.output_nodes(i)))
                .collect();
            let dist = batch_distance_matrix(&hists);
            if kind == SchedulerKind::OptimalCycle {
                Box::new(OptimalCycleScheduler::new(&dist, rng))
            } else {
                Box::new(WeightedScheduler::new(dist))
            }
        }
    }
}

/// Train `cfg.model` with `generator`'s plans.
pub fn train(
    rt: &mut Runtime,
    ds: &Dataset,
    cfg: &TrainConfig,
    generator: &mut dyn BatchGenerator,
    rng: &mut Rng,
) -> Result<TrainResult> {
    let train_nodes = &ds.splits.train;
    let val_nodes = &ds.splits.val;
    anyhow::ensure!(!train_nodes.is_empty(), "empty training set");

    // ---- preprocessing (timed separately, like the paper's tables) ----
    let t_pre = Timer::start();
    let mut cache = BatchCache::build(&generator.plan(ds, train_nodes, rng));
    let val_cache = if generator.is_fixed() && !val_nodes.is_empty() {
        Some(BatchCache::build(&generator.plan(ds, val_nodes, rng)))
    } else {
        None
    };
    let preprocess_s = t_pre.elapsed_s();
    anyhow::ensure!(!cache.is_empty(), "generator produced no batches");

    let max_train = cache.max_batch_nodes();
    let train_kind = if cfg.grad_accum > 1 { "grad" } else { "train" };
    let meta_train = rt
        .manifest
        .bucket_meta(&cfg.model, train_kind, max_train)
        .ok_or_else(|| {
            anyhow!(
                "no {train_kind} bucket for {} fitting {max_train}",
                cfg.model
            )
        })?
        .clone();
    rt.executable(&meta_train.id)?; // compile outside the timed epochs
    anyhow::ensure!(
        ds.feat_dim == meta_train.feat,
        "dataset feat {} != artifact feat {}",
        ds.feat_dim,
        meta_train.feat
    );

    let mut state = ModelState::init(&meta_train, cfg.seed);

    // Executor-backed validation: built once, reused every eval epoch.
    // Scratch grows to the val high-water shape on the first pass and
    // stays allocation-free thereafter.
    let mut val_exec: Option<(Box<dyn Executor>, ArtifactMeta, ExecScratch)> =
        match (cfg.val_executor, val_cache.as_ref()) {
            (Some(kind), Some(vc)) => {
                let max_val = vc.max_batch_nodes();
                let meta_val = rt
                    .manifest
                    .bucket_meta(&cfg.model, "infer", max_val)
                    .ok_or_else(|| {
                        anyhow!(
                            "no infer bucket for {} fitting {max_val} nodes",
                            cfg.model
                        )
                    })?
                    .clone();
                Some((kind.build()?, meta_val, ExecScratch::new()))
            }
            _ => None,
        };

    let mut sched = make_scheduler(cfg.scheduler, ds, &cache, rng);
    let mut plateau =
        super::lr_schedule::ReduceLROnPlateau::paper_defaults(cfg.lr);

    // One arena serves the whole run: the train ring, and (through
    // `infer_with_batches`) the validation ring. After the first epoch
    // every buffer comes back from the pools.
    let mut arena = BatchArena::new(ds.feat_dim);
    let depth = cfg.prefetch_depth.max(1);

    let mut history = Vec::new();
    let mut best_val_loss = f64::INFINITY;
    let mut best_val_acc = 0.0f64;
    let mut bad_epochs = 0usize;
    let mut lr = cfg.lr;
    let mut epoch_times = Vec::new();
    let mut wait_total = 0.0;
    let mut consume_total = 0.0;
    let t_train = Timer::start();
    let cache_bytes = cache.memory_bytes()
        + val_cache.as_ref().map_or(0, |c| c.memory_bytes());

    let mut grad_buf = vec![0.0f32; meta_train.param_count];
    let mut epochs_run = 0;
    for epoch in 0..cfg.epochs {
        let t_epoch = Timer::start();
        // stochastic methods re-plan every epoch (their real cost) but
        // keep materializing into the same arena buffers
        if !generator.is_fixed() {
            cache = BatchCache::build(&generator.plan(ds, train_nodes, rng));
            if cache.is_empty() {
                continue;
            }
            sched = Box::new(ShuffleScheduler {
                num_batches: cache.len(),
            });
            let max_now = cache.max_batch_nodes();
            anyhow::ensure!(
                max_now <= meta_train.n_pad,
                "epoch {epoch}: batch of {max_now} exceeds bucket {}",
                meta_train.n_pad
            );
        }
        let order = sched.epoch_order(rng);
        let ring = arena.acquire_many(meta_train.n_pad, depth);
        let mut train_metrics = StepMetrics::default();
        let mut err: Option<anyhow::Error> = None;
        let mut accum_count = 0usize;
        let mut step_idx = 0usize;
        let cache_ref = &cache;
        let (stats, ring) = run_prefetched(
            &order,
            ring,
            |i, buf| cache_ref.materialize_into(ds, i, buf),
            |_, buf| {
                if err.is_some() {
                    return;
                }
                let seed = (cfg.seed as i32)
                    .wrapping_mul(31)
                    .wrapping_add((epoch * 10_007 + step_idx) as i32);
                step_idx += 1;
                let res = if cfg.grad_accum > 1 {
                    // gradients accumulate straight into the caller-owned
                    // buffer — no per-batch Vec from the runtime
                    rt.grad_step(&meta_train, &state, buf, seed, &mut grad_buf)
                        .map(|m| {
                        accum_count += 1;
                        if accum_count == cfg.grad_accum {
                            for v in grad_buf.iter_mut() {
                                *v /= accum_count as f32;
                            }
                            host_adam(&mut state, &grad_buf, lr);
                            grad_buf.fill(0.0);
                            accum_count = 0;
                        }
                        m
                    })
                } else {
                    rt.train_step(&meta_train, &mut state, buf, lr, seed)
                };
                match res {
                    Ok(m) => train_metrics.merge(&m),
                    Err(e) => err = Some(e),
                }
            },
        );
        arena.release_many(ring);
        if let Some(e) = err {
            return Err(e);
        }
        // flush a trailing partial accumulation group
        if cfg.grad_accum > 1 && accum_count > 0 {
            for v in grad_buf.iter_mut() {
                *v /= accum_count as f32;
            }
            host_adam(&mut state, &grad_buf, lr);
            grad_buf.fill(0.0);
        }
        wait_total += stats.wait_s;
        consume_total += stats.consume_s;
        epoch_times.push(t_epoch.elapsed_s());
        epochs_run = epoch + 1;

        // ---- validation (method-approximated, like the paper) ----
        if epoch % cfg.eval_every != 0 && epoch + 1 != cfg.epochs {
            continue;
        }
        let (val_loss, val_acc) = if val_nodes.is_empty() {
            (train_metrics.mean_loss(), train_metrics.accuracy())
        } else if let Some((exec, meta_val, scratch)) = val_exec.as_mut() {
            let report = crate::inference::infer_with_executor(
                exec.as_ref(),
                meta_val,
                ds,
                &state,
                val_cache.as_ref().expect("val_exec implies val_cache"),
                scratch,
            )?;
            (report.mean_loss, report.accuracy)
        } else {
            let report = crate::inference::infer_with_batches(
                rt,
                ds,
                &cfg.model,
                &state,
                generator,
                val_cache.as_ref(),
                val_nodes,
                rng,
                &mut arena,
                depth,
            )?;
            (report.mean_loss, report.accuracy)
        };
        history.push(EpochRecord {
            epoch,
            wall_s: t_train.elapsed_s(),
            train_loss: train_metrics.mean_loss(),
            val_loss,
            val_acc,
            lr,
        });
        best_val_acc = best_val_acc.max(val_acc);
        lr = plateau.step(val_loss);
        if val_loss < best_val_loss - 1e-9 {
            best_val_loss = val_loss;
            bad_epochs = 0;
        } else {
            bad_epochs += 1;
            if cfg.early_stop > 0 && bad_epochs >= cfg.early_stop {
                break;
            }
        }
    }

    let mean_epoch_s = if epoch_times.is_empty() {
        0.0
    } else {
        epoch_times.iter().sum::<f64>() / epoch_times.len() as f64
    };
    let overlap_ratio = if wait_total + consume_total > 0.0 {
        consume_total / (wait_total + consume_total)
    } else {
        1.0
    };
    Ok(TrainResult {
        history,
        preprocess_s,
        mean_epoch_s,
        state,
        meta_train,
        best_val_acc,
        epochs_run,
        cache_bytes,
        overlap_ratio,
        arena_allocations: arena.allocations(),
    })
}

/// One ring slot for the native training pipeline: a plan's gathered
/// features and labels, sparse — no adjacency densification, no
/// padding. `x`/`labels` ratchet to the epoch's high-water batch size,
/// so after the first lap the ring performs zero allocations.
struct NativeBatch {
    plan: usize,
    n: usize,
    num_outputs: usize,
    x: Vec<f32>,
    labels: Vec<i32>,
    /// Fill wall time, recorded on the worker thread and emitted as a
    /// [`Stage::Materialize`] instant from the consume side (the
    /// worker closure is `Fn + Sync` and cannot hold the trace buffer).
    fill_us: u64,
}

/// Train `cfg.model` entirely on the host through a native
/// [`crate::exec::TrainExecutor`] — no AOT artifacts, no runtime, no
/// dense padding (DESIGN.md §16). Mirrors [`train`]'s protocol
/// (schedulers, plateau LR, early stop, grad accumulation,
/// ring-prefetched materialization) with the fused sparse step in
/// place of the runtime round-trip. Validation runs through the
/// inference [`Executor`] matching the training backend (overridable
/// via `cfg.val_executor`).
///
/// Emits [`Stage::Materialize`] / [`Stage::TrainStep`] instants per
/// batch when `tracer` is attached (`ibmb train --trace`).
pub fn train_native(
    ds: &Dataset,
    cfg: &TrainConfig,
    generator: &mut dyn BatchGenerator,
    rng: &mut Rng,
    tracer: &Tracer,
) -> Result<TrainResult> {
    let train_nodes = &ds.splits.train;
    let val_nodes = &ds.splits.val;
    anyhow::ensure!(!train_nodes.is_empty(), "empty training set");
    if cfg.model == "gat" {
        bail!(
            "native training supports gcn|sage (the GAT attention VJP \
             is not implemented); use --executor runtime"
        );
    }
    if cfg.executor == TrainExecutorKind::Runtime {
        bail!("train_native: --executor runtime goes through training::train");
    }

    // ---- preprocessing (timed separately, like the paper's tables) ----
    let t_pre = Timer::start();
    let mut cache = BatchCache::build(&generator.plan(ds, train_nodes, rng));
    let val_cache = if generator.is_fixed() && !val_nodes.is_empty() {
        Some(BatchCache::build(&generator.plan(ds, val_nodes, rng)))
    } else {
        None
    };
    let preprocess_s = t_pre.elapsed_s();
    anyhow::ensure!(!cache.is_empty(), "generator produced no batches");

    let meta_train = train_artifact(
        &cfg.model,
        ds.feat_dim,
        ds.num_classes,
        cfg.hidden,
        cfg.layers,
        cfg.heads,
        cfg.dropout as f64,
        cfg.weight_decay as f64,
        cache.max_batch_nodes(),
    );
    let texec = cfg.executor.build()?;
    let mut state = ModelState::init(&meta_train, cfg.seed);
    let mut scratch = TrainScratch::new();
    let mut grad_buf = vec![0.0f32; meta_train.param_count];

    // Validation backend: the inference executor matching the training
    // backend (no padding either), built once and reused every eval.
    let mut val_exec: Option<(Box<dyn Executor>, ArtifactMeta, ExecScratch)> =
        if val_nodes.is_empty() {
            None
        } else {
            let kind = cfg.val_executor.unwrap_or(match cfg.executor {
                TrainExecutorKind::Reference => ExecutorKind::Reference,
                _ => ExecutorKind::Blocked,
            });
            let meta_val = crate::serve::reference_artifact(
                &cfg.model,
                ds.feat_dim,
                ds.num_classes,
                cfg.hidden,
                cfg.layers,
                cfg.heads,
                cache.max_batch_nodes(),
            );
            Some((kind.build()?, meta_val, ExecScratch::new()))
        };

    let mut sched = make_scheduler(cfg.scheduler, ds, &cache, rng);
    let mut plateau =
        super::lr_schedule::ReduceLROnPlateau::paper_defaults(cfg.lr);

    let depth = cfg.prefetch_depth.max(1);
    let max_nodes = cache.max_batch_nodes();
    let mut ring: Vec<NativeBatch> = (0..depth)
        .map(|_| NativeBatch {
            plan: 0,
            n: 0,
            num_outputs: 0,
            x: Vec::with_capacity(max_nodes * ds.feat_dim),
            labels: Vec::with_capacity(max_nodes),
            fill_us: 0,
        })
        .collect();
    let mut tb = tracer.buffer();

    let mut history = Vec::new();
    let mut best_val_loss = f64::INFINITY;
    let mut best_val_acc = 0.0f64;
    let mut bad_epochs = 0usize;
    let mut lr = cfg.lr;
    let mut epoch_times = Vec::new();
    let mut wait_total = 0.0;
    let mut consume_total = 0.0;
    let t_train = Timer::start();
    let cache_bytes = cache.memory_bytes()
        + val_cache.as_ref().map_or(0, |c| c.memory_bytes());

    let mut epochs_run = 0;
    for epoch in 0..cfg.epochs {
        let t_epoch = Timer::start();
        if !generator.is_fixed() {
            cache = BatchCache::build(&generator.plan(ds, train_nodes, rng));
            if cache.is_empty() {
                continue;
            }
            sched = Box::new(ShuffleScheduler {
                num_batches: cache.len(),
            });
        }
        let order = sched.epoch_order(rng);
        let mut train_metrics = StepMetrics::default();
        let mut accum_count = 0usize;
        let mut step_idx = 0usize;
        let cache_ref = &cache;
        let feat = ds.feat_dim;
        let (stats, ring_back) = run_prefetched(
            &order,
            ring,
            |i, buf: &mut NativeBatch| {
                let t_fill = Timer::start();
                buf.plan = i;
                buf.n = cache_ref.gather_features_into(ds, i, &mut buf.x);
                cache_ref.gather_labels_into(ds, i, &mut buf.labels);
                buf.num_outputs = cache_ref.num_outputs(i);
                buf.fill_us = (t_fill.elapsed_s() * 1e6) as u64;
            },
            |_, buf| {
                tb.instant(
                    Stage::Materialize,
                    NO_QUERY,
                    buf.plan as u64,
                    NO_SHARD,
                    buf.fill_us,
                );
                let t_step = Timer::start();
                let view = PlanView {
                    n: buf.n,
                    edge_src: cache_ref.edge_src_of(buf.plan),
                    edge_dst: cache_ref.edge_dst_of(buf.plan),
                    weights: cache_ref.edge_weights_of(buf.plan),
                };
                let sbatch = TrainBatch {
                    view,
                    x: &buf.x[..buf.n * feat],
                    labels: &buf.labels[..buf.n],
                    num_outputs: buf.num_outputs,
                };
                let seed = (cfg.seed as i32)
                    .wrapping_mul(31)
                    .wrapping_add((epoch * 10_007 + step_idx) as i32);
                step_idx += 1;
                let m = if cfg.grad_accum > 1 {
                    let m = texec.grad_step(
                        &meta_train,
                        &state,
                        &sbatch,
                        seed,
                        &mut grad_buf,
                        &mut scratch,
                    );
                    accum_count += 1;
                    if accum_count == cfg.grad_accum {
                        for v in grad_buf.iter_mut() {
                            *v /= accum_count as f32;
                        }
                        host_adam(&mut state, &grad_buf, lr);
                        grad_buf.fill(0.0);
                        accum_count = 0;
                    }
                    m
                } else {
                    texec.train_step(
                        &meta_train,
                        &mut state,
                        &sbatch,
                        lr,
                        seed,
                        &mut scratch,
                    )
                };
                train_metrics.merge(&m);
                tb.instant(
                    Stage::TrainStep,
                    NO_QUERY,
                    buf.plan as u64,
                    NO_SHARD,
                    (t_step.elapsed_s() * 1e6) as u64,
                );
            },
        );
        ring = ring_back;
        // flush a trailing partial accumulation group
        if cfg.grad_accum > 1 && accum_count > 0 {
            for v in grad_buf.iter_mut() {
                *v /= accum_count as f32;
            }
            host_adam(&mut state, &grad_buf, lr);
            grad_buf.fill(0.0);
        }
        wait_total += stats.wait_s;
        consume_total += stats.consume_s;
        epoch_times.push(t_epoch.elapsed_s());
        epochs_run = epoch + 1;

        // ---- validation (host executor, method-approximated) ----
        if epoch % cfg.eval_every != 0 && epoch + 1 != cfg.epochs {
            continue;
        }
        let (val_loss, val_acc) = match val_exec.as_mut() {
            None => (train_metrics.mean_loss(), train_metrics.accuracy()),
            Some((exec, meta_val, vscratch)) => {
                let owned_vc;
                let vc = match val_cache.as_ref() {
                    Some(c) => c,
                    None => {
                        owned_vc = BatchCache::build(
                            &generator.plan(ds, val_nodes, rng),
                        );
                        &owned_vc
                    }
                };
                let report = crate::inference::infer_with_executor(
                    exec.as_ref(),
                    meta_val,
                    ds,
                    &state,
                    vc,
                    vscratch,
                )?;
                (report.mean_loss, report.accuracy)
            }
        };
        history.push(EpochRecord {
            epoch,
            wall_s: t_train.elapsed_s(),
            train_loss: train_metrics.mean_loss(),
            val_loss,
            val_acc,
            lr,
        });
        best_val_acc = best_val_acc.max(val_acc);
        lr = plateau.step(val_loss);
        if val_loss < best_val_loss - 1e-9 {
            best_val_loss = val_loss;
            bad_epochs = 0;
        } else {
            bad_epochs += 1;
            if cfg.early_stop > 0 && bad_epochs >= cfg.early_stop {
                break;
            }
        }
    }
    tb.flush();

    let mean_epoch_s = if epoch_times.is_empty() {
        0.0
    } else {
        epoch_times.iter().sum::<f64>() / epoch_times.len() as f64
    };
    let overlap_ratio = if wait_total + consume_total > 0.0 {
        consume_total / (wait_total + consume_total)
    } else {
        1.0
    };
    Ok(TrainResult {
        history,
        preprocess_s,
        mean_epoch_s,
        state,
        meta_train,
        best_val_acc,
        epochs_run,
        cache_bytes,
        overlap_ratio,
        // the native ring: one slot per prefetch depth, reused forever
        arena_allocations: depth,
    })
}
