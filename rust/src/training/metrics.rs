//! Classification metrics beyond plain accuracy: confusion matrix,
//! per-class accuracy/F1, macro averages. Used by the inference
//! drivers' detailed reports and by tests asserting that models learn
//! *all* classes (not just the majority ones).

/// Streaming confusion matrix over `classes` labels.
#[derive(Debug, Clone)]
pub struct Confusion {
    pub classes: usize,
    /// Row = true label, column = prediction.
    counts: Vec<u64>,
}

impl Confusion {
    pub fn new(classes: usize) -> Confusion {
        Confusion {
            classes,
            counts: vec![0; classes * classes],
        }
    }

    #[inline]
    pub fn record(&mut self, truth: usize, pred: usize) {
        debug_assert!(truth < self.classes && pred < self.classes);
        self.counts[truth * self.classes + pred] += 1;
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    pub fn accuracy(&self) -> f64 {
        let correct: u64 =
            (0..self.classes).map(|c| self.counts[c * self.classes + c]).sum();
        let total = self.total();
        if total == 0 {
            0.0
        } else {
            correct as f64 / total as f64
        }
    }

    /// Recall (per-class accuracy) for class `c`.
    pub fn recall(&self, c: usize) -> f64 {
        let row: u64 = self.counts[c * self.classes..(c + 1) * self.classes]
            .iter()
            .sum();
        if row == 0 {
            return 0.0;
        }
        self.counts[c * self.classes + c] as f64 / row as f64
    }

    pub fn precision(&self, c: usize) -> f64 {
        let col: u64 = (0..self.classes)
            .map(|r| self.counts[r * self.classes + c])
            .sum();
        if col == 0 {
            return 0.0;
        }
        self.counts[c * self.classes + c] as f64 / col as f64
    }

    pub fn f1(&self, c: usize) -> f64 {
        let p = self.precision(c);
        let r = self.recall(c);
        if p + r == 0.0 {
            0.0
        } else {
            2.0 * p * r / (p + r)
        }
    }

    /// Unweighted mean F1 over classes that appear in the data.
    pub fn macro_f1(&self) -> f64 {
        let present: Vec<usize> = (0..self.classes)
            .filter(|&c| {
                self.counts[c * self.classes..(c + 1) * self.classes]
                    .iter()
                    .sum::<u64>()
                    > 0
            })
            .collect();
        if present.is_empty() {
            return 0.0;
        }
        present.iter().map(|&c| self.f1(c)).sum::<f64>() / present.len() as f64
    }

    pub fn merge(&mut self, other: &Confusion) {
        assert_eq!(self.classes, other.classes);
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Confusion {
        let mut c = Confusion::new(3);
        // class 0: 3 right, 1 as class 1
        for _ in 0..3 {
            c.record(0, 0);
        }
        c.record(0, 1);
        // class 1: 2 right
        c.record(1, 1);
        c.record(1, 1);
        // class 2: never predicted right
        c.record(2, 0);
        c
    }

    #[test]
    fn accuracy_and_total() {
        let c = sample();
        assert_eq!(c.total(), 7);
        assert!((c.accuracy() - 5.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn per_class_metrics() {
        let c = sample();
        assert!((c.recall(0) - 0.75).abs() < 1e-12);
        assert!((c.recall(1) - 1.0).abs() < 1e-12);
        assert_eq!(c.recall(2), 0.0);
        assert!((c.precision(0) - 3.0 / 4.0).abs() < 1e-12);
        assert!((c.precision(1) - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(c.f1(2), 0.0);
    }

    #[test]
    fn macro_f1_ignores_absent_classes() {
        let mut c = Confusion::new(5);
        c.record(0, 0);
        c.record(1, 1);
        // classes 2..4 absent
        assert!((c.macro_f1() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = sample();
        let b = sample();
        a.merge(&b);
        assert_eq!(a.total(), 14);
        assert!((a.accuracy() - 5.0 / 7.0).abs() < 1e-12);
    }

    #[test]
    fn empty_is_safe() {
        let c = Confusion::new(4);
        assert_eq!(c.accuracy(), 0.0);
        assert_eq!(c.macro_f1(), 0.0);
    }
}
