//! Staleness-tracked incremental replanning under graph deltas
//! (DESIGN.md §10).
//!
//! The precomputed plan set is IBMB's entire serving advantage, so
//! instead of re-running the full pipeline (per-root PPR → partition →
//! assembly) on every graph change, [`DynamicPlanSet`] keeps the
//! *inputs* of planning alive — one residual-carrying
//! [`PprState`] per output root — and repairs them with the local
//! correction rule of [`crate::ppr::incremental`]. Two inverted
//! indexes make staleness detection delta-local:
//!
//! * **support** (node → roots with estimate mass there) finds the
//!   roots whose PPR a touched node can shift;
//! * **members** (node → plans containing it) finds plans whose
//!   induced topology a touched edge can change.
//!
//! A plan is **rebuilt** (aux selection re-run from the refreshed PPR
//! vectors, node list may change) only when its outputs' summed L1
//! drift exceeds `l1_tol`; it is merely **patched** (same node list,
//! topology re-induced, epoch bumped) when it contains touched or
//! feature-updated nodes but its influence stayed put. The output
//! partition itself is stable across deltas — outputs never migrate
//! between plans — so the serving router's node → plan index stays
//! valid and only per-plan *epochs* move, which is what the results
//! memo keys freshness on ([`crate::serve::results`]).

use std::collections::{HashMap, HashSet};
use std::time::Instant;

use super::batch::BatchPlan;
use super::cache::{BatchCache, CowCache, PlanPayload};
use super::ibmb_node::assemble_plan;
use crate::graph::delta::AppliedDelta;
use crate::graph::{induced_subgraph, GraphView};
use crate::partition::pprdist::ppr_distance_partition;
use crate::ppr::incremental::{push_ppr_state, refresh_ppr_state, PprState};
use crate::ppr::push::{PushConfig, PushWorkspace};
use crate::util::Rng;

/// Dynamic replanning knobs. The planning triple mirrors
/// [`super::NodeWiseIbmb`]; `l1_tol` is the drift budget below which a
/// plan's auxiliary selection is considered still influence-optimal.
#[derive(Debug, Clone)]
pub struct RefreshConfig {
    pub aux_per_output: usize,
    pub max_outputs_per_batch: usize,
    pub node_budget: usize,
    /// Rebuild a plan when the summed L1 drift of its outputs' PPR
    /// estimates exceeds this.
    pub l1_tol: f32,
    pub push: PushConfig,
}

impl Default for RefreshConfig {
    fn default() -> Self {
        RefreshConfig {
            aux_per_output: 16,
            max_outputs_per_batch: 96,
            node_budget: 2048,
            l1_tol: 0.05,
            push: PushConfig::default(),
        }
    }
}

/// What one [`DynamicPlanSet::apply_delta`] did.
#[derive(Debug, Clone, Default)]
pub struct RefreshReport {
    /// Graph epoch the plan set now reflects.
    pub epoch: u64,
    /// Nodes whose adjacency changed in this delta.
    pub touched_nodes: usize,
    /// Roots whose PPR state was incrementally repaired.
    pub roots_refreshed: usize,
    pub plans_total: usize,
    /// Plans whose aux selection was re-run (influence drifted).
    pub plans_rebuilt: usize,
    /// Plans re-induced / epoch-bumped without replanning.
    pub plans_patched: usize,
    /// Ids of all changed (rebuilt + patched) plans.
    pub changed_plans: Vec<u32>,
    /// Largest per-root L1 drift observed.
    pub max_root_l1: f32,
    /// Seconds in PPR refresh.
    pub refresh_s: f64,
    /// Seconds in plan rebuild/patch (assembly + induction).
    pub replan_s: f64,
}

impl RefreshReport {
    /// Fraction of plans fully rebuilt — the bench headline: << 1 for
    /// small deltas is what makes incremental maintenance worth it.
    pub fn rebuilt_fraction(&self) -> f64 {
        if self.plans_total == 0 {
            0.0
        } else {
            self.plans_rebuilt as f64 / self.plans_total as f64
        }
    }

    /// Rebuilt + patched plans (anything whose epoch moved) — the
    /// "stale plans" count surfaced by the CI smoke.
    pub fn stale_plans(&self) -> usize {
        self.plans_rebuilt + self.plans_patched
    }
}

/// The live planning state: per-root PPR, the current plan set, plan
/// epochs, and the two inverted indexes driving staleness detection.
pub struct DynamicPlanSet {
    cfg: RefreshConfig,
    out_nodes: Vec<u32>,
    /// output node id → root index.
    root_of: HashMap<u32, usize>,
    /// Per-root push states, aligned with `out_nodes`.
    states: Vec<PprState>,
    /// root index → plan id.
    plan_of_root: Vec<u32>,
    plans: Vec<BatchPlan>,
    /// Per-plan epoch: the graph epoch the plan last reflected.
    epochs: Vec<u64>,
    epoch: u64,
    /// node → root indexes with nonzero estimate mass at that node.
    support: HashMap<u32, Vec<u32>>,
    /// node → plan ids whose node list contains it.
    members: HashMap<u32, Vec<u32>>,
    ws: PushWorkspace,
}

impl DynamicPlanSet {
    /// Full initial plan: per-root PPR states, PPR-distance output
    /// partition, influence-maximal assembly — node-wise IBMB with the
    /// planning inputs retained for later incremental repair.
    pub fn plan_initial<G: GraphView>(
        g: &G,
        out_nodes: &[u32],
        cfg: RefreshConfig,
        rng: &mut Rng,
    ) -> DynamicPlanSet {
        let mut ws = PushWorkspace::new(g.num_nodes());
        let states: Vec<PprState> = out_nodes
            .iter()
            .map(|&s| push_ppr_state(g, s, &cfg.push, &mut ws))
            .collect();
        let sparse: Vec<_> = states.iter().map(|s| s.to_sparse()).collect();
        let groups = ppr_distance_partition(
            out_nodes,
            &sparse,
            cfg.max_outputs_per_batch,
            rng,
        );
        let root_of: HashMap<u32, usize> = out_nodes
            .iter()
            .enumerate()
            .map(|(i, &u)| (u, i))
            .collect();
        let mut plan_of_root = vec![0u32; out_nodes.len()];
        let mut plans = Vec::with_capacity(groups.len());
        for outputs in &groups {
            let pid = plans.len() as u32;
            let per_output: Vec<(&[u32], &[f32])> = outputs
                .iter()
                .map(|o| {
                    let sp = &sparse[root_of[o]];
                    (&sp.nodes[..], &sp.scores[..])
                })
                .collect();
            plans.push(assemble_plan(
                g,
                outputs,
                &per_output,
                cfg.aux_per_output,
                cfg.node_budget,
            ));
            for o in outputs {
                plan_of_root[root_of[o]] = pid;
            }
        }
        let epochs = vec![0u64; plans.len()];
        let mut set = DynamicPlanSet {
            cfg,
            out_nodes: out_nodes.to_vec(),
            root_of,
            states,
            plan_of_root,
            plans,
            epochs,
            epoch: 0,
            support: HashMap::new(),
            members: HashMap::new(),
            ws,
        };
        for r in 0..set.states.len() {
            set.index_support(r);
        }
        for pid in 0..set.plans.len() {
            set.index_members(pid as u32);
        }
        set
    }

    pub fn len(&self) -> usize {
        self.plans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.plans.is_empty()
    }

    pub fn plans(&self) -> &[BatchPlan] {
        &self.plans
    }

    pub fn epochs(&self) -> &[u64] {
        &self.epochs
    }

    /// Graph epoch the plan set currently reflects.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Pack the current plans into a fresh contiguous [`BatchCache`].
    pub fn build_cache(&self) -> BatchCache {
        BatchCache::build(&self.plans)
    }

    /// Bucket the current plans into a copy-on-write store (the
    /// serving snapshot's plan cache, DESIGN.md §11).
    pub fn cow_cache(&self) -> CowCache {
        CowCache::from_plans(&self.plans)
    }

    /// Build the *next* snapshot's plan store from the previous one by
    /// replacing only the `changed` buckets (typically
    /// [`RefreshReport::changed_plans`]) — every untouched plan is a
    /// pointer copy, so the per-delta cost scales with the delta, not
    /// the deployment.
    pub fn patch_cow(&self, prev: &CowCache, changed: &[u32]) -> CowCache {
        debug_assert_eq!(
            prev.len(),
            self.plans.len(),
            "plan set is size-stable across deltas"
        );
        prev.with_patched(changed.iter().map(|&pid| {
            (pid, PlanPayload::from_plan(&self.plans[pid as usize]))
        }))
    }

    /// Clamp the node budget for *future* rebuilds (the serving bucket
    /// `n_pad` is fixed at prepare time; rebuilt plans must keep
    /// fitting it).
    pub fn clamp_node_budget(&mut self, cap: usize) {
        self.cfg.node_budget = self.cfg.node_budget.min(cap);
    }

    // Support must track every node with *nonzero* estimate (refreshed
    // states can carry small negative p after edge removals): the
    // correction term scales by p(y), so a p != 0 root skipped here
    // would silently miss its repair on the next delta.
    fn index_support(&mut self, root_idx: usize) {
        let st = &self.states[root_idx];
        for (i, &v) in st.nodes.iter().enumerate() {
            if st.p[i] != 0.0 {
                self.support.entry(v).or_default().push(root_idx as u32);
            }
        }
    }

    fn unindex_support(&mut self, root_idx: usize) {
        let st = &self.states[root_idx];
        for (i, &v) in st.nodes.iter().enumerate() {
            if st.p[i] != 0.0 {
                if let Some(roots) = self.support.get_mut(&v) {
                    roots.retain(|&r| r != root_idx as u32);
                }
            }
        }
    }

    fn index_members(&mut self, pid: u32) {
        for &v in &self.plans[pid as usize].nodes {
            self.members.entry(v).or_default().push(pid);
        }
    }

    fn unindex_members(&mut self, pid: u32) {
        for &v in &self.plans[pid as usize].nodes {
            if let Some(pids) = self.members.get_mut(&v) {
                pids.retain(|&p| p != pid);
            }
        }
    }

    /// Repair the plan set against one applied delta: refresh the PPR
    /// states whose support intersects the touched nodes, rebuild
    /// plans whose influence drifted past `l1_tol`, patch (re-induce)
    /// plans merely containing touched or feature-updated nodes, and
    /// bump the epochs of everything that changed.
    pub fn apply_delta<G: GraphView>(
        &mut self,
        g_new: &G,
        applied: &AppliedDelta,
    ) -> RefreshReport {
        self.epoch = applied.epoch;
        self.ws.ensure(g_new.num_nodes());

        // roots whose estimate mass sits on a touched node — the only
        // states the correction rule can move
        let mut affected: Vec<u32> = Vec::new();
        {
            let mut seen: HashSet<u32> = HashSet::new();
            for y in &applied.touched {
                if let Some(roots) = self.support.get(y) {
                    for &r in roots {
                        if seen.insert(r) {
                            affected.push(r);
                        }
                    }
                }
            }
            affected.sort_unstable();
        }

        let t_refresh = Instant::now();
        let mut drift: HashMap<u32, f32> = HashMap::new();
        let mut max_root_l1 = 0.0f32;
        for &r in &affected {
            let (new_state, l1) = refresh_ppr_state(
                g_new,
                &self.states[r as usize],
                applied,
                &self.cfg.push,
                &mut self.ws,
            );
            self.unindex_support(r as usize);
            self.states[r as usize] = new_state;
            self.index_support(r as usize);
            *drift.entry(self.plan_of_root[r as usize]).or_insert(0.0) += l1;
            max_root_l1 = max_root_l1.max(l1);
        }
        let refresh_s = t_refresh.elapsed().as_secs_f64();

        // rebuild set: influence drifted past tolerance
        let mut rebuild: Vec<u32> = drift
            .iter()
            .filter(|(_, &l1)| l1 > self.cfg.l1_tol)
            .map(|(&pid, _)| pid)
            .collect();
        rebuild.sort_unstable();
        let rebuild_set: HashSet<u32> = rebuild.iter().copied().collect();

        // patch set: plans containing touched or feature-updated nodes
        let mut patch: Vec<u32> = Vec::new();
        {
            let mut seen: HashSet<u32> = HashSet::new();
            for y in applied.touched.iter().chain(&applied.feature_updates) {
                if let Some(pids) = self.members.get(y) {
                    for &pid in pids {
                        if !rebuild_set.contains(&pid) && seen.insert(pid) {
                            patch.push(pid);
                        }
                    }
                }
            }
            patch.sort_unstable();
        }

        let t_replan = Instant::now();
        for &pid in &rebuild {
            let outputs = self.plans[pid as usize].output_nodes().to_vec();
            let sparse: Vec<_> = outputs
                .iter()
                .map(|o| self.states[self.root_of[o]].to_sparse())
                .collect();
            let per_output: Vec<(&[u32], &[f32])> = sparse
                .iter()
                .map(|sp| (&sp.nodes[..], &sp.scores[..]))
                .collect();
            let plan = assemble_plan(
                g_new,
                &outputs,
                &per_output,
                self.cfg.aux_per_output,
                self.cfg.node_budget,
            );
            self.unindex_members(pid);
            self.plans[pid as usize] = plan;
            self.index_members(pid);
            self.epochs[pid as usize] = self.epoch;
        }
        for &pid in &patch {
            let nodes = &self.plans[pid as usize].nodes;
            let sg = induced_subgraph(g_new, nodes);
            debug_assert_eq!(sg.nodes.len(), nodes.len());
            let plan = &mut self.plans[pid as usize];
            plan.edges = sg.edges;
            plan.weights = sg.weights;
            self.epochs[pid as usize] = self.epoch;
        }
        let replan_s = t_replan.elapsed().as_secs_f64();

        let mut changed_plans = rebuild.clone();
        changed_plans.extend_from_slice(&patch);
        changed_plans.sort_unstable();
        RefreshReport {
            epoch: self.epoch,
            touched_nodes: applied.touched.len(),
            roots_refreshed: affected.len(),
            plans_total: self.plans.len(),
            plans_rebuilt: rebuild.len(),
            plans_patched: patch.len(),
            changed_plans,
            max_root_l1,
            refresh_s,
            replan_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batching::{BatchGenerator, NodeWiseIbmb};
    use crate::datasets::{sbm, Dataset, DatasetSpec};
    use crate::graph::delta::{DynamicGraph, GraphDelta};

    fn setup() -> (Dataset, DynamicPlanSet) {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 61);
        let cfg = RefreshConfig {
            aux_per_output: 6,
            max_outputs_per_batch: 30,
            node_budget: 200,
            l1_tol: 0.02,
            ..Default::default()
        };
        let mut rng = Rng::new(5);
        let set = DynamicPlanSet::plan_initial(
            &ds.graph,
            &ds.splits.train,
            cfg,
            &mut rng,
        );
        (ds, set)
    }

    #[test]
    fn initial_plan_matches_node_wise_ibmb() {
        let (ds, set) = setup();
        let mut gen = NodeWiseIbmb {
            aux_per_output: 6,
            max_outputs_per_batch: 30,
            node_budget: 200,
            ..Default::default()
        };
        let mut rng = Rng::new(5);
        let want = gen.plan(&ds, &ds.splits.train, &mut rng);
        assert_eq!(set.len(), want.len());
        for (a, b) in set.plans().iter().zip(&want) {
            assert_eq!(a.nodes, b.nodes);
            assert_eq!(a.num_outputs, b.num_outputs);
            assert_eq!(a.edges, b.edges);
            assert_eq!(a.weights, b.weights);
        }
        assert!(set.epochs().iter().all(|&e| e == 0));
    }

    #[test]
    fn small_delta_rebuilds_few_plans() {
        let (ds, mut set) = setup();
        let mut dg = DynamicGraph::new(ds.graph.clone());
        // one edge between two train nodes
        let (a, b) = (ds.splits.train[0], ds.splits.train[1]);
        let applied = dg
            .apply(&GraphDelta {
                add_edges: vec![(a, b)],
                ..Default::default()
            })
            .unwrap();
        let report = set.apply_delta(&dg, &applied);
        assert_eq!(report.epoch, 1);
        assert!(report.stale_plans() > 0, "an output edge must go stale");
        assert!(
            report.rebuilt_fraction() < 1.0,
            "one edge cannot invalidate every plan: {report:?}"
        );
        assert!(report.roots_refreshed > 0);
        assert!(report.roots_refreshed < set.out_nodes.len());
        // changed plans carry the new epoch, unchanged keep the old
        for (pid, &e) in set.epochs().iter().enumerate() {
            let changed = report.changed_plans.contains(&(pid as u32));
            assert_eq!(e == 1, changed, "plan {pid}");
        }
        // every plan still validates against the new graph
        for p in set.plans() {
            assert!(p.validate().is_ok());
        }
    }

    #[test]
    fn patched_plans_pick_up_new_topology() {
        let (ds, mut set) = setup();
        let mut dg = DynamicGraph::new(ds.graph.clone());
        let (a, b) = (ds.splits.train[0], ds.splits.train[1]);
        let applied = dg
            .apply(&GraphDelta {
                add_edges: vec![(a, b)],
                ..Default::default()
            })
            .unwrap();
        set.apply_delta(&dg, &applied);
        // any plan containing both endpoints must now carry the edge
        for p in set.plans() {
            let la = p.nodes.iter().position(|&u| u == a);
            let lb = p.nodes.iter().position(|&u| u == b);
            if let (Some(la), Some(lb)) = (la, lb) {
                assert!(
                    p.edges.contains(&(la as u32, lb as u32)),
                    "stale topology survived the delta"
                );
            }
        }
    }

    #[test]
    fn feature_update_bumps_containing_plans_only() {
        let (ds, mut set) = setup();
        let mut dg = DynamicGraph::new(ds.graph.clone());
        let target = ds.splits.train[3];
        let applied = dg
            .apply(&GraphDelta {
                feature_updates: vec![target],
                ..Default::default()
            })
            .unwrap();
        let report = set.apply_delta(&dg, &applied);
        assert_eq!(report.plans_rebuilt, 0, "no topology change");
        assert!(report.plans_patched > 0);
        for &pid in &report.changed_plans {
            assert!(set.plans()[pid as usize].nodes.contains(&target));
        }
    }

    #[test]
    fn cow_patch_matches_full_rebuild_and_shares_untouched_buckets() {
        let (ds, mut set) = setup();
        let before = set.cow_cache();
        let mut dg = DynamicGraph::new(ds.graph.clone());
        let (a, b) = (ds.splits.train[0], ds.splits.train[2]);
        let applied = dg
            .apply(&GraphDelta {
                add_edges: vec![(a, b)],
                ..Default::default()
            })
            .unwrap();
        let report = set.apply_delta(&dg, &applied);
        assert!(!report.changed_plans.is_empty());
        let patched = set.patch_cow(&before, &report.changed_plans);
        let full = set.cow_cache();
        assert_eq!(patched.len(), full.len());
        for i in 0..full.len() {
            assert_eq!(patched.batch_nodes(i), full.batch_nodes(i), "{i}");
            assert_eq!(patched.edge_src_of(i), full.edge_src_of(i), "{i}");
            assert_eq!(patched.edge_dst_of(i), full.edge_dst_of(i), "{i}");
            assert_eq!(
                patched.edge_weights_of(i),
                full.edge_weights_of(i),
                "{i}"
            );
        }
        assert_eq!(
            patched.shared_with(&before).buckets,
            full.len() - report.changed_plans.len(),
            "every untouched bucket must be pointer-shared"
        );
    }

    #[test]
    fn cache_rebuild_reflects_current_plans() {
        let (ds, mut set) = setup();
        let mut dg = DynamicGraph::new(ds.graph.clone());
        let (a, b) = (ds.splits.train[0], ds.splits.train[4]);
        let applied = dg
            .apply(&GraphDelta {
                add_edges: vec![(a, b)],
                ..Default::default()
            })
            .unwrap();
        set.apply_delta(&dg, &applied);
        let cache = set.build_cache();
        assert_eq!(cache.len(), set.len());
        for (i, p) in set.plans().iter().enumerate() {
            let got = cache.to_plan(i);
            assert_eq!(got.nodes, p.nodes);
            assert_eq!(got.edges, p.edges);
        }
    }
}
