//! Arena of reusable [`DenseBatch`] buffers (DESIGN.md §7).
//!
//! A densified batch is O(n_pad²) memory (`adj` dominates); allocating
//! and zeroing one per batch was the hot-path cost the paper's
//! "consecutive memory accesses" argument says we must not pay. The
//! arena pools buffers **per bucket size** and hands them out dirty:
//! [`super::materialize`] zeroes exactly the region the previous
//! occupant touched, so a pooled buffer materializes bit-identically to
//! a fresh [`DenseBatch::zeros`] one (asserted by the arena-parity
//! test in `rust/tests/pipeline.rs`). Steady-state training and
//! inference therefore perform **zero** tensor allocations: the
//! [`allocations`](BatchArena::allocations) counter stops growing after
//! warmup.
//!
//! One arena is shared across an entire run — the trainer's epoch loop
//! and its per-epoch validation inference draw from the same pools, as
//! does a standalone inference driver serving request waves.

use super::batch::DenseBatch;

/// Pool of [`DenseBatch`] buffers keyed by bucket size (`n_pad`).
#[derive(Debug)]
pub struct BatchArena {
    feat: usize,
    /// `(n_pad, parked buffers)` — a handful of bucket sizes at most,
    /// so a linear scan beats hashing.
    pools: Vec<(usize, Vec<DenseBatch>)>,
    allocations: usize,
}

impl BatchArena {
    /// An empty arena for a dataset/artifact feature width.
    pub fn new(feat: usize) -> BatchArena {
        BatchArena {
            feat,
            pools: Vec::new(),
            allocations: 0,
        }
    }

    /// Feature width every pooled buffer shares.
    pub fn feat(&self) -> usize {
        self.feat
    }

    /// Fresh `DenseBatch::zeros` allocations performed so far. The
    /// steady-state invariant: this equals the high-water buffer count
    /// (pipeline depth × distinct buckets) and stops growing after the
    /// first epoch.
    pub fn allocations(&self) -> usize {
        self.allocations
    }

    /// Buffers currently parked in the arena.
    pub fn pooled(&self) -> usize {
        self.pools.iter().map(|(_, p)| p.len()).sum()
    }

    /// Bytes held by parked buffers (Table 6 memory accounting).
    pub fn memory_bytes(&self) -> usize {
        self.pools
            .iter()
            .flat_map(|(_, p)| p.iter())
            .map(|b| b.memory_bytes())
            .sum()
    }

    fn pool_mut(&mut self, n_pad: usize) -> &mut Vec<DenseBatch> {
        if let Some(i) = self.pools.iter().position(|(b, _)| *b == n_pad) {
            &mut self.pools[i].1
        } else {
            self.pools.push((n_pad, Vec::new()));
            &mut self.pools.last_mut().unwrap().1
        }
    }

    /// Hand out a buffer for bucket `n_pad`: pooled (dirty — reset
    /// incrementally by [`super::materialize`]) or freshly allocated.
    pub fn acquire(&mut self, n_pad: usize) -> DenseBatch {
        let pooled = self.pool_mut(n_pad).pop();
        match pooled {
            Some(buf) => {
                debug_assert_eq!(buf.feat, self.feat);
                debug_assert_eq!(buf.n_pad, n_pad);
                buf
            }
            None => {
                self.allocations += 1;
                DenseBatch::zeros(n_pad, self.feat)
            }
        }
    }

    /// Acquire a ring of `count` buffers for one pipeline run.
    pub fn acquire_many(&mut self, n_pad: usize, count: usize) -> Vec<DenseBatch> {
        (0..count).map(|_| self.acquire(n_pad)).collect()
    }

    /// Park a buffer back in its bucket pool.
    pub fn release(&mut self, buf: DenseBatch) {
        assert_eq!(
            buf.feat, self.feat,
            "arena feat mismatch: buffer {} vs arena {}",
            buf.feat, self.feat
        );
        let n_pad = buf.n_pad;
        self.pool_mut(n_pad).push(buf);
    }

    /// Park a whole ring back (the return value of
    /// [`crate::pipeline::run_prefetched`]).
    pub fn release_many(&mut self, bufs: impl IntoIterator<Item = DenseBatch>) {
        for b in bufs {
            self.release(b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn acquire_release_cycles_do_not_reallocate() {
        let mut arena = BatchArena::new(8);
        for _ in 0..10 {
            let b = arena.acquire(64);
            arena.release(b);
        }
        assert_eq!(arena.allocations(), 1);
        assert_eq!(arena.pooled(), 1);
    }

    #[test]
    fn pools_are_keyed_by_bucket_size() {
        let mut arena = BatchArena::new(8);
        let a = arena.acquire(64);
        let b = arena.acquire(128);
        assert_eq!(arena.allocations(), 2);
        arena.release_many([a, b]);
        // each size comes back from its own pool
        let a2 = arena.acquire(64);
        let b2 = arena.acquire(128);
        assert_eq!((a2.n_pad, b2.n_pad), (64, 128));
        assert_eq!(arena.allocations(), 2);
        // a third size allocates
        let c = arena.acquire(256);
        assert_eq!(arena.allocations(), 3);
        arena.release_many([a2, b2, c]);
        assert_eq!(arena.pooled(), 3);
        assert!(arena.memory_bytes() > 0);
    }

    #[test]
    fn ring_acquisition_counts_once() {
        let mut arena = BatchArena::new(4);
        for _epoch in 0..5 {
            let ring = arena.acquire_many(32, 3);
            assert_eq!(ring.len(), 3);
            arena.release_many(ring);
        }
        assert_eq!(arena.allocations(), 3);
    }

    #[test]
    #[should_panic(expected = "feat mismatch")]
    fn rejects_foreign_feature_width() {
        let mut arena = BatchArena::new(4);
        arena.release(DenseBatch::zeros(16, 8));
    }
}
