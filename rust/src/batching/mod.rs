//! Mini-batch generation: the paper's core contribution (L3).
//!
//! A mini-batch is (1) a set of *output* nodes whose predictions this
//! batch computes, (2) a set of *auxiliary* nodes providing
//! message-passing context, and (3) the induced subgraph over both.
//! Generators implement [`BatchGenerator`]; IBMB variants precompute a
//! fixed batch set once ([`BatchGenerator::is_fixed`]) which the
//! training loop stores in a contiguous [`cache::BatchCache`], while
//! stochastic baselines resample per epoch.

pub mod batch;
pub mod cache;
pub mod cache_io;
pub mod fixed_random;
pub mod ibmb_batch;
pub mod ibmb_node;

pub use batch::{densify, CachedBatch, DenseBatch};
pub use cache::BatchCache;
pub use ibmb_batch::BatchWiseIbmb;
pub use ibmb_node::NodeWiseIbmb;

use crate::datasets::Dataset;
use crate::util::Rng;

/// A mini-batch generation method (IBMB variant or baseline).
pub trait BatchGenerator {
    /// Display name used in experiment tables.
    fn name(&self) -> &'static str;

    /// Whether batches are fixed after preprocessing (cacheable) or
    /// resampled every epoch.
    fn is_fixed(&self) -> bool {
        true
    }

    /// Generate the batch set for `out_nodes`. For fixed methods this is
    /// the (expensive) preprocessing step, run once; for stochastic
    /// methods it is called per epoch.
    fn generate(
        &mut self,
        ds: &Dataset,
        out_nodes: &[u32],
        rng: &mut Rng,
    ) -> Vec<CachedBatch>;
}

/// Pick the smallest artifact bucket that fits `n` nodes.
pub fn bucket_for(n: usize, buckets: &[usize]) -> Option<usize> {
    buckets.iter().copied().find(|&b| b >= n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection() {
        let buckets = [256, 512, 1024, 2048];
        assert_eq!(bucket_for(10, &buckets), Some(256));
        assert_eq!(bucket_for(256, &buckets), Some(256));
        assert_eq!(bucket_for(257, &buckets), Some(512));
        assert_eq!(bucket_for(4096, &buckets), None);
    }
}
