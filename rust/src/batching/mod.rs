//! Mini-batch generation: the paper's core contribution (L3), as a
//! two-phase **plan / materialize** pipeline (DESIGN.md §4).
//!
//! A mini-batch is (1) a set of *output* nodes whose predictions this
//! batch computes, (2) a set of *auxiliary* nodes providing
//! message-passing context, and (3) the induced subgraph over both.
//! The stack splits that into:
//!
//! * **plan** — [`BatchGenerator::plan`] decides *which* nodes: every
//!   method (IBMB variants and all five baselines) emits compact
//!   [`BatchPlan`]s — node lists, local topology, bucket sizes — and
//!   never touches a tensor;
//! * **materialize** — the generator-independent [`materialize`]
//!   densifies one plan into a caller-owned [`DenseBatch`]. Buffers
//!   come from a [`BatchArena`] and are reset, not reallocated, so the
//!   steady-state epoch loop performs zero tensor allocations.
//!
//! IBMB variants precompute a fixed plan set once
//! ([`BatchGenerator::is_fixed`]) which the training loop packs into a
//! contiguous [`cache::BatchCache`] and streams through the ring
//! prefetcher; stochastic baselines re-plan per epoch but reuse the
//! same arena buffers.

pub mod arena;
pub mod batch;
pub mod cache;
pub mod cache_io;
pub mod fixed_random;
pub mod ibmb_batch;
pub mod ibmb_node;
pub mod refresh;

pub use arena::BatchArena;
pub use batch::{materialize, BatchPlan, DenseBatch};
pub use cache::{BatchCache, CowCache, PlanPayload, Sharing};
pub use fixed_random::FixedRandomBatches;
pub use ibmb_batch::BatchWiseIbmb;
pub use ibmb_node::NodeWiseIbmb;
pub use refresh::{DynamicPlanSet, RefreshConfig, RefreshReport};

use crate::datasets::Dataset;
use crate::util::Rng;

/// A mini-batch generation method (IBMB variant or baseline).
pub trait BatchGenerator {
    /// Display name used in experiment tables.
    fn name(&self) -> &'static str;

    /// Whether the plan set is fixed after preprocessing (cacheable) or
    /// resampled every epoch.
    fn is_fixed(&self) -> bool {
        true
    }

    /// Phase 1: plan the batch set for `out_nodes` — node lists and
    /// bucket sizes only, no dense tensors. For fixed methods this is
    /// the (expensive) preprocessing step, run once; for stochastic
    /// methods it is called per epoch. Phase 2 is the
    /// generator-independent [`materialize`].
    fn plan(
        &mut self,
        ds: &Dataset,
        out_nodes: &[u32],
        rng: &mut Rng,
    ) -> Vec<BatchPlan>;
}

/// Pick the smallest artifact bucket that fits `n` nodes.
pub fn bucket_for(n: usize, buckets: &[usize]) -> Option<usize> {
    buckets.iter().copied().find(|&b| b >= n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection() {
        let buckets = [256, 512, 1024, 2048];
        assert_eq!(bucket_for(10, &buckets), Some(256));
        assert_eq!(bucket_for(256, &buckets), Some(256));
        assert_eq!(bucket_for(257, &buckets), Some(512));
        assert_eq!(bucket_for(4096, &buckets), None);
    }
}
