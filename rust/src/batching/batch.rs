//! Batch plans and their materialization.
//!
//! [`BatchPlan`] is the compact *planning* product (node ids + induced
//! local topology + bucket choice); [`DenseBatch`] is the padded buffer
//! set matching the AOT artifact's batch interchange format
//! (DESIGN.md §6). The two phases are deliberately decoupled
//! (DESIGN.md §4): planning decides **which** nodes, materialization —
//! feature generation, adjacency fill, padding — produces tensors into
//! a caller-owned buffer on the prefetch thread, so the execute thread
//! only ever hands ready buffers to PJRT and buffers can be pooled in a
//! [`super::BatchArena`] instead of reallocated per batch.

use crate::datasets::Dataset;

/// A planned mini-batch in compact form.
///
/// `nodes` holds global ids with the **output nodes first**
/// (`nodes[..num_outputs]`); `edges`/`weights` are the induced subgraph
/// in local ids with global symmetric-normalization weights. No dense
/// tensors live here — [`materialize`] produces those on demand.
#[derive(Debug, Clone)]
pub struct BatchPlan {
    pub nodes: Vec<u32>,
    pub num_outputs: usize,
    pub edges: Vec<(u32, u32)>,
    pub weights: Vec<f32>,
}

impl BatchPlan {
    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }
    pub fn output_nodes(&self) -> &[u32] {
        &self.nodes[..self.num_outputs]
    }
    pub fn memory_bytes(&self) -> usize {
        self.nodes.len() * 4 + self.edges.len() * 8 + self.weights.len() * 4
    }

    /// Smallest artifact bucket this plan fits into — the plan-side half
    /// of bucket selection (`buckets` ascending, from the manifest).
    pub fn bucket(&self, buckets: &[usize]) -> Option<usize> {
        super::bucket_for(self.num_nodes(), buckets)
    }

    /// Structural sanity (tests + debug assertions in the loader).
    pub fn validate(&self) -> Result<(), String> {
        let n = self.nodes.len() as u32;
        if self.num_outputs > self.nodes.len() {
            return Err("num_outputs exceeds nodes".into());
        }
        if self.edges.len() != self.weights.len() {
            return Err("edges/weights length mismatch".into());
        }
        for &(s, d) in &self.edges {
            if s >= n || d >= n {
                return Err(format!("edge ({s},{d}) out of range {n}"));
            }
        }
        Ok(())
    }

    /// Whether node ids are unique (true for IBMB/Cluster-GCN/sampling
    /// batches; false by design for shaDow's stacked subgraphs).
    pub fn has_unique_nodes(&self) -> bool {
        let mut seen = std::collections::HashSet::new();
        self.nodes.iter().all(|&u| seen.insert(u))
    }
}

/// Padded buffers in the artifact's layout: `x [n_pad * feat]`,
/// `adj [n_pad * n_pad]` (row-major, `adj[d * n_pad + s]` so that
/// `adj @ h` aggregates *into* destination rows), `labels`, `mask`.
#[derive(Debug, Clone)]
pub struct DenseBatch {
    pub n_pad: usize,
    pub feat: usize,
    pub x: Vec<f32>,
    pub adj: Vec<f32>,
    pub labels: Vec<i32>,
    pub mask: Vec<f32>,
    pub num_real: usize,
    pub num_outputs: usize,
}

impl DenseBatch {
    /// Allocate zeroed buffers for a bucket. Hot paths should acquire
    /// from a [`super::BatchArena`] instead of calling this per batch.
    pub fn zeros(n_pad: usize, feat: usize) -> DenseBatch {
        DenseBatch {
            n_pad,
            feat,
            x: vec![0.0; n_pad * feat],
            adj: vec![0.0; n_pad * n_pad],
            labels: vec![0; n_pad],
            mask: vec![0.0; n_pad],
            num_real: 0,
            num_outputs: 0,
        }
    }

    pub fn memory_bytes(&self) -> usize {
        self.x.len() * 4 + self.adj.len() * 4 + self.labels.len() * 4 + self.mask.len() * 4
    }
}

/// Materialize a plan into `dense`: streamed features, zero-padded
/// normalized adjacency, labels, output mask. Generator-independent —
/// every batching method's plans densify through this one function.
/// Buffers are fully overwritten (zeroing only the region the previous
/// occupant touched), which is what makes arena reuse exact: a dirty
/// pooled buffer materializes bit-identically to a fresh
/// [`DenseBatch::zeros`] one.
pub fn materialize(ds: &Dataset, plan: &BatchPlan, dense: &mut DenseBatch) {
    let n = plan.num_nodes();
    assert!(
        n <= dense.n_pad,
        "batch of {n} nodes exceeds bucket {}",
        dense.n_pad
    );
    assert_eq!(ds.feat_dim, dense.feat);
    let n_pad = dense.n_pad;

    // Zero the region the *previous* occupant used (cheaper than a full
    // clear when batches are much smaller than the bucket).
    let prev = dense.num_real.max(n);
    dense.adj[..prev * n_pad].iter_mut().for_each(|v| *v = 0.0);
    dense.x[..prev * dense.feat].iter_mut().for_each(|v| *v = 0.0);
    dense.mask[..prev].iter_mut().for_each(|v| *v = 0.0);
    dense.labels[..prev].iter_mut().for_each(|v| *v = 0);

    for (i, &u) in plan.nodes.iter().enumerate() {
        ds.node_features_into(u, &mut dense.x[i * dense.feat..(i + 1) * dense.feat]);
        dense.labels[i] = ds.labels[u as usize] as i32;
    }
    for i in 0..plan.num_outputs {
        dense.mask[i] = 1.0;
    }
    // adj[dst][src] = w  =>  (adj @ h)[dst] = sum_src w * h[src]
    for (&(s, d), &w) in plan.edges.iter().zip(&plan.weights) {
        dense.adj[d as usize * n_pad + s as usize] = w;
    }
    dense.num_real = n;
    dense.num_outputs = plan.num_outputs;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{sbm, DatasetSpec};
    use crate::graph::induced_subgraph;

    fn tiny_ds() -> Dataset {
        sbm::generate(&DatasetSpec::tiny_for_tests(), 40)
    }

    fn plan_from(ds: &Dataset, nodes: &[u32], n_out: usize) -> BatchPlan {
        let sg = induced_subgraph(&ds.graph, nodes);
        BatchPlan {
            nodes: sg.nodes,
            num_outputs: n_out,
            edges: sg.edges,
            weights: sg.weights,
        }
    }

    #[test]
    fn materialize_layout_is_correct() {
        let ds = tiny_ds();
        let p = plan_from(&ds, &[5, 6, 7, 100], 2);
        let mut d = DenseBatch::zeros(16, ds.feat_dim);
        materialize(&ds, &p, &mut d);
        assert_eq!(d.num_real, 4);
        assert_eq!(d.num_outputs, 2);
        assert_eq!(&d.mask[..4], &[1.0, 1.0, 0.0, 0.0]);
        assert_eq!(d.labels[0], ds.labels[5] as i32);
        // self loop weight at (0,0)
        let w00 = d.adj[0];
        assert!((w00 - ds.graph.norm_weight(5, 5)).abs() < 1e-7);
        // features match the streamed generator
        let mut want = vec![0.0; ds.feat_dim];
        ds.node_features_into(6, &mut want);
        assert_eq!(&d.x[ds.feat_dim..2 * ds.feat_dim], &want[..]);
    }

    #[test]
    fn materialize_clears_previous_occupant() {
        let ds = tiny_ds();
        let big = plan_from(&ds, &(0u32..12).collect::<Vec<_>>(), 12);
        let small = plan_from(&ds, &[300, 301], 1);
        let mut d = DenseBatch::zeros(16, ds.feat_dim);
        materialize(&ds, &big, &mut d);
        materialize(&ds, &small, &mut d);
        // everything beyond the small batch must be zero again
        assert!(d.mask[2..].iter().all(|&m| m == 0.0));
        assert!(d.labels[2..].iter().all(|&l| l == 0));
        for r in 2..16 {
            assert!(
                d.adj[r * 16..(r + 1) * 16].iter().all(|&v| v == 0.0),
                "row {r} dirty"
            );
        }
        // columns of padding region in live rows must be zero too
        for r in 0..2 {
            assert!(d.adj[r * 16 + 2..(r + 1) * 16].iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn validate_catches_bad_plans() {
        let ds = tiny_ds();
        let mut p = plan_from(&ds, &[1, 2, 3], 1);
        assert!(p.validate().is_ok());
        p.edges.push((9, 0));
        p.weights.push(0.1);
        assert!(p.validate().is_err());
        let dup = BatchPlan {
            nodes: vec![1, 1],
            num_outputs: 1,
            edges: vec![],
            weights: vec![],
        };
        assert!(dup.validate().is_ok()); // duplicates are legal (shaDow)
        assert!(!dup.has_unique_nodes());
    }

    #[test]
    fn plan_bucket_selection() {
        let ds = tiny_ds();
        let p = plan_from(&ds, &[1, 2, 3], 1);
        assert_eq!(p.bucket(&[2, 4, 8]), Some(4));
        assert_eq!(p.bucket(&[2]), None);
    }

    #[test]
    #[should_panic(expected = "exceeds bucket")]
    fn materialize_rejects_oversized_plan() {
        let ds = tiny_ds();
        let p = plan_from(&ds, &(0u32..20).collect::<Vec<_>>(), 4);
        let mut d = DenseBatch::zeros(16, ds.feat_dim);
        materialize(&ds, &p, &mut d);
    }
}
