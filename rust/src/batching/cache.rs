//! Contiguous plan cache — the computational heart of the paper's
//! training speedup (§4 "Computational advantages"): "we can then cache
//! each mini-batch in consecutive blocks of memory, thereby ...
//! circumventing expensive random data accesses."
//!
//! All plans live in four flat arenas (nodes, edge sources, edge
//! destinations, weights) with per-batch offsets, so iterating an epoch
//! is a single forward scan over memory. [`BatchCache::materialize_into`]
//! reads straight from the arenas into a padded [`DenseBatch`] without
//! materializing intermediate structures — it is the fixed-method fast
//! path of the plan/materialize split (DESIGN.md §4): fixed generators
//! plan once, the cache streams those plans through the ring prefetcher
//! every epoch.

use super::batch::{BatchPlan, DenseBatch};
use crate::datasets::Dataset;

/// Immutable arena-packed plan set.
#[derive(Debug, Clone)]
pub struct BatchCache {
    nodes: Vec<u32>,
    edge_src: Vec<u32>,
    edge_dst: Vec<u32>,
    weights: Vec<f32>,
    /// `node_off[i]..node_off[i+1]` is batch i's node range.
    node_off: Vec<usize>,
    /// `edge_off[i]..edge_off[i+1]` is batch i's edge range.
    edge_off: Vec<usize>,
    num_outputs: Vec<usize>,
}

impl BatchCache {
    /// Pack planned batches into contiguous arenas.
    pub fn build(plans: &[BatchPlan]) -> BatchCache {
        let total_nodes: usize = plans.iter().map(|b| b.num_nodes()).sum();
        let total_edges: usize = plans.iter().map(|b| b.num_edges()).sum();
        let mut c = BatchCache {
            nodes: Vec::with_capacity(total_nodes),
            edge_src: Vec::with_capacity(total_edges),
            edge_dst: Vec::with_capacity(total_edges),
            weights: Vec::with_capacity(total_edges),
            node_off: Vec::with_capacity(plans.len() + 1),
            edge_off: Vec::with_capacity(plans.len() + 1),
            num_outputs: Vec::with_capacity(plans.len()),
        };
        c.node_off.push(0);
        c.edge_off.push(0);
        for b in plans {
            debug_assert!(b.validate().is_ok());
            c.nodes.extend_from_slice(&b.nodes);
            for (&(s, d), &w) in b.edges.iter().zip(&b.weights) {
                c.edge_src.push(s);
                c.edge_dst.push(d);
                c.weights.push(w);
            }
            c.node_off.push(c.nodes.len());
            c.edge_off.push(c.edge_src.len());
            c.num_outputs.push(b.num_outputs);
        }
        c
    }

    pub fn len(&self) -> usize {
        self.num_outputs.len()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn num_nodes(&self, i: usize) -> usize {
        self.node_off[i + 1] - self.node_off[i]
    }
    pub fn num_edges(&self, i: usize) -> usize {
        self.edge_off[i + 1] - self.edge_off[i]
    }
    pub fn num_outputs(&self, i: usize) -> usize {
        self.num_outputs[i]
    }
    pub fn batch_nodes(&self, i: usize) -> &[u32] {
        &self.nodes[self.node_off[i]..self.node_off[i + 1]]
    }
    pub fn output_nodes(&self, i: usize) -> &[u32] {
        &self.nodes[self.node_off[i]..self.node_off[i] + self.num_outputs[i]]
    }

    /// Plan `i`'s edge sources (local ids) as an arena slice — the
    /// zero-copy plan-index view the serving executor and `cache_io`
    /// read instead of cloning whole plans via [`Self::to_plan`].
    pub fn edge_src_of(&self, i: usize) -> &[u32] {
        &self.edge_src[self.edge_off[i]..self.edge_off[i + 1]]
    }
    /// Plan `i`'s edge destinations (local ids), parallel to
    /// [`Self::edge_src_of`].
    pub fn edge_dst_of(&self, i: usize) -> &[u32] {
        &self.edge_dst[self.edge_off[i]..self.edge_off[i + 1]]
    }
    /// Plan `i`'s edge weights, parallel to [`Self::edge_src_of`].
    pub fn edge_weights_of(&self, i: usize) -> &[f32] {
        &self.weights[self.edge_off[i]..self.edge_off[i + 1]]
    }

    /// Largest batch node count — picks the artifact bucket.
    pub fn max_batch_nodes(&self) -> usize {
        (0..self.len()).map(|i| self.num_nodes(i)).max().unwrap_or(0)
    }

    /// Total arena bytes (Table 6 main-memory accounting).
    pub fn memory_bytes(&self) -> usize {
        self.nodes.len() * 4
            + self.edge_src.len() * 4
            + self.edge_dst.len() * 4
            + self.weights.len() * 4
            + (self.node_off.len() + self.edge_off.len() + self.num_outputs.len()) * 8
    }

    /// Materialize batch `i` straight out of the arenas (no
    /// intermediate allocation — prefetch-thread hot path). Equivalent
    /// to `materialize(ds, &self.to_plan(i), dense)` without building
    /// the owned plan.
    pub fn materialize_into(&self, ds: &Dataset, i: usize, dense: &mut DenseBatch) {
        let nodes = self.batch_nodes(i);
        let n = nodes.len();
        assert!(n <= dense.n_pad, "batch {i}: {n} > bucket {}", dense.n_pad);
        let n_pad = dense.n_pad;
        let prev = dense.num_real.max(n);
        dense.adj[..prev * n_pad].iter_mut().for_each(|v| *v = 0.0);
        dense.x[..prev * dense.feat].iter_mut().for_each(|v| *v = 0.0);
        dense.mask[..prev].iter_mut().for_each(|v| *v = 0.0);
        dense.labels[..prev].iter_mut().for_each(|v| *v = 0);

        for (li, &u) in nodes.iter().enumerate() {
            ds.node_features_into(
                u,
                &mut dense.x[li * dense.feat..(li + 1) * dense.feat],
            );
            dense.labels[li] = ds.labels[u as usize] as i32;
        }
        for m in dense.mask.iter_mut().take(self.num_outputs[i]) {
            *m = 1.0;
        }
        let (es, ee) = (self.edge_off[i], self.edge_off[i + 1]);
        for e in es..ee {
            let (s, d) = (self.edge_src[e] as usize, self.edge_dst[e] as usize);
            dense.adj[d * n_pad + s] = self.weights[e];
        }
        dense.num_real = n;
        dense.num_outputs = self.num_outputs[i];
    }

    /// Owned copy of plan `i` (tests / non-hot-path consumers).
    pub fn to_plan(&self, i: usize) -> BatchPlan {
        let (es, ee) = (self.edge_off[i], self.edge_off[i + 1]);
        BatchPlan {
            nodes: self.batch_nodes(i).to_vec(),
            num_outputs: self.num_outputs[i],
            edges: (es..ee)
                .map(|e| (self.edge_src[e], self.edge_dst[e]))
                .collect(),
            weights: self.weights[es..ee].to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batching::batch::materialize;
    use crate::batching::{BatchGenerator, NodeWiseIbmb};
    use crate::datasets::{sbm, DatasetSpec};
    use crate::util::Rng;

    fn build() -> (Dataset, Vec<BatchPlan>, BatchCache) {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 80);
        let mut g = NodeWiseIbmb {
            aux_per_output: 6,
            max_outputs_per_batch: 30,
            node_budget: 200,
            ..Default::default()
        };
        let out = ds.splits.train.clone();
        let mut rng = Rng::new(5);
        let plans = g.plan(&ds, &out, &mut rng);
        let cache = BatchCache::build(&plans);
        (ds, plans, cache)
    }

    #[test]
    fn roundtrips_plans_exactly() {
        let (_, plans, cache) = build();
        assert_eq!(cache.len(), plans.len());
        for (i, b) in plans.iter().enumerate() {
            let got = cache.to_plan(i);
            assert_eq!(got.nodes, b.nodes);
            assert_eq!(got.num_outputs, b.num_outputs);
            assert_eq!(got.edges, b.edges);
            assert_eq!(got.weights, b.weights);
        }
    }

    #[test]
    fn materialize_into_matches_direct_materialize() {
        let (ds, plans, cache) = build();
        let bucket = cache.max_batch_nodes().next_power_of_two().max(16);
        let mut a = DenseBatch::zeros(bucket, ds.feat_dim);
        let mut b = DenseBatch::zeros(bucket, ds.feat_dim);
        for i in 0..cache.len() {
            cache.materialize_into(&ds, i, &mut a);
            materialize(&ds, &plans[i], &mut b);
            assert_eq!(a.x, b.x, "batch {i} x");
            assert_eq!(a.adj, b.adj, "batch {i} adj");
            assert_eq!(a.labels, b.labels);
            assert_eq!(a.mask, b.mask);
            assert_eq!(a.num_real, b.num_real);
        }
    }

    #[test]
    fn edge_slice_views_match_owned_plans() {
        let (_, _, cache) = build();
        for i in 0..cache.len() {
            let plan = cache.to_plan(i);
            let src = cache.edge_src_of(i);
            let dst = cache.edge_dst_of(i);
            let w = cache.edge_weights_of(i);
            assert_eq!(src.len(), plan.edges.len());
            assert_eq!(dst.len(), plan.edges.len());
            assert_eq!(w, &plan.weights[..]);
            for (e, &(s, d)) in plan.edges.iter().enumerate() {
                assert_eq!((src[e], dst[e]), (s, d), "batch {i} edge {e}");
            }
        }
    }

    #[test]
    fn memory_accounting_is_consistent() {
        let (_, plans, cache) = build();
        let loose: usize = plans.iter().map(|b| b.memory_bytes()).sum();
        // arena holds same payload (+ offsets overhead)
        assert!(cache.memory_bytes() >= loose);
        assert!(cache.memory_bytes() < loose + 64 * (plans.len() + 2));
    }
}
