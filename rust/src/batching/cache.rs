//! Contiguous plan cache — the computational heart of the paper's
//! training speedup (§4 "Computational advantages"): "we can then cache
//! each mini-batch in consecutive blocks of memory, thereby ...
//! circumventing expensive random data accesses."
//!
//! All plans live in four flat arenas (nodes, edge sources, edge
//! destinations, weights) with per-batch offsets, so iterating an epoch
//! is a single forward scan over memory. [`BatchCache::materialize_into`]
//! reads straight from the arenas into a padded [`DenseBatch`] without
//! materializing intermediate structures — it is the fixed-method fast
//! path of the plan/materialize split (DESIGN.md §4): fixed generators
//! plan once, the cache streams those plans through the ring prefetcher
//! every epoch.

use std::sync::Arc;

use super::batch::{BatchPlan, DenseBatch};
use crate::datasets::Dataset;

/// Immutable arena-packed plan set.
#[derive(Debug, Clone)]
pub struct BatchCache {
    nodes: Vec<u32>,
    edge_src: Vec<u32>,
    edge_dst: Vec<u32>,
    weights: Vec<f32>,
    /// `node_off[i]..node_off[i+1]` is batch i's node range.
    node_off: Vec<usize>,
    /// `edge_off[i]..edge_off[i+1]` is batch i's edge range.
    edge_off: Vec<usize>,
    num_outputs: Vec<usize>,
}

impl BatchCache {
    /// Pack planned batches into contiguous arenas.
    pub fn build(plans: &[BatchPlan]) -> BatchCache {
        let total_nodes: usize = plans.iter().map(|b| b.num_nodes()).sum();
        let total_edges: usize = plans.iter().map(|b| b.num_edges()).sum();
        let mut c = BatchCache {
            nodes: Vec::with_capacity(total_nodes),
            edge_src: Vec::with_capacity(total_edges),
            edge_dst: Vec::with_capacity(total_edges),
            weights: Vec::with_capacity(total_edges),
            node_off: Vec::with_capacity(plans.len() + 1),
            edge_off: Vec::with_capacity(plans.len() + 1),
            num_outputs: Vec::with_capacity(plans.len()),
        };
        c.node_off.push(0);
        c.edge_off.push(0);
        for b in plans {
            debug_assert!(b.validate().is_ok());
            c.nodes.extend_from_slice(&b.nodes);
            for (&(s, d), &w) in b.edges.iter().zip(&b.weights) {
                c.edge_src.push(s);
                c.edge_dst.push(d);
                c.weights.push(w);
            }
            c.node_off.push(c.nodes.len());
            c.edge_off.push(c.edge_src.len());
            c.num_outputs.push(b.num_outputs);
        }
        c
    }

    pub fn len(&self) -> usize {
        self.num_outputs.len()
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn num_nodes(&self, i: usize) -> usize {
        self.node_off[i + 1] - self.node_off[i]
    }
    pub fn num_edges(&self, i: usize) -> usize {
        self.edge_off[i + 1] - self.edge_off[i]
    }
    pub fn num_outputs(&self, i: usize) -> usize {
        self.num_outputs[i]
    }
    pub fn batch_nodes(&self, i: usize) -> &[u32] {
        &self.nodes[self.node_off[i]..self.node_off[i + 1]]
    }
    pub fn output_nodes(&self, i: usize) -> &[u32] {
        &self.nodes[self.node_off[i]..self.node_off[i] + self.num_outputs[i]]
    }

    /// Plan `i`'s edge sources (local ids) as an arena slice — the
    /// zero-copy plan-index view the serving executor and `cache_io`
    /// read instead of cloning whole plans via [`Self::to_plan`].
    pub fn edge_src_of(&self, i: usize) -> &[u32] {
        &self.edge_src[self.edge_off[i]..self.edge_off[i + 1]]
    }
    /// Plan `i`'s edge destinations (local ids), parallel to
    /// [`Self::edge_src_of`].
    pub fn edge_dst_of(&self, i: usize) -> &[u32] {
        &self.edge_dst[self.edge_off[i]..self.edge_off[i + 1]]
    }
    /// Plan `i`'s edge weights, parallel to [`Self::edge_src_of`].
    pub fn edge_weights_of(&self, i: usize) -> &[f32] {
        &self.weights[self.edge_off[i]..self.edge_off[i + 1]]
    }

    /// Gather batch `i`'s dense features into `x` (resized to
    /// `n · feat_dim`, batch-local row order), returning `n`. The
    /// sparse-path fill: no adjacency densification, no padding —
    /// shared by the native trainer's ring worker and
    /// [`crate::inference::infer_with_executor`]. `x` ratchets to the
    /// high-water batch size and is then reused allocation-free.
    pub fn gather_features_into(
        &self,
        ds: &Dataset,
        i: usize,
        x: &mut Vec<f32>,
    ) -> usize {
        let nodes = self.batch_nodes(i);
        let n = nodes.len();
        let f = ds.feat_dim;
        x.resize(n * f, 0.0);
        for (j, &u) in nodes.iter().enumerate() {
            ds.node_features_into(u, &mut x[j * f..(j + 1) * f]);
        }
        n
    }

    /// Gather batch `i`'s labels (batch-local order, `i32` like the
    /// artifact interchange format) into `labels`.
    pub fn gather_labels_into(
        &self,
        ds: &Dataset,
        i: usize,
        labels: &mut Vec<i32>,
    ) {
        let nodes = self.batch_nodes(i);
        labels.clear();
        labels.extend(nodes.iter().map(|&u| i32::from(ds.labels[u as usize])));
    }

    /// Largest batch node count — picks the artifact bucket.
    pub fn max_batch_nodes(&self) -> usize {
        (0..self.len()).map(|i| self.num_nodes(i)).max().unwrap_or(0)
    }

    /// Total arena bytes (Table 6 main-memory accounting).
    pub fn memory_bytes(&self) -> usize {
        self.nodes.len() * 4
            + self.edge_src.len() * 4
            + self.edge_dst.len() * 4
            + self.weights.len() * 4
            + (self.node_off.len() + self.edge_off.len() + self.num_outputs.len()) * 8
    }

    /// Materialize batch `i` straight out of the arenas (no
    /// intermediate allocation — prefetch-thread hot path). Equivalent
    /// to `materialize(ds, &self.to_plan(i), dense)` without building
    /// the owned plan.
    pub fn materialize_into(&self, ds: &Dataset, i: usize, dense: &mut DenseBatch) {
        let nodes = self.batch_nodes(i);
        let n = nodes.len();
        assert!(n <= dense.n_pad, "batch {i}: {n} > bucket {}", dense.n_pad);
        let n_pad = dense.n_pad;
        let prev = dense.num_real.max(n);
        dense.adj[..prev * n_pad].iter_mut().for_each(|v| *v = 0.0);
        dense.x[..prev * dense.feat].iter_mut().for_each(|v| *v = 0.0);
        dense.mask[..prev].iter_mut().for_each(|v| *v = 0.0);
        dense.labels[..prev].iter_mut().for_each(|v| *v = 0);

        for (li, &u) in nodes.iter().enumerate() {
            ds.node_features_into(
                u,
                &mut dense.x[li * dense.feat..(li + 1) * dense.feat],
            );
            dense.labels[li] = ds.labels[u as usize] as i32;
        }
        for m in dense.mask.iter_mut().take(self.num_outputs[i]) {
            *m = 1.0;
        }
        let (es, ee) = (self.edge_off[i], self.edge_off[i + 1]);
        for e in es..ee {
            let (s, d) = (self.edge_src[e] as usize, self.edge_dst[e] as usize);
            dense.adj[d * n_pad + s] = self.weights[e];
        }
        dense.num_real = n;
        dense.num_outputs = self.num_outputs[i];
    }

    /// Owned copy of plan `i` (tests / non-hot-path consumers).
    pub fn to_plan(&self, i: usize) -> BatchPlan {
        let (es, ee) = (self.edge_off[i], self.edge_off[i + 1]);
        BatchPlan {
            nodes: self.batch_nodes(i).to_vec(),
            num_outputs: self.num_outputs[i],
            edges: (es..ee)
                .map(|e| (self.edge_src[e], self.edge_dst[e]))
                .collect(),
            weights: self.weights[es..ee].to_vec(),
        }
    }
}

/// One plan's packed payload: the per-bucket unit of structural
/// sharing in a [`CowCache`]. Edge endpoints are pre-split into
/// parallel arrays (the executor builds a
/// [`crate::inference::fullgraph::SparseGraphRef`] from slices with no
/// per-query work), mirroring the [`BatchCache`] arena views.
#[derive(Debug, Clone, PartialEq)]
pub struct PlanPayload {
    /// Plan node list (global ids, outputs first).
    pub nodes: Vec<u32>,
    pub num_outputs: usize,
    /// Edge sources (local ids), parallel to `edge_dst` / `weights`.
    pub edge_src: Vec<u32>,
    pub edge_dst: Vec<u32>,
    pub weights: Vec<f32>,
}

impl PlanPayload {
    pub fn from_plan(b: &BatchPlan) -> PlanPayload {
        debug_assert!(b.validate().is_ok());
        let (edge_src, edge_dst): (Vec<u32>, Vec<u32>) =
            b.edges.iter().copied().unzip();
        PlanPayload {
            nodes: b.nodes.clone(),
            num_outputs: b.num_outputs,
            edge_src,
            edge_dst,
            weights: b.weights.clone(),
        }
    }

    pub fn to_plan(&self) -> BatchPlan {
        BatchPlan {
            nodes: self.nodes.clone(),
            num_outputs: self.num_outputs,
            edges: self
                .edge_src
                .iter()
                .zip(&self.edge_dst)
                .map(|(&s, &d)| (s, d))
                .collect(),
            weights: self.weights.clone(),
        }
    }

    pub fn memory_bytes(&self) -> usize {
        self.nodes.len() * 4
            + self.edge_src.len() * 4
            + self.edge_dst.len() * 4
            + self.weights.len() * 4
    }
}

/// Copy-on-write plan store: per-plan `Arc<PlanPayload>` buckets, so
/// cloning the whole store is `len()` pointer bumps and a patch copies
/// *only the touched buckets* — the plan-cache half of the serving
/// snapshot contract (DESIGN.md §11). Accessors mirror [`BatchCache`];
/// the flat arena cache remains the training/epoch-scan layout, the
/// cow store is the layout serving snapshots share across epochs.
#[derive(Debug, Clone, Default)]
pub struct CowCache {
    plans: Vec<Arc<PlanPayload>>,
}

impl CowCache {
    pub fn from_plans(plans: &[BatchPlan]) -> CowCache {
        CowCache {
            plans: plans
                .iter()
                .map(|b| Arc::new(PlanPayload::from_plan(b)))
                .collect(),
        }
    }

    /// Re-bucket a flat arena cache (e.g. one reloaded from disk).
    pub fn from_cache(cache: &BatchCache) -> CowCache {
        CowCache {
            plans: (0..cache.len())
                .map(|i| {
                    Arc::new(PlanPayload {
                        nodes: cache.batch_nodes(i).to_vec(),
                        num_outputs: cache.num_outputs(i),
                        edge_src: cache.edge_src_of(i).to_vec(),
                        edge_dst: cache.edge_dst_of(i).to_vec(),
                        weights: cache.edge_weights_of(i).to_vec(),
                    })
                })
                .collect(),
        }
    }

    /// Flatten into a contiguous [`BatchCache`] (persistence path).
    pub fn to_batch_cache(&self) -> BatchCache {
        let plans: Vec<BatchPlan> =
            self.plans.iter().map(|p| p.to_plan()).collect();
        BatchCache::build(&plans)
    }

    /// Structural-sharing patch: the new store aliases every untouched
    /// bucket (pointer copy) and owns fresh payloads only for the
    /// `replacements`. Plan ids out of range are ignored (the plan set
    /// is size-stable across deltas — outputs never migrate).
    pub fn with_patched(
        &self,
        replacements: impl IntoIterator<Item = (u32, PlanPayload)>,
    ) -> CowCache {
        let mut plans = self.plans.clone();
        for (pid, payload) in replacements {
            if let Some(slot) = plans.get_mut(pid as usize) {
                *slot = Arc::new(payload);
            }
        }
        CowCache { plans }
    }

    pub fn len(&self) -> usize {
        self.plans.len()
    }
    pub fn is_empty(&self) -> bool {
        self.plans.is_empty()
    }

    pub fn num_nodes(&self, i: usize) -> usize {
        self.plans[i].nodes.len()
    }
    pub fn num_edges(&self, i: usize) -> usize {
        self.plans[i].edge_src.len()
    }
    pub fn num_outputs(&self, i: usize) -> usize {
        self.plans[i].num_outputs
    }
    pub fn batch_nodes(&self, i: usize) -> &[u32] {
        &self.plans[i].nodes
    }
    pub fn output_nodes(&self, i: usize) -> &[u32] {
        &self.plans[i].nodes[..self.plans[i].num_outputs]
    }
    pub fn edge_src_of(&self, i: usize) -> &[u32] {
        &self.plans[i].edge_src
    }
    pub fn edge_dst_of(&self, i: usize) -> &[u32] {
        &self.plans[i].edge_dst
    }
    pub fn edge_weights_of(&self, i: usize) -> &[f32] {
        &self.plans[i].weights
    }

    pub fn to_plan(&self, i: usize) -> BatchPlan {
        self.plans[i].to_plan()
    }

    /// Plan `i`'s shared payload bucket — the unit the content-addressed
    /// store hashes and persists. Cloning is a pointer bump.
    pub fn payload(&self, i: usize) -> Arc<PlanPayload> {
        self.plans[i].clone()
    }

    /// Largest plan node count — picks the artifact bucket.
    pub fn max_batch_nodes(&self) -> usize {
        self.plans.iter().map(|p| p.nodes.len()).max().unwrap_or(0)
    }

    /// Payload bytes (shared buckets counted once per store).
    pub fn memory_bytes(&self) -> usize {
        self.plans.iter().map(|p| p.memory_bytes()).sum::<usize>()
            + self.plans.len() * std::mem::size_of::<Arc<PlanPayload>>()
    }

    /// What this store shares (same allocation) with `other` — the
    /// structural-sharing meter the snapshot tests assert on. Reports
    /// both bucket counts and payload bytes so the dedup ratio lines
    /// up unit-for-unit with `gc_retained_bytes_peak` and the plan
    /// store's byte accounting (`ibmb store-stat`).
    pub fn shared_with(&self, other: &CowCache) -> Sharing {
        let mut s = Sharing::default();
        for (a, b) in self.plans.iter().zip(&other.plans) {
            if Arc::ptr_eq(a, b) {
                s.buckets += 1;
                s.bytes += a.memory_bytes();
            }
        }
        s
    }
}

/// Structural-sharing accounting between two [`CowCache`]s: how many
/// buckets alias the same allocation, and how many payload bytes those
/// buckets carry (same unit as [`PlanPayload::memory_bytes`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Sharing {
    /// Pointer-identical buckets.
    pub buckets: usize,
    /// Payload bytes in those buckets.
    pub bytes: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batching::batch::materialize;
    use crate::batching::{BatchGenerator, NodeWiseIbmb};
    use crate::datasets::{sbm, DatasetSpec};
    use crate::util::Rng;

    fn build() -> (Dataset, Vec<BatchPlan>, BatchCache) {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 80);
        let mut g = NodeWiseIbmb {
            aux_per_output: 6,
            max_outputs_per_batch: 30,
            node_budget: 200,
            ..Default::default()
        };
        let out = ds.splits.train.clone();
        let mut rng = Rng::new(5);
        let plans = g.plan(&ds, &out, &mut rng);
        let cache = BatchCache::build(&plans);
        (ds, plans, cache)
    }

    #[test]
    fn roundtrips_plans_exactly() {
        let (_, plans, cache) = build();
        assert_eq!(cache.len(), plans.len());
        for (i, b) in plans.iter().enumerate() {
            let got = cache.to_plan(i);
            assert_eq!(got.nodes, b.nodes);
            assert_eq!(got.num_outputs, b.num_outputs);
            assert_eq!(got.edges, b.edges);
            assert_eq!(got.weights, b.weights);
        }
    }

    #[test]
    fn materialize_into_matches_direct_materialize() {
        let (ds, plans, cache) = build();
        let bucket = cache.max_batch_nodes().next_power_of_two().max(16);
        let mut a = DenseBatch::zeros(bucket, ds.feat_dim);
        let mut b = DenseBatch::zeros(bucket, ds.feat_dim);
        for i in 0..cache.len() {
            cache.materialize_into(&ds, i, &mut a);
            materialize(&ds, &plans[i], &mut b);
            assert_eq!(a.x, b.x, "batch {i} x");
            assert_eq!(a.adj, b.adj, "batch {i} adj");
            assert_eq!(a.labels, b.labels);
            assert_eq!(a.mask, b.mask);
            assert_eq!(a.num_real, b.num_real);
        }
    }

    #[test]
    fn edge_slice_views_match_owned_plans() {
        let (_, _, cache) = build();
        for i in 0..cache.len() {
            let plan = cache.to_plan(i);
            let src = cache.edge_src_of(i);
            let dst = cache.edge_dst_of(i);
            let w = cache.edge_weights_of(i);
            assert_eq!(src.len(), plan.edges.len());
            assert_eq!(dst.len(), plan.edges.len());
            assert_eq!(w, &plan.weights[..]);
            for (e, &(s, d)) in plan.edges.iter().enumerate() {
                assert_eq!((src[e], dst[e]), (s, d), "batch {i} edge {e}");
            }
        }
    }

    #[test]
    fn memory_accounting_is_consistent() {
        let (_, plans, cache) = build();
        let loose: usize = plans.iter().map(|b| b.memory_bytes()).sum();
        // arena holds same payload (+ offsets overhead)
        assert!(cache.memory_bytes() >= loose);
        assert!(cache.memory_bytes() < loose + 64 * (plans.len() + 2));
    }

    #[test]
    fn cow_cache_mirrors_flat_cache() {
        let (_, plans, cache) = build();
        for cow in [CowCache::from_plans(&plans), CowCache::from_cache(&cache)]
        {
            assert_eq!(cow.len(), cache.len());
            assert_eq!(cow.max_batch_nodes(), cache.max_batch_nodes());
            for i in 0..cache.len() {
                assert_eq!(cow.batch_nodes(i), cache.batch_nodes(i));
                assert_eq!(cow.output_nodes(i), cache.output_nodes(i));
                assert_eq!(cow.num_outputs(i), cache.num_outputs(i));
                assert_eq!(cow.edge_src_of(i), cache.edge_src_of(i));
                assert_eq!(cow.edge_dst_of(i), cache.edge_dst_of(i));
                assert_eq!(cow.edge_weights_of(i), cache.edge_weights_of(i));
            }
        }
        // roundtrip back to the flat layout is lossless
        let flat = CowCache::from_plans(&plans).to_batch_cache();
        for i in 0..cache.len() {
            assert_eq!(flat.to_plan(i).nodes, cache.to_plan(i).nodes);
            assert_eq!(flat.to_plan(i).edges, cache.to_plan(i).edges);
        }
    }

    #[test]
    fn patch_copies_only_touched_buckets() {
        let (_, plans, _) = build();
        assert!(plans.len() >= 2, "need two plans to patch one");
        let cow = CowCache::from_plans(&plans);
        let clone = cow.clone();
        let full = clone.shared_with(&cow);
        assert_eq!(full.buckets, cow.len(), "a clone shares every bucket");
        assert_eq!(
            full.bytes,
            (0..cow.len()).map(|i| cow.payload(i).memory_bytes()).sum::<usize>(),
            "shared bytes of a clone == total payload bytes"
        );
        let mut replacement = PlanPayload::from_plan(&plans[1]);
        replacement.weights.iter_mut().for_each(|w| *w *= 2.0);
        let patched = cow.with_patched([(1u32, replacement)]);
        let part = patched.shared_with(&cow);
        assert_eq!(part.buckets, cow.len() - 1);
        assert_eq!(
            part.bytes,
            full.bytes - cow.payload(1).memory_bytes(),
            "patched bucket's bytes drop out of the shared total"
        );
        assert_eq!(patched.batch_nodes(0), cow.batch_nodes(0));
        assert_ne!(patched.edge_weights_of(1), cow.edge_weights_of(1));
        // out-of-range patches are ignored, not panics
        let same = cow.with_patched([(
            u32::MAX,
            PlanPayload::from_plan(&plans[0]),
        )]);
        assert_eq!(same.shared_with(&cow), full);
    }
}
