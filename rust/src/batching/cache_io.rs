//! Versioned `IBMBCACH` container: batch-cache, router-index, and
//! delta-log persistence.
//!
//! The paper: "preprocessing rarely needs to be re-run. Instead, its
//! result can be saved to disk and re-used for training different
//! models." This module serializes the arena-packed [`BatchCache`] —
//! and, since format 3, the serving router's inverted output-node →
//! plan index and dynamic-update delta logs — into one sectioned
//! binary container, so a cold-started `ibmb serve` skips both the
//! planning pass *and* the index inversion, and update streams replay
//! from the same versioned format. Layout (little endian):
//!
//! ```text
//! magic "IBMBCACH" | u64 version (=4) | u64 section_count
//! then per section: u64 tag | u64 byte_len | u64 crc32 | payload
//!                   (version 3 files omit the crc32 word)
//!
//! tag 1 = PLANS:   u64 batches | u64 nodes | u64 edges
//!                  | u64 node_off[batches+1] | u64 edge_off[batches+1]
//!                  | u64 num_outputs[batches]
//!                  | u32 nodes[nodes] | u32 edge_src[edges]
//!                  | u32 edge_dst[edges] | f32 weights[edges]
//! tag 2 = ROUTER:  u64 n | u64 packed[n]      (router.rs packed form)
//! tag 3 = DELTALOG: utf-8 text in the graph::delta line grammar
//! ```
//!
//! The `crc32` word (IEEE CRC-32 of the payload bytes, zero-extended
//! to u64) lets the loader distinguish *corruption* from *format
//! drift*: a bit-flipped section fails its checksum with an error
//! naming the section, before any parsing touches the damaged bytes.
//!
//! The version field lets readers reject files whose layout they do
//! not understand instead of misparsing them, and **unknown section
//! tags are rejected the same way** — a future section is a version
//! bump, never a silent skip. Version history: 1 = headerless seed
//! format (no version field; rejected), 2 = single unsectioned plan
//! payload (rejected — regenerate), 3 = sectioned container without
//! checksums (still readable), 4 = current, adds the per-section
//! crc32 word.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::batch::BatchPlan;
use super::cache::BatchCache;
use crate::graph::delta::{format_delta_log, parse_delta_log, GraphDelta};
use crate::util::crc::crc32;

const MAGIC: &[u8; 8] = b"IBMBCACH";

/// Current on-disk format version. Bump on any layout change and
/// keep the history note in the module docs in sync.
pub const FORMAT_VERSION: u64 = 4;

/// Oldest version this reader still parses (v3 = v4 minus the
/// per-section checksum word).
const OLDEST_READABLE_VERSION: u64 = 3;

/// Section tags. Readers reject tags they do not know.
const SECTION_PLANS: u64 = 1;
const SECTION_ROUTER: u64 = 2;
const SECTION_DELTA_LOG: u64 = 3;

/// Human name of a section tag for error messages.
fn section_name(tag: u64) -> &'static str {
    match tag {
        SECTION_PLANS => "plan",
        SECTION_ROUTER => "router",
        SECTION_DELTA_LOG => "delta-log",
        _ => "unknown",
    }
}

fn push_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn plans_section(cache: &BatchCache) -> Vec<u8> {
    let b = cache.len();
    let total_nodes: usize = (0..b).map(|i| cache.num_nodes(i)).sum();
    let total_edges: usize = (0..b).map(|i| cache.num_edges(i)).sum();
    let mut buf = Vec::with_capacity(
        24 + 8 * (3 * b + 2) + 4 * total_nodes + 12 * total_edges,
    );
    for v in [b as u64, total_nodes as u64, total_edges as u64] {
        push_u64(&mut buf, v);
    }
    let mut off = 0u64;
    push_u64(&mut buf, off);
    for i in 0..b {
        off += cache.num_nodes(i) as u64;
        push_u64(&mut buf, off);
    }
    off = 0;
    push_u64(&mut buf, off);
    for i in 0..b {
        off += cache.num_edges(i) as u64;
        push_u64(&mut buf, off);
    }
    for i in 0..b {
        push_u64(&mut buf, cache.num_outputs(i) as u64);
    }
    for i in 0..b {
        for &u in cache.batch_nodes(i) {
            buf.extend_from_slice(&u.to_le_bytes());
        }
    }
    // edges straight from the arena slice views (src then dst then
    // weights, per batch order so offsets line up)
    for i in 0..b {
        for &s in cache.edge_src_of(i) {
            buf.extend_from_slice(&s.to_le_bytes());
        }
    }
    for i in 0..b {
        for &d in cache.edge_dst_of(i) {
            buf.extend_from_slice(&d.to_le_bytes());
        }
    }
    for i in 0..b {
        for &wt in cache.edge_weights_of(i) {
            buf.extend_from_slice(&wt.to_le_bytes());
        }
    }
    buf
}

fn write_container(path: &Path, sections: &[(u64, Vec<u8>)]) -> Result<()> {
    let mut w = BufWriter::new(
        File::create(path).with_context(|| format!("create {path:?}"))?,
    );
    w.write_all(MAGIC)?;
    w.write_all(&FORMAT_VERSION.to_le_bytes())?;
    w.write_all(&(sections.len() as u64).to_le_bytes())?;
    for (tag, body) in sections {
        w.write_all(&tag.to_le_bytes())?;
        w.write_all(&(body.len() as u64).to_le_bytes())?;
        w.write_all(&(crc32(body) as u64).to_le_bytes())?;
        w.write_all(body)?;
    }
    // Drop would swallow a flush failure (ENOSPC etc.) and report a
    // truncated file as a successful save; flush explicitly.
    w.flush().with_context(|| format!("flush {path:?}"))?;
    Ok(())
}

/// Serialize a cache to disk (plan section only).
pub fn save(cache: &BatchCache, path: &Path) -> Result<()> {
    write_container(path, &[(SECTION_PLANS, plans_section(cache))])
}

/// Serialize a cache plus the serving router's packed warm index
/// (`RouterIndex::to_packed`) so a cold-started server skips the
/// index inversion.
pub fn save_with_index(
    cache: &BatchCache,
    packed_index: &[u64],
    path: &Path,
) -> Result<()> {
    let mut router = Vec::with_capacity(8 + 8 * packed_index.len());
    push_u64(&mut router, packed_index.len() as u64);
    for &p in packed_index {
        push_u64(&mut router, p);
    }
    write_container(
        path,
        &[
            (SECTION_PLANS, plans_section(cache)),
            (SECTION_ROUTER, router),
        ],
    )
}

/// Serialize a delta stream (the `graph::delta` line grammar) into the
/// versioned container — `ibmb update --save-log`.
pub fn save_delta_log(batches: &[GraphDelta], path: &Path) -> Result<()> {
    let text = format_delta_log(batches);
    write_container(path, &[(SECTION_DELTA_LOG, text.into_bytes())])
}

fn take_u64s(buf: &[u8], n: usize) -> Result<(Vec<u64>, &[u8])> {
    if buf.len() < n * 8 {
        bail!("truncated section (wanted {} bytes, had {})", n * 8, buf.len());
    }
    let (head, rest) = buf.split_at(n * 8);
    Ok((
        head.chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect(),
        rest,
    ))
}

fn take_u32s(buf: &[u8], n: usize) -> Result<(Vec<u32>, &[u8])> {
    if buf.len() < n * 4 {
        bail!("truncated section (wanted {} bytes, had {})", n * 4, buf.len());
    }
    let (head, rest) = buf.split_at(n * 4);
    Ok((
        head.chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect(),
        rest,
    ))
}

fn parse_plans_section(body: &[u8]) -> Result<BatchCache> {
    let (head, rest) = take_u64s(body, 3)?;
    let (b, total_nodes, total_edges) =
        (head[0] as usize, head[1] as usize, head[2] as usize);
    // Sanity-check the declared counts against the section length
    // BEFORE sizing any allocation from them, so a corrupt count is a
    // clean error instead of a multi-petabyte Vec or an OOB slice.
    // The layout has no padding: the expected size is exact.
    let expected: u128 = 24
        + 8 * (3 * b as u128 + 2) // node_off + edge_off + num_outputs
        + 4 * total_nodes as u128
        + 12 * total_edges as u128;
    if expected != body.len() as u128 {
        bail!(
            "plan section counts ({b} batches, {total_nodes} nodes, \
             {total_edges} edges) imply {expected} bytes but the section \
             has {} (corrupt header)",
            body.len()
        );
    }
    let (offsets, rest) = take_u64s(rest, 2 * (b + 1) + b)?;
    let node_off = &offsets[..b + 1];
    let edge_off = &offsets[b + 1..2 * (b + 1)];
    let num_outputs = &offsets[2 * (b + 1)..];
    if node_off.first().copied() != Some(0)
        || edge_off.first().copied() != Some(0)
        || node_off.last().copied() != Some(total_nodes as u64)
        || edge_off.last().copied() != Some(total_edges as u64)
    {
        bail!("inconsistent plan-section offsets");
    }
    if node_off.windows(2).any(|w| w[1] < w[0])
        || edge_off.windows(2).any(|w| w[1] < w[0])
    {
        bail!("non-monotonic plan-section offsets (corrupt file)");
    }
    let (nodes, rest) = take_u32s(rest, total_nodes)?;
    let (edge_src, rest) = take_u32s(rest, total_edges)?;
    let (edge_dst, rest) = take_u32s(rest, total_edges)?;
    let (wbits, rest) = take_u32s(rest, total_edges)?;
    if !rest.is_empty() {
        bail!("{} trailing bytes in plan section", rest.len());
    }
    let weights: Vec<f32> = wbits.into_iter().map(f32::from_bits).collect();

    // rebuild through BatchPlan (validates ranges on the way)
    let mut batches = Vec::with_capacity(b);
    for i in 0..b {
        let (ns, ne) = (node_off[i] as usize, node_off[i + 1] as usize);
        let (es, ee) = (edge_off[i] as usize, edge_off[i + 1] as usize);
        let cb = BatchPlan {
            nodes: nodes[ns..ne].to_vec(),
            num_outputs: num_outputs[i] as usize,
            edges: edge_src[es..ee]
                .iter()
                .zip(&edge_dst[es..ee])
                .map(|(&s, &d)| (s, d))
                .collect(),
            weights: weights[es..ee].to_vec(),
        };
        if let Err(e) = cb.validate() {
            bail!("batch {i}: {e}");
        }
        batches.push(cb);
    }
    Ok(BatchCache::build(&batches))
}

fn parse_router_section(body: &[u8]) -> Result<Vec<u64>> {
    let (head, rest) = take_u64s(body, 1)?;
    let n = head[0] as usize;
    if rest.len() != n * 8 {
        bail!(
            "router section declares {n} entries ({} bytes) but carries {}",
            n * 8,
            rest.len()
        );
    }
    let (packed, _) = take_u64s(rest, n)?;
    Ok(packed)
}

/// Sections of one parsed container file.
struct Container {
    plans: Option<BatchCache>,
    router: Option<Vec<u64>>,
    delta_log: Option<Vec<GraphDelta>>,
}

fn read_container(path: &Path) -> Result<Container> {
    let file_len = std::fs::metadata(path)
        .with_context(|| format!("{path:?}: stat"))?
        .len();
    let mut r = BufReader::new(
        File::open(path).with_context(|| format!("open {path:?}"))?,
    );
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)
        .with_context(|| format!("{path:?}: truncated (no magic)"))?;
    if &magic != MAGIC {
        bail!("{path:?}: bad magic (not an IBMB cache file)");
    }
    let mut head = [0u8; 16];
    r.read_exact(&mut head)
        .with_context(|| format!("{path:?}: truncated header"))?;
    let version = u64::from_le_bytes(head[..8].try_into().unwrap());
    if !(OLDEST_READABLE_VERSION..=FORMAT_VERSION).contains(&version) {
        bail!(
            "{path:?}: unsupported IBMBCACH version {version} \
             (this build reads versions {OLDEST_READABLE_VERSION}..=\
             {FORMAT_VERSION}; older versions predate the sectioned \
             container — regenerate the file)"
        );
    }
    // v3 section headers are tag+len; v4 adds the crc32 word
    let checksummed = version >= 4;
    let nsections = u64::from_le_bytes(head[8..].try_into().unwrap());
    let mut out = Container {
        plans: None,
        router: None,
        delta_log: None,
    };
    let mut consumed = 24u64; // magic + version + count
    for s in 0..nsections {
        let mut shead = [0u8; 16];
        r.read_exact(&mut shead)
            .with_context(|| format!("{path:?}: truncated section {s}"))?;
        let tag = u64::from_le_bytes(shead[..8].try_into().unwrap());
        let len = u64::from_le_bytes(shead[8..].try_into().unwrap());
        consumed += 16;
        let want_crc = if checksummed {
            let mut c = [0u8; 8];
            r.read_exact(&mut c)
                .with_context(|| format!("{path:?}: truncated section {s}"))?;
            consumed += 8;
            Some(u64::from_le_bytes(c))
        } else {
            None
        };
        // bound the declared length by the actual file size before
        // allocating for it (saturating: a crafted len near u64::MAX
        // must not wrap the comparison past the guard)
        if len > file_len.saturating_sub(consumed) {
            bail!(
                "{path:?}: section {s} (tag {tag}) declares {len} bytes \
                 past end of file"
            );
        }
        let mut body = vec![0u8; len as usize];
        r.read_exact(&mut body)
            .with_context(|| format!("{path:?}: truncated section {s}"))?;
        consumed += len;
        if let Some(want) = want_crc {
            let got = crc32(&body) as u64;
            if got != want {
                bail!(
                    "{path:?}: {} section (tag {tag}) checksum mismatch \
                     (stored {want:#010x}, computed {got:#010x}) — the \
                     file is corrupt",
                    section_name(tag),
                );
            }
        }
        match tag {
            SECTION_PLANS => {
                out.plans = Some(
                    parse_plans_section(&body)
                        .with_context(|| format!("{path:?}: plan section"))?,
                );
            }
            SECTION_ROUTER => {
                out.router = Some(
                    parse_router_section(&body)
                        .with_context(|| format!("{path:?}: router section"))?,
                );
            }
            SECTION_DELTA_LOG => {
                let text = String::from_utf8(body).map_err(|_| {
                    anyhow::anyhow!("{path:?}: delta log is not utf-8")
                })?;
                out.delta_log = Some(parse_delta_log(&text).map_err(|e| {
                    anyhow::anyhow!("{path:?}: delta log: {e}")
                })?);
            }
            // reject-unknown preserved across the format bump: a tag
            // from the future means a version this reader cannot parse
            other => bail!("{path:?}: unknown section tag {other}"),
        }
    }
    if consumed != file_len {
        bail!(
            "{path:?}: {} trailing bytes after {nsections} sections",
            file_len - consumed
        );
    }
    Ok(out)
}

/// Load a cache previously written by [`save`] /
/// [`save_with_index`].
pub fn load(path: &Path) -> Result<BatchCache> {
    load_with_index(path).map(|(cache, _)| cache)
}

/// Load a cache and, when the file carries one, the packed router
/// index (validate it with `RouterIndex::from_packed` before use).
pub fn load_with_index(path: &Path) -> Result<(BatchCache, Option<Vec<u64>>)> {
    let c = read_container(path)?;
    let cache = c
        .plans
        .ok_or_else(|| anyhow::anyhow!("{path:?}: no plan section"))?;
    Ok((cache, c.router))
}

/// Load a delta stream previously written by [`save_delta_log`] —
/// `ibmb update --load-log`.
pub fn load_delta_log(path: &Path) -> Result<Vec<GraphDelta>> {
    read_container(path)?
        .delta_log
        .ok_or_else(|| anyhow::anyhow!("{path:?}: no delta-log section"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batching::{BatchGenerator, CowCache, NodeWiseIbmb};
    use crate::datasets::{sbm, DatasetSpec};
    use crate::serve::RouterIndex;
    use crate::util::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("ibmb_cache_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn build_cache() -> (crate::datasets::Dataset, BatchCache) {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 150);
        let mut gen = NodeWiseIbmb {
            aux_per_output: 6,
            max_outputs_per_batch: 40,
            node_budget: 256,
            ..Default::default()
        };
        let mut rng = Rng::new(15);
        let cache =
            BatchCache::build(&gen.plan(&ds, &ds.splits.train, &mut rng));
        (ds, cache)
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let (_, cache) = build_cache();
        let path = tmp("cache.bin");
        save(&cache, &path).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.len(), cache.len());
        for i in 0..cache.len() {
            let a = cache.to_plan(i);
            let b = loaded.to_plan(i);
            assert_eq!(a.nodes, b.nodes);
            assert_eq!(a.num_outputs, b.num_outputs);
            assert_eq!(a.edges, b.edges);
            assert_eq!(a.weights, b.weights);
        }
        // a plans-only file reports no router index
        let (_, idx) = load_with_index(&path).unwrap();
        assert!(idx.is_none());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn router_index_rides_alongside_the_cache() {
        let (ds, cache) = build_cache();
        let cow = CowCache::from_cache(&cache);
        let index = RouterIndex::build(ds.graph.num_nodes(), &cow);
        let path = tmp("cache_with_index.bin");
        save_with_index(&cache, &index.to_packed(), &path).unwrap();
        let (loaded, packed) = load_with_index(&path).unwrap();
        assert_eq!(loaded.len(), cache.len());
        let packed = packed.expect("router section present");
        let back =
            RouterIndex::from_packed(packed, &CowCache::from_cache(&loaded))
                .unwrap();
        assert_eq!(back.coverage(), index.coverage());
        for u in 0..ds.graph.num_nodes() as u32 {
            assert_eq!(back.lookup(u), index.lookup(u), "node {u}");
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn delta_logs_roundtrip_through_the_container() {
        let batches = vec![
            GraphDelta {
                add_edges: vec![(0, 1), (2, 3)],
                remove_edges: vec![(1, 2)],
                add_node_labels: vec![4],
                feature_updates: vec![0],
            },
            GraphDelta {
                add_edges: vec![(3, 0)],
                ..Default::default()
            },
        ];
        let path = tmp("deltas.bin");
        save_delta_log(&batches, &path).unwrap();
        let back = load_delta_log(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].add_edges, batches[0].add_edges);
        assert_eq!(back[0].remove_edges, batches[0].remove_edges);
        assert_eq!(back[0].add_node_labels, batches[0].add_node_labels);
        assert_eq!(back[0].feature_updates, batches[0].feature_updates);
        assert_eq!(back[1].add_edges, batches[1].add_edges);
        // a delta-log container is not a plan cache and vice versa
        assert!(load(&path).is_err());
        let (_, cache) = build_cache();
        let cpath = tmp("not_deltas.bin");
        save(&cache, &cpath).unwrap();
        assert!(load_delta_log(&cpath).is_err());
        std::fs::remove_file(path).ok();
        std::fs::remove_file(cpath).ok();
    }

    #[test]
    fn rejects_corrupt_unknown_and_old_files() {
        let path = tmp("bad.bin");
        std::fs::write(&path, b"IBMBCACHgarbage").unwrap();
        assert!(load(&path).is_err());
        std::fs::write(&path, b"WRONGMAG").unwrap();
        assert!(load(&path).is_err());
        // an old version-2 file is rejected, not misparsed
        let mut v2 = Vec::new();
        v2.extend_from_slice(MAGIC);
        v2.extend_from_slice(&2u64.to_le_bytes());
        v2.extend_from_slice(&[0u8; 24]);
        std::fs::write(&path, &v2).unwrap();
        let err = format!("{:#}", load(&path).unwrap_err());
        assert!(err.contains("version 2"), "{err}");
        // an unknown section tag is rejected, not skipped
        let mut future = Vec::new();
        future.extend_from_slice(MAGIC);
        future.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        future.extend_from_slice(&1u64.to_le_bytes()); // one section
        future.extend_from_slice(&99u64.to_le_bytes()); // unknown tag
        future.extend_from_slice(&0u64.to_le_bytes()); // empty body
        future.extend_from_slice(&0u64.to_le_bytes()); // crc32(empty) = 0
        std::fs::write(&path, &future).unwrap();
        let err = format!("{:#}", load(&path).unwrap_err());
        assert!(err.contains("unknown section tag 99"), "{err}");
        // a section running past end-of-file is a clean error
        let mut truncated = Vec::new();
        truncated.extend_from_slice(MAGIC);
        truncated.extend_from_slice(&FORMAT_VERSION.to_le_bytes());
        truncated.extend_from_slice(&1u64.to_le_bytes());
        truncated.extend_from_slice(&1u64.to_le_bytes()); // PLANS
        truncated.extend_from_slice(&(1u64 << 40).to_le_bytes());
        std::fs::write(&path, &truncated).unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn checksum_catches_payload_corruption_per_section() {
        let (ds, cache) = build_cache();
        let cow = CowCache::from_cache(&cache);
        let index = RouterIndex::build(ds.graph.num_nodes(), &cow);
        let path = tmp("corrupt_v4.bin");
        save_with_index(&cache, &index.to_packed(), &path).unwrap();
        let clean = std::fs::read(&path).unwrap();

        // flip one byte deep inside the plan payload: the error must
        // name the plan section, not surface as a parse failure
        let mut bytes = clean.clone();
        let plans_len =
            u64::from_le_bytes(clean[32..40].try_into().unwrap()) as usize;
        let mid = 48 + plans_len / 2; // file header 24 + section header 24
        bytes[mid] ^= 0x40;
        std::fs::write(&path, &bytes).unwrap();
        let err = format!("{:#}", load(&path).unwrap_err());
        assert!(err.contains("checksum mismatch"), "{err}");
        assert!(err.contains("plan section"), "{err}");

        // flip the last payload byte (inside the trailing router
        // section): the router section is named instead
        let mut bytes = clean.clone();
        let last = bytes.len() - 1;
        bytes[last] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let err = format!("{:#}", load_with_index(&path).unwrap_err());
        assert!(err.contains("checksum mismatch"), "{err}");
        assert!(err.contains("router section"), "{err}");

        // untouched file still loads
        std::fs::write(&path, &clean).unwrap();
        load_with_index(&path).unwrap();
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn reads_v3_files_without_checksums() {
        // hand-write a v3 container (pre-checksum section headers)
        // around the same section payloads
        let (ds, cache) = build_cache();
        let cow = CowCache::from_cache(&cache);
        let index = RouterIndex::build(ds.graph.num_nodes(), &cow);
        let mut router = Vec::new();
        push_u64(&mut router, index.to_packed().len() as u64);
        for &p in &index.to_packed() {
            push_u64(&mut router, p);
        }
        let sections = [(SECTION_PLANS, plans_section(&cache)), (SECTION_ROUTER, router)];
        let mut v3 = Vec::new();
        v3.extend_from_slice(MAGIC);
        v3.extend_from_slice(&3u64.to_le_bytes());
        v3.extend_from_slice(&(sections.len() as u64).to_le_bytes());
        for (tag, body) in &sections {
            push_u64(&mut v3, *tag);
            push_u64(&mut v3, body.len() as u64);
            v3.extend_from_slice(body);
        }
        let path = tmp("compat_v3.bin");
        std::fs::write(&path, &v3).unwrap();
        let (loaded, packed) = load_with_index(&path).unwrap();
        assert_eq!(loaded.len(), cache.len());
        assert_eq!(packed.as_deref(), Some(&index.to_packed()[..]));
        std::fs::remove_file(path).ok();
    }
}
