//! Batch-cache disk persistence.
//!
//! The paper: "preprocessing rarely needs to be re-run. Instead, its
//! result can be saved to disk and re-used for training different
//! models." This module serializes the arena-packed [`BatchCache`] to a
//! flat binary file so one preprocessing pass serves every model and
//! every seed. Format (little endian):
//!
//! ```text
//! magic "IBMBCACH" | u64 version (=2)
//! | u64 batches | u64 nodes | u64 edges
//! | u64 node_off[batches+1] | u64 edge_off[batches+1]
//! | u64 num_outputs[batches]
//! | u32 nodes[nodes] | u32 edge_src[edges] | u32 edge_dst[edges]
//! | f32 weights[edges]
//! ```
//!
//! The version field lets the serving router persist/reload plan
//! indexes safely across format changes: readers reject files whose
//! version they do not understand instead of misparsing them. Version
//! history: 1 = headerless seed format (no version field; now
//! rejected), 2 = current.

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::batch::BatchPlan;
use super::cache::BatchCache;

const MAGIC: &[u8; 8] = b"IBMBCACH";

/// Current on-disk format version. Bump on any layout change and
/// keep the history note in the module docs in sync.
pub const FORMAT_VERSION: u64 = 2;

/// Serialize a cache to disk.
pub fn save(cache: &BatchCache, path: &Path) -> Result<()> {
    let mut w = BufWriter::new(
        File::create(path).with_context(|| format!("create {path:?}"))?,
    );
    w.write_all(MAGIC)?;
    let b = cache.len();
    let total_nodes: usize = (0..b).map(|i| cache.num_nodes(i)).sum();
    let total_edges: usize = (0..b).map(|i| cache.num_edges(i)).sum();
    for v in [FORMAT_VERSION, b as u64, total_nodes as u64, total_edges as u64] {
        w.write_all(&v.to_le_bytes())?;
    }
    let mut off = 0u64;
    w.write_all(&off.to_le_bytes())?;
    for i in 0..b {
        off += cache.num_nodes(i) as u64;
        w.write_all(&off.to_le_bytes())?;
    }
    off = 0;
    w.write_all(&off.to_le_bytes())?;
    for i in 0..b {
        off += cache.num_edges(i) as u64;
        w.write_all(&off.to_le_bytes())?;
    }
    for i in 0..b {
        w.write_all(&(cache.num_outputs(i) as u64).to_le_bytes())?;
    }
    for i in 0..b {
        for &u in cache.batch_nodes(i) {
            w.write_all(&u.to_le_bytes())?;
        }
    }
    // edges straight from the arena slice views (src then dst then
    // weights, per batch order so offsets line up)
    for i in 0..b {
        for &s in cache.edge_src_of(i) {
            w.write_all(&s.to_le_bytes())?;
        }
    }
    for i in 0..b {
        for &d in cache.edge_dst_of(i) {
            w.write_all(&d.to_le_bytes())?;
        }
    }
    for i in 0..b {
        for &wt in cache.edge_weights_of(i) {
            w.write_all(&wt.to_le_bytes())?;
        }
    }
    // Drop would swallow a flush failure (ENOSPC etc.) and report a
    // truncated file as a successful save; flush explicitly.
    w.flush().with_context(|| format!("flush {path:?}"))?;
    Ok(())
}

fn read_u64s(r: &mut impl Read, n: usize) -> Result<Vec<u64>> {
    let mut buf = vec![0u8; n * 8];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn read_u32s(r: &mut impl Read, n: usize) -> Result<Vec<u32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

/// Load a cache previously written by [`save`].
pub fn load(path: &Path) -> Result<BatchCache> {
    let mut r = BufReader::new(
        File::open(path).with_context(|| format!("open {path:?}"))?,
    );
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)
        .with_context(|| format!("{path:?}: truncated (no magic)"))?;
    if &magic != MAGIC {
        bail!("{path:?}: bad magic (not an IBMB cache file)");
    }
    let version = read_u64s(&mut r, 1)
        .with_context(|| format!("{path:?}: truncated (no version)"))?[0];
    if version != FORMAT_VERSION {
        bail!(
            "{path:?}: unsupported IBMBCACH version {version} \
             (this build reads version {FORMAT_VERSION}; version-1 \
             files predate the version field — regenerate the cache)"
        );
    }
    let head = read_u64s(&mut r, 3)
        .with_context(|| format!("{path:?}: truncated header"))?;
    let (b, total_nodes, total_edges) =
        (head[0] as usize, head[1] as usize, head[2] as usize);
    // Sanity-check the declared counts against the file length BEFORE
    // sizing any allocation from them, so a corrupt count is a clean
    // error instead of a multi-petabyte Vec or an OOB slice. The
    // format has no padding: the expected size is exact.
    let file_len = std::fs::metadata(path)
        .with_context(|| format!("{path:?}: stat"))?
        .len() as u128;
    let expected: u128 = 8  // magic
        + 8 // version
        + 24 // batches/nodes/edges
        + 8 * (3 * b as u128 + 2) // node_off + edge_off + num_outputs
        + 4 * total_nodes as u128 // nodes
        + 12 * total_edges as u128; // edge_src + edge_dst + weights
    if expected != file_len {
        bail!(
            "{path:?}: header counts ({b} batches, {total_nodes} nodes, \
             {total_edges} edges) imply {expected} bytes but the file \
             has {file_len} (corrupt header)"
        );
    }
    let offsets = read_u64s(&mut r, 2 * (b + 1) + b)
        .with_context(|| format!("{path:?}: truncated offset tables"))?;
    let node_off = &offsets[..b + 1];
    let edge_off = &offsets[b + 1..2 * (b + 1)];
    let num_outputs = &offsets[2 * (b + 1)..];
    if node_off.first().copied() != Some(0)
        || edge_off.first().copied() != Some(0)
        || node_off.last().copied() != Some(total_nodes as u64)
        || edge_off.last().copied() != Some(total_edges as u64)
    {
        bail!("{path:?}: inconsistent offsets");
    }
    if node_off.windows(2).any(|w| w[1] < w[0])
        || edge_off.windows(2).any(|w| w[1] < w[0])
    {
        bail!("{path:?}: non-monotonic offsets (corrupt file)");
    }
    let nodes = read_u32s(&mut r, total_nodes)
        .with_context(|| format!("{path:?}: truncated node arena"))?;
    let edge_src = read_u32s(&mut r, total_edges)
        .with_context(|| format!("{path:?}: truncated edge sources"))?;
    let edge_dst = read_u32s(&mut r, total_edges)
        .with_context(|| format!("{path:?}: truncated edge destinations"))?;
    let mut wbuf = vec![0u8; total_edges * 4];
    r.read_exact(&mut wbuf)
        .with_context(|| format!("{path:?}: truncated edge weights"))?;
    let weights: Vec<f32> = wbuf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect();

    // rebuild through BatchPlan (validates ranges on the way)
    let mut batches = Vec::with_capacity(b);
    for i in 0..b {
        let (ns, ne) = (node_off[i] as usize, node_off[i + 1] as usize);
        let (es, ee) = (edge_off[i] as usize, edge_off[i + 1] as usize);
        let cb = BatchPlan {
            nodes: nodes[ns..ne].to_vec(),
            num_outputs: num_outputs[i] as usize,
            edges: edge_src[es..ee]
                .iter()
                .zip(&edge_dst[es..ee])
                .map(|(&s, &d)| (s, d))
                .collect(),
            weights: weights[es..ee].to_vec(),
        };
        if let Err(e) = cb.validate() {
            bail!("{path:?}: batch {i}: {e}");
        }
        batches.push(cb);
    }
    Ok(BatchCache::build(&batches))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batching::{BatchGenerator, NodeWiseIbmb};
    use crate::datasets::{sbm, DatasetSpec};
    use crate::util::Rng;

    #[test]
    fn roundtrip_preserves_everything() {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 150);
        let mut gen = NodeWiseIbmb {
            aux_per_output: 6,
            max_outputs_per_batch: 40,
            node_budget: 256,
            ..Default::default()
        };
        let mut rng = Rng::new(15);
        let cache =
            BatchCache::build(&gen.plan(&ds, &ds.splits.train, &mut rng));
        let dir = std::env::temp_dir().join("ibmb_cache_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("cache.bin");
        save(&cache, &path).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(loaded.len(), cache.len());
        for i in 0..cache.len() {
            let a = cache.to_plan(i);
            let b = loaded.to_plan(i);
            assert_eq!(a.nodes, b.nodes);
            assert_eq!(a.num_outputs, b.num_outputs);
            assert_eq!(a.edges, b.edges);
            assert_eq!(a.weights, b.weights);
        }
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_corrupt_files() {
        let dir = std::env::temp_dir().join("ibmb_cache_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"IBMBCACHgarbage").unwrap();
        assert!(load(&path).is_err());
        std::fs::write(&path, b"WRONGMAG").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_file(path).ok();
    }
}
