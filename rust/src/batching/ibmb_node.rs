//! Node-wise IBMB (paper §3.1 "Node-wise selection" + §3.2
//! "Distance-based partitioning") — the paper's strongest variant.
//!
//! Per output node, approximate PPR yields its top-k influence
//! neighborhood; the *same* PPR vectors then drive the greedy
//! distance-based output partition, so preprocessing pays for both
//! steps at once. Each batch's auxiliary set is the union of its
//! output nodes' top-k lists, trimmed to the node budget by total
//! influence score.

use std::collections::HashMap;

use super::batch::BatchPlan;
use super::BatchGenerator;
use crate::datasets::Dataset;
use crate::graph::{induced_subgraph, GraphView};
use crate::partition::pprdist::ppr_distance_partition;
use crate::ppr::push::{PushConfig, SparsePpr};
use crate::ppr::topk::top_k_indices;
use crate::util::Rng;

/// Node-wise IBMB configuration.
#[derive(Debug, Clone)]
pub struct NodeWiseIbmb {
    /// Auxiliary nodes per output node (the paper's one free knob:
    /// 16 for arxiv, 64 for products, 8 for Reddit, 96 for papers).
    pub aux_per_output: usize,
    /// Output nodes per batch (set by GPU memory in the paper).
    pub max_outputs_per_batch: usize,
    /// Hard cap on total batch nodes (largest artifact bucket).
    pub node_budget: usize,
    pub push: PushConfig,
    /// Preprocessing worker threads (1 = serial; pushes are
    /// root-independent, see [`crate::ppr::parallel`]).
    pub threads: usize,
}

impl Default for NodeWiseIbmb {
    fn default() -> Self {
        NodeWiseIbmb {
            aux_per_output: 16,
            max_outputs_per_batch: 96,
            node_budget: 2048,
            push: PushConfig::default(),
            threads: 1,
        }
    }
}

/// Assemble one influence-maximal batch from its output nodes and
/// their (sparse) PPR vectors — auxiliary nodes are the union of the
/// outputs' top-k influence lists, trimmed to `node_budget` by total
/// score. Shared by [`NodeWiseIbmb::plan`] and the dynamic replan path
/// ([`super::refresh`]), and generic over [`GraphView`] so rebuilds can
/// run on a delta overlay without a CSR snapshot.
///
/// `pprs[i]` is the `(nodes, scores)` pair of `outputs[i]`'s PPR
/// vector.
pub(crate) fn assemble_plan<G: GraphView>(
    g: &G,
    outputs: &[u32],
    pprs: &[(&[u32], &[f32])],
    aux_per_output: usize,
    node_budget: usize,
) -> BatchPlan {
    debug_assert_eq!(outputs.len(), pprs.len());
    // accumulate influence of candidate aux nodes over all outputs
    let mut is_output = HashMap::new();
    for &o in outputs {
        is_output.insert(o, ());
    }
    let mut score: HashMap<u32, f32> = HashMap::new();
    for &(ppr_nodes, ppr_scores) in pprs {
        let top = top_k_indices(ppr_scores, aux_per_output + 1);
        for t in top {
            let v = ppr_nodes[t];
            if !is_output.contains_key(&v) {
                *score.entry(v).or_insert(0.0) += ppr_scores[t];
            }
        }
    }
    let mut cands: Vec<(u32, f32)> = score.into_iter().collect();
    cands.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0)));
    let budget = node_budget.saturating_sub(outputs.len());
    cands.truncate(budget);

    let mut nodes: Vec<u32> = outputs.to_vec();
    nodes.extend(cands.iter().map(|&(v, _)| v));
    let sg = induced_subgraph(g, &nodes);
    BatchPlan {
        nodes: sg.nodes,
        num_outputs: outputs.len(),
        edges: sg.edges,
        weights: sg.weights,
    }
}

impl NodeWiseIbmb {
    /// Compute per-output PPR vectors (shared by selection+partition).
    fn pprs(&self, ds: &Dataset, out_nodes: &[u32]) -> Vec<SparsePpr> {
        crate::ppr::parallel_push_ppr(
            &ds.graph,
            out_nodes,
            &self.push,
            self.threads,
        )
    }

    /// Assemble one batch from its outputs and their PPR vectors.
    fn assemble(
        &self,
        ds: &Dataset,
        outputs: &[u32],
        idx_of: &HashMap<u32, usize>,
        pprs: &[SparsePpr],
    ) -> BatchPlan {
        let per_output: Vec<(&[u32], &[f32])> = outputs
            .iter()
            .map(|o| {
                let ppr = &pprs[idx_of[o]];
                (&ppr.nodes[..], &ppr.scores[..])
            })
            .collect();
        assemble_plan(
            &ds.graph,
            outputs,
            &per_output,
            self.aux_per_output,
            self.node_budget,
        )
    }
}

impl BatchGenerator for NodeWiseIbmb {
    fn name(&self) -> &'static str {
        "node-wise IBMB"
    }

    fn plan(
        &mut self,
        ds: &Dataset,
        out_nodes: &[u32],
        rng: &mut Rng,
    ) -> Vec<BatchPlan> {
        let pprs = self.pprs(ds, out_nodes);
        let partition = ppr_distance_partition(
            out_nodes,
            &pprs,
            self.max_outputs_per_batch,
            rng,
        );
        let idx_of: HashMap<u32, usize> = out_nodes
            .iter()
            .enumerate()
            .map(|(i, &u)| (u, i))
            .collect();
        partition
            .iter()
            .map(|outputs| self.assemble(ds, outputs, &idx_of, &pprs))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{sbm, DatasetSpec};

    fn gen(k: usize, cap: usize) -> (Dataset, Vec<BatchPlan>) {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 50);
        let mut g = NodeWiseIbmb {
            aux_per_output: k,
            max_outputs_per_batch: cap,
            node_budget: 256,
            ..Default::default()
        };
        let out = ds.splits.train.clone();
        let mut rng = Rng::new(0);
        let batches = g.plan(&ds, &out, &mut rng);
        (ds, batches)
    }

    #[test]
    fn covers_every_output_node_exactly_once() {
        let (ds, batches) = gen(8, 40);
        let mut seen = std::collections::HashSet::new();
        for b in &batches {
            assert!(b.validate().is_ok());
            for &o in b.output_nodes() {
                assert!(seen.insert(o), "output {o} twice");
            }
        }
        assert_eq!(seen.len(), ds.splits.train.len());
    }

    #[test]
    fn respects_caps() {
        let (_, batches) = gen(8, 40);
        for b in &batches {
            assert!(b.num_outputs <= 40);
            assert!(b.num_nodes() <= 256);
        }
    }

    #[test]
    fn aux_nodes_are_nearby() {
        // with homophilic SBM, most batch nodes share the outputs' labels
        let (ds, batches) = gen(8, 40);
        let mut same = 0.0;
        let mut tot = 0.0;
        for b in &batches {
            let out_hist = ds.label_histogram(b.output_nodes());
            let dominant = out_hist
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap()
                .0;
            for &v in &b.nodes {
                tot += 1.0;
                if ds.labels[v as usize] as usize == dominant {
                    same += 1.0;
                }
            }
        }
        assert!(same / tot > 0.35, "locality too weak: {}", same / tot);
    }

    #[test]
    fn more_aux_nodes_means_bigger_batches() {
        let (_, small) = gen(4, 40);
        let (_, big) = gen(16, 40);
        let avg = |bs: &[BatchPlan]| {
            bs.iter().map(|b| b.num_nodes()).sum::<usize>() as f64
                / bs.len() as f64
        };
        assert!(avg(&big) > avg(&small));
    }

    #[test]
    fn is_fixed_generator() {
        assert!(NodeWiseIbmb::default().is_fixed());
    }
}
