//! Batch-wise IBMB (paper §3.1 "Batch-wise selection" + §3.2 "Graph
//! partitioning"): METIS partitions define the output batches, then one
//! topic-sensitive PPR run per batch scores every node's joint
//! influence on the whole output set, and the top scorers become the
//! auxiliary nodes ("we use as many auxiliary nodes as the size of each
//! partition", App. B).

use super::batch::BatchPlan;
use super::BatchGenerator;
use crate::datasets::Dataset;
use crate::graph::induced_subgraph;
use crate::partition::metis::{metis_output_partition, MetisConfig};
use crate::ppr::heat::{heat_kernel, HeatConfig};
use crate::ppr::power::{batch_ppr, PowerConfig};
use crate::ppr::topk::top_k_indices;
use crate::util::Rng;

/// Batch-wise IBMB configuration.
#[derive(Debug, Clone)]
pub struct BatchWiseIbmb {
    /// Number of batches (paper Table 1 tunes this per dataset).
    pub num_batches: usize,
    /// Auxiliary nodes as a multiple of the batch's output count
    /// (1.0 reproduces the paper's "as many as the partition size").
    pub aux_factor: f64,
    /// Hard cap on total batch nodes (largest artifact bucket).
    pub node_budget: usize,
    pub power: PowerConfig,
    pub metis: MetisConfig,
    /// Swap PPR for heat-kernel diffusion (Table 5 sensitivity study).
    pub heat: Option<HeatConfig>,
}

impl Default for BatchWiseIbmb {
    fn default() -> Self {
        BatchWiseIbmb {
            num_batches: 8,
            aux_factor: 1.0,
            node_budget: 2048,
            power: PowerConfig::default(),
            metis: MetisConfig::default(),
            heat: None,
        }
    }
}

impl BatchWiseIbmb {
    fn assemble(&self, ds: &Dataset, outputs: &[u32]) -> BatchPlan {
        let (cand_nodes, cand_scores) = match &self.heat {
            Some(h) => heat_kernel(&ds.graph, outputs, h),
            None => batch_ppr(&ds.graph, outputs, &self.power),
        };
        let is_output: std::collections::HashSet<u32> =
            outputs.iter().copied().collect();
        let want_aux = ((outputs.len() as f64 * self.aux_factor) as usize)
            .min(self.node_budget.saturating_sub(outputs.len()));
        // top scorers that are not outputs
        let order = top_k_indices(&cand_scores, cand_nodes.len());
        let mut nodes: Vec<u32> = outputs.to_vec();
        for i in order {
            if nodes.len() >= outputs.len() + want_aux {
                break;
            }
            let v = cand_nodes[i];
            if !is_output.contains(&v) {
                nodes.push(v);
            }
        }
        let sg = induced_subgraph(&ds.graph, &nodes);
        BatchPlan {
            nodes: sg.nodes,
            num_outputs: outputs.len(),
            edges: sg.edges,
            weights: sg.weights,
        }
    }
}

impl BatchGenerator for BatchWiseIbmb {
    fn name(&self) -> &'static str {
        "batch-wise IBMB"
    }

    fn plan(
        &mut self,
        ds: &Dataset,
        out_nodes: &[u32],
        rng: &mut Rng,
    ) -> Vec<BatchPlan> {
        let partition = metis_output_partition(
            &ds.graph,
            out_nodes,
            self.num_batches,
            &self.metis,
            rng,
        );
        partition
            .iter()
            .map(|outputs| self.assemble(ds, outputs))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{sbm, DatasetSpec};

    fn gen(num_batches: usize) -> (Dataset, Vec<BatchPlan>) {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 60);
        let mut g = BatchWiseIbmb {
            num_batches,
            node_budget: 512,
            ..Default::default()
        };
        let out = ds.splits.train.clone();
        let mut rng = Rng::new(1);
        let batches = g.plan(&ds, &out, &mut rng);
        (ds, batches)
    }

    #[test]
    fn covers_outputs_once() {
        let (ds, batches) = gen(6);
        let mut seen = std::collections::HashSet::new();
        for b in &batches {
            assert!(b.validate().is_ok());
            for &o in b.output_nodes() {
                assert!(seen.insert(o));
            }
        }
        assert_eq!(seen.len(), ds.splits.train.len());
    }

    #[test]
    fn aux_count_tracks_output_count() {
        let (_, batches) = gen(6);
        for b in &batches {
            let aux = b.num_nodes() - b.num_outputs;
            // aux_factor = 1.0 => roughly as many aux as outputs
            // (can be fewer if the PPR ball is small)
            assert!(
                aux <= b.num_outputs + 1,
                "aux {aux} vs outputs {}",
                b.num_outputs
            );
        }
    }

    #[test]
    fn batches_overlap_is_possible_but_outputs_do_not() {
        let (_, batches) = gen(4);
        if batches.len() < 2 {
            return;
        }
        let a: std::collections::HashSet<u32> =
            batches[0].output_nodes().iter().copied().collect();
        for &o in batches[1].output_nodes() {
            assert!(!a.contains(&o));
        }
    }

    #[test]
    fn respects_node_budget() {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 61);
        let mut g = BatchWiseIbmb {
            num_batches: 2,
            node_budget: 64,
            ..Default::default()
        };
        let out = ds.splits.train.clone();
        let mut rng = Rng::new(2);
        for b in g.plan(&ds, &out, &mut rng) {
            // outputs may exceed the aux budget (partition is given),
            // but aux selection must not blow past the cap
            assert!(
                b.num_nodes() <= b.num_outputs.max(64),
                "{} nodes",
                b.num_nodes()
            );
        }
    }
}
