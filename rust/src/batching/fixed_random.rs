//! "Fixed random" ablation (Fig. 6) / "IBMB, rand batch." (Fig. 2):
//! influence-based auxiliary selection with *random* output batching.
//! Isolates the contribution of output-node partitioning — these
//! batches lose the neighborhood-sharing synergy and are therefore
//! bigger (less overlap) and converge more slowly.

use std::collections::HashMap;

use super::batch::BatchPlan;
use super::BatchGenerator;
use crate::datasets::Dataset;
use crate::graph::induced_subgraph;
use crate::partition::random::random_partition;
use crate::ppr::push::{push_ppr, PushConfig, PushWorkspace};
use crate::ppr::topk::top_k_indices;
use crate::util::Rng;

/// Random output batching + node-wise top-k PPR auxiliary selection.
#[derive(Debug, Clone)]
pub struct FixedRandomBatches {
    pub aux_per_output: usize,
    pub num_batches: usize,
    pub node_budget: usize,
    pub push: PushConfig,
}

impl Default for FixedRandomBatches {
    fn default() -> Self {
        FixedRandomBatches {
            aux_per_output: 16,
            num_batches: 8,
            node_budget: 2048,
            push: PushConfig::default(),
        }
    }
}

impl BatchGenerator for FixedRandomBatches {
    fn name(&self) -> &'static str {
        "fixed random"
    }

    fn plan(
        &mut self,
        ds: &Dataset,
        out_nodes: &[u32],
        rng: &mut Rng,
    ) -> Vec<BatchPlan> {
        let partition = random_partition(out_nodes, self.num_batches, rng);
        let mut ws = PushWorkspace::new(ds.graph.num_nodes());
        partition
            .iter()
            .map(|outputs| {
                let out_set: HashMap<u32, ()> =
                    outputs.iter().map(|&o| (o, ())).collect();
                let mut score: HashMap<u32, f32> = HashMap::new();
                for &o in outputs {
                    let ppr = push_ppr(&ds.graph, o, &self.push, &mut ws);
                    for t in
                        top_k_indices(&ppr.scores, self.aux_per_output + 1)
                    {
                        let v = ppr.nodes[t];
                        if !out_set.contains_key(&v) {
                            *score.entry(v).or_insert(0.0) += ppr.scores[t];
                        }
                    }
                }
                let mut cands: Vec<(u32, f32)> = score.into_iter().collect();
                cands.sort_by(|a, b| {
                    b.1.partial_cmp(&a.1).unwrap().then(a.0.cmp(&b.0))
                });
                cands.truncate(
                    self.node_budget.saturating_sub(outputs.len()),
                );
                let mut nodes = outputs.clone();
                nodes.extend(cands.iter().map(|&(v, _)| v));
                let sg = induced_subgraph(&ds.graph, &nodes);
                BatchPlan {
                    nodes: sg.nodes,
                    num_outputs: outputs.len(),
                    edges: sg.edges,
                    weights: sg.weights,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::batching::NodeWiseIbmb;
    use crate::datasets::{sbm, DatasetSpec};

    #[test]
    fn covers_outputs_and_validates() {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 70);
        let out = ds.splits.train.clone();
        let mut g = FixedRandomBatches {
            num_batches: 6,
            node_budget: 400,
            ..Default::default()
        };
        let mut rng = Rng::new(3);
        let batches = g.plan(&ds, &out, &mut rng);
        let total: usize = batches.iter().map(|b| b.num_outputs).sum();
        assert_eq!(total, out.len());
        for b in &batches {
            assert!(b.validate().is_ok());
        }
    }

    #[test]
    fn random_batches_have_less_aux_overlap_than_ibmb() {
        // The synergy claim of §3.2: locality-partitioned outputs share
        // auxiliary nodes, random ones do not => random batches need
        // more total nodes for the same k.
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 71);
        let out = ds.splits.train.clone();
        let mut rng = Rng::new(4);
        let mut ibmb = NodeWiseIbmb {
            aux_per_output: 8,
            max_outputs_per_batch: 50,
            node_budget: 4096,
            ..Default::default()
        };
        let ibmb_batches = ibmb.plan(&ds, &out, &mut rng);
        let nb = ibmb_batches.len().max(1);
        let mut rand = FixedRandomBatches {
            aux_per_output: 8,
            num_batches: nb,
            node_budget: 4096,
            ..Default::default()
        };
        let rand_batches = rand.plan(&ds, &out, &mut rng);
        let total = |bs: &[BatchPlan]| {
            bs.iter().map(|b| b.num_nodes()).sum::<usize>()
        };
        assert!(
            total(&rand_batches) as f64 > total(&ibmb_batches) as f64 * 1.1,
            "random {} vs ibmb {}",
            total(&rand_batches),
            total(&ibmb_batches)
        );
    }
}
