//! Synthetic dataset substrate.
//!
//! The paper evaluates on ogbn-arxiv / ogbn-products / Reddit /
//! ogbn-papers100M. Those are gated (size, licensing, 256 GB RAM), so —
//! per the substitution rule in DESIGN.md §3 — we generate seeded
//! planted-partition (degree-corrected SBM) graphs with homophilic
//! Gaussian features that preserve the properties IBMB exploits:
//! locality, label homophily, skewed degrees, and small label rates.
//!
//! Node features are **not** materialized: they are deterministic
//! functions of `(dataset seed, node id)` and are generated straight
//! into the batch buffer during densification. This mirrors the
//! disk-backed feature streaming of billion-node deployments and keeps
//! Table 6 memory accounting honest.

pub mod registry;
pub mod sbm;
pub mod splits;

pub use registry::{spec_by_name, DatasetSpec, ALL_DATASETS};
pub use splits::Splits;

use crate::graph::CsrGraph;
use crate::util::Rng;

/// A fully generated dataset: graph + labels + splits + feature model.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: String,
    pub graph: CsrGraph,
    /// Ground-truth class per node.
    pub labels: Vec<u16>,
    pub num_classes: usize,
    pub feat_dim: usize,
    /// Per-class feature means, row-major `[classes, feat_dim]`.
    pub class_means: Vec<f32>,
    /// Gaussian feature noise scale.
    pub noise: f32,
    pub seed: u64,
    pub splits: Splits,
    /// Per-node feature version, folded into the feature generator's
    /// seed. All zero at generation time; dynamic feature updates
    /// (`GraphDelta::feature_updates`, DESIGN.md §10) bump a node's
    /// entry, deterministically re-rolling its noise while leaving
    /// every other node bit-identical.
    pub feat_epoch: Vec<u32>,
}

impl Dataset {
    /// Deterministically generate node `u`'s feature row into `out`
    /// (length `feat_dim`): class mean + seeded Gaussian noise keyed by
    /// `(dataset seed, node id, feature epoch)`.
    pub fn node_features_into(&self, u: u32, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.feat_dim);
        let c = self.labels[u as usize] as usize;
        let mean = &self.class_means[c * self.feat_dim..(c + 1) * self.feat_dim];
        let mut rng = Rng::new(
            self.seed
                ^ (u as u64).wrapping_mul(0xA24BAED4963EE407)
                ^ (self.feat_epoch[u as usize] as u64)
                    .wrapping_mul(0x9E3779B97F4A7C15),
        );
        for (o, &m) in out.iter_mut().zip(mean) {
            *o = m + self.noise * rng.normal();
        }
    }

    /// Label distribution (counts) over an arbitrary node set — the
    /// scheduler's batch-distance signal.
    pub fn label_histogram(&self, nodes: &[u32]) -> Vec<f64> {
        let mut h = vec![0.0; self.num_classes];
        for &u in nodes {
            h[self.labels[u as usize] as usize] += 1.0;
        }
        h
    }

    /// Bytes held in memory for this dataset (graph + labels + means).
    pub fn memory_bytes(&self) -> usize {
        self.graph.memory_bytes()
            + self.labels.len() * 2
            + self.class_means.len() * 4
            + self.splits.memory_bytes()
            + self.feat_epoch.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn features_are_deterministic_and_class_separated() {
        let spec = registry::DatasetSpec::tiny_for_tests();
        let ds = sbm::generate(&spec, 7);
        let mut a = vec![0.0; ds.feat_dim];
        let mut b = vec![0.0; ds.feat_dim];
        ds.node_features_into(3, &mut a);
        ds.node_features_into(3, &mut b);
        assert_eq!(a, b);
        // two nodes of different classes should differ in expectation
        let (mut u, mut v) = (0u32, 0u32);
        for i in 0..ds.labels.len() as u32 {
            if ds.labels[i as usize] != ds.labels[0] {
                v = i;
                break;
            }
            u = i;
        }
        ds.node_features_into(u, &mut a);
        ds.node_features_into(v, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn label_histogram_counts() {
        let spec = registry::DatasetSpec::tiny_for_tests();
        let ds = sbm::generate(&spec, 7);
        let h = ds.label_histogram(&ds.splits.train);
        assert_eq!(h.iter().sum::<f64>() as usize, ds.splits.train.len());
        assert_eq!(h.len(), ds.num_classes);
    }
}
