//! Named dataset presets mirroring the paper's four benchmarks at a
//! scale this CPU testbed can sweep (DESIGN.md §3). All share
//! `feat = 64`, `classes = 10` so one artifact set serves every dataset.

/// Generator parameters for a synthetic planted-partition dataset.
#[derive(Debug, Clone)]
pub struct DatasetSpec {
    pub name: &'static str,
    pub nodes: usize,
    /// Communities are arranged on a ring; labels are `community % classes`.
    pub communities: usize,
    pub classes: usize,
    pub feat_dim: usize,
    /// Target average degree (before self loops).
    pub avg_degree: f64,
    /// Fraction of edges that stay inside the community.
    pub p_intra: f64,
    /// Fraction of edges that go to a ring-adjacent community
    /// (creates locality structure beyond the community itself).
    pub p_adjacent: f64,
    /// Degree-correction Pareto shape; smaller = heavier tail.
    pub degree_tail: f64,
    /// Gaussian feature noise scale (class-mean magnitude is 1).
    pub noise: f32,
    /// Split fractions (train, val); test is the remainder.
    pub train_frac: f64,
    pub val_frac: f64,
}

impl DatasetSpec {
    /// Uniform scale-down of the node count (benches' smoke mode).
    pub fn scaled(&self, factor: f64) -> DatasetSpec {
        let mut s = self.clone();
        s.nodes = ((s.nodes as f64 * factor) as usize).max(64);
        s
    }

    /// A minimal spec for unit tests.
    pub fn tiny_for_tests() -> DatasetSpec {
        DatasetSpec {
            name: "tiny",
            nodes: 600,
            communities: 12,
            classes: 4,
            feat_dim: 16,
            avg_degree: 8.0,
            p_intra: 0.7,
            p_adjacent: 0.2,
            degree_tail: 2.5,
            noise: 1.0,
            train_frac: 0.5,
            val_frac: 0.15,
        }
    }
}

/// synth-arxiv — stands in for ogbn-arxiv (169k nodes, deg ~13,
/// 54 % train labels): moderate size, high label rate.
pub const SYNTH_ARXIV: DatasetSpec = DatasetSpec {
    name: "synth-arxiv",
    nodes: 24_000,
    communities: 60,
    classes: 10,
    feat_dim: 64,
    avg_degree: 8.0,
    p_intra: 0.65,
    p_adjacent: 0.25,
    degree_tail: 2.5,
    noise: 2.8,
    train_frac: 0.54,
    val_frac: 0.18,
};

/// synth-products — stands in for ogbn-products (2.4M nodes, deg ~50,
/// 8 % train labels): larger, denser, low label rate.
pub const SYNTH_PRODUCTS: DatasetSpec = DatasetSpec {
    name: "synth-products",
    nodes: 60_000,
    communities: 150,
    classes: 10,
    feat_dim: 64,
    avg_degree: 12.0,
    p_intra: 0.7,
    p_adjacent: 0.22,
    degree_tail: 2.0,
    noise: 2.8,
    train_frac: 0.08,
    val_frac: 0.02,
};

/// synth-reddit — stands in for Reddit (233k nodes, deg ~490 downsampled
/// to 8 by the paper; we use a dense-but-tractable 24): very dense.
pub const SYNTH_REDDIT: DatasetSpec = DatasetSpec {
    name: "synth-reddit",
    nodes: 16_000,
    communities: 40,
    classes: 10,
    feat_dim: 64,
    avg_degree: 24.0,
    p_intra: 0.75,
    p_adjacent: 0.18,
    degree_tail: 2.2,
    noise: 2.6,
    train_frac: 0.66,
    val_frac: 0.10,
};

/// synth-papers — stands in for ogbn-papers100M (111M nodes, 1.1 % train
/// labels): the "huge graph, tiny label rate" regime where IBMB's
/// output-node scaling dominates.
pub const SYNTH_PAPERS: DatasetSpec = DatasetSpec {
    name: "synth-papers",
    nodes: 200_000,
    communities: 500,
    classes: 10,
    feat_dim: 64,
    avg_degree: 6.0,
    p_intra: 0.65,
    p_adjacent: 0.25,
    degree_tail: 2.0,
    noise: 2.8,
    train_frac: 0.011,
    val_frac: 0.004,
};

pub const ALL_DATASETS: [&DatasetSpec; 4] = [
    &SYNTH_ARXIV,
    &SYNTH_PRODUCTS,
    &SYNTH_REDDIT,
    &SYNTH_PAPERS,
];

/// Look up a preset by name.
pub fn spec_by_name(name: &str) -> Option<&'static DatasetSpec> {
    ALL_DATASETS.iter().copied().find(|s| s.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_lookup() {
        assert!(spec_by_name("synth-arxiv").is_some());
        assert!(spec_by_name("synth-papers").is_some());
        assert!(spec_by_name("nope").is_none());
    }

    #[test]
    fn all_specs_share_model_interface() {
        for s in ALL_DATASETS {
            assert_eq!(s.feat_dim, 64);
            assert_eq!(s.classes, 10);
            assert!(s.train_frac + s.val_frac < 1.0);
            assert!(s.p_intra + s.p_adjacent <= 1.0);
        }
    }

    #[test]
    fn scaled_changes_nodes_only() {
        let s = SYNTH_PAPERS.scaled(0.1);
        assert_eq!(s.nodes, 20_000);
        assert_eq!(s.classes, SYNTH_PAPERS.classes);
    }
}
