//! Degree-corrected planted-partition (SBM) generator.
//!
//! Communities sit on a ring; each node draws a Pareto degree weight and
//! connects (a) inside its community with probability `p_intra`,
//! (b) to a ring-adjacent community with `p_adjacent`, and (c) uniformly
//! otherwise. Labels are `community % classes`, so neighborhoods are
//! label-homophilic with locality structure that PPR and METIS can
//! actually exploit — the regime the paper's datasets live in.

use super::registry::DatasetSpec;
use super::splits;
use super::Dataset;
use crate::graph::GraphBuilder;
use crate::util::Rng;

/// Generate a seeded dataset from a spec.
pub fn generate(spec: &DatasetSpec, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x1B4D_B002);
    let n = spec.nodes;
    let k = spec.communities.min(n).max(1);

    // contiguous community blocks => community of u is u * k / n
    let comm_of = |u: usize| -> usize { u * k / n };
    let comm_start = |c: usize| -> usize { c * n / k };
    let comm_end = |c: usize| -> usize { (c + 1) * n / k };

    // degree-correction weights: Pareto(shape=tail) capped
    let mut deg_target = Vec::with_capacity(n);
    for _ in 0..n {
        let u = rng.next_f64().max(1e-9);
        let w = u.powf(-1.0 / spec.degree_tail).min(20.0); // mean ~ tail/(tail-1)
        deg_target.push(w);
    }
    let mean_w: f64 = deg_target.iter().sum::<f64>() / n as f64;

    let mut builder = GraphBuilder::new(n);
    for u in 0..n {
        let c = comm_of(u);
        // each node initiates half its target stubs (other half arrives
        // from peers), scaled by its degree weight
        let stubs = (spec.avg_degree * 0.5 * deg_target[u] / mean_w).round()
            as usize;
        for _ in 0..stubs.max(1) {
            let r = rng.next_f64();
            let v = if r < spec.p_intra {
                // inside the community
                let (s, e) = (comm_start(c), comm_end(c));
                s + rng.next_below((e - s).max(1))
            } else if r < spec.p_intra + spec.p_adjacent {
                // ring-adjacent community
                let dir = if rng.next_f64() < 0.5 { 1 } else { k - 1 };
                let cc = (c + dir) % k;
                let (s, e) = (comm_start(cc), comm_end(cc));
                s + rng.next_below((e - s).max(1))
            } else {
                rng.next_below(n)
            };
            if v != u {
                builder.add_edge(u as u32, v as u32);
            }
        }
    }
    let graph = builder.build();

    let labels: Vec<u16> = (0..n)
        .map(|u| (comm_of(u) % spec.classes) as u16)
        .collect();

    // class means: random unit-scale directions
    let mut means_rng = Rng::new(seed ^ 0xFEA7_0001);
    let class_means: Vec<f32> = (0..spec.classes * spec.feat_dim)
        .map(|_| means_rng.normal())
        .collect();

    let splits = splits::make_splits(
        n,
        spec.train_frac,
        spec.val_frac,
        &mut Rng::new(seed ^ 0x5911_7000),
    );

    Dataset {
        name: spec.name.to_string(),
        graph,
        labels,
        num_classes: spec.classes,
        feat_dim: spec.feat_dim,
        class_means,
        noise: spec.noise,
        seed,
        splits,
        feat_epoch: vec![0; n],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::registry::DatasetSpec;

    fn tiny() -> Dataset {
        generate(&DatasetSpec::tiny_for_tests(), 3)
    }

    #[test]
    fn generator_is_deterministic() {
        let a = tiny();
        let b = tiny();
        assert_eq!(a.graph.indices, b.graph.indices);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.splits.train, b.splits.train);
    }

    #[test]
    fn graph_is_valid_and_roughly_right_degree() {
        let ds = tiny();
        assert!(ds.graph.validate().is_ok());
        let avg = ds.graph.avg_degree();
        // target 8 (+1 self loop); generous band for the small n
        assert!(avg > 4.0 && avg < 16.0, "avg degree {avg}");
    }

    #[test]
    fn labels_cover_all_classes() {
        let ds = tiny();
        let mut seen = vec![false; ds.num_classes];
        for &l in &ds.labels {
            seen[l as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn graph_is_homophilic() {
        // neighbors share labels far more often than chance (1/classes)
        let ds = tiny();
        let mut same = 0usize;
        let mut total = 0usize;
        for u in 0..ds.graph.num_nodes() as u32 {
            for &v in ds.graph.neighbors(u) {
                if v != u {
                    total += 1;
                    if ds.labels[u as usize] == ds.labels[v as usize] {
                        same += 1;
                    }
                }
            }
        }
        let h = same as f64 / total as f64;
        assert!(h > 0.5, "homophily {h} too low");
    }

    #[test]
    fn degrees_are_skewed() {
        let ds = tiny();
        let degs: Vec<usize> = (0..ds.graph.num_nodes() as u32)
            .map(|u| ds.graph.degree(u))
            .collect();
        let max = *degs.iter().max().unwrap() as f64;
        let avg = degs.iter().sum::<usize>() as f64 / degs.len() as f64;
        assert!(max > 2.5 * avg, "max {max} vs avg {avg}: no tail");
    }

    #[test]
    fn seeds_differ() {
        let spec = DatasetSpec::tiny_for_tests();
        let a = generate(&spec, 1);
        let b = generate(&spec, 2);
        assert_ne!(a.graph.indices, b.graph.indices);
    }
}
