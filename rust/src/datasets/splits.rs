//! Train/validation/test node splits.
//!
//! Also provides the label-rate subsampling used by the paper's Fig. 4
//! ("we reduce the label rate by sub-sampling the training nodes").

use crate::util::Rng;

/// Disjoint node-id splits.
#[derive(Debug, Clone)]
pub struct Splits {
    pub train: Vec<u32>,
    pub val: Vec<u32>,
    pub test: Vec<u32>,
}

impl Splits {
    pub fn memory_bytes(&self) -> usize {
        (self.train.len() + self.val.len() + self.test.len()) * 4
    }

    /// Subsample the training set to `frac` of its size (Fig. 4's
    /// label-rate sweep). Deterministic given the rng seed.
    pub fn with_train_fraction(&self, frac: f64, rng: &mut Rng) -> Splits {
        let k = ((self.train.len() as f64 * frac).round() as usize)
            .clamp(1, self.train.len());
        let idx = rng.sample_distinct(self.train.len(), k);
        let mut train: Vec<u32> = idx.iter().map(|&i| self.train[i]).collect();
        train.sort_unstable();
        Splits {
            train,
            val: self.val.clone(),
            test: self.test.clone(),
        }
    }
}

/// Random disjoint splits over `n` nodes.
pub fn make_splits(n: usize, train_frac: f64, val_frac: f64, rng: &mut Rng) -> Splits {
    let mut ids: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut ids);
    let n_train = (n as f64 * train_frac).round() as usize;
    let n_val = (n as f64 * val_frac).round() as usize;
    let mut train = ids[..n_train].to_vec();
    let mut val = ids[n_train..n_train + n_val].to_vec();
    let mut test = ids[n_train + n_val..].to_vec();
    train.sort_unstable();
    val.sort_unstable();
    test.sort_unstable();
    Splits { train, val, test }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splits_are_disjoint_and_cover() {
        let mut rng = Rng::new(1);
        let s = make_splits(1000, 0.5, 0.2, &mut rng);
        assert_eq!(s.train.len(), 500);
        assert_eq!(s.val.len(), 200);
        assert_eq!(s.test.len(), 300);
        let mut all: Vec<u32> = s
            .train
            .iter()
            .chain(&s.val)
            .chain(&s.test)
            .copied()
            .collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 1000);
    }

    #[test]
    fn train_fraction_subsamples_only_train() {
        let mut rng = Rng::new(2);
        let s = make_splits(1000, 0.5, 0.2, &mut rng);
        let sub = s.with_train_fraction(0.1, &mut rng);
        assert_eq!(sub.train.len(), 50);
        assert_eq!(sub.val, s.val);
        assert_eq!(sub.test, s.test);
        // subsample is a subset of the original train set
        assert!(sub.train.iter().all(|u| s.train.binary_search(u).is_ok()));
    }

    #[test]
    fn fraction_clamps() {
        let mut rng = Rng::new(3);
        let s = make_splits(100, 0.3, 0.1, &mut rng);
        assert_eq!(s.train.len(), 30);
        let sub = s.with_train_fraction(0.0, &mut rng);
        assert_eq!(sub.train.len(), 1);
        let full = s.with_train_fraction(2.0, &mut rng);
        assert_eq!(full.train.len(), 30);
    }
}
