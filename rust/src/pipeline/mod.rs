//! Prefetching loader — "we fully pipeline data loading and batch
//! creation by prefetching batches in parallel" (paper §5).
//!
//! A single worker thread densifies (features + adjacency fill +
//! padding) the *next* batch while the caller executes the current one,
//! with two rotating buffers and bounded channels for backpressure.
//! The paper found one worker optimal ("data loading is limited by
//! memory bandwidth, which is shared between workers") — we match that.

pub mod prefetch;

pub use prefetch::{run_prefetched, PrefetchStats};
