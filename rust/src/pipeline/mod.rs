//! Prefetching loader — "we fully pipeline data loading and batch
//! creation by prefetching batches in parallel" (paper §5).
//!
//! A single worker thread materializes (features + adjacency fill +
//! padding) upcoming batches while the caller executes the current one,
//! rotating a ring of N arena-owned buffers through bounded channels
//! for backpressure (DESIGN.md §7). The paper found one worker optimal
//! ("data loading is limited by memory bandwidth, which is shared
//! between workers") — we match that and expose the *buffer* count as
//! the tunable instead: `--prefetch-depth` / `IBMB_PREFETCH_DEPTH`
//! selects N (default 2 = double buffering; deeper rings absorb
//! materialization-time jitter at N× buffer memory).

pub mod prefetch;

pub use prefetch::{run_prefetched, PrefetchStats};
