//! Generic double-buffered prefetch executor over scoped threads
//! (tokio is unavailable offline; std threads express the same
//! pipeline semantics — DESIGN.md §7).

use std::sync::mpsc;
use std::time::Instant;

/// Overlap accounting for the §Perf target ("densify fully hidden
/// behind execute").
#[derive(Debug, Clone, Copy, Default)]
pub struct PrefetchStats {
    /// Seconds the consumer spent blocked waiting for a buffer.
    pub wait_s: f64,
    /// Seconds the consumer spent executing.
    pub consume_s: f64,
    /// Items processed.
    pub items: usize,
}

impl PrefetchStats {
    /// 1.0 = producer fully hidden; 0.0 = fully serialized.
    pub fn overlap_ratio(&self) -> f64 {
        let total = self.wait_s + self.consume_s;
        if total <= 0.0 {
            return 1.0;
        }
        self.consume_s / total
    }
}

/// Run `consume(i, buf)` over `order`, with `fill(i, buf)` for the next
/// item executing concurrently on a worker thread. Two buffers rotate
/// through bounded channels (capacity 1 each) providing backpressure.
pub fn run_prefetched<B: Send>(
    order: &[usize],
    mut buf_a: B,
    buf_b: B,
    fill: impl Fn(usize, &mut B) + Send + Sync,
    mut consume: impl FnMut(usize, &B),
) -> PrefetchStats {
    let mut stats = PrefetchStats::default();
    if order.is_empty() {
        return stats;
    }
    if order.len() == 1 {
        // no pipeline needed
        fill(order[0], &mut buf_a);
        let t = Instant::now();
        consume(order[0], &buf_a);
        stats.consume_s = t.elapsed().as_secs_f64();
        stats.items = 1;
        return stats;
    }

    std::thread::scope(|scope| {
        // filled buffers flow worker -> consumer; empties flow back
        let (full_tx, full_rx) = mpsc::sync_channel::<(usize, B)>(1);
        let (empty_tx, empty_rx) = mpsc::sync_channel::<B>(2);

        // seed the worker with both buffers
        fill(order[0], &mut buf_a);
        full_tx.send((order[0], buf_a)).unwrap();

        let fill_ref = &fill;
        scope.spawn(move || {
            let mut next = Some(buf_b);
            for &i in &order[1..] {
                let mut buf = match next.take() {
                    Some(b) => b,
                    None => match empty_rx.recv() {
                        Ok(b) => b,
                        Err(_) => return, // consumer dropped
                    },
                };
                fill_ref(i, &mut buf);
                if full_tx.send((i, buf)).is_err() {
                    return;
                }
            }
        });

        for _ in 0..order.len() {
            let t_wait = Instant::now();
            let (i, buf) = full_rx.recv().expect("producer died");
            stats.wait_s += t_wait.elapsed().as_secs_f64();
            let t_run = Instant::now();
            consume(i, &buf);
            stats.consume_s += t_run.elapsed().as_secs_f64();
            stats.items += 1;
            let _ = empty_tx.send(buf); // worker may already be done
        }
    });
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn processes_all_items_in_order() {
        let order: Vec<usize> = (0..20).collect();
        let mut seen = Vec::new();
        let stats = run_prefetched(
            &order,
            0usize,
            0usize,
            |i, buf| *buf = i * 10,
            |i, buf| {
                assert_eq!(*buf, i * 10);
                seen.push(i);
            },
        );
        assert_eq!(seen, order);
        assert_eq!(stats.items, 20);
    }

    #[test]
    fn single_item_and_empty() {
        let mut count = 0;
        let s = run_prefetched(&[7], 0u8, 0u8, |_, _| {}, |_, _| count += 1);
        assert_eq!((count, s.items), (1, 1));
        let s = run_prefetched(&[], 0u8, 0u8, |_, _| {}, |_, _| {});
        assert_eq!(s.items, 0);
    }

    #[test]
    fn producer_overlaps_consumer() {
        // producer and consumer each sleep; pipelined wall time must be
        // well below the serial sum
        let order: Vec<usize> = (0..8).collect();
        let t = Instant::now();
        let stats = run_prefetched(
            &order,
            0u8,
            0u8,
            |_, _| std::thread::sleep(std::time::Duration::from_millis(10)),
            |_, _| std::thread::sleep(std::time::Duration::from_millis(10)),
        );
        let wall = t.elapsed().as_secs_f64();
        assert!(wall < 0.145, "no overlap: {wall}s");
        assert!(stats.overlap_ratio() > 0.5, "{:?}", stats);
    }

    #[test]
    fn fill_runs_once_per_item() {
        let fills = AtomicUsize::new(0);
        let order: Vec<usize> = (0..50).collect();
        run_prefetched(
            &order,
            0u8,
            0u8,
            |_, _| {
                fills.fetch_add(1, Ordering::Relaxed);
            },
            |_, _| {},
        );
        assert_eq!(fills.load(Ordering::Relaxed), 50);
    }
}
