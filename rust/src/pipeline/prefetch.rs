//! Generic depth-N ring prefetch executor over scoped threads
//! (tokio is unavailable offline; std threads express the same
//! pipeline semantics — DESIGN.md §7).
//!
//! [`run_prefetched`] drives `consume(i, buf)` over `order` while a
//! single worker thread runs `fill(i, buf)` for upcoming items into a
//! ring of N caller-owned buffers (N = pipeline depth). Bounded
//! channels provide backpressure: at most N−1 filled buffers ever wait
//! ahead of the consumer. Depth 1 degenerates to a serial fill→consume
//! loop (the no-pipeline baseline the benches compare against), depth 2
//! is classic double buffering, and deeper rings absorb fill-time
//! jitter. All buffers are handed back to the caller afterwards so a
//! [`crate::batching::BatchArena`] can reclaim them — the ring borrows
//! memory, it never owns it.

use std::sync::mpsc;
use std::time::Instant;

/// Overlap accounting for the §Perf target ("materialization fully
/// hidden behind execute").
#[derive(Debug, Clone, Copy, Default)]
pub struct PrefetchStats {
    /// Seconds the consumer spent blocked waiting for a buffer.
    pub wait_s: f64,
    /// Seconds the consumer spent executing.
    pub consume_s: f64,
    /// Items processed.
    pub items: usize,
    /// Ring depth the run used (number of buffers).
    pub depth: usize,
}

impl PrefetchStats {
    /// 1.0 = producer fully hidden; 0.0 = fully serialized.
    pub fn overlap_ratio(&self) -> f64 {
        let total = self.wait_s + self.consume_s;
        if total <= 0.0 {
            return 1.0;
        }
        self.consume_s / total
    }
}

/// Run `consume(i, buf)` over `order` with `fill(i, buf)` for upcoming
/// items executing concurrently on a worker thread, rotating through
/// the `buffers` ring. Returns the stats and every buffer (order
/// unspecified) for reuse.
///
/// Panics if `buffers` is empty.
pub fn run_prefetched<B: Send>(
    order: &[usize],
    mut buffers: Vec<B>,
    fill: impl Fn(usize, &mut B) + Send + Sync,
    mut consume: impl FnMut(usize, &B),
) -> (PrefetchStats, Vec<B>) {
    assert!(!buffers.is_empty(), "run_prefetched needs >= 1 buffer");
    let depth = buffers.len();
    let mut stats = PrefetchStats {
        depth,
        ..Default::default()
    };
    if order.is_empty() {
        return (stats, buffers);
    }
    if depth == 1 || order.len() == 1 {
        // serial: every fill is consumer wait by definition
        let buf = &mut buffers[0];
        for &i in order {
            let t = Instant::now();
            fill(i, buf);
            stats.wait_s += t.elapsed().as_secs_f64();
            let t = Instant::now();
            consume(i, buf);
            stats.consume_s += t.elapsed().as_secs_f64();
            stats.items += 1;
        }
        return (stats, buffers);
    }

    let mut recovered: Vec<B> = Vec::with_capacity(depth);
    std::thread::scope(|scope| {
        // filled buffers flow worker -> consumer; empties flow back
        let (full_tx, full_rx) = mpsc::sync_channel::<(usize, B)>(depth - 1);
        let (empty_tx, empty_rx) = mpsc::sync_channel::<B>(depth);
        let seed: Vec<B> = std::mem::take(&mut buffers);
        let fill_ref = &fill;
        let worker = scope.spawn(move || {
            let mut pool = seed;
            for &i in order {
                let next = pool.pop().or_else(|| empty_rx.recv().ok());
                let mut buf = match next {
                    Some(b) => b,
                    None => return pool, // consumer dropped
                };
                fill_ref(i, &mut buf);
                if full_tx.send((i, buf)).is_err() {
                    return pool;
                }
            }
            pool // leftover empties when depth > items
        });

        // The worker needs exactly len - depth recycled empties (it
        // starts with the whole ring); the final `depth` buffers are
        // kept out of the channel so the caller gets them back.
        let handoffs = order.len().saturating_sub(depth);
        for k in 0..order.len() {
            let t = Instant::now();
            let (i, buf) = full_rx.recv().expect("prefetch worker died");
            stats.wait_s += t.elapsed().as_secs_f64();
            let t = Instant::now();
            consume(i, &buf);
            stats.consume_s += t.elapsed().as_secs_f64();
            stats.items += 1;
            if k < handoffs {
                let _ = empty_tx.send(buf);
            } else {
                recovered.push(buf);
            }
        }
        drop(empty_tx);
        recovered.extend(worker.join().expect("prefetch worker panicked"));
    });
    (stats, recovered)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn processes_all_items_in_order() {
        let order: Vec<usize> = (0..20).collect();
        let mut seen = Vec::new();
        let (stats, bufs) = run_prefetched(
            &order,
            vec![0usize, 0usize],
            |i, buf| *buf = i * 10,
            |i, buf| {
                assert_eq!(*buf, i * 10);
                seen.push(i);
            },
        );
        assert_eq!(seen, order);
        assert_eq!(stats.items, 20);
        assert_eq!(stats.depth, 2);
        assert_eq!(bufs.len(), 2);
    }

    #[test]
    fn depths_one_two_four_agree_on_consume_order() {
        let order: Vec<usize> = (0..37).collect();
        let mut orders = Vec::new();
        for depth in [1usize, 2, 4] {
            let mut seen = Vec::new();
            let (stats, bufs) = run_prefetched(
                &order,
                vec![0usize; depth],
                |i, buf| *buf = i * 3 + 1,
                |i, buf| {
                    assert_eq!(*buf, i * 3 + 1, "depth {depth}: stale buffer");
                    seen.push(i);
                },
            );
            assert_eq!(stats.items, order.len(), "depth {depth}");
            assert_eq!(stats.depth, depth);
            assert_eq!(bufs.len(), depth, "depth {depth}: buffers lost");
            let r = stats.overlap_ratio();
            assert!((0.0..=1.0).contains(&r), "depth {depth}: overlap {r}");
            orders.push(seen);
        }
        assert_eq!(orders[0], order);
        assert!(orders.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn single_item_and_empty() {
        let mut count = 0;
        let (s, b) =
            run_prefetched(&[7], vec![0u8, 0u8], |_, _| {}, |_, _| count += 1);
        assert_eq!((count, s.items, b.len()), (1, 1, 2));
        let (s, b) = run_prefetched(&[], vec![0u8, 0u8], |_, _| {}, |_, _| {});
        assert_eq!((s.items, b.len()), (0, 2));
    }

    #[test]
    fn ring_deeper_than_order_returns_all_buffers() {
        let mut seen = Vec::new();
        let (stats, bufs) = run_prefetched(
            &[3, 1],
            vec![0usize; 5],
            |i, buf| *buf = i,
            |i, buf| {
                assert_eq!(*buf, i);
                seen.push(i);
            },
        );
        assert_eq!(seen, vec![3, 1]);
        assert_eq!(stats.items, 2);
        assert_eq!(bufs.len(), 5);
    }

    #[test]
    fn producer_overlaps_consumer() {
        // producer and consumer each sleep; pipelined wall time must be
        // well below the serial sum
        let order: Vec<usize> = (0..8).collect();
        let t = Instant::now();
        let (stats, _) = run_prefetched(
            &order,
            vec![0u8, 0u8],
            |_, _| std::thread::sleep(std::time::Duration::from_millis(10)),
            |_, _| std::thread::sleep(std::time::Duration::from_millis(10)),
        );
        let wall = t.elapsed().as_secs_f64();
        assert!(wall < 0.145, "no overlap: {wall}s");
        assert!(stats.overlap_ratio() > 0.5, "{:?}", stats);
    }

    #[test]
    fn fill_runs_once_per_item() {
        let fills = AtomicUsize::new(0);
        let order: Vec<usize> = (0..50).collect();
        for depth in [1, 2, 3] {
            fills.store(0, Ordering::Relaxed);
            run_prefetched(
                &order,
                vec![0u8; depth],
                |_, _| {
                    fills.fetch_add(1, Ordering::Relaxed);
                },
                |_, _| {},
            );
            assert_eq!(fills.load(Ordering::Relaxed), 50, "depth {depth}");
        }
    }
}
