//! Max-distance TSP cycle by simulated annealing.
//!
//! The paper finds "the fixed batch cycle that maximizes the batch
//! distances between consecutive batches. This is a traveling salesman
//! problem ... We determine the optimal batch order for IBMB via
//! simulated annealing" (App. B, python-tsp). 2-opt neighborhood,
//! geometric cooling, seeded.

use crate::util::Rng;

/// Simulated-annealing knobs.
#[derive(Debug, Clone, Copy)]
pub struct SaConfig {
    pub iterations: usize,
    pub t_start: f64,
    pub t_end: f64,
}

impl Default for SaConfig {
    fn default() -> Self {
        SaConfig {
            iterations: 20_000,
            t_start: 1.0,
            t_end: 1e-3,
        }
    }
}

fn cycle_length(dist: &[Vec<f64>], order: &[usize]) -> f64 {
    let b = order.len();
    (0..b)
        .map(|i| dist[order[i]][order[(i + 1) % b]])
        .sum()
}

/// Find a high-total-distance cycle visiting every batch once.
pub fn optimal_cycle_with(
    dist: &[Vec<f64>],
    cfg: &SaConfig,
    rng: &mut Rng,
) -> Vec<usize> {
    let b = dist.len();
    if b <= 2 {
        return (0..b).collect();
    }
    let mut order: Vec<usize> = (0..b).collect();
    rng.shuffle(&mut order);
    let mut best = order.clone();
    let mut cur_len = cycle_length(dist, &order);
    let mut best_len = cur_len;
    let cool = (cfg.t_end / cfg.t_start).powf(1.0 / cfg.iterations as f64);
    let mut t = cfg.t_start;
    // scale temperature by a typical distance so acceptance is sane
    let scale = {
        let mut s = 0.0;
        let mut c = 0;
        for i in 0..b {
            for j in (i + 1)..b {
                s += dist[i][j];
                c += 1;
            }
        }
        (s / c.max(1) as f64).max(1e-9)
    };
    for _ in 0..cfg.iterations {
        // 2-opt: reverse a random segment
        let i = rng.next_below(b);
        let j = rng.next_below(b);
        let (lo, hi) = (i.min(j), i.max(j));
        if hi - lo < 1 || (lo == 0 && hi == b - 1) {
            t *= cool;
            continue;
        }
        // delta from swapping the two boundary edges
        let prev = order[(lo + b - 1) % b];
        let next = order[(hi + 1) % b];
        let old = dist[prev][order[lo]] + dist[order[hi]][next];
        let new = dist[prev][order[hi]] + dist[order[lo]][next];
        let delta = new - old; // maximize
        if delta > 0.0
            || rng.next_f64() < (delta / (t * scale)).exp()
        {
            order[lo..=hi].reverse();
            cur_len += delta;
            if cur_len > best_len {
                best_len = cur_len;
                best = order.clone();
            }
        }
        t *= cool;
    }
    best
}

/// Default-config SA cycle.
pub fn optimal_cycle(dist: &[Vec<f64>], rng: &mut Rng) -> Vec<usize> {
    optimal_cycle_with(dist, &SaConfig::default(), rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn random_dist(b: usize, rng: &mut Rng) -> Vec<Vec<f64>> {
        let mut d = vec![vec![0.0; b]; b];
        for i in 0..b {
            for j in (i + 1)..b {
                let v = rng.next_f64();
                d[i][j] = v;
                d[j][i] = v;
            }
        }
        d
    }

    #[test]
    fn returns_permutation() {
        let mut rng = Rng::new(4);
        let d = random_dist(9, &mut rng);
        let mut c = optimal_cycle(&d, &mut rng);
        c.sort_unstable();
        assert_eq!(c, (0..9).collect::<Vec<_>>());
    }

    #[test]
    fn beats_random_orders() {
        let mut rng = Rng::new(5);
        let d = random_dist(12, &mut rng);
        let sa = optimal_cycle(&d, &mut rng);
        let sa_len = cycle_length(&d, &sa);
        let mut rand_best = 0.0f64;
        for _ in 0..200 {
            let mut o: Vec<usize> = (0..12).collect();
            rng.shuffle(&mut o);
            rand_best = rand_best.max(cycle_length(&d, &o));
        }
        assert!(
            sa_len >= rand_best * 0.98,
            "sa {sa_len} vs random-best {rand_best}"
        );
    }

    #[test]
    fn finds_exact_optimum_on_small_instance() {
        // 4 nodes: brute-force the max cycle
        let mut rng = Rng::new(6);
        let d = random_dist(4, &mut rng);
        let sa_len = cycle_length(&d, &optimal_cycle(&d, &mut rng));
        let mut best = 0.0f64;
        let perms = [
            [0usize, 1, 2, 3],
            [0, 1, 3, 2],
            [0, 2, 1, 3],
            [0, 2, 3, 1],
            [0, 3, 1, 2],
            [0, 3, 2, 1],
        ];
        for p in perms {
            best = best.max(cycle_length(&d, &p));
        }
        assert!((sa_len - best).abs() < 1e-9, "sa {sa_len} best {best}");
    }

    #[test]
    fn degenerate_sizes() {
        let mut rng = Rng::new(7);
        assert!(optimal_cycle(&[], &mut rng).is_empty());
        assert_eq!(optimal_cycle(&[vec![0.0]], &mut rng), vec![0]);
        assert_eq!(
            optimal_cycle(&random_dist(2, &mut rng), &mut rng).len(),
            2
        );
    }
}
