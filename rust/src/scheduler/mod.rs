//! Batch scheduling (paper §4 "Batch scheduling").
//!
//! Fixed, locality-correlated batches can form suboptimal *sequences*:
//! a run of similar batches drives the optimizer in one direction and
//! causes the paper's "downward spikes in accuracy". The fix is to
//! maximize dissimilarity between consecutive batches, where batch
//! distance is the **symmetrized KL divergence of training-label
//! distributions**. Two schedulers:
//!
//! * [`tsp::optimal_cycle`] — a fixed maximum-distance batch cycle via
//!   simulated annealing on the max-TSP tour (paper: python-tsp SA).
//! * [`WeightedScheduler`] — sample the next batch with probability
//!   proportional to its distance from the current one.
//! * [`SequentialScheduler`] / [`ShuffleScheduler`] — controls.

pub mod tsp;

use crate::util::stats::symmetric_kl;
use crate::util::Rng;

/// Pairwise symmetrized-KL distance matrix between batch label
/// histograms (each histogram is the label counts of a batch's
/// *output* nodes).
pub fn batch_distance_matrix(histograms: &[Vec<f64>]) -> Vec<Vec<f64>> {
    let b = histograms.len();
    let mut d = vec![vec![0.0; b]; b];
    for i in 0..b {
        for j in (i + 1)..b {
            let v = symmetric_kl(&histograms[i], &histograms[j]);
            d[i][j] = v;
            d[j][i] = v;
        }
    }
    d
}

/// Produces the batch visit order for each epoch.
pub trait Scheduler {
    fn name(&self) -> &'static str;
    /// Order of batch indices for one epoch.
    fn epoch_order(&mut self, rng: &mut Rng) -> Vec<usize>;
}

/// Fixed 0..b order (worst case for correlated batches).
pub struct SequentialScheduler {
    pub num_batches: usize,
}

impl Scheduler for SequentialScheduler {
    fn name(&self) -> &'static str {
        "sequential"
    }
    fn epoch_order(&mut self, _rng: &mut Rng) -> Vec<usize> {
        (0..self.num_batches).collect()
    }
}

/// Uniform random shuffle per epoch (the usual default).
pub struct ShuffleScheduler {
    pub num_batches: usize,
}

impl Scheduler for ShuffleScheduler {
    fn name(&self) -> &'static str {
        "shuffle"
    }
    fn epoch_order(&mut self, rng: &mut Rng) -> Vec<usize> {
        let mut order: Vec<usize> = (0..self.num_batches).collect();
        rng.shuffle(&mut order);
        order
    }
}

/// The paper's fixed max-distance cycle, rotated to a random start
/// each epoch so every batch is still used exactly once per epoch.
pub struct OptimalCycleScheduler {
    cycle: Vec<usize>,
}

impl OptimalCycleScheduler {
    pub fn new(dist: &[Vec<f64>], rng: &mut Rng) -> Self {
        OptimalCycleScheduler {
            cycle: tsp::optimal_cycle(dist, rng),
        }
    }
    pub fn cycle(&self) -> &[usize] {
        &self.cycle
    }
}

impl Scheduler for OptimalCycleScheduler {
    fn name(&self) -> &'static str {
        "optimal cycle"
    }
    fn epoch_order(&mut self, rng: &mut Rng) -> Vec<usize> {
        let b = self.cycle.len();
        if b == 0 {
            return Vec::new();
        }
        let start = rng.next_below(b);
        (0..b).map(|i| self.cycle[(start + i) % b]).collect()
    }
}

/// Distance-weighted sampling without replacement: each epoch visits
/// every batch once, choosing the next proportional to its distance
/// from the current batch (paper's variant (ii)).
pub struct WeightedScheduler {
    dist: Vec<Vec<f64>>,
    last: Option<usize>,
}

impl WeightedScheduler {
    pub fn new(dist: Vec<Vec<f64>>) -> Self {
        WeightedScheduler { dist, last: None }
    }
}

impl Scheduler for WeightedScheduler {
    fn name(&self) -> &'static str {
        "weighted sampling"
    }
    fn epoch_order(&mut self, rng: &mut Rng) -> Vec<usize> {
        let b = self.dist.len();
        let mut remaining: Vec<usize> = (0..b).collect();
        let mut order = Vec::with_capacity(b);
        let mut cur = self.last;
        while !remaining.is_empty() {
            let next_pos = match cur {
                None => rng.next_below(remaining.len()),
                Some(c) => {
                    let w: Vec<f64> = remaining
                        .iter()
                        .map(|&j| self.dist[c][j].max(1e-9))
                        .collect();
                    rng.weighted(&w)
                }
            };
            let next = remaining.swap_remove(next_pos);
            order.push(next);
            cur = Some(next);
        }
        self.last = cur;
        order
    }
}

/// Mean distance between consecutive batches of an order (quality
/// metric used by Fig. 7's reproduction).
pub fn order_quality(dist: &[Vec<f64>], order: &[usize]) -> f64 {
    if order.len() < 2 {
        return 0.0;
    }
    let mut total = 0.0;
    for w in order.windows(2) {
        total += dist[w[0]][w[1]];
    }
    total / (order.len() - 1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_dist() -> Vec<Vec<f64>> {
        // two clusters {0,1} and {2,3}: cross distances large
        let h = [
            vec![10.0, 0.0],
            vec![9.0, 1.0],
            vec![0.0, 10.0],
            vec![1.0, 9.0],
        ];
        batch_distance_matrix(&h)
    }

    #[test]
    fn distance_matrix_is_symmetric_zero_diag() {
        let d = toy_dist();
        for i in 0..4 {
            assert_eq!(d[i][i], 0.0);
            for j in 0..4 {
                assert_eq!(d[i][j], d[j][i]);
            }
        }
        assert!(d[0][2] > d[0][1]);
    }

    #[test]
    fn all_schedulers_produce_permutations() {
        let d = toy_dist();
        let mut rng = Rng::new(1);
        let mut scheds: Vec<Box<dyn Scheduler>> = vec![
            Box::new(SequentialScheduler { num_batches: 4 }),
            Box::new(ShuffleScheduler { num_batches: 4 }),
            Box::new(OptimalCycleScheduler::new(&d, &mut rng)),
            Box::new(WeightedScheduler::new(d.clone())),
        ];
        for s in scheds.iter_mut() {
            for _ in 0..3 {
                let mut o = s.epoch_order(&mut rng);
                o.sort_unstable();
                assert_eq!(o, vec![0, 1, 2, 3], "{}", s.name());
            }
        }
    }

    #[test]
    fn optimal_cycle_beats_sequential_on_clustered_batches() {
        let d = toy_dist();
        let mut rng = Rng::new(2);
        let mut opt = OptimalCycleScheduler::new(&d, &mut rng);
        let mut seq = SequentialScheduler { num_batches: 4 };
        let q_opt = order_quality(&d, &opt.epoch_order(&mut rng));
        let q_seq = order_quality(&d, &seq.epoch_order(&mut rng));
        assert!(q_opt > q_seq, "opt {q_opt} vs seq {q_seq}");
    }

    #[test]
    fn weighted_scheduler_prefers_distant_followups() {
        let d = toy_dist();
        let mut rng = Rng::new(3);
        let mut sched = WeightedScheduler::new(d.clone());
        let mut cross = 0usize;
        let mut total = 0usize;
        for _ in 0..200 {
            let o = sched.epoch_order(&mut rng);
            for w in o.windows(2) {
                total += 1;
                // cluster of 0,1 is {0}, of 2,3 is {1}
                if (w[0] < 2) != (w[1] < 2) {
                    cross += 1;
                }
            }
        }
        // random order would cross ~2/3 of the time at most; weighted
        // should cross more often
        assert!(
            cross as f64 / total as f64 > 0.6,
            "cross rate {}",
            cross as f64 / total as f64
        );
    }
}
