//! shaDow (Zeng et al. 2021): decoupled per-output subgraphs.
//!
//! Each output node gets its own PPR-selected subgraph — like node-wise
//! IBMB's auxiliary selection — but shaDow does **not** partition
//! output nodes, so per-output subgraphs are stacked independently and
//! shared nodes are *duplicated* across (and within) batches. The
//! duplication is its characteristic cost: per-batch node counts are
//! Σ(k+1) instead of |union|, which reproduces the paper's "worse
//! runtimes" (Table 7: shaDow inference is the slowest scalable method).

use crate::batching::batch::BatchPlan;
use crate::batching::BatchGenerator;
use crate::datasets::Dataset;
use crate::graph::induced_subgraph;
use crate::ppr::push::{push_ppr, PushConfig, PushWorkspace};
use crate::ppr::topk::top_k_nodes;
use crate::util::Rng;

/// shaDow-style decoupled subgraph batching.
#[derive(Debug, Clone)]
pub struct Shadow {
    /// PPR neighborhood size per output node.
    pub aux_per_output: usize,
    /// Node budget per stacked batch (bucket size).
    pub node_budget: usize,
    pub push: PushConfig,
}

impl Default for Shadow {
    fn default() -> Self {
        Shadow {
            aux_per_output: 16,
            node_budget: 2048,
            push: PushConfig::default(),
        }
    }
}

impl BatchGenerator for Shadow {
    fn name(&self) -> &'static str {
        "shaDow"
    }

    fn plan(
        &mut self,
        ds: &Dataset,
        out_nodes: &[u32],
        rng: &mut Rng,
    ) -> Vec<BatchPlan> {
        // outputs per batch limited by the stacked (duplicated) size
        let per_graph = self.aux_per_output + 1;
        let outs_per_batch = (self.node_budget / per_graph).max(1);
        let mut order = out_nodes.to_vec();
        rng.shuffle(&mut order);

        let mut ws = PushWorkspace::new(ds.graph.num_nodes());
        let mut batches = Vec::new();
        for chunk in order.chunks(outs_per_batch) {
            // stack per-output subgraphs as disjoint components with
            // duplicated nodes: offsets partition the local id space
            let mut nodes: Vec<u32> = Vec::new(); // global ids (dup ok)
            let mut edges: Vec<(u32, u32)> = Vec::new();
            let mut weights: Vec<f32> = Vec::new();
            // local index of each component's root (= component start,
            // since `sel[0]` is always the root)
            let mut root_locals: Vec<u32> = Vec::new();
            for &o in chunk {
                let ppr = push_ppr(&ds.graph, o, &self.push, &mut ws);
                let mut sel =
                    top_k_nodes(&ppr.nodes, &ppr.scores, per_graph);
                // root must be present and first
                if let Some(pos) = sel.iter().position(|&v| v == o) {
                    sel.swap(0, pos);
                } else {
                    sel.insert(0, o);
                    sel.truncate(per_graph);
                }
                let sg = induced_subgraph(&ds.graph, &sel);
                let off = nodes.len() as u32;
                root_locals.push(off);
                nodes.extend_from_slice(&sg.nodes);
                for (&(s, d), &w) in sg.edges.iter().zip(&sg.weights) {
                    edges.push((s + off, d + off));
                    weights.push(w);
                }
            }
            // Reorder so roots come first: build a permutation.
            let mut perm: Vec<u32> = Vec::with_capacity(nodes.len());
            let root_set: std::collections::HashSet<u32> =
                root_locals.iter().copied().collect();
            perm.extend(root_locals.iter().copied());
            perm.extend(
                (0..nodes.len() as u32).filter(|i| !root_set.contains(i)),
            );
            // inverse permutation to relabel edges
            let mut inv = vec![0u32; nodes.len()];
            for (new_i, &old_i) in perm.iter().enumerate() {
                inv[old_i as usize] = new_i as u32;
            }
            let new_nodes: Vec<u32> =
                perm.iter().map(|&i| nodes[i as usize]).collect();
            let new_edges: Vec<(u32, u32)> = edges
                .iter()
                .map(|&(s, d)| (inv[s as usize], inv[d as usize]))
                .collect();
            batches.push(BatchPlan {
                nodes: new_nodes,
                num_outputs: chunk.len(),
                edges: new_edges,
                weights,
            });
        }
        batches
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{sbm, DatasetSpec};

    #[test]
    fn stacks_duplicated_subgraphs() {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 130);
        let out: Vec<u32> = ds.splits.val[..20.min(ds.splits.val.len())].to_vec();
        let mut g = Shadow {
            aux_per_output: 8,
            node_budget: 256,
            ..Default::default()
        };
        let mut rng = Rng::new(14);
        let batches = g.plan(&ds, &out, &mut rng);
        let total_out: usize = batches.iter().map(|b| b.num_outputs).sum();
        assert_eq!(total_out, out.len());
        // outputs lead each batch and match the roots
        for b in &batches {
            assert!(b.num_nodes() <= 256 + 9);
            for &o in b.output_nodes() {
                assert!(out.contains(&o));
            }
        }
    }

    #[test]
    fn duplication_makes_batches_bigger_than_union() {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 131);
        // clustered outputs => heavy overlap => duplication visible
        let out: Vec<u32> = (0..30u32).collect();
        let mut g = Shadow {
            aux_per_output: 8,
            node_budget: 4096,
            ..Default::default()
        };
        let mut rng = Rng::new(15);
        let batches = g.plan(&ds, &out, &mut rng);
        let stacked: usize = batches.iter().map(|b| b.num_nodes()).sum();
        let union: std::collections::HashSet<u32> = batches
            .iter()
            .flat_map(|b| b.nodes.iter().copied())
            .collect();
        assert!(
            stacked as f64 > union.len() as f64 * 1.3,
            "stacked {stacked} union {}",
            union.len()
        );
    }
}
