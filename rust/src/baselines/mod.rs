//! The five baseline mini-batching methods from the paper's evaluation
//! (§5), implemented from scratch against the same [`BatchGenerator`]
//! interface so every method feeds the same AOT-compiled executables:
//!
//! * [`neighbor_sampling`] — GraphSAGE-style per-layer fanout sampling
//!   (Hamilton et al. 2017). Stochastic, resampled per epoch.
//! * [`ladies`] — Layer-Dependent Importance Sampling (Zou et al. 2019).
//!   Stochastic, layer-wise shared samples.
//! * [`graphsaint`] — GraphSAINT-RW random-walk subgraph sampling
//!   (Zeng et al. 2020). Stochastic, global.
//! * [`cluster_gcn`] — Cluster-GCN (Chiang et al. 2019): METIS partition
//!   *is* the batch; no influence-based auxiliary selection.
//! * [`shadow`] — shaDow (Zeng et al. 2021): per-output PPR subgraphs
//!   stacked independently (shared nodes are duplicated — its
//!   characteristic inefficiency).
//!
//! `full-batch` inference lives in [`crate::inference::fullgraph`] as an
//! exact sparse forward pass.

pub mod cluster_gcn;
pub mod graphsaint;
pub mod ladies;
pub mod neighbor_sampling;
pub mod shadow;

pub use cluster_gcn::ClusterGcn;
pub use graphsaint::GraphSaintRw;
pub use ladies::Ladies;
pub use neighbor_sampling::NeighborSampling;
pub use shadow::Shadow;

use crate::batching::BatchGenerator;

/// All method constructors by name — the experiment drivers' registry.
/// `aux_k` is each method's main budget knob, mapped to its natural
/// meaning (fanout, walk count, PPR k, ...).
pub fn by_name(
    name: &str,
    aux_k: usize,
    num_batches: usize,
    node_budget: usize,
) -> Option<Box<dyn BatchGenerator>> {
    use crate::batching::{fixed_random::FixedRandomBatches, BatchWiseIbmb, NodeWiseIbmb};
    let g: Box<dyn BatchGenerator> = match name {
        "node-wise IBMB" => Box::new(NodeWiseIbmb {
            aux_per_output: aux_k,
            max_outputs_per_batch: node_budget / (1 + aux_k / 4).max(1),
            node_budget,
            ..Default::default()
        }),
        "batch-wise IBMB" => Box::new(BatchWiseIbmb {
            num_batches,
            node_budget,
            ..Default::default()
        }),
        "fixed random" => Box::new(FixedRandomBatches {
            aux_per_output: aux_k,
            num_batches,
            node_budget,
            ..Default::default()
        }),
        "neighbor sampling" => Box::new(NeighborSampling {
            fanouts: vec![aux_k.max(2) / 2 + 1; 3],
            num_batches,
            node_budget,
        }),
        "LADIES" => Box::new(Ladies {
            nodes_per_layer: aux_k * 24,
            num_batches,
            node_budget,
        }),
        "GraphSAINT-RW" => Box::new(GraphSaintRw {
            walk_length: 2,
            num_steps: num_batches,
            roots_per_batch: (node_budget / 3).max(8),
            node_budget,
        }),
        "Cluster-GCN" => Box::new(ClusterGcn {
            num_batches,
            ..Default::default()
        }),
        "shaDow" => Box::new(Shadow {
            aux_per_output: aux_k,
            node_budget,
            ..Default::default()
        }),
        _ => return None,
    };
    Some(g)
}

#[cfg(test)]
mod tests {
    #[test]
    fn registry_knows_all_methods() {
        for name in [
            "node-wise IBMB",
            "batch-wise IBMB",
            "fixed random",
            "neighbor sampling",
            "LADIES",
            "GraphSAINT-RW",
            "Cluster-GCN",
            "shaDow",
        ] {
            assert!(super::by_name(name, 8, 4, 512).is_some(), "{name}");
        }
        assert!(super::by_name("bogus", 8, 4, 512).is_none());
    }
}
