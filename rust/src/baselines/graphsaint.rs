//! GraphSAINT-RW (Zeng et al. 2020): random-walk subgraph sampling.
//!
//! Each step samples `roots_per_batch` root nodes and walks
//! `walk_length` hops; the union of visited nodes induces the batch
//! subgraph. All *training* nodes inside the subgraph are outputs —
//! GraphSAINT is a *global* method that touches the whole graph
//! regardless of label rate, which is why its gap to IBMB grows in the
//! paper's Fig. 4 as training sets shrink.

use std::collections::HashSet;

use crate::batching::batch::BatchPlan;
use crate::batching::BatchGenerator;
use crate::datasets::Dataset;
use crate::graph::induced_subgraph;
use crate::util::Rng;

/// GraphSAINT random-walk sampler.
#[derive(Debug, Clone)]
pub struct GraphSaintRw {
    /// Walk length (paper Table 4: 2).
    pub walk_length: usize,
    /// Batches ("steps") per epoch.
    pub num_steps: usize,
    /// Root nodes per batch.
    pub roots_per_batch: usize,
    pub node_budget: usize,
}

impl BatchGenerator for GraphSaintRw {
    fn name(&self) -> &'static str {
        "GraphSAINT-RW"
    }
    fn is_fixed(&self) -> bool {
        false
    }

    fn plan(
        &mut self,
        ds: &Dataset,
        out_nodes: &[u32],
        rng: &mut Rng,
    ) -> Vec<BatchPlan> {
        let out_set: HashSet<u32> = out_nodes.iter().copied().collect();
        let n = ds.graph.num_nodes();
        (0..self.num_steps)
            .map(|_| {
                // roots sampled from the WHOLE graph (global method);
                // during inference the paper roots walks at out nodes —
                // we root at out nodes when they exist to guarantee
                // coverage of small output sets.
                let mut visited: Vec<u32> = Vec::new();
                let mut in_set = HashSet::new();
                for _ in 0..self.roots_per_batch {
                    let mut u = if out_set.is_empty() {
                        rng.next_below(n) as u32
                    } else if rng.next_f64() < 0.5 {
                        out_nodes[rng.next_below(out_nodes.len())]
                    } else {
                        rng.next_below(n) as u32
                    };
                    if in_set.insert(u) {
                        visited.push(u);
                    }
                    for _ in 0..self.walk_length {
                        let nbrs = ds.graph.neighbors(u);
                        if nbrs.is_empty() {
                            break;
                        }
                        u = nbrs[rng.next_below(nbrs.len())];
                        if in_set.insert(u) {
                            visited.push(u);
                        }
                    }
                    if visited.len() + self.walk_length > self.node_budget {
                        break;
                    }
                }
                // outputs = training/output nodes inside the subgraph,
                // moved to the front
                let mut outputs: Vec<u32> = visited
                    .iter()
                    .copied()
                    .filter(|v| out_set.contains(v))
                    .collect();
                let aux: Vec<u32> = visited
                    .iter()
                    .copied()
                    .filter(|v| !out_set.contains(v))
                    .collect();
                let n_out = outputs.len();
                outputs.extend(aux);
                let sg = induced_subgraph(&ds.graph, &outputs);
                BatchPlan {
                    nodes: sg.nodes,
                    num_outputs: n_out,
                    edges: sg.edges,
                    weights: sg.weights,
                }
            })
            .filter(|b| b.num_outputs > 0)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{sbm, DatasetSpec};

    #[test]
    fn batches_validate_and_outputs_lead() {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 110);
        let mut g = GraphSaintRw {
            walk_length: 2,
            num_steps: 6,
            roots_per_batch: 60,
            node_budget: 400,
        };
        let out = ds.splits.train.clone();
        let out_set: std::collections::HashSet<u32> =
            out.iter().copied().collect();
        let mut rng = Rng::new(10);
        let batches = g.plan(&ds, &out, &mut rng);
        assert!(!batches.is_empty());
        for b in &batches {
            assert!(b.validate().is_ok());
            for &o in b.output_nodes() {
                assert!(out_set.contains(&o));
            }
            for &v in &b.nodes[b.num_outputs..] {
                assert!(!out_set.contains(&v));
            }
        }
    }

    #[test]
    fn is_global_method_touching_non_train_nodes() {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 111);
        // tiny output set: GraphSAINT still visits plenty of other nodes
        let out: Vec<u32> = ds.splits.train[..5].to_vec();
        let mut g = GraphSaintRw {
            walk_length: 2,
            num_steps: 4,
            roots_per_batch: 50,
            node_budget: 400,
        };
        let mut rng = Rng::new(11);
        let batches = g.plan(&ds, &out, &mut rng);
        let aux: usize = batches
            .iter()
            .map(|b| b.num_nodes() - b.num_outputs)
            .sum();
        let outs: usize = batches.iter().map(|b| b.num_outputs).sum();
        assert!(aux > outs * 3, "aux {aux} outs {outs}");
    }
}
