//! Neighbor sampling (GraphSAGE, Hamilton et al. 2017).
//!
//! Per epoch: shuffle output nodes into batches, then BFS outward for
//! `L` layers sampling at most `fanouts[l]` neighbors per frontier node.
//! The union of sampled nodes forms the batch subgraph. The per-epoch
//! resampling and the random data access it causes are exactly the
//! overhead IBMB's precomputed cache eliminates (paper Table 7:
//! neighbor sampling is accurate but "extremely slow").

use std::collections::HashSet;

use crate::batching::batch::BatchPlan;
use crate::batching::BatchGenerator;
use crate::datasets::Dataset;
use crate::graph::induced_subgraph;
use crate::partition::random::random_partition;
use crate::util::Rng;

/// GraphSAGE-style sampler.
#[derive(Debug, Clone)]
pub struct NeighborSampling {
    /// Neighbors sampled per node, one entry per GNN layer
    /// (paper Table 3, e.g. [6, 5, 5] for GCN/arxiv).
    pub fanouts: Vec<usize>,
    pub num_batches: usize,
    pub node_budget: usize,
}

impl BatchGenerator for NeighborSampling {
    fn name(&self) -> &'static str {
        "neighbor sampling"
    }
    fn is_fixed(&self) -> bool {
        false
    }

    fn plan(
        &mut self,
        ds: &Dataset,
        out_nodes: &[u32],
        rng: &mut Rng,
    ) -> Vec<BatchPlan> {
        let partition = random_partition(out_nodes, self.num_batches, rng);
        partition
            .iter()
            .map(|outputs| {
                let mut selected: Vec<u32> = outputs.clone();
                let mut in_set: HashSet<u32> =
                    outputs.iter().copied().collect();
                let mut frontier: Vec<u32> = outputs.clone();
                for &fanout in &self.fanouts {
                    let mut next = Vec::new();
                    for &u in &frontier {
                        let nbrs = ds.graph.neighbors(u);
                        let take = fanout.min(nbrs.len());
                        for idx in rng.sample_distinct(nbrs.len(), take) {
                            let v = nbrs[idx];
                            if in_set.insert(v) {
                                if selected.len() >= self.node_budget {
                                    break;
                                }
                                selected.push(v);
                                next.push(v);
                            }
                        }
                        if selected.len() >= self.node_budget {
                            break;
                        }
                    }
                    frontier = next;
                    if selected.len() >= self.node_budget {
                        break;
                    }
                }
                let sg = induced_subgraph(&ds.graph, &selected);
                BatchPlan {
                    nodes: sg.nodes,
                    num_outputs: outputs.len(),
                    edges: sg.edges,
                    weights: sg.weights,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{sbm, DatasetSpec};

    fn run(fanouts: Vec<usize>) -> (Dataset, Vec<BatchPlan>) {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 90);
        let mut g = NeighborSampling {
            fanouts,
            num_batches: 5,
            node_budget: 400,
        };
        let out = ds.splits.train.clone();
        let mut rng = Rng::new(6);
        let b = g.plan(&ds, &out, &mut rng);
        (ds, b)
    }

    #[test]
    fn covers_outputs_and_validates() {
        let (ds, batches) = run(vec![4, 4, 4]);
        let total: usize = batches.iter().map(|b| b.num_outputs).sum();
        assert_eq!(total, ds.splits.train.len());
        for b in &batches {
            assert!(b.validate().is_ok());
            assert!(b.num_nodes() <= 400);
        }
    }

    #[test]
    fn resamples_every_epoch() {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 91);
        let mut g = NeighborSampling {
            fanouts: vec![3, 3],
            num_batches: 4,
            node_budget: 400,
        };
        let out = ds.splits.train.clone();
        let mut rng = Rng::new(7);
        let a = g.plan(&ds, &out, &mut rng);
        let b = g.plan(&ds, &out, &mut rng);
        assert!(!g.is_fixed());
        let nodes =
            |bs: &[BatchPlan]| bs.iter().flat_map(|b| b.nodes.clone()).collect::<Vec<_>>();
        assert_ne!(nodes(&a), nodes(&b));
    }

    #[test]
    fn bigger_fanout_bigger_batches() {
        let (_, small) = run(vec![2, 2]);
        let (_, big) = run(vec![8, 8]);
        let avg = |bs: &[BatchPlan]| {
            bs.iter().map(|b| b.num_nodes()).sum::<usize>() as f64
                / bs.len() as f64
        };
        assert!(avg(&big) > avg(&small));
    }
}
