//! Cluster-GCN (Chiang et al. 2019).
//!
//! The METIS partition *is* the batch: outputs are the output nodes in
//! the part, auxiliary nodes are simply all other nodes of the part.
//! No influence-based selection — the paper's §2 notes this "does not
//! select the most relevant auxiliary nodes and cannot ignore
//! irrelevant parts of the graph", which is exactly what our Fig. 4 /
//! Table 7 reproductions show (slow on small label rates, boundary
//! accuracy loss).

use crate::batching::batch::BatchPlan;
use crate::batching::BatchGenerator;
use crate::datasets::Dataset;
use crate::graph::induced_subgraph;
use crate::partition::metis::{partition_graph, MetisConfig};
use crate::util::Rng;

/// Cluster-GCN batching.
#[derive(Debug, Clone)]
pub struct ClusterGcn {
    /// Number of graph partitions == batches (paper: same as
    /// batch-wise IBMB, Table 1).
    pub num_batches: usize,
    pub metis: MetisConfig,
}

impl Default for ClusterGcn {
    fn default() -> Self {
        ClusterGcn {
            num_batches: 8,
            metis: MetisConfig::default(),
        }
    }
}

impl BatchGenerator for ClusterGcn {
    fn name(&self) -> &'static str {
        "Cluster-GCN"
    }

    fn plan(
        &mut self,
        ds: &Dataset,
        out_nodes: &[u32],
        rng: &mut Rng,
    ) -> Vec<BatchPlan> {
        let part = partition_graph(&ds.graph, self.num_batches, &self.metis, rng);
        let out_set: std::collections::HashSet<u32> =
            out_nodes.iter().copied().collect();
        let mut parts: Vec<Vec<u32>> = vec![Vec::new(); self.num_batches];
        for (u, &p) in part.iter().enumerate() {
            parts[p as usize].push(u as u32);
        }
        parts
            .into_iter()
            .filter_map(|members| {
                // outputs first, then the rest of the partition
                let mut outputs: Vec<u32> = members
                    .iter()
                    .copied()
                    .filter(|v| out_set.contains(v))
                    .collect();
                if outputs.is_empty() {
                    return None;
                }
                let n_out = outputs.len();
                outputs.extend(
                    members.iter().copied().filter(|v| !out_set.contains(v)),
                );
                let sg = induced_subgraph(&ds.graph, &outputs);
                Some(BatchPlan {
                    nodes: sg.nodes,
                    num_outputs: n_out,
                    edges: sg.edges,
                    weights: sg.weights,
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{sbm, DatasetSpec};

    #[test]
    fn covers_outputs_once_and_uses_whole_parts() {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 120);
        let mut g = ClusterGcn {
            num_batches: 5,
            ..Default::default()
        };
        let out = ds.splits.train.clone();
        let mut rng = Rng::new(12);
        let batches = g.plan(&ds, &out, &mut rng);
        let total_out: usize = batches.iter().map(|b| b.num_outputs).sum();
        assert_eq!(total_out, out.len());
        // every node of the graph appears in exactly one batch:
        // Cluster-GCN is global
        let total_nodes: usize = batches.iter().map(|b| b.num_nodes()).sum();
        assert_eq!(total_nodes, ds.graph.num_nodes());
        for b in &batches {
            assert!(b.validate().is_ok());
        }
    }

    #[test]
    fn small_label_rate_still_pays_for_whole_graph() {
        // the key contrast with IBMB (paper Fig. 4)
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 121);
        let out: Vec<u32> = ds.splits.train[..4].to_vec();
        let mut g = ClusterGcn {
            num_batches: 4,
            ..Default::default()
        };
        let mut rng = Rng::new(13);
        let batches = g.plan(&ds, &out, &mut rng);
        let total_nodes: usize = batches.iter().map(|b| b.num_nodes()).sum();
        // drags in whole partitions (~N/num_batches nodes each) despite
        // having only 4 output nodes
        assert!(
            total_nodes > 25 * out.len(),
            "{total_nodes} nodes for {} outputs",
            out.len()
        );
    }
}
