//! LADIES — Layer-Dependent Importance Sampling (Zou et al. 2019).
//!
//! Per batch and per layer, a *shared* pool of nodes is sampled from the
//! union of the current frontier's neighborhoods, with probability
//! proportional to the squared norm of the corresponding column of the
//! normalized adjacency (degree-based importance). Unlike node-wise
//! sampling, all output nodes of the batch share each layer's samples.
//! Faithful-in-spirit port: we sample node sets layer by layer and run
//! the model on the union subgraph (our artifacts are whole-model,
//! not per-layer — see DESIGN.md §3).

use std::collections::HashSet;

use crate::batching::batch::BatchPlan;
use crate::batching::BatchGenerator;
use crate::datasets::Dataset;
use crate::graph::induced_subgraph;
use crate::partition::random::random_partition;
use crate::util::Rng;

/// LADIES sampler.
#[derive(Debug, Clone)]
pub struct Ladies {
    /// Nodes sampled per layer (paper Table 2 uses tens of thousands;
    /// scaled to our datasets).
    pub nodes_per_layer: usize,
    pub num_batches: usize,
    pub node_budget: usize,
}

impl BatchGenerator for Ladies {
    fn name(&self) -> &'static str {
        "LADIES"
    }
    fn is_fixed(&self) -> bool {
        false
    }

    fn plan(
        &mut self,
        ds: &Dataset,
        out_nodes: &[u32],
        rng: &mut Rng,
    ) -> Vec<BatchPlan> {
        let layers = 3; // matches the artifact models
        let partition = random_partition(out_nodes, self.num_batches, rng);
        partition
            .iter()
            .map(|outputs| {
                let mut selected: Vec<u32> = outputs.clone();
                let mut in_set: HashSet<u32> =
                    outputs.iter().copied().collect();
                let mut frontier: Vec<u32> = outputs.clone();
                for _ in 0..layers {
                    // candidate pool: union of frontier neighborhoods
                    let mut cands: Vec<u32> = Vec::new();
                    let mut seen = HashSet::new();
                    for &u in &frontier {
                        for &v in ds.graph.neighbors(u) {
                            if !in_set.contains(&v) && seen.insert(v) {
                                cands.push(v);
                            }
                        }
                    }
                    if cands.is_empty() {
                        break;
                    }
                    // importance ∝ squared column norm of normalized adj
                    // restricted to the frontier ≈ deg-weighted
                    let weights: Vec<f64> = cands
                        .iter()
                        .map(|&v| {
                            let d = ds.graph.inv_sqrt_deg[v as usize] as f64;
                            let overlap = ds
                                .graph
                                .neighbors(v)
                                .iter()
                                .filter(|n| in_set.contains(n))
                                .count()
                                as f64;
                            (d * d * overlap).max(1e-12)
                        })
                        .collect();
                    let take = self
                        .nodes_per_layer
                        .min(cands.len())
                        .min(self.node_budget.saturating_sub(selected.len()));
                    let mut picked = Vec::with_capacity(take);
                    let mut w = weights;
                    for _ in 0..take {
                        let i = rng.weighted(&w);
                        w[i] = 0.0;
                        picked.push(cands[i]);
                    }
                    for &v in &picked {
                        in_set.insert(v);
                        selected.push(v);
                    }
                    frontier = picked;
                    if selected.len() >= self.node_budget {
                        break;
                    }
                }
                let sg = induced_subgraph(&ds.graph, &selected);
                BatchPlan {
                    nodes: sg.nodes,
                    num_outputs: outputs.len(),
                    edges: sg.edges,
                    weights: sg.weights,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{sbm, DatasetSpec};

    #[test]
    fn covers_outputs_and_respects_budget() {
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 100);
        let mut g = Ladies {
            nodes_per_layer: 50,
            num_batches: 4,
            node_budget: 300,
        };
        let out = ds.splits.train.clone();
        let mut rng = Rng::new(8);
        let batches = g.plan(&ds, &out, &mut rng);
        let total: usize = batches.iter().map(|b| b.num_outputs).sum();
        assert_eq!(total, out.len());
        for b in &batches {
            assert!(b.validate().is_ok());
            assert!(b.num_nodes() <= 300);
        }
    }

    #[test]
    fn layer_samples_are_shared_not_per_output()
    {
        // LADIES batches should be much smaller than (outputs × fanout^L)
        let ds = sbm::generate(&DatasetSpec::tiny_for_tests(), 101);
        let mut g = Ladies {
            nodes_per_layer: 30,
            num_batches: 2,
            node_budget: 4096,
        };
        let out = ds.splits.train.clone();
        let mut rng = Rng::new(9);
        let batches = g.plan(&ds, &out, &mut rng);
        for b in &batches {
            assert!(b.num_nodes() <= b.num_outputs + 3 * 30);
        }
    }
}
